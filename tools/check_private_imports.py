"""Cross-package private-import guard for ``src/repro/``.

An ``_underscore`` name is a package-internal contract. Importing one
from a *different* ``repro.<pkg>`` subpackage couples two packages
through an interface nobody promised to keep — exactly the
``serve.bc_service`` → ``approx.driver._single_host_step`` leak the
``repro.bc`` facade redesign removed. This script fails (exit 1) when
any module under ``src/repro/`` does it again:

* ``from repro.other.mod import _name``        — private symbol
* ``from repro.other import _mod`` / ``import repro.other._mod``
                                               — private module
* relative imports are resolved first; imports *within* one subpackage
  (``repro.core.mfbc`` → ``repro.core._helpers``) stay legal, as does
  aliasing a public name to a private local (``import x as _x``).

CI runs this next to ruff (see .github/workflows/ci.yml); run locally
with

    python tools/check_private_imports.py
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"
ROOT_PKG = "repro"


def _module_name(py: Path) -> str:
    """Dotted module name of a file under src/ (pkg/__init__.py → pkg)."""
    rel = py.relative_to(SRC).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _subpackage(dotted: str) -> str:
    """The ``repro.<pkg>`` grouping key: '' for repro itself and its
    top-level modules (repro.compat), else the first component below it."""
    parts = dotted.split(".")
    if len(parts) < 2 or parts[0] != ROOT_PKG:
        return ""
    return parts[1]


def _resolve_relative(importer: str, is_pkg: bool, module: str | None,
                      level: int) -> str | None:
    """Absolute dotted target of a level-N relative import, or None."""
    base = importer.split(".")
    if not is_pkg:
        base = base[:-1]
    if level > 1:
        base = base[:len(base) - (level - 1)]
    if not base:
        return None
    return ".".join(base + ([module] if module else []))


def _violations(py: Path) -> list[str]:
    importer = _module_name(py)
    importer_pkg = _subpackage(importer)
    # the importing file's *module* subpackage; __init__ of repro itself
    # has importer == "repro" → pkg "" (cross to everything below it is
    # fine: a facade package re-exporting is the public surface)
    try:
        tree = ast.parse(py.read_text(), filename=str(py))
    except SyntaxError as e:  # pragma: no cover — ruff gates syntax first
        return [f"{py}: syntax error: {e}"]
    errs: list[str] = []

    def check_target(target: str, names: list[str], lineno: int) -> None:
        if not target.startswith(ROOT_PKG + ".") and target != ROOT_PKG:
            return  # third-party / stdlib: not ours to police
        target_pkg = _subpackage(target)
        if target_pkg == importer_pkg:
            return  # same subpackage: private sharing is allowed
        # every dotted component below the root package counts — a
        # top-level private module (repro._util) is just as internal
        private = [p for p in target.split(".")[1:] if p.startswith("_")]
        private += [s for s in names
                    if s.startswith("_") and not s.startswith("__")]
        home = (f"{ROOT_PKG}.{importer_pkg}" if importer_pkg else ROOT_PKG)
        for name in private:
            errs.append(f"{py.relative_to(REPO)}:{lineno}: cross-package "
                        f"private import {name!r} from {target!r} "
                        f"(importer package {home})")

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                check_target(alias.name, [], node.lineno)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                target = _resolve_relative(
                    importer, py.name == "__init__.py", node.module,
                    node.level)
                if target is None:
                    continue
            else:
                target = node.module or ""
            check_target(target, [a.name for a in node.names], node.lineno)
    return errs


def main() -> int:
    files = sorted(p for p in (SRC / ROOT_PKG).rglob("*.py")
                   if "__pycache__" not in p.parts)
    if not files:
        print("check_private_imports: no files under src/repro",
              file=sys.stderr)
        return 1
    errors: list[str] = []
    for f in files:
        errors += _violations(f)
    if errors:
        for e in errors:
            print(f"check_private_imports: LEAK  {e}", file=sys.stderr)
        print(f"check_private_imports: {len(errors)} cross-package private "
              f"import(s) in {len(files)} file(s)", file=sys.stderr)
        return 1
    print(f"check_private_imports: OK — {len(files)} files, no "
          f"cross-package private imports")
    return 0


if __name__ == "__main__":
    sys.exit(main())
