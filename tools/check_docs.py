"""Staleness checker for the prose docs (README.md + docs/).

Architecture and algorithm specs carry HTML comments tying each section
to the source of truth they describe:

    <!-- staleness-marker: src/repro/core/dist_bc.py:prepare_mesh_batch_step -->

This script fails (exit 1) when any marker's target rots:

* the file path (relative to the repo root) no longer exists, or
* the symbol — ``def``/``class``/module-level assignment, a dotted
  ``Class.method``, or a literal ``--cli-flag`` — no longer appears in
  that file.

It also enforces coverage inside ``docs/``: every ``##`` section of every
markdown file there must contain at least one marker, so new sections
cannot be added without naming the code they document. CI runs this next
to ruff (see .github/workflows/ci.yml); run locally with

    python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MARKER = re.compile(r"<!--\s*staleness-marker:\s*([^\s:]+?)"
                    r"(?::([A-Za-z_][\w.]*|--[\w-]+))?\s*-->")
SECTION = re.compile(r"^##\s+(.+)$", re.MULTILINE)


def _symbol_defined(text: str, symbol: str) -> bool:
    """True iff ``symbol`` is still defined (or present, for flags)."""
    if symbol.startswith("--"):
        return symbol in text
    parts = symbol.split(".")
    for part in parts:
        pat = re.compile(
            rf"(?:^|\s)(?:def|class)\s+{re.escape(part)}\b"
            rf"|^{re.escape(part)}\s*[:=]", re.MULTILINE)
        if not pat.search(text):
            return False
    return True


def check_markers(md_path: Path) -> tuple[list[str], int]:
    """Returns (errors, marker count) for one markdown file."""
    errors = []
    text = md_path.read_text()
    rel = md_path.relative_to(REPO)
    markers = MARKER.findall(text)
    if not markers:
        errors.append(f"{rel}: no staleness markers at all")
    for target, symbol in markers:
        target_path = REPO / target
        if not target_path.is_file():
            errors.append(f"{rel}: marker target {target} does not exist")
            continue
        if symbol and not _symbol_defined(target_path.read_text(), symbol):
            errors.append(f"{rel}: symbol {symbol!r} not found in {target}")
    return errors, len(markers)


def check_section_coverage(md_path: Path) -> list[str]:
    """Every ## section of a docs/ file must contain >= 1 marker."""
    errors = []
    text = md_path.read_text()
    rel = md_path.relative_to(REPO)
    heads = list(SECTION.finditer(text))
    for i, head in enumerate(heads):
        end = heads[i + 1].start() if i + 1 < len(heads) else len(text)
        if not MARKER.search(text, head.end(), end):
            errors.append(f"{rel}: section {head.group(1)!r} has no "
                          f"staleness marker")
    return errors


def main() -> int:
    docs = sorted((REPO / "docs").rglob("*.md")) if (REPO / "docs").is_dir() \
        else []
    readme = REPO / "README.md"
    files = ([readme] if readme.is_file() else []) + docs
    if not files:
        print("check_docs: no README.md or docs/ found", file=sys.stderr)
        return 1
    errors: list[str] = []
    n_markers = 0
    for f in files:
        errs, n = check_markers(f)
        errors += errs
        n_markers += n
    for f in docs:
        errors += check_section_coverage(f)
    if errors:
        for e in errors:
            print(f"check_docs: STALE  {e}", file=sys.stderr)
        print(f"check_docs: {len(errors)} problem(s) in {len(files)} file(s)",
              file=sys.stderr)
        return 1
    print(f"check_docs: OK — {n_markers} markers across {len(files)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
