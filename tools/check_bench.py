"""Sanity-assert the benchmark artifacts before CI uploads them.

Extends the old inline ``BENCH_approx.json`` plan assert: every record a
downstream perf dashboard keys on must be present and well-formed, so a
refactor that silently stops recording (planner decisions, the fused
serving legs) fails CI instead of producing a hollow artifact.

* ``BENCH_approx.json`` — headline exact-vs-approx record with executed
  ``BCPlan``s (``plan``, ``plan_exact``) and the mesh-epochs comparison
  with per-leg plans. Plus the self-calibrated ``backends`` race: at
  least one recorded plan must have *executed* on the COO backend, the
  planner-routed ``auto`` leg must be calibrated and must not lose to
  the pinned legs, COO must beat dense wall-clock, the frontier-sparse
  CSR leg must beat pinned COO and carry a monotone-plausible
  frontier-occupancy trace on its executed plan, and every leg that
  records a ``measured_seconds`` next to its plan must satisfy the
  ISSUE-6 drift gate ``|predicted_seconds − measured| / measured ≤ 2``.
  Plus the ``scaling`` record merged in by ``benchmarks/bc_scaling.py``:
  chunked-ingest records with content digests, measured sources/sec legs
  (gated against ``benchmarks/baselines/scaling.json`` when a baseline
  is recorded) at R-MAT scale ≥ 18, and the HLO-measured bytes-on-wire
  per mesh shape against the §5.2 model — a loose absolute band per
  shape and a tight band on the 2D→3D reduction.
* ``BENCH_serve.json`` — the fused-vs-unfused serving sweep: both legs
  present per concurrency level, positive throughput, every run carrying
  its executed per-request ``BCPlan``s (with the bucket sets), a fused
  leg at ≥ 4 concurrent queries, and no fused-vs-unfused throughput
  regression at ≥ 2 concurrent queries. Plus the mixed-tier QoS
  scenario: per-tier p50/p95 latency for the FIFO baseline and the
  deadline-scheduler legs, the tight-ε tier's p95 strictly better under
  the scheduler, tiers recorded in the executed plans, and no
  wholesale throughput collapse between the two legs. Plus the
  ``gateway`` record merged in by ``benchmarks/bc_gateway.py``: the
  content-addressed cache hit must be well under the cold solve with a
  byte-identical payload, the looser-entry refine must flag
  ``refining=true`` and land bitwise-equal to a from-scratch tight run,
  and the overload burst must reject (or degrade) without starving the
  interactive tier. Plus the ``metrics`` record merged in by
  ``benchmarks/bc_metrics.py``: one graph upload must serve ≥ 3 distinct
  metrics through the gateway, each repeat a byte-identical cache hit
  with its executed plan recorded, the metric-keyed cache must be
  collision-free, and the mixed-metric fused leg must not regress
  against unfused.

Usage: ``python tools/check_bench.py BENCH_approx.json BENCH_serve.json``
(file kind is sniffed from the record, not the name).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def _check_plan(plan: dict, where: str) -> list:
    errors = []
    if not isinstance(plan, dict):
        return [f"{where}: plan is not a record"]
    if not plan.get("n_b", 0) > 0:
        errors.append(f"{where}: plan.n_b missing or not positive")
    if not plan.get("placement"):
        errors.append(f"{where}: plan.placement missing")
    buckets = plan.get("buckets")
    if not buckets or buckets[-1] != plan.get("n_b"):
        errors.append(f"{where}: plan.buckets missing or not capped at n_b")
    return errors


def _check_backends(bk) -> list:
    """The calibrated sparse fast-path gates (ISSUE 6 + ISSUE 9)."""
    if not bk:
        return ["approx: backends record missing (self-calibrated "
                "dense/COO/CSR race)"]
    errors = []
    legs = [l for l in ("dense", "coo", "csr", "auto") if l in bk]
    for leg in ("dense", "coo", "csr", "auto"):
        if leg not in bk:
            errors.append(f"approx.backends: {leg} leg missing")
    # (a) the COO fast path actually executed: >= 1 recorded plan ran
    # with backend="coo" (the pinned COO leg and, on a calibrated CPU/TPU
    # host, the auto-routed leg).
    if not any(bk[l].get("plan", {}).get("backend") == "coo" for l in legs):
        errors.append("approx.backends: no recorded plan executed with "
                      "backend='coo'")
    # (b) prediction drift: every executed plan recorded next to a
    # measured wall-clock must be within 2x of it.
    for leg in legs:
        pred = bk[leg].get("predicted_seconds")
        meas = bk[leg].get("measured_seconds")
        where = f"approx.backends.{leg}"
        errors += _check_plan(bk[leg].get("plan"), f"{where}.plan")
        if not (pred and meas and meas > 0):
            errors.append(f"{where}: predicted/measured seconds missing")
        elif abs(pred - meas) / meas > 2.0:
            errors.append(f"{where}: cost-model drift |{pred:.3g} - "
                          f"{meas:.3g}| / {meas:.3g} > 2")
    if errors:
        return errors
    # The routed leg must plan from measured constants and not lose to
    # both pinned legs (a router that picks the slower backend is priced
    # wrong); COO must beat dense wall-clock (the fast path pays).
    if not bk["auto"].get("calibrated"):
        errors.append("approx.backends.auto: plan not calibrated — "
                      "results/cost_calibration.json was not picked up")
    best_pinned = min(bk["dense"]["measured_seconds"],
                      bk["coo"]["measured_seconds"],
                      bk["csr"]["measured_seconds"])
    if bk["auto"]["measured_seconds"] > 1.5 * best_pinned:
        errors.append(f"approx.backends: auto leg "
                      f"({bk['auto']['measured_seconds']:.3g}s) lost to the "
                      f"best pinned backend ({best_pinned:.3g}s) by > 1.5x")
    if bk.get("coo_speedup", 0) < 1.0:
        errors.append(f"approx.backends: COO did not beat dense wall-clock "
                      f"(speedup {bk.get('coo_speedup', 0):.2f}x < 1)")
    # ISSUE 9: the frontier-sparse CSR step must beat the full-edge-list
    # COO relax wall-clock, and its executed plan must carry a plausible
    # frontier-occupancy trace (a maximal-frontier sweep starts with
    # every seeded row active and drains — first-iteration nnz >= last).
    if bk.get("csr_speedup", 0) < 1.0:
        errors.append(f"approx.backends: CSR did not beat pinned COO "
                      f"wall-clock (csr_speedup "
                      f"{bk.get('csr_speedup', 0):.2f}x < 1)")
    occ = bk["csr"].get("plan", {}).get("occupancy")
    if not occ:
        errors.append("approx.backends.csr: plan.occupancy trace missing")
    else:
        per_iter = occ.get("per_iter_bf") or []
        if not per_iter:
            errors.append("approx.backends.csr: occupancy.per_iter_bf "
                          "empty — no frontier trace recorded")
        if not occ.get("fnnz_first", 0) >= occ.get("fnnz_last", 0):
            errors.append(
                f"approx.backends.csr: occupancy not monotone-plausible "
                f"(fnnz_first {occ.get('fnnz_first')} < fnnz_last "
                f"{occ.get('fnnz_last')})")
        if not occ.get("relax_calls", 0) > 0:
            errors.append("approx.backends.csr: occupancy.relax_calls "
                          "missing or zero")
    return errors


def check_approx(rec: dict) -> list:
    errors = _check_plan(rec.get("plan"), "approx.plan")
    errors += _check_plan(rec.get("plan_exact"), "approx.plan_exact")
    errors += _check_backends(rec.get("backends"))
    me = rec.get("mesh_epochs")
    if not me:
        errors.append("approx: mesh_epochs record missing")
    else:
        for leg in ("single_host", "mesh"):
            if leg not in me:
                errors.append(f"approx.mesh_epochs: {leg} leg missing")
            else:
                errors += _check_plan(me[leg].get("plan"),
                                      f"approx.mesh_epochs.{leg}.plan")
    errors += _check_scaling(rec.get("scaling"))
    return errors


# Gates for the bc_scaling record (ISSUE 7 acceptance): the HLO-measured
# collective bytes must track the §5.2 model — a loose absolute band
# (monoid leaf counts and tie-mask doubling are deliberately unmodeled
# constants) and a tight band on the 2D→3D shape-to-shape reduction (the
# p^{1/3}-style scaling the paper claims, which constants cancel out of).
SCALING_ABS_RATIO = 8.0        # per-shape measured/model, either side
SCALING_REL_RATIO = 1.6        # measured vs model bytes *reduction*
SCALING_REGRESSION = 0.5       # sources/sec floor vs recorded baseline


def _check_scaling(sc) -> list:
    """The out-of-core ingest + communication-scaling record."""
    if not sc:
        return ["approx: scaling record missing (run benchmarks/"
                "bc_scaling.py --merge)"]
    errors = []
    ingest = {r.get("graph"): r for r in sc.get("ingest", [])}
    if len(ingest) < 2:
        errors.append("approx.scaling: need >= 2 ingest records "
                      f"(real graph + R-MAT), got {sorted(ingest)}")
    for name, r in ingest.items():
        where = f"approx.scaling.ingest[{name}]"
        if not (len(r.get("digest", "")) == 64 and r.get("n_chunks", 0) > 0):
            errors.append(f"{where}: content digest / chunk count missing")
        if not r.get("edges_per_sec", 0) > 0:
            errors.append(f"{where}: edges_per_sec missing or zero")

    legs = sc.get("legs", [])
    if not any(_rmat_scale(leg.get("graph", "")) >= 18 for leg in legs):
        errors.append("approx.scaling: no measured leg at R-MAT scale "
                      ">= 18")
    for leg in legs:
        name = leg.get("graph")
        where = f"approx.scaling.legs[{name}]"
        errors += _check_plan(leg.get("plan"), f"{where}.plan")
        if not leg.get("sources_per_sec", 0) > 0:
            errors.append(f"{where}: sources_per_sec missing or zero")
        if name in ingest and leg.get("digest") != ingest[name]["digest"]:
            errors.append(f"{where}: digest does not match its ingest "
                          "record — leg ran on different data")
        base = leg.get("baseline_sources_per_sec")
        if base and leg.get("sources_per_sec", 0) < SCALING_REGRESSION * base:
            errors.append(
                f"{where}: sources/sec regressed "
                f"({leg['sources_per_sec']:.3g} < {SCALING_REGRESSION} * "
                f"baseline {base:.3g})")

    comm = sc.get("comm")
    if not comm:
        return errors + ["approx.scaling: comm record missing"]
    if comm.get("scale", 0) < 18:
        errors.append(f"approx.scaling.comm: measured at scale "
                      f"{comm.get('scale')} < 18")
    shapes = comm.get("shapes", {})
    if len(shapes) < 2:
        errors.append(f"approx.scaling.comm: need >= 2 mesh shapes, got "
                      f"{sorted(shapes)}")
    for name, s in shapes.items():
        where = f"approx.scaling.comm[{name}]"
        wire, model = s.get("wire_bytes", 0), s.get("model_bytes", 0)
        if not (wire > 0 and model > 0):
            errors.append(f"{where}: wire/model bytes missing")
        elif not (1.0 / SCALING_ABS_RATIO
                  <= wire / model <= SCALING_ABS_RATIO):
            errors.append(f"{where}: measured/model bytes ratio "
                          f"{wire / model:.2f} outside "
                          f"[1/{SCALING_ABS_RATIO:g}, {SCALING_ABS_RATIO:g}]")
    red_m = comm.get("reduction_measured", 0)
    red_p = comm.get("reduction_model", 0)
    if not (red_m > 0 and red_p > 0):
        errors.append("approx.scaling.comm: 2D->3D reduction missing")
    else:
        if red_m <= 1.0:
            errors.append(f"approx.scaling.comm: replication did not reduce "
                          f"bytes on the wire (reduction {red_m:.2f}x)")
        rel = red_m / red_p
        if not (1.0 / SCALING_REL_RATIO <= rel <= SCALING_REL_RATIO):
            errors.append(
                f"approx.scaling.comm: measured reduction {red_m:.2f}x "
                f"deviates from the model's {red_p:.2f}x by more than "
                f"{SCALING_REL_RATIO}x")
    return errors


def _rmat_scale(name: str) -> int:
    if name.startswith("rmat_s"):
        try:
            return int(name[len("rmat_s"):].split("_")[0])
        except ValueError:
            return 0
    return 0


def check_serve(rec: dict) -> list:
    errors = []
    runs = rec.get("runs", [])
    if not runs:
        return ["serve: no runs recorded"]
    errors += _check_plan(rec.get("graph_plan"), "serve.graph_plan")
    seen = set()
    for r in runs:
        where = f"serve.run[c={r.get('concurrency')},fused={r.get('fused')}]"
        seen.add((r.get("concurrency"), bool(r.get("fused"))))
        if not r.get("sources_per_sec", 0) > 0:
            errors.append(f"{where}: sources_per_sec missing or zero")
        if not r.get("all_converged", False):
            errors.append(f"{where}: not all requests converged")
        plans = r.get("plans", [])
        if not plans:
            errors.append(f"{where}: executed BCPlans missing")
        for i, p in enumerate(plans):
            errors += _check_plan(p, f"{where}.plans[{i}]")
    levels = {c for c, _ in seen}
    for c in levels:
        for fused in (False, True):
            if (c, fused) not in seen:
                errors.append(f"serve: concurrency {c} missing the "
                              f"{'fused' if fused else 'unfused'} leg")
    if not any(c >= 4 and fused for c, fused in seen):
        errors.append("serve: no fused-throughput record at >= 4 "
                      "concurrent queries")
    # No fused regression where fusion is supposed to pay (>= 2
    # concurrent queries); 0.9 tolerates benchmark-host noise.
    for c, s in (rec.get("fused_speedup") or {}).items():
        if int(c) >= 2 and s < 0.9:
            errors.append(f"serve: fused throughput regressed at "
                          f"concurrency {c} (speedup {s:.2f} < 0.9)")
    errors += _check_mixed_tier(rec.get("mixed_tier"))
    errors += _check_gateway(rec.get("gateway"))
    errors += _check_metrics(rec.get("metrics"))
    return errors


def _check_metrics(mrec) -> list:
    """The metric-generic serving record: one upload, many analytics."""
    if not mrec:
        return ["serve: metrics record missing (run benchmarks/"
                "bc_metrics.py after bc_gateway)"]
    errors = []
    gw = mrec.get("gateway") or {}
    per = gw.get("per_metric") or {}
    if len(per) < 3:
        errors.append(f"serve.metrics: need >= 3 metrics through the "
                      f"gateway, got {sorted(per)}")
    base_metrics = {k.split(":")[0] for k in per}
    if "betweenness" not in base_metrics or len(base_metrics) < 3:
        errors.append(f"serve.metrics: expected betweenness plus >= 2 "
                      f"other metrics, got {sorted(base_metrics)}")
    for key, p in per.items():
        where = f"serve.metrics.gateway[{key}]"
        if not p.get("cache_hit", False):
            errors.append(f"{where}: identical repeat was not a cache hit")
        if not p.get("cache_identical", False):
            errors.append(f"{where}: cached payload differs from the "
                          f"cold run's")
        errors += _check_plan(p.get("plan"), f"{where}.plan")
    if not gw.get("collision_free", False):
        errors.append("serve.metrics.gateway: metric-keyed cache entries "
                      "collided (one metric's hit returned another's λ)")
    if gw.get("n_uploads", 0) != 1:
        errors.append(f"serve.metrics.gateway: expected exactly one graph "
                      f"upload, got {gw.get('n_uploads')}")
    fz = mrec.get("fused") or {}
    legs = fz.get("legs") or {}
    for leg in ("unfused", "fused"):
        r = legs.get(leg)
        where = f"serve.metrics.fused.{leg}"
        if not r:
            errors.append(f"{where}: leg missing")
            continue
        if not r.get("sources_per_sec", 0) > 0:
            errors.append(f"{where}: sources_per_sec missing or zero")
        if not r.get("all_converged", False):
            errors.append(f"{where}: not all requests converged")
        plans = r.get("plans", [])
        if not plans:
            errors.append(f"{where}: executed BCPlans missing")
        elif leg == "fused":
            # only the fused leg carries per-request plans — unfused
            # requests are sized by the graph capacity plan. Default-
            # metric plans omit the key (wire-format stability).
            recorded = {p.get("metric", "betweenness") for p in plans}
            if not recorded >= {"betweenness", "closeness"}:
                errors.append(f"{where}: plans do not record the mixed "
                              f"metrics (got {sorted(recorded)})")
        for i, p in enumerate(plans):
            errors += _check_plan(p, f"{where}.plans[{i}]")
    # fusion across metrics must pay (0.9 tolerates host noise)
    if legs and fz.get("mixed_speedup", 0) < 0.9:
        errors.append(f"serve.metrics.fused: mixed-metric fused throughput "
                      f"regressed (speedup {fz.get('mixed_speedup', 0):.2f} "
                      f"< 0.9)")
    return errors


def _check_gateway(gw) -> list:
    """The HTTP gateway record: the cache must pay, the refine contract
    must hold over the wire, and overload must never starve the tight
    tier."""
    if not gw:
        return ["serve: gateway record missing (run benchmarks/"
                "bc_gateway.py after bc_serve)"]
    errors = []
    lat = gw.get("latency")
    if not lat:
        errors.append("serve.gateway: latency record missing")
    else:
        # a cache hit skips the solver entirely — anything under 2x
        # means the cache (or the cold path) is broken, the real margin
        # is order(s) of magnitude
        if not lat.get("cached_speedup", 0) >= 2.0:
            errors.append(f"serve.gateway: cache-hit latency not well "
                          f"under cold ({lat.get('cached_speedup', 0):.1f}x "
                          f"< 2x)")
        if not lat.get("cache_identical_payload", False):
            errors.append("serve.gateway: cached repeat payload differs "
                          "from the cold run's")
        if not lat.get("refining_flagged", False):
            errors.append("serve.gateway: looser-entry hit did not flag "
                          "refining=true")
        if not lat.get("refine_bitwise", False):
            errors.append("serve.gateway: refined result != from-scratch "
                          "tight run (bitwise resume contract broken)")
        if not lat.get("refine_stale_s", 1e9) < lat.get("refine_done_s", 0):
            errors.append("serve.gateway: stale answer not faster than "
                          "the finished refinement")
    over = gw.get("overload") or {}
    for policy in ("reject", "degrade"):
        leg = over.get(policy)
        where = f"serve.gateway.overload[{policy}]"
        if not leg:
            errors.append(f"{where}: leg missing")
            continue
        tiers = leg.get("tiers", {})
        tight = tiers.get("interactive", {})
        served = (tight.get("admitted", 0) + tight.get("cache_hits", 0)
                  + tight.get("cache_refines", 0))
        if not served > 0:
            errors.append(f"{where}: overload starved the interactive "
                          f"tier (nothing served)")
        if not leg.get("tight_admit_rate", 0) >= \
                leg.get("loose_admit_rate", 1):
            errors.append(f"{where}: tight tier admitted at a lower rate "
                          f"than the flooding loose tier "
                          f"({leg.get('tight_admit_rate')} < "
                          f"{leg.get('loose_admit_rate')})")
        if policy == "reject":
            if not leg.get("rejected", 0) > 0:
                errors.append(f"{where}: burst past the horizon drew no "
                              f"429s")
            if not leg.get("degraded", 1) == 0:
                errors.append(f"{where}: reject policy must not degrade")
        else:
            if not leg.get("degraded", 0) > 0:
                errors.append(f"{where}: burst past the horizon degraded "
                              f"nothing")
            if not leg.get("rejected", 1) == 0:
                errors.append(f"{where}: degrade policy must not reject")
    return errors


def _check_mixed_tier(mt) -> list:
    """The QoS scenario: tight-tier tail latency must beat FIFO."""
    if not mt:
        return ["serve: mixed_tier record missing"]
    errors = []
    tight = mt.get("tight_tier")
    if not tight:
        return ["serve.mixed_tier: tight_tier missing"]
    legs = mt.get("legs", {})
    for leg in ("fifo", "deadline"):
        r = legs.get(leg)
        where = f"serve.mixed_tier.{leg}"
        if not r:
            errors.append(f"{where}: leg missing")
            continue
        if not r.get("sources_per_sec", 0) > 0:
            errors.append(f"{where}: sources_per_sec missing or zero")
        if not r.get("all_converged", False):
            errors.append(f"{where}: not all requests converged")
        pt = r.get("per_tier", {})
        # the tight tier plus at least one other (loose) tier, each with
        # real latency samples — tier names come from the artifact
        if len(pt) < 2:
            errors.append(f"{where}: mixed load needs >= 2 tiers, got "
                          f"{sorted(pt)}")
        for tier in {tight} | set(pt):
            if not pt.get(tier, {}).get("n", 0) > 0:
                errors.append(f"{where}: no latency record for tier "
                              f"{tier!r}")
        plans = r.get("plans", [])
        if not plans:
            errors.append(f"{where}: executed BCPlans missing")
        elif not any(p.get("tier") == tight for p in plans):
            errors.append(f"{where}: no executed plan records the "
                          f"{tight!r} tier")
        for i, p in enumerate(plans):
            errors += _check_plan(p, f"{where}.plans[{i}]")
    if errors:
        return errors
    # The tight tier's tail must beat the FIFO baseline. p95 over a
    # handful of requests is a max-like statistic, so one CI-runner
    # stall can inflate it: forgive a p95 miss of up to 10% when the
    # median corroborates the scheduler clearly working (>= 20% better)
    # — the structural margin is far larger than both budgets.
    p95_fifo = legs["fifo"]["per_tier"][tight]["p95_s"]
    p95_dl = legs["deadline"]["per_tier"][tight]["p95_s"]
    p50_fifo = legs["fifo"]["per_tier"][tight]["p50_s"]
    p50_dl = legs["deadline"]["per_tier"][tight]["p50_s"]
    improved = (p95_dl < p95_fifo
                or (p95_dl < 1.1 * p95_fifo and p50_dl < 0.8 * p50_fifo))
    if not improved:
        errors.append(f"serve.mixed_tier: tight-tier tail latency did not "
                      f"improve (p95 deadline {p95_dl:.3f}s vs fifo "
                      f"{p95_fifo:.3f}s, p50 {p50_dl:.3f}s vs "
                      f"{p50_fifo:.3f}s)")
    thr_f = legs["fifo"]["sources_per_sec"]
    thr_d = legs["deadline"]["sources_per_sec"]
    if thr_d < 0.8 * thr_f:
        errors.append(f"serve.mixed_tier: deadline leg throughput "
                      f"collapsed ({thr_d:.1f} < 0.8 * {thr_f:.1f} src/s)")
    return errors


def main(argv) -> int:
    if not argv:
        print("usage: check_bench.py BENCH_*.json ...", file=sys.stderr)
        return 2
    errors = []
    for name in argv:
        path = Path(name)
        if not path.is_file():
            errors.append(f"{name}: file not found")
            continue
        rec = json.loads(path.read_text())
        kind = "serve" if "runs" in rec else "approx"
        errs = (check_serve if kind == "serve" else check_approx)(rec)
        errors += [f"{name}: {e}" for e in errs]
        if not errs:
            print(f"check_bench: OK — {name} ({kind})")
    if errors:
        for e in errors:
            print(f"check_bench: BAD  {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
