"""Continuous-batching serving engine (slot-based, vLLM-style scheduling
at the batch level — the serving substrate for the decode cells).

A fixed pool of ``n_slots`` sequences decodes in lockstep (one jitted
``decode_step`` per tick, static shapes). Requests join free slots via a
prefill (right-padded into the shared cache at the slot row); finished
sequences (EOS or max-tokens) free their slot immediately — no
head-of-line blocking on long generations. Per-slot position masking keeps
attention correct for heterogeneous prompt lengths.

This is single-host; on a pod the same engine drives the sharded
``decode_step`` (batch dim = slots over DP) with identical scheduling
logic — scheduling is host-side and mesh-oblivious.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    pos: int = 0  # next cache position
    remaining: int = 0


class ServeEngine:
    def __init__(self, cfg: T.TransformerConfig, params, *, n_slots: int,
                 max_len: int, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = T.init_cache(cfg, n_slots, max_len)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self._tokens = np.zeros((n_slots, 1), np.int32)

        # one-slot prefill: (params, tokens(1, L), cache, slot) -> cache, tok
        def _prefill(params, tokens, cache, slot):
            ck, cv = cache
            one = (jax.lax.dynamic_slice_in_dim(ck, slot, 1, axis=1),
                   jax.lax.dynamic_slice_in_dim(cv, slot, 1, axis=1))
            logits, (nk, nv) = T.prefill(cfg, params, tokens, one)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, nk, slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, nv, slot, axis=1)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            return (ck, cv), tok

        self._prefill = jax.jit(_prefill, static_argnames=())

        # Slots at different positions decode in *position groups* (one
        # T.decode_step per distinct position). A group's step must write
        # k/v ONLY for its own rows — an unmasked write at pos would
        # corrupt the prompt history of slots already past pos — so the
        # cache update is row-masked against the pre-step cache.
        def _decode_masked(params, tok, pos, cache, row_mask):
            logits, (nk, nv) = T.decode_step(cfg, params, tok, pos, cache)
            ok, ov = cache
            mk = row_mask[None, :, None, None, None]

            def merge(new, old):
                new_at = jax.lax.dynamic_slice_in_dim(new, pos, 1, axis=2)
                old_at = jax.lax.dynamic_slice_in_dim(old, pos, 1, axis=2)
                keep = jnp.where(mk, new_at, old_at)
                return jax.lax.dynamic_update_slice_in_dim(new, keep, pos,
                                                           axis=2)

            return logits, (merge(nk, ok), merge(nv, ov))

        self._decode = jax.jit(_decode_masked)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.rid < 0]

    def _admit(self) -> None:
        for i in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            L = int(req.prompt.shape[0])
            cache, tok = self._prefill(self.params,
                                       jnp.asarray(req.prompt[None, :]),
                                       self.cache, i)
            self.cache = cache
            self.slots[i] = _Slot(rid=req.rid, pos=L, remaining=req.max_new)
            self._tokens[i, 0] = int(tok[0])
            req.out.append(int(tok[0]))
            self.active[req.rid] = req
            self._retire_if_done(i)

    def _retire_if_done(self, i: int) -> None:
        s = self.slots[i]
        if s.rid < 0:
            return
        req = self.active[s.rid]
        s.remaining -= 1
        hit_eos = self.eos_id is not None and req.out and \
            req.out[-1] == self.eos_id
        if s.remaining <= 0 or hit_eos or s.pos >= self.max_len:
            req.done = True
            self.finished.append(req)
            del self.active[s.rid]
            self.slots[i] = _Slot()

    def step(self) -> int:
        """One engine tick: admit new requests, decode one token for every
        position-group of active slots. Returns #tokens produced."""
        self._admit()
        groups: Dict[int, List[int]] = {}
        for i, s in enumerate(self.slots):
            if s.rid >= 0:
                groups.setdefault(s.pos, []).append(i)
        produced = 0
        for pos, idxs in sorted(groups.items()):
            toks = jnp.asarray(self._tokens)
            row_mask = np.zeros(self.n_slots, bool)
            row_mask[idxs] = True
            logits, self.cache = self._decode(self.params, toks,
                                              jnp.int32(pos), self.cache,
                                              jnp.asarray(row_mask))
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
            for i in idxs:
                tok = int(nxt[i])
                self._tokens[i, 0] = tok
                req = self.active[self.slots[i].rid]
                req.out.append(tok)
                self.slots[i].pos += 1
                produced += 1
                self._retire_if_done(i)
        return produced

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
