"""repro.serve — the approximate-BC serving stack, front to back.

Three layers, outermost first:

* ``gateway`` — the wire: a stdlib HTTP front (``BCGateway`` +
  ``start_gateway``) exposing submit/poll/graphs/metrics JSON
  endpoints, with overload-aware admission (predicted-seconds backlog
  vs a deadline horizon; reject or degrade) and per-tier
  ``GatewayMetrics``.
* ``cache`` — the content-addressed ``ResultCache``: finished answers
  keyed on graph digest + (δ, k, rule, tier); equal-or-tighter ε hits
  instantly, looser entries refine from their checkpoint.
* ``bc_service`` — the solver loop: ``BCService`` tick-scheduling
  ``BCRequest``s over slot-fused adaptive sampling, retiring
  ``BCResponse``s (JSON round-trippable, optionally checkpointed).

``engine.ServeEngine`` is the earlier single-graph serving loop, kept
for its tests; new code should front ``BCService``.
"""
from repro.serve.bc_service import BCRequest, BCResponse, BCService
from repro.serve.cache import HIT, MISS, REFINE, CacheEntry, ResultCache
from repro.serve.gateway import (BCGateway, GatewayConfig, GatewayMetrics,
                                 GatewayServer, start_gateway)

__all__ = [
    "BCRequest", "BCResponse", "BCService",
    "CacheEntry", "ResultCache", "HIT", "REFINE", "MISS",
    "BCGateway", "GatewayConfig", "GatewayMetrics", "GatewayServer",
    "start_gateway",
]
