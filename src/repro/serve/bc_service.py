"""Top-k central-vertices serving endpoint over approximate BC.

The request/response scheduling mirrors ``serve.engine.ServeEngine``: a
fixed pool of ``n_slots`` concurrently progressing jobs, a FIFO admission
queue, and a host-side ``step()`` tick that advances every active slot by
one unit of work — here one *sampling epoch* of the adaptive approximate-
BC driver instead of one decode token. Long-running queries (tight ε on a
big graph) therefore never block short ones (loose ε / top-k early exit):
a slot frees the moment its estimator converges, exactly the
no-head-of-line-blocking property of the decode engine.

Graphs are registered up front (like model weights); the unified
``repro.bc`` planner resolves each one to a capacity ``BCPlan`` and a
shared ``BatchExecutor`` — jitted batch step plus device-resident
adjacency — reused by every request that names the graph. On top of
that per-graph amortization the tick loop runs the two per-query
optimizations of the serving stack:

* **per-request planning** — each distinct (graph, ε, δ, rule) resolves
  its own ``BCPlan`` through ``repro.bc.plan_for_request`` (cached), so
  a loose-ε request samples small epochs instead of inheriting the
  graph-wide batch size;
* **cross-request fusion** — active slots are grouped by graph each
  tick and their epoch demand is drained through one
  ``repro.bc.BatchAssembler`` into slot-tagged fused batches for the
  executor's ``step_segmented``: several under-filled per-request
  batches become one padded batch, paying the step's fixed cost (kernel
  dispatch; on a mesh, the fused moments all-reduce) once per batch
  instead of once per request. A lone request whose batch size matches
  the executor's runs the classic per-request path, so single-query
  service answers are bit-identical to ``repro.bc.solve``'s driver.

``fuse=False`` disables both (the pre-fusion behavior, kept for the
fused-vs-unfused benchmark ``benchmarks/bc_serve.py``).

This module deliberately imports only public ``repro.bc`` names — the
facade re-exports the estimator surface — so the old private-API leak
(``approx.driver._single_host_step``) is gone; ``tools/
check_private_imports.py`` enforces that in CI.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.bc import (AdaptiveSampler, BatchAssembler, BatchExecutor,
                      BCPlan, BCQuery, LambdaEstimator, build_executor,
                      honest_converged, plan_for_request, scatter)
from repro.bc import plan as bc_plan
from repro.bc import stopping_check
from repro.graphs.formats import Graph


@dataclasses.dataclass
class BCRequest:
    rid: int
    graph: str  # registered graph name
    k: int = 10  # top-k query size
    eps: float = 0.05
    delta: float = 0.1
    rule: str = "normal"
    seed: int = 0
    max_samples: Optional[int] = None  # hard cap under the Hoeffding budget


@dataclasses.dataclass
class BCResponse:
    rid: int
    graph: str
    topk: List[int]
    lam: np.ndarray  # (k,) estimates for the top-k ids
    halfwidth: np.ndarray  # (k,) CI halfwidths (λ scale)
    n_samples: int
    n_epochs: int
    converged: bool
    seconds: float
    plan: Optional[BCPlan] = None  # the per-request plan that sized the run


@dataclasses.dataclass
class _Job:
    req: BCRequest
    sampler: AdaptiveSampler
    est: LambdaEstimator
    plan: BCPlan  # per-request plan (plan_for_request, cached)
    t0: float
    n_epochs: int = 0


class BCService:
    """Slot-scheduled approximate-BC query service.

    ``mesh=None`` lets the ``repro.bc`` planner place each graph (one
    visible device → single host); with a jax device mesh every
    registered graph's executor is the distributed moments step instead
    (identical (S1, S2, n_reach) protocol, so the slot loop never
    branches on placement). ``iters`` bounds the mesh step's static
    forward/backward sweeps (0 = graph size, always safe). Per-graph
    capacity plans are inspectable via ``plan_for(name)``, per-request
    plans via the ``plan`` field of each ``BCResponse``.

    ``run`` never drops work silently: if ``max_ticks`` expires with
    requests still queued or active, ``exhausted`` is True and
    ``pending`` lists every unfinished request.
    """

    def __init__(self, graphs: Dict[str, Graph], *, n_slots: int = 4,
                 backend: str = "dense", mesh=None, iters: int = 0,
                 fuse: bool = True):
        self.graphs = dict(graphs)
        self.backend = backend
        self.mesh = mesh
        self.iters = iters
        self.n_slots = n_slots
        self.fuse = fuse
        self.slots: List[Optional[_Job]] = [None] * n_slots
        self.queue: Deque[BCRequest] = deque()
        self.finished: List[BCResponse] = []
        self.exhausted = False  # run() hit max_ticks with work pending
        self._executors: Dict[str, BatchExecutor] = {}
        self._assemblers: Dict[str, BatchAssembler] = {}
        self._request_plans: Dict[Tuple, BCPlan] = {}

    # ------------------------------------------------------------------
    def submit(self, req: BCRequest) -> None:
        if req.graph not in self.graphs:
            raise KeyError(f"unknown graph {req.graph!r}")
        self.queue.append(req)

    def _graph_executor(self, name: str) -> BatchExecutor:
        """Capacity plan + executor per registered graph, built lazily,
        shared by every request that names the graph. Fused batches are
        capped at this executor's ``n_b``; per-request (ε, δ) sizing
        happens in ``_plan_for_request`` on top."""
        if name not in self._executors:
            g = self.graphs[name]
            pl = bc_plan(g, BCQuery(mode="approx", backend=self.backend,
                                    iters=self.iters),
                         mesh=self.mesh)
            self._executors[name] = build_executor(g, pl, mesh=self.mesh)
        return self._executors[name]

    def _assembler(self, name: str) -> BatchAssembler:
        if name not in self._assemblers:
            self._assemblers[name] = BatchAssembler(
                self._graph_executor(name))
        return self._assemblers[name]

    def _plan_for_request(self, req: BCRequest) -> BCPlan:
        """Per-request configuration search, cached by what sizes it:
        requests sharing (graph, ε, δ, rule, cap) share one plan."""
        key = (req.graph, req.eps, req.delta, req.rule, req.max_samples)
        if key not in self._request_plans:
            self._request_plans[key] = plan_for_request(
                self.graphs[req.graph], eps=req.eps, delta=req.delta,
                rule=req.rule, max_samples=req.max_samples,
                backend=self.backend, iters=self.iters, mesh=self.mesh)
        return self._request_plans[key]

    def plan_for(self, name: str):
        """The capacity ``BCPlan`` serving this graph (builds the
        executor)."""
        return self._graph_executor(name).plan

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            g = self.graphs[req.graph]
            ex = self._graph_executor(req.graph)
            # The sampler's n_b sets the request's epoch schedule (τ₀)
            # and the unfused chunking; fused batches are assembled at
            # executor capacity regardless. Without fusion fall back to
            # the graph-wide capacity plan (the pre-fusion behavior) —
            # the plan on the response is whatever actually sized the run.
            pl = (self._plan_for_request(req) if self.fuse else ex.plan)
            # Capacity-sized requests use the *executor's* n_b (mesh
            # executors round the plan's up) — exactly what solve() and
            # the pre-fusion service did, which keeps the lone-request
            # classic path bit-identical; smaller requests keep their own
            # per-request size (the executors bucket it).
            nb = (ex.n_b if pl.n_b >= ex.plan.n_b
                  else min(pl.n_b, ex.n_b))
            sampler = AdaptiveSampler(g.n, eps=req.eps, delta=req.delta,
                                      n_b=nb, cap=req.max_samples,
                                      seed=req.seed)
            est = LambdaEstimator(g.n, req.eps, req.delta, req.rule)
            self.slots[i] = _Job(req=req, sampler=sampler, est=est,
                                 plan=pl, t0=time.time())

    def _retire(self, i: int, converged: bool) -> None:
        job = self.slots[i]
        res = job.est.result(n_epochs=job.n_epochs, converged=converged)
        ids = res.topk(job.req.k)
        self.finished.append(BCResponse(
            rid=job.req.rid, graph=job.req.graph, topk=ids.tolist(),
            lam=res.lam[ids], halfwidth=res.halfwidth[ids],
            n_samples=res.n_samples, n_epochs=res.n_epochs,
            converged=res.converged,
            seconds=time.time() - job.t0, plan=job.plan))
        self.slots[i] = None

    # ------------------------------------------------------------------
    def _run_unfused(self, ex: BatchExecutor, job: _Job,
                     sources: np.ndarray) -> int:
        """The classic per-request path: chop one slot's epoch into
        sampler-sized chunks, each padded to the executor's ``n_b``."""
        nb = job.sampler.n_b
        done = 0
        for lo in range(0, sources.shape[0], nb):
            chunk = sources[lo:lo + nb]
            s1, s2, _ = ex.step(chunk, np.ones(chunk.shape[0], bool))
            job.est.update(s1, s2, int(chunk.shape[0]))
            done += int(chunk.shape[0])
        return done

    def _run_fused(self, name: str, ex: BatchExecutor,
                   demand: List[Tuple[int, np.ndarray]]) -> int:
        """Drain several slots' epoch demand through fused batches."""
        done = 0
        for fb in self._assembler(name).assemble(demand):
            s1, s2, nr = ex.step_segmented(fb.sources, fb.valid,
                                           fb.slot_ids, fb.n_slots)
            for slot, (r1, r2, _, cnt) in scatter(fb, (s1, s2, nr)).items():
                self.slots[slot].est.update(r1, r2, cnt)
            done += fb.n_valid
        return done

    def step(self) -> int:
        """One tick: admit, then advance every active slot by one epoch.

        Active slots are grouped by graph; each group resolves its
        executor once and drains all slots' source demand together —
        fused into slot-tagged batches when more than one request is
        live on the graph. Returns the number of source samples
        processed this tick.
        """
        self._admit()
        processed = 0
        by_graph: Dict[str, List[int]] = {}
        for i, job in enumerate(self.slots):
            if job is not None:
                by_graph.setdefault(job.req.graph, []).append(i)
        for name, idxs in by_graph.items():
            ex = self._graph_executor(name)  # once per graph, not per slot
            # -- demand: each live slot asks for one epoch of sources --
            demand: List[Tuple[int, np.ndarray]] = []
            epoch_of: Dict[int, int] = {}
            for i in idxs:
                job = self.slots[i]
                nxt = job.sampler.next_epoch()
                if nxt is None:
                    # Stopped or capped: certify honestly (Hoeffding
                    # budget reached, or the empirical CIs) — a cap
                    # below the budget is NOT convergence by itself.
                    self._retire(i, converged=honest_converged(job.est))
                    continue
                ei, tau_e = nxt
                epoch_of[i] = ei
                demand.append((i, job.sampler.draw(tau_e)))
            if not demand:
                continue
            # -- execute: fused across requests, or the classic path --
            lone = (len(demand) == 1
                    and self.slots[demand[0][0]].sampler.n_b == ex.n_b)
            if self.fuse and not lone:
                processed += self._run_fused(name, ex, demand)
            else:
                for i, srcs in demand:
                    processed += self._run_unfused(ex, self.slots[i], srcs)
            # -- epoch boundary: same sequential test as repro.bc.solve
            # (one hw pass per epoch, δ split across checks) so CLI and
            # service answers agree --
            for i, _ in demand:
                job = self.slots[i]
                ei = epoch_of[i]
                job.n_epochs = ei + 1
                done, _ = stopping_check(job.est, job.req.eps, job.req.k, ei)
                if done:
                    job.sampler.stop()
                    self._retire(i, converged=True)
        return processed

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def pending(self) -> List[BCRequest]:
        """Requests admitted or queued but not yet finished."""
        return ([job.req for job in self.slots if job is not None]
                + list(self.queue))

    def run(self, max_ticks: int = 10_000) -> List[BCResponse]:
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        # Never drop queued/active work silently: callers can see the
        # cut-off and the exact requests still outstanding.
        self.exhausted = bool(self.queue or self.active)
        return self.finished
