"""Top-k central-vertices serving endpoint over approximate BC.

The request/response scheduling mirrors ``serve.engine.ServeEngine``: a
fixed pool of ``n_slots`` concurrently progressing jobs, an admission
queue, and a host-side ``step()`` tick that advances active slots by
units of work — here *sampling epochs* of the adaptive approximate-BC
driver instead of decode tokens. Long-running queries (tight ε on a big
graph) therefore never block short ones (loose ε / top-k early exit): a
slot frees the moment its estimator converges.

Graphs are registered up front (like model weights); the unified
``repro.bc`` planner resolves each one to a capacity ``BCPlan`` and a
shared ``BatchExecutor`` — jitted batch step plus device-resident
adjacency — reused by every request that names the graph. On top of
that per-graph amortization the tick loop runs the per-query
optimizations of the serving stack:

* **per-request planning** — each distinct (graph, ε, δ, rule, tier)
  resolves its own ``BCPlan`` through ``repro.bc.plan_for_request``
  (cached), so a loose-ε request samples small epochs instead of
  inheriting the graph-wide batch size;
* **cross-request fusion** — active slots are grouped by graph each
  tick and their epoch demand is drained through one
  ``repro.bc.BatchAssembler`` into slot-tagged fused batches for the
  executor's ``step_segmented``: several under-filled per-request
  batches become one padded batch, paying the step's fixed cost (kernel
  dispatch; on a mesh, the fused moments all-reduce) once per batch
  instead of once per request. A lone request whose batch size matches
  the executor's runs the classic per-request path, so single-query
  service answers are bit-identical to ``repro.bc.solve``'s driver run
  over the same source stream;
* **QoS scheduling** — requests carry a latency tier
  (``priority`` ∈ ``repro.bc.TIERS``, or an explicit ``deadline_s``)
  and both admission and demand draining are deadline-aware:
  admission is earliest-deadline-first over *absolute* deadlines
  (``pack="fifo"`` restores strict submit order), which is also the
  aging rule — a queued batch-tier request's fixed deadline eventually
  undercuts every newly arriving interactive one, so loose work is
  never starved; draining orders each tick's ``(slot, sources)``
  demand through ``repro.bc.order_demand`` (deadline slack or
  per-tenant fair share) and, under a ``tick_budget``, drains
  *partially*: a tight-ε burst preempts loose-ε slots mid-epoch, whose
  remaining chunks are deferred to the next tick. Deferral is safe:
  the sampler's demand/assembly split draws each epoch's sources once
  up front (``AdaptiveSampler.draw`` is chunking-invariant), so a
  deferred chunk is the same sources it would have been undeferred.

Each admitted request samples its own RNG stream derived from
``(seed, rid)`` — two concurrent requests that share a seed (e.g. both
left it at the default 0) still draw independent source streams, so
their (ε, δ) guarantees and top-k answers stay independent. To
reproduce a request exactly, resubmit it with the same ``seed`` *and*
``rid``.

``fuse=False`` disables per-request planning and fusion (the
pre-fusion behavior, kept for the fused-vs-unfused benchmark
``benchmarks/bc_serve.py``).

This module deliberately imports only public ``repro.bc`` names — the
facade re-exports the estimator surface — so the old private-API leak
(``approx.driver._single_host_step``) is gone; ``tools/
check_private_imports.py`` enforces that in CI.
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.bc import (PACKS, TIER_DEADLINE_S, TIERS, AdaptiveSampler,
                      ApproxCheckpoint, BatchAssembler, BatchExecutor,
                      BCPlan, BCQuery, ExecutionConfig, LambdaEstimator,
                      build_executor, checkpoint_from, fuse_group,
                      honest_converged, metric_spec, order_demand,
                      plan_for_request, scatter)
from repro.bc import plan as bc_plan
from repro.bc import stopping_check
from repro.graphs.formats import Graph, graph_digest


@dataclasses.dataclass
class BCRequest:
    """One top-k BC query.

    ``priority`` names the latency tier (``repro.bc.TIERS``); the
    scheduler turns it into an absolute deadline of ``submit_time +
    deadline_s`` (tier default from ``repro.bc.TIER_DEADLINE_S`` unless
    ``deadline_s`` is given). ``tenant`` feeds the ``pack="fair"``
    drain policy. The served source stream is derived from
    ``(seed, rid)`` — identical requests with distinct rids draw
    independent streams; same (seed, rid) reproduces exactly.
    """

    rid: int
    graph: str  # registered graph name
    k: int = 10  # top-k query size
    eps: float = 0.05
    delta: float = 0.1
    rule: str = "normal"
    seed: int = 0
    max_samples: Optional[int] = None  # hard cap under the Hoeffding budget
    priority: str = "normal"  # latency tier, one of repro.bc.TIERS
    deadline_s: Optional[float] = None  # None = the tier's default
    tenant: str = "default"  # fair-share accounting key
    metric: str = "betweenness"  # repro.bc.registered_metrics()
    hops: int = 0  # hop bound, required (>=1) for bounded metrics only

    def __post_init__(self) -> None:
        if self.priority not in TIERS:
            raise ValueError(f"priority must be one of {TIERS}, "
                             f"got {self.priority!r}")
        # Same metric validation as BCQuery, but at request construction
        # — a bad metric must 400 at submit, not explode ticks later
        # inside _plan_for_request.
        spec = metric_spec(self.metric)
        if spec.bounded:
            if self.hops < 1:
                raise ValueError(f"metric {self.metric!r} needs hops >= 1, "
                                 f"got {self.hops}")
        elif self.hops:
            raise ValueError(f"hops only applies to hop-bounded metrics, "
                             f"not {self.metric!r}")
        # rid and seed feed np.random.SeedSequence entropy (the per-job
        # stream is derived from (seed, rid)), which rejects negatives —
        # fail at construction, not ticks later inside _admit.
        if self.rid < 0 or self.seed < 0:
            raise ValueError(f"rid and seed must be non-negative (they "
                             f"seed the job's RNG stream), got rid="
                             f"{self.rid} seed={self.seed}")


@dataclasses.dataclass
class BCResponse:
    rid: int
    graph: str
    topk: List[int]
    lam: np.ndarray  # (k,) estimates for the top-k ids
    halfwidth: np.ndarray  # (k,) CI halfwidths (λ scale)
    n_samples: int
    n_epochs: int
    converged: bool
    seconds: float  # admission -> retirement (service time)
    plan: Optional[BCPlan] = None  # the per-request plan that sized the run
    tier: str = "normal"  # the request's latency tier
    latency_s: float = 0.0  # submit -> retirement (what QoS is measured on)
    digest: Optional[str] = None  # content digest of the graph served
    # resumable (S1, S2, τ) estimator state, attached only when the
    # service runs with checkpoints=True (the result cache's refine
    # path). Host-side only — never serialized onto the wire.
    checkpoint: Optional[ApproxCheckpoint] = None

    def to_json(self) -> Dict:
        """JSON wire form (the gateway's result payload).

        Every numpy scalar/array is converted to a plain Python value —
        ``json.dumps`` on dataclass fields would otherwise choke on the
        ``np.float64``/``np.int64`` leaking out of the estimator — and
        Python's shortest-repr float serialization round-trips each
        float64 *exactly*, so cached payloads compare bitwise. The
        ``checkpoint`` (host-side numpy state) stays off the wire.
        """
        return {
            "rid": int(self.rid),
            "graph": str(self.graph),
            "topk": [int(v) for v in self.topk],
            "lam": [float(x) for x in np.asarray(self.lam)],
            "halfwidth": [float(x) for x in np.asarray(self.halfwidth)],
            "n_samples": int(self.n_samples),
            "n_epochs": int(self.n_epochs),
            "converged": bool(self.converged),
            "seconds": float(self.seconds),
            "plan": self.plan.to_json() if self.plan is not None else None,
            "tier": str(self.tier),
            "latency_s": float(self.latency_s),
            "digest": self.digest,
        }

    @classmethod
    def from_json(cls, d: Dict) -> "BCResponse":
        """Inverse of ``to_json`` (float64 arrays restored bit-exactly)."""
        plan = d.get("plan")
        return cls(
            rid=int(d["rid"]), graph=d["graph"],
            topk=[int(v) for v in d["topk"]],
            lam=np.asarray(d["lam"], dtype=np.float64),
            halfwidth=np.asarray(d["halfwidth"], dtype=np.float64),
            n_samples=int(d["n_samples"]), n_epochs=int(d["n_epochs"]),
            converged=bool(d["converged"]), seconds=float(d["seconds"]),
            plan=None if plan is None else BCPlan.from_json(plan),
            tier=d.get("tier", "normal"),
            latency_s=float(d.get("latency_s", 0.0)),
            digest=d.get("digest"))


@dataclasses.dataclass
class _Queued:
    """Admission-queue entry: absolute deadline + arrival order."""

    deadline: float  # absolute, on the monotonic clock
    seq: int  # arrival order (FIFO key / EDF tie-break)
    t_submit: float
    req: BCRequest


@dataclasses.dataclass
class _Job:
    req: BCRequest
    sampler: AdaptiveSampler
    est: LambdaEstimator
    plan: BCPlan  # per-request plan (plan_for_request, cached)
    t0: float  # admission time
    t_submit: float
    deadline: float  # absolute
    seq: int  # arrival order (the FIFO drain key — slot indices recycle)
    n_epochs: int = 0
    # -- partial-drain state: the epoch currently draining ----------------
    epoch_idx: Optional[int] = None  # index of the epoch backlog belongs to
    backlog: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))


class BCService:
    """Slot-scheduled approximate-BC query service with QoS tiers.

    ``mesh=None`` lets the ``repro.bc`` planner place each graph (one
    visible device → single host); with a jax device mesh every
    registered graph's executor is the distributed moments step instead
    (identical (S1, S2, n_reach) protocol, so the slot loop never
    branches on placement). ``iters`` bounds the mesh step's static
    forward/backward sweeps (0 = graph size, always safe). Per-graph
    capacity plans are inspectable via ``plan_for(name)``, per-request
    plans via the ``plan`` field of each ``BCResponse``.

    ``pack`` picks the scheduling policy (``repro.bc.PACKS``):
    ``"deadline"`` (default) admits earliest-absolute-deadline-first and
    drains each tick's demand tightest-slack-first; ``"fair"`` balances
    drained rows across request tenants; ``"fifo"`` is the legacy
    strict-arrival-order behavior. With all-default requests (one tier,
    no explicit deadlines) every policy degenerates to FIFO, so tiering
    is strictly opt-in. ``tick_budget`` caps the source samples executed
    per tick: when set, low-priority slots mid-epoch are *preempted* —
    their remaining sources are deferred to later ticks while
    tight-deadline demand drains first.

    ``run`` never drops work silently: if ``max_ticks`` expires with
    requests still queued or active, ``exhausted`` is True and
    ``pending`` lists every unfinished request.
    """

    def __init__(self, graphs: Dict[str, Graph], *, n_slots: int = 4,
                 execution: Optional[ExecutionConfig] = None,
                 backend: Optional[str] = None, mesh=None, iters: int = 0,
                 fuse: bool = True, pack: str = "deadline",
                 tick_budget: Optional[int] = None,
                 checkpoints: bool = False):
        if pack not in PACKS:
            raise ValueError(f"pack must be one of {PACKS}, got {pack!r}")
        if tick_budget is not None and tick_budget <= 0:
            raise ValueError(f"tick_budget must be positive or None, "
                             f"got {tick_budget}")
        if backend is not None:
            # Legacy string shim (pre-ExecutionConfig API). The new
            # default is execution=None — the planner picks the backend
            # per graph from the calibrated regime model, so serving
            # rides the COO fast path where it measures faster.
            warnings.warn("BCService(backend=...) is deprecated; pass "
                          "execution=ExecutionConfig(backend=...) instead",
                          DeprecationWarning, stacklevel=2)
            if execution is not None and execution.backend not in (None,
                                                                   backend):
                raise ValueError("BCService got both execution= and a "
                                 "conflicting legacy backend=")
            execution = (execution or ExecutionConfig()).resolve(
                backend=backend)
        # Registration accepts a plain Graph or a (Graph, digest) pair —
        # the out-of-core ingest path (graphs.formats.IngestResult)
        # already computed the content digest during its streaming pass,
        # so serve must not recompute it; graphs registered without one
        # get graph_digest() lazily on first use. Either way the serve
        # path and the ingest pipeline share one content identity — the
        # result cache's key.
        self.graphs: Dict[str, Graph] = {}
        self._digests: Dict[str, Optional[str]] = {}
        for name, val in graphs.items():
            if isinstance(val, tuple):
                g, dg = val
            else:
                g, dg = val, None
            self.graphs[name] = g
            self._digests[name] = dg
        self.execution = execution
        self.checkpoints = checkpoints
        self.backend = execution.backend if execution is not None else None
        self.mesh = mesh
        self.iters = iters
        self.n_slots = n_slots
        self.fuse = fuse
        self.pack = pack
        self.tick_budget = tick_budget
        self.slots: List[Optional[_Job]] = [None] * n_slots
        self.queue: List[_Queued] = []
        self.finished: List[BCResponse] = []
        self.exhausted = False  # run() hit max_ticks with work pending
        self._seq = 0
        self._served: Dict[str, int] = {}  # tenant -> rows drained (fair)
        self._executors: Dict[str, BatchExecutor] = {}
        self._assemblers: Dict[str, BatchAssembler] = {}
        self._request_plans: Dict[Tuple, BCPlan] = {}

    # ------------------------------------------------------------------
    def submit(self, req: BCRequest) -> None:
        if req.graph not in self.graphs:
            raise KeyError(f"unknown graph {req.graph!r}")
        # Monotonic clock throughout: deadlines, slack, and latencies are
        # only ever compared/subtracted internally, and a wall-clock step
        # (NTP) must not reorder EDF or produce negative latencies.
        t = time.monotonic()
        horizon = (req.deadline_s if req.deadline_s is not None
                   else TIER_DEADLINE_S[req.priority])
        self.queue.append(_Queued(deadline=t + horizon, seq=self._seq,
                                  t_submit=t, req=req))
        self._seq += 1

    def _graph_executor(self, name: str) -> BatchExecutor:
        """Capacity plan + executor per registered graph, built lazily,
        shared by every request that names the graph. Fused batches are
        capped at this executor's ``n_b``; per-request (ε, δ) sizing
        happens in ``_plan_for_request`` on top."""
        if name not in self._executors:
            g = self.graphs[name]
            pl = bc_plan(g, BCQuery(mode="approx", execution=self.execution,
                                    iters=self.iters),
                         mesh=self.mesh)
            self._executors[name] = build_executor(g, pl, mesh=self.mesh)
        return self._executors[name]

    def _assembler(self, name: str) -> BatchAssembler:
        # pack="fifo" on purpose: step() already fixed the tick's drain
        # order (order_demand over ALL graphs, before the budget cut),
        # and each graph's demand arrives here in that order — re-sorting
        # inside the assembler would re-run the policy on a mid-tick
        # ``_served`` snapshot and could disagree with the schedule that
        # allocated the budget.
        if name not in self._assemblers:
            self._assemblers[name] = BatchAssembler(
                self._graph_executor(name))
        return self._assemblers[name]

    def _plan_for_request(self, req: BCRequest) -> BCPlan:
        """Per-request configuration search, cached by what sizes (or
        tags) it: requests sharing (graph, ε, δ, rule, cap, tier,
        metric, hops) share one plan."""
        key = (req.graph, req.eps, req.delta, req.rule, req.max_samples,
               req.priority, req.metric, req.hops)
        if key not in self._request_plans:
            self._request_plans[key] = plan_for_request(
                self.graphs[req.graph], eps=req.eps, delta=req.delta,
                rule=req.rule, max_samples=req.max_samples,
                tier=req.priority, execution=self.execution,
                iters=self.iters, mesh=self.mesh,
                metric=req.metric, hops=req.hops)
        return self._request_plans[key]

    def plan_for(self, name: str):
        """The capacity ``BCPlan`` serving this graph (builds the
        executor)."""
        return self._graph_executor(name).plan

    # ------------------------------------------------- public introspection
    def executor_for(self, name: str) -> BatchExecutor:
        """The shared per-graph executor (the gateway's refine path runs
        ``repro.bc.resume_approx`` through it, so refined and scratch
        answers execute on the same jitted step + device adjacency)."""
        return self._graph_executor(name)

    def request_plan(self, req: BCRequest) -> BCPlan:
        """The per-request ``BCPlan`` a request would be sized by (what
        ``BCResponse.plan`` will carry) — the gateway prices admission
        decisions off its ``predicted_seconds`` *before* submitting."""
        return (self._plan_for_request(req) if self.fuse
                else self._graph_executor(req.graph).plan)

    def progress(self, rid: int) -> Optional[List[Tuple[int, float]]]:
        """Epoch-by-epoch ``(τ, max normalized halfwidth)`` history of an
        *active* request — the streaming partial-results hook the
        gateway's poll endpoint exposes while a job is still running.
        Returns ``None`` when no active slot carries the rid (queued, or
        already finished — the final answer supersedes partials)."""
        for job in self.slots:
            if job is not None and job.req.rid == rid:
                return list(job.est.hw_history)
        return None

    def digest(self, name: str) -> Optional[str]:
        """Content digest of a registered graph (the cache-key identity).

        Returns the digest supplied at registration (ingest already paid
        for it), else computes ``graphs.formats.graph_digest`` once and
        caches it. Stats-only registrations (``GraphStats``) carry their
        own digest field; without one — no edge arrays to hash — this
        stays ``None`` and cache-backed serving is off for that graph.
        """
        if self._digests.get(name) is None:
            g = self.graphs[name]
            if getattr(g, "digest", None):
                self._digests[name] = g.digest
            elif hasattr(g, "src"):
                self._digests[name] = graph_digest(g)
        return self._digests.get(name)

    def describe_graph(self, name: str) -> Dict:
        """One registry row (the gateway's ``GET /v1/graphs`` record)."""
        g = self.graphs[name]
        return {"name": name, "n": int(g.n), "m": int(g.m),
                "digest": self.digest(name),
                "plan": self.plan_for(name).to_json()}

    # ------------------------------------------------------- admission
    def _pop_next(self) -> _Queued:
        """Next request to admit: earliest absolute deadline (EDF) with
        arrival-order tie-break, or strict arrival order for
        ``pack="fifo"``. EDF over absolute deadlines is also the aging
        rule — a queued loose-tier request's deadline is fixed while
        newly submitted tight-tier deadlines keep moving forward, so
        after at most its own deadline horizon the loose request sorts
        first and cannot be starved."""
        if self.pack == "fifo":
            j = min(range(len(self.queue)), key=lambda k: self.queue[k].seq)
        else:
            j = min(range(len(self.queue)),
                    key=lambda k: (self.queue[k].deadline, self.queue[k].seq))
        return self.queue.pop(j)

    def _finish_fixed_point(self, q: _Queued) -> None:
        """Answer a fixed-point metric (components) at admission time.

        A label fixed point is one whole-graph sweep with no sampling
        epochs, so there is nothing for a slot to advance tick by tick —
        running it inline keeps the slot pool for the queries that need
        incremental progress. The labels land in the response's ``lam``
        channel (value = component id), halfwidths are exactly zero and
        ``converged`` is True by construction.
        """
        req = q.req
        t0 = time.monotonic()
        ex = self._graph_executor(req.graph)
        pl = (self._plan_for_request(req) if self.fuse else ex.plan)
        lam = ex.labels()
        ids = np.argsort(lam)[::-1][:req.k]
        now = time.monotonic()
        self.finished.append(BCResponse(
            rid=req.rid, graph=req.graph, topk=[int(v) for v in ids],
            lam=lam[ids], halfwidth=np.zeros(ids.shape[0]),
            n_samples=int(self.graphs[req.graph].n), n_epochs=1,
            converged=True, seconds=now - t0, plan=pl,
            tier=req.priority, latency_s=now - q.t_submit,
            digest=self.digest(req.graph)))

    def _admit(self) -> None:
        # Fixed-point metrics bypass the slot pool entirely — they are
        # answered the tick they would have been admitted, in admission
        # order, even when every slot is busy.
        fp = [q for q in self.queue
              if metric_spec(q.req.metric).fixed_point]
        if fp:
            self.queue = [q for q in self.queue
                          if not metric_spec(q.req.metric).fixed_point]
            for q in sorted(fp, key=lambda q: q.seq):
                self._finish_fixed_point(q)
        for i in range(self.n_slots):
            if self.slots[i] is not None or not self.queue:
                continue
            q = self._pop_next()
            req = q.req
            g = self.graphs[req.graph]
            ex = self._graph_executor(req.graph)
            # The sampler's n_b sets the request's epoch schedule (τ₀)
            # and the unfused chunking; fused batches are assembled at
            # executor capacity regardless. Without fusion fall back to
            # the graph-wide capacity plan (the pre-fusion behavior) —
            # the plan on the response is whatever actually sized the run.
            pl = (self._plan_for_request(req) if self.fuse else ex.plan)
            # Capacity-sized requests use the *executor's* n_b (mesh
            # executors round the plan's up) — exactly what solve() and
            # the pre-fusion service did, which keeps the lone-request
            # classic path bit-identical; smaller requests keep their own
            # per-request size (the executors bucket it).
            nb = (ex.n_b if pl.n_b >= ex.plan.n_b
                  else min(pl.n_b, ex.n_b))
            # Per-job stream from (seed, rid): concurrent requests that
            # share the default seed must not draw identical sources —
            # correlated streams silently defeat independent (ε, δ)
            # guarantees. Same (seed, rid) still reproduces exactly.
            sampler = AdaptiveSampler(g.n, eps=req.eps, delta=req.delta,
                                      n_b=nb, cap=req.max_samples,
                                      seed=(req.seed, req.rid))
            est = LambdaEstimator(g.n, req.eps, req.delta, req.rule)
            self.slots[i] = _Job(req=req, sampler=sampler, est=est,
                                 plan=pl, t0=time.monotonic(),
                                 t_submit=q.t_submit, deadline=q.deadline,
                                 seq=q.seq)

    def _retire(self, i: int, converged: bool) -> None:
        job = self.slots[i]
        res = job.est.result(n_epochs=job.n_epochs, converged=converged)
        ids = res.topk(job.req.k)
        now = time.monotonic()
        # checkpoints=True: snapshot the (S1, S2, τ) sums + sampling
        # stream so a cached answer stays *resumable* — the gateway's
        # looser-ε cache hits refine from here instead of resampling.
        ckpt = (checkpoint_from(job.est, job.sampler, n_epochs=res.n_epochs)
                if self.checkpoints else None)
        self.finished.append(BCResponse(
            rid=job.req.rid, graph=job.req.graph, topk=ids.tolist(),
            lam=res.lam[ids], halfwidth=res.halfwidth[ids],
            n_samples=res.n_samples, n_epochs=res.n_epochs,
            converged=res.converged,
            seconds=now - job.t0, plan=job.plan,
            tier=job.req.priority, latency_s=now - job.t_submit,
            digest=self.digest(job.req.graph), checkpoint=ckpt))
        self.slots[i] = None

    # ------------------------------------------------------------------
    def _run_unfused(self, ex: BatchExecutor, job: _Job,
                     sources: np.ndarray) -> int:
        """The classic per-request path: chop one slot's sources into
        sampler-sized chunks, each padded to the executor's ``n_b``."""
        nb = job.sampler.n_b
        done = 0
        for lo in range(0, sources.shape[0], nb):
            chunk = sources[lo:lo + nb]
            s1, s2, _ = ex.step(chunk, np.ones(chunk.shape[0], bool),
                                metric=job.req.metric, hops=job.req.hops)
            job.est.update(s1, s2, int(chunk.shape[0]))
            done += int(chunk.shape[0])
        return done

    def _run_fused(self, name: str, ex: BatchExecutor,
                   demand: List[Tuple[int, np.ndarray]]) -> int:
        """Drain several slots' demand (already in the tick's scheduled
        order) through fused batches.

        Demand arrives pre-grouped by ``fuse_group`` — every slot here
        shares one sweep structure (and hop bound), so a single
        ``step_segmented`` collective serves mixed metrics: the
        executor's per-row metric tags pick each slot's contribution
        formula out of the shared (Tw, Tm) sweep.
        """
        done = 0
        for fb in self._assembler(name).assemble(demand):
            metrics = tuple(self.slots[key].req.metric for key in fb.slots)
            hops = self.slots[fb.slots[0]].req.hops
            s1, s2, nr = ex.step_segmented(fb.sources, fb.valid,
                                           fb.slot_ids, fb.n_slots,
                                           metrics=metrics, hops=hops)
            for slot, (r1, r2, _, cnt) in scatter(fb, (s1, s2, nr)).items():
                self.slots[slot].est.update(r1, r2, cnt)
            done += fb.n_valid
        return done

    def step(self) -> int:
        """One tick: admit, schedule, then drain demand under the budget.

        1. **admit** queued requests into free slots (EDF with aging,
           or FIFO);
        2. **refill**: every active slot with no outstanding backlog
           asks its sampler for one epoch of demand (drawn up front —
           the RNG stream is chunking-invariant, so deferral cannot
           change which sources a request samples); samplers that are
           done (stopped or capped) retire their slot honestly;
        3. **schedule**: all slots' backlogs are ordered by the ``pack``
           policy (deadline slack / fair share / FIFO) and, if
           ``tick_budget`` is set, truncated to the budget — the tail
           keeps its remaining sources as backlog for the next tick
           (mid-epoch preemption);
        4. **execute**: the scheduled demand is grouped by graph (each
           group resolves its executor once) and drained — fused into
           slot-tagged batches when more than one request is live on
           the graph — and slots whose epoch completed run the same
           sequential ``stopping_check`` as ``repro.bc.solve``.

        Returns the number of source samples processed this tick.
        """
        self._admit()
        now = time.monotonic()
        # -- refill: one epoch of demand per idle-backlog slot ----------
        for i in range(self.n_slots):
            job = self.slots[i]
            if job is None or job.backlog.size or job.epoch_idx is not None:
                continue
            nxt = job.sampler.next_epoch()
            if nxt is None:
                # Stopped or capped: certify honestly (Hoeffding budget
                # reached, or the empirical CIs) — a cap below the
                # budget is NOT convergence by itself.
                self._retire(i, converged=honest_converged(job.est))
                continue
            ei, tau_e = nxt
            job.epoch_idx = ei
            job.backlog = job.sampler.draw(tau_e)
        # -- schedule: policy order + tick budget over ALL graphs.
        # Base order is admission order (job.seq), NOT slot index: slots
        # recycle, so under pack="fifo" with a tick budget an old
        # request in a high slot would otherwise be starved by fresh
        # admissions landing in lower slots. --
        live = sorted(((i, self.slots[i]) for i in range(self.n_slots)
                       if self.slots[i] is not None
                       and self.slots[i].backlog.size),
                      key=lambda e: e[1].seq)
        slack = {i: job.deadline - now for i, job in live}
        tenant = {i: job.req.tenant for i, job in live}
        ordered = order_demand([(i, job.backlog) for i, job in live],
                               self.pack, slack=slack, tenant=tenant,
                               served=self._served)
        remaining = (math.inf if self.tick_budget is None
                     else int(self.tick_budget))
        sched: List[Tuple[int, np.ndarray]] = []
        for i, rows in ordered:
            if remaining <= 0:
                break  # preempted: rows stay in the slot's backlog
            k = int(min(rows.size, remaining))
            sched.append((i, rows[:k]))
            self.slots[i].backlog = rows[k:]
            remaining -= k
        # -- execute per (graph, fuse group): metrics sharing one sweep
        # structure (betweenness + closeness; khop at one hop bound)
        # fuse into a single collective, mismatched structures drain as
        # separate batches (order preserved within each group) ------
        processed = 0
        by_group: Dict[Tuple[str, str], List[Tuple[int, np.ndarray]]] = {}
        for i, rows in sched:
            r = self.slots[i].req
            by_group.setdefault((r.graph, fuse_group(r.metric, r.hops)),
                                []).append((i, rows))
        for (name, _), dem in by_group.items():
            ex = self._graph_executor(name)  # once per group, not per slot
            lone = (len(dem) == 1
                    and self.slots[dem[0][0]].sampler.n_b == ex.n_b)
            if self.fuse and not lone:
                processed += self._run_fused(name, ex, dem)
            else:
                for i, srcs in dem:
                    processed += self._run_unfused(ex, self.slots[i], srcs)
            for i, rows in dem:
                t = self.slots[i].req.tenant
                self._served[t] = self._served.get(t, 0) + int(rows.size)
        # -- epoch boundary: same sequential test as repro.bc.solve
        # (one hw pass per epoch, δ split across checks) so CLI and
        # service answers agree. Only fully drained epochs are tested —
        # a preempted slot's epoch waits for its deferred chunks. --
        for i, _ in sched:
            job = self.slots[i]
            if job is None or job.backlog.size or job.epoch_idx is None:
                continue
            ei = job.epoch_idx
            job.n_epochs = ei + 1
            job.epoch_idx = None
            done, _ = stopping_check(job.est, job.req.eps, job.req.k, ei)
            if done:
                job.sampler.stop()
                self._retire(i, converged=True)
        return processed

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def pending(self) -> List[BCRequest]:
        """Requests admitted or queued but not yet finished (queued part
        in admission order)."""
        key = ((lambda q: q.seq) if self.pack == "fifo"
               else (lambda q: (q.deadline, q.seq)))
        return ([job.req for job in self.slots if job is not None]
                + [q.req for q in sorted(self.queue, key=key)])

    def run(self, max_ticks: int = 10_000) -> List[BCResponse]:
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        # Never drop queued/active work silently: callers can see the
        # cut-off and the exact requests still outstanding.
        self.exhausted = bool(self.queue or self.active)
        return self.finished
