"""Top-k central-vertices serving endpoint over approximate BC.

The request/response scheduling mirrors ``serve.engine.ServeEngine``: a
fixed pool of ``n_slots`` concurrently progressing jobs, a FIFO admission
queue, and a host-side ``step()`` tick that advances every active slot by
one unit of work — here one *sampling epoch* of the adaptive approximate-
BC driver instead of one decode token. Long-running queries (tight ε on a
big graph) therefore never block short ones (loose ε / top-k early exit):
a slot frees the moment its estimator converges, exactly the
no-head-of-line-blocking property of the decode engine.

Graphs are registered up front (like model weights); the unified
``repro.bc`` planner resolves each one to a ``BCPlan`` and a shared
``BatchExecutor`` — jitted batch step plus device-resident adjacency —
reused by every request that names the graph: the serving-side
amortization that makes "BC from millions of users" viable. With a
``mesh``, the planner pins placement to the distributed Theorem 5.1
moments step; the slot loop is executor-oblivious either way because
both executors speak the same ``step(sources, valid) -> (S1, S2,
n_reach)`` protocol.

This module deliberately imports only public ``repro.bc`` names — the
facade re-exports the estimator surface — so the old private-API leak
(``approx.driver._single_host_step``) is gone; ``tools/
check_private_imports.py`` enforces that in CI.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.bc import (AdaptiveSampler, BatchExecutor, BCQuery,
                      LambdaEstimator, build_executor)
from repro.bc import plan as bc_plan
from repro.bc import stopping_check
from repro.graphs.formats import Graph


@dataclasses.dataclass
class BCRequest:
    rid: int
    graph: str  # registered graph name
    k: int = 10  # top-k query size
    eps: float = 0.05
    delta: float = 0.1
    rule: str = "normal"
    seed: int = 0


@dataclasses.dataclass
class BCResponse:
    rid: int
    graph: str
    topk: List[int]
    lam: np.ndarray  # (k,) estimates for the top-k ids
    halfwidth: np.ndarray  # (k,) CI halfwidths (λ scale)
    n_samples: int
    n_epochs: int
    converged: bool
    seconds: float


@dataclasses.dataclass
class _Job:
    req: BCRequest
    sampler: AdaptiveSampler
    est: LambdaEstimator
    epochs: object  # iterator from sampler.epochs()
    t0: float
    n_epochs: int = 0


class BCService:
    """Slot-scheduled approximate-BC query service.

    ``mesh=None`` lets the ``repro.bc`` planner place each graph (one
    visible device → single host); with a jax device mesh every
    registered graph's executor is the distributed moments step instead
    (identical (S1, S2, n_reach) protocol, so the slot loop never
    branches on placement). ``iters`` bounds the mesh step's static
    forward/backward sweeps (0 = graph size, always safe). Per-graph
    plans are inspectable via ``plan_for(name)``.
    """

    def __init__(self, graphs: Dict[str, Graph], *, n_slots: int = 4,
                 backend: str = "dense", mesh=None, iters: int = 0):
        self.graphs = dict(graphs)
        self.backend = backend
        self.mesh = mesh
        self.iters = iters
        self.n_slots = n_slots
        self.slots: List[Optional[_Job]] = [None] * n_slots
        self.queue: Deque[BCRequest] = deque()
        self.finished: List[BCResponse] = []
        self._executors: Dict[str, BatchExecutor] = {}

    # ------------------------------------------------------------------
    def submit(self, req: BCRequest) -> None:
        if req.graph not in self.graphs:
            raise KeyError(f"unknown graph {req.graph!r}")
        self.queue.append(req)

    def _graph_executor(self, name: str) -> BatchExecutor:
        """Plan + executor per registered graph, built lazily, shared by
        every request (n_b is per-graph; per-query re-sizing is the open
        ROADMAP autotuning item)."""
        if name not in self._executors:
            g = self.graphs[name]
            pl = bc_plan(g, BCQuery(mode="approx", backend=self.backend,
                                    iters=self.iters),
                         mesh=self.mesh)
            self._executors[name] = build_executor(g, pl, mesh=self.mesh)
        return self._executors[name]

    def plan_for(self, name: str):
        """The ``BCPlan`` serving this graph (builds the executor)."""
        return self._graph_executor(name).plan

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            g = self.graphs[req.graph]
            ex = self._graph_executor(req.graph)
            sampler = AdaptiveSampler(g.n, eps=req.eps, delta=req.delta,
                                      n_b=ex.n_b, seed=req.seed)
            est = LambdaEstimator(g.n, req.eps, req.delta, req.rule)
            self.slots[i] = _Job(req=req, sampler=sampler, est=est,
                                 epochs=sampler.epochs(), t0=time.time())

    def _retire(self, i: int, converged: bool) -> None:
        job = self.slots[i]
        res = job.est.result(n_epochs=job.n_epochs, converged=converged)
        ids = res.topk(job.req.k)
        self.finished.append(BCResponse(
            rid=job.req.rid, graph=job.req.graph, topk=ids.tolist(),
            lam=res.lam[ids], halfwidth=res.halfwidth[ids],
            n_samples=res.n_samples, n_epochs=res.n_epochs,
            converged=res.converged or job.sampler.capped,
            seconds=time.time() - job.t0))
        self.slots[i] = None

    def step(self) -> int:
        """One tick: admit, then advance every active slot by one epoch.

        Returns the number of source samples processed this tick.
        """
        self._admit()
        processed = 0
        for i in range(self.n_slots):
            job = self.slots[i]
            if job is None:
                continue
            ex = self._graph_executor(job.req.graph)
            try:
                ei, batches = next(job.epochs)
            except StopIteration:
                self._retire(i, converged=job.sampler.capped)
                continue
            for b in batches:
                s1, s2, _ = ex.step(b.sources, b.valid)
                job.est.update(s1, s2, b.n_valid)
                processed += b.n_valid
            job.n_epochs = ei + 1
            # Same sequential test as repro.bc.solve (one hw pass per
            # epoch, δ split across checks) so CLI and service answers
            # agree.
            done, _ = stopping_check(job.est, job.req.eps, job.req.k, ei)
            if done:
                job.sampler.stop()
                self._retire(i, converged=True)
        return processed

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def run(self, max_ticks: int = 10_000) -> List[BCResponse]:
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
