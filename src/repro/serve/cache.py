"""Content-addressed result cache for approximate-BC serving.

Millions of users mostly ask the same things: the same graphs, the same
top-k sizes, a handful of accuracy tiers. This cache keys finished
``BCResponse`` payloads on *content identity* — the canonical graph
digest computed by the ingest pipeline (``graphs.formats.graph_digest``,
the same value ``ChunkedCSRBuilder`` accumulates during an out-of-core
pass) plus the query parameters ``(δ, k, rule, tier)`` — so a repeat
query is served in O(1) without touching the solver, and re-registering
the same graph under a different name (or re-ingesting it from disk)
still hits.

ε is deliberately *not* part of the key. Accuracy targets are ordered:
a cached answer at ε' ≤ ε satisfies an ε request outright (``HIT``),
and a cached answer at ε' > ε is still the right λ estimate — just a
looser one — so it is returned immediately as a stale answer
(``REFINE``) while the estimator resumes from its checkpointed
(S1, S2, τ) sums toward the tighter target (``repro.bc.resume_approx``).
Each key therefore stores exactly one entry: the *tightest* result seen,
with the checkpoint that makes it resumable.

The cache is a bounded LRU (``max_entries``): lookups refresh recency,
insertions past the cap evict the least-recently-used key. Everything
here is plain numpy/stdlib — no jax, no service state — so the gateway
can consult it under its request lock without touching the tick loop.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, Optional, Tuple

from repro.bc import ApproxCheckpoint

__all__ = ["CacheEntry", "ResultCache", "HIT", "REFINE", "MISS"]

# Lookup outcomes (returned next to the entry, never None-punned):
HIT = "hit"        # cached ε ≤ requested ε — serve as-is, O(1)
REFINE = "refine"  # cached ε > requested ε — serve stale + resume tighter
MISS = "miss"      # no usable entry — full solve

# (graph_digest, delta, k, rule, tier, metric): everything that changes
# the answer except ε, which the lookup orders instead of matching.
# metric is part of the key — a closeness answer and a betweenness
# answer at the same (digest, δ, k, rule, tier) are different analytics
# and must never collide. Hop-bounded metrics fold the bound into the
# metric component ("khop:3"), so distinct bounds are distinct keys too.
Key = Tuple[str, float, int, str, str, str]


@dataclasses.dataclass
class CacheEntry:
    """One cached answer: the wire payload plus what makes it resumable.

    ``payload`` is the exact ``BCResponse.to_json()`` dict of the run
    that produced it — a HIT returns it verbatim, so repeat queries see
    byte-identical results. ``eps`` is the target the payload satisfies;
    ``checkpoint`` the (S1, S2, τ) + stream snapshot a REFINE resumes
    from (None for entries whose service ran without checkpoints — those
    can only HIT, never refine).
    """

    key: Key
    eps: float
    payload: Dict
    checkpoint: Optional[ApproxCheckpoint] = None
    hits: int = 0
    refines: int = 0


class ResultCache:
    """Bounded LRU of the tightest-ε answer per content-addressed key."""

    def __init__(self, max_entries: int = 256):
        if max_entries <= 0:
            raise ValueError(f"max_entries must be positive, "
                             f"got {max_entries}")
        self.max_entries = max_entries
        self._entries: "OrderedDict[Key, CacheEntry]" = OrderedDict()
        self._lock = threading.Lock()
        self.evictions = 0
        # lifetime totals — per-entry counters die with their entry
        # (a refined put replaces the entry that served the lookups)
        self.hits = 0
        self.refines = 0
        self.misses = 0

    @staticmethod
    def key(digest: str, *, delta: float, k: int, rule: str,
            tier: str, metric: str = "betweenness") -> Key:
        return (digest, float(delta), int(k), str(rule), str(tier),
                str(metric))

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------------
    def lookup(self, digest: Optional[str], *, eps: float, delta: float,
               k: int, rule: str, tier: str, metric: str = "betweenness"
               ) -> Tuple[Optional[CacheEntry], str]:
        """Resolve one query against the cache: (entry, HIT|REFINE|MISS).

        A ``None`` digest (stats-only graph with no content identity)
        can never hit — identity is the whole point of the key. An entry
        at a looser ε than requested only refines when it carries a
        checkpoint; without one it is reported as a MISS (serving a
        looser answer with no path to the tighter target would silently
        break the ε contract).
        """
        if digest is None:
            with self._lock:
                self.misses += 1
            return None, MISS
        key = self.key(digest, delta=delta, k=k, rule=rule, tier=tier,
                       metric=metric)
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None, MISS
            self._entries.move_to_end(key)
            if entry.eps <= eps:
                entry.hits += 1
                self.hits += 1
                return entry, HIT
            if entry.checkpoint is not None:
                entry.refines += 1
                self.refines += 1
                return entry, REFINE
            self.misses += 1
            return None, MISS

    def put(self, digest: Optional[str], *, eps: float, delta: float,
            k: int, rule: str, tier: str, metric: str = "betweenness",
            payload: Dict, checkpoint: Optional[ApproxCheckpoint] = None
            ) -> Optional[CacheEntry]:
        """Insert one finished answer; keeps the tightest ε per key.

        A looser result never overwrites a tighter cached one (the
        tighter entry already serves both), so concurrent misses racing
        to fill the same key converge on the best answer. Returns the
        entry now cached under the key (None for digest-less graphs).
        """
        if digest is None:
            return None
        key = self.key(digest, delta=delta, k=k, rule=rule, tier=tier,
                       metric=metric)
        with self._lock:
            existing = self._entries.get(key)
            if existing is not None and existing.eps <= eps:
                self._entries.move_to_end(key)
                return existing
            entry = CacheEntry(key=key, eps=float(eps), payload=payload,
                               checkpoint=checkpoint)
            self._entries[key] = entry
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.evictions += 1
            return entry

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        """Counters for the metrics snapshot (O(entries), lock-held)."""
        with self._lock:
            return {
                "entries": len(self._entries),
                "max_entries": self.max_entries,
                "evictions": self.evictions,
                "hits": self.hits,
                "refines": self.refines,
                "misses": self.misses,
            }
