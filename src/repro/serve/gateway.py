"""HTTP gateway + overload-aware admission in front of ``BCService``.

The serving stack so far ends at a Python object: ``BCService.submit``
then ``tick``. This module puts a wire protocol in front of it — a
stdlib-only (``http.server``) JSON API — and composes the two pieces
that make repeated centrality queries cheap at the edge:

* the **content-addressed result cache** (``serve.cache.ResultCache``):
  finished responses keyed on the canonical graph digest + (δ, k, rule,
  tier). An equal-or-tighter-ε entry answers instantly; a looser one is
  returned immediately with ``refining=true`` while the estimator
  resumes from its checkpointed (S1, S2, τ) sums toward the tighter
  target (``repro.bc.resume_approx``) — cached samples are never thrown
  away.
* **overload-aware admission**: each miss is priced by its per-request
  plan (``BCPlan.predicted_seconds``, the §6.2 α-β cost model), and the
  gateway tracks the predicted backlog *at equal-or-tighter deadlines*
  — the work EDF will run before this request. When that exceeds the
  configured horizon the request is refused (HTTP 429 + retry-after)
  or, under ``overload="degrade"``, admitted at a looser ε recorded on
  the response. Deadline-relative backlog means a flood of batch-tier
  work can never talk the gateway into rejecting interactive requests:
  the tight tier only sees backlog that genuinely runs before it.

Endpoints (all JSON)::

    POST /v1/bc        {graph, eps?, delta?, k?, rule?, seed?,
                        priority?, deadline_s?, tenant?,
                        metric?, hops?}
                       -> 202 {rid, status} | 200 (cache) | 429 | 404
    GET  /v1/bc/{rid}  -> {rid, status: queued|running|partial|done,
                           queue_depth, result?, refining?, latency_s?,
                           progress?}   (progress while running: the
                           epoch-by-epoch CI-halfwidth history)
    GET  /v1/graphs    -> {graphs: [{name, n, m, digest, plan}]}
    GET  /v1/metrics   -> per-tier admit/reject/degrade/cache counters
                          + cache stats + queue depths + the learned
                          per-(metric, backend) admission correction

``metric`` picks the analytic (any ``repro.bc.registered_metrics()``
name — betweenness, closeness, khop + hops, components); every metric
rides the same plan → admit → slot/fuse → cache path, and cache keys
carry the metric so distinct analytics never collide.

Threading: HTTP handler threads only touch the gateway under its lock
(submit, poll, metrics — all O(pending)); a single worker thread owns
the solver side, alternating ``BCService.step()`` ticks with queued
cache refinements, so the service object itself is never entered
concurrently.
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

from repro.bc import (TIER_DEADLINE_S, TIERS, ApproxCheckpoint, metric_spec,
                      resume_approx)
from repro.serve.bc_service import BCRequest, BCResponse, BCService
from repro.serve.cache import HIT, MISS, REFINE, ResultCache

__all__ = ["GatewayConfig", "GatewayMetrics", "BCGateway",
           "GatewayServer", "start_gateway"]


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    """Gateway policy knobs (admission, overload response, cache).

    ``horizon_s`` is the admission horizon: a request is overloaded when
    the predicted seconds of pending work at equal-or-tighter deadlines,
    plus its own prediction, exceed it. ``overload`` picks the response
    — ``"reject"`` (HTTP 429 + retry-after) or ``"degrade"`` (admit at
    ``max(eps, degrade_eps)``, recorded on the response). ``refine``
    gates the looser-ε cache path; switching it off turns those lookups
    into plain misses.
    """

    horizon_s: float = 5.0
    overload: str = "reject"  # or "degrade"
    degrade_eps: float = 0.2  # ε floor a degraded request is relaxed to
    retry_after_s: Optional[float] = None  # None: computed from backlog
    cache_entries: int = 256
    refine: bool = True
    idle_sleep_s: float = 0.001  # worker sleep when no work is pending

    def __post_init__(self) -> None:
        if self.overload not in ("reject", "degrade"):
            raise ValueError(f"overload must be 'reject' or 'degrade', "
                             f"got {self.overload!r}")
        if self.horizon_s <= 0:
            raise ValueError("horizon_s must be positive")


class GatewayMetrics:
    """Per-tier admission/cache counters behind one lock.

    Everything the overload gate and the cache do is counted per latency
    tier, so the bench harness (and ``tools/check_bench.py``) can verify
    that a loose-tier flood raises loose rejects without starving the
    interactive tier.
    """

    COUNTERS = ("submitted", "admitted", "rejected", "degraded",
                "cache_hits", "cache_refines", "completed", "refined",
                "errors")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._c: Dict[str, Dict[str, int]] = {
            t: {c: 0 for c in self.COUNTERS} for t in TIERS}

    def bump(self, tier: str, counter: str, by: int = 1) -> None:
        with self._lock:
            self._c[tier][counter] += by

    def snapshot(self) -> Dict:
        with self._lock:
            tiers = {t: dict(c) for t, c in self._c.items()}
        totals = {c: sum(tiers[t][c] for t in tiers)
                  for c in self.COUNTERS}
        return {"tiers": tiers, "totals": totals}


@dataclasses.dataclass
class _GwRequest:
    """Registry entry: one submitted request's lifecycle."""

    rid: int
    tier: str
    eps: float  # effective ε (after any degrade)
    status: str  # queued | running | partial | done | error
    t_submit: float
    deadline_rel: float  # relative deadline used for admission
    predicted_s: float = 0.0
    # cache-key params (with eps/tier): what the finished answer is
    # cached under when the service retires it
    delta: float = 0.1
    k: int = 10
    rule: str = "normal"
    metric: str = "betweenness"
    hops: int = 0
    result: Optional[Dict] = None  # BCResponse.to_json payload
    cached: bool = False
    refining: bool = False
    refined: bool = False
    degraded_from: Optional[float] = None  # original ε if degraded
    error: Optional[str] = None
    latency_s: Optional[float] = None


@dataclasses.dataclass
class _RefineJob:
    """One queued background refinement (looser cache entry → tight ε)."""

    rid: int
    req: BCRequest
    checkpoint: ApproxCheckpoint
    digest: str
    t_submit: float


class BCGateway:
    """The gateway core: cache → admission → service, plus the registry.

    Owns a ``BCService`` (which should run with ``checkpoints=True`` —
    without checkpoints finished answers still cache, but looser entries
    can only HIT, never refine) and a ``ResultCache``. All public
    methods are thread-safe; the solver only ever runs on the worker
    thread (``start``/``close``), or inline via ``drain`` for
    single-threaded tests.
    """

    def __init__(self, service: BCService,
                 config: Optional[GatewayConfig] = None):
        self.service = service
        self.config = config or GatewayConfig()
        self.cache = ResultCache(max_entries=self.config.cache_entries)
        self.metrics = GatewayMetrics()
        self._lock = threading.RLock()
        self._requests: Dict[int, _GwRequest] = {}
        self._refines: List[_RefineJob] = []
        self._next_rid = 0
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None
        # Admission recalibration: EWMA of observed latency_s /
        # predicted_seconds per (metric, backend), multiplied into each
        # miss's predicted cost before the horizon test. The α-β model
        # prices relative work well but its absolute scale drifts with
        # the machine; a consistently slow solver inflates the factor
        # above 1 and the horizon tightens to match reality.
        self._correction: Dict[Tuple[str, str], float] = {}

    _EWMA_ALPHA = 0.3  # smoothing for the admission correction factor

    def _observe_latency(self, metric: str, backend: str, seconds: float,
                         predicted: float) -> None:
        """Fold one finished run's observed/predicted ratio into the
        (metric, backend) admission correction EWMA. Callers hold the
        gateway lock."""
        if predicted <= 0 or seconds <= 0:
            return
        key = (metric, backend)
        ratio = seconds / predicted
        prev = self._correction.get(key)
        self._correction[key] = (
            ratio if prev is None
            else (1.0 - self._EWMA_ALPHA) * prev + self._EWMA_ALPHA * ratio)

    def _predict(self, req: BCRequest) -> float:
        """Admission price: the plan's α-β prediction scaled by the
        (metric, backend) correction learned from finished runs."""
        plan = self.service.request_plan(req)
        factor = self._correction.get((req.metric, plan.backend), 1.0)
        return float(plan.predicted_seconds) * factor

    # ------------------------------------------------------------ submit
    def submit(self, payload: Dict) -> Dict:
        """One POST /v1/bc: cache lookup → admission → service submit.

        Returns a JSON-able dict whose ``http_status`` key the HTTP
        layer peels off: 200 done-from-cache, 202 accepted (queued or
        partial-with-refinement), 429 overloaded, 400/404 bad input.
        """
        try:
            graph = payload["graph"]
        except (KeyError, TypeError):
            return {"http_status": 400, "error": "missing 'graph'"}
        if graph not in self.service.graphs:
            return {"http_status": 404,
                    "error": f"unknown graph {graph!r}",
                    "graphs": sorted(self.service.graphs)}
        tier = payload.get("priority", "normal")
        if tier not in TIERS:
            return {"http_status": 400,
                    "error": f"priority must be one of {TIERS}"}
        eps = float(payload.get("eps", 0.05))
        delta = float(payload.get("delta", 0.1))
        k = int(payload.get("k", 10))
        rule = payload.get("rule", "normal")
        seed = int(payload.get("seed", 0))
        deadline_rel = float(payload.get("deadline_s")
                             or TIER_DEADLINE_S[tier])
        tenant = payload.get("tenant", "default")
        if eps <= 0 or not (0 < delta < 1) or k <= 0:
            return {"http_status": 400,
                    "error": "need eps > 0, 0 < delta < 1, k > 0"}
        metric = payload.get("metric", "betweenness")
        hops = int(payload.get("hops", 0))
        try:
            spec = metric_spec(metric)
        except ValueError as e:
            return {"http_status": 400, "error": str(e)}
        if spec.bounded and hops < 1:
            return {"http_status": 400,
                    "error": f"metric {metric!r} needs hops >= 1"}
        if not spec.bounded and hops:
            return {"http_status": 400,
                    "error": f"hops only applies to hop-bounded metrics, "
                             f"not {metric!r}"}
        # Metric component of the cache key: distinct metrics (and
        # distinct hop bounds) must never share an entry.
        cache_metric = f"{metric}:{hops}" if spec.bounded else metric

        with self._lock:
            self.metrics.bump(tier, "submitted")
            now = time.monotonic()
            digest = self.service.digest(graph)
            entry, kind = self.cache.lookup(
                digest, eps=eps, delta=delta, k=k, rule=rule, tier=tier,
                metric=cache_metric)
            if kind == REFINE and not self.config.refine:
                entry, kind = None, MISS

            rid = self._next_rid
            self._next_rid += 1

            if kind == HIT:
                # Served verbatim from cache: the payload is the exact
                # wire form of the run that produced it (its rid names
                # that run; the top-level rid names this request).
                self.metrics.bump(tier, "cache_hits")
                self.metrics.bump(tier, "completed")
                gw = _GwRequest(rid=rid, tier=tier, eps=eps, status="done",
                                t_submit=now, deadline_rel=deadline_rel,
                                result=entry.payload, cached=True,
                                latency_s=time.monotonic() - now)
                self._requests[rid] = gw
                return {"http_status": 200, **self._status_doc(gw)}

            req = BCRequest(rid=rid, graph=graph, k=k, eps=eps,
                            delta=delta, rule=rule, seed=seed,
                            priority=tier, deadline_s=deadline_rel,
                            tenant=tenant, metric=metric, hops=hops)

            if kind == REFINE:
                # Looser entry answers now; the tighter run continues
                # from its checkpoint on the worker instead of
                # resampling from scratch.
                self.metrics.bump(tier, "cache_refines")
                gw = _GwRequest(rid=rid, tier=tier, eps=eps,
                                status="partial", t_submit=now,
                                deadline_rel=deadline_rel,
                                result=entry.payload, refining=True,
                                metric=metric, hops=hops)
                self._requests[rid] = gw
                self._refines.append(_RefineJob(
                    rid=rid, req=req, checkpoint=entry.checkpoint,
                    digest=digest, t_submit=now))
                return {"http_status": 202, **self._status_doc(gw)}

            # MISS: price the request (α-β prediction × the learned
            # (metric, backend) correction) and test the admission
            # horizon.
            pred = self._predict(req)
            backlog = self._backlog_at(deadline_rel)
            if backlog + pred > self.config.horizon_s:
                if self.config.overload == "reject":
                    self.metrics.bump(tier, "rejected")
                    retry = (self.config.retry_after_s
                             if self.config.retry_after_s is not None
                             else max(0.1,
                                      backlog + pred - self.config.horizon_s))
                    # No registry entry: a rejected request never
                    # existed as far as the solver is concerned.
                    self._next_rid = rid
                    return {"http_status": 429, "error": "overloaded",
                            "retry_after_s": round(retry, 3),
                            "backlog_s": round(backlog, 3),
                            "predicted_s": round(pred, 3),
                            "horizon_s": self.config.horizon_s}
                degraded = max(eps, self.config.degrade_eps)
                if degraded > eps:
                    self.metrics.bump(tier, "degraded")
                    req = dataclasses.replace(req, eps=degraded)
                    pred = self._predict(req)
                    gw_degraded_from: Optional[float] = eps
                    eps = degraded
                else:
                    gw_degraded_from = None
            else:
                gw_degraded_from = None

            self.metrics.bump(tier, "admitted")
            gw = _GwRequest(rid=rid, tier=tier, eps=eps, status="queued",
                            t_submit=now, deadline_rel=deadline_rel,
                            predicted_s=pred, delta=delta, k=k, rule=rule,
                            metric=metric, hops=hops,
                            degraded_from=gw_degraded_from)
            self._requests[rid] = gw
            self.service.submit(req)
            return {"http_status": 202, **self._status_doc(gw)}

    def _backlog_at(self, deadline_rel: float) -> float:
        """Predicted seconds of unfinished work EDF runs before a request
        with this relative deadline (equal-or-tighter deadlines only)."""
        return sum(gw.predicted_s for gw in self._requests.values()
                   if gw.status in ("queued", "running")
                   and gw.deadline_rel <= deadline_rel)

    # ------------------------------------------------------------- poll
    def get(self, rid: int) -> Optional[Dict]:
        """One GET /v1/bc/{rid}; None for unknown rids (HTTP 404)."""
        with self._lock:
            gw = self._requests.get(rid)
            if gw is None:
                return None
            if gw.status == "queued" and any(
                    job is not None and job.req.rid == rid
                    for job in self.service.slots):
                gw.status = "running"
            return self._status_doc(gw)

    def _status_doc(self, gw: _GwRequest) -> Dict:
        doc: Dict = {"rid": gw.rid, "status": gw.status, "tier": gw.tier,
                     "eps": gw.eps, "queue_depth": self._queue_depth()}
        if gw.degraded_from is not None:
            doc["degraded_from"] = gw.degraded_from
        if gw.status in ("queued", "running"):
            doc["predicted_s"] = round(gw.predicted_s, 4)
        if gw.status == "running":
            # Streaming partial results: the estimator's epoch-by-epoch
            # (τ, max normalized halfwidth) history, so pollers watch a
            # long run converge instead of a frozen "running". Early
            # epochs can have an undefined (infinite) halfwidth — JSON
            # has no inf, so those stream as null.
            hist = self.service.progress(gw.rid)
            if hist:
                doc["progress"] = {"epochs": [
                    {"tau": int(t),
                     "halfwidth": (float(h) if math.isfinite(h) else None)}
                    for t, h in hist]}
        if gw.refining:
            doc["refining"] = True
        if gw.result is not None:
            doc["result"] = gw.result
            doc["cached"] = gw.cached
            doc["refined"] = gw.refined
        if gw.latency_s is not None:
            doc["latency_s"] = gw.latency_s
        if gw.error is not None:
            doc["error"] = gw.error
        return doc

    def _queue_depth(self) -> Dict[str, int]:
        depth = {t: 0 for t in TIERS}
        for gw in self._requests.values():
            if gw.status in ("queued", "running", "partial"):
                depth[gw.tier] += 1
        return depth

    # ---------------------------------------------------------- listing
    def graphs(self) -> Dict:
        with self._lock:
            return {"graphs": [self.service.describe_graph(name)
                               for name in sorted(self.service.graphs)]}

    def metrics_doc(self) -> Dict:
        doc = self.metrics.snapshot()
        doc["cache"] = self.cache.stats()
        with self._lock:
            doc["queue_depth"] = self._queue_depth()
            doc["admission_correction"] = {
                f"{m}/{b}": round(v, 4)
                for (m, b), v in sorted(self._correction.items())}
        return doc

    # ------------------------------------------------------ solver side
    def drain(self, max_ticks: int = 10_000) -> None:
        """Run the solver inline until nothing is pending (test hook —
        the HTTP path uses the worker thread instead)."""
        for _ in range(max_ticks):
            if not self._work_once():
                return

    def _work_once(self) -> bool:
        """One worker beat: a service tick or one refinement. True if
        any work happened (False = idle, the worker may sleep)."""
        with self._lock:
            if self.service.queue or self.service.active:
                self.service.step()
                self._drain_finished()
                return True
            if self._refines:
                job = self._refines.pop(0)
                self._run_refine(job)
                return True
        return False

    def _drain_finished(self) -> None:
        for resp in self.service.finished:
            gw = self._requests.get(resp.rid)
            if gw is None or gw.status == "done":
                continue
            payload = resp.to_json()
            gw.result = payload
            gw.status = "done"
            gw.latency_s = time.monotonic() - gw.t_submit
            self.metrics.bump(gw.tier, "completed")
            if resp.plan is not None:
                self._observe_latency(gw.metric, resp.plan.backend,
                                      float(resp.seconds),
                                      float(resp.plan.predicted_seconds))
            # Fixed-point answers (components) are exact: cache them at
            # ε = 0 so every future ε for the key HITs outright.
            put_eps = (0.0 if metric_spec(gw.metric).fixed_point
                       else gw.eps)
            self.cache.put(resp.digest, eps=put_eps, delta=gw.delta,
                           k=gw.k, rule=gw.rule, tier=gw.tier,
                           metric=self._cache_metric(gw),
                           payload=payload, checkpoint=resp.checkpoint)
        self.service.finished.clear()

    @staticmethod
    def _cache_metric(gw: _GwRequest) -> str:
        """The metric component of a registry entry's cache key (hop
        bounds fold in — ``hops`` is nonzero iff the metric is
        bounded)."""
        return f"{gw.metric}:{gw.hops}" if gw.hops else gw.metric

    def _run_refine(self, job: _RefineJob) -> None:
        t0 = time.monotonic()
        gw = self._requests[job.rid]
        try:
            ex = self.service.executor_for(job.req.graph)
            res, ckpt = resume_approx(
                ex, job.checkpoint, eps=job.req.eps, delta=job.req.delta,
                topk=job.req.k, max_samples=job.req.max_samples,
                metric=job.req.metric, hops=job.req.hops)
            ids = res.topk(job.req.k)
            now = time.monotonic()
            resp = BCResponse(
                rid=job.rid, graph=job.req.graph, topk=ids.tolist(),
                lam=res.lam[ids], halfwidth=res.halfwidth[ids],
                n_samples=res.n_samples, n_epochs=res.n_epochs,
                converged=res.converged, seconds=now - t0,
                plan=self.service.request_plan(job.req),
                tier=job.req.priority, latency_s=now - job.t_submit,
                digest=job.digest, checkpoint=ckpt)
            payload = resp.to_json()
            self.cache.put(job.digest, eps=job.req.eps,
                           delta=job.req.delta, k=job.req.k,
                           rule=job.req.rule, tier=job.req.priority,
                           metric=(f"{job.req.metric}:{job.req.hops}"
                                   if job.req.hops else job.req.metric),
                           payload=payload, checkpoint=ckpt)
            gw.result = payload
            gw.status = "done"
            gw.refining = False
            gw.refined = True
            gw.latency_s = now - job.t_submit
            self.metrics.bump(gw.tier, "refined")
            self.metrics.bump(gw.tier, "completed")
        except Exception as e:  # surface, never kill the worker
            gw.status = "error"
            gw.refining = False
            gw.error = f"{type(e).__name__}: {e}"
            self.metrics.bump(gw.tier, "errors")

    # ----------------------------------------------------------- worker
    def start(self) -> None:
        if self._worker is not None:
            return
        self._stop.clear()
        self._worker = threading.Thread(target=self._loop,
                                        name="bc-gateway-worker",
                                        daemon=True)
        self._worker.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            if not self._work_once():
                time.sleep(self.config.idle_sleep_s)

    def close(self) -> None:
        self._stop.set()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
            self._worker = None


# ---------------------------------------------------------------- HTTP
class _Handler(BaseHTTPRequestHandler):
    """Thin JSON shim: routes to the gateway, never touches the solver."""

    server: "GatewayHTTPServer"

    def log_message(self, fmt: str, *args) -> None:  # silence stderr
        pass

    def _reply(self, status: int, doc: Dict,
               headers: Optional[Dict[str, str]] = None) -> None:
        body = json.dumps(doc).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def do_POST(self) -> None:
        if self.path.rstrip("/") != "/v1/bc":
            self._reply(404, {"error": f"no route {self.path}"})
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(length) or b"{}")
        except (ValueError, json.JSONDecodeError):
            self._reply(400, {"error": "body must be JSON"})
            return
        doc = self.server.gateway.submit(payload)
        status = doc.pop("http_status")
        headers = ({"Retry-After": str(doc["retry_after_s"])}
                   if status == 429 else None)
        self._reply(status, doc, headers)

    def do_GET(self) -> None:
        gw = self.server.gateway
        path = self.path.rstrip("/")
        if path == "/v1/graphs":
            self._reply(200, gw.graphs())
        elif path == "/v1/metrics":
            self._reply(200, gw.metrics_doc())
        elif path.startswith("/v1/bc/"):
            try:
                rid = int(path.rsplit("/", 1)[1])
            except ValueError:
                self._reply(400, {"error": "rid must be an integer"})
                return
            doc = gw.get(rid)
            if doc is None:
                self._reply(404, {"error": f"unknown rid {rid}"})
            else:
                self._reply(200, doc)
        else:
            self._reply(404, {"error": f"no route {self.path}"})


class GatewayHTTPServer(ThreadingHTTPServer):
    daemon_threads = True

    def __init__(self, addr, gateway: BCGateway):
        super().__init__(addr, _Handler)
        self.gateway = gateway


@dataclasses.dataclass
class GatewayServer:
    """A running gateway: HTTP server + worker thread, one ``close()``."""

    gateway: BCGateway
    httpd: GatewayHTTPServer
    thread: threading.Thread

    @property
    def port(self) -> int:
        return self.httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self.httpd.server_address[:2]
        return f"http://{host}:{port}"

    def close(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        self.thread.join(timeout=5.0)
        self.gateway.close()


def start_gateway(gateway: BCGateway, host: str = "127.0.0.1",
                  port: int = 0) -> GatewayServer:
    """Serve a gateway on (host, port); port 0 picks an ephemeral port.

    Starts both the HTTP listener and the gateway's solver worker;
    ``GatewayServer.close()`` tears both down.
    """
    httpd = GatewayHTTPServer((host, port), gateway)
    thread = threading.Thread(target=httpd.serve_forever,
                              name="bc-gateway-http", daemon=True)
    thread.start()
    gateway.start()
    return GatewayServer(gateway=gateway, httpd=httpd, thread=thread)
