"""Pallas TPU kernels for the MFBC compute hot spots.

The paper's hot spot is the generalized sparse matmul executed every
frontier iteration; on TPU the dense-frontier regime runs on the VPU via
the two blocked kernels here (see DESIGN.md §3 for the GPU→TPU adaptation
rationale). Validated in interpret mode against the pure-jnp oracles in
``ref.py`` over shape/dtype sweeps.
"""
from repro.kernels import ops, ref
from repro.kernels.centpath_mm import centpath_matmul_pallas
from repro.kernels.tropical_mm import multpath_matmul_pallas

__all__ = ["ops", "ref", "centpath_matmul_pallas", "multpath_matmul_pallas"]
