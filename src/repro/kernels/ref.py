"""Pure-jnp oracles for the Pallas kernels (exact semantics, naive memory)."""
from __future__ import annotations

import jax.numpy as jnp

INF = jnp.inf


def multpath_matmul_ref(fw, fm, a):
    """Naive O(nb·n·n2)-memory reference for tropical_mm."""
    cand = fw[:, :, None] + a[None, :, :]  # (nb, n, n2)
    cw = jnp.min(cand, axis=1)
    tie = (cand == cw[:, None, :]) & jnp.isfinite(cand)
    cm = jnp.sum(jnp.where(tie, fm[:, :, None], 0.0), axis=1)
    return cw, cm


def centpath_matmul_ref(fw, fp, b):
    """Naive reference for centpath_mm."""
    cand = fw[:, :, None] - b[None, :, :]
    cand = jnp.where(jnp.isfinite(fw)[:, :, None] & jnp.isfinite(b)[None, :, :],
                     cand, -INF)
    cw = jnp.max(cand, axis=1)
    tie = (cand == cw[:, None, :]) & jnp.isfinite(cand)
    cp = jnp.sum(jnp.where(tie, fp[:, :, None], 0.0), axis=1)
    cc = jnp.sum(jnp.where(tie, 1.0, 0.0), axis=1)
    return cw, cp, cc
