"""jit'd public wrappers for the Pallas kernels.

Handles (a) padding to block multiples with monoid identities so padding is
algebraically inert, (b) interpret-mode fallback on non-TPU backends (the
interpreter executes the kernel body with plain JAX ops, so it lowers to
regular HLO on CPU — used by tests and the dry-run), and (c) block-size
selection.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.centpath_mm import centpath_matmul_pallas
from repro.kernels.tropical_mm import multpath_matmul_pallas

INF = jnp.inf


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, rows, cols, fill):
    r, c = x.shape
    return jnp.pad(x, ((0, rows - r), (0, cols - c)), constant_values=fill)


def _pick_block(dim: int, pref: int) -> int:
    """Largest power-of-two block <= pref that keeps padding sane."""
    b = pref
    while b > 8 and dim < b // 2:
        b //= 2
    return b


def multpath_matmul(fw: jax.Array, fm: jax.Array, a: jax.Array, *,
                    bm: int = 128, bk: int = 128, bn: int = 128):
    """Padded/blocked multpath matmul. fw/fm: (nb, n); a: (n, n2)."""
    nb, n = fw.shape
    n2 = a.shape[1]
    bm = _pick_block(nb, bm)
    bk = _pick_block(n, bk)
    bn = _pick_block(n2, bn)
    NB, N, N2 = -(-nb // bm) * bm, -(-n // bk) * bk, -(-n2 // bn) * bn
    fw_p = _pad_to(fw, NB, N, INF)
    fm_p = _pad_to(fm, NB, N, 0.0)
    a_p = _pad_to(a, N, N2, INF)
    cw, cm = multpath_matmul_pallas(fw_p, fm_p, a_p, bm=bm, bk=bk, bn=bn,
                                    interpret=not _on_tpu())
    return cw[:nb, :n2], cm[:nb, :n2]


def centpath_matmul(fw: jax.Array, fp: jax.Array, b: jax.Array, *,
                    bm: int = 128, bk: int = 128, bn: int = 128):
    """Padded/blocked centpath matmul. fw/fp: (nb, n); b: (n, n2) (= A^T)."""
    nb, n = fw.shape
    n2 = b.shape[1]
    bm = _pick_block(nb, bm)
    bk = _pick_block(n, bk)
    bn = _pick_block(n2, bn)
    NB, N, N2 = -(-nb // bm) * bm, -(-n // bk) * bk, -(-n2 // bn) * bn
    fw_p = _pad_to(fw, NB, N, -INF)
    fp_p = _pad_to(fp, NB, N, 0.0)
    b_p = _pad_to(b, N, N2, INF)
    cw, cp, cc = centpath_matmul_pallas(fw_p, fp_p, b_p, bm=bm, bk=bk, bn=bn,
                                        interpret=not _on_tpu())
    return cw[:nb, :n2], cp[:nb, :n2], cc[:nb, :n2]
