"""Pallas TPU kernel: blocked centpath matmul (the MFBr Brandes action).

Computes ``C = F •_(⊗,g) B`` where (for the Brandes step ``B = A^T``)
``C.w(i,j) = max_k (F.w(i,k) - B(k,j))``   (inactive/no-edge -> -inf)
``C.p(i,j) = Σ_k F.p(i,k) · [tie at max]``
``C.c(i,j) = Σ_k [tie at max]``             (#children that reported)

Same VPU/VMEM structure as ``tropical_mm``; three accumulators (max-weight,
tie-summed partial centrality, tie count) stay resident in VMEM across the
k-sweep. Masking follows DESIGN.md §3: inactive frontier entries carry
``-inf`` and ``finite - inf = -inf`` loses the max-select, so no explicit
activity mask is needed inside the hot loop (weights are positive and the
frontier never holds ``+inf``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _kernel(fw_ref, fp_ref, b_ref, cw_ref, cp_ref, cc_ref, *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        cw_ref[...] = jnp.full_like(cw_ref, NEG_INF)
        cp_ref[...] = jnp.zeros_like(cp_ref)
        cc_ref[...] = jnp.zeros_like(cc_ref)

    fw = fw_ref[...]  # (bm, bk)
    fp = fp_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)

    def body(k, carry):
        accw, accp, accc = carry  # (bm, bn)
        # cand = F.w - B; -inf frontier or inf edge both yield -inf.
        cand = fw[:, k][:, None] - b[k, :][None, :]
        cand = jnp.where(jnp.isnan(cand), NEG_INF, cand)  # (-inf) - (-w) guard
        pv = fp[:, k][:, None]
        better = cand > accw
        tie = (cand == accw) & jnp.isfinite(cand)
        accp = jnp.where(better, jnp.broadcast_to(pv, accp.shape),
                         jnp.where(tie, accp + pv, accp))
        accc = jnp.where(better, jnp.ones_like(accc),
                         jnp.where(tie, accc + 1.0, accc))
        accw = jnp.maximum(accw, cand)
        return accw, accp, accc

    accw, accp, accc = jax.lax.fori_loop(
        0, bk, body, (cw_ref[...], cp_ref[...], cc_ref[...]))
    cw_ref[...] = accw
    cp_ref[...] = accp
    cc_ref[...] = accc


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def centpath_matmul_pallas(fw: jax.Array, fp: jax.Array, b: jax.Array, *,
                           bm: int = 128, bk: int = 128, bn: int = 128,
                           interpret: bool = False):
    """fw/fp: (nb, n); b: (n, n2). Returns (cw, cp, cc): (nb, n2)."""
    nb, n = fw.shape
    n2 = b.shape[1]
    assert nb % bm == 0 and n % bk == 0 and n2 % bn == 0, (fw.shape, b.shape)
    grid = (nb // bm, n2 // bn, n // bk)
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, n2), fw.dtype),
            jax.ShapeDtypeStruct((nb, n2), fp.dtype),
            jax.ShapeDtypeStruct((nb, n2), fw.dtype),
        ],
        interpret=interpret,
    )(fw, fp, b)
