"""Pallas TPU kernel: blocked multpath matmul (the MFBF Bellman-Ford action).

Computes ``C = F •_(⊕,f) A`` where
``C.w(i,j) = min_k (F.w(i,k) + A(k,j))`` and
``C.m(i,j) = Σ_k F.m(i,k) · [F.w(i,k) + A(k,j) == C.w(i,j)]``.

TPU adaptation notes (DESIGN.md §3): min-plus cannot run on the MXU, so
this is a VPU kernel. The value of the kernel is (a) HBM traffic — the
naive formulation materializes an (nb, k, n) candidate tensor in HBM per
k-block, while here candidates only ever exist as (bm, bn) vector tiles in
VMEM — and (b) keeping TWO accumulators (running min-weight + tie-summed
multiplicity) resident in VMEM across the whole k-sweep of the grid.

Grid layout: ``(i, j, k)`` with k innermost; the output BlockSpec index map
ignores k, so the same output tile is revisited and accumulated across the
k-sweep (the canonical Pallas reduction pattern). Inside the kernel an
``fori_loop`` sweeps the bk rows of the A tile one at a time, updating the
running (min, mult) pair with (bm, bn) vector ops — the 3D candidate block
is never materialized.

Block sizes default to (bm, bk, bn) = (128, 128, 128): 4 f32 tiles of
128x128 = 256 KiB live VMEM, well under the ~16 MiB/core budget, and all
dims are multiples of the 8x128 VPU lane shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = float("inf")


def _kernel(fw_ref, fm_ref, a_ref, cw_ref, cm_ref, *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        cw_ref[...] = jnp.full_like(cw_ref, INF)
        cm_ref[...] = jnp.zeros_like(cm_ref)

    fw = fw_ref[...]  # (bm, bk)
    fm = fm_ref[...]  # (bm, bk)
    a = a_ref[...]  # (bk, bn)

    def body(k, carry):
        accw, accm = carry  # (bm, bn)
        cand = fw[:, k][:, None] + a[k, :][None, :]  # (bm, bn)
        mult = fm[:, k][:, None]
        better = cand < accw
        tie = (cand == accw) & jnp.isfinite(cand)
        accm = jnp.where(better, jnp.broadcast_to(mult, accm.shape),
                         jnp.where(tie, accm + mult, accm))
        accw = jnp.minimum(accw, cand)
        return accw, accm

    accw, accm = jax.lax.fori_loop(0, bk, body, (cw_ref[...], cm_ref[...]))
    cw_ref[...] = accw
    cm_ref[...] = accm


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def multpath_matmul_pallas(fw: jax.Array, fm: jax.Array, a: jax.Array, *,
                           bm: int = 128, bk: int = 128, bn: int = 128,
                           interpret: bool = False):
    """fw/fm: (nb, n); a: (n, n2). Returns (cw, cm): (nb, n2).

    Shapes must be multiples of the block sizes (the ops.py wrapper pads).
    """
    nb, n = fw.shape
    n2 = a.shape[1]
    assert nb % bm == 0 and n % bk == 0 and n2 % bn == 0, (fw.shape, a.shape)
    grid = (nb // bm, n2 // bn, n // bk)
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, n2), fw.dtype),
            jax.ShapeDtypeStruct((nb, n2), fm.dtype),
        ],
        interpret=interpret,
    )(fw, fm, a)
