"""Adaptive approximate-BC driver over the batched MFBC step.

The driver owns the host-side loop: pull padded source batches from a
strategy (``approx.sampling``), push them through the jitted batch step —
single-host ``core.mfbc.mfbc_batch_moments`` or the distributed
``core.dist_bc`` step — and fold the per-vertex dependency moments into a
running λ estimator with confidence intervals. The stopping rule is
evaluated only at epoch boundaries (epoch-doubling, 1910.11039 §4).

Estimator. For τ uniform source samples with running sums
``S1(v) = Σ_s δ_s(v)`` and ``S2(v) = Σ_s δ_s(v)²``:

  λ̂(v)  = (n/τ)·S1(v)                      (unbiased for λ(v) = Σ_s δ_s(v))
  x̄(v)  = S1(v)/((n-2)·τ) ∈ [0, 1]         (normalized-scale mean)
  hw(v)  = CI halfwidth of x̄(v)            (Bernstein or CLT rule)

Convergence: ``max_v hw(v) ≤ ε`` — or, when a ``topk`` query is given,
the earlier of that and CI-separation of the top-k set (the relative-error
early exit: every vertex in the estimated top-k has a lower confidence
bound above the upper bound of every vertex outside it).

Batch-size selection consults the SpGEMM cost layer
(``spgemm.autotune.choose_bc_regime``): per-source step cost is flat in
``n_b`` for the dense regime, so the model picks the largest ``n_b`` that
fits the per-device memory budget and does not overshoot the first epoch —
amortizing per-batch dispatch without wasting samples.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.approx import sampling as S
from repro.core.adjacency import coo_adj_from_graph, dense_adj_from_graph
from repro.core.mfbc import mfbc_batch_moments
from repro.graphs.formats import Graph


def _topk_separated(lam: np.ndarray, halfwidth: np.ndarray, k: int) -> bool:
    """True iff the k largest estimates are CI-separated from the rest."""
    if k >= lam.shape[0]:
        return True
    order = np.argsort(lam)[::-1]
    lo = lam[order[:k]] - halfwidth[order[:k]]
    hi = lam[order[k:]] + halfwidth[order[k:]]
    return bool(lo.min() > hi.max())


@dataclasses.dataclass
class ApproxResult:
    """Outcome of one approximate-BC run (λ convention of ``core.mfbc``)."""

    lam: np.ndarray  # (n,) λ̂ estimate, unnormalized
    halfwidth: np.ndarray  # (n,) CI halfwidth, same unnormalized scale
    n_samples: int
    n_epochs: int
    converged: bool  # stopping rule met (False: hit the sample cap)
    eps: float
    delta: float
    rule: str
    has_moments: bool = True  # CIs backed by real Σδ² (always, since PR 2)

    def topk(self, k: int) -> np.ndarray:
        """Vertex ids of the k largest estimates, descending."""
        order = np.argsort(self.lam)[::-1]
        return order[:k]

    def topk_separated(self, k: int) -> bool:
        """True iff the top-k set is CI-separated from the rest."""
        return _topk_separated(self.lam, self.halfwidth, k)


class LambdaEstimator:
    """Running moments of per-source dependencies, with CIs.

    The (Σδ, Σδ²) contract: every batch step feeding this estimator —
    single-host ``core.mfbc.mfbc_batch_moments`` and the distributed
    ``core.dist_bc.prepare_mesh_batch_step(..., moments=True)`` — returns
    per-vertex first and second moments of the *unnormalized* dependency
    ``δ_s(v) ∈ [0, n-2]`` summed over the batch's valid sources:
    ``S1(v) = Σ_s δ_s(v)`` and ``S2(v) = Σ_s δ_s(v)²``. ``update`` folds
    them into running sums; halfwidths are computed on the normalized
    scale ``x_s(v) = δ_s(v)/(n-2) ∈ [0, 1]`` (divide S1 by n-2, S2 by
    (n-2)²). Since PR 2 the mesh path supplies real second moments too,
    so variance-based (Bernstein/CLT) stopping is available everywhere
    and the old first-moments-only Hoeffding fallback is gone.

    Stopping rule per code path: ``rule="bernstein"`` — rigorous
    empirical-Bernstein CIs (``sampling.bernstein_halfwidth``), the
    default of ``approx_bc`` and ``launch.bc_run --approx``;
    ``rule="normal"`` — CLT profile (``sampling.normal_halfwidth``), the
    ``serve.bc_service`` default. Both consume the same (Σδ, Σδ²) sums.
    """

    def __init__(self, n: int, eps: float, delta: float, rule: str):
        if rule not in ("bernstein", "normal"):
            raise ValueError(f"unknown stopping rule {rule!r}")
        self.n = n
        self.eps = eps
        self.delta = delta
        self.rule = rule
        self.s1 = np.zeros(n, dtype=np.float64)
        self.s2 = np.zeros(n, dtype=np.float64)
        self.tau = 0

    def update(self, s1_batch: np.ndarray, s2_batch: np.ndarray,
               n_valid: int) -> None:
        """Fold one batch's (S1, S2) sums over ``n_valid`` sources in."""
        self.s1 += s1_batch
        self.s2 += s2_batch
        self.tau += n_valid

    def _norm(self) -> float:
        return float(max(self.n - 2, 1))

    def halfwidth_normalized(self, delta: Optional[float] = None
                             ) -> np.ndarray:
        """CI halfwidth of x̄(v) on the [0, 1] normalized-dependency scale.

        The failure budget (``delta`` overrides ``self.delta`` — used by
        the sequential ``stopping_check``) is split non-uniformly across
        vertices (``sampling.allocate_delta``): empirical variance decides
        where δ is spent, so hub CIs — the ones the max over v binds on —
        shrink fastest.
        """
        d = self.delta if delta is None else delta
        c = self._norm()
        x1, x2 = self.s1 / c, self.s2 / (c * c)
        tau = max(self.tau, 2)
        mean = x1 / tau
        var = np.maximum(x2 / tau - mean * mean, 0.0)
        delta_v = S.allocate_delta(var, d)
        fn = (S.bernstein_halfwidth if self.rule == "bernstein"
              else S.normal_halfwidth)
        return fn(x1, x2, self.tau, delta_v)

    def lam_scaled(self) -> np.ndarray:
        """λ̂(v) = (n/τ)·S1(v) — unnormalized λ units.

        Same ordered-pair convention as ``core.mfbc.mfbc`` (λ(v) =
        Σ_s δ_s(v), endpoints excluded): the Horvitz–Thompson scale-up
        n/τ makes the uniform-source sample mean unbiased for λ. Divide
        by n·(n-2) to land on the normalized [0, 1] scale that ``eps``
        is quoted on.
        """
        return self.s1 * (self.n / max(self.tau, 1))

    def hw_scaled(self, hw_normalized: np.ndarray) -> np.ndarray:
        """Normalized-scale CI halfwidth → λ units (λ̂ = n·(n-2)·x̄)."""
        return hw_normalized * self.n * self._norm()

    def converged(self) -> bool:
        if self.tau < 2:
            return False
        return bool(self.halfwidth_normalized().max() <= self.eps)

    def result(self, *, n_epochs: int, converged: bool) -> ApproxResult:
        return ApproxResult(
            lam=self.lam_scaled(),
            halfwidth=self.hw_scaled(self.halfwidth_normalized()),
            n_samples=self.tau,
            n_epochs=n_epochs,
            converged=converged,
            eps=self.eps,
            delta=self.delta,
            rule=self.rule,
        )


def choose_sample_batch(n: int, m_edges: int, *, p: int = 1,
                        backend: str = "dense",
                        mem_bytes: float = 4 * 2 ** 30,
                        budget_hint: Optional[int] = None,
                        candidates: Tuple[int, ...] = (16, 32, 64, 128, 256),
                        dispatch_overhead_s: float = 5e-4) -> int:
    """Pick the sample-batch size n_b from the SpGEMM cost model.

    Scores each candidate with per-iteration relax seconds from
    ``spgemm.autotune.choose_bc_regime`` (dense/COO regime min) plus an
    amortized per-batch dispatch overhead, per *source*; rejects batch
    state that busts the memory budget (6 f32 state matrices of (n_b, n)
    plus the adjacency — dense (n, n) only when ``backend="dense"`` on a
    single device; COO edge arrays or a p-way sharded adjacency
    otherwise). With a ``budget_hint`` (e.g. the first epoch's length)
    candidates larger than the whole budget only waste padded rows and
    are skipped.

    Both sampling paths consult this: ``p=1`` for the single-host
    ``mfbc_batch_moments`` step, ``p=mesh.devices.size`` for the
    distributed moments step (whose P(model, data)-sharded adjacency
    divides the per-device footprint by p; ``prepare_mesh_batch_step``
    then rounds the chosen n_b up to a mesh-divisible count).
    """
    from repro.spgemm.autotune import choose_bc_regime

    if backend == "dense" and p == 1:
        adj_bytes = 4.0 * n * n
    elif backend == "dense":
        adj_bytes = 4.0 * n * n / p  # P(model, data)-sharded
    else:
        adj_bytes = 12.0 * m_edges  # COO (src, dst, w)
    best_nb, best_cost = candidates[0], float("inf")
    for nb in candidates:
        if budget_hint is not None and nb > max(budget_hint, candidates[0]):
            continue
        state_bytes = 6.0 * 4.0 * nb * n
        if adj_bytes + state_bytes > mem_bytes:
            continue
        reg = choose_bc_regime(n, m_edges, nb, fill=0.5, p=p)
        step_s = min(reg["dense_s"], reg["coo_s"])
        per_source = step_s + dispatch_overhead_s / nb
        if per_source < best_cost:
            best_nb, best_cost = nb, per_source
    return best_nb


def _single_host_step(g: Graph, backend: str, block: int, use_kernel: bool):
    """Returns step(sources, valid) -> (S1, S2, n_reach) on one host."""
    if backend == "dense":
        adj = dense_adj_from_graph(g, block=block, use_kernel=use_kernel)
    elif backend == "coo":
        adj = coo_adj_from_graph(g)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    def step(sources: np.ndarray, valid: np.ndarray):
        s1, s2, nr = mfbc_batch_moments(adj, jnp.asarray(sources),
                                        jnp.asarray(valid))
        return (np.asarray(s1, np.float64), np.asarray(s2, np.float64),
                np.asarray(nr))

    return step


def stopping_check(est: "LambdaEstimator", eps: float, topk: Optional[int],
                   check_index: int):
    """One sequential convergence test; returns (stop, hw_normalized).

    The failure budget for the *sequence* of epoch-boundary checks is
    split geometrically — check i tests at level δ/2^(i+1), Σ_i δ_i ≤ δ —
    so repeatedly peeking at the CIs does not inflate the overall failure
    probability (the per-epoch budget split of 1910.11039 Alg. 1).
    Shared by ``approx_bc`` and ``serve.bc_service``.
    """
    delta_check = est.delta / (2.0 ** (check_index + 1))
    hw = est.halfwidth_normalized(delta=delta_check)
    if hw.max() <= eps:
        return True, hw
    if topk is not None and est.tau >= 2:
        return _topk_separated(est.lam_scaled(), est.hw_scaled(hw), topk), hw
    return False, hw


def approx_bc(g: Graph, *, eps: float = 0.05, delta: float = 0.1,
              strategy: str = "adaptive", rule: str = "bernstein",
              n_b: Optional[int] = None, backend: str = "dense",
              block: int = 512, use_kernel: bool = False,
              topk: Optional[int] = None, seed: int = 0,
              mesh=None, iters: int = 0,
              max_samples: Optional[int] = None,
              progress_cb: Optional[Callable] = None) -> ApproxResult:
    """Approximate betweenness centrality by adaptive source sampling.

    Args:
      g: host COO graph.
      eps: target CI halfwidth on the normalized dependency scale
        (δ_s(v)/(n-2) ∈ [0,1]); λ̂(v) is within ε·n·(n-2) of λ(v) w.p. 1-δ.
      delta: total failure probability (union-bounded across vertices).
      strategy: "adaptive" (epoch-doubling + stopping rule) or "uniform"
        (fixed Hoeffding budget, no early exit).
      rule: "bernstein" (rigorous empirical-Bernstein CIs) or "normal"
        (CLT profile — the practical serving configuration).
      topk: when set, also stop as soon as the top-k set is CI-separated
        (relative-error early exit).
      mesh: optional jax device mesh — epochs run through the distributed
        Theorem 5.1 batch step instead of the single-host one. The mesh
        step returns real per-vertex (Σδ, Σδ²) (one fused all-reduce per
        batch), so adaptive Bernstein/CLT stopping and variance-weighted
        δ allocation work identically at pod scale — the result reports
        ``has_moments=True`` on both paths.
      max_samples: hard cap overriding the Hoeffding budget cap.
      progress_cb: optional callback(epoch, tau, max_halfwidth).

    Returns:
      ApproxResult with λ̂, per-vertex CI halfwidths (λ scale) and
      convergence metadata.
    """
    n = g.n
    hoeffding = S.hoeffding_budget(n, eps, delta)
    if n_b is None:
        p = int(mesh.devices.size) if mesh is not None else 1
        n_b = min(n, choose_sample_batch(n, g.m, p=p, backend=backend,
                                         budget_hint=hoeffding))
    cap = max_samples if max_samples is not None else None

    if mesh is not None:
        from repro.core.dist_bc import prepare_mesh_batch_step

        step, n_b = prepare_mesh_batch_step(
            g, mesh, nb=n_b, iters=iters if iters > 0 else n,
            use_kernel=use_kernel, block=block, moments=True)
    else:
        step = _single_host_step(g, backend, block, use_kernel)

    est = LambdaEstimator(n, eps, delta, rule)

    def run_batch(b: S.SampleBatch) -> None:
        s1, s2, _ = step(b.sources, b.valid)
        est.update(s1, s2, b.n_valid)

    def honest_converged() -> bool:
        """A cap below the Hoeffding budget carries no a-priori guarantee
        — only the empirical CIs can still certify convergence there."""
        if est.tau >= hoeffding:
            return True
        return est.converged()

    if strategy == "uniform":
        sampler = S.UniformSampler(n, eps=eps, delta=delta, n_b=n_b,
                                   budget=cap, seed=seed)
        epochs = 0
        for b in sampler.batches():
            run_batch(b)
            epochs = b.epoch + 1
        return est.result(n_epochs=epochs, converged=honest_converged())

    if strategy != "adaptive":
        raise ValueError(f"unknown strategy {strategy!r}")

    sampler = S.AdaptiveSampler(n, eps=eps, delta=delta, n_b=n_b,
                                cap=cap, seed=seed)
    n_epochs = 0
    converged = False
    for ei, batches in sampler.epochs():
        for b in batches:
            run_batch(b)
        n_epochs = ei + 1
        stop, hw = stopping_check(est, eps, topk, ei)
        if progress_cb is not None:
            progress_cb(ei, est.tau, float(hw.max()))
        if stop:
            converged = True
            sampler.stop()
    if sampler.capped and not converged:
        converged = honest_converged()
    return est.result(n_epochs=n_epochs, converged=converged)
