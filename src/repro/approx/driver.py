"""Adaptive approximate-BC estimator (λ moments, CIs, stopping rule).

The host-side sampling loop itself now lives in ``repro.bc.solve`` (the
unified query/plan/executor API): it pulls padded source batches from a
strategy (``approx.sampling``), pushes them through a ``BatchExecutor``
and folds the per-vertex dependency moments into the ``LambdaEstimator``
defined here, testing ``stopping_check`` at epoch boundaries
(epoch-doubling, 1910.11039 §4). This module keeps the estimator
mathematics plus ``choose_sample_batch`` (the n_b cost-model pick that
``repro.bc.BCPlanner`` consults); ``approx_bc`` remains as a deprecated
shim delegating to ``repro.bc.solve``.

Estimator. For τ uniform source samples with running sums
``S1(v) = Σ_s δ_s(v)`` and ``S2(v) = Σ_s δ_s(v)²``:

  λ̂(v)  = (n/τ)·S1(v)                      (unbiased for λ(v) = Σ_s δ_s(v))
  x̄(v)  = S1(v)/((n-2)·τ) ∈ [0, 1]         (normalized-scale mean)
  hw(v)  = CI halfwidth of x̄(v)            (Bernstein or CLT rule)

Convergence: ``max_v hw(v) ≤ ε`` — or, when a ``topk`` query is given,
the earlier of that and CI-separation of the top-k set (the relative-error
early exit: every vertex in the estimated top-k has a lower confidence
bound above the upper bound of every vertex outside it).

Batch-size selection consults the SpGEMM cost layer
(``spgemm.autotune.choose_bc_regime``): per-source step cost is flat in
``n_b`` for the dense regime, so the model picks the largest ``n_b`` that
fits the per-device memory budget and does not overshoot the first epoch —
amortizing per-batch dispatch without wasting samples.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional, Tuple

import numpy as np

from repro.approx import sampling as S
from repro.graphs.formats import Graph


def _topk_separated(lam: np.ndarray, halfwidth: np.ndarray, k: int) -> bool:
    """True iff the k largest estimates are CI-separated from the rest."""
    if k >= lam.shape[0]:
        return True
    order = np.argsort(lam)[::-1]
    lo = lam[order[:k]] - halfwidth[order[:k]]
    hi = lam[order[k:]] + halfwidth[order[k:]]
    return bool(lo.min() > hi.max())


@dataclasses.dataclass
class ApproxResult:
    """Outcome of one approximate-BC run (λ convention of ``core.mfbc``)."""

    lam: np.ndarray  # (n,) λ̂ estimate, unnormalized
    halfwidth: np.ndarray  # (n,) CI halfwidth, same unnormalized scale
    n_samples: int
    n_epochs: int
    converged: bool  # stopping rule met (False: hit the sample cap)
    eps: float
    delta: float
    rule: str
    has_moments: bool = True  # CIs backed by real Σδ² (always, since PR 2)

    def topk(self, k: int) -> np.ndarray:
        """Vertex ids of the k largest estimates, descending."""
        order = np.argsort(self.lam)[::-1]
        return order[:k]

    def topk_separated(self, k: int) -> bool:
        """True iff the top-k set is CI-separated from the rest."""
        return _topk_separated(self.lam, self.halfwidth, k)


class LambdaEstimator:
    """Running moments of per-source dependencies, with CIs.

    The (Σδ, Σδ²) contract: every batch step feeding this estimator —
    single-host ``core.mfbc.mfbc_batch_moments`` and the distributed
    ``core.dist_bc.prepare_mesh_batch_step(..., moments=True)`` — returns
    per-vertex first and second moments of the *unnormalized* dependency
    ``δ_s(v) ∈ [0, n-2]`` summed over the batch's valid sources:
    ``S1(v) = Σ_s δ_s(v)`` and ``S2(v) = Σ_s δ_s(v)²``. ``update`` folds
    them into running sums; halfwidths are computed on the normalized
    scale ``x_s(v) = δ_s(v)/(n-2) ∈ [0, 1]`` (divide S1 by n-2, S2 by
    (n-2)²). Since PR 2 the mesh path supplies real second moments too,
    so variance-based (Bernstein/CLT) stopping is available everywhere
    and the old first-moments-only Hoeffding fallback is gone.

    Stopping rule per code path: ``rule="bernstein"`` — rigorous
    empirical-Bernstein CIs (``sampling.bernstein_halfwidth``), the
    default of ``approx_bc`` and ``launch.bc_run --approx``;
    ``rule="normal"`` — CLT profile (``sampling.normal_halfwidth``), the
    ``serve.bc_service`` default. Both consume the same (Σδ, Σδ²) sums.
    """

    def __init__(self, n: int, eps: float, delta: float, rule: str):
        if rule not in ("bernstein", "normal"):
            raise ValueError(f"unknown stopping rule {rule!r}")
        self.n = n
        self.eps = eps
        self.delta = delta
        self.rule = rule
        self.s1 = np.zeros(n, dtype=np.float64)
        self.s2 = np.zeros(n, dtype=np.float64)
        self.tau = 0
        # Epoch-by-epoch convergence trace: ``stopping_check`` appends
        # (τ, max normalized halfwidth) at each boundary it tests, so
        # serving can stream partial convergence to polling clients.
        self.hw_history: list = []

    def update(self, s1_batch: np.ndarray, s2_batch: np.ndarray,
               n_valid: int) -> None:
        """Fold one batch's (S1, S2) sums over ``n_valid`` sources in."""
        self.s1 += s1_batch
        self.s2 += s2_batch
        self.tau += n_valid

    def _norm(self) -> float:
        return float(max(self.n - 2, 1))

    def halfwidth_normalized(self, delta: Optional[float] = None
                             ) -> np.ndarray:
        """CI halfwidth of x̄(v) on the [0, 1] normalized-dependency scale.

        The failure budget (``delta`` overrides ``self.delta`` — used by
        the sequential ``stopping_check``) is split non-uniformly across
        vertices (``sampling.allocate_delta``): empirical variance decides
        where δ is spent, so hub CIs — the ones the max over v binds on —
        shrink fastest.

        Fewer than two samples carry no variance estimate: the halfwidth
        is +inf everywhere, so a zero/one-sample run can never be
        mistaken for a converged one (``stopping_check`` sees an
        infinite max halfwidth, and a retired ``ApproxResult`` honestly
        reports unbounded CIs instead of finite garbage).
        """
        if self.tau < 2:
            return np.full(self.n, np.inf)
        d = self.delta if delta is None else delta
        c = self._norm()
        x1, x2 = self.s1 / c, self.s2 / (c * c)
        mean = x1 / self.tau
        var = np.maximum(x2 / self.tau - mean * mean, 0.0)
        delta_v = S.allocate_delta(var, d)
        fn = (S.bernstein_halfwidth if self.rule == "bernstein"
              else S.normal_halfwidth)
        return fn(x1, x2, self.tau, delta_v)

    def lam_scaled(self) -> np.ndarray:
        """λ̂(v) = (n/τ)·S1(v) — unnormalized λ units.

        Same ordered-pair convention as ``core.mfbc.mfbc`` (λ(v) =
        Σ_s δ_s(v), endpoints excluded): the Horvitz–Thompson scale-up
        n/τ makes the uniform-source sample mean unbiased for λ. Divide
        by n·(n-2) to land on the normalized [0, 1] scale that ``eps``
        is quoted on.
        """
        return self.s1 * (self.n / max(self.tau, 1))

    def hw_scaled(self, hw_normalized: np.ndarray) -> np.ndarray:
        """Normalized-scale CI halfwidth → λ units (λ̂ = n·(n-2)·x̄)."""
        return hw_normalized * self.n * self._norm()

    def converged(self) -> bool:
        if self.tau < 2:
            return False
        return bool(self.halfwidth_normalized().max() <= self.eps)

    def result(self, *, n_epochs: int, converged: bool) -> ApproxResult:
        return ApproxResult(
            lam=self.lam_scaled(),
            halfwidth=self.hw_scaled(self.halfwidth_normalized()),
            n_samples=self.tau,
            n_epochs=n_epochs,
            converged=converged,
            eps=self.eps,
            delta=self.delta,
            rule=self.rule,
        )


def adjacency_bytes(n: int, m_edges: int, *, backend: str = "dense",
                    p: int = 1, transpose: bool = False) -> float:
    """Per-device bytes of the adjacency operand.

    The one memory model shared by ``choose_sample_batch`` (n_b
    rejection) and ``repro.bc.BCPlanner`` (plan predictions): f32 dense
    (n, n) divided across ``p`` devices, replicated COO (src, dst, w)
    edge arrays, or the CSR backend's dual-sorted arc lists (by-src and
    by-dst copies plus two int32 row-pointer arrays). ``transpose=True``
    doubles dense storage for paths that keep A and Aᵀ resident (the
    distributed step does).
    """
    if backend == "dense":
        b = 4.0 * n * n / max(p, 1)
        return 2.0 * b if transpose else b
    if backend == "csr":
        return 24.0 * m_edges + 8.0 * (n + 1)
    return 12.0 * m_edges


def state_bytes(n: int, nb: int, *, p: int = 1) -> float:
    """Per-device bytes of one batch's BC state (≈6 f32 (nb, n) mats)."""
    return 6.0 * 4.0 * nb * n / max(p, 1)


def choose_sample_batch(n: int, m_edges: int, *, p: int = 1,
                        backend: str = "dense",
                        mem_bytes: float = 4 * 2 ** 30,
                        budget_hint: Optional[int] = None,
                        candidates: Tuple[int, ...] = (16, 32, 64, 128, 256),
                        dispatch_overhead_s: float = 5e-4,
                        calibration=None) -> int:
    """Pick the sample-batch size n_b from the SpGEMM cost model.

    Scores each candidate with per-iteration relax seconds from
    ``spgemm.autotune.choose_bc_regime`` (dense/COO regime min) plus an
    amortized per-batch dispatch overhead, per *source*; rejects batch
    state that busts the memory budget (6 f32 state matrices of (n_b, n)
    plus the adjacency — dense (n, n) only when ``backend="dense"`` on a
    single device; COO edge arrays or a p-way sharded adjacency
    otherwise). With a ``budget_hint`` (e.g. the first epoch's length)
    candidates larger than the whole budget only waste padded rows and
    are skipped.

    Both sampling paths consult this: ``p=1`` for the single-host
    ``mfbc_batch_moments`` step, ``p=mesh.devices.size`` for the
    distributed moments step (whose P(model, data)-sharded adjacency
    divides the per-device footprint by p; ``prepare_mesh_batch_step``
    then rounds the chosen n_b up to a mesh-divisible count).

    With a measured ``calibration`` (``spgemm.cost_model.Calibration``)
    both the per-iteration seconds and the per-batch dispatch overhead
    come from the fitted α-β constants instead of the analytic TPU
    model, so n_b tracks the host the run actually executes on.
    """
    from repro.spgemm.autotune import choose_bc_regime

    adj_b = adjacency_bytes(n, m_edges, backend=backend, p=p)
    best_nb, best_cost = candidates[0], float("inf")
    for nb in candidates:
        if budget_hint is not None and nb > max(budget_hint, candidates[0]):
            continue
        # state priced unsharded (p=1) on purpose: a conservative bound
        # that keeps n_b picks stable whatever the batch-axis layout
        if adj_b + state_bytes(n, nb) > mem_bytes:
            continue
        reg = choose_bc_regime(n, m_edges, nb, fill=0.5, p=p,
                               calibration=calibration)
        step_s = min(reg["dense_s"], reg["coo_s"],
                     reg.get("csr_s", float("inf")))
        overhead = dispatch_overhead_s
        if calibration is not None and calibration.has(backend):
            overhead = calibration.overhead_seconds(backend)
        per_source = step_s + overhead / nb
        if per_source < best_cost:
            best_nb, best_cost = nb, per_source
    return best_nb


def stopping_check(est: "LambdaEstimator", eps: float, topk: Optional[int],
                   check_index: int):
    """One sequential convergence test; returns (stop, hw_normalized).

    The failure budget for the *sequence* of epoch-boundary checks is
    split geometrically — check i tests at level δ/2^(i+1), Σ_i δ_i ≤ δ —
    so repeatedly peeking at the CIs does not inflate the overall failure
    probability (the per-epoch budget split of 1910.11039 Alg. 1).
    Shared by ``approx_bc`` and ``serve.bc_service``.
    """
    delta_check = est.delta / (2.0 ** (check_index + 1))
    hw = est.halfwidth_normalized(delta=delta_check)
    est.hw_history.append((int(est.tau), float(hw.max())))
    if hw.max() <= eps:
        return True, hw
    if topk is not None and est.tau >= 2:
        return _topk_separated(est.lam_scaled(), est.hw_scaled(hw), topk), hw
    return False, hw


def approx_bc(g: Graph, *, eps: float = 0.05, delta: float = 0.1,
              strategy: str = "adaptive", rule: str = "bernstein",
              n_b: Optional[int] = None, backend: str = "dense",
              block: int = 512, use_kernel: bool = False,
              topk: Optional[int] = None, seed: int = 0,
              mesh=None, iters: int = 0,
              max_samples: Optional[int] = None,
              progress_cb: Optional[Callable] = None) -> ApproxResult:
    """Deprecated: use ``repro.bc.solve(g, BCQuery(mode="approx", ...))``.

    Thin shim kept for one release: builds the equivalent ``BCQuery``,
    delegates to the unified solver (same samplers, estimator and
    stopping rule — identical results for identical seeds) and returns
    the embedded ``ApproxResult``.
    """
    warnings.warn(
        "approx.driver.approx_bc is deprecated; use repro.bc.solve with "
        "BCQuery(mode='approx', ...)", DeprecationWarning, stacklevel=2)
    from repro.bc import BCPlanner, BCQuery, ExecutionConfig, solve

    # The old driver ignored ``backend`` on the mesh path (the
    # distributed step is dense-only); keep that lenience here rather
    # than let the planner reject mesh + backend="coo". The old default
    # use_kernel=False is pinned explicitly — the shim must keep the
    # historical behavior, not inherit the calibrated kernel verdict.
    query = BCQuery(mode="approx", eps=eps, delta=delta, strategy=strategy,
                    rule=rule, topk=topk, max_samples=max_samples, seed=seed,
                    n_b=n_b, iters=iters,
                    execution=ExecutionConfig(
                        backend=None if mesh is not None else backend,
                        use_kernel=use_kernel, block=block))
    if mesh is None:
        # Historical contract: approx_bc without a mesh always ran single
        # host. Pin the plan so results stay identical on multi-device
        # hosts (the planner would otherwise auto-place a mesh there).
        pl = BCPlanner().plan(g, query, n_devices=1)
        return solve(g, query, plan=pl, progress_cb=progress_cb).approx
    return solve(g, query, mesh=mesh, progress_cb=progress_cb).approx
