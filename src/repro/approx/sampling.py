"""Source-sampling strategies and stopping rules for approximate BC.

Samples are *sources*: one sample scores every vertex v with the
normalized dependency ``x_s(v) = δ_s(v)/(n-2) ∈ [0, 1]`` computed by one
row of the batched MFBC step. Strategies emit padded static-shape batches
(jit requirement, same convention as ``core.mfbc``: padding rows carry
``valid=False`` and contribute nothing).

Stopping rules (all on the normalized scale, see ``approx/__init__``):

* ``hoeffding_budget`` — a-priori sample count ``τ ≥ ln(2n/δ)/(2ε²)``
  such that P(∃v: |x̄(v) − μ(v)| > ε) ≤ δ. The uniform strategy's fixed
  budget and the adaptive strategy's hard cap. (A per-τ Hoeffding CI
  used to back the moments-free mesh path; since the distributed step
  returns (Σδ, Σδ²) that fallback is gone and the budget is the only
  Hoeffding artifact left.)
* ``bernstein_halfwidth`` — empirical-Bernstein CI [Maurer & Pontil 2009]
  with the failure budget union-bounded across vertices
  (δ_v = δ/n), the rule of 1910.11039 Alg. 1: adaptive sampling stops as
  soon as every vertex's halfwidth ≤ ε. Variance-adaptive: vertices with
  near-zero dependency variance (almost all of them on power-law graphs)
  converge in one epoch; only the hubs keep the loop alive.
* ``normal_halfwidth`` — CLT profile (z·σ̂/√τ, per-vertex δ): the
  practical production rule, matching how deployed approximate-BC systems
  trade the concentration-bound slack for ~3-5× fewer samples. Selected
  with ``rule="normal"``; the rigorous default is ``"bernstein"``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Optional, Tuple

import numpy as np


def hoeffding_budget(n: int, eps: float, delta: float) -> int:
    """Samples for a uniform ε-approximation of all n vertices w.p. 1-δ."""
    if eps <= 0:
        raise ValueError("eps must be positive")
    return int(math.ceil(math.log(2.0 * max(n, 2) / delta) / (2.0 * eps * eps)))


def epoch_schedule(tau0: int, growth: float = 2.0) -> Iterator[int]:
    """Epoch lengths ``tau0, tau0·g, tau0·g², …`` (1910.11039 §4 doubling).

    The stopping rule is only evaluated at epoch boundaries, so the
    host-device sync cost is logarithmic in the total sample count.
    """
    t = max(1, int(tau0))
    while True:
        yield t
        t = max(t + 1, int(t * growth))


def allocate_delta(var: np.ndarray, delta: float) -> np.ndarray:
    """Non-uniform per-vertex failure budget (the KADABRA δ-splitting).

    Half of δ is spread uniformly; the other half proportionally to the
    empirical variance. The union bound Σδ_v = δ holds for any fixed
    allocation, and the few high-variance hubs that dominate
    ``max_v hw(v)`` get orders of magnitude more budget than the δ/n
    uniform split — a ~25% tighter CI exactly where the stopping rule
    binds. Caveat (shared with KADABRA's δ-splitting heuristic): the
    allocation is estimated from the same samples the CI is computed on,
    so the bound is rigorous under a two-phase reading (allocate on epoch
    e, test on epoch e+1) and a practical approximation as implemented.
    """
    n = var.shape[0]
    total = float(var.sum())
    if total <= 0.0:
        return np.full(n, delta / n)
    return delta * (0.5 / n + 0.5 * var / total)


def bernstein_halfwidth(s1: np.ndarray, s2: np.ndarray, tau: int,
                        delta_v) -> np.ndarray:
    """Empirical-Bernstein CI halfwidth for means of [0,1] samples.

    ``s1``/``s2`` are running Σx and Σx² per vertex; ``delta_v`` the
    per-vertex failure budget — scalar (uniform δ/n union bound) or array
    (``allocate_delta``). With probability ≥ 1-δ_v:
      |x̄ − μ| ≤ √(2·V̂·ln(3/δ_v)/τ) + 3·ln(3/δ_v)/τ,
    where V̂ is the *unbiased* sample variance (the Maurer–Pontil bound
    is stated for Σ(x_i − x̄)²/(τ−1), not the biased Σx²/τ − x̄²).
    Fewer than two samples carry no variance estimate at all: the
    halfwidth is +inf, so no stopping rule can certify from them.
    """
    if tau < 2:
        return np.full_like(np.asarray(s1, np.float64), np.inf)
    mean = s1 / tau
    var = np.maximum(s2 / tau - mean * mean, 0.0) * tau / (tau - 1)
    log_term = np.log(3.0 / np.asarray(delta_v, np.float64))
    return np.sqrt(2.0 * var * log_term / tau) + 3.0 * log_term / tau


def normal_halfwidth(s1: np.ndarray, s2: np.ndarray, tau: int,
                     delta_v) -> np.ndarray:
    """CLT halfwidth z_{1-δ_v/2}·σ̂/√τ with a 1/τ small-sample cushion.

    σ̂² is the unbiased sample variance; τ < 2 yields +inf (no variance
    estimate exists), matching ``bernstein_halfwidth``.
    """
    if tau < 2:
        return np.full_like(np.asarray(s1, np.float64), np.inf)
    mean = s1 / tau
    var = np.maximum(s2 / tau - mean * mean, 0.0) * tau / (tau - 1)
    z = math.sqrt(2.0) * _erfinv(1.0 - np.asarray(delta_v, np.float64))
    return z * np.sqrt(var / tau) + 1.0 / tau


def _erfinv(y):
    """Inverse error function (Winitzki's approximation, |err| < 2e-3)."""
    y = np.clip(np.asarray(y, np.float64), -(1 - 1e-12), 1 - 1e-12)
    a = 0.147
    ln1my2 = np.log(1.0 - y * y)
    t1 = 2.0 / (math.pi * a) + ln1my2 / 2.0
    return np.sign(y) * np.sqrt(np.sqrt(t1 * t1 - ln1my2 / a) - t1)


@dataclasses.dataclass(frozen=True)
class SampleBatch:
    """One padded static-shape source batch for ``mfbc_batch``."""

    sources: np.ndarray  # (n_b,) int32, padded with 0
    valid: np.ndarray  # (n_b,) bool, False on padding rows
    epoch: int

    @property
    def n_valid(self) -> int:
        return int(self.valid.sum())


class UniformSampler:
    """Fixed-budget uniform source sampling (Brandes & Pich 2007).

    Draws the full Hoeffding budget (or an explicit ``budget``) uniformly
    with replacement, chopped into ``n_b``-sized padded batches.
    """

    def __init__(self, n: int, *, eps: float = 0.05, delta: float = 0.1,
                 n_b: int = 64, budget: Optional[int] = None, seed: int = 0):
        self.n = n
        self.n_b = n_b
        self.budget = int(budget if budget is not None
                          else hoeffding_budget(n, eps, delta))
        self.rng = np.random.default_rng(seed)
        self._drawn = 0

    def batches(self) -> Iterator[SampleBatch]:
        epoch = 0
        while self._drawn < self.budget:
            k = min(self.n_b, self.budget - self._drawn)
            yield self._pad(self.rng.integers(0, self.n, k), epoch)
            self._drawn += k
            epoch += 1

    def _pad(self, srcs: np.ndarray, epoch: int) -> SampleBatch:
        k = srcs.shape[0]
        sources = np.zeros(self.n_b, np.int32)
        sources[:k] = srcs.astype(np.int32)
        valid = np.zeros(self.n_b, bool)
        valid[:k] = True
        return SampleBatch(sources, valid, epoch)


class AdaptiveSampler:
    """Epoch-doubling adaptive source sampling (1910.11039 §4).

    Demand and assembly are separate surfaces. The *demand* side —
    ``next_epoch() -> (epoch_index, m)`` ("give me m sources this
    epoch") plus ``draw(k)`` — is what cross-request fusion consumes:
    ``repro.bc.fusion.BatchAssembler`` drains many live samplers' demand
    on the same graph and packs it into slot-tagged fused batches, so
    how sources are *drawn* (this class) is decoupled from how they are
    *batched* (the assembler, or the classic per-request chunking). The
    ``epochs()`` iterator is the single-query assembly built on that
    demand side: padded ``n_b``-sized batches, drawing chunk by chunk —
    the sequential driver in ``repro.bc.solve`` pulls these and updates
    the estimator at epoch boundaries, then calls ``stop()``. Both
    assemblies consume the identical RNG stream (numpy draws bounded
    integers element-wise), so a request samples the same sources
    whichever path batches it.

    ``cap`` bounds the total draw at the Hoeffding budget — by then the
    a-priori guarantee holds regardless of what the empirical CIs say,
    so sampling past it is pure waste.

    ``seed`` is anything ``np.random.default_rng`` accepts — an int, or
    a sequence of ints such as ``(seed, rid)``, which is how
    ``serve.BCService`` derives an independent stream per request
    without giving up exact reproducibility (same (seed, rid), same
    stream).
    """

    def __init__(self, n: int, *, eps: float = 0.05, delta: float = 0.1,
                 n_b: int = 64, tau0: Optional[int] = None,
                 growth: float = 2.0, cap: Optional[int] = None,
                 seed: int = 0):
        self.n = n
        self.n_b = n_b
        self.eps = eps
        self.delta = delta
        self.cap = int(cap if cap is not None
                       else hoeffding_budget(n, eps, delta))
        self._epochs = epoch_schedule(tau0 if tau0 else n_b, growth)
        self._ei = 0
        self.rng = np.random.default_rng(seed)
        self._drawn = 0
        self._stop = False

    def stop(self) -> None:
        """Signal convergence: no further epochs are generated."""
        self._stop = True

    @property
    def drawn(self) -> int:
        return self._drawn

    @property
    def capped(self) -> bool:
        return self._drawn >= self.cap

    # ----------------------------------------------------- checkpointing
    def state(self) -> dict:
        """Portable snapshot of the sampling stream position.

        Everything ``from_state`` needs to continue this exact stream:
        the epoch-schedule position, the draw count, and the generator's
        bit-level state. The stop latch is *not* captured — a restored
        sampler is re-armed on purpose (resumption exists to keep
        sampling past the point the original run stopped at).
        """
        return {
            "ei": self._ei,
            "drawn": self._drawn,
            "rng_state": self.rng.bit_generator.state,
        }

    @classmethod
    def from_state(cls, n: int, state: dict, *, eps: float, delta: float,
                   n_b: int, tau0: Optional[int] = None, growth: float = 2.0,
                   cap: Optional[int] = None) -> "AdaptiveSampler":
        """Rebuild a sampler mid-stream from a ``state()`` snapshot.

        ``eps``/``delta``/``cap`` are the *new* run's targets (a
        refinement resumes under a tighter ε, hence a larger Hoeffding
        cap); ``n_b``/``tau0``/``growth`` must match the original run or
        the epoch schedule — and with it the drawn stream — diverges.
        The schedule generator is re-advanced to the snapshot's epoch
        index, so the next ``next_epoch()`` demands exactly the epoch
        the original sampler would have demanded next.
        """
        s = cls(n, eps=eps, delta=delta, n_b=n_b, tau0=tau0, growth=growth,
                cap=cap)
        for _ in range(state["ei"]):
            next(s._epochs)
        s._ei = int(state["ei"])
        s._drawn = int(state["drawn"])
        s.rng.bit_generator.state = state["rng_state"]
        return s

    # ------------------------------------------------------- demand side
    def next_epoch(self) -> Optional[Tuple[int, int]]:
        """Demand for one epoch: ``(epoch_index, n_sources)``, or ``None``
        once stopped/capped. Advances the epoch schedule — callers must
        ``draw`` the returned count (in any chunking) before asking for
        the next epoch."""
        if self._stop or self._drawn >= self.cap:
            return None
        tau_e = min(next(self._epochs), self.cap - self._drawn)
        ei = self._ei
        self._ei += 1
        return ei, tau_e

    def draw(self, k: int) -> np.ndarray:
        """Draw k uniform sources (int32) and account for them."""
        srcs = self.rng.integers(0, self.n, k).astype(np.int32)
        self._drawn += k
        return srcs

    # ---------------------------------------------- single-query assembly
    def epochs(self) -> Iterator[Tuple[int, Iterator[SampleBatch]]]:
        """Yields (epoch_index, batch iterator); check ``stop`` between."""
        while True:
            nxt = self.next_epoch()
            if nxt is None:
                return
            ei, tau_e = nxt
            yield ei, self._epoch_batches(ei, tau_e)

    def _epoch_batches(self, epoch: int, tau_e: int) -> Iterator[SampleBatch]:
        left = tau_e
        while left > 0:
            k = min(self.n_b, left)
            sources = np.zeros(self.n_b, np.int32)
            sources[:k] = self.draw(k)
            valid = np.zeros(self.n_b, bool)
            valid[:k] = True
            left -= k
            yield SampleBatch(sources, valid, epoch)
