"""Adaptive-sampling approximate betweenness centrality.

Exact MFBC (``repro.core.mfbc``) runs all ``n`` sources through the
batched Algorithm 3 step. This subsystem serves the sampling regime
instead: pick sources uniformly at random, run the *same* jitted batch
step, and stop as soon as per-vertex confidence intervals certify the
requested accuracy — the adaptive-sampling design of van der Grinten &
Meyerhenke [arXiv:1910.11039], transplanted from MPI onto the jax mesh.

Mapping to 1910.11039 (their ADS algorithm, itself a KADABRA descendant):

* **per-sample value** — their algorithm samples shortest paths; source
  sampling [Brandes & Pich 2007] samples a source ``s`` and scores every
  vertex with the normalized dependency ``x_s(v) = δ_s(v)/(n-2) ∈ [0,1]``
  (``δ_s(v) = Σ_t σ(s,t,v)/σ̄(s,t)``). One sample costs one row of the
  MFBC batch step, so a whole epoch is a single padded static-shape batch.
* **epoch doubling** — §4 of the paper synchronizes the stopping check at
  epoch boundaries whose lengths grow geometrically, amortizing the
  reduction; ``sampling.epoch_schedule`` reproduces the doubling schedule
  and the driver checks the stopping rule only there (amortizing the
  host-side sync with the device batch loop).
* **stopping rule** — their Alg. 1 stops when every vertex's confidence
  interval, from an empirical-Bernstein concentration bound with a
  union-bounded failure budget, shrinks below the target. We implement
  that (``sampling.bernstein_halfwidth``), with the failure budget split
  twice: across vertices (variance-weighted, ``sampling.allocate_delta``)
  and geometrically across the sequence of epoch-boundary checks
  (``driver.stopping_check``, δ_i = δ/2^{i+1}) so repeated peeking stays
  within δ. The Hoeffding a-priori budget is the uniform strategy's
  sample count and the adaptive cap, and a relative-error / top-k
  separation early exit (their §5 "relative" variant) stops once the
  top-k set is CI-separated from the rest.
* **distributed epochs** — the batch step is mesh-oblivious: the driver
  runs epochs through the single-host step or through
  ``core.dist_bc.build_mfbc_step`` (Theorem 5.1 collectives), matching the
  paper's MPI scaling story. Both paths return per-vertex (Σδ, Σδ²) — the
  mesh step fuses the Σδ² reduction into the same stacked all-reduce as
  Σδ — so empirical-Bernstein/CLT adaptive stopping works identically at
  pod scale (no Hoeffding fallback).

The entry point is the unified solver facade:
``repro.bc.solve(g, BCQuery(mode="approx", ...))`` — the sampling loop
lives in ``repro.bc.solve``, the estimator mathematics here.
``launch.bc_run --approx`` and ``serve.bc_service`` go through that
facade; ``driver.approx_bc`` remains as a deprecated delegating shim.
"""
from repro.approx.driver import ApproxResult, approx_bc, choose_sample_batch
from repro.approx.sampling import (AdaptiveSampler, UniformSampler,
                                   allocate_delta, bernstein_halfwidth,
                                   epoch_schedule, hoeffding_budget,
                                   normal_halfwidth)

__all__ = [
    "ApproxResult", "approx_bc", "choose_sample_batch",
    "AdaptiveSampler", "UniformSampler", "allocate_delta",
    "bernstein_halfwidth", "epoch_schedule", "hoeffding_budget",
    "normal_halfwidth",
]
