"""AdamW optimizer (from scratch — no optax dependency).

Optimizer state is a pytree mirroring the parameters, so it inherits the
parameter sharding (FSDP'd moments). Includes global-norm gradient
clipping and a linear-warmup + cosine schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # "f32" or "int8" — compressed moments: m in int8 with per-row absmax
    # scales (sign-symmetric, quantizes well), v in bf16 (g² has squared
    # dynamic range — linear int8 underflows it to zero and explodes the
    # update, so v keeps bf16's exponent range). 8+16 bits vs 64: ~2.7x
    # optimizer-state reduction — the difference between fitting and not
    # fitting a 235B model's moments on a 16 GiB chip (§Perf iter 3).
    moment_dtype: str = "f32"


def _q8(x: jax.Array):
    """Quantize to int8 with per-leading-dim absmax scales."""
    if x.ndim == 0:
        return {"q": x.astype(jnp.float32), "s": jnp.ones((), jnp.float32)}
    red = tuple(range(1, x.ndim))
    s = jnp.max(jnp.abs(x), axis=red, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-12)
    return {"q": jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8),
            "s": s.astype(jnp.float32)}


def _dq8(t) -> jax.Array:
    if t["q"].dtype != jnp.int8:
        return t["q"]
    return t["q"].astype(jnp.float32) * t["s"]


def _is_q8(t) -> bool:
    return isinstance(t, dict) and set(t) == {"q", "s"}


def init_state(params, moment_dtype: str = "f32") -> dict:
    if moment_dtype == "int8":
        z8 = lambda p: _q8(jnp.zeros(p.shape, jnp.float32))
        zv = lambda p: jnp.zeros(p.shape, jnp.bfloat16)
        return {"m": jax.tree.map(z8, params),
                "v": jax.tree.map(zv, params),
                "step": jnp.zeros((), jnp.int32)}
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _shard_like(p, shape, dtype):
    sh = getattr(p, "sharding", None)
    if sh is not None and not callable(sh) and len(shape) == getattr(
            p, "ndim", len(shape)):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_state(abstract_params, moment_dtype: str = "f32") -> dict:
    if moment_dtype == "int8":
        def mk8(p):
            sshape = (p.shape[0],) + (1,) * (len(p.shape) - 1) if p.shape \
                else ()
            return {"q": _shard_like(p, p.shape, jnp.int8),
                    "s": jax.ShapeDtypeStruct(sshape, jnp.float32)}

        mkv = lambda p: _shard_like(p, p.shape, jnp.bfloat16)
        return {"m": jax.tree.map(mk8, abstract_params),
                "v": jax.tree.map(mkv, abstract_params),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
    mk = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32,
                                        sharding=getattr(p, "sharding", None))
    return {"m": jax.tree.map(mk, abstract_params),
            "v": jax.tree.map(mk, abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(cfg: AdamWConfig, grads, state, params) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = jnp.zeros(())
    if cfg.grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    q8 = cfg.moment_dtype == "int8"
    get = _dq8 if q8 else (lambda x: x)
    put = _q8 if q8 else (lambda x: x)
    leaf = _is_q8 if q8 else None
    m = jax.tree.map(
        lambda m_, g: put(b1 * get(m_) + (1 - b1) * g.astype(jnp.float32)),
        state["m"], grads, is_leaf=leaf)
    vput = (lambda x: x.astype(jnp.bfloat16)) if q8 else (lambda x: x)
    vget = (lambda x: x.astype(jnp.float32)) if q8 else (lambda x: x)
    v = jax.tree.map(
        lambda v_, g: vput(b2 * vget(v_)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))),
        state["v"], grads)
    t = step.astype(jnp.float32) + 1.0
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)

    vget2 = (lambda x: x.astype(jnp.float32)) if q8 else (lambda x: x)

    def upd(p, m_, v_):
        delta = (get(m_) * mhat_scale) / (
            jnp.sqrt(vget2(v_) * vhat_scale) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v, is_leaf=leaf)
    return new_params, {"m": m, "v": v, "step": step + 1}, \
        {"lr": lr, "grad_norm": gnorm}
