"""Gradient compression with error feedback, applied before the DP
all-reduce (distributed-optimization trick for 1000+ node scale).

Two compressors:

* ``topk``  — per-leaf magnitude top-k sparsification (k = ratio·size);
  the residual (what was dropped) is carried in an error-feedback buffer
  and added back next step [1-bit SGD / Deep Gradient Compression lineage].
* ``int8``  — per-leaf symmetric int8 quantization with fp32 scale;
  error feedback likewise.

Both are pure functions over pytrees: ``compress`` returns the compressed
representation + new error buffer; ``decompress`` reconstructs. In the
training loop the compressed payload is what crosses the DP axis (psum of
the dense-ified payload — on real hardware the wire format is the sparse
(values, indices) pair; byte accounting in the cost model uses that).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    kind: str = "topk"  # topk | int8 | none
    topk_ratio: float = 0.01


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_leaf(g, err, ratio):
    g = g.astype(jnp.float32) + err
    flat = g.reshape(-1)
    k = max(1, int(flat.size * ratio))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    kept = jnp.zeros_like(flat).at[idx].set(vals).reshape(g.shape)
    return kept, g - kept, (vals, idx)


def _int8_leaf(g, err):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq, (q, scale)


def compress(cfg: CompressConfig, grads, err):
    """Returns (dense_payload, new_err, wire_bytes_estimate).

    ``dense_payload`` is the decompressed-equivalent gradient (what the
    optimizer consumes after the all-reduce); ``wire_bytes`` counts the
    actual compressed representation for the cost model.
    """
    if cfg.kind == "none":
        bytes_ = sum(l.size * 4 for l in jax.tree.leaves(grads))
        return grads, err, bytes_

    outs = []
    wire = 0
    for (g, e) in zip(jax.tree.leaves(grads), jax.tree.leaves(err)):
        if cfg.kind == "topk":
            kept, new_e, (vals, idx) = _topk_leaf(g, e, cfg.topk_ratio)
            wire += vals.size * 4 + idx.size * 4
        elif cfg.kind == "int8":
            kept, new_e, (q, _) = _int8_leaf(g, e)
            wire += q.size + 4
        else:
            raise ValueError(cfg.kind)
        outs.append((kept, new_e))
    treedef = jax.tree.structure(grads)
    dense = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return dense, new_err, wire


def compression_ratio(cfg: CompressConfig, params) -> float:
    raw = sum(l.size * 4 for l in jax.tree.leaves(params))
    if cfg.kind == "topk":
        return cfg.topk_ratio * 2  # values + indices
    if cfg.kind == "int8":
        return 0.25
    return 1.0
