"""Fault-tolerant checkpointing with mesh-resharding restore.

Design (no orbax/tensorstore dependency — built from scratch):

* ``save(path, step, tree)`` — writes one ``.npz`` per host-visible shard
  set plus a JSON manifest, then **atomically renames** the staging
  directory (a crash mid-save never corrupts the latest checkpoint).
* ``restore(path, like=...)`` — loads into the *current* mesh/sharding: the
  arrays are stored unsharded (gathered) with their tree structure, and
  ``jax.device_put`` against the target sharding re-shards, so a checkpoint
  written on a ``(4, 2)`` mesh restores onto ``(2, 4)`` or ``(8,)`` —
  elastic scale up/down.
* ``latest_step(dir)`` / retention — the restart loop's entry point.

For BC runs the checkpoint is tiny (λ accumulator + batch index); for
training it is params + optimizer state + step + data-pipeline position.
Deterministic pipelines keyed by step make restarts bit-exact
(``tests/test_fault_tolerance.py``).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomic checkpoint write. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    stage = final + ".tmp"
    if os.path.exists(stage):
        shutil.rmtree(stage)
    os.makedirs(stage)
    flat = _flatten(tree)
    arrays = {}
    meta = {"step": step, "keys": []}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        arrays[k] = arr
        meta["keys"].append({"key": k, "shape": list(arr.shape),
                             "dtype": str(arr.dtype)})
    np.savez(os.path.join(stage, "arrays.npz"),
             **{k.replace(_SEP, "__"): v for k, v in arrays.items()})
    with open(os.path.join(stage, "manifest.json"), "w") as f:
        json.dump(meta, f)
    os.replace(stage, final)  # atomic publish
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            out.append(int(name[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None, *, like=None):
    """Load a checkpoint. ``like`` (pytree of arrays or ShapeDtypeStructs
    with shardings) re-shards every leaf onto the current mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    data = np.load(os.path.join(final, "arrays.npz"))
    flat = {k.replace("__", _SEP): data[k] for k in data.files}

    if like is None:
        return flat, step

    like_flat = _flatten(like)
    leaves = {}
    for k, ref in like_flat.items():
        arr = flat[k]
        sharding = getattr(ref, "sharding", None)
        if sharding is not None and not callable(sharding):
            leaves[k] = jax.device_put(arr, sharding)
        else:
            leaves[k] = jax.device_put(arr)
    # rebuild the tree in `like`'s structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path, _ in paths:
        key = _SEP.join(_path_str(p) for p in path)
        ordered.append(leaves[key])
    return jax.tree_util.tree_unflatten(treedef, ordered), step
