"""Train/serve step builders wiring model + optimizer + compression.

``make_lm_train_step`` returns the production training step: loss + grad,
optional gradient compression with error feedback (the compressed payload
is what crosses the DP axis), AdamW update. State is a plain dict pytree —
checkpoint- and reshard-friendly.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.grad_compress import CompressConfig, compress, init_error
from repro.sharding.rules import NO_SHARDING, ShardingPolicy


def make_lm_train_step(cfg: T.TransformerConfig,
                       opt_cfg: adamw.AdamWConfig,
                       policy: ShardingPolicy = NO_SHARDING,
                       compress_cfg: Optional[CompressConfig] = None):
    """Returns (init_fn(key) -> state, step_fn(state, batch) -> (state, metrics))."""

    def init_fn(key):
        params = T.init_params(cfg, key)
        state = {"params": params,
                 "opt": adamw.init_state(params, opt_cfg.moment_dtype)}
        if compress_cfg is not None and compress_cfg.kind != "none":
            state["err"] = init_error(params)
        return state

    @jax.jit
    def step_fn(state, batch):
        def loss_fn(p):
            return T.loss_fn(cfg, p, batch["tokens"], batch["targets"],
                             policy)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        metrics = {"loss": loss}
        if "err" in state:
            grads, new_err, wire = compress(compress_cfg, grads,
                                            state["err"])
            metrics["wire_bytes"] = jnp.asarray(wire)
        params, opt, opt_metrics = adamw.update(opt_cfg, grads,
                                                state["opt"],
                                                state["params"])
        new_state = {"params": params, "opt": opt}
        if "err" in state:
            new_state["err"] = new_err
        return new_state, {**metrics, **opt_metrics}

    return init_fn, step_fn


def make_generic_train_step(loss_fn, init_params_fn,
                            opt_cfg: adamw.AdamWConfig):
    """Family-agnostic variant (GNN / recsys smoke training loops)."""

    def init_fn(key):
        params = init_params_fn(key)
        return {"params": params, "opt": adamw.init_state(params)}

    @jax.jit
    def step_fn(state, batch):
        lv, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        params, opt, m = adamw.update(opt_cfg, grads, state["opt"],
                                      state["params"])
        return {"params": params, "opt": opt}, {"loss": lv, **m}

    return init_fn, step_fn
