"""Elastic scaling: reshard a running job onto a different mesh.

At 1000+ node scale, node loss means continuing on p' < p nodes (and
re-expanding later). Because checkpoints are stored unsharded-logical
(``checkpoint.py``) and every sharding is derived from the logical rules,
elasticity is: rebuild policy for the new mesh → rebuild abstract state →
``restore(..., like=new_abstract)``. For MFBC specifically, the batch size
``n_b = c·m/n`` re-derives from the new replication factor (paper §5.3.4:
strong scaling holds from p₀ to p₀^{3/2}·n²/m).
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.train import checkpoint as ckpt_lib


def reshard_checkpoint(ckpt_dir: str, new_like, step: Optional[int] = None):
    """Restore the latest checkpoint onto a new mesh's shardings."""
    return ckpt_lib.restore(ckpt_dir, step=step, like=new_like)


def bc_elastic_nb(n: int, m_edges: int, p: int, mem_bytes: float,
                  word: int = 8) -> int:
    """Re-derive the MFBC batch size for a new processor count (paper:
    n_b = c·m/n with c clamped by memory)."""
    from repro.spgemm.cost_model import best_replication

    c = best_replication(n, m_edges, p, mem_bytes, word=word)
    return max(1, int(c * m_edges / max(n, 1)))
