"""Fault tolerance: supervised restart loop + straggler mitigation.

``Supervisor.run`` drives a step function under a retry policy: on worker
failure (``WorkerFailure`` — raised by the harness when a host/device dies,
or injected by tests/chaos config) it restores the latest checkpoint and
resumes. The data pipeline is keyed by step, so a restarted run consumes
exactly the batches it would have — restarts are bit-exact (tested).

Straggler mitigation (``BackupTaskPolicy``): at 1000+ node scale the
slowest host dominates step time. The policy tracks a running latency
EWMA per data shard producer; when a producer exceeds ``threshold`` x the
median, its next input shard is *duplicated* onto the spare producer and
the first result wins (speculative execution at the input layer — the
device-side collectives stay bulk-synchronous, which is the only part we
can emulate honestly on CPU).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from repro.train import checkpoint as ckpt_lib


class WorkerFailure(RuntimeError):
    """A (simulated) lost worker/host."""


@dataclasses.dataclass
class ChaosConfig:
    """Deterministic failure injection for tests."""

    fail_at_steps: tuple = ()
    already_failed: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.already_failed:
            self.already_failed.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class Supervisor:
    ckpt_dir: str
    save_every: int = 10
    max_restarts: int = 10
    keep: int = 3

    def run(self, *, init_state, step_fn: Callable[[Any, int], Any],
            n_steps: int, chaos: Optional[ChaosConfig] = None,
            state_like=None, log: Optional[List[str]] = None):
        """Run ``step_fn(state, step) -> state`` with checkpoint/restart.

        Returns the final state. ``state_like`` (abstract tree with target
        shardings) enables restore onto a different mesh than the one that
        wrote the checkpoint.
        """
        restarts = 0
        state = init_state
        start = ckpt_lib.latest_step(self.ckpt_dir)
        if start is not None:
            state, start = ckpt_lib.restore(
                self.ckpt_dir, like=state_like if state_like is not None
                else init_state)
            start += 1
            if log is not None:
                log.append(f"resumed@{start}")
        else:
            start = 0

        step = start
        while step < n_steps:
            try:
                if chaos is not None:
                    chaos.maybe_fail(step)
                state = step_fn(state, step)
                if (step + 1) % self.save_every == 0 or step + 1 == n_steps:
                    ckpt_lib.save(self.ckpt_dir, step, state, keep=self.keep)
                step += 1
            except WorkerFailure as e:
                restarts += 1
                if log is not None:
                    log.append(f"failure@{step}:{e}")
                if restarts > self.max_restarts:
                    raise
                latest = ckpt_lib.latest_step(self.ckpt_dir)
                if latest is None:
                    state, step = init_state, 0
                else:
                    state, saved = ckpt_lib.restore(
                        self.ckpt_dir,
                        like=state_like if state_like is not None
                        else init_state)
                    step = saved + 1
                if log is not None:
                    log.append(f"restart@{step}")
        return state


@dataclasses.dataclass
class BackupTaskPolicy:
    """Speculative re-execution of slow input-shard producers."""

    n_producers: int
    threshold: float = 2.0
    ewma: float = 0.7
    _lat: Dict[int, float] = dataclasses.field(default_factory=dict)

    def observe(self, producer: int, seconds: float) -> None:
        prev = self._lat.get(producer, seconds)
        self._lat[producer] = self.ewma * prev + (1 - self.ewma) * seconds

    def stragglers(self) -> List[int]:
        if len(self._lat) < max(2, self.n_producers // 2):
            return []
        med = sorted(self._lat.values())[len(self._lat) // 2]
        return [p for p, l in self._lat.items() if l > self.threshold * med]

    def fetch(self, producers: Dict[int, Callable[[], Any]],
              timer=time.monotonic) -> Dict[int, Any]:
        """Fetch every shard; duplicate flagged stragglers onto the least
        loaded producer and take the first completion (here: the faster of
        the two measured calls — single-process emulation)."""
        flagged = set(self.stragglers())
        out = {}
        for pid, fn in producers.items():
            t0 = timer()
            val = fn()
            dt = timer() - t0
            if pid in flagged:
                # speculative duplicate on the backup producer
                t1 = timer()
                val2 = fn()
                dt2 = timer() - t1
                if dt2 < dt:
                    val, dt = val2, dt2
            self.observe(pid, dt)
            out[pid] = val
        return out
