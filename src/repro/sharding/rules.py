"""Logical-axis → PartitionSpec rules (MaxText-style, condensed).

Every parameter and activation is annotated with a tuple of *logical* axis
names; a ``ShardingPolicy`` maps logical names to mesh axes:

  batch    → (pod, data)    — DP
  fsdp     → (pod, data)    — weight shard (ZeRO-3); all-gathered per layer
  model    → model          — TP (heads / ffn / vocab / experts)
  seq      → model           — sequence parallelism for long-context cells
  (None)   → replicated

The policy is a plain dict so perf hillclimbing can swap assignments
without touching model code.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: usable as a
class ShardingPolicy:                          # static arg to jax.checkpoint
    mesh: Optional[Mesh]
    rules: Dict[str, object]  # logical name -> mesh axis (str|tuple|None)

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(self.mesh.shape.get("model", 1))

    def spec(self, logical: Logical) -> P:
        return P(*(self.rules.get(ax) if ax else None for ax in logical))

    def _axes_size(self, assignment) -> int:
        if assignment is None:
            return 1
        axes = (assignment,) if isinstance(assignment, str) else assignment
        size = 1
        for a in axes:
            size *= int(self.mesh.shape.get(a, 1))
        return size

    def spec_for_shape(self, logical: Logical, shape) -> P:
        """Like ``spec`` but (a) drops assignments a dim cannot host (e.g.
        a batch-1 decode cell over a 16-way data axis) and (b) removes mesh
        axes already claimed by an earlier dim (e.g. ``expert`` over
        (pod, model) alongside ``batch`` over (pod, data) keeps only
        ``data`` for the batch dim)."""
        parts = []
        used = set()
        for ax, dim in zip(logical, shape):
            a = self.rules.get(ax) if ax else None
            if a is not None:
                axes = (a,) if isinstance(a, str) else tuple(a)
                axes = tuple(x for x in axes if x not in used)
                a = None if not axes else (axes[0] if len(axes) == 1
                                           else axes)
            if a is not None and dim % max(self._axes_size(a), 1) != 0:
                a = None
            if a is not None:
                used.update((a,) if isinstance(a, str) else a)
            parts.append(a)
        return P(*parts)

    def constrain(self, x, logical: Logical):
        """with_sharding_constraint if a mesh is active; no-op otherwise."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec_for_shape(logical,
                                                            x.shape)))

    def named(self, logical: Logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical))

    def named_for_shape(self, logical: Logical, shape
                        ) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for_shape(logical, shape))


NO_SHARDING = ShardingPolicy(None, {})


def make_policy(mesh: Optional[Mesh], *, seq_shard: bool = False,
                fsdp: bool = True, overrides: Optional[Dict] = None
                ) -> ShardingPolicy:
    if mesh is None:
        return NO_SHARDING
    has_pod = "pod" in mesh.axis_names
    dp = ("pod", "data") if has_pod else ("data",)
    rules = {
        "batch": dp,
        "fsdp": dp if fsdp else None,
        "model": "model",
        "expert": "model",
        "seq": "model" if seq_shard else None,
        "kv_seq": ("data", "model"),  # long-context KV cache sharding
        "vocab": "model",
    }
    if overrides:
        rules.update(overrides)
    return ShardingPolicy(mesh, rules)


def param_sharding(policy: ShardingPolicy, logical_tree):
    """Map a pytree of logical tuples to NamedShardings (or None)."""
    return jax.tree.map(policy.named, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
