from repro.sharding.rules import (ShardingPolicy, make_policy, param_sharding,
                                  NO_SHARDING)

__all__ = ["ShardingPolicy", "make_policy", "param_sharding", "NO_SHARDING"]
