"""2D edge-partitioned GNN message passing — the paper's SpGEMM insight
applied to graph neural networks (hillclimb, EXPERIMENTS.md §Perf).

Baseline GSPMD lowering of ``segment_sum`` message passing realizes the
paper's **1D variant C**: every device computes a full-size partial node
buffer and all-reduces it (bytes ≈ 2·|H| per layer per device). The 2D
decomposition (paper §5.2) assigns edges to a (R × C) = (data × model)
grid by (dst-range, src-shard):

* device (r, c) holds the edges whose **source** lives in its local
  feature shard S_c and whose **destination** falls in contiguous range r
  → message gather is 100% local;
* partial destination sums (N/R, h) reduce-scatter over ``model`` and
  all-gather over ``data`` — bytes ≈ |H|/R + |H|/C per device: a
  ``R·C·2/(R+C)`` ≈ 16x collective reduction on the production mesh.

Node state lives in the same interleaved Π-layout as the distributed BC
step (see ``repro.core.dist_bc`` module docstring); the closed-form id map
lets the host bucket edges once. Implemented for GCN (the regime
representative); the same structure drops into GIN/GAT.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Grid2D:
    n_pad: int  # padded node count (divisible by R*C)
    e_max: int  # max edges per device (padded)
    r_axes: Tuple[str, ...]  # destination-range axes (e.g. ("pod","data"))
    c_axis: str  # source-shard axis ("model")
    R: int
    C: int

    @property
    def sub(self) -> int:
        return self.n_pad // (self.R * self.C)

    @property
    def n_loc(self) -> int:  # state rows per device (model shard)
        return self.n_pad // self.C


def make_grid(mesh: Mesh, n: int, e_total: int) -> Grid2D:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    r_axes = tuple(a for a in ("pod", "data") if a in sizes)
    R = int(np.prod([sizes[a] for a in r_axes]))
    C = sizes["model"]
    n_pad = -(-n // (R * C)) * (R * C)
    # balanced-bucket assumption (paper §5.2 balls-into-bins): budget 1.5x
    e_max = -(-int(1.5 * e_total / (R * C)) // 128) * 128 + 128
    return Grid2D(n_pad, e_max, r_axes, "model", R, C)


# --- host-side bucketing ----------------------------------------------------


def _pos_in_layout(g: Grid2D, v: np.ndarray):
    """(shard c, local row) of vertex v in the interleaved Π-layout."""
    blk_r = g.n_pad // g.R
    c = (v % blk_r) // g.sub
    local = (v // blk_r) * g.sub + (v % g.sub)
    return c, local


def bucket_edges(g: Grid2D, src: np.ndarray, dst: np.ndarray,
                 coef: Optional[np.ndarray] = None):
    """Bucket edges onto the (R, C) grid.

    Returns (src_local, dst_local, coef, valid): each (R*C, e_max).
    Bucket of edge (u, v): c = source's model shard, r = v // (N/R).
    dst_local indexes a per-device (N/R,) partial buffer.
    """
    if coef is None:
        coef = np.ones(src.shape[0], np.float32)
    blk_r = g.n_pad // g.R
    c_src, src_loc = _pos_in_layout(g, src.astype(np.int64))
    r_dst = dst.astype(np.int64) // blk_r
    dst_loc = dst.astype(np.int64) % blk_r
    bucket = r_dst * g.C + c_src

    nb = g.R * g.C
    order = np.argsort(bucket, kind="stable")
    bucket_s = bucket[order]
    counts = np.bincount(bucket_s, minlength=nb)
    if counts.max() > g.e_max:
        raise ValueError(f"bucket overflow: {counts.max()} > {g.e_max}")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    out_src = np.zeros((nb, g.e_max), np.int32)
    out_dst = np.full((nb, g.e_max), blk_r, np.int32)  # pad -> dummy row
    out_coef = np.zeros((nb, g.e_max), np.float32)
    for b in range(nb):
        sl = order[starts[b]:starts[b] + counts[b]]
        out_src[b, :counts[b]] = src_loc[sl]
        out_dst[b, :counts[b]] = dst_loc[sl]
        out_coef[b, :counts[b]] = coef[sl]
    return out_src, out_dst, out_coef


def layout_features(g: Grid2D, x: np.ndarray) -> np.ndarray:
    """Permute (N, d) host features into the Π-layout (concat of S_c)."""
    n, d = x.shape
    xp = np.zeros((g.n_pad, d), x.dtype)
    xp[:n] = x
    blk_r = g.n_pad // g.R
    v = np.arange(g.n_pad)
    c, local = _pos_in_layout(g, v)
    out = np.zeros_like(xp)
    out_index = c * g.n_loc + local
    out[out_index] = xp[v]
    return out


# --- device-side 2D GCN -----------------------------------------------------


def _gcn2d_local(g: Grid2D, n_layers: int, params, x_loc, src, dst, coef,
                 labels_loc, mask_loc):
    """Per-device GCN forward + CE loss. x_loc: (n_loc, d)."""
    blk_r = g.n_pad // g.R

    def propagate(h):  # h: (n_loc, dh) -> aggregated (n_loc, dh)
        m = h[src] * coef[:, None]  # local gather (E, dh)
        part = jax.ops.segment_sum(m, dst, num_segments=blk_r + 1)[:blk_r]
        # reduce over model (partial over src shards), scatter rows
        part = jax.lax.psum_scatter(part, g.c_axis, scatter_dimension=0,
                                    tiled=True)  # (blk_r/C, dh)
        # re-gather rows over the dst-range axes -> (n_loc, dh), Π-layout
        for ax in reversed(g.r_axes):
            part = jax.lax.all_gather(part, ax, axis=0, tiled=True)
        return part

    h = x_loc
    for i, w in enumerate(params["w"]):
        h = propagate(h @ w)
        if i + 1 < n_layers:
            h = jax.nn.relu(h)
    # masked CE over local rows; every row appears once per (model) fiber
    logz = jax.nn.logsumexp(h.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(h.astype(jnp.float32),
                               labels_loc[:, None], axis=-1)[:, 0]
    loss = jnp.sum(jnp.where(mask_loc, logz - gold, 0.0))
    cnt = jnp.sum(mask_loc.astype(jnp.float32))
    loss = jax.lax.psum(loss, g.c_axis)
    cnt = jax.lax.psum(cnt, g.c_axis)
    return loss / jnp.maximum(cnt, 1.0)


def build_gcn2d_loss(mesh: Mesh, g: Grid2D, n_layers: int):
    """Returns loss(params, batch) distributed on the 2D grid.

    batch: x (n_pad, d) P(model on rows); src/dst/coef (R*C, e_max)
    P((r_axes, c_axis) on dim 0); labels/mask (n_pad,) P(model).
    """
    edge_spec = P(g.r_axes + (g.c_axis,), None)
    state_spec = P(g.c_axis, None)
    vec_spec = P(g.c_axis)

    def local(params, x, src, dst, coef, labels, mask):
        return _gcn2d_local(g, n_layers, params,
                            x, src[0], dst[0], coef[0], labels, mask)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(), state_spec, edge_spec, edge_spec, edge_spec,
                  vec_spec, vec_spec),
        out_specs=P(),
        check_vma=False,
    )
    return fn


def abstract_inputs(mesh: Mesh, g: Grid2D, d_in: int):
    sds = jax.ShapeDtypeStruct
    edge_spec = NamedSharding(mesh, P(g.r_axes + (g.c_axis,), None))
    state = NamedSharding(mesh, P(g.c_axis, None))
    vec = NamedSharding(mesh, P(g.c_axis))
    return {
        "x": sds((g.n_pad, d_in), jnp.float32, sharding=state),
        "src": sds((g.R * g.C, g.e_max), jnp.int32, sharding=edge_spec),
        "dst": sds((g.R * g.C, g.e_max), jnp.int32, sharding=edge_spec),
        "coef": sds((g.R * g.C, g.e_max), jnp.float32, sharding=edge_spec),
        "labels": sds((g.n_pad,), jnp.int32, sharding=vec),
        "mask": sds((g.n_pad,), jnp.bool_, sharding=vec),
    }
