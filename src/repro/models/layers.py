"""Transformer building blocks: RMSNorm, RoPE, GQA attention (softcap +
sliding window), gated MLP, and capacity-based top-k MoE.

Sharding strategy (see DESIGN.md §4): parameters carry explicit
NamedSharding via the logical rules in ``repro.sharding.rules``; activations
get ``with_sharding_constraint`` at layer boundaries. TP = heads/ffn/vocab
over ``model``; FSDP = the other big dim over ``(pod, data)``; EP = experts
over ``model``.

All functions are pure; parameters are nested dicts of arrays (stacked on a
leading layer dim for ``lax.scan``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             zero_centered: bool = True) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale) if zero_centered else scale
    return (y * w).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
         ) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    window: Optional[int] = None  # sliding-window size for local layers
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)


def attention(cfg: AttnConfig, p: Params, x: jax.Array,
              positions: jax.Array, *, mask: Optional[jax.Array] = None,
              kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_pos: Optional[jax.Array] = None,
              dp_spec=None) -> Tuple[jax.Array, Optional[Tuple]]:
    """GQA attention.

    x: (B, S, d). With ``kv_cache=(k, v)`` of shape (B, S_max, n_kv, hd),
    appends the new keys/values at ``cache_pos`` and attends over the cache
    (decode / chunked prefill). Returns (out, new_cache).
    """
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]).reshape(B, S, K, hd)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]).reshape(B, S, K, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    scale = cfg.query_scale if cfg.query_scale is not None else hd ** -0.5
    q = q * scale

    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 cache_pos, axis=1)
        k_all, v_all = ck, cv
        kv_positions = jnp.arange(k_all.shape[1])
        new_cache = (ck, cv)
    else:
        k_all, v_all = k, v
        kv_positions = positions[0] if positions.ndim > 1 else positions
        new_cache = None

    T = k_all.shape[1]
    g = H // K  # queries per kv group
    qg = q.reshape(B, S, K, g, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k_all)
    logits = softcap(logits, cfg.attn_softcap)

    q_pos = positions if positions.ndim > 1 else positions[None, :]
    causal = kv_positions[None, None, :] <= q_pos[:, :, None]  # (B, S, T)
    if cfg.window is not None:
        causal &= kv_positions[None, None, :] > q_pos[:, :, None] - cfg.window
    if kv_cache is not None:
        valid = kv_positions[None, None, :] < (cache_pos + S)
        causal &= valid
    if mask is not None:
        causal &= mask
    logits = jnp.where(causal[:, None, None, :, :], logits, -1e30)

    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v_all).reshape(B, S, H * hd)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return out, new_cache


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    d_ff: int
    act: str = "silu"  # silu (llama/command-r) | gelu (gemma2/granite)
    style: str = "gated"  # gated (SwiGLU/GeGLU) | plain (GPT-BigCode)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu,
                                                           approximate=True),
            "relu": jax.nn.relu}[name]


def gated_mlp(cfg: MlpConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.style == "plain":
        return _act(cfg.act)(x @ p["w_up"]) @ p["w_down"]
    h = _act(cfg.act)(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert ffn width
    act: str = "silu"
    capacity_factor: float = 1.25
    router_softcap: Optional[float] = None
    n_shared: int = 0  # shared (always-on) experts, moonshot-style
    d_ff_shared: int = 0


def moe_block(cfg: MoeConfig, p: Params, x: jax.Array,
              policy=None) -> jax.Array:
    """Capacity-based top-k MoE with sort-based dispatch (MegaBlocks-style
    grouped GEMM realized as an (E, cap, d) einsum; EP = experts sharded
    over ``model``, tokens reach their experts through the all-to-all XLA
    inserts for the resharding between token-major and expert-major forms).
    """
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, D)
    logits = softcap(xt @ p["router"], cfg.router_softcap)  # (T, E)
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_g, top_e = jax.lax.top_k(gates, K)  # (T, K)
    top_g = (top_g / jnp.clip(top_g.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    cap = int(np.ceil(T * K / E * cfg.capacity_factor))
    cap = max(cap, 4)
    # flatten assignments; rank-within-expert via one stable sort (the
    # (T·K, E) one-hot cumsum variant is quadratic-ish on some backends)
    flat_e = top_e.reshape(-1)  # (T*K,)
    flat_g = top_g.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(jnp.ones_like(flat_e), flat_e,
                                 num_segments=E)  # (E,)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(T * K) - starts[sorted_e]
    rank = jnp.zeros((T * K,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, E * cap)  # drop -> scratch

    # scatter tokens into (E*cap+1, D) buffer; expert-major form is
    # sharded (E over model = EP, cap over data) — the token->expert
    # resharding here IS the MoE all-to-all.
    buf = jnp.zeros((E * cap + 1, D), x.dtype).at[slot].add(xt[flat_tok])
    buf = buf[:E * cap].reshape(E, cap, D)
    if policy is not None:
        buf = policy.constrain(buf, ("expert", "batch", None))
    h = _act(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if policy is not None:
        h = policy.constrain(h, ("expert", "batch", None))
    yb = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * cap, D)
    yb = jnp.concatenate([yb, jnp.zeros((1, D), yb.dtype)], axis=0)
    y = jnp.zeros((T, D), x.dtype).at[flat_tok].add(
        yb[slot] * jnp.where(keep, flat_g, 0.0)[:, None])

    if cfg.n_shared:
        sh = MlpConfig(cfg.d_ff_shared or cfg.d_ff, cfg.act)
        y = y + gated_mlp(sh, p["shared"], xt)
    return y.reshape(B, S, D)


def embed_tokens(p: Params, tokens: jax.Array, *, scale: bool = False
                 ) -> jax.Array:
    emb = p["embedding"][tokens]
    if scale:
        emb = emb * (p["embedding"].shape[-1] ** 0.5)
    return emb


def lm_logits(p: Params, x: jax.Array, *, cap: Optional[float] = None,
              tied: bool = True) -> jax.Array:
    w = p["embedding"].T if tied else p["lm_head"]
    return softcap(jnp.einsum("bsd,dv->bsv", x, w), cap)
