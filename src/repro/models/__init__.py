"""Architecture zoo: LM transformers (dense + MoE), GNNs, recsys."""
from repro.models import gnn, layers, recsys, transformer

__all__ = ["gnn", "layers", "recsys", "transformer"]
