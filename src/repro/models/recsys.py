"""xDeepFM [Lian et al., arXiv:1803.05170]: linear + CIN + DNN over sparse
categorical fields.

EmbeddingBag is built from scratch (JAX has none): one flat table with
per-field offsets, ``jnp.take`` gather + ``segment_sum`` for multi-hot
bags. The table is the paper-technique surface (DESIGN.md §5): a lookup is
the sparse matmul ``onehot(idx) · W`` and the table-shard-vs-replicate
decision is the 1D "variant B" cost comparison from §5.2 — the table shards
rows over ``model`` and the gather's collective is exactly the variant-B
broadcast.

CIN (Compressed Interaction Network): x^{k+1}_h = Σ_{i,j} W^k_{h,i,j}
(x^k_i ∘ x^0_j), realized as one outer-product einsum per layer, sum-pooled
over the embedding dim into the final logit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str
    n_fields: int = 39
    vocab_per_field: int = 1_000_000  # uniform for the synthetic pipeline
    embed_dim: int = 10
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    mlp_layers: Tuple[int, ...] = (400, 400)
    multi_hot: int = 1  # ids per field (bag size)

    @property
    def total_vocab(self) -> int:
        # padded to a mesh-divisible row count (512 = model x fsdp ways)
        raw = self.n_fields * self.vocab_per_field
        return -(-raw // 512) * 512

    def n_params(self) -> int:
        m = self.n_fields
        n = self.total_vocab * self.embed_dim + self.total_vocab  # emb + linear
        prev = m
        for h in self.cin_layers:
            n += h * prev * m  # W^k: (H_k, H_{k-1}, m)
            prev = h
        d = m * self.embed_dim
        for h in self.mlp_layers:
            n += d * h + h
            d = h
        n += d + sum(self.cin_layers) + 1
        return n


def _dense(key, shape):
    return jax.random.normal(key, shape, jnp.float32) / np.sqrt(max(shape[0], 1))


def init_params(cfg: XDeepFMConfig, key) -> Params:
    keys = iter(jax.random.split(key, 8 + len(cfg.cin_layers)
                                 + len(cfg.mlp_layers)))
    p: Params = {
        "table": jax.random.normal(next(keys),
                                   (cfg.total_vocab, cfg.embed_dim),
                                   jnp.float32) * 0.01,
        "linear": jnp.zeros((cfg.total_vocab,), jnp.float32),
        "bias": jnp.zeros(()),
    }
    prev = cfg.n_fields
    cin = []
    for h in cfg.cin_layers:
        cin.append(_dense(next(keys), (h, prev, cfg.n_fields)))
        prev = h
    p["cin"] = cin
    p["cin_out"] = _dense(next(keys), (sum(cfg.cin_layers),))
    mlp = []
    d = cfg.n_fields * cfg.embed_dim
    for h in cfg.mlp_layers:
        mlp.append({"w": _dense(next(keys), (d, h)), "b": jnp.zeros(h)})
        d = h
    p["mlp"] = mlp
    p["mlp_out"] = _dense(next(keys), (d,))
    return p


def abstract_params(cfg: XDeepFMConfig, policy=None):
    """ShapeDtypeStructs with the table row-sharded over model x fsdp."""
    p = init_shapes(cfg)

    def mk(path_shape):
        shape, logical = path_shape
        sh = policy.named(logical) if policy is not None and \
            policy.mesh is not None else None
        return jax.ShapeDtypeStruct(shape, jnp.float32, sharding=sh)

    return jax.tree.map(mk, p, is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and isinstance(x[0], tuple))


def init_shapes(cfg: XDeepFMConfig):
    """(shape, logical_axes) pairs; table rows shard over (model, fsdp)."""
    prev = cfg.n_fields
    cin = []
    for h in cfg.cin_layers:
        cin.append((( h, prev, cfg.n_fields), (None, None, None)))
        prev = h
    d = cfg.n_fields * cfg.embed_dim
    mlp = []
    for h in cfg.mlp_layers:
        mlp.append({"w": ((d, h), (None, None)), "b": ((h,), (None,))})
        d = h
    return {
        "table": ((cfg.total_vocab, cfg.embed_dim), (("model", "fsdp"), None)),
        "linear": ((cfg.total_vocab,), (("model", "fsdp"),)),
        "bias": ((), ()),
        "cin": cin,
        "cin_out": ((sum(cfg.cin_layers),), (None,)),
        "mlp": mlp,
        "mlp_out": ((d,), (None,)),
    }


def embedding_bag(table: jax.Array, ids: jax.Array, weights=None,
                  combine: str = "sum") -> jax.Array:
    """ids: (B, F, H) flat-vocab ids (H = bag size). -> (B, F, D).

    The from-scratch EmbeddingBag: gather + in-bag reduction. For H == 1
    this is a plain lookup.
    """
    emb = jnp.take(table, ids, axis=0)  # (B, F, H, D)
    if weights is not None:
        emb = emb * weights[..., None]
    if combine == "sum":
        return jnp.sum(emb, axis=2)
    if combine == "mean":
        return jnp.mean(emb, axis=2)
    raise ValueError(combine)


def forward(cfg: XDeepFMConfig, p: Params, ids: jax.Array,
            policy=None) -> jax.Array:
    """ids: (B, n_fields, multi_hot) flat ids -> logits (B,)."""
    B = ids.shape[0]
    if policy is not None:
        ids = policy.constrain(ids, ("batch", None, None))
    x0 = embedding_bag(p["table"], ids)  # (B, m, D)
    lin = jnp.sum(jnp.take(p["linear"], ids, axis=0), axis=(1, 2))  # (B,)

    # CIN branch
    xk = x0
    pooled = []
    for w in p["cin"]:
        inter = jnp.einsum("bhd,bmd->bhmd", xk, x0)  # (B, H_k, m, D)
        xk = jnp.einsum("bhmd,nhm->bnd", inter, w)  # (B, H_{k+1}, D)
        pooled.append(jnp.sum(xk, axis=-1))  # (B, H_{k+1})
    cin_logit = jnp.concatenate(pooled, axis=-1) @ p["cin_out"]

    # DNN branch
    h = x0.reshape(B, -1)
    for lp in p["mlp"]:
        h = jax.nn.relu(h @ lp["w"] + lp["b"])
    mlp_logit = h @ p["mlp_out"]

    return lin + cin_logit + mlp_logit + p["bias"]


def bce_loss(cfg: XDeepFMConfig, p: Params, ids: jax.Array,
             labels: jax.Array, policy=None) -> jax.Array:
    logits = forward(cfg, p, ids, policy)
    return jnp.mean(jnp.clip(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_score(cfg: XDeepFMConfig, p: Params, query_ids: jax.Array,
                    cand_ids: jax.Array, policy=None) -> jax.Array:
    """retrieval_cand cell: one query (1, F, H) against N candidate items.

    Candidates are represented by their item-field ids (N, Fc, H). Scoring
    is a batched dot between the query's pooled user vector and candidate
    embeddings — a single matmul, not a loop.
    """
    q = embedding_bag(p["table"], query_ids)  # (1, F, D)
    qv = q.mean(axis=1)  # (1, D)
    c = embedding_bag(p["table"], cand_ids)  # (N, Fc, D)
    cv = c.mean(axis=1)  # (N, D)
    if policy is not None:
        cv = policy.constrain(cv, ("batch", None))
    return cv @ qv[0]  # (N,)
