"""GNN architecture family: GCN, GIN, GAT, and an E(3)-equivariant
NequIP-class network.

Message passing is built from ``jnp.take`` (gather) + ``jax.ops.segment_sum``
/ ``segment_max`` over a static-shape padded edge list — the TPU-native
SpMM idiom (JAX has no CSR; see kernel_taxonomy §GNN). Padding edges point
at a dummy node slot ``n`` (arrays are sized n+1) so they are algebraically
inert.

The paper-technique tie-in (DESIGN.md §5): message passing *is* a sparse
matmul ``Â·X``; the spgemm cost model drives the edge/node axis assignment
(edges over ``data``, nodes over ``model``, pod = the paper's replication
factor c for full-batch large graphs).

NequIP (arXiv:2101.03164) is realized with l_max = 2 in the *Cartesian*
tensor basis — features are (scalars, vectors, traceless-symmetric rank-2)
channels and the Clebsch-Gordan products become the closed-form Cartesian
contractions (TensorNet-style, arXiv:2306.06482). This is mathematically
the same O(3)-irrep content as spherical l ≤ 2 but avoids CG-coefficient
gathers (MXU/VPU-friendly). Exact equivariance is property-tested under
random rotations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def _seg_sum(x, idx, n):
    return jax.ops.segment_sum(x, idx, num_segments=n)


def _seg_max(x, idx, n):
    return jax.ops.segment_max(x, idx, num_segments=n)


def _dense(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# GCN [Kipf & Welling, arXiv:1609.02907]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 16
    d_in: int = 1433
    n_classes: int = 7
    dropout: float = 0.0  # deterministic eval path


def gcn_init(cfg: GCNConfig, key) -> Params:
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, cfg.n_layers)
    return {"w": [_dense(keys[i], (dims[i], dims[i + 1]))
                  for i in range(cfg.n_layers)]}


def gcn_forward(cfg: GCNConfig, p: Params, batch: Dict[str, jax.Array]
                ) -> jax.Array:
    """batch: x (n+1, d_in), src/dst (E,), deg (n+1,). Sym-normalized."""
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    n1 = x.shape[0]
    dinv = jax.lax.rsqrt(jnp.clip(batch["deg"].astype(jnp.float32), 1.0))
    coef = (dinv[src] * dinv[dst])[:, None]
    for i, w in enumerate(p["w"]):
        h = x @ w
        h = _seg_sum(h[src] * coef, dst, n1) + h * (dinv * dinv)[:, None]
        x = jax.nn.relu(h) if i + 1 < len(p["w"]) else h
    return x


# ---------------------------------------------------------------------------
# GIN [Xu et al., arXiv:1810.00826]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 7
    n_classes: int = 2
    learn_eps: bool = True


def gin_init(cfg: GINConfig, key) -> Params:
    keys = jax.random.split(key, 2 * cfg.n_layers + 1)
    mlps = []
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        mlps.append({"w1": _dense(keys[2 * i], (d_prev, cfg.d_hidden)),
                     "b1": jnp.zeros(cfg.d_hidden),
                     "w2": _dense(keys[2 * i + 1], (cfg.d_hidden, cfg.d_hidden)),
                     "b2": jnp.zeros(cfg.d_hidden)})
        d_prev = cfg.d_hidden
    return {"mlps": mlps, "eps": jnp.zeros(cfg.n_layers),
            "readout": _dense(keys[-1], (cfg.d_hidden, cfg.n_classes))}


def gin_forward(cfg: GINConfig, p: Params, batch: Dict[str, jax.Array]
                ) -> jax.Array:
    """Graph-level readout when ``graph_ids`` present, else node logits."""
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    n1 = x.shape[0]
    for i, mlp in enumerate(p["mlps"]):
        agg = _seg_sum(x[src], dst, n1)
        h = (1.0 + p["eps"][i]) * x + agg
        h = jax.nn.relu(h @ mlp["w1"] + mlp["b1"])
        x = jax.nn.relu(h @ mlp["w2"] + mlp["b2"])
    if "graph_ids" in batch:
        gx = _seg_sum(x, batch["graph_ids"], batch["n_graphs"])
        return gx @ p["readout"]
    return x @ p["readout"]


# ---------------------------------------------------------------------------
# GAT [Veličković et al., arXiv:1710.10903]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    negative_slope: float = 0.2


def gat_init(cfg: GATConfig, key) -> Params:
    layers = []
    d_prev = cfg.d_in
    keys = jax.random.split(key, 3 * cfg.n_layers)
    for i in range(cfg.n_layers):
        last = i + 1 == cfg.n_layers
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        layers.append({
            "w": _dense(keys[3 * i], (d_prev, heads * d_out)),
            "a_src": _dense(keys[3 * i + 1], (heads, d_out)),
            "a_dst": _dense(keys[3 * i + 2], (heads, d_out)),
        })
        d_prev = heads * d_out
    return {"layers": layers}


def gat_forward(cfg: GATConfig, p: Params, batch: Dict[str, jax.Array]
                ) -> jax.Array:
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    n1 = x.shape[0]
    pad = batch.get("edge_pad")  # bool (E,), True = padding edge
    for i, lp in enumerate(p["layers"]):
        last = i + 1 == len(p["layers"])
        heads = 1 if last else cfg.n_heads
        d_out = lp["w"].shape[1] // heads
        h = (x @ lp["w"]).reshape(n1, heads, d_out)
        al = jnp.einsum("nhd,hd->nh", h, lp["a_src"])
        ar = jnp.einsum("nhd,hd->nh", h, lp["a_dst"])
        e = jax.nn.leaky_relu(al[src] + ar[dst], cfg.negative_slope)  # (E, H)
        if pad is not None:
            e = jnp.where(pad[:, None], -1e30, e)
        emax = _seg_max(e, dst, n1)[dst]
        ex = jnp.exp(e - emax)
        if pad is not None:
            ex = jnp.where(pad[:, None], 0.0, ex)
        denom = jnp.clip(_seg_sum(ex, dst, n1), 1e-9)[dst]
        alpha = ex / denom  # (E, H) edge softmax (SDDMM -> segment softmax)
        msg = h[src] * alpha[:, :, None]
        out = _seg_sum(msg, dst, n1)  # (n1, H, d_out)
        x = out.reshape(n1, heads * d_out)
        if not last:
            x = jax.nn.elu(x)
    return x


# ---------------------------------------------------------------------------
# NequIP-class E(3)-equivariant network (Cartesian l_max = 2 realization)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str
    n_layers: int = 5
    channels: int = 32
    l_max: int = 2  # 0: scalars, 1: +vectors, 2: +rank-2 traceless
    n_rbf: int = 8
    cutoff: float = 5.0
    d_in: int = 16  # species / input feature dim
    readout: str = "energy"  # energy (sum) | node (per-node scalar head)
    n_out: int = 1


def nequip_init(cfg: NequIPConfig, key) -> Params:
    C = cfg.channels
    keys = iter(jax.random.split(key, 4 + cfg.n_layers * 8))
    p: Params = {"embed": _dense(next(keys), (cfg.d_in, C))}
    layers = []
    n_paths = 6  # radial weights per message block (see nequip_forward)
    for _ in range(cfg.n_layers):
        layers.append({
            "radial_w1": _dense(next(keys), (cfg.n_rbf, 32)),
            "radial_w2": _dense(next(keys), (32, C * n_paths)),
            "mix_s": _dense(next(keys), (C, C)),
            "mix_v": _dense(next(keys), (C, C)),
            "mix_t": _dense(next(keys), (C, C)),
            "gate_w": _dense(next(keys), (3 * C, 2 * C)),
            "upd_w1": _dense(next(keys), (3 * C, 2 * C)),
            "upd_w2": _dense(next(keys), (2 * C, C)),
        })
    p["layers"] = layers
    p["out_w1"] = _dense(next(keys), (C, C))
    p["out_w2"] = _dense(next(keys), (C, cfg.n_out))
    return p


def _rbf(dist, n_rbf, cutoff):
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    basis = jnp.exp(-gamma * jnp.square(dist[:, None] - mu[None, :]))
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cutoff, 0, 1)) + 1.0)
    return basis * env[:, None]


def nequip_forward(cfg: NequIPConfig, p: Params, batch: Dict[str, jax.Array]
                   ) -> jax.Array:
    """batch: pos (n+1, 3), x (n+1, d_in), src/dst (E,), optional
    graph_ids/n_graphs. Padding edges must connect the dummy node to
    itself (zero edge vector -> zero envelope contribution guarded)."""
    pos, src, dst = batch["pos"], batch["src"], batch["dst"]
    n1 = pos.shape[0]
    C = cfg.channels
    s = batch["x"] @ p["embed"]  # (n1, C) scalars
    v = jnp.zeros((n1, C, 3))
    t = jnp.zeros((n1, C, 3, 3))

    r = pos[src] - pos[dst]  # (E, 3)
    d = jnp.sqrt(jnp.sum(r * r, axis=-1) + 1e-12)
    u = r / d[:, None]
    rbf = _rbf(d, cfg.n_rbf, cfg.cutoff)  # (E, R)
    real = d > 1e-6  # padding edges have zero length
    eye = jnp.eye(3)
    Y2 = u[:, :, None] * u[:, None, :] - eye[None] / 3.0  # (E, 3, 3)

    for lp in p["layers"]:
        w = jax.nn.silu(rbf @ lp["radial_w1"]) @ lp["radial_w2"]
        w = jnp.where(real[:, None], w, 0.0).reshape(-1, C, 6)  # (E, C, 6)
        sj, vj, tj = s[src], v[src], t[src]
        # l-mixing message paths (Cartesian CG products, l <= 2):
        m_s = w[..., 0] * sj                                    # 0⊗0→0
        m_s = m_s + w[..., 1] * jnp.einsum("eci,ei->ec", vj, u)  # 1⊗1→0
        m_v = w[..., 2, None] * vj                               # 1⊗0→1
        m_v = m_v + w[..., 3, None] * sj[..., None] * u[:, None, :]  # 0⊗1→1
        m_v = m_v + w[..., 4, None] * jnp.einsum("ecij,ej->eci", tj, u)  # 2⊗1→1
        m_t = w[..., 5, None, None] * sj[..., None, None] * Y2[:, None]  # 0⊗2→2
        agg_s = _seg_sum(m_s, dst, n1)
        agg_v = _seg_sum(m_v, dst, n1)
        agg_t = _seg_sum(m_t, dst, n1)
        # channel mixing (equivariant: acts on channel dim only)
        s_n = agg_s @ lp["mix_s"]
        v_n = jnp.einsum("ncx,cd->ndx", agg_v, lp["mix_v"])
        t_n = jnp.einsum("ncxy,cd->ndxy", agg_t, lp["mix_t"])
        # invariants -> gates
        inv = jnp.concatenate(
            [s_n, jnp.sum(v_n * v_n, -1), jnp.einsum("ncxy,ncxy->nc", t_n, t_n)],
            axis=-1)  # (n1, 3C)
        gates = jax.nn.sigmoid(inv @ lp["gate_w"]).reshape(n1, 2, C)
        upd = jax.nn.silu(inv @ lp["upd_w1"]) @ lp["upd_w2"]
        s = s + upd
        v = v + gates[:, 0][..., None] * v_n
        t = t + gates[:, 1][..., None, None] * t_n
    h = jax.nn.silu(s @ p["out_w1"]) @ p["out_w2"]  # (n1, n_out) invariant
    if cfg.readout == "energy" and "graph_ids" in batch:
        return _seg_sum(h, batch["graph_ids"], batch["n_graphs"])
    return h


# ---------------------------------------------------------------------------
# Unified entry points (used by configs / dryrun / smoke tests).
# ---------------------------------------------------------------------------

FORWARD = {"gcn": gcn_forward, "gin": gin_forward, "gat": gat_forward,
           "nequip": nequip_forward}
INIT = {"gcn": gcn_init, "gin": gin_init, "gat": gat_init,
        "nequip": nequip_init}


def node_ce_loss(kind, cfg, params, batch):
    logits = FORWARD[kind](cfg, params, batch).astype(jnp.float32)
    labels = batch["labels"]
    mask = batch.get("label_mask", jnp.ones(labels.shape[0], bool))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.sum(jnp.where(mask, logz - gold, 0.0)) / jnp.clip(
        jnp.sum(mask), 1)


def energy_mse_loss(cfg, params, batch):
    e = nequip_forward(cfg, params, batch)[:, 0]
    return jnp.mean(jnp.square(e - batch["energy"]))
