"""Decoder-only LM family: dense (gemma2 / command-r / granite) and MoE
(moonshot / qwen3) variants with a single scan-over-layers implementation.

Supports three block styles:
  * ``prenorm``  — llama-style sequential pre-norm (granite, qwen3, moonshot)
  * ``sandwich`` — gemma2 pre+post norms around both sublayers
  * ``parallel`` — command-r parallel attention+MLP with one input norm

plus per-layer sliding windows (gemma2 alternating local/global), logit
softcaps, GQA, tied embeddings, and capacity-based MoE.

Entry points:
  * ``init_params(cfg, key)``                   — host-side init (smoke tests)
  * ``abstract_params(cfg)``                    — ShapeDtypeStructs (dry-run)
  * ``forward(cfg, params, tokens, policy)``    — logits
  * ``loss_fn`` / ``make_train_step``           — training
  * ``init_cache`` / ``prefill`` / ``decode_step`` — serving
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.sharding.rules import NO_SHARDING, ShardingPolicy

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    block_style: str = "prenorm"  # prenorm | sandwich | parallel
    mlp_style: str = "gated"  # gated | plain
    act: str = "silu"
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None
    tie_embeddings: bool = True
    scale_embeddings: bool = False
    window_pattern: Optional[Tuple[Optional[int], ...]] = None  # cycle per layer
    # MoE (None -> dense)
    moe: Optional[L.MoeConfig] = None
    moe_every: int = 1  # apply MoE on layers where l % moe_every == 0
    dtype: Any = jnp.float32
    remat: str = "none"  # none | full | dots
    unroll: bool = False  # python-loop the layers (dry-run cost fidelity)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attn(self) -> L.AttnConfig:
        return L.AttnConfig(self.n_heads, self.n_kv, self.hd,
                            rope_theta=self.rope_theta,
                            attn_softcap=self.attn_softcap,
                            query_scale=self.query_scale)

    @property
    def mlp(self) -> L.MlpConfig:
        return L.MlpConfig(self.d_ff, self.act, self.mlp_style)

    def layer_windows(self) -> np.ndarray:
        """(L,) int32 per-layer window (big sentinel = global)."""
        big = 1 << 30
        if self.window_pattern is None:
            return np.full(self.n_layers, big, np.int32)
        pat = [w if w is not None else big for w in self.window_pattern]
        return np.asarray([pat[l % len(pat)] for l in range(self.n_layers)],
                          np.int32)

    def n_params(self) -> int:
        """Analytic parameter count (used by roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv) + self.n_heads * hd * d
        if self.moe is not None:
            ff = 3 * d * self.moe.d_ff * self.moe.n_experts + d * self.moe.n_experts
            if self.moe.n_shared:
                ff += 3 * d * (self.moe.d_ff_shared or self.moe.d_ff)
        else:
            mats = 2 if self.mlp_style == "plain" else 3
            ff = mats * d * self.d_ff
        norms = 4 * d if self.block_style == "sandwich" else 2 * d
        per_layer = attn + ff + norms
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def n_active_params(self) -> int:
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        dense = self.n_params() - self.n_layers * 3 * d * self.moe.d_ff * \
            self.moe.n_experts
        act_ff = self.n_layers * 3 * d * self.moe.d_ff * self.moe.top_k
        return dense + act_ff


# ---------------------------------------------------------------------------
# Parameter trees.
# ---------------------------------------------------------------------------


def _layer_shapes(cfg: TransformerConfig) -> Dict[str, Tuple[int, ...]]:
    d, hd = cfg.d_model, cfg.hd
    shapes = {
        "attn": {
            "wq": (d, cfg.n_heads, hd),
            "wk": (d, cfg.n_kv, hd),
            "wv": (d, cfg.n_kv, hd),
            "wo": (cfg.n_heads * hd, d),
        },
        "norm_attn": {"scale": (d,)},
        "norm_mlp": {"scale": (d,)},
    }
    if cfg.block_style == "sandwich":
        shapes["norm_attn_post"] = {"scale": (d,)}
        shapes["norm_mlp_post"] = {"scale": (d,)}
    if cfg.moe is not None:
        m = cfg.moe
        shapes["moe"] = {
            "router": (d, m.n_experts),
            "w_gate": (m.n_experts, d, m.d_ff),
            "w_up": (m.n_experts, d, m.d_ff),
            "w_down": (m.n_experts, m.d_ff, d),
        }
        if m.n_shared:
            dsh = m.d_ff_shared or m.d_ff
            shapes["moe"]["shared"] = {"w_gate": (d, dsh), "w_up": (d, dsh),
                                       "w_down": (dsh, d)}
    elif cfg.mlp_style == "plain":
        shapes["mlp"] = {"w_up": (d, cfg.d_ff), "w_down": (cfg.d_ff, d)}
    else:
        shapes["mlp"] = {"w_gate": (d, cfg.d_ff), "w_up": (d, cfg.d_ff),
                         "w_down": (cfg.d_ff, d)}
    return shapes


def param_shapes(cfg: TransformerConfig) -> Dict[str, Any]:
    Ln = cfg.n_layers
    stack = jax.tree.map(lambda s: (Ln,) + s, _layer_shapes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    tree = {
        "embedding": (cfg.vocab, cfg.d_model),
        "final_norm": {"scale": (cfg.d_model,)},
        "layers": stack,
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = (cfg.d_model, cfg.vocab)
    return tree


def param_logical_axes(cfg: TransformerConfig, model_size: int = 1
                       ) -> Dict[str, Any]:
    """Logical sharding axes per parameter (layer dim first for stacks).

    KV heads shard over ``model`` only when divisible (GQA/MQA with few KV
    heads replicates them — the standard TP treatment); the KV *cache* then
    shards its sequence dim instead (see ``cache_abstract``).
    """
    kv_ax = "model" if model_size > 0 and cfg.n_kv % max(model_size, 1) == 0 \
        else None
    lax_ = {
        "attn": {
            "wq": (None, "fsdp", "model", None),
            "wk": (None, "fsdp", kv_ax, None),
            "wv": (None, "fsdp", kv_ax, None),
            "wo": (None, "model", "fsdp"),
        },
        "norm_attn": {"scale": (None, None)},
        "norm_mlp": {"scale": (None, None)},
    }
    if cfg.block_style == "sandwich":
        lax_["norm_attn_post"] = {"scale": (None, None)}
        lax_["norm_mlp_post"] = {"scale": (None, None)}
    if cfg.moe is not None:
        lax_["moe"] = {
            "router": (None, "fsdp", None),
            "w_gate": (None, "expert", "fsdp", None),
            "w_up": (None, "expert", "fsdp", None),
            "w_down": (None, "expert", None, "fsdp"),
        }
        if cfg.moe.n_shared:
            lax_["moe"]["shared"] = {"w_gate": (None, "fsdp", "model"),
                                     "w_up": (None, "fsdp", "model"),
                                     "w_down": (None, "model", "fsdp")}
    elif cfg.mlp_style == "plain":
        lax_["mlp"] = {"w_up": (None, "fsdp", "model"),
                       "w_down": (None, "model", "fsdp")}
    else:
        lax_["mlp"] = {"w_gate": (None, "fsdp", "model"),
                       "w_up": (None, "fsdp", "model"),
                       "w_down": (None, "model", "fsdp")}
    tree = {
        "embedding": ("vocab", "fsdp"),
        "final_norm": {"scale": (None,)},
        "layers": lax_,
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ("fsdp", "vocab")
    return tree


def abstract_params(cfg: TransformerConfig,
                    policy: ShardingPolicy = NO_SHARDING):
    shapes = param_shapes(cfg)
    logical = param_logical_axes(cfg, policy.model_size)

    def mk(shape, logic):
        sh = policy.named(logic) if policy.mesh is not None else None
        return jax.ShapeDtypeStruct(shape, cfg.dtype, sharding=sh)

    return jax.tree.map(mk, shapes, logical,
                        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: TransformerConfig, key: jax.Array) -> Params:
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes,
                                       is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))

    def mk(shape, k):
        # norm scales: (d,) or stacked (L, d) -> zeros (zero-centered RMS)
        if shape[-1] == cfg.d_model and (
                len(shape) == 1 or (len(shape) == 2
                                    and shape[0] == cfg.n_layers)):
            return jnp.zeros(shape, cfg.dtype)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(k, shape, cfg.dtype)
                * (1.0 / np.sqrt(max(fan_in, 1))))

    return jax.tree.unflatten(treedef, [mk(s, k) for s, k in
                                        zip(leaves, keys)])


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------


def _block(cfg: TransformerConfig, lp: Params, x, positions, window,
           policy: ShardingPolicy, kv_cache=None, cache_pos=None):
    """One transformer layer. window: traced scalar (big = global)."""
    attn_cfg = dataclasses.replace(cfg.attn, window=None)
    B, S, _ = x.shape

    def attend(xin):
        # per-layer window as a traced mask (static pattern, traced value)
        q_pos = positions if positions.ndim > 1 else positions[None, :]
        T = kv_cache[0].shape[1] if kv_cache is not None else S
        kv_pos = jnp.arange(T)
        wmask = kv_pos[None, None, :] > (q_pos[:, :, None] - window)
        return L.attention(attn_cfg, lp["attn"], xin, positions,
                           mask=wmask, kv_cache=kv_cache, cache_pos=cache_pos)

    if cfg.block_style == "parallel":
        h = L.rms_norm(x, lp["norm_attn"]["scale"])
        a, cache = attend(h)
        mlp_in = L.rms_norm(x, lp["norm_mlp"]["scale"])
        m = L.gated_mlp(cfg.mlp, lp["mlp"], mlp_in) if cfg.moe is None \
            else L.moe_block(cfg.moe, lp["moe"], mlp_in, policy)
        out = x + a + m
    else:
        h = L.rms_norm(x, lp["norm_attn"]["scale"])
        a, cache = attend(h)
        if cfg.block_style == "sandwich":
            a = L.rms_norm(a, lp["norm_attn_post"]["scale"])
        x = x + a
        h = L.rms_norm(x, lp["norm_mlp"]["scale"])
        m = L.gated_mlp(cfg.mlp, lp["mlp"], h) if cfg.moe is None \
            else L.moe_block(cfg.moe, lp["moe"], h, policy)
        if cfg.block_style == "sandwich":
            m = L.rms_norm(m, lp["norm_mlp_post"]["scale"])
        out = x + m
    out = policy.constrain(out, ("batch", "seq", None))
    return out, cache


def forward(cfg: TransformerConfig, params: Params, tokens: jax.Array,
            policy: ShardingPolicy = NO_SHARDING) -> jax.Array:
    """tokens: (B, S) int32 -> logits (B, S, vocab)."""
    x = forward_hidden(cfg, params, tokens, policy)
    logits = L.lm_logits(params, x, cap=cfg.final_softcap,
                         tied=cfg.tie_embeddings)
    # NB: seq stays unsharded here — "seq" and "vocab" both map to model.
    return policy.constrain(logits, ("batch", None, "vocab"))


def forward_hidden(cfg: TransformerConfig, params: Params,
                   tokens: jax.Array,
                   policy: ShardingPolicy = NO_SHARDING) -> jax.Array:
    """Forward pass up to (but excluding) the LM head: (B, S, d)."""
    B, S = tokens.shape
    x = L.embed_tokens(params, tokens, scale=cfg.scale_embeddings)
    x = policy.constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    windows = jnp.asarray(cfg.layer_windows())

    fn = _block
    if cfg.remat == "full":
        fn = jax.checkpoint(_block, static_argnums=(0, 5))
    elif cfg.remat == "dots":
        fn = jax.checkpoint(
            _block, static_argnums=(0, 5),
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def body(x, layer):
        lp, w = layer
        out, _ = fn(cfg, lp, x, positions, w, policy)
        return out, None

    if cfg.unroll:
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[l], params["layers"])
            x, _ = body(x, (lp, windows[l]))
    else:
        x, _ = jax.lax.scan(body, x, (params["layers"], windows))
    return L.rms_norm(x, params["final_norm"]["scale"])


def loss_fn(cfg: TransformerConfig, params: Params, tokens: jax.Array,
            targets: jax.Array, policy: ShardingPolicy = NO_SHARDING,
            *, chunks: int = 1):
    """Next-token cross entropy.

    ``chunks > 1``: chunked CE — the (B, S, vocab) logits tensor is never
    materialized whole; each sequence chunk's logits are computed,
    consumed, and (on the backward pass, via jax.checkpoint) recomputed.
    Peak temp memory drops by ~chunks x (see EXPERIMENTS.md §Perf).
    """
    if chunks <= 1:
        logits = forward(cfg, params, tokens, policy).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    h = forward_hidden(cfg, params, tokens, policy)
    B, S, D = h.shape
    assert S % chunks == 0, (S, chunks)
    hc = h.reshape(B, chunks, S // chunks, D).swapaxes(0, 1)
    tc = targets.reshape(B, chunks, S // chunks).swapaxes(0, 1)
    w = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]

    @jax.checkpoint
    def chunk_loss(hx, tx):
        logits = L.softcap(jnp.einsum("bsd,dv->bsv", hx, w),
                           cfg.final_softcap).astype(jnp.float32)
        logits = policy.constrain(logits, ("batch", None, "vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tx[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    def body(acc, xs):
        hx, tx = xs
        return acc + chunk_loss(hx, tx), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    return total / (B * S)


# ---------------------------------------------------------------------------
# Serving: KV cache, prefill, decode.
# ---------------------------------------------------------------------------


def _cache_logical(cfg: TransformerConfig, batch: int,
                   policy: ShardingPolicy):
    """KV cache sharding: batch over DP when batch > 1; KV heads over
    ``model`` when divisible, else the sequence dim; batch-1 long-context
    cells spread the sequence over every axis (``kv_seq``)."""
    if batch == 1:
        return (None, None, "kv_seq", None, None)
    if cfg.n_kv % max(policy.model_size, 1) == 0 and policy.model_size > 1:
        return (None, "batch", None, "model", None)
    return (None, "batch", "seq", None, None)


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               policy: ShardingPolicy = NO_SHARDING,
               dtype=jnp.float32):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
    logical = _cache_logical(cfg, batch, policy)
    k = jnp.zeros(shape, dtype)
    v = jnp.zeros(shape, dtype)
    return policy.constrain(k, logical), policy.constrain(v, logical)


def cache_abstract(cfg: TransformerConfig, batch: int, max_len: int,
                   policy: ShardingPolicy = NO_SHARDING, dtype=jnp.float32):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
    logical = _cache_logical(cfg, batch, policy)
    sh = policy.named(logical) if policy.mesh is not None else None
    return (jax.ShapeDtypeStruct(shape, dtype, sharding=sh),) * 2


def _scan_layers_cached(cfg, params, x, positions, cache, cache_pos, policy):
    windows = jnp.asarray(cfg.layer_windows())
    ck, cv = cache

    def body(x, layer):
        lp, w, k_l, v_l = layer
        out, new_cache = _block(cfg, lp, x, positions, w, policy,
                                kv_cache=(k_l, v_l), cache_pos=cache_pos)
        return out, new_cache

    if cfg.unroll:
        ks, vs = [], []
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[l], params["layers"])
            x, (k_l, v_l) = body(x, (lp, windows[l], ck[l], cv[l]))
            ks.append(k_l)
            vs.append(v_l)
        return x, (jnp.stack(ks), jnp.stack(vs))
    x, new_kv = jax.lax.scan(body, x, (params["layers"], windows, ck, cv))
    return x, new_kv


def prefill(cfg: TransformerConfig, params: Params, tokens: jax.Array,
            cache, policy: ShardingPolicy = NO_SHARDING):
    """Fill the cache with a prompt; returns (logits_last, cache)."""
    B, S = tokens.shape
    x = L.embed_tokens(params, tokens, scale=cfg.scale_embeddings)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, (ck, cv) = _scan_layers_cached(cfg, params, x, positions, cache,
                                      jnp.int32(0), policy)
    x = L.rms_norm(x, params["final_norm"]["scale"])
    logits = L.lm_logits(params, x[:, -1:], cap=cfg.final_softcap,
                         tied=cfg.tie_embeddings)
    return logits, (ck, cv)


def decode_step(cfg: TransformerConfig, params: Params, token: jax.Array,
                pos: jax.Array, cache,
                policy: ShardingPolicy = NO_SHARDING):
    """One decode step. token: (B, 1) int32; pos: scalar int32 (cache fill).

    Returns (logits (B, 1, V), new_cache).
    """
    B = token.shape[0]
    x = L.embed_tokens(params, token, scale=cfg.scale_embeddings)
    positions = jnp.broadcast_to(pos[None], (B, 1)).astype(jnp.int32)
    x, new_cache = _scan_layers_cached(cfg, params, x, positions, cache, pos,
                                       policy)
    x = L.rms_norm(x, params["final_norm"]["scale"])
    logits = L.lm_logits(params, x, cap=cfg.final_softcap,
                         tied=cfg.tie_embeddings)
    return logits, new_cache
