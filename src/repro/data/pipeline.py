"""Deterministic synthetic data pipelines.

Every batch is a pure function of (seed, step) — the property the restart
loop relies on for bit-exact resume (``fault.py``). Token streams use a
fixed-order LCG permutation over a synthetic corpus so consecutive steps
see disjoint data; graph/recsys batches hash (seed, step) into generator
seeds.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    corpus_tokens: int = 1 << 24  # synthetic zipf corpus length


class LMPipeline:
    """Zipf-distributed synthetic token stream (shape-faithful)."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = _rng(c.seed, step)
        toks = rng.zipf(1.3, size=(c.batch, c.seq + 1)) % c.vocab
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class RecsysDataConfig:
    total_vocab: int
    n_fields: int
    batch: int
    multi_hot: int = 1
    seed: int = 0


class RecsysPipeline:
    def __init__(self, cfg: RecsysDataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = _rng(c.seed, step)
        ids = rng.integers(0, c.total_vocab,
                           (c.batch, c.n_fields, c.multi_hot)).astype(np.int32)
        labels = rng.integers(0, 2, c.batch).astype(np.float32)
        return {"ids": ids, "labels": labels}


@dataclasses.dataclass(frozen=True)
class GraphDataConfig:
    kind: str  # full | sampled | molecule
    seed: int = 0
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


class GraphPipeline:
    """Graph batches: full graph (static), neighbor-sampled, or molecules."""

    def __init__(self, cfg: GraphDataConfig, graph=None, sampler=None):
        self.cfg = cfg
        self.graph = graph
        self.sampler = sampler

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        m = self.cfg.meta
        if self.cfg.kind == "molecule":
            from repro.graphs.sampler import batch_molecules
            return batch_molecules(m["batch"], m["n"], m["e"], m["d"],
                                   seed=int(_rng(self.cfg.seed, step)
                                            .integers(1 << 31)))
        if self.cfg.kind == "sampled":
            rng = _rng(self.cfg.seed, step)
            seeds = rng.choice(self.graph.n, size=m["batch"], replace=False)
            return self.sampler.sample(seeds.astype(np.int64))
        raise ValueError(self.cfg.kind)
