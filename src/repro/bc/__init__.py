"""repro.bc — the unified betweenness-centrality solver facade.

One query → plan → executor surface over every BC path in the repo:

* ``BCQuery`` — what the caller wants (exact/approx, ε/δ/top-k/rule,
  seed, sample cap, optional n_b/backend overrides).
* ``BCPlanner`` / ``BCPlan`` — the §6.2 configuration search as a
  first-class, inspectable object: backend (dense/COO), batch size n_b,
  single-host vs (pod, data, model) mesh placement, predicted
  bytes/seconds/memory from the SpGEMM α-β cost layer.
* ``BatchExecutor`` — one ``step(sources, valid) -> (S1, S2, n_reach)``
  protocol implemented by ``SingleHostExecutor`` (jitted
  ``mfbc_batch_moments``) and ``MeshExecutor`` (Theorem 5.1 distributed
  moments step), so exact sweeps and adaptive sampling epochs are just
  two drivers over the same executor.

Typical use::

    from repro.bc import BCQuery, plan, solve

    res = solve(g, BCQuery(mode="approx", eps=0.05, delta=0.1, topk=10))
    res.topk(10), res.approx.halfwidth      # λ̂ ids + CI halfwidths

    pl = plan(g, BCQuery(mode="exact"))     # inspect before running
    print(pl.summary())

The serving stack's fusion surface lives here too: ``plan_for_request``
(per-query (ε, δ)-aware configuration search), ``BatchAssembler`` /
``FusedBatch`` (cross-request batch fusion over the executors'
``step_segmented``), and ``honest_converged`` (the one rule for
certifying capped runs, shared by ``solve`` and ``serve.BCService``).

The estimator surface (``LambdaEstimator``, ``stopping_check``,
``AdaptiveSampler``, ``ApproxResult``, ``choose_sample_batch``) is
re-exported so downstream packages (serving) need only public
``repro.bc`` names.

``approx.driver.approx_bc`` and ``core.dist_bc.dist_mfbc`` remain as
thin ``DeprecationWarning`` shims delegating to ``solve``.
"""
from repro.approx.driver import (ApproxResult, LambdaEstimator,
                                 choose_sample_batch, stopping_check)
from repro.approx.sampling import AdaptiveSampler, UniformSampler
from repro.bc.config import Backend, ExecutionConfig, as_backend
from repro.bc.executor import (BackendSpec, BatchExecutor, MeshExecutor,
                               SingleHostExecutor, backend_spec,
                               build_executor, register_backend,
                               registered_backends)
from repro.bc.fusion import (PACKS, BatchAssembler, FusedBatch,
                             order_demand, scatter)
from repro.bc.planner import (BCPlan, BCPlanner, bucket_sizes,
                              plan_for_request)
from repro.bc.query import TIER_DEADLINE_S, TIERS, BCQuery
from repro.bc.refine import (ApproxCheckpoint, checkpoint_from,
                             resume_approx)
from repro.bc.solve import BCResult, honest_converged, plan, solve
from repro.core.metrics import (METRICS, MetricSpec, fuse_group, metric_spec,
                                register_metric, registered_metrics)

__all__ = [
    "BCQuery", "BCPlan", "BCPlanner", "BCResult",
    "Backend", "ExecutionConfig", "as_backend",
    "BackendSpec", "register_backend", "backend_spec", "registered_backends",
    "MetricSpec", "register_metric", "metric_spec", "registered_metrics",
    "METRICS", "fuse_group",
    "BatchExecutor", "SingleHostExecutor", "MeshExecutor", "build_executor",
    "plan", "solve", "honest_converged",
    "BatchAssembler", "FusedBatch", "scatter", "order_demand", "PACKS",
    "TIERS", "TIER_DEADLINE_S",
    "plan_for_request", "bucket_sizes",
    "ApproxCheckpoint", "checkpoint_from", "resume_approx",
    "ApproxResult", "LambdaEstimator", "stopping_check",
    "choose_sample_batch", "AdaptiveSampler", "UniformSampler",
]
