"""ExecutionConfig — the typed backend-dispatch vocabulary of the solver.

Before this module the "how does the relax step run" choice was a
stringly-typed ``backend: str`` / ``use_kernel: bool`` pair scattered
across ``BCQuery``, ``BCPlan`` and ``BCPlanner`` (and forwarded
positionally into the executors). CombBLAS's lesson — regime switching
between sparse-multiplication routines only stays tractable behind one
backend-polymorphic surface — applies directly: ``ExecutionConfig``
is that surface, carried on every ``BCPlan`` and resolved against the
backend registry in ``repro.bc.executor``.

Field semantics are two-sided:

* on a **query** (``BCQuery.execution``) every field is an optional
  *pin* — ``None`` means "the planner decides" (backend from the
  calibrated dense-vs-COO regime model, kernel flag from the
  calibration's measured kernel-vs-fallback verdict, placement from
  the device topology);
* on a **plan** (``BCPlan.execution``) the config is fully *resolved*:
  ``backend``, ``use_kernel`` and ``placement`` are concrete, and the
  executor layer dispatches on them without re-deciding anything.

``Backend`` subclasses ``str`` so existing comparisons
(``plan.backend == "coo"``) and JSON serialization keep working
verbatim; always use ``.value`` when formatting messages (plain
``str()`` of a py3.10 enum prints the member name).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Union

PLACEMENTS = ("single_host", "mesh")


class Backend(str, enum.Enum):
    """Relax-step backend: which sparse-multiplication routine runs.

    ``DENSE`` — blocked tropical matmul over an (n, n) adjacency
    (``monoids.*_relax_dense``), optionally routed through the Pallas
    VPU kernels (``kernels.tropical_mm`` / ``kernels.centpath_mm``)
    when the config's ``use_kernel`` is set. The only backend with a
    distributed (mesh) step.

    ``COO`` — edge-list relaxation via ``segment_min/max`` + tie-masked
    ``segment_sum`` (``monoids.*_relax_coo``); work scales with nnz
    instead of n², the paper's sparse-frontier regime. Single-host only.

    ``CSR`` — frontier-compacted edge relaxation over dual-sorted arc
    lists (``core.adjacency.CsrAdj``): each iteration compacts the
    active maximal frontier into a static power-of-two capacity bucket,
    expands only its incident CSR arc ranges and scatters candidates
    with segment ops — per-iteration work tracks frontier nnz × average
    degree instead of E, with a correctness-preserving fallback to the
    full COO relax when every bucket overflows. Single-host only.
    """

    DENSE = "dense"
    COO = "coo"
    CSR = "csr"


def as_backend(value: Union["Backend", str, None]) -> Optional[Backend]:
    """Coerce a legacy backend string (or None) to the enum."""
    if value is None or isinstance(value, Backend):
        return value
    try:
        return Backend(value)
    except ValueError:
        raise ValueError(
            f"backend must be one of "
            f"{tuple(b.value for b in Backend)}, got {value!r}") from None


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """One typed execution choice: (backend, kernel flag, placement).

    ``None`` fields mean "planner decides" (query-side pins); the
    planner always emits a fully resolved config on the ``BCPlan``
    (``resolved`` is True). ``block`` is the dense relax block size —
    it has no "decide for me" state, so it carries a concrete default.
    """

    backend: Optional[Backend] = None
    use_kernel: Optional[bool] = None
    placement: Optional[str] = None  # "single_host" | "mesh"
    block: int = 512

    def __post_init__(self) -> None:
        object.__setattr__(self, "backend", as_backend(self.backend))
        if self.placement is not None and self.placement not in PLACEMENTS:
            raise ValueError(f"placement must be None or one of "
                             f"{PLACEMENTS}, got {self.placement!r}")
        if self.block <= 0:
            raise ValueError(f"block must be positive, got {self.block}")

    @property
    def resolved(self) -> bool:
        """True when nothing is left for the planner to decide."""
        return (self.backend is not None and self.use_kernel is not None
                and self.placement is not None)

    def resolve(self, **overrides) -> "ExecutionConfig":
        """A copy with the given fields pinned (planner's resolution step)."""
        return dataclasses.replace(self, **overrides)

    def to_json(self) -> Dict:
        return {
            "backend": None if self.backend is None else self.backend.value,
            "use_kernel": self.use_kernel,
            "placement": self.placement,
            "block": self.block,
        }

    @classmethod
    def from_json(cls, d: Dict) -> "ExecutionConfig":
        return cls(backend=as_backend(d.get("backend")),
                   use_kernel=d.get("use_kernel"),
                   placement=d.get("placement"),
                   block=int(d.get("block", 512)))

    def describe(self) -> str:
        be = "auto" if self.backend is None else self.backend.value
        kern = ("auto" if self.use_kernel is None
                else ("kernel" if self.use_kernel else "jnp"))
        return f"{be}/{kern}@{self.placement or 'auto'}"
