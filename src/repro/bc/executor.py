"""Executors — one batch-step protocol over every BC backend.

A ``BatchExecutor`` turns a padded source batch into per-vertex
dependency statistics through three methods: ``step(sources, valid) ->
(S1, S2, n_reach)`` with ``S1(v) = Σ_s δ_s(v)`` and
``S2(v) = Σ_s δ_s(v)²`` over the batch's valid sources (the (Σδ, Σδ²)
contract of ``approx.driver.LambdaEstimator``, what the sampling epochs
call), ``step_sum(sources, valid) -> S1`` (the exact sweep's Σδ-only
reduction, skipping the moments overhead), and ``step_segmented(sources,
valid, slot_ids, n_slots) -> (S1, S2, n_reach)`` shaped ``(n_slots, n)``
— the cross-request fusion primitive: one device call (one fused
all-reduce on the mesh) serving a batch packed from several concurrent
queries, segment-reduced per slot. Both drivers in ``repro.bc.solve``
run over this one protocol, so "exact vs approx" and "single host vs
mesh" are orthogonal choices, and ``serve.bc_service`` fuses requests
over it without branching on placement.

Shape bucketing: ``step`` / ``step_sum`` pad to the plan's ``n_b``
exactly (so single-query results are bit-stable across releases), while
``step_segmented`` pads to the smallest power-of-two bucket ≥ the batch
length (``plan.buckets``, see ``planner.bucket_sizes``) — one executor
serves many ragged fused batch sizes with a bounded set of compiled
shapes instead of a retrace per length or an always-pad-to-``n_b``.

``SingleHostExecutor`` is the former ``approx.driver._single_host_step``
made public: dense or COO adjacency on one device, jitted
``core.mfbc.mfbc_batch_moments``. ``MeshExecutor`` holds one
``core.dist_bc.MeshBCContext`` (device-resident A/Aᵀ shared by every
bucket and variant; Theorem 5.1 collectives, fused (Σδ, Σδ², n_reach)
all-reduce); its ``n_b`` is the mesh-divisible rounded-up batch size,
which callers must use when sizing sample batches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Protocol, Tuple, Union, \
    runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.bc.config import Backend, as_backend
from repro.bc.planner import BCPlan, bucket_sizes
from repro.core.adjacency import (CsrAdj, coo_adj_from_graph,
                                  csr_adj_from_graph, dense_adj_from_graph)
from repro.core.metrics import components_graph, components_labels
from repro.core.mfbc import (metric_batch_moments,
                             metric_batch_moments_segmented, mfbc_batch,
                             mfbc_batch_moments,
                             mfbc_batch_moments_segmented,
                             mfbc_batch_moments_traced)
from repro.graphs.formats import Graph

Moments = Tuple[np.ndarray, np.ndarray, np.ndarray]  # (S1, S2, n_reach)


# --- backend registry ------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """How one ``Backend`` plugs into the executor layer.

    ``make_adjacency(g, plan)`` builds the device-resident adjacency the
    single-host relax steps dispatch on (``core.adjacency.DenseAdj`` /
    ``CooAdj`` — the jitted ``core.mfbc`` batch functions branch on its
    type, so one factory is the whole backend-specific surface here);
    ``placements`` lists where the backend can run (only DENSE has a
    distributed Theorem 5.1 step); ``supports_kernel`` gates the Pallas
    kernel route (COO's segment ops have no kernel variant).
    """

    backend: Backend
    make_adjacency: Callable[[Graph, BCPlan], Any]
    placements: Tuple[str, ...] = ("single_host",)
    supports_kernel: bool = False


_BACKEND_REGISTRY: Dict[Backend, BackendSpec] = {}


def register_backend(spec: BackendSpec) -> BackendSpec:
    """Register (or replace) the executor-layer spec for a backend."""
    _BACKEND_REGISTRY[spec.backend] = spec
    return spec


def backend_spec(backend: Union[Backend, str]) -> BackendSpec:
    """Resolve a backend (enum or legacy string) to its registered spec."""
    be = as_backend(backend)
    try:
        return _BACKEND_REGISTRY[be]
    except KeyError:
        raise ValueError(f"no executor registered for backend "
                         f"{be.value!r}") from None


def registered_backends() -> Tuple[Backend, ...]:
    return tuple(_BACKEND_REGISTRY)


register_backend(BackendSpec(
    backend=Backend.DENSE,
    make_adjacency=lambda g, plan: dense_adj_from_graph(
        g, block=plan.block, use_kernel=plan.use_kernel),
    placements=("single_host", "mesh"),
    supports_kernel=True))

register_backend(BackendSpec(
    backend=Backend.COO,
    make_adjacency=lambda g, plan: coo_adj_from_graph(g),
    placements=("single_host",)))

register_backend(BackendSpec(
    backend=Backend.CSR,
    # The plan's n_b sizes the compaction capacity ladder: the frontier
    # buckets bound (batch row, vertex) slots, so the batch axis is part
    # of the capacity math (see core.adjacency.frontier_caps).
    make_adjacency=lambda g, plan: csr_adj_from_graph(g, n_b=plan.n_b),
    placements=("single_host",)))


@runtime_checkable
class BatchExecutor(Protocol):
    """The one surface both solve drivers (exact sweep, epochs) run over."""

    n_b: int  # effective batch size (mesh executors round the plan's up)
    buckets: Tuple[int, ...]  # padded shapes served (ascending, max = n_b)
    plan: BCPlan

    def step(self, sources: np.ndarray, valid: np.ndarray, *,
             metric: str = "betweenness", hops: int = 0) -> Moments:
        """Per-vertex (Σδ, Σδ², n_reach) over the batch's valid sources.
        ``metric`` selects the per-source contribution formula
        (``core.metrics`` registry); the default is the original
        betweenness path, byte-for-byte."""
        ...

    def step_sum(self, sources: np.ndarray, valid: np.ndarray, *,
                 metric: str = "betweenness", hops: int = 0) -> np.ndarray:
        """Σδ only — the exact sweep's reduction, skipping the moments
        overhead (on the mesh: one n/p_model all-reduce instead of the
        3× stacked one). Built lazily, so approx-only callers never
        compile it."""
        ...

    def step_segmented(self, sources: np.ndarray, valid: np.ndarray,
                       slot_ids: np.ndarray, n_slots: int, *,
                       metrics=None, hops: int = 0) -> Moments:
        """Per-slot (Σδ, Σδ², n_reach), each ``(n_slots, n)`` — the fused
        cross-request batch: row tags ``slot_ids ∈ [0, n_slots)`` say
        which query each source belongs to. Slot j's statistics are
        bitwise what an unfused run of its rows (in the same order)
        would produce on the same executor. Batches are padded to the
        smallest serving bucket, not ``n_b``. ``metrics`` optionally
        names each slot's metric (length ``n_slots``; ``None`` means all
        betweenness) — the cross-metric fusion surface, restricted to
        slots whose sweep structures match (``core.metrics.fuse_group``).
        """
        ...

    def bucket_for(self, k: int) -> int:
        """The padded shape a k-source fused batch runs at."""
        ...

    def labels(self) -> np.ndarray:
        """Fixed-point metric entry (components): (n,) float64 min-label
        array over the zero-weight symmetrized structure, computed in
        one call. Single-host only."""
        ...


def _pad_batch(sources: np.ndarray, valid: np.ndarray, n_b: int):
    sources = np.asarray(sources, np.int32)
    valid = np.asarray(valid, bool)
    if sources.shape[0] > n_b:
        # Never truncate silently: dropped sources would bias any
        # estimator fed the full batch's n_valid.
        raise ValueError(f"batch of {sources.shape[0]} sources exceeds "
                         f"the executor's n_b={n_b}; split it or build "
                         f"an executor from a plan with a larger n_b")
    if sources.shape[0] == n_b:
        return sources, valid
    src = np.zeros(n_b, np.int32)
    val = np.zeros(n_b, bool)
    k = sources.shape[0]
    src[:k], val[:k] = sources[:k], valid[:k]
    return src, val


def _pad_segmented(sources, valid, slot_ids, bucket: int, pad_slot: int):
    """Pad a fused batch to its bucket; padding rows carry ``valid=False``
    and slot id ``pad_slot`` (the segment count the kernel runs with —
    its dump segment, dropped from the result)."""
    sources = np.asarray(sources, np.int32)
    valid = np.asarray(valid, bool)
    slot_ids = np.asarray(slot_ids, np.int32)
    if not (sources.shape == valid.shape == slot_ids.shape):
        raise ValueError("sources, valid and slot_ids must share one shape")
    k = sources.shape[0]
    if k == bucket:
        return sources, valid, slot_ids
    src = np.zeros(bucket, np.int32)
    val = np.zeros(bucket, bool)
    sid = np.full(bucket, pad_slot, np.int32)
    src[:k], val[:k], sid[:k] = sources, valid, slot_ids
    return src, val, sid


def _bucket_for(k: int, buckets: Tuple[int, ...], n_b: int) -> int:
    for b in buckets:
        if k <= b:
            return b
    raise ValueError(f"batch of {k} sources exceeds the executor's "
                     f"n_b={n_b}; split it (the BatchAssembler caps "
                     f"fused batches at executor capacity)")


def _slot_bucket(n_slots: int) -> int:
    """Segment-count bucket: next power of two ≥ n_slots.

    ``n_slots`` is a static jit argument, so compiling per exact slot
    count would retrace as requests retire (16, 15, 14, … live slots).
    Bucketing the slot dimension the same way as the batch dimension
    keeps the compiled-shape set at O(log buckets · log slots); the
    extra segments are empty and sliced off."""
    b = 1
    while b < n_slots:
        b <<= 1
    return b


class _ExecutorBase:
    """Shared padding/bucketing half of every ``BatchExecutor``.

    Subclasses set ``plan`` / ``n_b`` / ``buckets`` in ``__init__`` and
    implement the three raw compute hooks; the base owns the shape
    contract (exact-``n_b`` padding for ``step``/``step_sum``, bucket +
    slot-dim padding for ``step_segmented``) so both placements — and
    any future backend — pad identically and the fused-vs-unfused
    bitwise-parity property cannot drift between implementations.
    """

    plan: BCPlan
    n_b: int
    buckets: Tuple[int, ...]

    def bucket_for(self, k: int) -> int:
        return _bucket_for(k, self.buckets, self.n_b)

    def step(self, sources: np.ndarray, valid: np.ndarray, *,
             metric: str = "betweenness", hops: int = 0) -> Moments:
        src, val = _pad_batch(sources, valid, self.n_b)
        if metric == "betweenness":
            # the original path, byte-for-byte (including the CSR trace)
            return self._moments(src, val)
        return self._metric_moments(src, val, metric, hops)

    def step_sum(self, sources: np.ndarray, valid: np.ndarray, *,
                 metric: str = "betweenness", hops: int = 0) -> np.ndarray:
        src, val = _pad_batch(sources, valid, self.n_b)
        if metric == "betweenness":
            return self._sum(src, val)
        return self._metric_moments(src, val, metric, hops)[0]

    def step_segmented(self, sources: np.ndarray, valid: np.ndarray,
                       slot_ids: np.ndarray, n_slots: int, *,
                       metrics=None, hops: int = 0) -> Moments:
        bucket = self.bucket_for(np.asarray(sources).shape[0])
        n_seg = _slot_bucket(n_slots)  # pad the slot dim too (jit-static)
        src, val, sid = _pad_segmented(sources, valid, slot_ids, bucket,
                                       n_seg)
        if metrics is None or all(m == "betweenness" for m in metrics):
            s1, s2, nr = self._segmented(src, val, sid, n_seg, bucket)
            return s1[:n_slots], s2[:n_slots], nr[:n_slots]
        if len(metrics) != n_slots:
            raise ValueError(f"metrics names {len(metrics)} slots, "
                             f"batch has {n_slots}")
        # static kinds tuple (first-appearance order) + per-row tags;
        # padding rows tag kind 0 — they are valid=False and land in the
        # dump segment regardless.
        kinds = tuple(dict.fromkeys(metrics))
        slot_kind = np.array([kinds.index(m) for m in metrics]
                             + [0], np.int32)  # [-1] = the dump segment
        mids = slot_kind[np.minimum(sid, len(metrics))]
        s1, s2, nr = self._metric_segmented(src, val, sid, mids, kinds,
                                            n_seg, bucket, hops)
        return s1[:n_slots], s2[:n_slots], nr[:n_slots]

    # -- compute hooks (padded inputs, full padded outputs) -------------
    def _moments(self, src, val) -> Moments:
        raise NotImplementedError

    def _sum(self, src, val) -> np.ndarray:
        raise NotImplementedError

    def _segmented(self, src, val, sid, n_seg: int, bucket: int) -> Moments:
        raise NotImplementedError

    # -- metric-generic hooks (betweenness never routes through these) --
    def _metric_moments(self, src, val, metric: str, hops: int) -> Moments:
        raise NotImplementedError(
            f"{type(self).__name__} runs betweenness only; metric "
            f"{metric!r} sweeps are single-host")

    def _metric_segmented(self, src, val, sid, mids, kinds, n_seg: int,
                          bucket: int, hops: int) -> Moments:
        raise NotImplementedError(
            f"{type(self).__name__} runs betweenness only; metrics "
            f"{kinds!r} fuse single-host")

    def labels(self) -> np.ndarray:
        raise NotImplementedError(
            f"{type(self).__name__} has no fixed-point metric entry "
            f"(components runs single-host)")


class SingleHostExecutor(_ExecutorBase):
    """One-device moments step (dense blocked, COO, or frontier-compacted
    CSR segment-op relax).

    The adjacency comes from the plan's backend via the registry
    (``backend_spec``); the jitted ``core.mfbc`` batch functions
    dispatch on its type, so every backend shares each line above the
    relax. A ``CsrAdj`` adjacency additionally routes ``step`` and
    ``step_sum`` through the traced moments entry point and accumulates
    the frontier occupancy side channel (``occupancy_summary``).
    """

    def __init__(self, g: Graph, plan: BCPlan):
        self.plan = plan
        self.n_b = plan.n_b
        self.buckets = plan.buckets or bucket_sizes(plan.n_b)
        self._g = g
        self._adj = backend_spec(plan.backend).make_adjacency(g, plan)
        # Frontier-occupancy trace: collected only for the compacting
        # adjacency (the frontier-sparse engine's side channel); dense and
        # COO moments run the untraced jit path, byte-for-byte as before.
        self._trace = isinstance(self._adj, CsrAdj)
        self._occ: Dict[str, Any] = {}
        # Lazy second adjacency for the components fixed point (the
        # zero-weight symmetrized structure) — non-components callers
        # never build it.
        self._cc_adj = None

    def _record_occupancy(self, tr_bf, tr_br) -> None:
        def trim(tr):
            iters = int(tr.iters)
            return [int(x) for x in
                    np.asarray(tr.fnnz)[:min(iters, tr.fnnz.shape[0])]]
        per_bf, per_br = trim(tr_bf), trim(tr_br)
        o = self._occ
        o["batches"] = o.get("batches", 0) + 1
        o["iters_bf"], o["iters_br"] = int(tr_bf.iters), int(tr_br.iters)
        o["per_iter_bf"], o["per_iter_br"] = per_bf, per_br
        o["fnnz_first"] = per_bf[0] if per_bf else 0
        o["fnnz_last"] = per_bf[-1] if per_bf else 0
        o["overflows"] = (o.get("overflows", 0) + int(tr_bf.overflows)
                          + int(tr_br.overflows))
        o["compact_hits"] = (o.get("compact_hits", 0)
                             + int(tr_bf.compact_hits)
                             + int(tr_br.compact_hits))
        o["relax_calls"] = (o.get("relax_calls", 0) + int(tr_bf.iters)
                            + int(tr_br.iters))
        calls = max(o["relax_calls"], 1)
        o["hit_rate"] = o["compact_hits"] / calls

    def occupancy_summary(self):
        """Accumulated frontier-occupancy trace, or None when not traced.

        Per-iteration profiles (``per_iter_bf``/``per_iter_br``, forward
        and backward sweep frontier nnz) are from the most recent batch;
        ``overflows``/``compact_hits``/``relax_calls``/``hit_rate``
        accumulate over every traced batch this executor ran.
        """
        return dict(self._occ) if self._occ else None

    def _moments(self, src, val) -> Moments:
        if self._trace:
            s1, s2, nr, tr_bf, tr_br = mfbc_batch_moments_traced(
                self._adj, jnp.asarray(src), jnp.asarray(val))
            self._record_occupancy(tr_bf, tr_br)
        else:
            s1, s2, nr = mfbc_batch_moments(self._adj, jnp.asarray(src),
                                            jnp.asarray(val))
        return (np.asarray(s1, np.float64), np.asarray(s2, np.float64),
                np.asarray(nr))

    def _sum(self, src, val) -> np.ndarray:
        if self._trace:
            # S1 of the moments entry point IS λ_partial, so the exact
            # sweep can ride the traced path at the cost of one extra
            # elementwise square it discards.
            s1, _, _, tr_bf, tr_br = mfbc_batch_moments_traced(
                self._adj, jnp.asarray(src), jnp.asarray(val))
            self._record_occupancy(tr_bf, tr_br)
            return np.asarray(s1, np.float64)
        lam_b, _, _ = mfbc_batch(self._adj, jnp.asarray(src),
                                 jnp.asarray(val))
        return np.asarray(lam_b, np.float64)

    def _segmented(self, src, val, sid, n_seg: int, bucket: int) -> Moments:
        s1, s2, nr = mfbc_batch_moments_segmented(
            self._adj, jnp.asarray(src), jnp.asarray(val), jnp.asarray(sid),
            n_slots=n_seg)
        return (np.asarray(s1, np.float64), np.asarray(s2, np.float64),
                np.asarray(nr))

    def _metric_moments(self, src, val, metric: str, hops: int) -> Moments:
        mids = jnp.zeros(src.shape[0], jnp.int32)
        s1, s2, nr = metric_batch_moments(
            self._adj, jnp.asarray(src), jnp.asarray(val), mids,
            kinds=(metric,), hops=int(hops))
        return (np.asarray(s1, np.float64), np.asarray(s2, np.float64),
                np.asarray(nr))

    def _metric_segmented(self, src, val, sid, mids, kinds, n_seg: int,
                          bucket: int, hops: int) -> Moments:
        s1, s2, nr = metric_batch_moments_segmented(
            self._adj, jnp.asarray(src), jnp.asarray(val), jnp.asarray(sid),
            jnp.asarray(mids), kinds=kinds, n_slots=n_seg, hops=int(hops))
        return (np.asarray(s1, np.float64), np.asarray(s2, np.float64),
                np.asarray(nr))

    def labels(self) -> np.ndarray:
        if self._cc_adj is None:
            self._cc_adj = backend_spec(self.plan.backend).make_adjacency(
                components_graph(self._g), self.plan)
        return np.asarray(components_labels(self._cc_adj), np.float64)


class MeshExecutor(_ExecutorBase):
    """Distributed Theorem 5.1 moments step on a (pod, data, model) mesh.

    ``mesh=None`` builds the mesh the plan chose (``plan.mesh_axes``) from
    the visible devices; pass an explicit mesh to reuse one. All variants
    and buckets share one lazily built ``MeshBCContext`` — the padded,
    permuted adjacency is uploaded once, and each (bucket, variant) pair
    compiles once.
    """

    def __init__(self, g: Graph, plan: BCPlan, mesh=None):
        if mesh is None:
            import jax

            axes = plan.axes_dict()
            if axes is None:
                raise ValueError("plan has no mesh_axes and no mesh given")
            mesh = jax.make_mesh(tuple(axes.values()), tuple(axes.keys()))
        self.plan = plan
        self.mesh = mesh
        self._g = g
        # Lazy context: an executor built for planning introspection never
        # pads or uploads the adjacency.
        self._ctx = None
        # MeshBCContext's batch rounding (sources are sharded over
        # pod×data), computed up front so callers can size sample
        # batches before any device work happens; _context asserts the
        # two stay in sync.
        sizes = dict(zip(mesh.axis_names, (int(s) for s in
                                           mesh.devices.shape)))
        chunk = sizes.get("pod", 1) * sizes.get("data", 1)
        self.n_b = -(-plan.n_b // chunk) * chunk
        # Bucket set: the plan's power-of-two shapes, each rounded up to
        # the mesh divisibility (dedup keeps them ascending).
        rounded = [-(-b // chunk) * chunk
                   for b in (plan.buckets or bucket_sizes(plan.n_b))]
        rounded.append(self.n_b)
        self.buckets = tuple(sorted({min(b, self.n_b) for b in rounded}))

    def _context(self):
        from repro.core.dist_bc import MeshBCContext

        if self._ctx is None:
            pl = self.plan
            self._ctx = MeshBCContext(self._g, self.mesh,
                                      iters=pl.iters if pl.iters > 0 else 0,
                                      use_kernel=pl.use_kernel,
                                      block=pl.block)
            assert self._ctx.round_nb(pl.n_b) == self.n_b, \
                (self._ctx.round_nb(pl.n_b), self.n_b)
        return self._ctx

    def _moments(self, src, val) -> Moments:
        return self._context().run_moments(src, val, nb=self.n_b)

    def _sum(self, src, val) -> np.ndarray:
        return self._context().run_sum(src, val, nb=self.n_b)

    def _segmented(self, src, val, sid, n_seg: int, bucket: int) -> Moments:
        return self._context().run_segmented(src, val, sid, n_seg, nb=bucket)


def build_executor(g: Graph, plan: BCPlan, *, mesh=None) -> BatchExecutor:
    """Instantiate the executor a ``BCPlan`` calls for.

    The plan's backend must be registered (``register_backend``) and
    must support the plan's placement — a mesh plan on a single-host-only
    backend is a planner bug surfaced here, not a silent fallback.
    """
    spec = backend_spec(plan.backend)
    if plan.placement == "mesh" or mesh is not None:
        if "mesh" not in spec.placements:
            raise ValueError(f"backend {spec.backend.value!r} has no mesh "
                             f"step (placements: {spec.placements})")
        return MeshExecutor(g, plan, mesh=mesh)
    return SingleHostExecutor(g, plan)
