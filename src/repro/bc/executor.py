"""Executors — one batch-step protocol over every BC backend.

A ``BatchExecutor`` turns a padded source batch into per-vertex
dependency statistics through two methods: ``step(sources, valid) ->
(S1, S2, n_reach)`` with ``S1(v) = Σ_s δ_s(v)`` and
``S2(v) = Σ_s δ_s(v)²`` over the batch's valid sources (the (Σδ, Σδ²)
contract of ``approx.driver.LambdaEstimator``, what the sampling epochs
call), and ``step_sum(sources, valid) -> S1`` (the exact sweep's
Σδ-only reduction, skipping the moments overhead). Both drivers in
``repro.bc.solve`` run over this one protocol, so "exact vs approx" and
"single host vs mesh" are orthogonal choices.

``SingleHostExecutor`` is the former ``approx.driver._single_host_step``
made public: dense or COO adjacency on one device, jitted
``core.mfbc.mfbc_batch_moments``. ``MeshExecutor`` wraps
``core.dist_bc.prepare_mesh_batch_step(..., moments=True)`` (Theorem 5.1
collectives, fused (Σδ, Σδ², n_reach) all-reduce); its ``n_b`` is the
mesh-divisible rounded-up batch size, which callers must use when sizing
sample batches.
"""
from __future__ import annotations

from typing import Protocol, Tuple, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.bc.planner import BCPlan
from repro.core.adjacency import coo_adj_from_graph, dense_adj_from_graph
from repro.core.mfbc import mfbc_batch, mfbc_batch_moments
from repro.graphs.formats import Graph

Moments = Tuple[np.ndarray, np.ndarray, np.ndarray]  # (S1, S2, n_reach)


@runtime_checkable
class BatchExecutor(Protocol):
    """The one surface both solve drivers (exact sweep, epochs) run over."""

    n_b: int  # effective batch size (mesh executors round the plan's up)
    plan: BCPlan

    def step(self, sources: np.ndarray, valid: np.ndarray) -> Moments:
        """Per-vertex (Σδ, Σδ², n_reach) over the batch's valid sources."""
        ...

    def step_sum(self, sources: np.ndarray, valid: np.ndarray) -> np.ndarray:
        """Σδ only — the exact sweep's reduction, skipping the moments
        overhead (on the mesh: one n/p_model all-reduce instead of the
        3× stacked one). Built lazily, so approx-only callers never
        compile it."""
        ...


def _pad_batch(sources: np.ndarray, valid: np.ndarray, n_b: int):
    sources = np.asarray(sources, np.int32)
    valid = np.asarray(valid, bool)
    if sources.shape[0] > n_b:
        # Never truncate silently: dropped sources would bias any
        # estimator fed the full batch's n_valid.
        raise ValueError(f"batch of {sources.shape[0]} sources exceeds "
                         f"the executor's n_b={n_b}; split it or build "
                         f"an executor from a plan with a larger n_b")
    if sources.shape[0] == n_b:
        return sources, valid
    src = np.zeros(n_b, np.int32)
    val = np.zeros(n_b, bool)
    k = sources.shape[0]
    src[:k], val[:k] = sources[:k], valid[:k]
    return src, val


class SingleHostExecutor:
    """One-device moments step (dense blocked or COO segment-op relax)."""

    def __init__(self, g: Graph, plan: BCPlan):
        self.plan = plan
        self.n_b = plan.n_b
        if plan.backend == "dense":
            self._adj = dense_adj_from_graph(g, block=plan.block,
                                             use_kernel=plan.use_kernel)
        elif plan.backend == "coo":
            self._adj = coo_adj_from_graph(g)
        else:
            raise ValueError(f"unknown backend {plan.backend!r}")

    def step(self, sources: np.ndarray, valid: np.ndarray) -> Moments:
        src, val = _pad_batch(sources, valid, self.n_b)
        s1, s2, nr = mfbc_batch_moments(self._adj, jnp.asarray(src),
                                        jnp.asarray(val))
        return (np.asarray(s1, np.float64), np.asarray(s2, np.float64),
                np.asarray(nr))

    def step_sum(self, sources: np.ndarray, valid: np.ndarray) -> np.ndarray:
        src, val = _pad_batch(sources, valid, self.n_b)
        lam_b, _, _ = mfbc_batch(self._adj, jnp.asarray(src),
                                 jnp.asarray(val))
        return np.asarray(lam_b, np.float64)


class MeshExecutor:
    """Distributed Theorem 5.1 moments step on a (pod, data, model) mesh.

    ``mesh=None`` builds the mesh the plan chose (``plan.mesh_axes``) from
    the visible devices; pass an explicit mesh to reuse one.
    """

    def __init__(self, g: Graph, plan: BCPlan, mesh=None):
        if mesh is None:
            import jax

            axes = plan.axes_dict()
            if axes is None:
                raise ValueError("plan has no mesh_axes and no mesh given")
            mesh = jax.make_mesh(tuple(axes.values()), tuple(axes.keys()))
        self.plan = plan
        self.mesh = mesh
        self._g = g
        # Lazy per-variant builds: an exact-only caller never compiles the
        # moments step and vice versa (each build is its own shard_map+jit).
        self._run_moments = None
        self._run_sum = None
        # prepare_mesh_batch_step's batch rounding (sources are sharded
        # over pod×data), computed up front so callers can size sample
        # batches before any device work happens; _prepare asserts the
        # two stay in sync.
        sizes = dict(zip(mesh.axis_names, (int(s) for s in
                                           mesh.devices.shape)))
        chunk = sizes.get("pod", 1) * sizes.get("data", 1)
        self.n_b = -(-plan.n_b // chunk) * chunk

    def _prepare(self, *, moments: bool):
        from repro.core.dist_bc import prepare_mesh_batch_step

        pl = self.plan
        run, nb = prepare_mesh_batch_step(
            self._g, self.mesh, nb=pl.n_b,
            iters=pl.iters if pl.iters > 0 else self._g.n,
            use_kernel=pl.use_kernel, block=pl.block, moments=moments)
        assert nb == self.n_b, (nb, self.n_b)
        return run

    def step(self, sources: np.ndarray, valid: np.ndarray) -> Moments:
        if self._run_moments is None:
            self._run_moments = self._prepare(moments=True)
        src, val = _pad_batch(sources, valid, self.n_b)
        return self._run_moments(src, val)

    def step_sum(self, sources: np.ndarray, valid: np.ndarray) -> np.ndarray:
        if self._run_sum is None:
            self._run_sum = self._prepare(moments=False)
        src, val = _pad_batch(sources, valid, self.n_b)
        return self._run_sum(src, val)


def build_executor(g: Graph, plan: BCPlan, *, mesh=None) -> BatchExecutor:
    """Instantiate the executor a ``BCPlan`` calls for."""
    if plan.placement == "mesh" or mesh is not None:
        return MeshExecutor(g, plan, mesh=mesh)
    return SingleHostExecutor(g, plan)
