"""BCPlanner — the configuration search as a first-class object.

The paper's §6.2 claim is that MFBC "automatically searches a space of
distributed data decompositions and sparse matrix multiplication
algorithms for the most advantageous configuration". Before this package
that search was scattered: ``approx.driver`` picked n_b, ``bc_run``
hard-coded the exact batch size, ``bc_service`` made its own mesh
decisions, and placement was whatever entry point the caller happened to
import. ``BCPlanner`` centralizes it: given a graph, a ``BCQuery`` and
the device topology it consults the SpGEMM cost layer
(``spgemm.autotune.choose_bc_regime`` for the dense-vs-COO relax regime,
``spgemm.cost_model.best_replication`` for the replication factor c,
``approx.driver.choose_sample_batch`` for n_b) and returns an
inspectable, JSON-serializable ``BCPlan``.

Placement rule: an explicit ``mesh`` always wins (even 1x1 — callers
that hand us a mesh want the distributed step); otherwise one visible
device plans single-host and multiple devices plan a (pod, data, model)
decomposition with c = min(best_replication, p^(1/3)) clamped to a
divisor of p and the remaining p/c grid split near-square — the debug
8-device topology lands on the paper's (2, 2, 2) grid.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, List, Optional, Tuple, Union

from repro.approx.driver import (adjacency_bytes, choose_sample_batch,
                                 state_bytes)
from repro.approx.sampling import hoeffding_budget
from repro.bc.config import Backend, ExecutionConfig
from repro.core.metrics import metric_spec
from repro.graphs.formats import Graph
from repro.spgemm.autotune import choose_bc_regime
from repro.spgemm.cost_model import (DEFAULT, Calibration, CostParams,
                                     best_replication, load_calibration)

import numpy as np

_WORD = 4.0  # f32 device word
BUCKET_FLOOR = 8  # smallest padded batch shape an executor compiles


def bucket_sizes(n_b: int, floor: int = BUCKET_FLOOR) -> Tuple[int, ...]:
    """Power-of-two padded batch buckets up to (and including) ``n_b``.

    The shape-bucketing contract shared by the planner (which records the
    set in the ``BCPlan``) and the executors (which keep one jitted step
    per bucket): a batch of k sources runs at the smallest bucket ≥ k, so
    an executor serves many ragged batch sizes with at most
    ``log2(n_b / floor) + 1`` compiled shapes — no retrace storms, no
    always-pad-to-``n_b`` waste. Mesh executors additionally round each
    bucket up to their pod·data divisibility.
    """
    if n_b <= 0:
        raise ValueError(f"n_b must be positive, got {n_b}")
    out = []
    b = floor
    while b < n_b:
        out.append(b)
        b <<= 1
    out.append(int(n_b))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class BCPlan:
    """One fully resolved execution configuration (what the planner chose).

    Predictions come from the α-β cost layer and are *per device*:
    ``predicted_step_seconds`` prices one relax iteration of one batch,
    ``predicted_comm_bytes`` the whole run's collective traffic
    (Theorem 5.1 bound ``(nnz(F) + 2·nnz(C))/√(p/c)`` per iteration, 0 on
    a single host), ``predicted_seconds`` the end-to-end estimate over
    ``n_batches`` batches of ``est_iters`` forward+backward iterations,
    and ``predicted_mem_bytes`` the peak adjacency+state footprint.
    """

    mode: str  # "exact" | "approx"
    placement: str  # "single_host" | "mesh"
    backend: str  # "dense" | "coo" | "csr" (flat mirror of execution.backend)
    use_kernel: bool
    n_b: int
    block: int
    iters: int  # static mesh sweep bound (0 = graph size)
    n_devices: int
    mesh_axes: Optional[Tuple[Tuple[str, int], ...]]  # None on single host
    sample_budget: int  # n for exact; Hoeffding budget / cap for approx
    n_batches: int
    est_iters: int  # relax iterations priced per batch (heuristic)
    predicted_step_seconds: float
    predicted_comm_bytes: float
    predicted_seconds: float
    predicted_mem_bytes: float
    regime: Dict[str, float]  # choose_bc_regime output (dense/coo/csr)
    buckets: Tuple[int, ...] = ()  # padded batch shapes the executor serves
    tier: Optional[str] = None  # latency tier of the request this plan sizes
    # Metric this plan prices (MetricSpec registry): forward-only sweeps
    # cost half of BC's forward+backward pair via ``spec.sweeps``.
    metric: str = "betweenness"
    hops: int = 0  # khop's bound; 0 for unbounded metrics
    # fully resolved typed execution choice (backend/use_kernel/placement
    # above are its flat mirrors, kept for JSON and legacy readers)
    execution: Optional[ExecutionConfig] = None
    notes: Tuple[str, ...] = ()  # planner diagnostics (e.g. forced fallbacks)
    # Frontier-occupancy trace of an *executed* plan (attached by
    # ``solve`` after the run when the executor collected one — the
    # frontier-sparse CSR backend's side channel): per-iteration frontier
    # nnz of the last batch's forward/backward sweeps, compaction hit
    # rate and overflow count. None on freshly planned (or dense/COO) plans.
    occupancy: Optional[Dict] = None

    def axes_dict(self) -> Optional[Dict[str, int]]:
        return dict(self.mesh_axes) if self.mesh_axes is not None else None

    def to_json(self) -> Dict:
        """JSON-serializable view (benchmarks record this next to timings)."""
        d = dataclasses.asdict(self)
        d["mesh_axes"] = self.axes_dict()
        d["buckets"] = list(self.buckets)
        d["backend"] = str(getattr(self.backend, "value", self.backend))
        d["execution"] = (self.execution.to_json()
                          if self.execution is not None else None)
        d["notes"] = list(self.notes)
        # Wire-schema compat: the occupancy side channel only appears on
        # executed CSR plans — older clients (and the golden fixture)
        # never see the key.
        if d.get("occupancy") is None:
            d.pop("occupancy", None)
        # Same rule for the metric fields: default-metric plans keep the
        # pre-metric wire schema byte-stable.
        if d.get("metric") == "betweenness":
            d.pop("metric", None)
        if not d.get("hops"):
            d.pop("hops", None)
        return d

    @classmethod
    def from_json(cls, d: Dict) -> "BCPlan":
        """Inverse of ``to_json`` — the serving wire form round-trips.

        Restores the tuple/enum shapes JSON flattens (``mesh_axes`` dict
        → ordered pairs, ``buckets``/``notes`` lists → tuples, the
        nested ``execution`` dict → ``ExecutionConfig``), so
        ``BCPlan.from_json(p.to_json())== p`` for any planner output.
        """
        d = dict(d)
        axes = d.get("mesh_axes")
        d["mesh_axes"] = (None if axes is None
                          else tuple((k, int(v)) for k, v in axes.items()))
        d["buckets"] = tuple(int(b) for b in d.get("buckets") or ())
        d["notes"] = tuple(d.get("notes") or ())
        ex = d.get("execution")
        d["execution"] = (None if ex is None
                          else ExecutionConfig.from_json(ex))
        return cls(**d)

    def summary(self) -> str:
        where = (f"mesh{self.axes_dict()}" if self.placement == "mesh"
                 else "single_host")
        return (f"BCPlan[{self.mode}] {where} backend={self.backend} "
                f"n_b={self.n_b} batches={self.n_batches} "
                f"~{self.predicted_seconds:.3g}s "
                f"~{self.predicted_comm_bytes:.3g}B comm "
                f"~{self.predicted_mem_bytes:.3g}B/dev")


def _near_square(q: int) -> Tuple[int, int]:
    """(data, model) with data·model = q, data ≥ model, as square as q allows."""
    model = 1
    for d in range(1, int(math.isqrt(q)) + 1):
        if q % d == 0:
            model = d
    return q // model, model


def _clamped_replication(n: int, m: int, p: int, mem_bytes: float) -> int:
    """Replication factor c: cost-model optimum, clamped to a divisor of p
    no larger than p^(1/3) (the Theorem 5.1 regime where replication pays)."""
    c_opt = best_replication(n, m, p, mem_bytes)
    cap = max(1, min(c_opt, int(round(p ** (1.0 / 3.0)))))
    c = 1
    for d in range(1, cap + 1):
        if p % d == 0:
            c = d
    return c


class BCPlanner:
    """Chooses backend, batch size and placement for a ``BCQuery``.

    ``calibration`` controls the measured step-time constants the regime
    choice and the ``predicted_*`` fields price with: the default
    ``"auto"`` loads ``results/cost_calibration.json`` (or
    ``$REPRO_BC_CALIBRATION``) fresh per plan — a benchmark that
    recalibrates mid-process is picked up via the mtime-keyed cache —
    while an explicit ``Calibration`` (tests, what-if planning) or
    ``None`` (force the analytic model) pins it.
    """

    def __init__(self, *, mem_bytes: float = 4 * 2 ** 30,
                 params: CostParams = DEFAULT,
                 calibration: Union[str, Calibration, None] = "auto"):
        self.mem_bytes = float(mem_bytes)
        self.params = params
        self._calibration = calibration

    @property
    def calibration(self) -> Optional[Calibration]:
        if isinstance(self._calibration, str):  # "auto"
            return load_calibration()
        return self._calibration

    # ------------------------------------------------------------------
    def plan(self, g: Graph, query, *, mesh=None,
             n_devices: Optional[int] = None) -> BCPlan:
        """Resolve ``query`` against the device topology.

        ``mesh``: explicit jax mesh — pins placement (and axes) to it.
        ``n_devices``: topology override for planning without touching
        jax device state (tests, dry runs). Default: ``len(jax.devices())``.
        """
        n, m = g.n, g.m
        pins = query.execution or ExecutionConfig()
        spec = metric_spec(query.metric)
        placement, axes, notes = self._placement(n, m, query, mesh, n_devices)
        p = 1
        if axes is not None:
            for _, s in axes:
                p *= s

        # `g` may be a stats-only record (graphs.formats.GraphStats) with
        # no edge arrays — the out-of-core path plans before (or without
        # ever) materializing the COO arrays on this host.
        if query.weighted is not None:
            weighted = query.weighted
        elif hasattr(g, "w"):
            weighted = bool(np.any(g.w != 1.0))
        else:
            weighted = bool(getattr(g, "weighted", False))
        # n_b sizing hint: the *uncapped* a-priori budget (a max_samples cap
        # below it should not shrink the batch the hardware wants to run).
        hint = (n if query.mode == "exact"
                else hoeffding_budget(n, query.eps, query.delta))
        # `max_samples=0` is a real (degenerate) cap, not "no cap" — the
        # sampler honors it, so the plan's budget must too.
        cap = (1 << 62) if query.max_samples is None else query.max_samples
        budget = n if query.mode == "exact" else min(hint, cap)

        cal = self.calibration
        # est_iters feeds the frontier-occupancy-aware CSR rate (total
        # frontier work amortizes over the sweep's iterations), so it is
        # resolved *before* any regime call.
        est_iters = self._est_iters(n, weighted, query.iters)
        if spec.bounded:
            # a hop-bounded sweep runs exactly hops - 1 relax iterations
            est_iters = max(1, min(est_iters, query.hops - 1))
        backend = pins.backend
        if placement == "mesh":
            # the distributed step is dense-adjacency only
            backend = Backend.DENSE if backend is None else backend
            if backend != Backend.DENSE:
                raise ValueError(f"mesh placement supports only the dense "
                                 f"backend, got {backend.value!r}")
        elif backend is None:
            # Resolve the regime *before* sizing n_b: on graphs whose
            # dense adjacency busts the memory budget, sizing against the
            # dense model would reject every candidate and collapse n_b
            # to the minimum even though the COO executor has room.
            backend = Backend(choose_bc_regime(n, m, query.n_b or 64,
                                               fill=0.5, p=p,
                                               calibration=cal,
                                               est_iters=est_iters)["regime"])
        n_b = query.n_b or min(n, choose_sample_batch(
            n, m, p=p, backend=backend.value,
            mem_bytes=self.mem_bytes, budget_hint=hint,
            calibration=cal))
        regime = choose_bc_regime(n, m, n_b, fill=0.5, p=p, calibration=cal,
                                  est_iters=est_iters)

        # Kernel flag: an explicit pin wins; otherwise light up the Pallas
        # dense kernels only where the calibration *measured* them faster
        # than the jnp fallback (True on the TPU target, False on CPU,
        # where the kernel runs in interpret mode).
        use_kernel = pins.use_kernel
        if use_kernel is None:
            use_kernel = bool(backend == Backend.DENSE and cal is not None
                              and cal.kernel_pays())

        # -- predictions (α-β cost layer, per device) -------------------
        if backend == Backend.DENSE:
            step_s = (regime["dense_kernel_s"]
                      if use_kernel and "dense_kernel_s" in regime
                      else regime["dense_s"])
        elif backend == Backend.CSR:
            # a calibrated regime may predate the CSR variant; price with
            # the COO rate then (an upper bound — CSR only sheds work)
            step_s = regime.get("csr_s", regime["coo_s"])
        else:
            step_s = regime["coo_s"]
        n_batches = -(-budget // n_b)
        if spec.fixed_point:
            # one whole-graph label fixed point, not per-source batches
            n_batches = 1
        state_nnz = _WORD * n_b * n  # one (n_b, n) f32 state matrix
        if placement == "mesh":
            c = dict(axes).get("pod", 1)
            # Theorem 5.1: (nnz(F) + 2·nnz(C))/√(p/c) per relax iteration
            comm_per_iter = 3.0 * state_nnz / max(math.sqrt(p / c), 1.0)
        else:
            comm_per_iter = 0.0
        # spec.sweeps relax sweeps of est_iters relaxations per batch:
        # MFBF + MFBr = 2 for betweenness, 1 for forward-only metrics —
        # the plan JSON records the metric next to this pricing.
        iters_total = spec.sweeps * est_iters * n_batches
        comm_bytes = comm_per_iter * iters_total
        # Calibrated fixed per-batch overhead (one device call per batch):
        # dispatch + host sync, the α of the measured α-β fit.
        overhead_s = (cal.overhead_seconds(backend, use_kernel=use_kernel)
                      if cal is not None
                      and cal.has(backend, use_kernel=use_kernel) else 0.0)
        seconds = (step_s * iters_total + overhead_s * n_batches
                   + self.params.cost(msgs=3.0 * iters_total, bytes_=comm_bytes))
        mem = self._mem_bytes(n, m, n_b, backend, placement, axes, p)

        execution = ExecutionConfig(backend=backend,
                                    use_kernel=bool(use_kernel),
                                    placement=placement, block=pins.block)
        return BCPlan(
            mode=query.mode, placement=placement, backend=backend.value,
            use_kernel=bool(use_kernel), n_b=int(n_b), block=pins.block,
            iters=query.iters, n_devices=p, mesh_axes=axes,
            sample_budget=int(budget), n_batches=int(n_batches),
            est_iters=int(est_iters), predicted_step_seconds=float(step_s),
            predicted_comm_bytes=float(comm_bytes),
            predicted_seconds=float(seconds), predicted_mem_bytes=float(mem),
            regime=regime, buckets=bucket_sizes(int(n_b)),
            tier=query.tier, metric=query.metric, hops=int(query.hops),
            execution=execution, notes=tuple(notes))

    # ------------------------------------------------------------------
    def _placement(self, n: int, m: int, query, mesh,
                   n_devices: Optional[int]):
        notes: List[str] = []
        pins = query.execution or ExecutionConfig()
        # Only betweenness has a distributed (Theorem 5.1) moments step;
        # sibling metrics run their sweeps single-host — never silently
        # when a topology was visible.
        if query.metric != "betweenness":
            if mesh is not None or pins.placement == "mesh":
                raise ValueError(
                    f"mesh placement is betweenness-only; metric "
                    f"{query.metric!r} has no distributed step")
            if n_devices is None:
                import jax

                n_devices = len(jax.devices())
            if n_devices > 1:
                note = (f"metric {query.metric!r} has no distributed step: "
                        f"planning single_host placement despite "
                        f"{n_devices} visible devices")
                notes.append(note)
            return "single_host", None, notes
        if mesh is not None:
            axes = tuple(zip(mesh.axis_names, (int(s) for s in
                                               mesh.devices.shape)))
            return "mesh", axes, notes
        if n_devices is None:
            import jax

            n_devices = len(jax.devices())
        if pins.placement == "single_host":
            return "single_host", None, notes
        # A pinned COO/CSR backend has no distributed step — stay on one
        # host, but never silently: the caller asked for a topology the
        # backend cannot use, so the fallback is warned and carried on
        # plan.notes.
        if pins.backend in (Backend.COO, Backend.CSR):
            if pins.placement == "mesh":
                raise ValueError(
                    f"mesh placement supports only the dense backend; the "
                    f"{pins.backend.value.upper()} step is single-host only")
            if n_devices > 1:
                note = (f"pinned backend {pins.backend.value!r} has no "
                        f"distributed step: falling back to single_host "
                        f"placement despite {n_devices} visible devices")
                notes.append(note)
                warnings.warn(note, UserWarning, stacklevel=3)
            return "single_host", None, notes
        if n_devices <= 1:
            if pins.placement == "mesh":
                raise ValueError("mesh placement pinned but only one "
                                 "device is visible")
            return "single_host", None, notes
        c = _clamped_replication(n, m, n_devices, self.mem_bytes)
        data, model = _near_square(n_devices // c)
        axes = (("pod", c),) if c > 1 else ()
        return "mesh", axes + (("data", data), ("model", model)), notes

    @staticmethod
    def _est_iters(n: int, weighted: bool, iters: int) -> int:
        if iters > 0:
            return iters
        # small-world heuristic: O(log n) hops, stretched by edge weights
        base = max(8, 2 * int(math.log2(max(n, 2))) + 2)
        return min(n, base * (8 if weighted else 1))

    def _mem_bytes(self, n, m, n_b, backend, placement, axes, p) -> float:
        """Peak per-device footprint, from the shared adjacency/state
        memory model in ``approx.driver`` (mesh: A and Aᵀ sharded over
        the (data, model) grid and replicated over pods, state over p)."""
        if placement == "mesh":
            sizes = dict(axes)
            grid = sizes.get("data", 1) * sizes.get("model", 1)
            return (adjacency_bytes(n, m, backend="dense", p=grid,
                                    transpose=True)
                    + state_bytes(n, n_b, p=p))
        return (adjacency_bytes(n, m, backend=backend)
                + state_bytes(n, n_b))


_REQUEST_PLANNER = BCPlanner()


def plan_for_request(g: Graph, *, eps: float, delta: float,
                     rule: str = "normal", topk: Optional[int] = None,
                     max_samples: Optional[int] = None, seed: int = 0,
                     tier: Optional[str] = None,
                     metric: str = "betweenness", hops: int = 0,
                     execution: Optional[ExecutionConfig] = None,
                     backend: Optional[str] = None, iters: int = 0,
                     mesh=None, n_devices: Optional[int] = None,
                     planner: Optional[BCPlanner] = None) -> BCPlan:
    """Size an approximate-BC plan from one serving request's (ε, δ).

    The per-query half of the serving autotuning story: instead of one
    frozen per-graph ``n_b``, each request's accuracy contract flows
    through the α-β cost model — the (ε, δ) Hoeffding budget is the
    ``budget_hint`` that ``choose_sample_batch`` sizes ``n_b`` against,
    so a loose-ε request plans a small first epoch and a tight-ε request
    a large one — and the resulting plan records the power-of-two
    ``buckets`` its batches will run at. ``serve.BCService`` calls this
    once per distinct (graph, ε, δ, rule) and caches the result; the
    cross-request half (packing several requests' demand into one fused
    batch) is ``repro.bc.fusion.BatchAssembler``.

    ``tier`` names the request's latency tier (``repro.bc.query.TIERS``);
    it does not change the configuration search, but it is recorded in
    the JSON ``BCPlan`` so benchmark artifacts and ``BCResponse.plan``
    carry the QoS class each plan was sized for.

    ``execution`` pins part of the typed execution choice
    (``repro.bc.ExecutionConfig``); ``backend=`` is the legacy string
    shim for its ``backend`` field (DeprecationWarning, same result).
    """
    from repro.bc.query import BCQuery

    if backend is not None:
        warnings.warn("plan_for_request(backend=...) is deprecated; pass "
                      "execution=ExecutionConfig(backend=...) instead",
                      DeprecationWarning, stacklevel=2)
        if execution is not None and execution.backend not in (None, backend):
            raise ValueError("plan_for_request got both execution= and a "
                             "conflicting legacy backend=")
        execution = (execution or ExecutionConfig()).resolve(backend=backend)
    # Fixed-point metrics (components) are exact by construction — the
    # (ε, δ) contract degenerates to "the answer", so the query plans in
    # exact mode while every sampled metric keeps the approx search.
    mode = "exact" if metric_spec(metric).fixed_point else "approx"
    q = BCQuery(mode=mode, eps=eps, delta=delta, rule=rule, topk=topk,
                max_samples=max_samples, seed=seed, tier=tier,
                metric=metric, hops=hops, execution=execution, iters=iters)
    return (planner or _REQUEST_PLANNER).plan(g, q, mesh=mesh,
                                              n_devices=n_devices)
