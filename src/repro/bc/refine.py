"""Resumable approximate-BC refinement from checkpointed (S1, S2, τ).

The adaptive estimator's whole state is three per-vertex running sums
plus the position of its source-sampling stream — which makes a finished
loose-ε run a *warm start* for a tighter one: restore the sums and the
stream, keep drawing epochs, and test the tighter stopping rule at the
same epoch boundaries a from-scratch run would. This is what lets the
serving result cache (``repro.serve.cache``) answer a tight-ε query
with a looser cached entry *immediately* while the refinement continues
in the background, instead of throwing the cached samples away.

The resume contract (the PR 3 checkpoint guarantee, lifted to the
estimator): when the original run's epochs were never truncated by its
sample cap (``ApproxCheckpoint.prefix_exact``), a refinement to a
tighter ε is **bitwise identical** to a from-scratch run at that ε over
the same stream — same ``(seed, rid)``-derived RNG, same ``n_b`` epoch
schedule, same chunking. That holds because

* the stream is chunking-invariant (``AdaptiveSampler.draw`` draws
  bounded integers element-wise), so the resumed draws are exactly the
  sources the scratch run would draw after its own identical prefix;
* a stopping rule at ε' < ε can never fire *before* the ε rule did
  (``hw.max() <= ε'`` implies ``hw.max() <= ε``, and the top-k
  separation test is ε-independent), so the scratch tight run walks
  through the same prefix of non-stopping epoch checks the loose run
  recorded — diverging only at (possibly) the loose run's final
  boundary, which ``resume_approx`` re-tests first at the tight ε;
* the estimator folds chunk sums in arrival order, and both paths chop
  each epoch into the same ``n_b``-sized chunks.

A cap-truncated prefix (``prefix_exact=False``) still refines correctly
— the sums are real samples either way — but the continued stream no
longer matches a scratch run's, so the bitwise claim is off and callers
that need it (the cache's parity tests) should fall back to scratch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.approx.driver import (ApproxResult, LambdaEstimator,
                                 stopping_check)
from repro.approx.sampling import (AdaptiveSampler, epoch_schedule,
                                   hoeffding_budget)
from repro.bc.executor import BatchExecutor
from repro.bc.solve import honest_converged

__all__ = ["ApproxCheckpoint", "checkpoint_from", "resume_approx"]


@dataclasses.dataclass
class ApproxCheckpoint:
    """Everything needed to resume one adaptive run at a tighter target.

    ``s1``/``s2``/``tau`` are the estimator's running (Σδ, Σδ², count)
    sums; ``sampler_state`` the stream snapshot
    (``AdaptiveSampler.state()``); ``eps``/``delta``/``rule`` the
    contract the run stopped at; ``n_b`` its epoch schedule unit
    (τ₀ and the chunk size — a resume must reuse it). ``prefix_exact``
    is True iff no epoch was truncated by the run's sample cap, i.e.
    the drawn stream equals what an uncapped schedule would have drawn
    — the precondition of the bitwise resume contract.
    """

    n: int
    eps: float
    delta: float
    rule: str
    n_b: int
    s1: np.ndarray  # (n,) float64 running Σδ
    s2: np.ndarray  # (n,) float64 running Σδ²
    tau: int
    n_epochs: int
    sampler_state: dict
    prefix_exact: bool

    @property
    def growth(self) -> float:
        return 2.0  # the one schedule every production sampler runs


def _untruncated(drawn: int, ei: int, n_b: int, growth: float = 2.0) -> bool:
    """True iff ``drawn`` equals the raw (cap-free) schedule prefix sum."""
    sched = epoch_schedule(n_b, growth)
    return drawn == sum(next(sched) for _ in range(ei))


def checkpoint_from(est: LambdaEstimator, sampler: AdaptiveSampler,
                    *, n_epochs: int) -> ApproxCheckpoint:
    """Snapshot a run's estimator + stream (arrays copied, not aliased)."""
    state = sampler.state()
    return ApproxCheckpoint(
        n=est.n, eps=est.eps, delta=est.delta, rule=est.rule,
        n_b=sampler.n_b, s1=est.s1.copy(), s2=est.s2.copy(), tau=est.tau,
        n_epochs=int(n_epochs), sampler_state=state,
        prefix_exact=_untruncated(state["drawn"], state["ei"], sampler.n_b))


def resume_approx(executor: BatchExecutor, ckpt: ApproxCheckpoint, *,
                  eps: float, delta: Optional[float] = None,
                  topk: Optional[int] = None,
                  max_samples: Optional[int] = None,
                  metric: str = "betweenness", hops: int = 0
                  ) -> Tuple[ApproxResult, ApproxCheckpoint]:
    """Continue a checkpointed run to a tighter ε; returns (result, ckpt).

    Restores the (S1, S2, τ) sums into a fresh estimator at the new
    target, re-tests the stopping rule at the *last completed* epoch
    boundary (a scratch run at ``eps`` would have tested there too —
    if it passes, the cached sums already certify the tighter target
    and nothing is sampled), then keeps drawing epochs through
    ``executor.step`` in ``n_b``-sized chunks — the classic
    per-request chunking — until the tighter rule fires or the new
    Hoeffding cap (``max_samples`` override) is reached.

    The returned checkpoint snapshots the *refined* run, so a chain of
    progressively tighter refinements stays resumable (the cache keeps
    only the tightest entry per key).
    """
    n = ckpt.n
    d = ckpt.delta if delta is None else delta
    est = LambdaEstimator(n, eps, d, ckpt.rule)
    est.s1 = ckpt.s1.copy()
    est.s2 = ckpt.s2.copy()
    est.tau = int(ckpt.tau)
    cap = (hoeffding_budget(n, eps, d) if max_samples is None
           else max_samples)
    sampler = AdaptiveSampler.from_state(n, ckpt.sampler_state, eps=eps,
                                         delta=d, n_b=ckpt.n_b, cap=cap)
    n_epochs = ckpt.n_epochs
    converged = False
    if n_epochs > 0:
        done, _ = stopping_check(est, eps, topk, n_epochs - 1)
        if done:
            converged = True
            sampler.stop()
    while not converged:
        nxt = sampler.next_epoch()
        if nxt is None:
            break
        ei, tau_e = nxt
        sources = sampler.draw(tau_e)
        for lo in range(0, tau_e, ckpt.n_b):
            chunk = sources[lo:lo + ckpt.n_b]
            # metric/hops must match the checkpointed run's — the sums
            # being resumed are per-metric contributions (the cache keys
            # entries per metric, so a refine never crosses metrics).
            s1, s2, _ = executor.step(chunk, np.ones(chunk.shape[0], bool),
                                      metric=metric, hops=hops)
            est.update(s1, s2, int(chunk.shape[0]))
        n_epochs = ei + 1
        done, _ = stopping_check(est, eps, topk, ei)
        if done:
            converged = True
            sampler.stop()
    if not converged and sampler.capped:
        converged = honest_converged(est)
    res = est.result(n_epochs=n_epochs, converged=converged)
    return res, checkpoint_from(est, sampler, n_epochs=n_epochs)
