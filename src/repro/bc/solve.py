"""repro.bc.solve — the single entry point over every BC path.

``solve(g, query)`` is what ``launch.bc_run``, ``serve.bc_service``,
``benchmarks/bc_approx.py`` and the examples all call: it plans (unless
handed a ``BCPlan``), builds the executor, and runs one of two drivers
over the shared ``step(sources, valid) -> (S1, S2, n_reach)`` protocol:

* **exact** — sweep all sources (or an explicit ``sources`` subset, the
  checkpoint-resume hook) in ``⌈budget/n_b⌉`` padded batches; λ is the
  running Σ S1.
* **approx** — the adaptive/uniform sampling epochs formerly in
  ``approx.driver.approx_bc``: fold batch moments into a
  ``LambdaEstimator``, test the Bernstein/CLT stopping rule at epoch
  boundaries with a geometrically split failure budget, stop early on
  top-k CI separation.

``approx.driver.approx_bc`` and ``core.dist_bc.dist_mfbc`` survive as
deprecation shims that delegate here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.approx import sampling as S
from repro.approx.driver import (ApproxResult, LambdaEstimator,
                                 stopping_check)
from repro.bc.executor import BatchExecutor, build_executor
from repro.bc.planner import BCPlan, BCPlanner
from repro.bc.query import BCQuery
from repro.core.metrics import metric_spec
from repro.graphs.formats import Graph

_DEFAULT_PLANNER = BCPlanner()


def honest_converged(est: LambdaEstimator) -> bool:
    """Can this estimator's run be certified as converged at its (ε, δ)?

    A sample cap *below* the Hoeffding budget carries no a-priori
    guarantee — only the empirical CIs can still certify convergence
    there; at or past the budget the a-priori bound holds regardless of
    what the CIs say. Shared by the ``solve`` approx driver and
    ``serve.BCService`` retirement, so a capped run is reported
    converged under exactly one rule everywhere.
    """
    if est.tau >= S.hoeffding_budget(est.n, est.eps, est.delta):
        return True
    return est.converged()


@dataclasses.dataclass
class BCResult:
    """Solver outcome: λ plus the plan that produced it.

    ``approx`` carries the estimator metadata (CIs, sample counts,
    convergence) for approximate queries and is ``None`` for exact ones.
    """

    lam: np.ndarray  # (n,) λ, unnormalized ordered-pair convention
    plan: BCPlan
    query: BCQuery
    seconds: float
    n_swept: int = 0  # sources actually run through the executor
    approx: Optional[ApproxResult] = None

    def topk(self, k: int) -> np.ndarray:
        """Vertex ids of the k largest λ values, descending."""
        return np.argsort(self.lam)[::-1][:k]

    @property
    def converged(self) -> bool:
        return True if self.approx is None else self.approx.converged

    @property
    def n_samples(self) -> int:
        """Sources actually swept (a restricted exact sweep counts only
        its ``sources`` subset)."""
        return self.n_swept if self.approx is None else self.approx.n_samples


def plan(g: Graph, query: Optional[BCQuery] = None, *, mesh=None,
         n_devices: Optional[int] = None,
         planner: Optional[BCPlanner] = None) -> BCPlan:
    """Plan a query without running it (inspectable configuration search)."""
    query = query if query is not None else BCQuery()
    planner = planner or _DEFAULT_PLANNER
    return planner.plan(g, query, mesh=mesh, n_devices=n_devices)


def solve(g: Graph, query: Optional[BCQuery] = None, *, mesh=None,
          plan: Optional[BCPlan] = None,
          executor: Optional[BatchExecutor] = None,
          sources: Optional[np.ndarray] = None,
          planner: Optional[BCPlanner] = None,
          progress_cb: Optional[Callable] = None) -> BCResult:
    """Solve one BC query end to end (plan → executor → driver).

    Args:
      g: host COO graph.
      query: what to compute (default: exact sweep).
      mesh: explicit jax mesh — pins placement to the distributed step.
      plan: pre-computed ``BCPlan`` (skips planning; ``repro.bc.plan``).
      executor: pre-built executor (serving reuses one across requests).
      sources: exact mode only — restrict the sweep to these sources
        (the checkpoint-resume hook of ``launch.bc_run``).
      progress_cb: exact mode ``cb(batch, n_batches, λ_running)``;
        approx mode ``cb(epoch, τ, max_halfwidth)``.

    Returns:
      ``BCResult`` with λ, the executed plan and (approx) CI metadata.
    """
    query = query if query is not None else BCQuery()
    if plan is None:
        plan = (executor.plan if executor is not None
                else (planner or _DEFAULT_PLANNER).plan(g, query, mesh=mesh))
    if executor is None:
        executor = build_executor(g, plan, mesh=mesh)
    t0 = time.time()
    spec = metric_spec(query.metric)
    if spec.fixed_point:
        # components: one whole-graph label fixed point, no source sweep
        lam = executor.labels()
        return BCResult(lam=lam, plan=plan, query=query,
                        seconds=time.time() - t0, n_swept=g.n)
    if query.mode == "exact":
        lam, n_swept = _run_exact(g, query, executor, sources, progress_cb)
        return BCResult(lam=lam, plan=_with_occupancy(plan, executor),
                        query=query, seconds=time.time() - t0,
                        n_swept=n_swept)
    res = _run_approx(g, query, executor, progress_cb)
    return BCResult(lam=res.lam, plan=_with_occupancy(plan, executor),
                    query=query, seconds=time.time() - t0,
                    n_swept=res.n_samples, approx=res)


def _with_occupancy(plan: BCPlan, executor: BatchExecutor) -> BCPlan:
    """Attach the executor's frontier-occupancy trace to the executed plan.

    Only the frontier-compacted CSR step collects a trace
    (``SingleHostExecutor.occupancy_summary`` returns ``None``
    otherwise), so dense/COO plans pass through *by identity* —
    callers that cache the plan object (serving) keep their reference.
    """
    occ_fn = getattr(executor, "occupancy_summary", None)
    occ = occ_fn() if occ_fn is not None else None
    if occ is None:
        return plan
    return dataclasses.replace(plan, occupancy=occ)


# ---------------------------------------------------------------- drivers
def _run_exact(g: Graph, q: BCQuery, ex: BatchExecutor, sources,
               progress_cb):
    all_sources = (np.arange(g.n, dtype=np.int32) if sources is None
                   else np.asarray(sources, np.int32))
    nb = ex.n_b
    n_batches = -(-all_sources.shape[0] // nb) if all_sources.size else 0
    lam = np.zeros(g.n, dtype=np.float64)
    for b in range(n_batches):
        chunk = all_sources[b * nb:(b + 1) * nb]
        # Σδ-only reduction: the sweep never needs Σδ², so skip the
        # moments overhead (3× stacked all-reduce on the mesh).
        lam += ex.step_sum(chunk, np.ones(chunk.shape[0], bool),
                           metric=q.metric, hops=q.hops)
        if progress_cb is not None:
            progress_cb(b, n_batches, lam)
    return lam, int(all_sources.shape[0])


def _run_approx(g: Graph, q: BCQuery, ex: BatchExecutor,
                progress_cb) -> ApproxResult:
    n = g.n
    est = LambdaEstimator(n, q.eps, q.delta, q.rule)

    def run_batch(b: S.SampleBatch) -> None:
        s1, s2, _ = ex.step(b.sources, b.valid, metric=q.metric, hops=q.hops)
        est.update(s1, s2, b.n_valid)

    if q.strategy == "uniform":
        sampler = S.UniformSampler(n, eps=q.eps, delta=q.delta, n_b=ex.n_b,
                                   budget=q.max_samples, seed=q.seed)
        epochs = 0
        for b in sampler.batches():
            run_batch(b)
            epochs = b.epoch + 1
        return est.result(n_epochs=epochs, converged=honest_converged(est))

    sampler = S.AdaptiveSampler(n, eps=q.eps, delta=q.delta, n_b=ex.n_b,
                                cap=q.max_samples, seed=q.seed)
    n_epochs = 0
    converged = False
    for ei, batches in sampler.epochs():
        for b in batches:
            run_batch(b)
        n_epochs = ei + 1
        stop, hw = stopping_check(est, q.eps, q.topk, ei)
        if progress_cb is not None:
            progress_cb(ei, est.tau, float(hw.max()))
        if stop:
            converged = True
            sampler.stop()
    if sampler.capped and not converged:
        converged = honest_converged(est)
    return est.result(n_epochs=n_epochs, converged=converged)
