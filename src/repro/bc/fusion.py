"""Cross-request batch fusion — many queries, one padded batch.

The serving-side half of the paper's batching story: the batched MFBC
step amortizes its fixed cost (kernel dispatch on one host, the fused
moments all-reduce on a mesh) over every source row in the batch, but a
slot-scheduled service advancing each request independently runs each
request's epoch as its own under-filled batch and pays that fixed cost
per *request*. ``BatchAssembler`` closes the gap: it drains the source
demand of many live requests on the same graph (the demand side of
``approx.sampling.AdaptiveSampler``) and packs it into slot-tagged
``FusedBatch``es for the executor's ``step_segmented`` — one device call
returns per-slot ``(S1, S2, n_reach)`` rows that ``scatter`` hands back
to each request's ``LambdaEstimator``.

Packing policy: ``order_demand`` decides *which slot drains first* —
``pack="fifo"`` keeps the caller's order, ``"deadline"`` sorts by
deadline slack (tightest first, the QoS scheduler's drain order), and
``"fair"`` greedily balances cumulative rows across tenants. Whatever
the policy, slots are laid out contiguously in the chosen order (never
interleaved), so each fused batch touches as few distinct slots as
possible and every slot's rows keep their draw order — which is what
makes a slot's fused statistics bitwise-identical to an unfused run of
the same rows (the segment-sum accumulates each slot's rows in batch
order) under *every* packing policy. Batches are chopped at the
executor's capacity ``n_b`` and padded to its power-of-two bucket, so
ragged multi-request demand never retraces and never pays
always-pad-to-``n_b`` waste.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.bc.executor import BatchExecutor

PACKS = ("fifo", "deadline", "fair")


def order_demand(demand: Sequence[Tuple[int, np.ndarray]],
                 pack: str = "fifo", *,
                 slack: Optional[Dict[int, float]] = None,
                 tenant: Optional[Dict[int, str]] = None,
                 served: Optional[Dict[str, int]] = None
                 ) -> List[Tuple[int, np.ndarray]]:
    """Order ``(slot_key, sources)`` demand entries by packing policy.

    The one ordering rule shared by ``BatchAssembler.assemble`` (within a
    graph) and the service's global budget allocation (across graphs), so
    "who drains first" and "who gets the tick budget" always agree.
    Entries are reordered *whole* — a slot's rows are never split or
    interleaved here, which preserves the per-slot row order the bitwise
    fused-parity guarantee rests on.

    * ``"fifo"`` — the caller's order (the pre-QoS behavior).
    * ``"deadline"`` — ascending deadline slack (``slack[key]`` seconds
      until the slot's deadline; missing keys sort last). Stable: ties
      keep the caller's order.
    * ``"fair"`` — greedy per-tenant fair share: repeatedly drain the
      entry whose tenant (``tenant[key]``, default ``"default"``) has
      the fewest cumulative rows, counting both this call and the
      caller's history (``served``, e.g. rows drained in earlier ticks);
      ties break toward tighter slack, then the caller's order.
    """
    if pack not in PACKS:
        raise ValueError(f"pack must be one of {PACKS}, got {pack!r}")
    entries = list(demand)
    if pack == "fifo" or len(entries) <= 1:
        return entries
    sl = slack or {}
    if pack == "deadline":
        return sorted(entries, key=lambda e: sl.get(e[0], math.inf))
    tn = tenant or {}
    totals: Dict[str, int] = dict(served or {})
    out: List[Tuple[int, np.ndarray]] = []
    remaining = entries
    while remaining:
        j = min(range(len(remaining)), key=lambda i: (
            totals.get(tn.get(remaining[i][0], "default"), 0),
            sl.get(remaining[i][0], math.inf), i))
        key, srcs = remaining.pop(j)
        t = tn.get(key, "default")
        totals[t] = totals.get(t, 0) + int(np.asarray(srcs).size)
        out.append((key, srcs))
    return out


@dataclasses.dataclass(frozen=True)
class FusedBatch:
    """One slot-tagged batch packed from several requests' demand.

    ``slots[j]`` is the caller's key for local slot j; ``counts[j]`` how
    many rows slot j contributed. Rows are unpadded here (every row is
    a real source, ``valid`` all True, length ≤ the assembler's
    capacity) — bucket padding, with ``valid=False`` rows tagged into a
    dump segment, happens inside the executor's ``step_segmented``.
    """

    sources: np.ndarray  # (B,) int32, B ≤ executor capacity
    valid: np.ndarray  # (B,) bool
    slot_ids: np.ndarray  # (B,) int32 in [0, n_slots)
    slots: Tuple[int, ...]  # local slot j -> caller slot key
    counts: Tuple[int, ...]  # valid rows per local slot

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    @property
    def n_valid(self) -> int:
        return int(sum(self.counts))


class BatchAssembler:
    """Packs per-request source demand into fused executor batches.

    One assembler per (graph, executor): capacity and buckets come from
    the executor it feeds. ``assemble`` is pure packing — it never draws
    sources itself, so callers control each request's RNG stream — and
    ``scatter`` is the inverse, mapping the segmented step's per-slot
    rows back to caller keys. ``pack`` picks the drain order
    (``order_demand``); whichever policy runs, per-slot statistics stay
    bitwise-identical to an unfused run, because ordering moves whole
    entries and never touches a slot's row order.
    """

    def __init__(self, executor: BatchExecutor, pack: str = "fifo"):
        if pack not in PACKS:
            raise ValueError(f"pack must be one of {PACKS}, got {pack!r}")
        self.executor = executor
        self.capacity = int(executor.n_b)
        self.pack = pack

    def assemble(self, demand: Sequence[Tuple[int, np.ndarray]], *,
                 slack: Optional[Dict[int, float]] = None,
                 tenant: Optional[Dict[int, str]] = None,
                 served: Optional[Dict[str, int]] = None
                 ) -> List[FusedBatch]:
        """Pack ``(slot_key, sources)`` demand into fused batches.

        Orders the entries by the assembler's ``pack`` policy (slack /
        tenant / served feed the deadline and fair policies and are
        ignored by FIFO), concatenates each slot's sources (preserving
        every slot's row order), chops the stream at the executor
        capacity, and tags rows with batch-local slot ids. Empty demand
        entries are dropped; an empty demand list yields no batches.
        Slot keys must be distinct — ``scatter`` maps per-slot rows back
        by key, so a duplicate would silently shadow its earlier
        statistics (concatenate a slot's sources instead).
        """
        keys: List[int] = []
        parts: List[np.ndarray] = []
        tags: List[np.ndarray] = []
        ordered = order_demand(demand, self.pack, slack=slack,
                               tenant=tenant, served=served)
        for key, srcs in ordered:
            srcs = np.asarray(srcs, np.int32)
            if srcs.size == 0:
                continue
            keys.append(key)
            parts.append(srcs)
            tags.append(np.full(srcs.size, len(keys) - 1, np.int32))
        if len(set(keys)) != len(keys):
            raise ValueError(f"duplicate slot keys in demand: {keys}; "
                             f"merge each slot's sources into one entry")
        if not parts:
            return []
        stream = np.concatenate(parts)
        stream_keys = np.concatenate(tags)
        out: List[FusedBatch] = []
        for lo in range(0, stream.size, self.capacity):
            hi = min(lo + self.capacity, stream.size)
            out.append(self._one_batch(stream[lo:hi], stream_keys[lo:hi],
                                       keys))
        return out

    def _one_batch(self, sources: np.ndarray, global_tags: np.ndarray,
                   keys: List[int]) -> FusedBatch:
        # Renumber to batch-local slot ids in order of first appearance,
        # so n_slots is the number of slots *in this batch*, not overall.
        uniq, first, inverse, counts = np.unique(
            global_tags, return_index=True, return_inverse=True,
            return_counts=True)
        order = np.argsort(first)  # unique tags by first appearance
        rank = np.empty(order.size, np.int64)
        rank[order] = np.arange(order.size)
        return FusedBatch(sources=sources,
                          valid=np.ones(sources.size, bool),
                          slot_ids=rank[inverse].astype(np.int32),
                          slots=tuple(keys[int(t)] for t in uniq[order]),
                          counts=tuple(int(c) for c in counts[order]))

    def run(self, demand: Sequence[Tuple[int, np.ndarray]], *,
            slack: Optional[Dict[int, float]] = None,
            tenant: Optional[Dict[int, str]] = None,
            served: Optional[Dict[str, int]] = None
            ) -> Iterator[Tuple[FusedBatch, Dict[int, Tuple]]]:
        """Assemble, step, scatter: yields ``(batch, per-slot moments)``.

        Convenience loop over ``assemble`` + ``step_segmented`` +
        ``scatter`` for callers (service tick, tests) that don't need to
        interleave other work between fused batches.
        """
        for fb in self.assemble(demand, slack=slack, tenant=tenant,
                                served=served):
            s1, s2, nr = self.executor.step_segmented(
                fb.sources, fb.valid, fb.slot_ids, fb.n_slots)
            yield fb, scatter(fb, (s1, s2, nr))


def scatter(fb: FusedBatch, moments: Tuple[np.ndarray, np.ndarray,
                                           np.ndarray]
            ) -> Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray, int]]:
    """Map segmented ``(S1, S2, n_reach)`` rows back to caller slot keys.

    Returns ``{slot_key: (s1_row, s2_row, n_reach_row, n_valid)}`` —
    exactly the arguments each slot's ``LambdaEstimator.update`` wants.
    """
    s1, s2, nr = moments
    return {key: (s1[j], s2[j], nr[j], fb.counts[j])
            for j, key in enumerate(fb.slots)}
