"""BCQuery — what the caller wants, decoupled from how it runs.

The unified solver API splits a betweenness-centrality request into three
layers (the §6.2 "automatic configuration search" made first-class):

* **query** (this module) — accuracy/budget intent: exact or approximate,
  (ε, δ) targets, top-k early exit, stopping rule, seed, sample cap.
* **plan** (``repro.bc.planner``) — the chosen execution configuration:
  backend, batch size n_b (plus its power-of-two serving ``buckets``),
  single-host vs mesh placement, predicted cost.
* **executor** (``repro.bc.executor``) — the jitted batch step behind one
  ``step(sources, valid) -> (S1, S2, n_reach)`` protocol (plus the
  slot-tagged ``step_segmented`` fused variant the serving stack packs
  many queries into).

A ``BCQuery`` carries *optional overrides* (``n_b`` and a typed
``execution: ExecutionConfig``) for callers that want to pin part of the
configuration — ``None``/default means "let the planner decide" (backend
from the calibrated dense-vs-COO regime model, kernel flag from the
measured kernel-vs-fallback verdict, placement from the topology). The
pre-``ExecutionConfig`` stringly-typed kwargs (``backend=``,
``use_kernel=``, ``block=``) still work as thin deprecation shims with
identical results. Serving requests reach this layer through
``repro.bc.plan_for_request``, which builds the equivalent approx query
from one request's (ε, δ) so per-query batch sizing flows through the
same planner as every other entry point.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.bc.config import Backend, ExecutionConfig
from repro.core.metrics import metric_spec

MODES = ("exact", "approx")
RULES = ("bernstein", "normal")
STRATEGIES = ("adaptive", "uniform")
BACKENDS = tuple(b.value for b in Backend)

# Latency tiers, the QoS vocabulary shared by the whole serving stack:
# ``serve.BCRequest.priority`` names one, ``plan_for_request`` records it
# in the JSON ``BCPlan``, and the scheduler turns it into a deadline
# (``TIER_DEADLINE_S`` when the request gives no explicit ``deadline_s``).
TIERS = ("interactive", "normal", "batch")
TIER_DEADLINE_S = {"interactive": 0.5, "normal": 5.0, "batch": 60.0}


@dataclasses.dataclass(frozen=True)
class BCQuery:
    """One betweenness-centrality request.

    Accuracy semantics for ``mode="approx"`` match ``repro.approx``:
    ``eps`` is the CI halfwidth target on the normalized dependency scale
    ``δ_s(v)/(n-2) ∈ [0, 1]``, ``delta`` the total failure probability,
    ``rule`` the CI family (rigorous empirical-Bernstein vs CLT profile),
    ``topk`` an optional CI-separation early exit, and ``max_samples`` a
    hard cap overriding the Hoeffding budget. ``mode="exact"`` ignores
    the accuracy knobs and sweeps every source.
    """

    mode: str = "exact"
    # -- metric (MetricSpec registry, repro.core.metrics) ----------------
    metric: str = "betweenness"
    hops: int = 0  # khop's bound (edges); required >= 1 iff metric="khop"
    # -- approx accuracy / budget ---------------------------------------
    eps: float = 0.05
    delta: float = 0.1
    rule: str = "bernstein"
    strategy: str = "adaptive"
    topk: Optional[int] = None
    max_samples: Optional[int] = None
    seed: int = 0
    tier: Optional[str] = None  # latency tier (serving QoS); None = untiered
    # -- hints ----------------------------------------------------------
    weighted: Optional[bool] = None  # None = infer from the graph
    # -- planner overrides (None / 0 / False = planner decides) ---------
    n_b: Optional[int] = None
    execution: Optional[ExecutionConfig] = None  # typed execution pins
    # legacy execution kwargs — deprecation shims for the pre-
    # ExecutionConfig API; after __post_init__ they mirror `execution`
    # so old readers (`query.backend`, `query.block`) keep working.
    backend: Optional[str] = None  # "dense" | "coo"
    use_kernel: Optional[bool] = None
    block: Optional[int] = None
    iters: int = 0  # static sweep bound for mesh executors (0 = graph size)

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.rule not in RULES:
            raise ValueError(f"rule must be one of {RULES}, got {self.rule!r}")
        if self.strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}, "
                             f"got {self.strategy!r}")
        spec = metric_spec(self.metric)  # raises with the registered list
        if spec.bounded:
            if self.hops < 1:
                raise ValueError(f"metric {self.metric!r} needs hops >= 1, "
                                 f"got {self.hops}")
        elif self.hops:
            raise ValueError(f"hops only applies to hop-bounded metrics, "
                             f"not {self.metric!r}")
        if spec.fixed_point and self.mode != "exact":
            raise ValueError(f"metric {self.metric!r} is a fixed point — "
                             f"exact only, not mode={self.mode!r}")
        self._resolve_execution()
        if self.tier is not None and self.tier not in TIERS:
            raise ValueError(f"tier must be None or one of {TIERS}, "
                             f"got {self.tier!r}")
        if self.mode == "approx" and not (0.0 < self.eps < 1.0
                                          and 0.0 < self.delta < 1.0):
            raise ValueError(f"approx mode needs eps, delta in (0, 1), got "
                             f"eps={self.eps} delta={self.delta}")

    def _resolve_execution(self) -> None:
        """Normalize the legacy (backend, use_kernel, block) kwargs and the
        typed ``execution`` into one ``ExecutionConfig``, then mirror it
        back onto the legacy fields.

        ``dataclasses.replace`` re-passes the mirrored legacy fields next
        to ``execution``; that round trip is silent — only a *conflicting*
        combination errors, and only a legacy kwarg used *instead of*
        ``execution`` warns.
        """
        exec_ = self.execution
        legacy_used = (self.backend is not None or self.use_kernel is not None
                       or self.block is not None)
        if exec_ is None:
            if legacy_used:
                warnings.warn(
                    "BCQuery(backend=, use_kernel=, block=) is deprecated; "
                    "pass execution=ExecutionConfig(...) instead "
                    "(repro.bc.ExecutionConfig)",
                    DeprecationWarning, stacklevel=4)
            exec_ = ExecutionConfig(
                backend=self.backend, use_kernel=self.use_kernel,
                block=self.block if self.block is not None else 512)
        elif legacy_used:
            mirrors = ((self.backend, exec_.backend),
                       (self.use_kernel, exec_.use_kernel),
                       (self.block, exec_.block))
            if any(v is not None and v != e for v, e in mirrors):
                raise ValueError(
                    "BCQuery got both execution= and conflicting legacy "
                    "backend/use_kernel/block kwargs; pass execution= only")
        object.__setattr__(self, "execution", exec_)
        object.__setattr__(self, "backend", exec_.backend)
        object.__setattr__(self, "use_kernel", exec_.use_kernel)
        object.__setattr__(self, "block", exec_.block)
