"""Betweenness-centrality launcher (the paper's own workload).

  PYTHONPATH=src python -m repro.launch.bc_run --graph rmat --scale 8 \
      --degree 8 --nb 64 [--weighted] [--backend dense|coo] [--ckpt-dir d]

Per-batch checkpointing: the λ accumulator + batch index is saved after
every batch, so a killed run resumes without recomputing finished batches
(Algorithm 3's outer loop is embarrassingly restartable).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import brandes_bc, mfbc
from repro.graphs.generators import erdos_renyi, rmat, uniform_random
from repro.train import checkpoint as ckpt_lib


def build_graph(args):
    if args.graph == "rmat":
        return rmat(args.scale, args.degree, weighted=args.weighted,
                    seed=args.seed)
    if args.graph == "uniform":
        return uniform_random(1 << args.scale, args.degree,
                              weighted=args.weighted, seed=args.seed)
    if args.graph == "er":
        return erdos_renyi(1 << args.scale, args.degree / (1 << args.scale),
                           weighted=args.weighted, seed=args.seed)
    raise ValueError(args.graph)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "uniform", "er"])
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--weighted", action="store_true")
    ap.add_argument("--nb", type=int, default=64)
    ap.add_argument("--backend", default="dense", choices=["dense", "coo"])
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="check against the Brandes oracle (slow)")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args(argv)

    g = build_graph(args)
    g, _ = g.remove_isolated()
    print(f"[bc] graph {g.name}: n={g.n} m={g.m}")

    start_batch = 0
    lam_acc = {"lam": np.zeros(g.n), "batch": -1}
    if args.ckpt_dir:
        step = ckpt_lib.latest_step(args.ckpt_dir)
        if step is not None:
            flat, _ = ckpt_lib.restore(args.ckpt_dir)
            lam_acc["lam"] = flat["lam"]
            start_batch = step + 1
            print(f"[bc] resuming at batch {start_batch}")

    def progress(b, n_batches, lam):
        if args.ckpt_dir:
            ckpt_lib.save(args.ckpt_dir, b, {"lam": lam, "batch": b})
        print(f"[bc] batch {b + 1}/{n_batches}")

    t0 = time.time()
    n_batches = -(-g.n // args.nb)
    sources = np.arange(start_batch * args.nb, g.n, dtype=np.int32)
    lam = mfbc(g, n_b=args.nb, backend=args.backend,
               use_kernel=args.use_kernel, sources=sources,
               progress_cb=progress)
    lam = lam + lam_acc["lam"]
    dt = time.time() - t0
    # TEPS as the paper counts it: every edge is traversed once per source
    teps = g.m * g.n / dt
    print(f"[bc] done in {dt:.2f}s — {teps:,.0f} TEPS (model)")
    top = np.argsort(lam)[::-1][:5]
    print("[bc] top-5 central vertices:", list(zip(top.tolist(),
                                                   np.round(lam[top], 2))))
    if args.verify:
        ref = brandes_bc(g)
        np.testing.assert_allclose(lam, ref, rtol=1e-4, atol=1e-6)
        print("[bc] verified against Brandes oracle")
    return lam


if __name__ == "__main__":
    main()
