"""Betweenness-centrality launcher (the paper's own workload).

  PYTHONPATH=src python -m repro.launch.bc_run --graph rmat --scale 8 \
      --degree 8 --nb 64 [--weighted] [--backend auto|dense|coo] \
      [--ckpt-dir d] [--metric betweenness|closeness|khop|components] \
      [--hops k]

``--metric`` swaps the analytic computed by the sweep (the MetricSpec
registry, ``repro.core.metrics``): closeness is the forward-only farness
profile, ``khop`` (with ``--hops k``) hop-bounded reachability, and
``components`` the min-label fixed point (exact mode only, no source
sweep). ``--verify`` checks each against its own host oracle.

Every mode is one call into the unified solver API: build a
``repro.bc.BCQuery``, let ``BCPlanner`` resolve backend / batch size /
placement (printed as the ``BCPlan`` line; pin with --nb / --backend /
--mesh), and run ``repro.bc.solve``.

Per-batch checkpointing: the λ accumulator + batch index is saved after
every batch, so a killed run resumes without recomputing finished batches
(Algorithm 3's outer loop is embarrassingly restartable).

Approximate mode (adaptive source sampling, see ``repro.approx``):

  PYTHONPATH=src python -m repro.launch.bc_run --graph rmat --scale 10 \
      --approx 0.05,0.1 [--topk 10] [--strategy adaptive|uniform] \
      [--rule bernstein|normal] [--mesh DxM | PxDxM]

``--approx eps,delta`` replaces the exact all-sources sweep with the
epoch-doubling sampler and prints the top-k central vertices with their
confidence intervals.

``--mesh`` pins placement to the distributed Theorem 5.1 moments step:
``--mesh 2x4`` maps (data=2, model=4), ``--mesh 2x2x2`` maps (pod=2,
data=2, model=2). The axis-size product must equal the visible jax
device count. Without the flag the planner places automatically
(single host on one device, a (pod, data, model) decomposition when
more are visible).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.bc import METRICS, BCQuery, ExecutionConfig
from repro.bc import plan as bc_plan
from repro.bc import solve as bc_solve
from repro.core import brandes_bc, cc_ref, closeness_ref, khop_ref
from repro.graphs.generators import from_spec
from repro.launch.mesh import mesh_from_spec
from repro.train import checkpoint as ckpt_lib


def _query_from_args(args, mode: str, **kw) -> BCQuery:
    # CLI flags map onto the typed ExecutionConfig: "auto" / an absent
    # --use-kernel leave the field None, so the planner resolves it from
    # the calibrated regime model (and the measured kernel verdict).
    execution = ExecutionConfig(
        backend=None if args.backend == "auto" else args.backend,
        use_kernel=True if args.use_kernel else None)
    try:
        return BCQuery(mode=mode, n_b=args.nb or None, execution=execution,
                       seed=args.seed, iters=args.iters, metric=args.metric,
                       hops=args.hops, **kw)
    except ValueError as e:  # e.g. --metric khop without --hops
        raise SystemExit(f"[bc] bad query: {e}")


# --verify oracles per metric (components verifies against union-find)
_REFS = {"betweenness": brandes_bc, "closeness": closeness_ref,
         "components": cc_ref}


def run_approx(args, g):
    """Adaptive-sampling approximate BC + top-k report via repro.bc."""
    try:
        eps_s, delta_s = args.approx.split(",")
        eps, delta = float(eps_s), float(delta_s)
    except ValueError:
        raise SystemExit(
            f"--approx expects 'eps,delta' (e.g. 0.05,0.1), got "
            f"{args.approx!r}")
    if not (0 < eps < 1 and 0 < delta < 1):
        raise SystemExit(f"--approx eps and delta must be in (0, 1), got "
                         f"eps={eps} delta={delta}")
    try:
        mesh = mesh_from_spec(args.mesh) if args.mesh else None
    except ValueError as e:
        raise SystemExit(f"--mesh: {e}")
    query = _query_from_args(args, "approx", eps=eps, delta=delta,
                             strategy=args.strategy, rule=args.rule,
                             topk=args.topk,
                             max_samples=args.max_samples or None)
    print(f"[bc] approx mode: eps={eps} delta={delta} "
          f"strategy={args.strategy} rule={args.rule}"
          + (f" mesh={args.mesh}" if args.mesh else ""))
    try:
        pl = bc_plan(g, query, mesh=mesh)
    except ValueError as e:  # e.g. --mesh with --backend coo
        raise SystemExit(f"[bc] cannot plan this query: {e}")
    print(f"[bc] {pl.summary()} execution={pl.execution.describe()}"
          + (" [calibrated]" if pl.regime.get("calibrated") else ""))
    for note in pl.notes:
        print(f"[bc] note: {note}")

    def progress(epoch, tau, max_hw):
        print(f"[bc] epoch {epoch}: tau={tau} max_halfwidth={max_hw:.4f}")

    t0 = time.time()
    out = bc_solve(g, query, mesh=mesh, plan=pl, progress_cb=progress)
    res = out.approx
    dt = time.time() - t0
    teps = g.m * res.n_samples / dt
    print(f"[bc] approx done in {dt:.2f}s — {res.n_samples} samples "
          f"({res.n_epochs} epochs, converged={res.converged}) — "
          f"{teps:,.0f} TEPS (model)")
    ids = res.topk(args.topk)
    print(f"[bc] top-{args.topk} central vertices (λ̂ ± CI):")
    for v in ids:
        print(f"[bc]   v={int(v):6d}  {res.lam[v]:12.2f} ± "
              f"{res.halfwidth[v]:.2f}")
    if args.verify:
        if args.metric != "betweenness":
            # Non-BC metrics have their own normalization constants; the
            # ε bound below is the BC one, so check ranking quality only.
            ref = (khop_ref(g, hops=args.hops) if args.metric == "khop"
                   else _REFS[args.metric](g))
            top_ref = set(np.argsort(ref)[::-1][:args.topk].tolist())
            prec = len(top_ref & set(ids.tolist())) / args.topk
            print(f"[bc] vs {args.metric} oracle: top-{args.topk} "
                  f"precision {prec:.2f}")
            return res
        ref = brandes_bc(g)
        norm = g.n * max(g.n - 2, 1)
        err = float(np.abs(res.lam - ref).max()) / norm
        top_ref = set(np.argsort(ref)[::-1][:args.topk].tolist())
        prec = len(top_ref & set(ids.tolist())) / args.topk
        print(f"[bc] vs Brandes oracle: max normalized error {err:.4f} "
              f"(eps={eps}), top-{args.topk} precision {prec:.2f}")
        if err > eps:
            # Legitimate with probability ≤ delta (and the "normal" rule's
            # CIs are a CLT profile, not a concentration bound) — warn,
            # don't crash.
            print(f"[bc] WARNING: error {err:.4f} exceeds eps={eps} "
                  f"(expected with probability <= {delta})")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "uniform", "er"])
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--weighted", action="store_true")
    ap.add_argument("--nb", type=int, default=0,
                    help="batch size (0 = planner's cost-model pick)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "dense", "coo"],
                    help="relax backend (auto = planner's regime choice)")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--metric", default="betweenness", choices=list(METRICS),
                    help="graph metric to solve (MetricSpec registry)")
    ap.add_argument("--hops", type=int, default=0,
                    help="hop bound (edges) for --metric khop")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="check against the Brandes oracle (slow)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--approx", default="",
                    help="eps,delta — run adaptive-sampling approximate BC")
    ap.add_argument("--topk", type=int, default=10,
                    help="top-k query size for --approx")
    ap.add_argument("--strategy", default="adaptive",
                    choices=["adaptive", "uniform"])
    ap.add_argument("--rule", default="bernstein",
                    choices=["bernstein", "normal"])
    ap.add_argument("--max-samples", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="DxM or PxDxM axis sizes — pin placement to the "
                         "distributed moments step")
    ap.add_argument("--iters", type=int, default=0,
                    help="static sweep bound for mesh placement "
                         "(0 = graph size)")
    args = ap.parse_args(argv)

    if args.mesh and not args.approx:
        raise SystemExit("--mesh requires --approx (the exact mesh sweep "
                         "is examples/bc_distributed.py)")

    g = from_spec(args.graph, scale=args.scale, degree=args.degree,
                  weighted=args.weighted, seed=args.seed)
    g, _ = g.remove_isolated()
    print(f"[bc] graph {g.name}: n={g.n} m={g.m}")

    if args.approx:
        return run_approx(args, g)

    query = _query_from_args(args, "exact")
    start_batch = 0
    lam_acc = np.zeros(g.n)
    if args.ckpt_dir:
        step = ckpt_lib.latest_step(args.ckpt_dir)
        if step is not None:
            flat, _ = ckpt_lib.restore(args.ckpt_dir)
            lam_acc = flat["lam"]
            start_batch = step + 1
            # The sweep's source ranges are keyed by nb: a resume must
            # reuse the checkpoint's batch size, not whatever the planner
            # (or a changed --nb) would pick today. Checkpoints predating
            # the 'nb' key were written with the old fixed default
            # (args.nb or 64), so that is the only safe legacy fallback.
            ckpt_nb = int(flat["nb"]) if "nb" in flat else (args.nb or 64)
            if args.nb and args.nb != ckpt_nb:
                raise SystemExit(f"--nb {args.nb} mismatches checkpoint "
                                 f"batch size nb={ckpt_nb}")
            query = dataclasses.replace(query, n_b=ckpt_nb)
            print(f"[bc] resuming at batch {start_batch} (nb={ckpt_nb})")

    pl = bc_plan(g, query, n_devices=1)  # exact CLI sweep is single-host
    print(f"[bc] {pl.summary()} execution={pl.execution.describe()}"
          + (" [calibrated]" if pl.regime.get("calibrated") else ""))
    nb = pl.n_b
    total_batches = -(-g.n // nb)

    def progress(b, n_batches, lam):
        gb = start_batch + b  # global batch index across resumes
        if args.ckpt_dir:
            # Cumulative λ at the global step: a second kill + resume
            # restores the whole prefix, not just this run's segment.
            ckpt_lib.save(args.ckpt_dir, gb,
                          {"lam": lam + lam_acc, "batch": gb, "nb": nb})
        print(f"[bc] batch {gb + 1}/{total_batches}")

    t0 = time.time()
    sources = np.arange(start_batch * nb, g.n, dtype=np.int32)
    out = bc_solve(g, query, plan=pl, sources=sources, progress_cb=progress)
    lam = out.lam + lam_acc
    dt = time.time() - t0
    # TEPS as the paper counts it: every edge is traversed once per source
    teps = g.m * g.n / dt
    print(f"[bc] done in {dt:.2f}s — {teps:,.0f} TEPS (model)")
    top = np.argsort(lam)[::-1][:5]
    print("[bc] top-5 central vertices:", list(zip(top.tolist(),
                                                   np.round(lam[top], 2))))
    if args.verify:
        ref = (khop_ref(g, hops=args.hops) if args.metric == "khop"
               else _REFS[args.metric](g))
        np.testing.assert_allclose(lam, ref, rtol=1e-4, atol=1e-6)
        print(f"[bc] verified against {args.metric} host oracle")
    return lam


if __name__ == "__main__":
    main()
