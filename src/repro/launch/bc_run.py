"""Betweenness-centrality launcher (the paper's own workload).

  PYTHONPATH=src python -m repro.launch.bc_run --graph rmat --scale 8 \
      --degree 8 --nb 64 [--weighted] [--backend dense|coo] [--ckpt-dir d]

Per-batch checkpointing: the λ accumulator + batch index is saved after
every batch, so a killed run resumes without recomputing finished batches
(Algorithm 3's outer loop is embarrassingly restartable).

Approximate mode (adaptive source sampling, see ``repro.approx``):

  PYTHONPATH=src python -m repro.launch.bc_run --graph rmat --scale 10 \
      --approx 0.05,0.1 [--topk 10] [--strategy adaptive|uniform] \
      [--rule bernstein|normal] [--mesh DxM | PxDxM]

``--approx eps,delta`` replaces the exact all-sources sweep with the
epoch-doubling sampler and prints the top-k central vertices with their
confidence intervals.

``--mesh`` runs the sampling epochs through the distributed Theorem 5.1
moments step instead of the single-host one: ``--mesh 2x4`` maps (data=2,
model=4), ``--mesh 2x2x2`` maps (pod=2, data=2, model=2). The axis-size
product must equal the visible jax device count. Since the mesh step
returns per-vertex (Σδ, Σδ²), adaptive Bernstein/CLT stopping works
unchanged at mesh scale — no Hoeffding fallback.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import brandes_bc, mfbc
from repro.graphs.generators import erdos_renyi, rmat, uniform_random
from repro.train import checkpoint as ckpt_lib


def build_graph(args):
    if args.graph == "rmat":
        return rmat(args.scale, args.degree, weighted=args.weighted,
                    seed=args.seed)
    if args.graph == "uniform":
        return uniform_random(1 << args.scale, args.degree,
                              weighted=args.weighted, seed=args.seed)
    if args.graph == "er":
        return erdos_renyi(1 << args.scale, args.degree / (1 << args.scale),
                           weighted=args.weighted, seed=args.seed)
    raise ValueError(args.graph)


def build_mesh(spec: str):
    """``"DxM"`` → (data, model) mesh; ``"PxDxM"`` → (pod, data, model)."""
    import jax

    try:
        dims = tuple(int(d) for d in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh expects DxM or PxDxM (e.g. 2x4), got "
                         f"{spec!r}")
    if len(dims) == 2:
        names = ("data", "model")
    elif len(dims) == 3:
        names = ("pod", "data", "model")
    else:
        raise SystemExit(f"--mesh expects 2 or 3 axis sizes, got {spec!r}")
    n_dev = len(jax.devices())
    need = 1
    for d in dims:
        need *= d
    if need != n_dev:
        raise SystemExit(f"--mesh {spec} needs {need} devices, "
                         f"jax sees {n_dev}")
    return jax.make_mesh(dims, names)


def run_approx(args, g):
    """Adaptive-sampling approximate BC + top-k report (repro.approx)."""
    from repro.approx import approx_bc

    try:
        eps_s, delta_s = args.approx.split(",")
        eps, delta = float(eps_s), float(delta_s)
    except ValueError:
        raise SystemExit(
            f"--approx expects 'eps,delta' (e.g. 0.05,0.1), got "
            f"{args.approx!r}")
    if not (0 < eps < 1 and 0 < delta < 1):
        raise SystemExit(f"--approx eps and delta must be in (0, 1), got "
                         f"eps={eps} delta={delta}")
    mesh = build_mesh(args.mesh) if args.mesh else None
    print(f"[bc] approx mode: eps={eps} delta={delta} "
          f"strategy={args.strategy} rule={args.rule}"
          + (f" mesh={args.mesh}" if args.mesh else ""))

    def progress(epoch, tau, max_hw):
        print(f"[bc] epoch {epoch}: tau={tau} max_halfwidth={max_hw:.4f}")

    t0 = time.time()
    res = approx_bc(g, eps=eps, delta=delta, strategy=args.strategy,
                    rule=args.rule, backend=args.backend,
                    use_kernel=args.use_kernel, topk=args.topk,
                    n_b=args.nb or None,  # 0 = cost-model pick
                    seed=args.seed, mesh=mesh, iters=args.iters,
                    max_samples=args.max_samples or None,
                    progress_cb=progress)
    dt = time.time() - t0
    teps = g.m * res.n_samples / dt
    print(f"[bc] approx done in {dt:.2f}s — {res.n_samples} samples "
          f"({res.n_epochs} epochs, converged={res.converged}) — "
          f"{teps:,.0f} TEPS (model)")
    ids = res.topk(args.topk)
    print(f"[bc] top-{args.topk} central vertices (λ̂ ± CI):")
    for v in ids:
        print(f"[bc]   v={int(v):6d}  {res.lam[v]:12.2f} ± "
              f"{res.halfwidth[v]:.2f}")
    if args.verify:
        ref = brandes_bc(g)
        norm = g.n * max(g.n - 2, 1)
        err = float(np.abs(res.lam - ref).max()) / norm
        top_ref = set(np.argsort(ref)[::-1][:args.topk].tolist())
        prec = len(top_ref & set(ids.tolist())) / args.topk
        print(f"[bc] vs Brandes oracle: max normalized error {err:.4f} "
              f"(eps={eps}), top-{args.topk} precision {prec:.2f}")
        if err > eps:
            # Legitimate with probability ≤ delta (and the "normal" rule's
            # CIs are a CLT profile, not a concentration bound) — warn,
            # don't crash.
            print(f"[bc] WARNING: error {err:.4f} exceeds eps={eps} "
                  f"(expected with probability <= {delta})")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "uniform", "er"])
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--weighted", action="store_true")
    ap.add_argument("--nb", type=int, default=0,
                    help="batch size (0 = 64 exact / cost-model pick approx)")
    ap.add_argument("--backend", default="dense", choices=["dense", "coo"])
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="check against the Brandes oracle (slow)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--approx", default="",
                    help="eps,delta — run adaptive-sampling approximate BC")
    ap.add_argument("--topk", type=int, default=10,
                    help="top-k query size for --approx")
    ap.add_argument("--strategy", default="adaptive",
                    choices=["adaptive", "uniform"])
    ap.add_argument("--rule", default="bernstein",
                    choices=["bernstein", "normal"])
    ap.add_argument("--max-samples", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="DxM or PxDxM axis sizes — run --approx epochs "
                         "through the distributed moments step")
    ap.add_argument("--iters", type=int, default=0,
                    help="static sweep bound for --mesh (0 = graph size)")
    args = ap.parse_args(argv)

    if args.mesh and not args.approx:
        raise SystemExit("--mesh requires --approx (the exact mesh sweep "
                         "is examples/bc_distributed.py)")

    g = build_graph(args)
    g, _ = g.remove_isolated()
    print(f"[bc] graph {g.name}: n={g.n} m={g.m}")

    if args.approx:
        return run_approx(args, g)

    start_batch = 0
    lam_acc = {"lam": np.zeros(g.n), "batch": -1}
    if args.ckpt_dir:
        step = ckpt_lib.latest_step(args.ckpt_dir)
        if step is not None:
            flat, _ = ckpt_lib.restore(args.ckpt_dir)
            lam_acc["lam"] = flat["lam"]
            start_batch = step + 1
            print(f"[bc] resuming at batch {start_batch}")

    def progress(b, n_batches, lam):
        if args.ckpt_dir:
            ckpt_lib.save(args.ckpt_dir, b, {"lam": lam, "batch": b})
        print(f"[bc] batch {b + 1}/{n_batches}")

    t0 = time.time()
    nb = args.nb or 64
    n_batches = -(-g.n // nb)
    sources = np.arange(start_batch * nb, g.n, dtype=np.int32)
    lam = mfbc(g, n_b=nb, backend=args.backend,
               use_kernel=args.use_kernel, sources=sources,
               progress_cb=progress)
    lam = lam + lam_acc["lam"]
    dt = time.time() - t0
    # TEPS as the paper counts it: every edge is traversed once per source
    teps = g.m * g.n / dt
    print(f"[bc] done in {dt:.2f}s — {teps:,.0f} TEPS (model)")
    top = np.argsort(lam)[::-1][:5]
    print("[bc] top-5 central vertices:", list(zip(top.tolist(),
                                                   np.round(lam[top], 2))))
    if args.verify:
        ref = brandes_bc(g)
        np.testing.assert_allclose(lam, ref, rtol=1e-4, atol=1e-6)
        print("[bc] verified against Brandes oracle")
    return lam


if __name__ == "__main__":
    main()
