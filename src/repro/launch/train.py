"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-27b \
      [--smoke] [--steps 200] [--ckpt-dir ckpts/run1] [--compress topk]

On this CPU container the full configs cannot execute; ``--smoke`` runs the
reduced config end-to-end (the quickstart example trains a ~100M-class
model this way). On a real TPU pod the same code path runs the full config
under the production mesh (``--mesh single|multi``).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import LMDataConfig, LMPipeline
from repro.optim import adamw
from repro.optim.grad_compress import CompressConfig
from repro.sharding.rules import NO_SHARDING, make_policy
from repro.train.fault import ChaosConfig, Supervisor
from repro.train.train_lib import make_lm_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--compress", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject WorkerFailure at these steps (chaos test)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    assert spec.family == "lm", "train.py drives LM archs; see bc_run.py"
    cfg = spec.config(smoke=args.smoke)
    batch = args.batch or (8 if args.smoke else 256)
    seq = args.seq or (128 if args.smoke else 4096)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                                total_steps=args.steps)
    comp = CompressConfig(kind=args.compress)
    init_fn, step_fn = make_lm_train_step(
        cfg, opt_cfg, NO_SHARDING,
        comp if args.compress != "none" else None)
    pipe = LMPipeline(LMDataConfig(vocab=cfg.vocab, batch=batch, seq=seq))

    state = init_fn(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={batch} seq={seq}")

    losses = []

    def do_step(st, step):
        t0 = time.time()
        st, metrics = step_fn(st, pipe.batch(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            tput = batch * seq / (time.time() - t0)
            print(f"[train] step {step} loss {loss:.4f} "
                  f"tok/s {tput:,.0f}")
        return st

    if args.ckpt_dir:
        sup = Supervisor(args.ckpt_dir, save_every=args.save_every)
        chaos = ChaosConfig(fail_at_steps=tuple(args.fail_at)) \
            if args.fail_at else None
        state = sup.run(init_state=state, step_fn=do_step,
                        n_steps=args.steps, chaos=chaos)
    else:
        for step in range(args.steps):
            state = do_step(state, step)

    print(f"[train] done: first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss must decrease"
    return losses


if __name__ == "__main__":
    main()
