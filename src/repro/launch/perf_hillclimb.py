"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Three cells, chosen per the methodology (worst roofline fraction, most
collective-bound, most paper-representative):

  gcn2d   — gcn-cora x ogb_products: replace the GSPMD 1D-variant-C
            allreduce with the paper's 2D edge partition (shard_map).
  qwen3ep — qwen3 x train_4k (multi): shard experts over (pod, model)
            — EP degree 32 halves the per-device FSDP gather bytes.
  bcblock — mfbc_paper x bc_web_256k: relax block-size sweep (measured)
            + Pallas kernel tile-traffic model (the TPU target numbers).

Each writes results/perf_iters/<name>.json with before/after terms.

Usage: PYTHONPATH=src python -m repro.launch.perf_hillclimb --which all

The 512-device fake topology is forced in ``main()`` (it must run
before jax initializes); importing this module for its measurement
scaffolding (``_compile_stats``, the sweeps) does NOT touch the device
count — ``repro.launch.calibrate`` reuses the helpers in-process.
"""
import argparse
import json
import os
import time


def _write(name, record):
    os.makedirs("results/perf_iters", exist_ok=True)
    with open(f"results/perf_iters/{name}.json", "w") as f:
        json.dump(record, f, indent=1)
    print(f"[perf] wrote results/perf_iters/{name}.json")


def _compile_stats(fn, args, donate=()):
    import jax

    from repro import compat

    from repro.roofline.hlo_parse import collective_bytes

    compiled = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
    cost = compat.cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes": coll["wire_bytes"],
        "messages": coll["messages"],
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
    }


def hillclimb_gcn2d():
    """ogb_products on the multi-pod mesh: baseline vs 2D edge partition."""
    import jax

    from repro import compat
    import jax.numpy as jnp

    from repro.launch.mesh import make_production_mesh
    from repro.models.gnn_dist import abstract_inputs, build_gcn2d_loss, \
        make_grid

    mesh = make_production_mesh(multi_pod=True)
    n, e, d_in, dh, classes = 2449029, 61859140, 100, 16, 47
    grid = make_grid(mesh, n, e)
    loss2d = build_gcn2d_loss(mesh, grid, n_layers=2)
    params = {"w": [jax.ShapeDtypeStruct((d_in, dh), jnp.float32),
                    jax.ShapeDtypeStruct((dh, classes), jnp.float32)]}
    ab = abstract_inputs(mesh, grid, d_in)
    args = (params, ab["x"], ab["src"], ab["dst"], ab["coef"],
            ab["labels"], ab["mask"])

    with compat.set_mesh(mesh):
        after = _compile_stats(jax.grad(loss2d), args)

    baseline_path = "results/dryrun/gcn-cora__ogb_products__multi.json"
    before = json.load(open(baseline_path))
    rec = {
        "cell": "gcn-cora x ogb_products x multi",
        "hypothesis": ("GSPMD lowers segment_sum message passing as the "
                       "paper's 1D variant C (full-size partial + "
                       "all-reduce, ~2|H| bytes/dev/layer); the 2D edge "
                       "partition should cut collectives ~R*C*2/(R+C)=21x "
                       "(R=32, C=16)"),
        "before_wire_bytes": before["collectives"]["wire_bytes"],
        "after_wire_bytes": after["wire_bytes"],
        "win": before["collectives"]["wire_bytes"]
        / max(after["wire_bytes"], 1.0),
        "before": {k: before.get(k) for k in
                   ("flops_per_device", "bytes_accessed_per_device")},
        "after": after,
        "note": ("before = full train step (loss+grad+adamw); after = "
                 "loss+grad (optimizer params replicated+tiny). Grad "
                 "psum of the replicated weights over 512 devices is "
                 "included in 'after'."),
    }
    _write("gcn2d", rec)
    return rec


def hillclimb_qwen3_ep():
    """qwen3 train_4k multi: experts over (pod, model) (EP degree 32)."""
    from repro.launch.dryrun import run_one

    rec_after = run_one("qwen3-moe-235b-a22b", "train_4k", "multi",
                        "results/perf_iters/qwen3ep_raw",
                        policy_overrides={"expert": ("pod", "model"),
                                          "fsdp": ("data",)})
    before = json.load(open(
        "results/dryrun/qwen3-moe-235b-a22b__train_4k__multi.json"))
    rec = {
        "cell": "qwen3-moe x train_4k x multi",
        "hypothesis": ("FSDP gathers of expert weights dominate the wire "
                       "(302MB/layer/dev at EP=16); sharding experts over "
                       "(pod, model) doubles EP to 32 and should halve "
                       "per-device gathered expert bytes"),
        "before_wire_bytes": before["collectives"]["wire_bytes"],
        "after_wire_bytes": rec_after["collectives"]["wire_bytes"],
        "win": before["collectives"]["wire_bytes"]
        / max(rec_after["collectives"]["wire_bytes"], 1.0),
        "before_mem": before["memory"],
        "after_mem": rec_after["memory"],
    }
    _write("qwen3ep", rec)
    return rec


def hillclimb_bc_blocks():
    """mfbc_paper bc_web_256k: measured block sweep + kernel tile model."""
    import jax

    from repro import compat

    from repro.configs import get_arch
    from repro.core import dist_bc
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.hlo_parse import collective_bytes
    from repro.roofline import constants as C

    mesh = make_production_mesh(multi_pod=True)
    n, nb, iters = 262144, 8192, 8

    def measure(block):
        cfg = dist_bc.BCMeshConfig(n=n, nb=nb, iters_bf=iters,
                                   iters_br=iters, pod_axis="pod",
                                   use_kernel=False, block=block,
                                   unroll=True)
        step = dist_bc.build_mfbc_step(mesh, cfg)
        sh = dist_bc.input_shardings(mesh, cfg)
        import jax.numpy as jnp
        sds = jax.ShapeDtypeStruct
        args = (sds((n, n), jnp.float32, sharding=sh[0]),
                sds((n, n), jnp.float32, sharding=sh[1]),
                sds((nb,), jnp.int32, sharding=sh[2]),
                sds((nb,), jnp.bool_, sharding=sh[3]))
        with compat.set_mesh(mesh):
            compiled = jax.jit(step).lower(*args).compile()
        cost = compat.cost_analysis(compiled)
        return {"block": block,
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                "flops": float(cost.get("flops", 0.0)),
                "wire_bytes": collective_bytes(compiled.as_text())
                ["wire_bytes"]}

    sweep = [measure(b) for b in (256, 1024, 4096)]

    # Pallas kernel tile-traffic model (TPU target; kernels validated for
    # correctness in interpret mode, perf from first principles):
    # per relax per device, tiles (bm, bk, bn):
    #   F bytes = nb_loc*n_loc*8 * (n_loc/bn)   [two f32 arrays: w, m]
    #   A bytes = n_loc*n_loc*4 * (nb_loc/bm)
    #   C bytes = nb_loc*n_loc*8 (written once; accumulators live in VMEM)
    nb_loc, n_loc = nb // 2, n // 16  # (pod, data) rows; model cols
    relaxes = 2 * (iters + 1) + 1

    def kernel_model(bm, bk, bn):
        f = nb_loc * n_loc * 8 * (n // 16 // bn)
        a = (n // 16) * (n // 16) * 4 * (nb_loc // bm)
        c = nb_loc * n_loc * 8
        vmem = (bm * bk * 2 + bk * bn + bm * bn * 2) * 4
        ops = 4.0 * nb_loc * (n // 16) * (n // 16)  # min-plus+tie updates
        return {"tiles": (bm, bk, bn),
                "hbm_bytes_per_relax": f + a + c,
                "hbm_bytes_total": (f + a + c) * relaxes,
                "t_memory_s": (f + a + c) * relaxes / C.HBM_BW,
                "t_compute_s": ops * relaxes / 3.9e12,  # VPU rate
                "vmem_bytes": vmem}

    kmodel = [kernel_model(*t) for t in
              ((128, 128, 128), (256, 256, 256), (512, 512, 512),
               (512, 1024, 512))]

    rec = {
        "cell": "mfbc_paper x bc_web_256k x multi",
        "hypothesis": ("the jnp fallback relax materializes candidate "
                       "blocks in HBM; block size trades candidate-buffer "
                       "traffic vs accumulator round trips. On the TPU "
                       "target the Pallas kernel keeps both accumulators "
                       "in VMEM: traffic = F*(n/bn) + A*(nb/bm) + C; "
                       "512-tiles should drop the memory term ~100x vs "
                       "the fallback and make the cell VPU-compute-bound"),
        "measured_block_sweep": sweep,
        "kernel_tile_model": kmodel,
        "hw": {"hbm_bw": C.HBM_BW, "vpu_ops": 3.9e12},
    }
    _write("bcblock", rec)
    return rec


def main():
    # Must precede jax initialization; kept out of module scope so
    # importing the scaffolding never mutates the process's devices.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", default="all",
                    choices=["all", "gcn2d", "qwen3ep", "bcblock"])
    args = ap.parse_args()
    if args.which in ("all", "gcn2d"):
        hillclimb_gcn2d()
    if args.which in ("all", "qwen3ep"):
        hillclimb_qwen3_ep()
    if args.which in ("all", "bcblock"):
        hillclimb_bc_blocks()


if __name__ == "__main__":
    main()
