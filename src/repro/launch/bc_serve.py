"""BC gateway launcher: serve registered graphs over HTTP.

  PYTHONPATH=src python -m repro.launch.bc_serve \
      --graph rmat:10:8 --graph ws:8:4 [--port 8080] \
      [--horizon 5.0] [--overload reject|degrade] [--degrade-eps 0.2] \
      [--slots 4] [--no-cache-refine]

Each ``--graph kind:scale:degree`` spec is generated, registered with a
checkpointing ``BCService``, and served by ``repro.serve.BCGateway`` on
``--port`` (0 picks an ephemeral port, printed on startup). Ctrl-C
shuts down cleanly. Try it::

  curl -s localhost:8080/v1/graphs
  curl -s -XPOST localhost:8080/v1/bc \
      -d '{"graph": "rmat:10:8", "eps": 0.1, "priority": "interactive"}'
  curl -s localhost:8080/v1/bc/0
  curl -s localhost:8080/v1/metrics
"""
from __future__ import annotations

import argparse
import time

from repro.graphs.generators import from_spec
from repro.serve import BCGateway, BCService, GatewayConfig, start_gateway


def _parse_graph(spec: str):
    kind, scale, degree = (spec.split(":") + ["8"])[:3]
    return spec, from_spec(kind, scale=int(scale), degree=float(degree))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", action="append", default=None,
                    help="kind:scale[:degree], repeatable "
                         "(default rmat:8:8)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--horizon", type=float, default=5.0,
                    help="admission horizon in predicted seconds")
    ap.add_argument("--overload", choices=("reject", "degrade"),
                    default="reject")
    ap.add_argument("--degrade-eps", type=float, default=0.2)
    ap.add_argument("--cache-entries", type=int, default=256)
    ap.add_argument("--no-cache-refine", action="store_true",
                    help="treat looser-ε cache entries as misses")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--run-for", type=float, default=None,
                    help="serve for N seconds then exit (tests/demos)")
    args = ap.parse_args(argv)

    graphs = dict(_parse_graph(s) for s in (args.graph or ["rmat:8:8"]))
    service = BCService(graphs, n_slots=args.slots, checkpoints=True)
    gateway = BCGateway(service, GatewayConfig(
        horizon_s=args.horizon, overload=args.overload,
        degrade_eps=args.degrade_eps, cache_entries=args.cache_entries,
        refine=not args.no_cache_refine))
    server = start_gateway(gateway, host=args.host, port=args.port)
    for name, g in graphs.items():
        print(f"  graph {name}: n={g.n} m={g.m} "
              f"digest={service.digest(name)[:12]}")
    print(f"bc gateway listening on {server.url} "
          f"(horizon={args.horizon}s overload={args.overload})")
    try:
        if args.run_for is not None:
            time.sleep(args.run_for)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
        print("gateway closed")


if __name__ == "__main__":
    main()
