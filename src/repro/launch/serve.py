"""Serving launcher: batched prefill + decode with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as T
from repro.sharding.rules import NO_SHARDING


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.config(smoke=args.smoke)
    max_len = args.prompt_len + args.gen
    params = T.init_params(cfg, jax.random.key(0))
    cache = T.init_cache(cfg, args.batch, max_len)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len)),
                         jnp.int32)

    prefill = jax.jit(lambda p, t, c: T.prefill(cfg, p, t, c))
    decode = jax.jit(lambda p, t, pos, c: T.decode_step(cfg, p, t, pos, c))

    t0 = time.time()
    logits, cache = prefill(params, prompt, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, jnp.int32(args.prompt_len + i),
                               cache)
        if args.temperature > 0:
            key = jax.random.key(i)
            tok = jax.random.categorical(
                key, logits[:, -1] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={gen.shape[1]} "
          f"tok/s {args.batch * gen.shape[1] / dt:,.1f}")
    print("[serve] sample token ids:", np.asarray(gen[0,:12]))
    assert gen.shape == (args.batch, args.gen)
    return np.asarray(gen)


if __name__ == "__main__":
    main()
