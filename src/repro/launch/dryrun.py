import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, record memory/cost/collective analysis.

The two lines above MUST stay first (before any other import): jax locks
the device count on first initialization, and the dry-run needs 512
placeholder CPU devices to build the production meshes. Smoke tests and
benchmarks must NOT import this module.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b \
      --shape train_4k --mesh multi --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
      # runs every cell in a fresh subprocess each (memory isolation),
      # skipping cells whose JSON is already present.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback


def cell_filename(arch: str, shape: str, mesh_kind: str) -> str:
    return f"{arch}__{shape}__{mesh_kind}.json"


def run_one(arch_id: str, shape_id: str, mesh_kind: str, out_dir: str,
            policy_overrides=None) -> dict:
    import jax

    from repro import compat

    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.hlo_parse import collective_bytes
    from repro.sharding.rules import make_policy

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    spec = get_arch(arch_id)
    cell = spec.cells()[shape_id]
    policy = make_policy(mesh, seq_shard=(spec.family == "lm"),
                         overrides=policy_overrides)
    if spec.family == "lm":
        # Production program: scan-over-layers (this is what must compile
        # and what memory_analysis describes).
        bundle = spec.build(cell, policy)
    elif spec.family == "bc":
        bundle = spec.build(cell, policy, unroll=True)
    else:
        bundle = spec.build(cell, policy)

    def _compile(b):
        with compat.set_mesh(mesh):
            jitted = jax.jit(b.fn, donate_argnums=b.donate)
            lowered = jitted.lower(*b.abstract_args)
            return lowered.compile()

    compiled = _compile(bundle)
    t_lower = 0.0
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    trips = dict(bundle.trip_counts)
    trip_map = {"*": trips.get("while", 1)}
    coll = collective_bytes(compiled.as_text(), trip_map)

    if spec.family == "lm":
        # Calibration: per-layer exact cost from two tiny unrolled builds
        # (scan bodies are counted once by cost_analysis; the production
        # layer count is recovered as outside + L x body).
        L = spec.config().n_layers

        def measure(k):
            bk = spec.build(cell, policy, unroll=True, layers_override=k)
            ck = _compile(bk)
            cost_k = compat.cost_analysis(ck)
            coll_k = collective_bytes(ck.as_text(), {})
            return (float(cost_k.get("flops", 0.0)),
                    float(cost_k.get("bytes accessed", 0.0)), coll_k)

        f1, b1, c1 = measure(1)
        f2, b2, c2 = measure(2)
        cost = dict(cost)
        cost["flops"] = f1 + (L - 1) * (f2 - f1)
        cost["bytes accessed"] = b1 + (L - 1) * (b2 - b1)
        coll = {k: c1.get(k, 0.0) + (L - 1) * (c2.get(k, 0.0) - c1.get(k, 0.0))
                for k in set(c1) | set(c2)}
        coll = {k: max(v, 0.0) for k, v in coll.items()}
        trip_map = {"calibrated": L}

    record = {
        "arch": arch_id,
        "shape": shape_id,
        "mesh": mesh_kind,
        "n_devices": int(n_dev),
        "ok": True,
        "seconds_lower": round(t_lower, 2),
        "seconds_compile": round(t_compile, 2),
        "model_flops": bundle.model_flops,
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "trip_counts": trips,
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_filename(arch_id, shape_id, mesh_kind))
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[dryrun] OK {arch_id} x {shape_id} x {mesh_kind}: "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
          f"peak/dev {record['memory']['peak_bytes']/2**30:.2f} GiB "
          f"flops/dev {record['flops_per_device']:.3e}")
    return record


def run_all(out_dir: str, mesh_kinds, only=None, timeout=3000):
    """Each cell in a fresh subprocess (isolation + incremental caching)."""
    from repro.configs import all_cells

    cells = all_cells()
    failures = []
    for mesh_kind in mesh_kinds:
        for arch_id, shape_id in cells:
            if only and arch_id not in only:
                continue
            path = os.path.join(out_dir, cell_filename(arch_id, shape_id,
                                                       mesh_kind))
            if os.path.exists(path):
                print(f"[dryrun] cached {arch_id} x {shape_id} x {mesh_kind}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch_id, "--shape", shape_id,
                   "--mesh", mesh_kind, "--out", out_dir]
            print(f"[dryrun] spawn {' '.join(cmd[3:])}")
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=timeout)
            sys.stdout.write(r.stdout[-2000:])
            if r.returncode != 0:
                failures.append((arch_id, shape_id, mesh_kind))
                err = {"arch": arch_id, "shape": shape_id, "mesh": mesh_kind,
                       "ok": False, "error": r.stderr[-4000:]}
                with open(path + ".fail", "w") as f:
                    json.dump(err, f, indent=1)
                print(f"[dryrun] FAIL {arch_id} x {shape_id} x {mesh_kind}\n"
                      + r.stderr[-1500:])
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="multi", choices=["single", "multi",
                                                        "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        failures = run_all(args.out, kinds, only=args.only)
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("[dryrun] all cells OK")
        return
    for k in kinds:
        run_one(args.arch, args.shape, k, args.out)


if __name__ == "__main__":
    main()
