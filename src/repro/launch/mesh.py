"""Mesh construction helpers (production, debug, and CLI-spec meshes).

Functions only, and jax is imported lazily *inside* them, so importing
this module never touches jax device state — callers that must set
``XLA_FLAGS`` (fake host devices) before jax initializes can import the
jax-free ``parse_mesh_spec`` first (``benchmarks/bc_approx.py`` does).

Production: 16x16 = 256 chips (data, model); multi-pod: 2 pods x 256 =
512 chips (pod, data, model). The ``pod`` axis is MFBC's replication
factor c (DESIGN.md §4) and plain DP for the LM archs.

``mesh_from_spec("DxM" | "PxDxM")`` is the shared CLI entry point
(``launch.bc_run --mesh``, benchmarks): 2 sizes map (data, model),
3 map (pod, data, model), and the product must equal the visible jax
device count.
"""
from __future__ import annotations

from typing import Tuple


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CI-scale multi-device runs (8 host devices)."""
    import jax

    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def parse_mesh_spec(spec: str) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """``"DxM"`` → ((D, M), (data, model)); ``"PxDxM"`` adds the pod axis.

    jax-free on purpose: callers validate the device count *before*
    anything imports jax (to set ``XLA_FLAGS``). Raises ``ValueError``
    on malformed specs.
    """
    try:
        dims = tuple(int(d) for d in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"mesh spec expects DxM or PxDxM (e.g. 2x4), "
                         f"got {spec!r}") from None
    if len(dims) == 2:
        names: Tuple[str, ...] = ("data", "model")
    elif len(dims) == 3:
        names = ("pod", "data", "model")
    else:
        raise ValueError(f"mesh spec expects 2 or 3 axis sizes, got {spec!r}")
    if min(dims) < 1:
        raise ValueError(f"mesh spec axis sizes must be positive, got "
                         f"{spec!r}")
    return dims, names


def mesh_from_spec(spec: str):
    """Build the jax mesh a CLI ``--mesh`` spec names, validating the
    axis-size product against the visible device count."""
    import jax

    dims, names = parse_mesh_spec(spec)
    need = 1
    for d in dims:
        need *= d
    n_dev = len(jax.devices())
    if need != n_dev:
        raise ValueError(f"mesh {spec!r} needs {need} devices, "
                         f"jax sees {n_dev}")
    return jax.make_mesh(dims, names)
