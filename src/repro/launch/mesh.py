"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips (data, model);
multi-pod: 2 pods x 256 = 512 chips (pod, data, model). The ``pod`` axis is
MFBC's replication factor c (DESIGN.md §4) and plain DP for the LM archs.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CI-scale multi-device runs (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
