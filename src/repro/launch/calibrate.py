"""Measure real batch-step times and fit the α-β cost constants.

The analytic regime model (``spgemm.autotune.choose_bc_regime``) prices
the TPU target from first-principles hardware constants; on the host a
run actually executes on it can be off by orders of magnitude (CPU CI:
predicted 0.059s vs measured ~4.1s per run). This module closes the
measurement loop the ISSUE's KADABRA citation demands — the sampling
layer's decisions only pay off when the per-step cost underneath them
is real:

1. build one executor per execution variant (dense / dense+Pallas-kernel
   / COO) on an R-MAT calibration graph, via the same
   ``BCPlanner`` → ``build_executor`` path production runs use;
2. time warm ``step`` calls at two batch sizes (best-of-``reps``, after
   a compile+warmup call);
3. fit ``t(n_b) = α + W(n_b)/rate`` per variant, where
   ``W(n_b) = 2·est_iters·relax_ops(backend, n, m, n_b)`` is the
   planner's *own* priced work for one batch (``BCPlanner._est_iters``,
   ``cost_model.relax_ops``) — deriving the rate through the planner's
   iteration heuristic makes the heuristic's error cancel when the plan
   multiplies it back in, so ``predicted_seconds`` tracks measured
   wall-clock on same-family graphs;
4. persist a ``spgemm.cost_model.Calibration`` to
   ``results/cost_calibration.json`` (``--out`` / ``save_calibration``),
   where ``load_calibration`` feeds it back to ``BCPlanner``,
   ``choose_bc_regime`` and ``choose_sample_batch``.

``benchmarks/bc_approx.py`` self-calibrates with ``calibrate()`` on its
own benchmark graph before planning, so the recorded
``predicted_seconds`` vs measured comparison ``tools/check_bench.py``
gates on (≤ 2× drift) is an honest closed loop.

Usage::

    PYTHONPATH=src python -m repro.launch.calibrate \
        --scale 10 --avg-degree 16 --nb 16,64 --reps 2 \
        --out results/cost_calibration.json
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.spgemm.cost_model import (Calibration, StepRates, relax_ops,
                                     save_calibration, variant_key)

#: (backend, use_kernel) pairs calibrated by default.
DEFAULT_VARIANTS: Tuple[Tuple[str, bool], ...] = (
    ("dense", False), ("dense", True), ("coo", False), ("csr", False))


def _measure_step_seconds(g, backend: str, use_kernel: bool, nb: int,
                          reps: int) -> float:
    """Warm wall-clock seconds of one padded ``step`` call (best of reps)."""
    from repro.bc.config import ExecutionConfig
    from repro.bc.executor import build_executor
    from repro.bc.planner import BCPlanner
    from repro.bc.query import BCQuery

    q = BCQuery(mode="approx", n_b=nb,
                execution=ExecutionConfig(backend=backend,
                                          use_kernel=use_kernel,
                                          placement="single_host"))
    plan = BCPlanner(calibration=None).plan(g, q, n_devices=1)
    ex = build_executor(g, plan)
    rng = np.random.default_rng(0)
    src = rng.integers(0, g.n, size=nb).astype(np.int32)
    valid = np.ones(nb, bool)
    ex.step(src, valid)  # compile + warm the caches
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        ex.step(src, valid)
        best = min(best, time.perf_counter() - t0)
    return best


def _fit_rates(backend: str, n: int, m: int, est_iters: int,
               t_by_nb: Dict[int, float]) -> StepRates:
    """Fit (rate, overhead) from measured batch times at two sizes.

    Two points on ``t(n_b) = α + W(n_b)/rate``: the slope over the
    priced work gives the throughput, the intercept (clamped ≥ 0 — a
    negative intercept is measurement noise) the fixed per-call α.
    Degenerate measurements (non-increasing time) fall back to a pure
    throughput fit through the larger point.
    """
    (nb1, t1), (nb2, t2) = sorted(t_by_nb.items())[:2]
    # est_iters is forwarded so the CSR variant's occupancy-amortized
    # per-iteration work is priced with the same iteration heuristic at
    # fit and predict time (dense/COO ignore it).
    w1 = 2.0 * est_iters * relax_ops(backend, n, m, nb1,
                                     est_iters=est_iters)
    w2 = 2.0 * est_iters * relax_ops(backend, n, m, nb2,
                                     est_iters=est_iters)
    if t2 > t1 > 0 and w2 > w1:
        rate = (w2 - w1) / (t2 - t1)
        overhead = max(0.0, t1 - w1 / rate)
    else:
        rate = w2 / max(t2, 1e-9)
        overhead = 0.0
    return StepRates(ops_per_s=rate, overhead_s=overhead)


def calibrate(g, *, nb_pair: Tuple[int, int] = (16, 64), reps: int = 2,
              variants: Sequence[Tuple[str, bool]] = DEFAULT_VARIANTS,
              verbose: bool = False) -> Calibration:
    """Measure ``variants`` on graph ``g`` and fit a ``Calibration``."""
    import jax

    from repro.bc.planner import BCPlanner

    est_iters = BCPlanner._est_iters(g.n, weighted=bool(np.any(g.w != 1.0)),
                                     iters=0)
    rates: Dict[str, StepRates] = {}
    measured: Dict[str, Dict[int, float]] = {}
    for backend, use_kernel in variants:
        t_by_nb: Dict[int, float] = {}
        for nb in sorted(set(nb_pair)):
            t_by_nb[nb] = _measure_step_seconds(g, backend, use_kernel,
                                                nb, reps)
            if verbose:
                print(f"[calibrate] {variant_key(backend, use_kernel)} "
                      f"n_b={nb}: {t_by_nb[nb]:.4f}s")
        key = variant_key(backend, use_kernel)
        measured[key] = t_by_nb
        if len(t_by_nb) == 1:  # degenerate pair: pure throughput fit
            (nb,) = t_by_nb
            t_by_nb = {0: 0.0, nb: t_by_nb[nb]}
        rates[key] = _fit_rates(backend, g.n, g.m, est_iters, t_by_nb)
    return Calibration(
        rates=rates,
        meta={
            "jax_backend": jax.default_backend(),
            "graph": {"n": int(g.n), "m": int(g.m)},
            "n_b": sorted(set(nb_pair)),
            "est_iters": int(est_iters),
            "reps": int(reps),
            "measured_step_s": {k: {str(nb): t for nb, t in v.items()}
                                for k, v in measured.items()},
            "measured_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                          time.gmtime()),
        })


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", type=int, default=10,
                    help="R-MAT scale of the calibration graph")
    ap.add_argument("--avg-degree", type=int, default=16)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--nb", default="16,64",
                    help="comma-separated batch-size pair to fit over")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--skip-kernel", action="store_true",
                    help="skip the Pallas dense-kernel variant (slow in "
                         "interpret mode on CPU)")
    ap.add_argument("--out", default=None,
                    help="output path (default results/cost_calibration.json"
                         " or $REPRO_BC_CALIBRATION)")
    args = ap.parse_args(argv)

    from repro.graphs.generators import rmat

    g = rmat(args.scale, args.avg_degree, seed=args.seed)
    nb_pair = tuple(int(x) for x in args.nb.split(","))
    variants = [v for v in DEFAULT_VARIANTS
                if not (args.skip_kernel and v[1])]
    cal = calibrate(g, nb_pair=nb_pair, reps=args.reps, variants=variants,
                    verbose=True)
    path = save_calibration(cal, args.out)
    print(f"[calibrate] wrote {path}")
    for key, r in sorted(cal.rates.items()):
        print(f"[calibrate]   {key}: {r.ops_per_s:.3e} ops/s "
              f"(+{r.overhead_s * 1e3:.2f} ms/call)")
    print(f"[calibrate] kernel_pays={cal.kernel_pays()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
