"""repro — MFBC: communication-efficient betweenness centrality on TPU pods.

Reproduction + extension of Solomonik, Besta, Vella, Hoefler,
"Scaling Betweenness Centrality using Communication-Efficient Sparse
Matrix Multiplication" (SC'17).
"""

__version__ = "1.0.0"
