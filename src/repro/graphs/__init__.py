from repro.graphs.formats import Graph, coo_to_csr, coo_to_dense, pad_edges
from repro.graphs.generators import (erdos_renyi, from_spec, rmat,
                                     uniform_random, ring_of_cliques,
                                     star_graph)

__all__ = [
    "Graph",
    "coo_to_csr",
    "coo_to_dense",
    "pad_edges",
    "erdos_renyi",
    "from_spec",
    "rmat",
    "uniform_random",
    "ring_of_cliques",
    "star_graph",
]
