"""Neighbor sampler for sampled-training GNN cells (minibatch_lg).

GraphSAGE-style layered uniform fanout sampling from a CSR adjacency
[arXiv:1706.02216]. Produces *static-shape* padded subgraph arrays (jit
requirement): the node budget is seeds·(1 + f₁ + f₁·f₂ …) and the edge
budget seeds·f₁·(1 + f₂ …); real counts are carried in masks. The dummy
node sits at index ``n_budget`` (one-past-the-end), matching the GNN
forward conventions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.graphs.formats import Graph, coo_to_csr


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    batch_nodes: int
    fanout: Tuple[int, ...]  # e.g. (15, 10)

    @property
    def node_budget(self) -> int:
        n, mult = self.batch_nodes, 1
        total = self.batch_nodes
        for f in self.fanout:
            mult *= f
            total += self.batch_nodes * mult
        return total

    @property
    def edge_budget(self) -> int:
        total, mult = 0, 1
        for f in self.fanout:
            mult *= f
            total += self.batch_nodes * mult
        return total


class NeighborSampler:
    def __init__(self, g: Graph, spec: SamplerSpec, seed: int = 0):
        self.spec = spec
        self.n = g.n
        self.indptr, self.indices, _ = coo_to_csr(g)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> Dict[str, np.ndarray]:
        """Returns padded subgraph with *local* node ids.

        keys: node_ids (Nb+1,) original ids (pad = n), src/dst (Eb,) local,
        edge_pad (Eb,) bool, seed_mask (Nb+1,) bool.
        """
        spec = self.spec
        assert seeds.shape[0] == spec.batch_nodes
        nodes = [seeds.astype(np.int64)]
        edges_src, edges_dst = [], []
        frontier = seeds.astype(np.int64)
        base = 0
        for f in spec.fanout:
            deg = self.indptr[frontier + 1] - self.indptr[frontier]
            # uniform sample f neighbors per frontier node (with replacement;
            # degree-0 nodes sample the dummy)
            r = self.rng.integers(0, 1 << 62, size=(frontier.shape[0], f))
            idx = np.where(deg[:, None] > 0, r % np.maximum(deg[:, None], 1), -1)
            nbr = np.where(
                idx >= 0,
                self.indices[self.indptr[frontier][:, None] + np.maximum(idx, 0)],
                -1)
            nodes.append(nbr.reshape(-1))
            new_base = sum(x.shape[0] for x in nodes[:-1])
            edges_src.append(new_base + np.arange(nbr.size))
            edges_dst.append(base + np.repeat(np.arange(frontier.shape[0]), f))
            base = new_base
            frontier = np.maximum(nbr.reshape(-1), 0)
        node_ids = np.concatenate(nodes)
        src = np.concatenate(edges_src)
        dst = np.concatenate(edges_dst)
        pad = node_ids[src] < 0  # sampled from degree-0: dummy edge
        nb = spec.node_budget
        node_ids_p = np.full(nb + 1, self.n, dtype=np.int64)
        node_ids_p[:node_ids.shape[0]] = np.where(node_ids < 0, self.n,
                                                  node_ids)
        src_p = np.where(pad, nb, src).astype(np.int32)
        dst_p = dst.astype(np.int32)
        seed_mask = np.zeros(nb + 1, bool)
        seed_mask[:spec.batch_nodes] = True
        return {
            "node_ids": node_ids_p,
            "src": src_p,
            "dst": dst_p,
            "edge_pad": pad,
            "seed_mask": seed_mask,
        }


def batch_molecules(n_graphs: int, n_nodes: int, n_edges: int, d_in: int,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """Block-diagonal batch of random small molecules (molecule cell)."""
    rng = np.random.default_rng(seed)
    N = n_graphs * n_nodes
    pos = rng.normal(size=(N + 1, 3)).astype(np.float32) * 2.0
    x = rng.normal(size=(N + 1, d_in)).astype(np.float32)
    src = np.zeros(n_graphs * n_edges, np.int32)
    dst = np.zeros(n_graphs * n_edges, np.int32)
    for gi in range(n_graphs):
        off = gi * n_nodes
        s = rng.integers(0, n_nodes, n_edges)
        shift = 1 + rng.integers(0, n_nodes - 1, n_edges)
        d = (s + shift) % n_nodes
        src[gi * n_edges:(gi + 1) * n_edges] = off + s
        dst[gi * n_edges:(gi + 1) * n_edges] = off + d
    graph_ids = np.repeat(np.arange(n_graphs), n_nodes)
    graph_ids = np.concatenate([graph_ids, [n_graphs]]).astype(np.int32)
    return {"pos": pos, "x": x, "src": src, "dst": dst,
            "graph_ids": graph_ids, "n_graphs": n_graphs + 1,
            "energy": rng.normal(size=n_graphs + 1).astype(np.float32)}
