"""Graph containers, format conversions and the out-of-core ingest path.

The MFBC system works with three representations of the same graph:

* ``Graph`` — a host-side COO container (numpy). This is the canonical
  format produced by generators and dataset loaders.
* dense adjacency — an ``(n, n)`` float matrix with ``inf`` where no edge
  exists. Used by the dense-frontier regime (Pallas tropical matmul) and by
  small-graph tests.
* padded COO device arrays — ``(src, dst, w)`` int32/float arrays padded to
  a static ``nnz`` so that jit'd programs have static shapes. Padding edges
  point at a sink row with weight ``inf`` and are therefore algebraically
  inert under the multpath/centpath monoids.

No self loops: ``A(i, i) = inf`` structurally, matching the paper
(Section 2.1: ``A(i,j) = w(i,j)`` iff ``(i,j) in E``).

On-disk formats and streaming ingest (the production loading path):

* ``EdgeListReader`` streams ``(src, dst, w)`` chunks out of whitespace
  edge-list text (``u v [w]`` rows, ``#``/``%`` comments — the SNAP
  convention) or the ``RCOO`` binary record format, transparently
  gunzipping ``*.gz``, in bounded memory per chunk.
* ``ChunkedCSRBuilder`` folds those chunks into the *canonical* graph —
  deduped (min-weight arc per (src, dst) pair, no self loops), optionally
  symmetrized, optionally isolated-vertex-compacted — with arrays that are
  bitwise identical to the in-memory ``Graph(...).dedup()`` /
  ``.symmetrize()`` / ``.remove_isolated()`` pipeline regardless of chunk
  size or arrival order, and a content ``digest`` computed during the
  emit pass (the future result-cache key).
* ``build_sharded_adjacency`` feeds chunks straight into a
  ``core.dist_bc.MeshBCContext`` so the ``(n, n)`` dense adjacency is
  materialized per device *shard*, never whole on one host.
"""
from __future__ import annotations

import dataclasses
import gzip
import hashlib
import io
import os
import re
import struct
from typing import IO, Iterable, Iterator, Optional, Tuple, Union

import numpy as np

INF = np.float32(np.inf)

# COO chunk: (src, dst, w) int32/int32/float32 host arrays of one length.
CooChunk = Tuple[np.ndarray, np.ndarray, np.ndarray]


@dataclasses.dataclass
class Graph:
    """Host-side COO graph. Directed; undirected graphs store both arcs."""

    n: int
    src: np.ndarray  # (nnz,) int32
    dst: np.ndarray  # (nnz,) int32
    w: np.ndarray  # (nnz,) float32, positive
    directed: bool = True
    name: str = "graph"

    def __post_init__(self) -> None:
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        self.w = np.asarray(self.w, dtype=np.float32)
        assert self.src.shape == self.dst.shape == self.w.shape

    @property
    def nnz(self) -> int:
        return int(self.src.shape[0])

    @property
    def m(self) -> int:
        """Edge count in the paper's sense (arcs for directed graphs)."""
        return self.nnz

    def dedup(self) -> "Graph":
        """Keep the minimum-weight arc for each (src, dst) pair; drop loops."""
        keep = self.src != self.dst
        src, dst, w = self.src[keep], self.dst[keep], self.w[keep]
        key = src.astype(np.int64) * self.n + dst.astype(np.int64)
        order = np.lexsort((w, key))
        key, src, dst, w = key[order], src[order], dst[order], w[order]
        first = np.ones(key.shape[0], dtype=bool)
        first[1:] = key[1:] != key[:-1]
        return Graph(self.n, src[first], dst[first], w[first], self.directed, self.name)

    def symmetrize(self) -> "Graph":
        """Return the undirected version (both arcs present, deduped)."""
        src = np.concatenate([self.src, self.dst])
        dst = np.concatenate([self.dst, self.src])
        w = np.concatenate([self.w, self.w])
        return Graph(self.n, src, dst, w, directed=False, name=self.name).dedup()

    def transpose(self) -> "Graph":
        return Graph(self.n, self.dst, self.src, self.w, self.directed, self.name + "_T")

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n)

    def remove_isolated(self) -> Tuple["Graph", np.ndarray]:
        """Drop vertices with no incident arcs (paper preprocessing).

        Returns the compacted graph and the array of kept original ids.
        """
        touched = np.zeros(self.n, dtype=bool)
        touched[self.src] = True
        touched[self.dst] = True
        kept = np.nonzero(touched)[0]
        remap = np.full(self.n, -1, dtype=np.int32)
        remap[kept] = np.arange(kept.shape[0], dtype=np.int32)
        return (
            Graph(int(kept.shape[0]), remap[self.src], remap[self.dst], self.w,
                  self.directed, self.name),
            kept,
        )


def coo_to_dense(g: Graph, dtype=np.float32) -> np.ndarray:
    """Dense adjacency with ``inf`` off-structure (min over duplicate arcs)."""
    a = np.full((g.n, g.n), np.inf, dtype=dtype)
    # np.minimum.at handles duplicate (src, dst) pairs.
    np.minimum.at(a, (g.src, g.dst), g.w.astype(dtype))
    np.fill_diagonal(a, np.inf)
    return a


def coo_to_csr(g: Graph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """CSR (indptr, indices, weights) sorted by (src, dst)."""
    order = np.lexsort((g.dst, g.src))
    src, dst, w = g.src[order], g.dst[order], g.w[order]
    indptr = np.zeros(g.n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst, w


def pad_edges(g: Graph, nnz_padded: Optional[int] = None, multiple: int = 128
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad the COO arrays to a static size.

    Padding arcs are ``(n-1) -> (n-1)`` with weight ``inf``: a self loop of
    infinite weight never relaxes anything (``f((w, m), inf) = (inf, m)``
    loses every ``min``), so the padding is algebraically invisible.
    """
    if nnz_padded is None:
        nnz_padded = ((g.nnz + multiple - 1) // multiple) * multiple
    nnz_padded = max(nnz_padded, multiple)
    assert nnz_padded >= g.nnz, (nnz_padded, g.nnz)
    pad = nnz_padded - g.nnz
    sink = g.n - 1
    src = np.concatenate([g.src, np.full(pad, sink, np.int32)])
    dst = np.concatenate([g.dst, np.full(pad, sink, np.int32)])
    w = np.concatenate([g.w, np.full(pad, np.inf, np.float32)])
    return src, dst, w


# ==========================================================================
# Out-of-core ingest: streaming readers, chunked canonicalization, digests.
# ==========================================================================


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """What the planner needs to size a run, without the edge arrays.

    ``BCPlanner.plan`` / ``plan_for_request`` accept this in place of a
    full ``Graph`` — a scale-20 ingest can plan its placement, regime and
    n_b from the stats the streaming pass produced before (or without
    ever) materializing the COO arrays on this host. ``digest`` is the
    canonical content digest (``graph_digest``) when known: the key the
    result-cache line of work will address cached λ by.
    """

    n: int
    m: int
    weighted: bool = False
    directed: bool = True
    name: str = "graph"
    digest: Optional[str] = None

    @classmethod
    def from_graph(cls, g: Graph, digest: Optional[str] = None
                   ) -> "GraphStats":
        return cls(n=g.n, m=g.m, weighted=bool(np.any(g.w != 1.0)),
                   directed=g.directed, name=g.name, digest=digest)


_DIGEST_MAGIC = b"repro-graph-v1"


def _digest_update(h, n: int, directed: bool, nnz: int) -> None:
    h.update(_DIGEST_MAGIC)
    h.update(struct.pack("<q?q", n, directed, nnz))


def graph_digest(g: Graph, chunk: int = 1 << 20) -> str:
    """Content digest of the *canonical* arc set (dedup order, min weight).

    Invariant under arc order and duplicate arcs: the digest is taken
    over the ``dedup()``-canonical ``(src, dst, w)`` arrays, streamed in
    chunks — ``ChunkedCSRBuilder`` computes the same value during its
    emit pass, so an out-of-core ingest and an in-memory build of the
    same graph share one cache key.
    """
    c = g.dedup()
    h = hashlib.sha256()
    _digest_update(h, c.n, c.directed, c.nnz)
    for lo in range(0, c.nnz, chunk):
        h.update(c.src[lo:lo + chunk].tobytes())
        h.update(c.dst[lo:lo + chunk].tobytes())
        h.update(c.w[lo:lo + chunk].tobytes())
    return h.hexdigest()


# --- RCOO binary record format --------------------------------------------
#
# Header: magic b"RCOO", u32 version, i64 n, i64 nnz, u8 flags
# (bit0 = weighted, bit1 = directed), then nnz interleaved little-endian
# (i32 src, i32 dst, f32 w) records. Record-major layout so a gzipped
# stream reads forward-only in bounded chunks (no per-array seeks).

_RCOO_MAGIC = b"RCOO"
_RCOO_HEADER = struct.Struct("<4sIqqB")
_RCOO_RECORD = np.dtype([("src", "<i4"), ("dst", "<i4"), ("w", "<f4")])


def write_binary_coo(path: str, g: Graph) -> str:
    """Write a ``Graph``'s raw arcs as an RCOO file (``.gz`` honored)."""
    rec = np.empty(g.nnz, dtype=_RCOO_RECORD)
    rec["src"], rec["dst"], rec["w"] = g.src, g.dst, g.w
    flags = (1 if np.any(g.w != 1.0) else 0) | (2 if g.directed else 0)
    with _open_binary(path, "wb") as f:
        f.write(_RCOO_HEADER.pack(_RCOO_MAGIC, 1, g.n, g.nnz, flags))
        f.write(rec.tobytes())
    return path


def write_edge_list(path: str, g: Graph, *, weights: Optional[bool] = None
                    ) -> str:
    """Write a whitespace edge list (``.gz`` honored; SNAP-style header)."""
    if weights is None:
        weights = bool(np.any(g.w != 1.0))
    with _open_binary(path, "wb") as fb:
        f = io.TextIOWrapper(fb, encoding="ascii")
        f.write(f"# {g.name}: n={g.n} nnz={g.nnz} "
                f"{'directed' if g.directed else 'undirected'}\n")
        for lo in range(0, g.nnz, 1 << 16):
            hi = min(lo + (1 << 16), g.nnz)
            cols = ([g.src[lo:hi], g.dst[lo:hi], g.w[lo:hi]] if weights
                    else [g.src[lo:hi], g.dst[lo:hi]])
            block = np.stack([np.asarray(c, np.float64) for c in cols], 1)
            # %.9g: 9 significant digits round-trip float32 exactly.
            fmt = "%d %d %.9g" if weights else "%d %d"
            np.savetxt(f, block, fmt=fmt)
        f.flush()
        f.detach()
    return path


def _open_binary(path: str, mode: str) -> IO[bytes]:
    if str(path).endswith(".gz"):
        return gzip.open(path, mode)
    return open(path, mode)


class EdgeListReader:
    """Streaming chunk reader over on-disk edge data (bounded memory).

    Formats (auto-detected from the filename, ``fmt=`` overrides):

    * ``"text"`` — whitespace-separated ``u v [w]`` rows; lines starting
      with ``#`` or ``%`` are comments (SNAP / Matrix-Market-adjacent).
    * ``"rcoo"`` — the RCOO binary record format (``write_binary_coo``),
      detected from a ``.rcoo`` / ``.bin`` suffix.

    A trailing ``.gz`` on either is gunzipped transparently. Iterating
    yields ``(src, dst, w)`` int32/int32/float32 chunks of at most
    ``chunk_edges`` arcs; the reader is restartable (each ``chunks()``
    call reopens the file), which is what lets ``build_sharded_adjacency``
    and the canonicalizing builder share one source. After a full pass,
    ``edges_read`` / ``n_min`` (max id + 1 seen) describe the stream.
    """

    def __init__(self, path: str, *, chunk_edges: int = 1 << 18,
                 fmt: Optional[str] = None, default_weight: float = 1.0):
        if chunk_edges <= 0:
            raise ValueError(f"chunk_edges must be positive, got "
                             f"{chunk_edges}")
        self.path = str(path)
        self.chunk_edges = int(chunk_edges)
        self.default_weight = float(default_weight)
        stem = self.path[:-3] if self.path.endswith(".gz") else self.path
        if fmt is None:
            fmt = ("rcoo" if stem.endswith((".rcoo", ".bin")) else "text")
        if fmt not in ("text", "rcoo"):
            raise ValueError(f"fmt must be 'text' or 'rcoo', got {fmt!r}")
        self.fmt = fmt
        self.edges_read = 0  # arcs yielded by the last full pass
        self.n_min = 0  # max id + 1 over the last full pass
        # Declared metadata, when the file carries it: the RCOO header, or
        # a text comment ("# ...: n=40 ... directed" / SNAP "# Nodes: 4039").
        self.header_n: Optional[int] = None
        self.header_directed: Optional[bool] = None
        self.name = os.path.basename(stem).rsplit(".", 1)[0] or "graph"

    def chunks(self) -> Iterator[CooChunk]:
        self.edges_read = 0
        self.n_min = 0
        it = (self._rcoo_chunks() if self.fmt == "rcoo"
              else self._text_chunks())
        for src, dst, w in it:
            if src.shape[0] == 0:
                continue
            self.edges_read += int(src.shape[0])
            hi = int(max(src.max(), dst.max())) + 1
            self.n_min = max(self.n_min, hi)
            yield src, dst, w

    __iter__ = chunks

    def _rcoo_chunks(self) -> Iterator[CooChunk]:
        with _open_binary(self.path, "rb") as f:
            head = f.read(_RCOO_HEADER.size)
            magic, version, n, nnz, flags = _RCOO_HEADER.unpack(head)
            if magic != _RCOO_MAGIC or version != 1:
                raise ValueError(f"{self.path}: not an RCOO v1 file "
                                 "(bad magic or version)")
            self.header_n = int(n)
            self.header_directed = bool(flags & 2)
            left = int(nnz)
            while left > 0:
                k = min(left, self.chunk_edges)
                buf = f.read(k * _RCOO_RECORD.itemsize)
                if len(buf) < k * _RCOO_RECORD.itemsize:
                    raise ValueError(f"{self.path}: truncated RCOO stream "
                                     f"({left} arcs missing)")
                rec = np.frombuffer(buf, dtype=_RCOO_RECORD)
                yield (rec["src"].astype(np.int32),
                       rec["dst"].astype(np.int32),
                       rec["w"].astype(np.float32))
                left -= k

    def _text_chunks(self) -> Iterator[CooChunk]:
        with _open_binary(self.path, "rb") as fb:
            f = io.TextIOWrapper(fb, encoding="utf-8", errors="replace")
            src, dst, w = [], [], []
            for line in f:
                s = line.strip()
                if not s or s[0] in "#%":
                    self._scan_header_comment(s)
                    continue
                parts = s.split()
                src.append(int(parts[0]))
                dst.append(int(parts[1]))
                w.append(float(parts[2]) if len(parts) > 2
                         else self.default_weight)
                if len(src) >= self.chunk_edges:
                    yield (np.asarray(src, np.int32),
                           np.asarray(dst, np.int32),
                           np.asarray(w, np.float32))
                    src, dst, w = [], [], []
            if src:
                yield (np.asarray(src, np.int32), np.asarray(dst, np.int32),
                       np.asarray(w, np.float32))

    _HEADER_N_RE = re.compile(r"\b(?:n=|Nodes:\s*)(\d+)")

    def _scan_header_comment(self, s: str) -> None:
        """Pick up declared metadata from a ``#`` comment line."""
        m = self._HEADER_N_RE.search(s)
        if m and self.header_n is None:
            self.header_n = int(m.group(1))
        if self.header_directed is None:
            if "undirected" in s.lower():
                self.header_directed = False
            elif "directed" in s.lower():
                self.header_directed = True


def _pack_key(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """(src, dst) -> one int64 key with (src, dst)-lexicographic order.

    Bit-packing instead of ``src * n + dst`` so streaming dedup needs no
    final ``n`` up front; both give the same sort order, which is all the
    canonical form depends on.
    """
    return (src.astype(np.int64) << 32) | dst.astype(np.int64)


def _dedup_sorted(key: np.ndarray, w: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Canonical run: sort by (key, w), keep the min-w arc per key.

    Exactly ``Graph.dedup``'s ``lexsort((w, key))`` + first-per-key, so
    composing this over any chunking of the same arc multiset lands on
    identical arrays.
    """
    order = np.lexsort((w, key))
    key, w = key[order], w[order]
    first = np.ones(key.shape[0], dtype=bool)
    first[1:] = key[1:] != key[:-1]
    return key[first], w[first]


@dataclasses.dataclass
class IngestResult:
    """What one streaming ingest pass produced."""

    graph: Graph
    kept: Optional[np.ndarray]  # original ids kept (None: no compaction)
    digest: str  # canonical content digest (== graph_digest(graph))
    edges_read: int  # raw arcs consumed (before dedup/symmetrize)
    n_chunks: int

    @property
    def stats(self) -> GraphStats:
        return GraphStats.from_graph(self.graph, digest=self.digest)


class ChunkedCSRBuilder:
    """Streaming canonicalizer: COO chunks in, canonical ``Graph``/CSR out.

    Feeds arbitrary-order, arbitrary-chunking arc streams through
    ``add(src, dst, w)`` and produces on ``finalize()`` a graph whose
    arrays are **bitwise identical** to the in-memory pipeline
    ``Graph(n, src, dst, w).dedup()`` (+ ``.symmetrize()`` when
    ``symmetrize=True``, + ``.remove_isolated()`` when
    ``remove_isolated=True``) applied to the concatenated stream.

    Memory: each chunk is deduped into a sorted run immediately;
    buffered runs merge-compact whenever they exceed ``buffer_edges``
    arcs, so the peak footprint is O(unique arcs + chunk), never
    O(raw stream). The content digest is accumulated during the final
    emit pass (one extra O(nnz) sweep, no extra copy).
    """

    def __init__(self, n: Optional[int] = None, *, symmetrize: bool = False,
                 remove_isolated: bool = False, directed: bool = True,
                 name: str = "graph", buffer_edges: int = 1 << 22):
        self._n_pin = n
        self._n_seen = 0
        self.symmetrize = bool(symmetrize)
        self.remove_isolated = bool(remove_isolated)
        self.directed = False if symmetrize else bool(directed)
        self.name = name
        self.buffer_edges = int(buffer_edges)
        self._runs: list[Tuple[np.ndarray, np.ndarray]] = []  # (key, w)
        self._buffered = 0
        self._touched = np.zeros(0, dtype=bool)
        self.edges_read = 0
        self.n_chunks = 0
        self._done = False

    # -- streaming side -----------------------------------------------------
    def add(self, src: np.ndarray, dst: np.ndarray,
            w: Optional[np.ndarray] = None) -> None:
        if self._done:
            raise RuntimeError("ChunkedCSRBuilder already finalized")
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        w = (np.ones(src.shape[0], np.float32) if w is None
             else np.asarray(w, np.float32))
        if not (src.shape == dst.shape == w.shape):
            raise ValueError("src, dst and w must share one shape")
        self.edges_read += int(src.shape[0])
        self.n_chunks += 1
        if src.shape[0] == 0:
            return
        if src.min() < 0 or dst.min() < 0:
            raise ValueError("negative vertex id in edge chunk")
        hi = int(max(src.max(), dst.max())) + 1
        if self._n_pin is not None and hi > self._n_pin:
            raise ValueError(f"vertex id {hi - 1} out of range for pinned "
                             f"n={self._n_pin}")
        self._n_seen = max(self._n_seen, hi)
        keep = src != dst  # canonical form has no self loops
        src, dst, w = src[keep], dst[keep], w[keep]
        if src.shape[0] == 0:
            return
        self._mark_touched(src, dst)
        if self.symmetrize:
            src, dst = (np.concatenate([src, dst]),
                        np.concatenate([dst, src]))
            w = np.concatenate([w, w])
        key, w = _dedup_sorted(_pack_key(src, dst), w)
        self._runs.append((key, w))
        self._buffered += int(key.shape[0])
        if self._buffered > self.buffer_edges and len(self._runs) > 1:
            self._compact()

    def add_chunks(self, chunks: Iterable[CooChunk]) -> "ChunkedCSRBuilder":
        for src, dst, w in chunks:
            self.add(src, dst, w)
        return self

    def _mark_touched(self, src: np.ndarray, dst: np.ndarray) -> None:
        if self._touched.shape[0] < self._n_seen:
            grown = np.zeros(max(self._n_seen, 2 * self._touched.shape[0]),
                             dtype=bool)
            grown[:self._touched.shape[0]] = self._touched
            self._touched = grown
        self._touched[src] = True
        self._touched[dst] = True

    def _compact(self) -> None:
        key = np.concatenate([k for k, _ in self._runs])
        w = np.concatenate([v for _, v in self._runs])
        key, w = _dedup_sorted(key, w)
        self._runs = [(key, w)]
        self._buffered = int(key.shape[0])

    # -- emit side ----------------------------------------------------------
    def finalize(self) -> IngestResult:
        """Merge runs, compact isolated vertices, digest, build the Graph."""
        self._done = True
        n = self._n_pin if self._n_pin is not None else self._n_seen
        if self._runs:
            self._compact()
            key, w = self._runs[0]
        else:
            key = np.zeros(0, np.int64)
            w = np.zeros(0, np.float32)
        src = (key >> 32).astype(np.int32)
        dst = (key & 0xFFFFFFFF).astype(np.int32)
        kept = None
        if self.remove_isolated:
            touched = np.zeros(n, dtype=bool)
            touched[:min(self._touched.shape[0], n)] = \
                self._touched[:n]
            kept = np.nonzero(touched)[0]
            remap = np.full(n, -1, dtype=np.int32)
            remap[kept] = np.arange(kept.shape[0], dtype=np.int32)
            src, dst = remap[src], remap[dst]
            # remap preserves id order, so (src, dst) sortedness survives
            n = int(kept.shape[0])
        h = hashlib.sha256()
        _digest_update(h, n, self.directed, int(src.shape[0]))
        for lo in range(0, src.shape[0], 1 << 20):
            h.update(src[lo:lo + (1 << 20)].tobytes())
            h.update(dst[lo:lo + (1 << 20)].tobytes())
            h.update(w[lo:lo + (1 << 20)].tobytes())
        g = Graph(n, src, dst, w, directed=self.directed, name=self.name)
        return IngestResult(graph=g, kept=kept, digest=h.hexdigest(),
                            edges_read=self.edges_read,
                            n_chunks=self.n_chunks)


def load_graph(path: str, *, n: Optional[int] = None,
               chunk_edges: int = 1 << 18, symmetrize: bool = False,
               remove_isolated: bool = True, fmt: Optional[str] = None,
               name: Optional[str] = None,
               default_weight: float = 1.0) -> IngestResult:
    """One-call chunked ingest: file → canonical ``Graph`` + digest.

    The production loading path (bounded memory per chunk): a streaming
    ``EdgeListReader`` pass through a ``ChunkedCSRBuilder``. The result's
    arrays are bitwise what the in-memory pipeline would produce on the
    same file, for every ``chunk_edges`` — the parity the ingest tests
    pin down.
    """
    reader = EdgeListReader(path, chunk_edges=chunk_edges, fmt=fmt,
                            default_weight=default_weight)
    builder = ChunkedCSRBuilder(n, symmetrize=symmetrize,
                                remove_isolated=remove_isolated,
                                name=name or reader.name)
    builder.add_chunks(reader.chunks())
    if builder._n_pin is None and reader.header_n:
        builder._n_pin = max(reader.header_n, builder._n_seen)
    if not symmetrize and reader.header_directed is not None:
        # RCOO flags / a text header comment declare directedness; the ids
        # alone cannot. Adopt it so a write → load round trip is identity.
        builder.directed = reader.header_directed
    return builder.finalize()


def as_coo_chunks(source: Union[Graph, IngestResult, EdgeListReader,
                                Iterable[CooChunk]]) -> Iterable[CooChunk]:
    """Normalize an adjacency source into an iterable of COO chunks."""
    if isinstance(source, IngestResult):
        source = source.graph
    if isinstance(source, Graph):
        return [(source.src, source.dst, source.w)]
    if isinstance(source, EdgeListReader):
        return source.chunks()
    return source


def build_sharded_adjacency(source, ctx, *, transform=None):
    """Stream an adjacency into a ``core.dist_bc.MeshBCContext``.

    ``source`` is anything ``as_coo_chunks`` understands — a ``Graph``,
    an ``IngestResult``, a restartable ``EdgeListReader``, or a raw
    iterable of ``(src, dst, w)`` chunks. Each chunk is routed to the
    per-device shard blocks it intersects (``MeshBCContext.
    upload_coo_chunks``), so the full ``(n, n)`` dense adjacency — the
    thing that cannot exist at scale 18+ — is only ever materialized one
    device block at a time. Chunks must already be canonical-enough for
    an adjacency (duplicates fold by min, self loops are dropped; but
    symmetrization is *not* applied here — feed a ``ChunkedCSRBuilder``
    result or a symmetric on-disk file for undirected graphs).

    ``ctx`` must be built for the stream's vertex count, e.g.
    ``MeshBCContext(ingest.stats, mesh, ...)`` — the stats-only
    constructor path that skips the dense upload. ``transform(src, dst,
    w) -> (src, dst, w)`` optionally rewrites each chunk in flight
    (id remapping, weight casts). Returns ``ctx``.
    """
    chunks = as_coo_chunks(source)
    if transform is not None:
        chunks = (transform(*c) for c in chunks)
    ctx.upload_coo_chunks(chunks)
    return ctx
