"""Graph containers and format conversions.

The MFBC system works with three representations of the same graph:

* ``Graph`` — a host-side COO container (numpy). This is the canonical
  format produced by generators and dataset loaders.
* dense adjacency — an ``(n, n)`` float matrix with ``inf`` where no edge
  exists. Used by the dense-frontier regime (Pallas tropical matmul) and by
  small-graph tests.
* padded COO device arrays — ``(src, dst, w)`` int32/float arrays padded to
  a static ``nnz`` so that jit'd programs have static shapes. Padding edges
  point at a sink row with weight ``inf`` and are therefore algebraically
  inert under the multpath/centpath monoids.

No self loops: ``A(i, i) = inf`` structurally, matching the paper
(Section 2.1: ``A(i,j) = w(i,j)`` iff ``(i,j) in E``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

INF = np.float32(np.inf)


@dataclasses.dataclass
class Graph:
    """Host-side COO graph. Directed; undirected graphs store both arcs."""

    n: int
    src: np.ndarray  # (nnz,) int32
    dst: np.ndarray  # (nnz,) int32
    w: np.ndarray  # (nnz,) float32, positive
    directed: bool = True
    name: str = "graph"

    def __post_init__(self) -> None:
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        self.w = np.asarray(self.w, dtype=np.float32)
        assert self.src.shape == self.dst.shape == self.w.shape

    @property
    def nnz(self) -> int:
        return int(self.src.shape[0])

    @property
    def m(self) -> int:
        """Edge count in the paper's sense (arcs for directed graphs)."""
        return self.nnz

    def dedup(self) -> "Graph":
        """Keep the minimum-weight arc for each (src, dst) pair; drop loops."""
        keep = self.src != self.dst
        src, dst, w = self.src[keep], self.dst[keep], self.w[keep]
        key = src.astype(np.int64) * self.n + dst.astype(np.int64)
        order = np.lexsort((w, key))
        key, src, dst, w = key[order], src[order], dst[order], w[order]
        first = np.ones(key.shape[0], dtype=bool)
        first[1:] = key[1:] != key[:-1]
        return Graph(self.n, src[first], dst[first], w[first], self.directed, self.name)

    def symmetrize(self) -> "Graph":
        """Return the undirected version (both arcs present, deduped)."""
        src = np.concatenate([self.src, self.dst])
        dst = np.concatenate([self.dst, self.src])
        w = np.concatenate([self.w, self.w])
        return Graph(self.n, src, dst, w, directed=False, name=self.name).dedup()

    def transpose(self) -> "Graph":
        return Graph(self.n, self.dst, self.src, self.w, self.directed, self.name + "_T")

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n)

    def remove_isolated(self) -> Tuple["Graph", np.ndarray]:
        """Drop vertices with no incident arcs (paper preprocessing).

        Returns the compacted graph and the array of kept original ids.
        """
        touched = np.zeros(self.n, dtype=bool)
        touched[self.src] = True
        touched[self.dst] = True
        kept = np.nonzero(touched)[0]
        remap = np.full(self.n, -1, dtype=np.int32)
        remap[kept] = np.arange(kept.shape[0], dtype=np.int32)
        return (
            Graph(int(kept.shape[0]), remap[self.src], remap[self.dst], self.w,
                  self.directed, self.name),
            kept,
        )


def coo_to_dense(g: Graph, dtype=np.float32) -> np.ndarray:
    """Dense adjacency with ``inf`` off-structure (min over duplicate arcs)."""
    a = np.full((g.n, g.n), np.inf, dtype=dtype)
    # np.minimum.at handles duplicate (src, dst) pairs.
    np.minimum.at(a, (g.src, g.dst), g.w.astype(dtype))
    np.fill_diagonal(a, np.inf)
    return a


def coo_to_csr(g: Graph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """CSR (indptr, indices, weights) sorted by (src, dst)."""
    order = np.lexsort((g.dst, g.src))
    src, dst, w = g.src[order], g.dst[order], g.w[order]
    indptr = np.zeros(g.n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst, w


def pad_edges(g: Graph, nnz_padded: Optional[int] = None, multiple: int = 128
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad the COO arrays to a static size.

    Padding arcs are ``(n-1) -> (n-1)`` with weight ``inf``: a self loop of
    infinite weight never relaxes anything (``f((w, m), inf) = (inf, m)``
    loses every ``min``), so the padding is algebraically invisible.
    """
    if nnz_padded is None:
        nnz_padded = ((g.nnz + multiple - 1) // multiple) * multiple
    nnz_padded = max(nnz_padded, multiple)
    assert nnz_padded >= g.nnz, (nnz_padded, g.nnz)
    pad = nnz_padded - g.nnz
    sink = g.n - 1
    src = np.concatenate([g.src, np.full(pad, sink, np.int32)])
    dst = np.concatenate([g.dst, np.full(pad, sink, np.int32)])
    w = np.concatenate([g.w, np.full(pad, np.inf, np.float32)])
    return src, dst, w
