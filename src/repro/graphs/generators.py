"""Synthetic graph generators used by the paper's evaluation (Section 7).

* ``erdos_renyi`` — uniform random graphs [Gilbert 1959], used for the
  weak-scaling experiments.
* ``rmat`` — power-law R-MAT graphs [Chakrabarti et al. 2004], used for the
  strong-scaling experiments (S = log2 n, E = average degree).
* ``uniform_random`` — fixed-expected-degree uniform graphs, the paper's
  "vertex weak scaling" family.
* ``ring_of_cliques`` — a structured graph with analytically known
  betweenness, handy for exact unit tests.

All generators are deterministic in ``seed`` and produce positive integer
weights in ``[1, max_weight]`` (the paper uses integers in [1, 100]) or
unit weights when ``weighted=False``.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.formats import Graph


def _weights(rng: np.random.Generator, nnz: int, weighted: bool, max_weight: int
             ) -> np.ndarray:
    if weighted:
        return rng.integers(1, max_weight + 1, size=nnz).astype(np.float32)
    return np.ones(nnz, dtype=np.float32)


def from_spec(kind: str, *, scale: int, degree: float = 8,
              weighted: bool = False, seed: int = 0,
              max_weight: int = 100) -> Graph:
    """The shared CLI/benchmark graph family spec: kind + (scale, degree).

    ``kind`` is one of ``"rmat"`` (power-law, n = 2^scale, E = degree),
    ``"uniform"`` (fixed expected degree) or ``"er"`` (Erdős–Rényi with
    p = degree/n). One helper so ``launch.bc_run``, the benchmarks and
    the tests all build byte-identical graphs from the same flags.
    """
    n = 1 << scale
    if kind == "rmat":
        return rmat(scale, int(degree), weighted=weighted, seed=seed,
                    max_weight=max_weight)
    if kind == "uniform":
        return uniform_random(n, degree, weighted=weighted, seed=seed,
                              max_weight=max_weight)
    if kind == "er":
        return erdos_renyi(n, degree / n, weighted=weighted, seed=seed,
                           max_weight=max_weight)
    raise ValueError(f"unknown graph kind {kind!r} "
                     f"(expected rmat | uniform | er)")


def erdos_renyi(n: int, p_edge: float, *, seed: int = 0, weighted: bool = False,
                max_weight: int = 100, directed: bool = False) -> Graph:
    rng = np.random.default_rng(seed)
    # Sample the number of arcs then arc endpoints — O(m) not O(n^2).
    expected = p_edge * n * (n - 1)
    nnz = int(rng.poisson(expected)) if expected < n * (n - 1) * 0.5 else int(expected)
    nnz = max(nnz, 1)
    src = rng.integers(0, n, size=nnz).astype(np.int32)
    dst = rng.integers(0, n, size=nnz).astype(np.int32)
    w = _weights(rng, nnz, weighted, max_weight)
    g = Graph(n, src, dst, w, directed=directed, name=f"er_n{n}_p{p_edge}").dedup()
    return g if directed else g.symmetrize()


def uniform_random(n: int, avg_degree: float, *, seed: int = 0,
                   weighted: bool = False, max_weight: int = 100,
                   directed: bool = False) -> Graph:
    return erdos_renyi(n, avg_degree / max(n - 1, 1), seed=seed, weighted=weighted,
                       max_weight=max_weight, directed=directed)


def rmat(scale: int, avg_degree: int, *, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0, weighted: bool = False,
         max_weight: int = 100, directed: bool = False) -> Graph:
    """R-MAT generator with the Graph500 default (a, b, c, d) quadrant mix."""
    n = 1 << scale
    nnz = n * avg_degree
    rng = np.random.default_rng(seed)
    src = np.zeros(nnz, dtype=np.int64)
    dst = np.zeros(nnz, dtype=np.int64)
    for level in range(scale):
        r = rng.random(nnz)
        # Quadrant picks: P(a)=a, P(b)=b, P(c)=c, P(d)=1-a-b-c.
        right = (r >= a) & (r < a + b) | (r >= a + b + c)
        down = r >= a + b
        src = src * 2 + down
        dst = dst * 2 + right
    w = _weights(rng, nnz, weighted, max_weight)
    g = Graph(n, src.astype(np.int32), dst.astype(np.int32), w,
              directed=directed, name=f"rmat_s{scale}_e{avg_degree}").dedup()
    return g if directed else g.symmetrize()


def ring_of_cliques(n_cliques: int, clique_size: int, *, weighted: bool = False,
                    seed: int = 0, max_weight: int = 10) -> Graph:
    """``n_cliques`` cliques joined in a ring by single bridge edges."""
    rng = np.random.default_rng(seed)
    n = n_cliques * clique_size
    src, dst = [], []
    for q in range(n_cliques):
        base = q * clique_size
        for i in range(clique_size):
            for j in range(clique_size):
                if i != j:
                    src.append(base + i)
                    dst.append(base + j)
        nxt = ((q + 1) % n_cliques) * clique_size
        src += [base, nxt]
        dst += [nxt, base]
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    w = _weights(rng, src.shape[0], weighted, max_weight)
    if weighted:
        # keep symmetric weights
        key = {}
        for e in range(src.shape[0]):
            k = (min(src[e], dst[e]), max(src[e], dst[e]))
            if k in key:
                w[e] = key[k]
            else:
                key[k] = w[e]
    return Graph(n, src, dst, w, directed=False,
                 name=f"roc_{n_cliques}x{clique_size}").dedup()


def star_graph(n: int, *, weighted: bool = False, seed: int = 0,
               max_weight: int = 10) -> Graph:
    """Hub vertex 0 joined to ``n-1`` leaves.

    The adaptive sampler's best case: every leaf source has the identical
    dependency profile (δ_s(hub) = n-2, zero elsewhere), so the empirical
    variance collapses and Bernstein/CLT stopping certifies ε long before
    the variance-free Hoeffding budget is spent.
    """
    rng = np.random.default_rng(seed)
    leaves = np.arange(1, n, dtype=np.int32)
    src = np.concatenate([np.zeros(n - 1, np.int32), leaves])
    dst = np.concatenate([leaves, np.zeros(n - 1, np.int32)])
    half = _weights(rng, n - 1, weighted, max_weight)
    w = np.concatenate([half, half])
    return Graph(n, src, dst, w, directed=False, name=f"star_{n}")


def path_graph(n: int, *, weighted: bool = False, seed: int = 0,
               max_weight: int = 10) -> Graph:
    rng = np.random.default_rng(seed)
    s = np.arange(n - 1, dtype=np.int32)
    src = np.concatenate([s, s + 1])
    dst = np.concatenate([s + 1, s])
    half = _weights(rng, n - 1, weighted, max_weight)
    w = np.concatenate([half, half])
    return Graph(n, src, dst, w, directed=False, name=f"path_{n}")
