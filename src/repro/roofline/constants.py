"""TPU v5e hardware constants (per chip)."""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s
PEAK_FLOPS_F32 = 98.5e12  # MXU f32 ~ half of bf16
HBM_BW = 819e9  # bytes/s
ICI_BW_PER_LINK = 50e9  # bytes/s per link
HBM_BYTES = 16 * 2 ** 30  # 16 GiB
VMEM_BYTES = 128 * 2 ** 20  # ~128 MiB vector memory (v5e)
