"""Parse compiled (post-SPMD-partitioning) HLO text for collective traffic.

``cost_analysis()`` reports FLOPs and HBM bytes but not collective bytes;
per the roofline methodology we parse ``compiled.as_text()`` and sum the
operand sizes of every ``all-gather`` / ``all-reduce`` / ``reduce-scatter``
/ ``all-to-all`` / ``collective-permute`` instruction. Shapes in a
partitioned module are per-device local shapes, so the sums are per-device
traffic.

Two metrics are reported:

* ``operand_bytes`` — Σ operand sizes (the required roofline metric);
* ``wire_bytes``    — estimated bytes on the wire per device using ring
  algorithms: all-gather = out−in, all-reduce = 2·in·(q−1)/q ≈ 2·in,
  reduce-scatter = in−out, all-to-all = in, collective-permute = in.

Collectives inside ``while`` bodies (e.g. FSDP gathers inside a
scan-over-layers) appear once in the text but execute once per iteration;
``CollectiveStats.scaled(loop_trip_counts)`` multiplies per-computation
totals by caller-supplied trip counts (the configs know their layer
counts). This is a structural limitation of text-level analysis, recorded
in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"((?:all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?)\(([^)]*)\)", re.M)
_ANY_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s*([\w\-]+)",
    re.M)
# Computation headers may carry tuple-typed params with nested parens
# (while bodies: ``%wide.region_… (p: (s32[], f32[8,512], …)) -> (…) {``),
# so the param list must match greedily up to the ``->``.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->", re.M)
_WHILE_RE = re.compile(r"while\(.*\).*?body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    computation: str
    out_bytes: int
    in_bytes: int

    @property
    def operand_bytes(self) -> int:
        return self.in_bytes

    @property
    def wire_bytes(self) -> int:
        k = self.kind
        if k == "all-gather":
            return max(self.out_bytes - self.in_bytes, 0)
        if k == "all-reduce":
            return 2 * self.in_bytes
        if k == "reduce-scatter":
            return max(self.in_bytes - self.out_bytes, 0)
        return self.in_bytes  # all-to-all, collective-permute


@dataclasses.dataclass
class CollectiveStats:
    ops: List[CollectiveOp]
    while_bodies: List[str]
    # callee -> [(caller, scaled_by_trip)]: one entry per call site; a
    # while's body/condition edges carry scaled_by_trip=True. Lets trip
    # counts propagate to collectives that XLA hoisted into fusion
    # computations *called from* a loop body — name-prefix matching alone
    # silently under-counts those.
    call_edges: Dict[str, List[Tuple[str, bool]]] = \
        dataclasses.field(default_factory=dict)

    def totals(self, loop_trip_counts: Optional[Dict[str, int]] = None
               ) -> Dict[str, float]:
        """Aggregate bytes; ops executed inside loops scale by trip count.

        loop_trip_counts: map from while-body-name substring to trip
        count (``{"*": k}`` matches every loop). The multiplier of a
        computation is summed over its call sites and compounds across
        nested loops; computations never called (the entry) count once.
        """
        loop_trip_counts = loop_trip_counts or {}
        mults = self._multipliers(loop_trip_counts)
        operand = wire = 0.0
        msgs = 0.0
        per_kind: Dict[str, float] = defaultdict(float)
        for op in self.ops:
            mult = mults.get(op.computation)
            if mult is None:  # no call-graph info: legacy prefix match
                mult = 1.0
                for body in self.while_bodies:
                    if (op.computation == body
                            or op.computation.startswith(body)):
                        mult = float(self._match_trip(body,
                                                      loop_trip_counts))
                        break
            operand += mult * op.operand_bytes
            wire += mult * op.wire_bytes
            msgs += mult
            per_kind[op.kind] += mult * op.wire_bytes
        return {"operand_bytes": operand, "wire_bytes": wire,
                "messages": msgs, **{f"wire_{k}": v for k, v in per_kind.items()}}

    def _multipliers(self, trips: Dict[str, int]) -> Dict[str, float]:
        """Executions per computation, from the call graph (memoized)."""
        memo: Dict[str, float] = {}

        def mult(comp: str, stack: Tuple[str, ...] = ()) -> float:
            if comp in memo:
                return memo[comp]
            edges = self.call_edges.get(comp)
            if not edges or comp in stack:  # root (entry) / cycle guard
                return 1.0
            total = 0.0
            for caller, scaled in edges:
                m = mult(caller, stack + (comp,))
                if scaled:
                    m *= float(self._match_trip(comp, trips))
                total += m
            memo[comp] = total
            return total

        return {c: mult(c) for c in self.call_edges}

    @staticmethod
    def _match_trip(body: str, trips: Dict[str, int]) -> int:
        for key, v in trips.items():
            if key != "*" and key in body:
                return v
        return trips.get("*", 1)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Extract collective ops (with computation attribution) from HLO text."""
    # Build a symbol table of instruction result types per computation.
    comp = "entry"
    types: Dict[Tuple[str, str], str] = {}
    comp_of_line: List[Tuple[str, str, str, str]] = []  # (comp, name, type, opcode)
    for line in hlo_text.splitlines():
        mcomp = _COMP_RE.match(line)
        if mcomp and ("{" in line or line.rstrip().endswith("->")
                      or "->" in line):
            comp = mcomp.group(1)
            continue
        mi = _ANY_INSTR_RE.match(line)
        if mi:
            name, tstr, opcode = mi.group(1), mi.group(2), mi.group(3)
            types[(comp, name)] = tstr
            comp_of_line.append((comp, name, tstr, line))

    ops: List[CollectiveOp] = []
    while_bodies: List[str] = []
    call_edges: Dict[str, List[Tuple[str, bool]]] = {}
    for comp_name, name, tstr, line in comp_of_line:
        mw = _WHILE_RE.search(line)
        if mw:
            while_bodies.append(mw.group(1))
            call_edges.setdefault(mw.group(1), []).append((comp_name, True))
            mc = _COND_RE.search(line)
            if mc:
                call_edges.setdefault(mc.group(1), []).append(
                    (comp_name, True))
        else:
            for callee in _CALLS_RE.findall(line):
                call_edges.setdefault(callee, []).append((comp_name, False))
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, out_type, kind, operands = m.group(1), m.group(2), m.group(3), m.group(4)
        base_kind = kind.replace("-start", "").replace("-done", "")
        if kind.endswith("-done"):
            continue  # counted at -start
        out_b = shape_bytes(out_type)
        in_b = 0
        for op_ref in operands.split(","):
            op_ref = op_ref.strip().lstrip("%")
            # operand may carry an inline type (older dumps) or be a name
            inline = shape_bytes(op_ref)
            if inline:
                in_b += inline
            else:
                op_name = op_ref.split(" ")[-1].lstrip("%")
                in_b += shape_bytes(types.get((comp_name, op_name), ""))
        if in_b == 0 and base_kind == "all-gather":
            in_b = 0  # unknown operand; wire estimate falls back to out
        ops.append(CollectiveOp(base_kind, comp_name, out_b, in_b))
    return CollectiveStats(ops, while_bodies, call_edges)


def collective_bytes(hlo_text: str,
                     loop_trip_counts: Optional[Dict[str, int]] = None
                     ) -> Dict[str, float]:
    return parse_collectives(hlo_text).totals(loop_trip_counts)
