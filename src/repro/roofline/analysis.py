"""Roofline analysis over the dry-run records.

For each (arch × shape × mesh) cell:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = wire_bytes_per_device / ICI_link_bw

Terms are *per step* wall-time lower bounds; the dominant term is the
bottleneck. ``MODEL_FLOPS / HLO_FLOPs`` measures how much compiled compute
is algorithmically useful (catches remat/dispatch waste). The estimated
step time assumes perfect compute/comm overlap (max of terms); the
"roofline fraction" = compute_term / max(terms) is the §Perf score.

Usage:
  PYTHONPATH=src python -m repro.roofline.analysis --dryrun results/dryrun \
      --out results/roofline.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.roofline import constants as C


def _advice(rec: Dict, dominant: str) -> str:
    fam = rec["arch"].split("-")[0]
    if dominant == "collective":
        return ("shrink the gathered operand (2D->3D decomposition / more "
                "replication c, or keep weights resident)" if fam in
                ("mfbc_paper",) else
                "overlap or shrink DP/FSDP gathers (bigger per-device batch, "
                "int8/topk grad compression, expert-local all-to-all)")
    if dominant == "memory":
        return ("bf16/int8 the dominant resident tensor (KV cache / "
                "embedding rows / frontier pairs) or fuse the streaming op")
    return "compute-bound: raise MXU occupancy (bf16, larger tiles)"


V5E_VPU_OPS = 3.9e12  # elementwise min-plus rate (the MXU cannot do it)


def _bc_kernel_terms(rec: Dict) -> Dict:
    """mfbc_paper cells: production terms from the Pallas kernel tile model
    (512-cube tiles; accumulators resident in VMEM — see tropical_mm.py).
    The HLO terms describe the pure-jnp fallback, which materializes the
    candidate blocks in HBM (~10^3x more traffic)."""
    meta = {"bc_web_256k": (262144, 8192, 8), "bc_dense_64k": (65536, 16384, 6)}
    n, nb, iters = meta[rec["shape"]]
    pod = 2 if rec["mesh"] == "multi" else 1
    nb_loc, n_loc = nb // pod, n // 16
    relaxes = 2 * (iters + 1) + 1
    bm = bk = bn = 512
    f = nb_loc * n_loc * 8 * (n_loc // bn)
    a = n_loc * n_loc * 4 * (nb_loc // bm)
    cbytes = nb_loc * n_loc * 8
    ops = 4.0 * nb_loc * n_loc * n_loc
    return {"t_memory_s": (f + a + cbytes) * relaxes / C.HBM_BW,
            "t_compute_s": ops * relaxes / V5E_VPU_OPS}


def analyze_record(rec: Dict, *, peak_flops: float = C.PEAK_FLOPS_BF16
                   ) -> Dict:
    flops_dev = rec["flops_per_device"]
    bytes_dev = rec["bytes_accessed_per_device"]
    wire = rec["collectives"].get("wire_bytes", 0.0)
    operand = rec["collectives"].get("operand_bytes", 0.0)
    t_compute = flops_dev / peak_flops
    t_memory = bytes_dev / C.HBM_BW
    t_coll = wire / C.ICI_BW_PER_LINK
    if rec["arch"] == "mfbc_paper":
        kt = _bc_kernel_terms(rec)
        t_compute = kt["t_compute_s"]
        t_memory = kt["t_memory_s"]
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_step = max(terms.values())
    model = rec.get("model_flops", 0.0)
    total_hlo = flops_dev * rec["n_devices"]
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "n_devices")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "t_step_s": t_step,
        "roofline_fraction": (t_compute / t_step) if t_step > 0 else 0.0,
        "model_flops": model,
        "hlo_flops_total": total_hlo,
        "useful_flops_ratio": model / total_hlo if total_hlo else 0.0,
        "collective_wire_bytes": wire,
        "collective_operand_bytes": operand,
        "peak_mem_gib": rec["memory"]["peak_bytes"] / 2 ** 30,
        "arg_mem_gib": rec["memory"]["argument_bytes"] / 2 ** 30,
        "advice": _advice(rec, dominant),
    }


def load_all(dryrun_dir: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok"):
            out.append(rec)
    return out


def _fmt_t(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def to_markdown(rows: List[Dict], mesh: Optional[str] = None) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | bound | "
           "roofline frac | useful/HLO | mem/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if mesh and r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_t(r['t_compute_s'])} | {_fmt_t(r['t_memory_s'])} "
            f"| {_fmt_t(r['t_collective_s'])} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.2f} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['arg_mem_gib'] + r['peak_mem_gib']:.1f} GiB |")
    return hdr + "\n".join(lines) + "\n"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.md")
    ap.add_argument("--json-out", default="results/roofline.json")
    args = ap.parse_args(argv)

    recs = load_all(args.dryrun)
    rows = [analyze_record(r) for r in recs]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    md = ["# Roofline (single-pod 16x16 = 256 chips)\n",
          to_markdown(rows, "single"),
          "\n# Multi-pod (2x16x16 = 512 chips) dry-run terms\n",
          to_markdown(rows, "multi")]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("".join(md))
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"[roofline] wrote {args.out} ({len(rows)} cells)")
    # worst cells (hillclimb candidates)
    single = [r for r in rows if r["mesh"] == "single"]
    if single:
        worst = sorted(single, key=lambda r: r["roofline_fraction"])[:5]
        print("[roofline] worst roofline fractions:")
        for r in worst:
            print(f"  {r['arch']} x {r['shape']}: "
                  f"{r['roofline_fraction']:.2f} ({r['dominant']})")
    return rows


if __name__ == "__main__":
    main()
