"""Distributed MFBC batch step — Theorem 5.1 on the production mesh.

Mesh mapping (paper grid (p₁, p₂, p₃) = (√(p/c), √(p/c), c)):

* ``model`` axis ↔ p₁ — shards the adjacency's *row* (u) dimension and the
  state's vertex (v) dimension.
* ``data`` axis ↔ p₂ — shards the adjacency's *column* dimension and the
  state's source (s) dimension.
* ``pod`` axis ↔ p₃ = c — the replication factor: the adjacency is
  replicated across pods (its broadcast amortizes over all products and
  batches, exactly as in the Theorem 5.1 proof) and each pod owns a
  disjoint slice of the source batch.

Per-iteration collectives (per device, F = frontier, C = product):

1. ``all_gather(F, data, dim=0)``          ≈ nnz(F)/p_model     bytes
2. local generalized matmul (Pallas/VPU)   — no communication
3. monoid reduce-scatter over ``model``    ≈ nnz(C)/p_data      bytes
4. ``all_gather(C, data, dim=1)`` + slice  ≈ nnz(C)/p_model     bytes

Total ≈ (nnz(F) + 2·nnz(C))/√(p/c) per iteration — the Theorem 5.1 bound.
The monoid reduction uses the pmin/pmax + tie-masked psum pair from
``repro.spgemm.semiring`` (DESIGN.md §3).

State layout: every (nb, n) matrix is P((pod, data), model) — sources over
pod×data, vertices over model. The adjacency (and its transpose, needed by
the backward MFBr sweep on directed graphs) is P(model, data), *no* pod
entry = replicated across pods.

Vertex id layout: the reduce-scatter(model) + all-gather(data) pipeline in
step 3–4 produces state columns in the *interleaved* order
``v(m; d', j) = d'·n/D + m·n/(D·M) + j`` (D, M = data/model axis sizes) for
the device's model index m. We adopt this as the canonical on-device vertex
order: the adjacency's **rows** are pre-permuted on the host with
``vertex_row_permutation`` so that contiguous P(model, ·) row blocks
enumerate exactly that order, local ids come from the closed form above,
and the host applies the inverse permutation to λ at the end. (CTF calls
this a cyclic-blocked layout; it is communication-free by construction.)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.compat import shard_map

from repro.core import monoids
from repro.core.monoids import Centpath, Multpath

INF = jnp.inf


@dataclasses.dataclass(frozen=True)
class BCMeshConfig:
    """Static configuration of the distributed BC step."""

    n: int  # padded vertex count (divisible by data*model and model*data)
    nb: int  # global batch size (divisible by pod*data)
    iters_bf: int  # static forward iteration bound (≥ weighted diameter)
    iters_br: int  # static backward bound
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: Optional[str] = "pod"  # None on single-pod meshes
    block: int = 512  # local relax block size
    use_kernel: bool = False  # route local relax through Pallas kernels
    unroll: bool = False  # python-loop iterations (dry-run cost fidelity)

    @property
    def batch_axes(self):
        return ((self.pod_axis, self.data_axis) if self.pod_axis
                else (self.data_axis,))

    def specs(self):
        state = P(self.batch_axes, self.model_axis)
        adj = P(self.model_axis, self.data_axis)
        src = P(self.batch_axes)
        lam = P(self.model_axis)
        return state, adj, src, lam


def _local_relax_mp(cfg, F: Multpath, a_loc) -> Multpath:
    if cfg.use_kernel:
        from repro.kernels import ops as kops

        w, m = kops.multpath_matmul(F.w, F.m, a_loc)
        return Multpath(w, m)
    return monoids.multpath_relax_dense(F, a_loc, block=cfg.block,
                                        unroll=cfg.unroll)


def _local_relax_cp(cfg, F: Centpath, at_loc) -> Centpath:
    if cfg.use_kernel:
        from repro.kernels import ops as kops

        w, p, c = kops.centpath_matmul(F.w, F.p, at_loc)
        return Centpath(w, p, c)
    return monoids.centpath_relax_dense(F, at_loc, block=cfg.block,
                                         unroll=cfg.unroll)


def _reduce_scatter_gather(cfg, tree, reduce_fn):
    """Steps 3+4: ⊕-reduce over model (scatter v), re-gather v over data.

    Input leaves: (nb_pod, n/data) partial over model.
    Output leaves: (nb_pod, n/model) replicated over data.
    """
    red = reduce_fn(tree, cfg.model_axis)  # full reduce (pmin/pmax+psum)
    m_idx = jax.lax.axis_index(cfg.model_axis)
    m_sz = compat.axis_size(cfg.model_axis)

    def scatter(v):
        blk = v.shape[1] // m_sz
        return jax.lax.dynamic_slice_in_dim(v, m_idx * blk, blk, axis=1)

    sc = jax.tree.map(scatter, red)  # (nb_pod, n/(data*model))
    return jax.tree.map(
        lambda v: jax.lax.all_gather(v, cfg.data_axis, axis=1, tiled=True),
        sc)  # (nb_pod, n/model)


def _slice_rows(cfg, tree):
    """Keep this device's source rows: (nb_pod, x) -> (nb_pod/data, x)."""
    d_idx = jax.lax.axis_index(cfg.data_axis)
    d_sz = compat.axis_size(cfg.data_axis)

    def slc(v):
        blk = v.shape[0] // d_sz
        return jax.lax.dynamic_slice_in_dim(v, d_idx * blk, blk, axis=0)

    return jax.tree.map(slc, tree)


def _gather_rows(cfg, tree):
    """(nb_pod/data, x) -> (nb_pod, x): step 1 frontier broadcast."""
    return jax.tree.map(
        lambda v: jax.lax.all_gather(v, cfg.data_axis, axis=0, tiled=True),
        tree)


def _mp_axis_reduce(x: Multpath, axis: str) -> Multpath:
    wmin = jax.lax.pmin(x.w, axis)
    m = jax.lax.psum(jnp.where((x.w == wmin) & jnp.isfinite(wmin), x.m, 0.0),
                     axis)
    return Multpath(wmin, m)


def _cp_axis_reduce(x: Centpath, axis: str) -> Centpath:
    wmax = jax.lax.pmax(x.w, axis)
    tie = (x.w == wmax) & jnp.isfinite(wmax)
    return Centpath(wmax, jax.lax.psum(jnp.where(tie, x.p, 0.0), axis),
                    jax.lax.psum(jnp.where(tie, x.c, 0.0), axis))


def _dist_relax_mp(cfg, F_state: Multpath, a_loc) -> Multpath:
    """One distributed MFBF relaxation (steps 1–4)."""
    Fg = _gather_rows(cfg, F_state)  # (nb_pod, n/model)
    C_part = _local_relax_mp(cfg, Fg, a_loc)  # (nb_pod, n/data), partial
    C = _reduce_scatter_gather(cfg, C_part, _mp_axis_reduce)
    return _slice_rows(cfg, C)  # (nb_pod/data, n/model)


def _dist_relax_cp(cfg, F_state: Centpath, at_loc) -> Centpath:
    Fg = _gather_rows(cfg, F_state)
    C_part = _local_relax_cp(cfg, Fg, at_loc)
    C = _reduce_scatter_gather(cfg, C_part, _cp_axis_reduce)
    return _slice_rows(cfg, C)


def _count_children(cfg, Tw_state, at_loc):
    """Distributed SP-DAG child count.

    c0(s, v) = #{u : Tw(s,v) + A(v,u) == Tw(s,u)}. Reuses the centpath
    relax over A^T: contributions from u where Tw(s,u) - A(v,u) == Tw(s,v)
    land at v with count 1 each. Unreachable entries (+inf) are masked to
    the centpath identity (-inf) first — +inf would win the max-select.
    """
    w = jnp.where(jnp.isfinite(Tw_state), Tw_state, -INF)
    F = Centpath(w, jnp.zeros_like(Tw_state), jnp.zeros_like(Tw_state))
    Pc = _dist_relax_cp(cfg, F, at_loc)
    hit = (Pc.w == Tw_state) & jnp.isfinite(Tw_state) & (Pc.c > 0)
    return jnp.where(hit, Pc.c, 0.0).astype(jnp.int32)


def _local_ids(cfg, n):
    """Global vertex ids of this device's state columns (interleaved order).

    Column c of a state shard on model index m maps to
    v = d'·(n/D) + m·(n/(D·M)) + j with d' = c // (n/(D·M)), j = c % ….
    """
    m_idx = jax.lax.axis_index(cfg.model_axis)
    d_sz = compat.axis_size(cfg.data_axis)
    m_sz = compat.axis_size(cfg.model_axis)
    n_loc = n // m_sz
    sub = n // (d_sz * m_sz)
    c = jax.lax.iota(jnp.int32, n_loc)
    return (c // sub) * (n // d_sz) + m_idx * sub + (c % sub)


def _seed_multpath(cfg, sources_loc, n):
    """Local seed frontier: (s, u) = (0, 1) iff u == source_s."""
    u_ids = _local_ids(cfg, n)
    hit = sources_loc[:, None] == u_ids[None, :]
    return Multpath(jnp.where(hit, 0.0, INF).astype(jnp.float32),
                    jnp.where(hit, 1.0, 0.0).astype(jnp.float32))


def _batch_delta_local(cfg: BCMeshConfig, a_loc, at_loc, sources_loc,
                       valid_loc):
    """The full Algorithm 3 batch, local (per-device) view.

    Returns ``(contrib, mask)`` with ``contrib[s, v] = δ_s(v)`` for this
    device's source rows and vertex columns (zeroed on unreachable and
    padding entries) and ``mask[s, v] = [v reachable from s ∧ s valid]``.
    The Σδ-only (``_batch_step_local``) and moments
    (``_batch_step_moments_local``) entry points share this body; only
    their final reductions differ.
    """
    n = cfg.n
    # ---- MFBF ----
    seed = _seed_multpath(cfg, sources_loc, n)
    T = _dist_relax_mp(cfg, seed, a_loc)  # direct edges (paper line 1)
    F = T

    def bf_body(_, state):
        T, F = state
        C = _dist_relax_mp(cfg, F, a_loc)
        T_new = monoids.multpath_combine(T, C)
        keep = (C.w == T_new.w) & jnp.isfinite(C.w) & (C.m > 0)
        F_new = Multpath(jnp.where(keep, C.w, INF),
                         jnp.where(keep, C.m, 0.0))
        return T_new, F_new

    if cfg.unroll:
        st = (T, F)
        for _ in range(cfg.iters_bf):
            st = bf_body(0, st)
        T, _ = st
    else:
        T, _ = jax.lax.fori_loop(0, cfg.iters_bf, bf_body, (T, F))

    # ---- mask the t = s destination ----
    ids = _local_ids(cfg, n)
    self_col = sources_loc[:, None] == ids[None, :]
    Tw = jnp.where(self_col, INF, T.w)
    Tm_safe = jnp.where(self_col | (T.m <= 0), 1.0, T.m)
    finite = jnp.isfinite(Tw)

    # ---- MFBr ----
    c0 = _count_children(cfg, Tw, at_loc)
    Zp = jnp.zeros_like(Tw)
    seed_mask = finite & (c0 == 0)

    def mk_frontier(mask, Zp):
        return Centpath(jnp.where(mask, Tw, -INF),
                        jnp.where(mask, Zp + 1.0 / Tm_safe, 0.0),
                        jnp.where(mask, 1.0, 0.0))

    state0 = (Zp, c0, seed_mask, mk_frontier(seed_mask, Zp))

    def br_body(_, st):
        Zp, c, done, Fc = st
        Pc = _dist_relax_cp(cfg, Fc, at_loc)
        contrib = (Pc.w == Tw) & finite & (Pc.c > 0)
        Zp = Zp + jnp.where(contrib, Pc.p, 0.0)
        c = c - jnp.where(contrib, Pc.c.astype(c.dtype), 0)
        newly = finite & (c == 0) & (~done)
        return Zp, c, done | newly, mk_frontier(newly, Zp)

    if cfg.unroll:
        st = state0
        for _ in range(cfg.iters_br):
            st = br_body(0, st)
        Zp, _, _, _ = st
    else:
        Zp, _, _, _ = jax.lax.fori_loop(0, cfg.iters_br, br_body, state0)

    mask = finite & valid_loc[:, None]
    contrib = jnp.where(mask, Zp * T.m, 0.0)
    return contrib, mask


def _batch_step_local(cfg: BCMeshConfig, a_loc, at_loc, sources_loc,
                      valid_loc):
    """Σδ-only batch step (the exact all-sources sweep's reduction)."""
    contrib, _ = _batch_delta_local(cfg, a_loc, at_loc, sources_loc,
                                    valid_loc)
    # λ accumulation: sum over local sources, then over the batch axes.
    lam_part = jnp.sum(contrib, axis=0)  # (n/model,)
    return jax.lax.psum(lam_part, cfg.batch_axes)


def _batch_step_moments_local(cfg: BCMeshConfig, a_loc, at_loc, sources_loc,
                              valid_loc):
    """Moments batch step: per-vertex (Σδ, Σδ², n_reach) over the batch.

    The mesh analogue of ``core.mfbc.mfbc_batch_moments``: instead of
    folding sources into a pre-summed λ, the step keeps the per-source
    dependency rows long enough to also square them, then reduces all
    three statistics in a *single* stacked ``psum`` over the batch axes —
    one fused all-reduce of 3·n/model floats per batch, not a second
    collective per source. This is what lets the adaptive approximate-BC
    estimator run empirical-Bernstein/CLT stopping at pod scale (ROADMAP
    "Distributed sampling epochs with second moments").
    """
    contrib, mask = _batch_delta_local(cfg, a_loc, at_loc, sources_loc,
                                       valid_loc)
    stats = jnp.stack([
        jnp.sum(contrib, axis=0),                       # S1 = Σ_s δ_s(v)
        jnp.sum(contrib * contrib, axis=0),             # S2 = Σ_s δ_s(v)²
        jnp.sum(mask, axis=0).astype(jnp.float32),      # n_reach
    ])  # (3, n/model)
    return jax.lax.psum(stats, cfg.batch_axes)


def _batch_step_moments_segmented_local(cfg: BCMeshConfig, n_slots: int,
                                        a_loc, at_loc, sources_loc,
                                        valid_loc, slots_loc):
    """Segment-reduced moments step: per-slot (Σδ, Σδ², n_reach).

    The cross-request fusion primitive on the mesh (the distributed
    counterpart of ``core.mfbc.mfbc_batch_moments_segmented``): each
    device segment-sums its local source rows into ``(n_slots, n/model)``
    per-slot statistics (rows tagged ``slots_loc == n_slots`` are padding
    and land in a dump segment that is dropped), then all three
    statistics for *all* slots ride one stacked ``psum`` over the batch
    axes — a fused batch packing many queries still costs exactly one
    collective of ``3·n_slots·n/p_model`` floats, which is the whole
    point of fusing under-filled per-request batches.
    """
    contrib, mask = _batch_delta_local(cfg, a_loc, at_loc, sources_loc,
                                       valid_loc)
    seg = functools.partial(jax.ops.segment_sum, segment_ids=slots_loc,
                            num_segments=n_slots + 1)
    stats = jnp.stack([
        seg(contrib)[:n_slots],                         # S1 per slot
        seg(contrib * contrib)[:n_slots],               # S2 per slot
        seg(mask.astype(jnp.float32))[:n_slots],        # n_reach per slot
    ])  # (3, n_slots, n/model)
    return jax.lax.psum(stats, cfg.batch_axes)


def build_mfbc_step(mesh: Mesh, cfg: BCMeshConfig, *, moments: bool = False,
                    segments: Optional[int] = None):
    """Returns a jit'd distributed batch step on ``mesh``.

    a / a_t: (n, n) dense adjacency and its transpose, laid out
    P(model, data) (replicated over pod). sources/valid: (nb,) laid out
    P((pod, data)).

    With ``moments=False`` the step returns λ: (n,) sharded over model
    (the exact sweep's Σδ). With ``moments=True`` it returns a (3, n)
    stack of (Σδ, Σδ², n_reach) sharded over model in the vertex
    dimension — the distributed counterpart of
    ``core.mfbc.mfbc_batch_moments``. With ``segments=n_slots`` the step
    additionally takes per-row slot ids (same P((pod, data)) layout as
    the sources) and returns a (3, n_slots, n) stack segment-reduced per
    slot — the fused cross-request batch step.
    """
    state_spec, adj_spec, src_spec, lam_spec = cfg.specs()
    if segments is not None:
        fn = shard_map(
            functools.partial(_batch_step_moments_segmented_local, cfg,
                              segments),
            mesh=mesh,
            in_specs=(adj_spec, adj_spec, src_spec, src_spec, src_spec),
            out_specs=P(None, None, cfg.model_axis),
            check_vma=False,
        )
        return jax.jit(fn)
    body = _batch_step_moments_local if moments else _batch_step_local
    out_spec = P(None, cfg.model_axis) if moments else lam_spec
    fn = shard_map(
        functools.partial(body, cfg),
        mesh=mesh,
        in_specs=(adj_spec, adj_spec, src_spec, src_spec),
        out_specs=out_spec,
        check_vma=False,
    )
    return jax.jit(fn)


def input_shardings(mesh: Mesh, cfg: BCMeshConfig):
    _, adj_spec, src_spec, _ = cfg.specs()
    return (NamedSharding(mesh, adj_spec), NamedSharding(mesh, adj_spec),
            NamedSharding(mesh, src_spec), NamedSharding(mesh, src_spec))


# --------------------------------------------------------------------------
# Host-side helpers: padding, row permutation, full-graph driver.
# --------------------------------------------------------------------------


def vertex_row_permutation(n: int, d_sz: int, m_sz: int):
    """Π such that A[Π, :] sharded P(model, ·) has row blocks matching the
    interleaved on-device vertex order (see module docstring)."""
    import numpy as np

    sub = n // (d_sz * m_sz)
    perm = np.empty(n, dtype=np.int64)
    i = 0
    for m in range(m_sz):
        for d in range(d_sz):
            base = d * (n // d_sz) + m * sub
            perm[i:i + sub] = np.arange(base, base + sub)
            i += sub
    return perm


class MeshBCContext:
    """Device-resident mesh state shared across batch-size buckets.

    Pads and permutes the adjacency once, uploads A and Aᵀ once, and
    hands out jitted batch steps per ``(nb, variant)`` from a cache — so
    one executor can serve several padded batch sizes (the power-of-two
    bucket set of ``repro.bc``) and the segmented fusion variant without
    re-uploading the adjacency or retracing already-compiled shapes.
    ``prepare_mesh_batch_step`` remains as the single-``nb`` convenience
    wrapper over this class.

    ``g`` is a ``Graph`` (adjacency uploaded eagerly) or anything
    stats-like with an ``n`` attribute but no edge arrays (e.g.
    ``repro.graphs.formats.GraphStats``): the context then comes up with
    *no* adjacency resident, and the caller streams it in through
    ``upload_coo_chunks`` / ``graphs.formats.build_sharded_adjacency``.
    That path densifies the adjacency one device shard at a time — the
    host never holds the full (n_pad, n_pad) matrix, which is what makes
    scale-18+ graphs loadable at all.
    """

    def __init__(self, g, mesh: Mesh, *, iters: int = 0,
                 use_kernel: bool = False, block: int = 512,
                 execution=None):
        # Duck-typed backend-dispatch config (repro.bc.ExecutionConfig):
        # the core layer never imports the solver facade, it just reads
        # the three relax-step fields. The mesh step is dense-only.
        if execution is not None:
            backend = getattr(execution, "backend", None)
            if backend is not None and str(getattr(backend, "value",
                                                   backend)) != "dense":
                raise ValueError("MeshBCContext supports only the dense "
                                 "backend")
            if execution.use_kernel is not None:
                use_kernel = bool(execution.use_kernel)
            block = int(execution.block)

        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.mesh = mesh
        self.n = g.n
        self._d_sz = axis_sizes["data"]
        self._m_sz = axis_sizes["model"]
        self._pod = "pod" if "pod" in axis_sizes else None
        self._p_sz = axis_sizes.get("pod", 1)
        self.chunk = self._p_sz * self._d_sz  # source-batch divisibility
        self.iters = iters if iters > 0 else g.n
        self._use_kernel = use_kernel
        self._block = block

        lcm = self._d_sz * self._m_sz
        self.n_pad = -(-g.n // lcm) * lcm
        self.perm = vertex_row_permutation(self.n_pad, self._d_sz, self._m_sz)
        # Shardings depend only on axis names, not on nb: one probe cfg.
        self._sh_a, self._sh_at, self._sh_src, self._sh_val = \
            input_shardings(mesh, self._cfg(self.chunk))
        self._a_dev = None
        self._at_dev = None
        self._steps = {}  # (nb_pad, variant, n_slots) -> jitted step
        if hasattr(g, "src"):
            self.upload_graph(g)

    # -- adjacency upload ----------------------------------------------------
    def upload_graph(self, g) -> "MeshBCContext":
        """Upload a host-resident ``Graph``'s adjacency (one chunk)."""
        return self.upload_coo_chunks([(g.src, g.dst, g.w)])

    def upload_coo_chunks(self, chunks) -> "MeshBCContext":
        """Build the device-sharded A / Aᵀ from streamed COO chunks.

        Each ``(src, dst, w)`` chunk is routed to the per-device shard
        blocks it intersects; blocks densify lazily inside
        ``jax.make_array_from_callback``, so peak host memory is
        O(nnz + one shard block), never O(n²). Duplicate arcs fold by
        ``min`` and self loops are dropped — bitwise the semantics of
        ``coo_to_dense`` (+ inf diagonal) on the concatenated stream,
        for any chunking.
        """
        import numpy as np

        rb = self.n_pad // self._m_sz  # shard rows  (model axis)
        cb = self.n_pad // self._d_sz  # shard cols  (data axis)
        inv_perm = np.empty(self.n_pad, dtype=np.int64)
        inv_perm[self.perm] = np.arange(self.n_pad)
        buckets_a: dict = {}
        buckets_at: dict = {}
        for src, dst, w in chunks:
            src = np.asarray(src, dtype=np.int64)
            dst = np.asarray(dst, dtype=np.int64)
            w = np.asarray(w, dtype=np.float32)
            keep = src != dst  # A(i, i) = inf structurally
            src, dst, w = src[keep], dst[keep], w[keep]
            if src.shape[0] and int(max(src.max(), dst.max())) >= self.n:
                raise ValueError("vertex id out of range for this context")
            # A[perm, :]: arc (s, d) lands at row inv_perm[s], col d.
            self._bucket(buckets_a, inv_perm[src], dst, w, rb, cb)
            # Aᵀ[perm, :]: arc (s, d) lands at row inv_perm[d], col s.
            self._bucket(buckets_at, inv_perm[dst], src, w, rb, cb)
        self._a_dev = self._densify(buckets_a, rb, cb, self._sh_a)
        self._at_dev = self._densify(buckets_at, rb, cb, self._sh_at)
        return self

    @staticmethod
    def _bucket(buckets, rows, cols, w, rb, cb) -> None:
        """Split one chunk's entries by the (row, col) shard block."""
        import numpy as np

        if rows.shape[0] == 0:
            return
        bid = (rows // rb) * (1 << 20) + cols // cb
        order = np.argsort(bid, kind="stable")
        bid, rows, cols, w = bid[order], rows[order], cols[order], w[order]
        cuts = np.nonzero(bid[1:] != bid[:-1])[0] + 1
        for lo, hi in zip(np.r_[0, cuts], np.r_[cuts, bid.shape[0]]):
            key = (int(rows[lo]) // rb, int(cols[lo]) // cb)
            buckets.setdefault(key, []).append(
                (rows[lo:hi] % rb, cols[lo:hi] % cb, w[lo:hi]))

    def _densify(self, buckets, rb, cb, sharding):
        import numpy as np

        def shard(index):
            r0 = index[0].start or 0
            c0 = index[1].start or 0
            blk = np.full((rb, cb), np.inf, dtype=np.float32)
            for rows, cols, w in buckets.get((r0 // rb, c0 // cb), ()):
                np.minimum.at(blk, (rows, cols), w)
            return blk

        return jax.make_array_from_callback(
            (self.n_pad, self.n_pad), sharding, shard)

    def _adjacency(self):
        if self._a_dev is None:
            raise RuntimeError(
                "MeshBCContext has no adjacency resident: built from stats "
                "only — stream the graph in with upload_coo_chunks() / "
                "graphs.formats.build_sharded_adjacency() first")
        return self._a_dev, self._at_dev

    def round_nb(self, nb: int) -> int:
        """Smallest pod·data multiple ≥ nb (the mesh batch divisibility)."""
        return -(-nb // self.chunk) * self.chunk

    def _cfg(self, nb_pad: int) -> BCMeshConfig:
        return BCMeshConfig(n=self.n_pad, nb=nb_pad, iters_bf=self.iters,
                            iters_br=self.iters, pod_axis=self._pod,
                            use_kernel=self._use_kernel, block=self._block)

    def _step(self, nb_pad: int, variant: str, n_slots: Optional[int] = None):
        key = (nb_pad, variant, n_slots)
        if key not in self._steps:
            cfg = self._cfg(nb_pad)
            if variant == "segmented":
                self._steps[key] = build_mfbc_step(self.mesh, cfg,
                                                   segments=n_slots)
            else:
                self._steps[key] = build_mfbc_step(
                    self.mesh, cfg, moments=(variant == "moments"))
        return self._steps[key]

    def _pad_inputs(self, nb_pad: int, sources, valid,
                    slot_ids=None, n_slots: int = 0):
        import numpy as np

        src = np.zeros(nb_pad, np.int32)
        val = np.zeros(nb_pad, bool)
        k = min(sources.shape[0], nb_pad)
        src[:k], val[:k] = sources[:k], valid[:k]
        out = [jax.device_put(jnp.asarray(src), self._sh_src),
               jax.device_put(jnp.asarray(val), self._sh_val)]
        if slot_ids is not None:
            # Padding rows land in the dump segment n_slots (dropped).
            sid = np.full(nb_pad, n_slots, np.int32)
            sid[:k] = slot_ids[:k]
            out.append(jax.device_put(jnp.asarray(sid), self._sh_src))
        return out

    def run_sum(self, sources, valid, *, nb: int):
        """Σδ-only batch contribution, original vertex order, length n."""
        import numpy as np

        nb_pad = self.round_nb(nb)
        a_dev, at_dev = self._adjacency()
        src, val = self._pad_inputs(nb_pad, sources, valid)
        lam_b = self._step(nb_pad, "sum")(a_dev, at_dev, src, val)
        lam = np.zeros(self.n_pad, dtype=np.float64)
        lam[self.perm] = np.asarray(lam_b, np.float64)  # undo permutation
        return lam[:self.n]

    def run_moments(self, sources, valid, *, nb: int):
        """(S1, S2, n_reach) per vertex — the sampling-epoch reduction."""
        import numpy as np

        nb_pad = self.round_nb(nb)
        a_dev, at_dev = self._adjacency()
        src, val = self._pad_inputs(nb_pad, sources, valid)
        stats_b = self._step(nb_pad, "moments")(a_dev, at_dev, src, val)
        stats = np.zeros((3, self.n_pad), dtype=np.float64)
        stats[:, self.perm] = np.asarray(stats_b, np.float64)
        return (stats[0, :self.n], stats[1, :self.n],
                stats[2, :self.n].astype(np.int64))

    def run_segmented(self, sources, valid, slot_ids, n_slots: int, *,
                      nb: int):
        """Per-slot (S1, S2, n_reach), each (n_slots, n) — fused batches."""
        import numpy as np

        nb_pad = self.round_nb(nb)
        a_dev, at_dev = self._adjacency()
        src, val, sid = self._pad_inputs(nb_pad, sources, valid,
                                         slot_ids, n_slots)
        stats_b = self._step(nb_pad, "segmented", n_slots)(
            a_dev, at_dev, src, val, sid)
        stats = np.zeros((3, n_slots, self.n_pad), dtype=np.float64)
        stats[:, :, self.perm] = np.asarray(stats_b, np.float64)
        return (stats[0, :, :self.n], stats[1, :, :self.n],
                stats[2, :, :self.n].astype(np.int64))


def prepare_mesh_batch_step(g, mesh: Mesh, *, nb: int, iters: int = 0,
                            use_kernel: bool = False, block: int = 512,
                            moments: bool = False):
    """Single-``nb`` convenience wrapper over ``MeshBCContext``.

    Returns ``(run, nb_pad)`` where ``run`` takes host arrays of up to
    ``nb_pad`` sources (shorter inputs are zero-padded with
    ``valid=False``) and returns results in *original* vertex order,
    length ``g.n``:

    * ``moments=False`` (the Σδ-only reduction):
      ``run(sources, valid) -> λ_partial`` — the batch's Σδ contribution,
      float64 (n,). This is what the unified ``repro.bc`` exact sweep
      runs (``MeshExecutor.step_sum``): one n/p_model all-reduce per
      batch instead of the moments step's 3× stacked one.
    * ``moments=True`` (the adaptive approximate-BC driver): ``run(sources,
      valid) -> (S1, S2, n_reach)`` with ``S1(v) = Σ_s δ_s(v)`` and
      ``S2(v) = Σ_s δ_s(v)²`` over the batch's valid sources and
      ``n_reach(v)`` the count of sources that reach v — the same
      (Σδ, Σδ²) contract as ``core.mfbc.mfbc_batch_moments``, so
      ``approx.driver.LambdaEstimator`` can run Bernstein/CLT stopping
      on the mesh path. The Σδ² reduction rides the same fused all-reduce
      as Σδ (see ``_batch_step_moments_local``), so the extra
      communication is one stacked psum per batch.

    Callers that serve several batch sizes (or the segmented fused step)
    should hold a ``MeshBCContext`` directly — this wrapper builds a
    fresh context, so the adjacency upload is not shared across calls.
    """
    ctx = MeshBCContext(g, mesh, iters=iters, use_kernel=use_kernel,
                        block=block)
    nb_pad = ctx.round_nb(nb)
    if moments:
        return (lambda s, v: ctx.run_moments(s, v, nb=nb_pad)), nb_pad
    return (lambda s, v: ctx.run_sum(s, v, nb=nb_pad)), nb_pad


def dist_mfbc(g, mesh: Mesh, *, nb: int, iters: int = 0,
              use_kernel: bool = False, block: int = 512):
    """Deprecated: use ``repro.bc.solve(g, BCQuery(mode="exact"), mesh=...)``.

    Thin shim kept for one release: the exact all-sources mesh sweep is
    now one of the two ``repro.bc`` drivers (a ``MeshExecutor`` under the
    exact sweep — same batches, same Theorem 5.1 step, λ = Σ S1).
    """
    import warnings

    warnings.warn(
        "core.dist_bc.dist_mfbc is deprecated; use repro.bc.solve with "
        "BCQuery(mode='exact', ...) and a mesh", DeprecationWarning,
        stacklevel=2)
    from repro.bc import BCQuery, ExecutionConfig, solve

    query = BCQuery(mode="exact", n_b=nb, iters=iters,
                    execution=ExecutionConfig(use_kernel=use_kernel,
                                              block=block))
    return solve(g, query, mesh=mesh).lam
