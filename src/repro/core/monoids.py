"""Multpath / centpath monoid algebra (paper Sections 3, 4.1.1, 4.2.1).

A *multpath* is a tuple ``(w, m)``: path weight + multiplicity. The monoid
``(M, ⊕)`` keeps the smaller weight and sums multiplicities on ties. The
Bellman-Ford *action* is ``f((w, m), a) = (w + a, m)``.

A *centpath* is a tuple ``(w, p, c)``: weight + partial centrality factor +
counter. The monoid ``(C, ⊗)`` keeps the **larger** weight and sums ``p``
and ``c`` on ties. The Brandes action is ``g((w, p, c), a) = (w - a, p, c)``.

TPU adaptation (see DESIGN.md §3): frontiers are dense-in-structure,
sparse-in-value. A multpath entry is *inactive* when ``(w, m) = (inf, 0)``;
a centpath entry is inactive when ``w = -inf``. CTF keeps nulls structurally
absent; we mask them explicitly, because IEEE ``inf - a = inf`` would
otherwise win the centpath max-selection.

Two relaxation regimes are provided for each action:

* ``*_relax_dense``  — blocked generalized matmul against a dense ``(n, n)``
  adjacency (``inf`` off-structure). ``C(i,j) = ⊕_k f(T(i,k), A(k,j))``.
  This is the jnp oracle for the Pallas kernels in ``repro.kernels``.
* ``*_relax_coo``    — edge-list relaxation via ``segment_min/max`` + a
  tie-masked ``segment_sum`` (the TPU-native sparse idiom).
* ``*_relax_csr``    — frontier-compacted relaxation: the active entries
  of ``F`` are compacted into a static-capacity slot buffer
  (``jnp.nonzero(..., size=cap)``), only their incident CSR arc ranges
  are expanded, and candidates are scattered with the same segment ops —
  per-iteration work tracks the maximal frontier instead of E.

Equality of float path weights is exact (paper assumes exact arithmetic;
integer-valued float32 weights are exact up to 2**24).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

INF = jnp.inf


class Multpath(NamedTuple):
    w: jax.Array  # weights, inactive = +inf
    m: jax.Array  # multiplicities, inactive = 0


class Centpath(NamedTuple):
    w: jax.Array  # weights, inactive = -inf
    p: jax.Array  # partial centrality factor
    c: jax.Array  # counter (number of contributing children on ties)


def multpath_identity(shape, dtype=jnp.float32) -> Multpath:
    return Multpath(jnp.full(shape, INF, dtype), jnp.zeros(shape, dtype))


def centpath_identity(shape, dtype=jnp.float32) -> Centpath:
    return Centpath(jnp.full(shape, -INF, dtype), jnp.zeros(shape, dtype),
                    jnp.zeros(shape, dtype))


def multpath_combine(x: Multpath, y: Multpath) -> Multpath:
    """Elementwise ⊕: min weight, sum multiplicities on exact ties."""
    w = jnp.minimum(x.w, y.w)
    tie = (x.w == y.w) & jnp.isfinite(x.w)
    m = jnp.where(x.w < y.w, x.m, jnp.where(tie, x.m + y.m, y.m))
    return Multpath(w, m)


def centpath_combine(x: Centpath, y: Centpath) -> Centpath:
    """Elementwise ⊗: max weight, sum p and c on exact ties."""
    w = jnp.maximum(x.w, y.w)
    tie = (x.w == y.w) & jnp.isfinite(x.w)
    p = jnp.where(x.w > y.w, x.p, jnp.where(tie, x.p + y.p, y.p))
    c = jnp.where(x.w > y.w, x.c, jnp.where(tie, x.c + y.c, y.c))
    return Centpath(w, p, c)


# ---------------------------------------------------------------------------
# Dense regime: blocked generalized matmul.
# ---------------------------------------------------------------------------


def _mp_block(Fw, Fm, Ablk):
    """min-plus with multiplicities over one k-block.

    Fw, Fm: (nb, bk); Ablk: (bk, n) -> (nb, n) pair.
    """
    cand = Fw[:, :, None] + Ablk[None, :, :]  # (nb, bk, n); inf + x = inf
    w = jnp.min(cand, axis=1)
    tie = (cand == w[:, None, :]) & jnp.isfinite(cand)
    m = jnp.sum(jnp.where(tie, Fm[:, :, None], 0.0), axis=1)
    return w, m


def multpath_relax_dense(F: Multpath, A: jax.Array, *, block: int = 256,
                         unroll: bool = False) -> Multpath:
    """``C = F •_(⊕,f) A``: C(s,v) = ⊕_u f(F(s,u), A(u,v)).

    F.w/F.m: (nb, k); A: (k, n_out) with inf off-structure. Returns
    (nb, n_out). Blocked over the contraction dim to keep the
    (nb, bk, n_out) intermediate bounded.
    """
    nb, k = F.w.shape
    n_out = A.shape[1]
    block = min(block, k)
    nblk = -(-k // block)
    kpad = nblk * block
    Fw = jnp.pad(F.w, ((0, 0), (0, kpad - k)), constant_values=INF)
    Fm = jnp.pad(F.m, ((0, 0), (0, kpad - k)))
    Ap = jnp.pad(A, ((0, kpad - k), (0, 0)), constant_values=INF)
    Fw = Fw.reshape(nb, nblk, block)
    Fm = Fm.reshape(nb, nblk, block)
    Ap = Ap.reshape(nblk, block, n_out)

    def step(acc, blk):
        fw, fm, ab = blk
        w, m = _mp_block(fw, fm, ab)
        return multpath_combine(acc, Multpath(w, m)), None

    init = multpath_identity((nb, n_out), F.w.dtype)
    if unroll:  # exact cost accounting for the dry-run (scan counts once)
        acc = init
        for i in range(nblk):
            acc, _ = step(acc, (Fw[:, i], Fm[:, i], Ap[i]))
        return acc
    out, _ = jax.lax.scan(step, init,
                          (jnp.moveaxis(Fw, 1, 0), jnp.moveaxis(Fm, 1, 0), Ap))
    return out


def _cp_block(Fw, Fp, Bblk):
    """max-select with p/c tie sums over one k-block.

    Fw, Fp: (nb, bk); Bblk: (bk, n). Inactive F entries carry w = -inf.
    cand(s, v) = F.w(s, u) - B(u, v); inactive or no-edge -> -inf.
    """
    cand = Fw[:, :, None] - Bblk[None, :, :]
    cand = jnp.where(jnp.isfinite(Fw)[:, :, None] & jnp.isfinite(Bblk)[None, :, :],
                     cand, -INF)
    w = jnp.max(cand, axis=1)
    tie = (cand == w[:, None, :]) & jnp.isfinite(cand)
    p = jnp.sum(jnp.where(tie, Fp[:, :, None], 0.0), axis=1)
    c = jnp.sum(jnp.where(tie, 1.0, 0.0), axis=1)
    return w, p, c


def centpath_relax_dense(F: Centpath, B: jax.Array, *, block: int = 256,
                         unroll: bool = False) -> Centpath:
    """``C = F •_(⊗,g) B`` with contraction over B's first axis.

    For the Brandes step the caller passes ``B = A.T`` so that
    ``C(s, v) = ⊗_u g(F(s, u), A(v, u))`` — contributions flow from
    SP-DAG children ``u`` back to predecessors ``v``.
    """
    nb, k = F.w.shape
    n_out = B.shape[1]
    block = min(block, k)
    nblk = -(-k // block)
    kpad = nblk * block
    Fw = jnp.pad(F.w, ((0, 0), (0, kpad - k)), constant_values=-INF)
    Fp = jnp.pad(F.p, ((0, 0), (0, kpad - k)))
    Bp = jnp.pad(B, ((0, kpad - k), (0, 0)), constant_values=INF)
    Fw = Fw.reshape(nb, nblk, block)
    Fp = Fp.reshape(nb, nblk, block)
    Bp = Bp.reshape(nblk, block, n_out)

    def step(acc, blk):
        fw, fp, bb = blk
        w, p, c = _cp_block(fw, fp, bb)
        return centpath_combine(acc, Centpath(w, p, c)), None

    init = centpath_identity((nb, n_out), F.w.dtype)
    if unroll:
        acc = init
        for i in range(nblk):
            acc, _ = step(acc, (Fw[:, i], Fp[:, i], Bp[i]))
        return acc
    out, _ = jax.lax.scan(step, init,
                          (jnp.moveaxis(Fw, 1, 0), jnp.moveaxis(Fp, 1, 0), Bp))
    return out


def count_sp_children_dense(Tw: jax.Array, A: jax.Array, *, block: int = 256
                            ) -> jax.Array:
    """c0(s, v) = #{u : T(s,v).w + A(v,u) == T(s,u).w, both finite}.

    The number of shortest-path-DAG children of v (vertices whose shortest
    path's last hop leaves v). Blocked over v's out-neighborhood.
    """
    nb, n = Tw.shape
    block = min(block, n)
    nblk = -(-n // block)
    npad = nblk * block
    Ap = jnp.pad(A, ((0, 0), (0, npad - n)), constant_values=INF)

    def step(acc, ub):
        Ablk = jax.lax.dynamic_slice_in_dim(Ap, ub * block, block, axis=1)  # (n, bk)
        Twu = jax.lax.dynamic_slice_in_dim(
            jnp.pad(Tw, ((0, 0), (0, npad - n)), constant_values=INF),
            ub * block, block, axis=1)  # (nb, bk)
        # cand(s, v, u) = Tw(s, v) + A(v, u)
        cand = Tw[:, :, None] + Ablk[None, :, :]
        hit = (cand == Twu[:, None, :]) & jnp.isfinite(cand)
        return acc + jnp.sum(hit, axis=2), None

    acc0 = jnp.zeros((nb, n), jnp.int32)
    out, _ = jax.lax.scan(step, acc0, jnp.arange(nblk))
    return out


# ---------------------------------------------------------------------------
# COO (sparse) regime: segment-op relaxations.
# ---------------------------------------------------------------------------


def multpath_relax_coo(F: Multpath, src: jax.Array, dst: jax.Array,
                       w: jax.Array, n: int) -> Multpath:
    """Edge-list version of ``multpath_relax_dense``.

    src/dst/w: (E,) padded COO arcs (padding arcs carry w = inf).
    F.w/F.m: (nb, n). Cost O(nb * E); chunk over nb upstream if needed.
    """
    cand = F.w[:, src] + w[None, :]  # (nb, E)
    minw = jax.ops.segment_min(cand.T, dst, num_segments=n,
                               indices_are_sorted=False).T  # (nb, n)
    tie = (cand == minw[:, dst]) & jnp.isfinite(cand)
    contrib = jnp.where(tie, F.m[:, src], 0.0)
    m = jax.ops.segment_sum(contrib.T, dst, num_segments=n).T
    # segment_min of empty segments yields +inf-ish max value for floats;
    # normalize: entries with zero multiplicity are inactive.
    minw = jnp.where(m > 0, minw, INF)
    return Multpath(minw, m)


def centpath_relax_coo(F: Centpath, src: jax.Array, dst: jax.Array,
                       w: jax.Array, n: int) -> Centpath:
    """Edge-list Brandes action: contributions flow dst -> src.

    For arc (v -> u, a): cand(s, v) over children u: F.w(s, u) - a.
    Segment over ``src`` (the predecessor side).
    """
    cand = F.w[:, dst] - w[None, :]  # (nb, E)
    active = jnp.isfinite(F.w[:, dst]) & jnp.isfinite(w)[None, :]
    cand = jnp.where(active, cand, -INF)
    maxw = jax.ops.segment_max(cand.T, src, num_segments=n).T  # (nb, n)
    tie = (cand == maxw[:, src]) & jnp.isfinite(cand)
    p = jax.ops.segment_sum(jnp.where(tie, F.p[:, dst], 0.0).T, src,
                            num_segments=n).T
    c = jax.ops.segment_sum(jnp.where(tie, 1.0, 0.0).T, src, num_segments=n).T
    maxw = jnp.where(c > 0, maxw, -INF)
    return Centpath(maxw, p, c)


def count_sp_children_coo(Tw: jax.Array, src: jax.Array, dst: jax.Array,
                          w: jax.Array, n: int) -> jax.Array:
    """COO version of ``count_sp_children_dense``: segment over ``src``."""
    cand = Tw[:, src] + w[None, :]  # (nb, E)
    hit = (cand == Tw[:, dst]) & jnp.isfinite(cand)
    return jax.ops.segment_sum(hit.astype(jnp.int32).T, src,
                               num_segments=n).T


# ---------------------------------------------------------------------------
# Frontier-compacted CSR regime: work tracks the maximal frontier.
# ---------------------------------------------------------------------------


def _compact_cols(mask: jax.Array, indptr: jax.Array, vcap: int):
    """Compact the frontier's active *columns* into ``vcap`` slots.

    mask: (nb, n) bool frontier occupancy. A column (vertex) is active
    when any batch row holds it — the union frontier. Compacting columns
    instead of (row, vertex) pairs keeps the batch axis contiguous, so
    the relax below runs the same SIMD-friendly 2D segment ops as the
    COO kernels, just over the frontier's incident arc set. Returns
    (u, offs): per-slot vertex id and the inclusive cumsum of per-slot
    arc degrees (``offs[-1]`` = total incident arcs). Slots past the
    population carry degree 0, so they own no arc range.
    """
    n = mask.shape[1]
    cols = jnp.nonzero(jnp.any(mask, axis=0), size=vcap, fill_value=n)[0]
    valid = cols < n
    u = jnp.where(valid, cols, 0).astype(jnp.int32)
    deg = jnp.where(valid, indptr[u + 1] - indptr[u], 0)
    offs = jnp.cumsum(deg)
    return u, offs


def _expand_edges(u: jax.Array, offs: jax.Array, indptr: jax.Array,
                  ecap: int):
    """Expand compacted slots into ``ecap`` load-balanced arc slots.

    Owner assignment is a scatter of each populated slot's start offset
    followed by a cumulative max — two linear passes over ``ecap``, no
    per-arc binary search. Returns (owner, arc_id, live); dead slots
    (``pos >= offs[-1]``) are masked.
    """
    vcap = u.shape[0]
    pos = jnp.arange(ecap, dtype=offs.dtype)
    starts = jnp.concatenate([jnp.zeros((1,), offs.dtype), offs[:-1]])
    slots = jnp.arange(vcap, dtype=jnp.int32)
    # Degree-0 slots share a start with their successor; dropping them
    # keeps the cummax from handing their (empty) range to the wrong owner.
    tgt = jnp.where(offs > starts, starts, ecap)
    owner = jnp.zeros((ecap,), jnp.int32).at[tgt].max(slots, mode="drop")
    j = jax.lax.cummax(owner)
    live = pos < offs[-1]
    eid = jnp.where(live, indptr[u[j]] + (pos - starts[j]), 0)
    return j, eid.astype(jnp.int32), live


def multpath_relax_csr(F: Multpath, indptr: jax.Array, dst: jax.Array,
                       w: jax.Array, n: int, *, vcap: int, ecap: int
                       ) -> Multpath:
    """Frontier-compacted ``multpath_relax_coo`` over by-src CSR arcs.

    Only arcs leaving the union frontier are touched: active columns
    compact into ``vcap`` slots, their out-arc ranges into ``ecap`` arc
    slots, and (nb, ecap) candidates scatter with the same batched 2D
    segment ops as the COO kernel. Dead arc slots carry w = inf — the
    COO kernel's own padding idiom — so they are monoid-inert. The
    result is exactly ``multpath_relax_coo`` *provided* the frontier
    fits — active columns ``<= vcap`` and incident arcs ``<= ecap`` —
    which the caller guarantees by capacity-bucket selection
    (``CsrAdj``): arcs from inactive columns hold F.w = inf in every
    batch row and can never win a segment min.
    """
    mask = jnp.isfinite(F.w)
    u, offs = _compact_cols(mask, indptr, vcap)
    j, eid, live = _expand_edges(u, offs, indptr, ecap)
    uj = u[j]
    wa = jnp.where(live, w[eid], INF)
    seg = jnp.where(live, dst[eid], 0)
    cand = F.w[:, uj] + wa[None, :]  # (nb, ecap)
    minw = jax.ops.segment_min(cand.T, seg, num_segments=n).T
    tie = (cand == minw[:, seg]) & jnp.isfinite(cand)
    m = jax.ops.segment_sum(jnp.where(tie, F.m[:, uj], 0.0).T, seg,
                            num_segments=n).T
    minw = jnp.where(m > 0, minw, INF)
    return Multpath(minw, m)


def centpath_relax_csr(F: Centpath, indptr_in: jax.Array, src_in: jax.Array,
                       w_in: jax.Array, n: int, *, vcap: int, ecap: int
                       ) -> Centpath:
    """Frontier-compacted ``centpath_relax_coo`` over by-dst (CSC) arcs.

    The active side of the Brandes action is the *child* (the arc's
    dst): active child columns compact into slots, each child's in-arc
    range expands, and (nb, ecap) candidates scatter to the predecessor
    side with the batched 2D segment ops of the COO kernel. Equals
    ``centpath_relax_coo`` under the same capacity proviso.
    """
    mask = jnp.isfinite(F.w)
    u, offs = _compact_cols(mask, indptr_in, vcap)
    j, eid, live = _expand_edges(u, offs, indptr_in, ecap)
    uj = u[j]
    wa = w_in[eid]
    alive = live & jnp.isfinite(wa)  # padding arcs never contribute
    seg = jnp.where(alive, src_in[eid], 0)
    Fw = F.w[:, uj]
    cand = jnp.where(alive[None, :] & jnp.isfinite(Fw),
                     Fw - wa[None, :], -INF)  # (nb, ecap)
    maxw = jax.ops.segment_max(cand.T, seg, num_segments=n).T
    tie = (cand == maxw[:, seg]) & jnp.isfinite(cand)
    p = jax.ops.segment_sum(jnp.where(tie, F.p[:, uj], 0.0).T, seg,
                            num_segments=n).T
    c = jax.ops.segment_sum(jnp.where(tie, 1.0, 0.0).T, seg,
                            num_segments=n).T
    maxw = jnp.where(c > 0, maxw, -INF)
    return Centpath(maxw, p, c)
