"""BFS-based batched betweenness centrality (the "CombBLAS-like" baseline).

Unweighted graphs only. This is the matrix-algebraic Brandes formulation
the paper compares against (Section 7): forward BFS waves accumulate σ and
depth; the backward sweep walks depth levels from the deepest frontier to
the root. Unlike MFBC, (a) it cannot handle weights and (b) each vertex
appears in exactly one frontier, so the frontier schedule is the BFS level
structure rather than the maximal frontier.

Implemented with the same adjacency containers as MFBC so the benchmark
comparison isolates the algorithmic difference.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adjacency import CooAdj, DenseAdj, coo_adj_from_graph, \
    dense_adj_from_graph
from repro.core.monoids import INF, Multpath
from repro.graphs.formats import Graph


def _bfs_forward(adj, sources, max_depth):
    """Returns depth (nb, n) float (inf unreached) and sigma (nb, n)."""
    n = adj.n
    nb = sources.shape[0]
    depth = jnp.full((nb, n), INF).at[jnp.arange(nb), sources].set(0.0)
    sigma = jnp.zeros((nb, n)).at[jnp.arange(nb), sources].set(1.0)
    f_sigma = sigma

    def body(lev, state):
        depth, sigma, f_sigma = state
        # propagate path counts one hop: contributions of current frontier
        C = adj.relax_mp(Multpath(jnp.where(f_sigma > 0, depth, INF), f_sigma))
        # newly reached vertices at this level
        new = (C.m > 0) & ~jnp.isfinite(depth)
        depth = jnp.where(new, lev + 1.0, depth)
        sigma = sigma + jnp.where(new, C.m, 0.0)
        f_sigma = jnp.where(new, C.m, 0.0)
        return depth, sigma, f_sigma

    depth, sigma, _ = jax.lax.fori_loop(0, max_depth, body,
                                        (depth, sigma, f_sigma))
    return depth, sigma


def _backward(adj, depth, sigma, max_depth):
    """δ accumulation level by level (classic algebraic Brandes)."""
    sigma_safe = jnp.where(sigma > 0, sigma, 1.0)
    delta = jnp.zeros_like(sigma)

    def body(i, delta):
        lev = max_depth - i  # sweep levels max_depth .. 1
        # frontier: vertices at depth == lev carrying (1 + δ)/σ
        fp = jnp.where(depth == lev, (1.0 + delta) / sigma_safe, 0.0)
        from repro.core.monoids import Centpath
        P = adj.relax_cp(Centpath(jnp.where(depth == lev, depth, -INF), fp,
                                  jnp.where(depth == lev, 1.0, 0.0)))
        # predecessors are exactly one level up
        take = (P.w == depth) & (depth == lev - 1.0) & (P.c > 0)
        return delta + jnp.where(take, P.p * sigma, 0.0)

    delta = jax.lax.fori_loop(0, max_depth, body, delta)
    return delta


@functools.partial(jax.jit, static_argnames=("max_depth",))
def bfs_bc_batch(adj, sources, valid, *, max_depth: int):
    depth, sigma = _bfs_forward(adj, sources, max_depth)
    nb = sources.shape[0]
    rows = jnp.arange(nb)
    # exclude t = s and v = s as in MFBC
    depth = depth.at[rows, sources].set(INF)
    delta = _backward(adj, depth, sigma, max_depth)
    contrib = jnp.where(jnp.isfinite(depth) & valid[:, None], delta, 0.0)
    return jnp.sum(contrib, axis=0)


def bfs_bc(g: Graph, *, n_b: Optional[int] = None, backend: str = "dense",
           max_depth: Optional[int] = None) -> np.ndarray:
    """Full unweighted BC via the BFS baseline."""
    assert np.all(g.w == 1.0), "bfs_bc is the unweighted baseline"
    n = g.n
    if n_b is None:
        n_b = min(n, 64)
    if max_depth is None:
        max_depth = n - 1
    adj = dense_adj_from_graph(g) if backend == "dense" else coo_adj_from_graph(g)
    lam = np.zeros(n, dtype=np.float64)
    for b in range(-(-n // n_b)):
        chunk = np.arange(b * n_b, min((b + 1) * n_b, n), dtype=np.int32)
        valid = np.ones(chunk.shape[0], dtype=bool)
        if chunk.shape[0] < n_b:
            pad = n_b - chunk.shape[0]
            chunk = np.concatenate([chunk, np.zeros(pad, np.int32)])
            valid = np.concatenate([valid, np.zeros(pad, bool)])
        lam += np.asarray(bfs_bc_batch(adj, jnp.asarray(chunk),
                                       jnp.asarray(valid),
                                       max_depth=max_depth), np.float64)
    return lam
