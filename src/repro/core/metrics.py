"""Metric registry — the sweep structure of every supported graph metric.

The paper closes with "our design methodology is readily extensible to
other graph problems": every metric here is an alternate monoid sweep
over the same relaxation engine (``adjacency.relax_mp`` — dense, COO and
CSR backends all work by construction, since they implement the shared
relax protocol).

``MetricSpec`` is the metric analogue of ``repro.bc.executor.BackendSpec``:
a frozen description of *how a metric sweeps* — how many α-β-priced relax
sweeps a batch costs (the planner multiplies its per-iteration step model
by this), whether the sampled estimator path applies, whether the forward
sweep is hop-bounded, and which fused ``step_segmented`` group the metric
may share a device batch with. The registry is the single source of truth
for ``BCQuery`` validation, planner pricing, executor dispatch and the
serving layer's cross-metric fusion grouping.

Per-source contribution semantics (all share MFBF's maximal-frontier
forward sweep and the ``t = s`` self-mask):

* ``betweenness`` — δ_s(v) = ζ(s, v)·σ̄(s, v): forward + backward sweep
  (Algorithm 3), the paper's own workload.
* ``closeness``   — δ_s(v) = τ(s, v) where finite: the farness / SSSP
  distance-profile aggregate, forward sweep only (the source's own
  column is masked to ∞ and contributes 0, exactly like d(s, s) = 0).
* ``khop``        — δ_s(v) = 1 iff v is within ``hops`` edges of s:
  Lemma 4.1's invariant (after j iterations T holds all paths of
  ≤ j+1 edges) makes this a *bounded* forward sweep of ``hops - 1``
  iterations — finiteness of T is hop-bounded reachability.
* ``components``  — weak connectivity as a min-label fixed point over
  the zero-weight symmetrized arc structure: one (1, n) Multpath row
  holding per-vertex labels, relaxed until no label improves. Exact by
  construction (labels are integer-valued f32, exact to 2²⁴), so it
  bypasses the estimator entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.monoids import INF, Multpath, multpath_combine
from repro.graphs.formats import Graph


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """How one metric sweeps through the shared relaxation engine.

    Attributes:
      name: registry key (``BCQuery.metric`` values).
      sweeps: α-β-priced relax sweeps per batch — the planner prices
        ``iters_total = sweeps * est_iters * n_batches``, so forward-only
        metrics cost half of BC's forward+backward pair.
      sampled: the adaptive-sampling estimator path applies (per-source
        contributions are i.i.d. samples of a per-vertex total).
      needs_backward: the batch body runs MFBr after MFBF.
      bounded: the forward sweep is bounded by ``BCQuery.hops``.
      fixed_point: whole-graph label fixed point — exact only, computed
        in one executor call (``BatchExecutor.labels``), never sampled
        and never fused.
      description: one line for docs / ``/v1/metrics`` surfaces.
    """

    name: str
    sweeps: int
    sampled: bool
    needs_backward: bool = False
    bounded: bool = False
    fixed_point: bool = False
    description: str = ""


_METRIC_REGISTRY: Dict[str, MetricSpec] = {}


def register_metric(spec: MetricSpec) -> MetricSpec:
    """Register (or override) the spec for a metric name."""
    _METRIC_REGISTRY[spec.name] = spec
    return spec


def metric_spec(name: str) -> MetricSpec:
    try:
        return _METRIC_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown metric {name!r} (registered: "
            f"{', '.join(sorted(_METRIC_REGISTRY))})") from None


def registered_metrics() -> Tuple[str, ...]:
    return tuple(sorted(_METRIC_REGISTRY))


def fuse_group(name: str, hops: int = 0) -> str:
    """``step_segmented`` compatibility key: requests whose groups match
    may share one fused device batch (identical forward-sweep structure);
    mismatched groups fall back to separate drains.

    Unbounded forward sweeps all share ``"sweep"`` (a closeness epoch and
    a BC forward sweep run the same relax sequence — BC rows just also
    feed the backward sweep). Hop-bounded sweeps group per bound, and
    fixed-point metrics never fuse.
    """
    spec = metric_spec(name)
    if spec.fixed_point:
        return f"fixed_point:{name}"
    if spec.bounded:
        return f"bounded:{int(hops)}"
    return "sweep"


register_metric(MetricSpec(
    name="betweenness", sweeps=2, sampled=True, needs_backward=True,
    description="shortest-path betweenness λ(v) (Algorithm 3, "
                "forward + backward sweep)"))
register_metric(MetricSpec(
    name="closeness", sweeps=1, sampled=True,
    description="farness Σ_s τ(s, v) — the SSSP distance-profile "
                "aggregate, forward sweep only"))
register_metric(MetricSpec(
    name="khop", sweeps=1, sampled=True, bounded=True,
    description="k-hop in-reachability |{s : τ_hops(s, v) < ∞}| — "
                "bounded forward sweep (Lemma 4.1)"))
register_metric(MetricSpec(
    name="components", sweeps=1, sampled=False, fixed_point=True,
    description="weakly connected components as a min-label fixed point "
                "over the zero-weight symmetrized structure"))

METRICS = registered_metrics()


# ------------------------------------------------------------ components
def components_graph(g: Graph) -> Graph:
    """The zero-weight symmetrized pseudo-graph the label sweep runs on.

    Weak connectivity ignores direction and weight: symmetrize the arc
    structure, then zero the weights so relaxation propagates labels
    unchanged (label + 0 = label). Any backend adjacency factory accepts
    the result — padding arcs stay ∞-weighted self loops, so they remain
    algebraically invisible.
    """
    sym = g.symmetrize()
    return Graph(sym.n, sym.src, sym.dst,
                 np.zeros(sym.nnz, dtype=np.float32),
                 directed=False, name=f"{g.name}+cc")


@jax.jit
def components_labels(adj) -> jax.Array:
    """Min-label fixed point: (n,) f32 labels, one per weak component.

    One (1, n) Multpath row holds the current labels (initially each
    vertex's own id). Each relax computes, per vertex, the minimum label
    over in-neighbors on the zero-weight structure; the frontier keeps
    only improved entries, and the loop stops when nothing improves.
    Labels are integer-valued f32 (exact to 2²⁴), so the fixed point is
    bitwise the min vertex id of each component — identical to a host
    union-find (``brandes_ref.cc_ref``).
    """
    n = adj.n
    ids = jnp.arange(n, dtype=jnp.float32)[None, :]
    T0 = Multpath(ids, jnp.ones_like(ids))

    def cond(state):
        return (state[2] > 0) & (state[3] < n)

    def body(state):
        T, F, _, it = state
        C = adj.relax_mp(F)
        T_new = multpath_combine(T, C)
        improved = T_new.w < T.w
        F_new = Multpath(jnp.where(improved, T_new.w, INF),
                         jnp.where(improved, 1.0, 0.0))
        return (T_new, F_new, jnp.sum(improved.astype(jnp.int32)),
                it + 1)

    T, _, _, _ = jax.lax.while_loop(
        cond, body, (T0, T0, jnp.int32(1), jnp.int32(0)))
    return T.w[0]
