"""Adjacency containers usable inside jit (registered pytrees).

``DenseAdj`` wraps an ``(n, n)`` float matrix with ``inf`` off-structure.
``CooAdj`` wraps padded edge arrays (static nnz). Both expose the two
monoid relaxations and the SP-DAG child count; dispatch is static (python
``isinstance``), so a jitted function specializes per format.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import monoids
from repro.core.monoids import Centpath, Multpath
from repro.graphs.formats import Graph, coo_to_dense, pad_edges


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseAdj:
    a: jax.Array  # (n, n), inf off-structure
    block: int = 512
    use_kernel: bool = False  # route dense relax through the Pallas kernels

    def tree_flatten(self):
        return (self.a,), (self.block, self.use_kernel)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    @property
    def n(self) -> int:
        return self.a.shape[-1]

    def gather_rows(self, sources: jax.Array) -> jax.Array:
        return self.a[sources, :]

    def relax_mp(self, F: Multpath) -> Multpath:
        if self.use_kernel:
            from repro.kernels import ops as kops

            w, m = kops.multpath_matmul(F.w, F.m, self.a)
            return Multpath(w, m)
        return monoids.multpath_relax_dense(F, self.a, block=self.block)

    def relax_cp(self, F: Centpath) -> Centpath:
        if self.use_kernel:
            from repro.kernels import ops as kops

            w, p, c = kops.centpath_matmul(F.w, F.p, self.a.T)
            return Centpath(w, p, c)
        return monoids.centpath_relax_dense(F, self.a.T, block=self.block)

    def count_sp_children(self, Tw: jax.Array) -> jax.Array:
        return monoids.count_sp_children_dense(Tw, self.a, block=self.block)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CooAdj:
    src: jax.Array  # (E,) int32, padded
    dst: jax.Array  # (E,) int32
    w: jax.Array  # (E,) float32, padding = inf
    n_static: int
    row_w: jax.Array  # (n,) unused placeholder for row gather; see gather_rows

    def tree_flatten(self):
        return (self.src, self.dst, self.w, self.row_w), (self.n_static,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux[0], children[3])

    @property
    def n(self) -> int:
        return self.n_static

    def gather_rows(self, sources: jax.Array) -> jax.Array:
        """Rows of the dense adjacency for the given sources: (nb, n).

        One scatter-min per batch: for arcs with src in ``sources`` place w.
        """
        nb = sources.shape[0]
        # match arcs to batch rows: (nb, E) bool — memory O(nb*E), fine for
        # the batch sizes used; chunked upstream for huge graphs.
        hit = self.src[None, :] == sources[:, None]
        cand = jnp.where(hit, self.w[None, :], jnp.inf)
        out = jax.ops.segment_min(cand.T, self.dst, num_segments=self.n).T
        return jnp.where(jnp.isfinite(out), out, jnp.inf)

    def relax_mp(self, F: Multpath) -> Multpath:
        return monoids.multpath_relax_coo(F, self.src, self.dst, self.w, self.n)

    def relax_cp(self, F: Centpath) -> Centpath:
        return monoids.centpath_relax_coo(F, self.src, self.dst, self.w, self.n)

    def count_sp_children(self, Tw: jax.Array) -> jax.Array:
        return monoids.count_sp_children_coo(Tw, self.src, self.dst, self.w,
                                             self.n)


def dense_adj_from_graph(g: Graph, *, block: int = 512,
                         use_kernel: bool = False) -> DenseAdj:
    return DenseAdj(jnp.asarray(coo_to_dense(g)), block=block,
                    use_kernel=use_kernel)


def coo_adj_from_graph(g: Graph, *, pad_multiple: int = 128) -> CooAdj:
    src, dst, w = pad_edges(g, multiple=pad_multiple)
    return CooAdj(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w),
                  g.n, jnp.zeros((g.n,), jnp.float32))
