"""Adjacency containers usable inside jit (registered pytrees).

``DenseAdj`` wraps an ``(n, n)`` float matrix with ``inf`` off-structure.
``CooAdj`` wraps padded edge arrays (static nnz). ``CsrAdj`` carries the
same arcs sorted both ways (by src and by dst) with row pointers, so its
relaxations can compact the active frontier and touch only incident arc
ranges. All expose the two monoid relaxations and the SP-DAG child count;
dispatch is static (python ``isinstance``), so a jitted function
specializes per format.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import monoids
from repro.core.monoids import Centpath, Multpath
from repro.graphs.formats import Graph, coo_to_dense, pad_edges


class RelaxStats(NamedTuple):
    """Cheap side output of one frontier-compacted relaxation.

    ``bucket`` is the capacity-ladder index that served the call
    (``len(caps)`` = the full-edge-list fallback, -1 = the backend has no
    compaction at all); ``overflow`` is 1 iff the fallback ran.
    """

    nnz: jax.Array  # int32 — active frontier entries seen by this relax
    arcs: jax.Array  # int32 — arc slots the frontier's ranges needed
    bucket: jax.Array  # int32 — ladder index chosen
    overflow: jax.Array  # int32 — 1 iff the full-edge-list fallback ran


def _gather_rows_scatter(src: jax.Array, dst: jax.Array, w: jax.Array,
                         n: int, sources: jax.Array) -> jax.Array:
    """Rows of the dense adjacency for ``sources``: (nb, n).

    Scatters each arc's weight into row ``searchsorted(sorted(sources),
    src)`` and reduces with one ``segment_min`` over (nb*n + 1) flat
    segments (the +1 is the dump for arcs whose src is not sampled) —
    O(E log nb + nb*n) instead of an (nb, E) boolean hit matrix. The
    final gather maps sorted rows back to the callers' order (duplicate
    sources all read the first occurrence's row).
    """
    nb = sources.shape[0]
    ss = jnp.sort(sources)
    rc = jnp.clip(jnp.searchsorted(ss, src), 0, nb - 1)
    flat = jnp.where(ss[rc] == src, rc * n + dst, nb * n)
    out = jax.ops.segment_min(w, flat, num_segments=nb * n + 1)
    out = out[:-1].reshape(nb, n)
    out = jnp.where(jnp.isfinite(out), out, jnp.inf)
    return out[jnp.searchsorted(ss, sources)]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseAdj:
    a: jax.Array  # (n, n), inf off-structure
    block: int = 512
    use_kernel: bool = False  # route dense relax through the Pallas kernels
    # Transpose hoisted out of the relax loop: computed once at build and
    # carried as a pytree leaf, so jitted relax_cp never re-transposes.
    at: Optional[jax.Array] = None

    def __post_init__(self):
        if self.at is None:
            self.at = self.a.T

    def tree_flatten(self):
        return (self.a, self.at), (self.block, self.use_kernel)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0], aux[1], children[1])

    @property
    def n(self) -> int:
        return self.a.shape[-1]

    def gather_rows(self, sources: jax.Array) -> jax.Array:
        return self.a[sources, :]

    def relax_mp(self, F: Multpath) -> Multpath:
        if self.use_kernel:
            from repro.kernels import ops as kops

            w, m = kops.multpath_matmul(F.w, F.m, self.a)
            return Multpath(w, m)
        return monoids.multpath_relax_dense(F, self.a, block=self.block)

    def relax_cp(self, F: Centpath) -> Centpath:
        if self.use_kernel:
            from repro.kernels import ops as kops

            w, p, c = kops.centpath_matmul(F.w, F.p, self.at)
            return Centpath(w, p, c)
        return monoids.centpath_relax_dense(F, self.at, block=self.block)

    def count_sp_children(self, Tw: jax.Array) -> jax.Array:
        return monoids.count_sp_children_dense(Tw, self.a, block=self.block)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CooAdj:
    src: jax.Array  # (E,) int32, padded
    dst: jax.Array  # (E,) int32
    w: jax.Array  # (E,) float32, padding = inf
    n_static: int

    def tree_flatten(self):
        return (self.src, self.dst, self.w), (self.n_static,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux[0])

    @property
    def n(self) -> int:
        return self.n_static

    def gather_rows(self, sources: jax.Array) -> jax.Array:
        return _gather_rows_scatter(self.src, self.dst, self.w, self.n,
                                    sources)

    def relax_mp(self, F: Multpath) -> Multpath:
        return monoids.multpath_relax_coo(F, self.src, self.dst, self.w, self.n)

    def relax_cp(self, F: Centpath) -> Centpath:
        return monoids.centpath_relax_coo(F, self.src, self.dst, self.w, self.n)

    def count_sp_children(self, Tw: jax.Array) -> jax.Array:
        return monoids.count_sp_children_coo(Tw, self.src, self.dst, self.w,
                                             self.n)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CsrAdj:
    """Dual-sorted arc lists with frontier-compacted relaxations.

    The same arcs are carried twice: sorted by src with row pointers
    (``indptr``/``src``/``dst``/``w`` — the by-src arrays double as valid
    COO for the overflow fallback) and sorted by dst (``indptr_in``/
    ``src_in``/``w_in`` — the CSC side MFBr's backward action expands).
    ``caps`` is the static power-of-two capacity ladder ``((vcap, ecap),
    ...)``: each relax counts the *union-column* frontier (vertices
    active in any batch row) and its incident arcs, picks the smallest
    bucket that fits with ``lax.switch``, and falls back to the
    full-edge-list COO relax when every bucket overflows — so results
    never depend on the ladder, only the work does.
    """

    indptr: jax.Array  # (n+1,) int32 row pointers into the by-src arrays
    src: jax.Array  # (E,) int32, sorted ascending
    dst: jax.Array  # (E,) int32
    w: jax.Array  # (E,) float32, padding = inf
    indptr_in: jax.Array  # (n+1,) int32 row pointers into the by-dst arrays
    src_in: jax.Array  # (E,) int32 — predecessor of each in-arc
    w_in: jax.Array  # (E,) float32
    n_static: int
    caps: Tuple[Tuple[int, int], ...]

    def tree_flatten(self):
        return ((self.indptr, self.src, self.dst, self.w,
                 self.indptr_in, self.src_in, self.w_in),
                (self.n_static, self.caps))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0], aux[1])

    @property
    def n(self) -> int:
        return self.n_static

    def gather_rows(self, sources: jax.Array) -> jax.Array:
        return _gather_rows_scatter(self.src, self.dst, self.w, self.n,
                                    sources)

    def _pick_bucket(self, mask: jax.Array, indptr: jax.Array):
        """Count the union-column frontier and choose the smallest fitting
        bucket. ``nnz`` is active *columns* (vertices live in any batch
        row — what the compacting relaxes expand), ``arcs`` their
        incident arc total."""
        deg = indptr[1:] - indptr[:-1]
        colmask = jnp.any(mask, axis=0)
        nnz = jnp.sum(colmask.astype(jnp.int32))
        arcs = jnp.sum(jnp.where(colmask, deg, 0)).astype(jnp.int32)
        bucket = jnp.int32(len(self.caps))
        for i in reversed(range(len(self.caps))):
            vcap, ecap = self.caps[i]
            fits = (nnz <= vcap) & (arcs <= ecap)
            bucket = jnp.where(fits, jnp.int32(i), bucket)
        return nnz, arcs, bucket

    def relax_mp_stats(self, F: Multpath) -> Tuple[Multpath, RelaxStats]:
        nnz, arcs, bucket = self._pick_bucket(jnp.isfinite(F.w), self.indptr)
        branches = [functools.partial(
            monoids.multpath_relax_csr, indptr=self.indptr, dst=self.dst,
            w=self.w, n=self.n, vcap=v, ecap=e) for v, e in self.caps]
        branches.append(lambda Fb: monoids.multpath_relax_coo(
            Fb, self.src, self.dst, self.w, self.n))
        out = jax.lax.switch(bucket, branches, F)
        overflow = (bucket == len(self.caps)).astype(jnp.int32)
        return out, RelaxStats(nnz, arcs, bucket, overflow)

    def relax_cp_stats(self, F: Centpath) -> Tuple[Centpath, RelaxStats]:
        nnz, arcs, bucket = self._pick_bucket(jnp.isfinite(F.w),
                                              self.indptr_in)
        branches = [functools.partial(
            monoids.centpath_relax_csr, indptr_in=self.indptr_in,
            src_in=self.src_in, w_in=self.w_in, n=self.n, vcap=v, ecap=e)
            for v, e in self.caps]
        branches.append(lambda Fb: monoids.centpath_relax_coo(
            Fb, self.src, self.dst, self.w, self.n))
        out = jax.lax.switch(bucket, branches, F)
        overflow = (bucket == len(self.caps)).astype(jnp.int32)
        return out, RelaxStats(nnz, arcs, bucket, overflow)

    def relax_mp(self, F: Multpath) -> Multpath:
        return self.relax_mp_stats(F)[0]

    def relax_cp(self, F: Centpath) -> Centpath:
        return self.relax_cp_stats(F)[0]

    def count_sp_children(self, Tw: jax.Array) -> jax.Array:
        return monoids.count_sp_children_coo(Tw, self.src, self.dst, self.w,
                                             self.n)


def frontier_caps(n_b: int, n: int, m: int) -> Tuple[Tuple[int, int], ...]:
    """Power-of-two ``(vcap, ecap)`` escalation ladder for compaction.

    ``vcap`` bounds the compacted union-frontier *columns* (vertices
    active in any batch row), ``ecap`` their incident arc slots. A
    compact relax costs ``n_b * ecap`` candidate work plus an O(n)
    compaction, against ``n_b * m`` for the full COO fallback — so the
    ladder's ecaps climb power-of-two from ~m/32 and stop short of
    ``m``, letting the fallback absorb saturated frontiers (typically
    the 1–3 mid-sweep iterations) while the compact buckets win the
    launch and drain phases. ``vcap = n`` on every rung: column count
    never overflows, only arc volume escalates.
    """
    full_e = max(m, 1)
    caps = []
    e = 2
    while e < max(full_e // 32, 2):
        e *= 2
    while e < full_e and len(caps) < 4:
        caps.append((int(n), int(e)))
        e *= 4
    if not caps:
        caps.append((int(n), int(full_e)))
    return tuple(caps)


def dense_adj_from_graph(g: Graph, *, block: int = 512,
                         use_kernel: bool = False) -> DenseAdj:
    return DenseAdj(jnp.asarray(coo_to_dense(g)), block=block,
                    use_kernel=use_kernel)


def coo_adj_from_graph(g: Graph, *, pad_multiple: int = 128) -> CooAdj:
    src, dst, w = pad_edges(g, multiple=pad_multiple)
    return CooAdj(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w), g.n)


def csr_adj_from_graph(g: Graph, *, n_b: int = 64,
                       caps: Optional[Tuple[Tuple[int, int], ...]] = None,
                       pad_multiple: int = 1) -> CsrAdj:
    """Build the dual-sorted container on the host (stable sorts).

    ``n_b`` sizes the default capacity ladder (it bounds the batch axis
    of the frontiers the relaxes will see); pass explicit ``caps`` to
    override — tests force escalation with caps like ``((1, 1),)``.
    """
    src, dst, w = pad_edges(g, multiple=pad_multiple)
    order = np.argsort(src, kind="stable")
    src_s, dst_s, w_s = src[order], dst[order], w[order]
    indptr = np.zeros(g.n + 1, np.int32)
    np.add.at(indptr, src_s + 1, 1)
    np.cumsum(indptr, out=indptr)
    order_in = np.argsort(dst, kind="stable")
    src_in, dst_in, w_in = src[order_in], dst[order_in], w[order_in]
    indptr_in = np.zeros(g.n + 1, np.int32)
    np.add.at(indptr_in, dst_in + 1, 1)
    np.cumsum(indptr_in, out=indptr_in)
    if caps is None:
        caps = frontier_caps(n_b, g.n, int(src_s.shape[0]))
    return CsrAdj(jnp.asarray(indptr), jnp.asarray(src_s),
                  jnp.asarray(dst_s), jnp.asarray(w_s),
                  jnp.asarray(indptr_in), jnp.asarray(src_in),
                  jnp.asarray(w_in), g.n, tuple(caps))
