"""MFBC — combined betweenness centrality driver (paper Algorithm 3).

``λ(v) = Σ_s ζ(s, v) · σ̄(s, v)`` accumulated over ``⌈n / n_b⌉`` source
batches. The per-batch computation is a single jitted function; the batch
loop runs on the host, which is also where fault tolerance lives — the λ
accumulator plus the batch index *is* the checkpoint (see
``repro.train.checkpoint``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mfbf as _mfbf
from repro.core import mfbr as _mfbr
from repro.core.adjacency import (CooAdj, CsrAdj, DenseAdj,
                                  coo_adj_from_graph, csr_adj_from_graph,
                                  dense_adj_from_graph)
from repro.core.monoids import INF, Multpath
from repro.graphs.formats import Graph


def _batch_contrib(adj, sources: jax.Array, valid: jax.Array, *,
                   iterate: str, max_iters_bf: int, max_iters_br: int):
    """Shared Algorithm 3 batch body: per-source contributions δ_s(v).

    Returns (contrib, mask, Tw, Tm) with contrib (nb, n) zeroed on
    unreachable/padding entries.
    """
    nb = sources.shape[0]
    Tw, Tm = _mfbf.mfbf(adj, sources, iterate=iterate, max_iters=max_iters_bf)
    # Exclude the t = s destination (σ(s, t, v) = 0 when t = s): mask the
    # source's own column to (∞, 1) — the 1 keeps reciprocals safe.
    rows = jnp.arange(nb)
    Tw = Tw.at[rows, sources].set(INF)
    Tm = Tm.at[rows, sources].set(1.0)
    Zp = _mfbr.mfbr(adj, Tw, Tm, iterate=iterate, max_iters=max_iters_br)
    mask = jnp.isfinite(Tw) & valid[:, None]
    contrib = jnp.where(mask, Zp * Tm, 0.0)
    return contrib, mask, Tw, Tm


@functools.partial(jax.jit, static_argnames=("iterate", "max_iters_bf",
                                             "max_iters_br"))
def mfbc_batch(adj, sources: jax.Array, valid: jax.Array, *,
               iterate: str = "while", max_iters_bf: int = 0,
               max_iters_br: int = 0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One batch of Algorithm 3: returns (λ_partial, Tw, Tm).

    valid: (nb,) bool — False for padding sources (contribute nothing).
    """
    contrib, _, Tw, Tm = _batch_contrib(adj, sources, valid, iterate=iterate,
                                        max_iters_bf=max_iters_bf,
                                        max_iters_br=max_iters_br)
    return jnp.sum(contrib, axis=0), Tw, Tm


@functools.partial(jax.jit, static_argnames=("iterate", "max_iters_bf",
                                             "max_iters_br"))
def mfbc_batch_moments(adj, sources: jax.Array, valid: jax.Array, *,
                       iterate: str = "while", max_iters_bf: int = 0,
                       max_iters_br: int = 0
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One Algorithm 3 batch returning per-vertex dependency moments.

    Returns (S1, S2, n_reach) where, over the batch's valid sources s,
    ``S1(v) = Σ_s δ_s(v)``, ``S2(v) = Σ_s δ_s(v)²`` and
    ``n_reach(v) = Σ_s [v reachable from s]``. S1 equals ``mfbc_batch``'s
    λ_partial; S2 feeds the empirical-Bernstein confidence intervals of the
    adaptive approximate-BC estimator (``repro.approx``), which need the
    second moment per *source sample*, not the batch sum.
    """
    contrib, mask, _, _ = _batch_contrib(adj, sources, valid, iterate=iterate,
                                         max_iters_bf=max_iters_bf,
                                         max_iters_br=max_iters_br)
    return (jnp.sum(contrib, axis=0), jnp.sum(contrib * contrib, axis=0),
            jnp.sum(mask, axis=0).astype(jnp.int32))


def _batch_contrib_traced(adj, sources: jax.Array, valid: jax.Array, *,
                          max_iters_bf: int, max_iters_br: int):
    """``_batch_contrib`` with the occupancy traces of both sweeps."""
    nb = sources.shape[0]
    Tw, Tm, tr_bf = _mfbf.mfbf(adj, sources, max_iters=max_iters_bf,
                               trace=True)
    rows = jnp.arange(nb)
    Tw = Tw.at[rows, sources].set(INF)
    Tm = Tm.at[rows, sources].set(1.0)
    Zp, tr_br = _mfbr.mfbr(adj, Tw, Tm, max_iters=max_iters_br, trace=True)
    mask = jnp.isfinite(Tw) & valid[:, None]
    contrib = jnp.where(mask, Zp * Tm, 0.0)
    return contrib, mask, tr_bf, tr_br


@functools.partial(jax.jit, static_argnames=("max_iters_bf", "max_iters_br"))
def mfbc_batch_moments_traced(adj, sources: jax.Array, valid: jax.Array, *,
                              max_iters_bf: int = 0, max_iters_br: int = 0):
    """``mfbc_batch_moments`` plus the per-iteration occupancy traces.

    Returns (S1, S2, n_reach, trace_bf, trace_br) where the traces are
    ``repro.core.mfbf.SweepTrace`` tuples for the forward (MFBF) and
    backward (MFBr) sweeps of this batch. Moment outputs are computed by
    the same relaxation sequence as the untraced entry point — the trace
    is a read-only side channel, so values are bitwise-unchanged.
    """
    contrib, mask, tr_bf, tr_br = _batch_contrib_traced(
        adj, sources, valid, max_iters_bf=max_iters_bf,
        max_iters_br=max_iters_br)
    return (jnp.sum(contrib, axis=0), jnp.sum(contrib * contrib, axis=0),
            jnp.sum(mask, axis=0).astype(jnp.int32), tr_bf, tr_br)


@functools.partial(jax.jit, static_argnames=("n_slots", "iterate",
                                             "max_iters_bf", "max_iters_br"))
def mfbc_batch_moments_segmented(adj, sources: jax.Array, valid: jax.Array,
                                 slot_ids: jax.Array, *, n_slots: int,
                                 iterate: str = "while",
                                 max_iters_bf: int = 0, max_iters_br: int = 0
                                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One Algorithm 3 batch, moments segment-reduced per request slot.

    The cross-request fusion primitive: a fused batch packs sources from
    several concurrent queries, tagged per row with ``slot_ids[s] ∈
    [0, n_slots)`` (padding rows carry ``slot_ids == n_slots``, a dump
    segment that is dropped). Returns (S1, S2, n_reach) each shaped
    ``(n_slots, n)``, where row j holds exactly what
    ``mfbc_batch_moments`` would return for slot j's rows alone — the
    segment-sum accumulates each slot's rows in batch order, so a slot's
    statistics are bitwise-identical to an unfused run of the same rows.
    One device call (and, on the mesh analogue, one fused all-reduce)
    therefore serves every query in the batch.
    """
    contrib, mask, _, _ = _batch_contrib(adj, sources, valid, iterate=iterate,
                                         max_iters_bf=max_iters_bf,
                                         max_iters_br=max_iters_br)
    seg = functools.partial(jax.ops.segment_sum, segment_ids=slot_ids,
                            num_segments=n_slots + 1)
    return (seg(contrib)[:n_slots], seg(contrib * contrib)[:n_slots],
            seg(mask.astype(jnp.int32))[:n_slots])


# ==========================================================================
# Metric-generic batch bodies (the MetricSpec sweep substrate).
#
# Every sampled metric shares MFBF's forward sweep and the t = s self-mask;
# they differ only in the final elementwise contribution formula (and, for
# betweenness, the extra MFBr backward sweep). ``kinds`` is the *static*
# tuple of metric names present in the batch and ``metric_ids`` tags each
# row with an index into it, so a fused batch mixes metrics row-wise while
# the relax sequence stays one shared collective. With
# ``kinds=("betweenness",)`` the computation is the same op sequence as
# ``mfbc_batch_moments`` — the generic entry points never perturb the
# default path, which keeps calling the original functions above.
# ==========================================================================


def _bounded_mfbf(adj, sources: jax.Array, *, hops: int):
    """MFBF stopped after ``hops - 1`` iterations (Lemma 4.1: T is then
    exactly the ≤ ``hops``-edge shortest paths; finiteness is hop-bounded
    reachability). ``hops=1`` runs zero iterations — T is the direct-edge
    row gather itself."""
    Tw0 = adj.gather_rows(sources)
    Tm0 = jnp.where(jnp.isfinite(Tw0), 1.0, 0.0).astype(Tw0.dtype)
    T0 = Multpath(Tw0, Tm0)

    def body(_, state):
        T, F = state
        T, F, _ = _mfbf._step(adj, T, F)
        return T, F

    T, _ = jax.lax.fori_loop(0, hops - 1, body, (T0, T0))
    return T.w, T.m


def _metric_contrib(adj, sources: jax.Array, valid: jax.Array,
                    metric_ids: jax.Array, *, kinds, hops: int,
                    iterate: str, max_iters_bf: int, max_iters_br: int):
    """Metric-generic Algorithm 3 batch body: (contrib, mask).

    kinds: static tuple of metric names; rows select theirs via
    ``metric_ids``. Bounded (khop) and unbounded sweeps never mix — the
    serving layer groups fusion by ``core.metrics.fuse_group``.
    """
    nb = sources.shape[0]
    bounded = any(k == "khop" for k in kinds)
    if bounded:
        if not all(k == "khop" for k in kinds):
            raise ValueError("hop-bounded sweeps cannot fuse with "
                             f"unbounded metrics: {kinds}")
        if hops < 1:
            raise ValueError(f"khop requires hops >= 1, got {hops}")
        Tw, Tm = _bounded_mfbf(adj, sources, hops=hops)
    else:
        Tw, Tm = _mfbf.mfbf(adj, sources, iterate=iterate,
                            max_iters=max_iters_bf)
    rows = jnp.arange(nb)
    Tw = Tw.at[rows, sources].set(INF)
    Tm = Tm.at[rows, sources].set(1.0)
    mask = jnp.isfinite(Tw) & valid[:, None]
    Zp = None
    if any(k == "betweenness" for k in kinds):
        Zp = _mfbr.mfbr(adj, Tw, Tm, iterate=iterate, max_iters=max_iters_br)

    def one(kind):
        if kind == "betweenness":
            return Zp * Tm
        if kind == "closeness":
            return Tw  # farness: δ_s(v) = τ(s, v) where finite
        if kind == "khop":
            return jnp.ones_like(Tw)  # reach indicator within the bound
        raise ValueError(f"metric {kind!r} has no sampled batch body")

    contrib = one(kinds[0])
    for i, kind in enumerate(kinds[1:], start=1):
        contrib = jnp.where((metric_ids == i)[:, None], one(kind), contrib)
    return jnp.where(mask, contrib, 0.0), mask


@functools.partial(jax.jit, static_argnames=("kinds", "hops", "iterate",
                                             "max_iters_bf", "max_iters_br"))
def metric_batch_moments(adj, sources: jax.Array, valid: jax.Array,
                         metric_ids: jax.Array, *, kinds, hops: int = 0,
                         iterate: str = "while", max_iters_bf: int = 0,
                         max_iters_br: int = 0
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``mfbc_batch_moments`` generalized over per-row metrics.

    Returns (S1, S2, n_reach) over the batch's valid sources, where each
    row's contribution formula is selected by ``kinds[metric_ids[row]]``.
    """
    contrib, mask = _metric_contrib(adj, sources, valid, metric_ids,
                                    kinds=kinds, hops=hops, iterate=iterate,
                                    max_iters_bf=max_iters_bf,
                                    max_iters_br=max_iters_br)
    return (jnp.sum(contrib, axis=0), jnp.sum(contrib * contrib, axis=0),
            jnp.sum(mask, axis=0).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("kinds", "hops", "n_slots",
                                             "iterate", "max_iters_bf",
                                             "max_iters_br"))
def metric_batch_moments_segmented(adj, sources: jax.Array,
                                   valid: jax.Array, slot_ids: jax.Array,
                                   metric_ids: jax.Array, *, kinds,
                                   n_slots: int, hops: int = 0,
                                   iterate: str = "while",
                                   max_iters_bf: int = 0,
                                   max_iters_br: int = 0
                                   ) -> Tuple[jax.Array, jax.Array,
                                              jax.Array]:
    """``mfbc_batch_moments_segmented`` generalized over per-row metrics.

    The cross-metric fusion primitive: a closeness epoch and a BC forward
    sweep share one relax collective, with each slot's rows selecting
    their own contribution formula. Per-slot segment sums accumulate each
    slot's rows in batch order, so slot j's statistics stay
    bitwise-identical to an unfused run of the same rows under the same
    ``kinds``-compatible sweep structure.
    """
    contrib, mask = _metric_contrib(adj, sources, valid, metric_ids,
                                    kinds=kinds, hops=hops, iterate=iterate,
                                    max_iters_bf=max_iters_bf,
                                    max_iters_br=max_iters_br)
    seg = functools.partial(jax.ops.segment_sum, segment_ids=slot_ids,
                            num_segments=n_slots + 1)
    return (seg(contrib)[:n_slots], seg(contrib * contrib)[:n_slots],
            seg(mask.astype(jnp.int32))[:n_slots])


def mfbc(g: Graph, *, n_b: Optional[int] = None, backend: str = "dense",
         iterate: str = "while", max_iters: int = 0, block: int = 512,
         use_kernel: bool = False, sources: Optional[np.ndarray] = None,
         progress_cb=None, execution=None) -> np.ndarray:
    """Full betweenness centrality of a host graph.

    Args:
      g: host COO graph (positive weights).
      n_b: batch size (paper's memory/time tradeoff). Default min(n, 64).
      backend: "dense" (blocked tropical matmul / Pallas), "coo"
        (segment-op message passing) or "csr" (frontier-compacted
        segment-op message passing).
      iterate: "while" | "fori" (static bound, for cost analysis).
      max_iters: static iteration bound for "fori" (default n-1).
      sources: optionally restrict to these sources (approximate BC).
      progress_cb: optional callback(batch_idx, n_batches, lam_partial)
        — the checkpoint hook.
      execution: optional backend-dispatch config overriding ``backend``/
        ``block``/``use_kernel``. Duck-typed (anything with those three
        attributes, e.g. ``repro.bc.ExecutionConfig``) so the core layer
        never imports the solver facade — ``repro.bc`` imports core, not
        the reverse.

    Returns:
      λ: (n,) float64 centrality scores (ordered-pair convention, endpoints
      excluded — matches the paper's λ definition).
    """
    n = g.n
    if n_b is None:
        n_b = min(n, 64)
    if execution is not None:
        if execution.backend is not None:
            backend = str(getattr(execution.backend, "value",
                                  execution.backend))
        if execution.use_kernel is not None:
            use_kernel = bool(execution.use_kernel)
        block = int(execution.block)
    if backend == "dense":
        adj = dense_adj_from_graph(g, block=block, use_kernel=use_kernel)
    elif backend == "coo":
        adj = coo_adj_from_graph(g)
    elif backend == "csr":
        adj = csr_adj_from_graph(g, n_b=n_b)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    all_sources = np.arange(n, dtype=np.int32) if sources is None \
        else np.asarray(sources, dtype=np.int32)
    n_src = all_sources.shape[0]
    n_batches = -(-n_src // n_b)
    lam = np.zeros(n, dtype=np.float64)
    for b in range(n_batches):
        chunk = all_sources[b * n_b:(b + 1) * n_b]
        valid = np.ones(chunk.shape[0], dtype=bool)
        if chunk.shape[0] < n_b:  # pad the ragged tail (paper's n mod n_b trick)
            pad = n_b - chunk.shape[0]
            chunk = np.concatenate([chunk, np.zeros(pad, np.int32)])
            valid = np.concatenate([valid, np.zeros(pad, bool)])
        lam_b, _, _ = mfbc_batch(adj, jnp.asarray(chunk), jnp.asarray(valid),
                                 iterate=iterate, max_iters_bf=max_iters,
                                 max_iters_br=max_iters)
        lam += np.asarray(lam_b, dtype=np.float64)
        if progress_cb is not None:
            progress_cb(b, n_batches, lam)
    return lam
