"""Core MFBC algorithms (the paper's contribution)."""
from repro.core.adjacency import (CooAdj, DenseAdj, coo_adj_from_graph,
                                  dense_adj_from_graph)
from repro.core.bfs_bc import bfs_bc
from repro.core.brandes_ref import (brandes_bc, cc_ref, closeness_ref,
                                    khop_ref)
from repro.core.metrics import (METRICS, MetricSpec, components_graph,
                                components_labels, fuse_group, metric_spec,
                                register_metric, registered_metrics)
from repro.core.mfbc import mfbc, mfbc_batch
from repro.core.mfbf import mfbf
from repro.core.mfbr import mfbr
from repro.core.monoids import (Centpath, Multpath, centpath_combine,
                                multpath_combine)

__all__ = [
    "CooAdj", "DenseAdj", "coo_adj_from_graph", "dense_adj_from_graph",
    "bfs_bc", "brandes_bc", "mfbc", "mfbc_batch", "mfbf", "mfbr",
    "closeness_ref", "cc_ref", "khop_ref",
    "MetricSpec", "register_metric", "metric_spec", "registered_metrics",
    "METRICS", "fuse_group", "components_graph", "components_labels",
    "Centpath", "Multpath", "centpath_combine", "multpath_combine",
]
