"""MFBF — Maximal Frontier Bellman-Ford (paper Algorithm 1, Lemma 4.1).

Computes, for a batch of ``n_b`` sources, the shortest distance ``τ(s, v)``
and the shortest-path multiplicity ``σ̄(s, v)`` for every vertex ``v``.

Loop invariant (the Lemma 4.1 induction): after ``j`` iterations

* ``T``  holds weight/multiplicity of all shortest paths of **≤ j+1** edges,
* the frontier ``F`` holds weight/multiplicity of minimal-weight paths of
  **exactly j+1** edges that tie the current best (everything that can still
  make progress — the *maximal* frontier).

The paper's ``(∞, 1)`` initialisation trick is kept implicitly: inactive
entries are ``(∞, 0)`` in the frontier (so they are never relaxed — CTF
keeps them structurally absent), while ``T``'s multiplicity for unreachable
vertices is clamped to 1 just before reciprocals are taken in MFBr.

``iterate`` selects ``lax.while_loop`` (dynamic trip count — production) or
``lax.fori_loop`` with a static bound (used by the dry-run/roofline so that
``cost_analysis`` sees the real per-iteration work).
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.monoids import INF, Multpath, multpath_combine


def _frontier_active(F: Multpath) -> jax.Array:
    return jnp.isfinite(F.w) & (F.m > 0)


def _step(adj, T: Multpath, F: Multpath) -> Tuple[Multpath, Multpath]:
    """One maximal-frontier relaxation: returns (T', F')."""
    C = adj.relax_mp(F)  # exactly-(j+1)-edge minimal paths from the frontier
    T_new = multpath_combine(T, C)
    # New frontier: candidates that match the (possibly improved) best
    # distance. Exactly-j-edge path classes are disjoint, so multiplicities
    # accumulate without double counting.
    keep = (C.w == T_new.w) & jnp.isfinite(C.w) & (C.m > 0)
    F_new = Multpath(jnp.where(keep, C.w, INF), jnp.where(keep, C.m, 0.0))
    return T_new, F_new


def mfbf(adj, sources: jax.Array, *, iterate: Union[str, Tuple[str, int]] = "while",
         max_iters: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Run MFBF for one batch of sources.

    Args:
      adj: DenseAdj or CooAdj.
      sources: (nb,) int32 vertex ids.
      iterate: "while" for a dynamic loop, "fori" for a static loop of
        ``max_iters`` iterations (must upper-bound the SP edge count).
      max_iters: static bound; also caps the while loop defensively
        (0 means n - 1).

    Returns:
      (Tw, Tm): (nb, n) distances and multiplicities. Unreachable = (inf, 0).
    """
    n = adj.n
    nb = sources.shape[0]
    bound = max_iters if max_iters > 0 else n - 1
    Tw0 = adj.gather_rows(sources)  # direct edges, (nb, n); paper line 1
    Tm0 = jnp.where(jnp.isfinite(Tw0), 1.0, 0.0).astype(Tw0.dtype)
    T0 = Multpath(Tw0, Tm0)
    F0 = T0  # paper line 2: initial frontier = exactly-1-edge paths

    if iterate == "while":

        def cond(state):
            _, F, it = state
            return jnp.any(_frontier_active(F)) & (it < bound)

        def body(state):
            T, F, it = state
            T, F = _step(adj, T, F)
            return T, F, it + 1

        T, _, _ = jax.lax.while_loop(cond, body, (T0, F0, jnp.int32(0)))
    else:

        def body(_, state):
            T, F = state
            return _step(adj, T, F)

        T, _ = jax.lax.fori_loop(0, bound, body, (T0, F0))

    return T.w, T.m
