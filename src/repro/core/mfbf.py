"""MFBF — Maximal Frontier Bellman-Ford (paper Algorithm 1, Lemma 4.1).

Computes, for a batch of ``n_b`` sources, the shortest distance ``τ(s, v)``
and the shortest-path multiplicity ``σ̄(s, v)`` for every vertex ``v``.

Loop invariant (the Lemma 4.1 induction): after ``j`` iterations

* ``T``  holds weight/multiplicity of all shortest paths of **≤ j+1** edges,
* the frontier ``F`` holds weight/multiplicity of minimal-weight paths of
  **exactly j+1** edges that tie the current best (everything that can still
  make progress — the *maximal* frontier).

The paper's ``(∞, 1)`` initialisation trick is kept implicitly: inactive
entries are ``(∞, 0)`` in the frontier (so they are never relaxed — CTF
keeps them structurally absent), while ``T``'s multiplicity for unreachable
vertices is clamped to 1 just before reciprocals are taken in MFBr.

``iterate`` selects ``lax.while_loop`` (dynamic trip count — production) or
``lax.fori_loop`` with a static bound (used by the dry-run/roofline so that
``cost_analysis`` sees the real per-iteration work).

The while-loop condition reads an active count folded into the loop carry:
``_step`` computes the next frontier's population from the ``keep`` mask it
already materializes, so the cond never re-reduces the full ``(n_b, n)``
frontier. ``F'`` is active exactly where ``keep`` holds, so the carried
count is identical to ``jnp.any(_frontier_active(F'))`` and results are
bitwise-unchanged.

``trace=True`` additionally threads a :class:`SweepTrace` through the loop:
per-iteration frontier nnz plus, for adjacencies with frontier compaction
(``CsrAdj``), how many relax calls a compaction bucket served and how many
overflowed to the full edge list.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.monoids import INF, Multpath, multpath_combine

# Fixed-size per-iteration occupancy trace; iterations past the cap fold
# into the last slot (so ``fnnz[min(iters, cap) - 1]`` is always the tail).
TRACE_CAP = 64


class SweepTrace(NamedTuple):
    """Occupancy side-channel of one frontier sweep (MFBF or MFBr)."""

    fnnz: jax.Array  # (TRACE_CAP,) int32 frontier nnz per iteration; -1 unused
    iters: jax.Array  # int32 — iterations executed
    overflows: jax.Array  # int32 — relax calls on the full-edge-list fallback
    compact_hits: jax.Array  # int32 — relax calls served by a capacity bucket


def empty_trace() -> SweepTrace:
    return SweepTrace(jnp.full((TRACE_CAP,), -1, jnp.int32), jnp.int32(0),
                      jnp.int32(0), jnp.int32(0))


def _frontier_active(F: Multpath) -> jax.Array:
    return jnp.isfinite(F.w) & (F.m > 0)


def _relax_with_stats(adj, F: Multpath):
    """(C, overflow, compact_hit) — zero stats for non-compacting formats."""
    fn = getattr(adj, "relax_mp_stats", None)
    if fn is None:
        return adj.relax_mp(F), jnp.int32(0), jnp.int32(0)
    C, st = fn(F)
    hit = ((st.bucket >= 0) & (st.overflow == 0)).astype(jnp.int32)
    return C, st.overflow, hit


def _step(adj, T: Multpath, F: Multpath
          ) -> Tuple[Multpath, Multpath, jax.Array]:
    """One maximal-frontier relaxation: returns (T', F', |F' active|)."""
    C = adj.relax_mp(F)  # exactly-(j+1)-edge minimal paths from the frontier
    T_new = multpath_combine(T, C)
    # New frontier: candidates that match the (possibly improved) best
    # distance. Exactly-j-edge path classes are disjoint, so multiplicities
    # accumulate without double counting.
    keep = (C.w == T_new.w) & jnp.isfinite(C.w) & (C.m > 0)
    F_new = Multpath(jnp.where(keep, C.w, INF), jnp.where(keep, C.m, 0.0))
    return T_new, F_new, jnp.sum(keep.astype(jnp.int32))


def mfbf(adj, sources: jax.Array, *,
         iterate: Union[str, Tuple[str, int]] = "while",
         max_iters: int = 0, trace: bool = False):
    """Run MFBF for one batch of sources.

    Args:
      adj: DenseAdj, CooAdj or CsrAdj.
      sources: (nb,) int32 vertex ids.
      iterate: "while" for a dynamic loop, "fori" for a static loop of
        ``max_iters`` iterations (must upper-bound the SP edge count).
      max_iters: static bound; also caps the while loop defensively
        (0 means n - 1).
      trace: also return the :class:`SweepTrace` occupancy side output.

    Returns:
      (Tw, Tm): (nb, n) distances and multiplicities. Unreachable = (inf, 0).
      With ``trace=True``: (Tw, Tm, SweepTrace).
    """
    n = adj.n
    bound = max_iters if max_iters > 0 else n - 1
    Tw0 = adj.gather_rows(sources)  # direct edges, (nb, n); paper line 1
    Tm0 = jnp.where(jnp.isfinite(Tw0), 1.0, 0.0).astype(Tw0.dtype)
    T0 = Multpath(Tw0, Tm0)
    F0 = T0  # paper line 2: initial frontier = exactly-1-edge paths
    nact0 = jnp.sum(_frontier_active(F0).astype(jnp.int32))

    if trace:

        def cond(state):
            return (state[3] > 0) & (state[2] < bound)

        def body(state):
            T, F, it, nact, tr = state
            C, over, hit = _relax_with_stats(adj, F)
            T_new = multpath_combine(T, C)
            keep = (C.w == T_new.w) & jnp.isfinite(C.w) & (C.m > 0)
            F_new = Multpath(jnp.where(keep, C.w, INF),
                             jnp.where(keep, C.m, 0.0))
            slot = jnp.minimum(it, TRACE_CAP - 1)
            tr = SweepTrace(tr.fnnz.at[slot].set(nact), it + 1,
                            tr.overflows + over, tr.compact_hits + hit)
            return (T_new, F_new, it + 1,
                    jnp.sum(keep.astype(jnp.int32)), tr)

        T, _, _, _, tr = jax.lax.while_loop(
            cond, body, (T0, F0, jnp.int32(0), nact0, empty_trace()))
        return T.w, T.m, tr

    if iterate == "while":

        def cond(state):
            return (state[3] > 0) & (state[2] < bound)

        def body(state):
            T, F, it, _ = state
            T, F, nact = _step(adj, T, F)
            return T, F, it + 1, nact

        T, _, _, _ = jax.lax.while_loop(cond, body,
                                        (T0, F0, jnp.int32(0), nact0))
    else:

        def body(_, state):
            T, F = state
            T, F, _ = _step(adj, T, F)
            return T, F

        T, _ = jax.lax.fori_loop(0, bound, body, (T0, F0))

    return T.w, T.m
