"""Pure-numpy Brandes betweenness centrality oracle.

Textbook Brandes [2001] with Dijkstra (weighted) or BFS (unweighted)
forward phases. Ordered-pair convention: λ(v) = Σ_{s≠t, v∉{s,t}}
σ(s,t,v)/σ̄(s,t) — identical to the paper's definition, no /2 for
undirected graphs. This is the ground truth for every MFBC correctness
test.
"""
from __future__ import annotations

import heapq
from typing import Optional

import numpy as np

from repro.graphs.formats import Graph, coo_to_csr


def brandes_bc(g: Graph, sources: Optional[np.ndarray] = None,
               return_aux: bool = False):
    """Betweenness centrality.

    Args:
      g: host graph with positive weights.
      sources: restrict the s-sum to these sources (default: all).
      return_aux: also return (dist, sigma) arrays of shape (n_src, n)
        — the MFBF oracle.
    """
    n = g.n
    indptr, indices, weights = coo_to_csr(g)
    tindptr, tindices, tweights = coo_to_csr(g.transpose())
    unweighted = bool(np.all(weights == 1.0))
    src_list = np.arange(n) if sources is None else np.asarray(sources)
    lam = np.zeros(n, dtype=np.float64)
    dists = np.full((len(src_list), n), np.inf) if return_aux else None
    sigmas = np.zeros((len(src_list), n)) if return_aux else None

    for si, s in enumerate(src_list):
        dist = np.full(n, np.inf)
        sigma = np.zeros(n, dtype=np.float64)
        dist[s] = 0.0
        sigma[s] = 1.0
        order = []  # vertices in nondecreasing finalized distance
        if unweighted:
            frontier = [int(s)]
            while frontier:
                order.extend(frontier)
                nxt = []
                for u in frontier:
                    for ei in range(indptr[u], indptr[u + 1]):
                        v = int(indices[ei])
                        nd = dist[u] + 1.0
                        if not np.isfinite(dist[v]):
                            dist[v] = nd
                            sigma[v] = sigma[u]
                            nxt.append(v)
                        elif nd == dist[v]:
                            sigma[v] += sigma[u]
                frontier = nxt
        else:
            done = np.zeros(n, dtype=bool)
            heap = [(0.0, int(s))]
            while heap:
                d, u = heapq.heappop(heap)
                if done[u] or d > dist[u]:
                    continue
                done[u] = True
                order.append(u)
                for ei in range(indptr[u], indptr[u + 1]):
                    v = int(indices[ei])
                    nd = d + weights[ei]
                    if nd < dist[v]:
                        dist[v] = nd
                        sigma[v] = sigma[u]
                        heapq.heappush(heap, (float(nd), v))
                    elif nd == dist[v]:
                        sigma[v] += sigma[u]

        # Backward dependency accumulation over incoming arcs:
        # v ∈ pred(u) iff dist[v] + w(v, u) == dist[u].
        delta = np.zeros(n, dtype=np.float64)
        for u in reversed(order):
            if u == s or not np.isfinite(dist[u]):
                continue
            for ei in range(tindptr[u], tindptr[u + 1]):
                v = int(tindices[ei])  # arc v -> u in the original graph
                if np.isfinite(dist[v]) and dist[v] + tweights[ei] == dist[u]:
                    delta[v] += sigma[v] / sigma[u] * (1.0 + delta[u])

        mask = np.ones(n, dtype=bool)
        mask[s] = False
        lam[mask] += delta[mask]
        if return_aux:
            dists[si] = dist
            sigmas[si] = sigma
    if return_aux:
        return lam, dists, sigmas
    return lam


# ==========================================================================
# Sibling-metric oracles (plain numpy BFS / Dijkstra / union-find) — the
# ground truth for the MetricSpec sweeps in ``repro.core.metrics``.
# ==========================================================================


def _sssp(g: Graph, s: int, indptr, indices, weights, unweighted: bool
          ) -> np.ndarray:
    """Single-source distances (BFS or Dijkstra), (n,) float64."""
    dist = np.full(g.n, np.inf)
    dist[s] = 0.0
    if unweighted:
        frontier = [int(s)]
        while frontier:
            nxt = []
            for u in frontier:
                for ei in range(indptr[u], indptr[u + 1]):
                    v = int(indices[ei])
                    if not np.isfinite(dist[v]):
                        dist[v] = dist[u] + 1.0
                        nxt.append(v)
            frontier = nxt
    else:
        heap = [(0.0, int(s))]
        while heap:
            d, u = heapq.heappop(heap)
            if d > dist[u]:
                continue
            for ei in range(indptr[u], indptr[u + 1]):
                v = int(indices[ei])
                nd = d + weights[ei]
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(heap, (float(nd), v))
    return dist


def closeness_ref(g: Graph, sources: Optional[np.ndarray] = None
                  ) -> np.ndarray:
    """Farness oracle: F(v) = Σ_s τ(s, v) over finite distances, s ≠ v.

    The transpose of the usual closeness orientation — distances *into*
    v from each source — matching the sweep convention where row s of T
    holds τ(s, ·). Unreachable pairs contribute 0.
    """
    indptr, indices, weights = coo_to_csr(g)
    unweighted = bool(np.all(weights == 1.0))
    src_list = np.arange(g.n) if sources is None else np.asarray(sources)
    far = np.zeros(g.n, dtype=np.float64)
    for s in src_list:
        dist = _sssp(g, int(s), indptr, indices, weights, unweighted)
        dist[int(s)] = np.inf  # self-pair excluded, like d(s, s) = 0
        finite = np.isfinite(dist)
        far[finite] += dist[finite]
    return far


def khop_ref(g: Graph, sources: Optional[np.ndarray] = None, *,
             hops: int = 1) -> np.ndarray:
    """k-hop in-reachability oracle: R(v) = |{s : v within ``hops`` edges
    of s, v ≠ s}| — hop-limited BFS on the arc structure (weights
    ignored; hop counts are edge counts)."""
    if hops < 1:
        raise ValueError(f"khop requires hops >= 1, got {hops}")
    indptr, indices, _ = coo_to_csr(g)
    src_list = np.arange(g.n) if sources is None else np.asarray(sources)
    reach = np.zeros(g.n, dtype=np.float64)
    for s in src_list:
        depth = np.full(g.n, -1, dtype=np.int64)
        depth[int(s)] = 0
        frontier = [int(s)]
        for d in range(hops):
            nxt = []
            for u in frontier:
                for ei in range(indptr[u], indptr[u + 1]):
                    v = int(indices[ei])
                    if depth[v] < 0:
                        depth[v] = d + 1
                        nxt.append(v)
            frontier = nxt
        hit = depth >= 0
        hit[int(s)] = False
        reach[hit] += 1.0
    return reach


def cc_ref(g: Graph) -> np.ndarray:
    """Weakly-connected-components oracle: label(v) = min vertex id in
    v's component (union-find over the undirected arc structure)."""
    parent = np.arange(g.n, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = int(parent[root])
        while parent[x] != root:
            parent[x], x = root, int(parent[x])
        return root

    for u, v in zip(g.src.tolist(), g.dst.tolist()):
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            # union by min id keeps the root the component minimum
            lo, hi = (ru, rv) if ru < rv else (rv, ru)
            parent[hi] = lo
    return np.array([find(v) for v in range(g.n)], dtype=np.float64)
