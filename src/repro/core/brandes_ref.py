"""Pure-numpy Brandes betweenness centrality oracle.

Textbook Brandes [2001] with Dijkstra (weighted) or BFS (unweighted)
forward phases. Ordered-pair convention: λ(v) = Σ_{s≠t, v∉{s,t}}
σ(s,t,v)/σ̄(s,t) — identical to the paper's definition, no /2 for
undirected graphs. This is the ground truth for every MFBC correctness
test.
"""
from __future__ import annotations

import heapq
from typing import Optional

import numpy as np

from repro.graphs.formats import Graph, coo_to_csr


def brandes_bc(g: Graph, sources: Optional[np.ndarray] = None,
               return_aux: bool = False):
    """Betweenness centrality.

    Args:
      g: host graph with positive weights.
      sources: restrict the s-sum to these sources (default: all).
      return_aux: also return (dist, sigma) arrays of shape (n_src, n)
        — the MFBF oracle.
    """
    n = g.n
    indptr, indices, weights = coo_to_csr(g)
    tindptr, tindices, tweights = coo_to_csr(g.transpose())
    unweighted = bool(np.all(weights == 1.0))
    src_list = np.arange(n) if sources is None else np.asarray(sources)
    lam = np.zeros(n, dtype=np.float64)
    dists = np.full((len(src_list), n), np.inf) if return_aux else None
    sigmas = np.zeros((len(src_list), n)) if return_aux else None

    for si, s in enumerate(src_list):
        dist = np.full(n, np.inf)
        sigma = np.zeros(n, dtype=np.float64)
        dist[s] = 0.0
        sigma[s] = 1.0
        order = []  # vertices in nondecreasing finalized distance
        if unweighted:
            frontier = [int(s)]
            while frontier:
                order.extend(frontier)
                nxt = []
                for u in frontier:
                    for ei in range(indptr[u], indptr[u + 1]):
                        v = int(indices[ei])
                        nd = dist[u] + 1.0
                        if not np.isfinite(dist[v]):
                            dist[v] = nd
                            sigma[v] = sigma[u]
                            nxt.append(v)
                        elif nd == dist[v]:
                            sigma[v] += sigma[u]
                frontier = nxt
        else:
            done = np.zeros(n, dtype=bool)
            heap = [(0.0, int(s))]
            while heap:
                d, u = heapq.heappop(heap)
                if done[u] or d > dist[u]:
                    continue
                done[u] = True
                order.append(u)
                for ei in range(indptr[u], indptr[u + 1]):
                    v = int(indices[ei])
                    nd = d + weights[ei]
                    if nd < dist[v]:
                        dist[v] = nd
                        sigma[v] = sigma[u]
                        heapq.heappush(heap, (float(nd), v))
                    elif nd == dist[v]:
                        sigma[v] += sigma[u]

        # Backward dependency accumulation over incoming arcs:
        # v ∈ pred(u) iff dist[v] + w(v, u) == dist[u].
        delta = np.zeros(n, dtype=np.float64)
        for u in reversed(order):
            if u == s or not np.isfinite(dist[u]):
                continue
            for ei in range(tindptr[u], tindptr[u + 1]):
                v = int(tindices[ei])  # arc v -> u in the original graph
                if np.isfinite(dist[v]) and dist[v] + tweights[ei] == dist[u]:
                    delta[v] += sigma[v] / sigma[u] * (1.0 + delta[u])

        mask = np.ones(n, dtype=bool)
        mask[s] = False
        lam[mask] += delta[mask]
        if return_aux:
            dists[si] = dist
            sigmas[si] = sigma
    if return_aux:
        return lam, dists, sigmas
    return lam
