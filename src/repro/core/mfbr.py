"""MFBr — Maximal Frontier Brandes back-propagation (paper Algorithm 2).

Given distances/multiplicities ``T = (Tw, Tm)`` from MFBF, computes the
partial centrality factors ``ζ(s, v) = δ(s, v) / σ̄(s, v)``.

We implement the Lemma 4.2 semantics with the counter mechanism:

* ``c0(s, v)`` = number of SP-DAG children of ``v`` (vertices ``u`` with
  ``τ(s,v) + A(v,u) = τ(s,u)``). The paper's Algorithm 2 lines 1–2 compute
  this with one ``•_(⊗,g)`` product; we use the equivalent one-shot count
  (see DESIGN.md §3 on the pseudocode's counter off-by-one).
* A vertex enters the frontier exactly once, when its counter hits zero
  (all children have reported), carrying ``1/σ̄(s,v) + ζ(s,v)``; it is then
  retired (paper's ``c = -1`` state → our ``done`` mask).
* Each round back-propagates the frontier with the centpath action
  ``g((w,p,c), a) = (w-a, p, c)`` and the ⊗ max-select: a predecessor ``v``
  accepts a contribution iff the shifted weight equals ``τ(s, v)`` exactly —
  i.e. the arc is on a shortest path — accumulating ``Σ_u (1/σ̄(s,u)+ζ(s,u))``
  and decrementing its counter by the number of children that reported.

The caller must mask the self-destination ``T(s, s̄(s)) = (∞, 1)`` first
(σ(s, t, v) with t = s is excluded from betweenness by definition).
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.monoids import INF, Centpath


def _seed_frontier(Tw, Tm, Zp, newly):
    Fw = jnp.where(newly, Tw, -INF)
    Fp = jnp.where(newly, Zp + 1.0 / Tm, 0.0)
    return Centpath(Fw, Fp, jnp.where(newly, 1.0, 0.0))


def _step(adj, Tw, Tm, finite, state):
    Zp, c, done, F = state
    P = adj.relax_cp(F)  # contributions shifted back along arcs
    contrib = (P.w == Tw) & finite & (P.c > 0)
    Zp = Zp + jnp.where(contrib, P.p, 0.0)
    c = c - jnp.where(contrib, P.c.astype(c.dtype), 0)
    newly = finite & (c == 0) & (~done)
    F = _seed_frontier(Tw, Tm, Zp, newly)
    done = done | newly
    return Zp, c, done, F


def mfbr(adj, Tw: jax.Array, Tm: jax.Array, *,
         iterate: Union[str, Tuple[str, int]] = "while",
         max_iters: int = 0) -> jax.Array:
    """Back-propagate centrality factors. Returns ``Zp`` with
    ``Zp[s, v] = ζ(s, v)`` (0 for unreachable/masked vertices)."""
    n = adj.n
    bound = max_iters if max_iters > 0 else n - 1
    finite = jnp.isfinite(Tw)
    Tm_safe = jnp.where(Tm > 0, Tm, 1.0)  # the paper's (∞, 1) reciprocal guard
    c0 = adj.count_sp_children(Tw)
    Zp0 = jnp.zeros_like(Tw)
    seed = finite & (c0 == 0)
    F0 = _seed_frontier(Tw, Tm_safe, Zp0, seed)
    state0 = (Zp0, c0, seed, F0)

    if iterate == "while":

        def cond(st):
            _, _, _, F = st
            return jnp.any(F.c > 0)

        def body(st):
            return _step(adj, Tw, Tm_safe, finite, st)

        # cap defensively at ``bound`` rounds via a fuel counter
        def cond_f(carry):
            st, it = carry
            return cond(st) & (it < bound)

        def body_f(carry):
            st, it = carry
            return body(st), it + 1

        (Zp, _, _, _), _ = jax.lax.while_loop(cond_f, body_f,
                                              (state0, jnp.int32(0)))
    else:

        def body(_, st):
            return _step(adj, Tw, Tm_safe, finite, st)

        Zp, _, _, _ = jax.lax.fori_loop(0, bound, body, state0)

    return Zp
