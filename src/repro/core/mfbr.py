"""MFBr — Maximal Frontier Brandes back-propagation (paper Algorithm 2).

Given distances/multiplicities ``T = (Tw, Tm)`` from MFBF, computes the
partial centrality factors ``ζ(s, v) = δ(s, v) / σ̄(s, v)``.

We implement the Lemma 4.2 semantics with the counter mechanism:

* ``c0(s, v)`` = number of SP-DAG children of ``v`` (vertices ``u`` with
  ``τ(s,v) + A(v,u) = τ(s,u)``). The paper's Algorithm 2 lines 1–2 compute
  this with one ``•_(⊗,g)`` product; we use the equivalent one-shot count
  (see DESIGN.md §3 on the pseudocode's counter off-by-one).
* A vertex enters the frontier exactly once, when its counter hits zero
  (all children have reported), carrying ``1/σ̄(s,v) + ζ(s,v)``; it is then
  retired (paper's ``c = -1`` state → our ``done`` mask).
* Each round back-propagates the frontier with the centpath action
  ``g((w,p,c), a) = (w-a, p, c)`` and the ⊗ max-select: a predecessor ``v``
  accepts a contribution iff the shifted weight equals ``τ(s, v)`` exactly —
  i.e. the arc is on a shortest path — accumulating ``Σ_u (1/σ̄(s,u)+ζ(s,u))``
  and decrementing its counter by the number of children that reported.

The caller must mask the self-destination ``T(s, s̄(s)) = (∞, 1)`` first
(σ(s, t, v) with t = s is excluded from betweenness by definition).
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.mfbf import TRACE_CAP, SweepTrace, empty_trace
from repro.core.monoids import INF, Centpath


def _seed_frontier(Tw, Tm, Zp, newly):
    Fw = jnp.where(newly, Tw, -INF)
    Fp = jnp.where(newly, Zp + 1.0 / Tm, 0.0)
    return Centpath(Fw, Fp, jnp.where(newly, 1.0, 0.0))


def _relax_with_stats(adj, F: Centpath):
    """(P, overflow, compact_hit) — zero stats for non-compacting formats."""
    fn = getattr(adj, "relax_cp_stats", None)
    if fn is None:
        return adj.relax_cp(F), jnp.int32(0), jnp.int32(0)
    P, st = fn(F)
    hit = ((st.bucket >= 0) & (st.overflow == 0)).astype(jnp.int32)
    return P, st.overflow, hit


def _step(adj, Tw, Tm, finite, state):
    """One back-prop round; the last element of the returned state is the
    population of the next frontier (vertices newly retired this round) —
    the while cond reads it instead of re-reducing ``F.c`` over (nb, n)."""
    Zp, c, done, F, _ = state
    P = adj.relax_cp(F)  # contributions shifted back along arcs
    contrib = (P.w == Tw) & finite & (P.c > 0)
    Zp = Zp + jnp.where(contrib, P.p, 0.0)
    c = c - jnp.where(contrib, P.c.astype(c.dtype), 0)
    newly = finite & (c == 0) & (~done)
    F = _seed_frontier(Tw, Tm, Zp, newly)
    done = done | newly
    return Zp, c, done, F, jnp.sum(newly.astype(jnp.int32))


def mfbr(adj, Tw: jax.Array, Tm: jax.Array, *,
         iterate: Union[str, Tuple[str, int]] = "while",
         max_iters: int = 0, trace: bool = False):
    """Back-propagate centrality factors. Returns ``Zp`` with
    ``Zp[s, v] = ζ(s, v)`` (0 for unreachable/masked vertices).
    With ``trace=True``: (Zp, SweepTrace) — see ``repro.core.mfbf``."""
    n = adj.n
    bound = max_iters if max_iters > 0 else n - 1
    finite = jnp.isfinite(Tw)
    Tm_safe = jnp.where(Tm > 0, Tm, 1.0)  # the paper's (∞, 1) reciprocal guard
    c0 = adj.count_sp_children(Tw)
    Zp0 = jnp.zeros_like(Tw)
    seed = finite & (c0 == 0)
    F0 = _seed_frontier(Tw, Tm_safe, Zp0, seed)
    nact0 = jnp.sum(seed.astype(jnp.int32))
    state0 = (Zp0, c0, seed, F0, nact0)

    if trace:

        def cond_t(carry):
            st, it, _ = carry
            return (st[4] > 0) & (it < bound)

        def body_t(carry):
            st, it, tr = carry
            Zp, c, done, F, nact = st
            P, over, hit = _relax_with_stats(adj, F)
            contrib = (P.w == Tw) & finite & (P.c > 0)
            Zp = Zp + jnp.where(contrib, P.p, 0.0)
            c = c - jnp.where(contrib, P.c.astype(c.dtype), 0)
            newly = finite & (c == 0) & (~done)
            F = _seed_frontier(Tw, Tm_safe, Zp, newly)
            done = done | newly
            slot = jnp.minimum(it, TRACE_CAP - 1)
            tr = SweepTrace(tr.fnnz.at[slot].set(nact), it + 1,
                            tr.overflows + over, tr.compact_hits + hit)
            n_new = jnp.sum(newly.astype(jnp.int32))
            return (Zp, c, done, F, n_new), it + 1, tr

        (st, _, tr) = jax.lax.while_loop(cond_t, body_t,
                                         (state0, jnp.int32(0),
                                          empty_trace()))
        return st[0], tr

    if iterate == "while":

        def cond_f(carry):
            st, it = carry
            return (st[4] > 0) & (it < bound)

        def body_f(carry):
            st, it = carry
            return _step(adj, Tw, Tm_safe, finite, st), it + 1

        (Zp, _, _, _, _), _ = jax.lax.while_loop(cond_f, body_f,
                                                 (state0, jnp.int32(0)))
    else:

        def body(_, st):
            return _step(adj, Tw, Tm_safe, finite, st)

        Zp, _, _, _, _ = jax.lax.fori_loop(0, bound, body, state0)

    return Zp
