"""Config system: architectures × input-shape cells.

Each assigned architecture provides an ``ArchSpec`` with:

* ``config(smoke=False)``  — the exact published configuration (or a tiny
  reduced config of the same family for CPU smoke tests);
* ``cells()``              — its input-shape cells (the 4 assigned shapes);
* ``build(cell, policy, smoke)`` — a ``StepBundle``: the step function to
  lower, abstract (ShapeDtypeStruct, sharded) arguments, while-body trip
  counts for the HLO collective scaling, and the analytic MODEL_FLOPS.

The dry-run lowers ``bundle.fn`` against ``bundle.abstract_args`` on the
production meshes; smoke tests call ``bundle.concrete_args`` and execute
one real step on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import gnn as G
from repro.models import recsys as R
from repro.models import transformer as T
from repro.optim import adamw
from repro.sharding.rules import NO_SHARDING, ShardingPolicy


@dataclasses.dataclass(frozen=True)
class Cell:
    shape_id: str
    kind: str  # train | prefill | decode | serve | retrieval
    batch: int
    seq: int = 0
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    abstract_args: Tuple
    trip_counts: Dict[str, int]
    model_flops: float
    donate: Tuple[int, ...] = ()
    concrete_args: Optional[Callable] = None  # key -> args (smoke tests)
    check: Optional[Callable] = None  # outputs -> None (smoke assertions)


class ArchSpec:
    arch_id: str = ""
    family: str = ""

    def config(self, smoke: bool = False):
        raise NotImplementedError

    def cells(self) -> Dict[str, Cell]:
        raise NotImplementedError

    def build(self, cell: Cell, policy: ShardingPolicy = NO_SHARDING,
              smoke: bool = False) -> StepBundle:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# LM family.
# ---------------------------------------------------------------------------

LM_CELLS = {
    "train_4k": Cell("train_4k", "train", batch=256, seq=4096),
    "prefill_32k": Cell("prefill_32k", "prefill", batch=32, seq=32768),
    "decode_32k": Cell("decode_32k", "decode", batch=128, seq=32768),
    "long_500k": Cell("long_500k", "decode", batch=1, seq=524288),
}

LM_SMOKE_CELLS = {
    "train_4k": Cell("train_4k", "train", batch=2, seq=64),
    "prefill_32k": Cell("prefill_32k", "prefill", batch=2, seq=64),
    "decode_32k": Cell("decode_32k", "decode", batch=2, seq=64),
    "long_500k": Cell("long_500k", "decode", batch=1, seq=128),
}


class LMArch(ArchSpec):
    family = "lm"

    def __init__(self, arch_id: str, full_cfg: Callable[[], T.TransformerConfig],
                 smoke_cfg: Callable[[], T.TransformerConfig]):
        self.arch_id = arch_id
        self._full = full_cfg
        self._smoke = smoke_cfg

    def config(self, smoke: bool = False) -> T.TransformerConfig:
        return self._smoke() if smoke else self._full()

    def cells(self) -> Dict[str, Cell]:
        return LM_CELLS

    def build(self, cell: Cell, policy: ShardingPolicy = NO_SHARDING,
              smoke: bool = False, unroll: bool = False,
              layers_override: int = 0) -> StepBundle:
        cfg = self.config(smoke)
        if unroll:
            cfg = dataclasses.replace(cfg, unroll=True)
        if layers_override:
            cfg = dataclasses.replace(cfg, n_layers=layers_override)
        if policy.mesh is not None:
            # production dtype policy: bf16 params/grads/KV-cache, f32
            # optimizer moments + loss (perf iteration 1, EXPERIMENTS §Perf)
            cfg = dataclasses.replace(cfg, dtype=jnp.bfloat16)
        cache_dtype = jnp.bfloat16 if policy.mesh is not None else jnp.float32
        c = (LM_SMOKE_CELLS if smoke else LM_CELLS)[cell.shape_id]
        B, S = c.batch, c.seq
        n_active = cfg.n_active_params()
        aparams = T.abstract_params(cfg, policy)

        def batch_sh_for(shape):
            return policy.named_for_shape(("batch",) + (None,) * (len(shape) - 1),
                                          shape)

        tok_t = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)

        if c.kind == "train":
            # production knobs (EXPERIMENTS.md §Perf): 8-bit Adam moments
            # + chunked CE on-mesh; plain f32/unchunked on CPU smoke
            opt_cfg = adamw.AdamWConfig(
                moment_dtype="int8" if policy.mesh is not None else "f32")
            ce_chunks = 8 if policy.mesh is not None else 1

            def step(params, opt_state, tokens, targets):
                loss, grads = jax.value_and_grad(
                    lambda p: T.loss_fn(cfg, p, tokens, targets, policy,
                                        chunks=ce_chunks)
                )(params)
                params, opt_state, metrics = adamw.update(opt_cfg, grads,
                                                          opt_state, params)
                return params, opt_state, {"loss": loss, **metrics}

            args = (aparams,
                    adamw.abstract_state(aparams, opt_cfg.moment_dtype),
                    tok_t((B, S), sharding=batch_sh_for((B, S))),
                    tok_t((B, S), sharding=batch_sh_for((B, S))))

            def concrete(key):
                p = T.init_params(cfg, key)
                toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
                return (p, adamw.init_state(p, opt_cfg.moment_dtype), toks,
                        toks)

            def check(out):
                _, _, m = out
                assert np.isfinite(float(m["loss"])), m

            trips = {} if cfg.unroll else {"while": cfg.n_layers}
            return StepBundle(step, args, trips,
                              6.0 * n_active * B * S, donate=(0, 1),
                              concrete_args=concrete, check=check)

        if c.kind == "prefill":
            def step(params, tokens, cache):
                return T.prefill(cfg, params, tokens, cache, policy)

            cache = T.cache_abstract(cfg, B, S, policy, dtype=cache_dtype)
            args = (aparams, tok_t((B, S), sharding=batch_sh_for((B, S))),
                    cache)

            def concrete(key):
                p = T.init_params(cfg, key)
                toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
                return (p, toks, T.init_cache(cfg, B, S, policy))

            def check(out):
                logits, _ = out
                assert np.all(np.isfinite(np.asarray(logits)))

            trips = {} if cfg.unroll else {"while": cfg.n_layers}
            return StepBundle(step, args, trips,
                              2.0 * n_active * B * S, donate=(2,),
                              concrete_args=concrete, check=check)

        # decode
        def step(params, token, pos, cache):
            return T.decode_step(cfg, params, token, pos, cache, policy)

        cache = T.cache_abstract(cfg, B, S, policy, dtype=cache_dtype)
        args = (aparams, tok_t((B, 1), sharding=batch_sh_for((B, 1))),
                jax.ShapeDtypeStruct((), jnp.int32), cache)

        def concrete(key):
            p = T.init_params(cfg, key)
            tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
            return (p, tok, jnp.int32(S // 2),
                    T.init_cache(cfg, B, S, policy))

        def check(out):
            logits, _ = out
            assert np.all(np.isfinite(np.asarray(logits)))

        # decode attention also reads O(B·S·kv·hd) cache bytes; FLOPs are
        # 2·N_active per token + attention dot 4·B·S·K·hd·g
        attn_flops = 4.0 * B * S * cfg.n_kv * cfg.hd * (cfg.n_heads // cfg.n_kv)
        trips = {} if cfg.unroll else {"while": cfg.n_layers}
        return StepBundle(step, args, trips,
                          2.0 * n_active * B + cfg.n_layers * attn_flops,
                          donate=(3,), concrete_args=concrete, check=check)


# ---------------------------------------------------------------------------
# GNN family.
# ---------------------------------------------------------------------------

GNN_CELLS = {
    "full_graph_sm": Cell("full_graph_sm", "train", batch=1,
                          meta=dict(n=2708, e=10556, d=1433, classes=7)),
    "minibatch_lg": Cell("minibatch_lg", "train", batch=1024,
                         meta=dict(n=232965, e=114615892, d=602, classes=41,
                                   fanout=(15, 10))),
    "ogb_products": Cell("ogb_products", "train", batch=1,
                         meta=dict(n=2449029, e=61859140, d=100, classes=47)),
    "molecule": Cell("molecule", "train", batch=128,
                     meta=dict(n=30, e=64, d=16, classes=2)),
}

GNN_SMOKE_META = {
    "full_graph_sm": dict(n=60, e=240, d=32, classes=7),
    "minibatch_lg": dict(n=200, e=800, d=16, classes=5, fanout=(3, 2),
                         batch=8),
    "ogb_products": dict(n=120, e=480, d=12, classes=4),
    "molecule": dict(n=6, e=12, d=8, classes=2, batch=4),
}


class GNNArch(ArchSpec):
    family = "gnn"

    def __init__(self, arch_id: str, kind: str, full_hp: Dict[str, Any],
                 smoke_hp: Dict[str, Any]):
        self.arch_id = arch_id
        self.kind = kind  # gcn | gin | gat | nequip
        self.full_hp = full_hp
        self.smoke_hp = smoke_hp

    def config(self, smoke: bool = False, **dims):
        hp = dict(self.smoke_hp if smoke else self.full_hp)
        hp.update(dims)
        cls = {"gcn": G.GCNConfig, "gin": G.GINConfig, "gat": G.GATConfig,
               "nequip": G.NequIPConfig}[self.kind]
        return cls(name=self.arch_id, **hp)

    def cells(self) -> Dict[str, Cell]:
        return GNN_CELLS

    def _abstract_batch(self, cell: Cell, meta, policy: ShardingPolicy):
        """ShapeDtypeStructs of the padded graph batch for this cell."""
        if cell.shape_id == "minibatch_lg":
            from repro.graphs.sampler import SamplerSpec
            bn = meta.get("batch", 1024)
            spec = SamplerSpec(bn, tuple(meta["fanout"]))
            n1 = spec.node_budget + 1
            E = spec.edge_budget
        elif cell.shape_id == "molecule":
            bsz = meta.get("batch", 128)
            n1 = bsz * meta["n"] + 1
            E = bsz * meta["e"]
        else:
            n1 = meta["n"] + 1
            E = meta["e"]
        if policy.mesh is not None:
            # pad node/edge counts to mesh-divisible sizes (padding nodes
            # are isolated; padding edges hit the dummy slot)
            n1 = -(-n1 // 512) * 512
            E = -(-E // 512) * 512
        node_sh = policy.named(("model", None))
        edge_sh = policy.named(("batch",))
        nvec_sh = policy.named(("model",))
        sds = jax.ShapeDtypeStruct
        b = {
            "x": sds((n1, meta["d"]), jnp.float32, sharding=node_sh),
            "src": sds((E,), jnp.int32, sharding=edge_sh),
            "dst": sds((E,), jnp.int32, sharding=edge_sh),
            "labels": sds((n1,), jnp.int32, sharding=nvec_sh),
        }
        if self.kind == "gcn":
            b["deg"] = sds((n1,), jnp.float32, sharding=nvec_sh)
        if self.kind == "gat":
            b["edge_pad"] = sds((E,), jnp.bool_, sharding=edge_sh)
        if self.kind == "nequip":
            b["pos"] = sds((n1, 3), jnp.float32, sharding=node_sh)
        if cell.shape_id == "molecule":
            bsz = meta.get("batch", 128)
            b["graph_ids"] = sds((n1,), jnp.int32, sharding=nvec_sh)
            b["n_graphs"] = bsz + 1
            b["labels"] = sds((bsz + 1,), jnp.int32)
        return b, n1, E

    def _concrete_batch(self, cell: Cell, meta, key):
        rng = np.random.default_rng(0)
        ab, n1, E = self._abstract_batch(cell, meta, NO_SHARDING)
        ab.pop("n_graphs", None)
        b = {}
        for k, v in ab.items():
            if not hasattr(v, "shape"):
                b[k] = v
            elif v.dtype == jnp.int32 and k == "labels":
                b[k] = jnp.asarray(rng.integers(0, meta["classes"], v.shape),
                                   jnp.int32)
            elif k in ("src", "dst"):
                b[k] = jnp.asarray(rng.integers(0, n1 - 1, v.shape), jnp.int32)
            elif k == "graph_ids":
                per = (n1 - 1) // (meta.get("batch", 1))
                gid = np.minimum(np.arange(n1) // max(per, 1),
                                 meta.get("batch", 1))
                b[k] = jnp.asarray(gid, jnp.int32)
            elif k == "edge_pad":
                b[k] = jnp.zeros(v.shape, bool)
            else:
                b[k] = jnp.asarray(rng.normal(size=v.shape), jnp.float32)
        if self.kind == "gcn":
            deg = np.bincount(np.asarray(b["dst"]), minlength=n1)
            b["deg"] = jnp.asarray(deg, jnp.float32)
        return b

    def _flops(self, meta, n1, E) -> float:
        d = meta["d"]
        if self.kind == "gcn":
            h = self.full_hp.get("d_hidden", 16)
            fwd = 2.0 * (n1 * d * h + E * h) * self.full_hp.get("n_layers", 2)
        elif self.kind == "gin":
            h = self.full_hp.get("d_hidden", 64)
            L = self.full_hp.get("n_layers", 5)
            fwd = 2.0 * L * (E * h + 2 * n1 * h * h) + 2.0 * n1 * d * h
        elif self.kind == "gat":
            h = self.full_hp.get("d_hidden", 8) * self.full_hp.get("n_heads", 8)
            fwd = 2.0 * self.full_hp.get("n_layers", 2) * (n1 * d * h + 3 * E * h)
        else:  # nequip
            C = self.full_hp.get("channels", 32)
            L = self.full_hp.get("n_layers", 5)
            fwd = 2.0 * L * (E * C * (9 + 13 * 6) + 3 * n1 * C * C * 13)
        return 3.0 * fwd  # train ~ 3x forward

    def build(self, cell: Cell, policy: ShardingPolicy = NO_SHARDING,
              smoke: bool = False) -> StepBundle:
        meta = dict(GNN_SMOKE_META[cell.shape_id] if smoke
                    else GNN_CELLS[cell.shape_id].meta)
        is_mol = cell.shape_id == "molecule"
        cfg = self.config(
            smoke, d_in=meta["d"],
            **({"n_out": meta["classes"], "readout": "node"}
               if self.kind == "nequip" and not is_mol else
               {"n_out": 1} if self.kind == "nequip" else
               {"n_classes": meta["classes"]}))
        opt_cfg = adamw.AdamWConfig(weight_decay=0.0)
        ab, n1, E = self._abstract_batch(cell, meta, policy)
        static_ng = ab.pop("n_graphs", None)  # static int, closed over

        def loss(params, batch):
            if static_ng is not None:
                batch = dict(batch, n_graphs=static_ng)
            if self.kind == "nequip" and is_mol:
                e = G.nequip_forward(cfg, params, batch)[:, 0]
                lbl = batch["labels"].astype(jnp.float32)
                return jnp.mean(jnp.square(e - lbl))
            logits = G.FORWARD[self.kind](cfg, params, batch)
            if is_mol and logits.shape[0] != batch["labels"].shape[0]:
                # graph classification: pool node logits (GIN pools itself)
                logits = jax.ops.segment_sum(logits, batch["graph_ids"],
                                             batch["n_graphs"])
            labels = batch["labels"]
            logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
            gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                       labels[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)

        def step(params, opt_state, batch):
            lv, grads = jax.value_and_grad(loss)(params, batch)
            params, opt_state, metrics = adamw.update(opt_cfg, grads,
                                                      opt_state, params)
            return params, opt_state, {"loss": lv, **metrics}

        key0 = jax.random.key(0)
        params0 = G.INIT[self.kind](cfg, key0)
        aparams = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params0)
        args = (aparams, adamw.abstract_state(aparams), ab)

        def concrete(key):
            p = G.INIT[self.kind](cfg, key)
            return (p, adamw.init_state(p), self._concrete_batch(cell, meta,
                                                                 key))

        def check(out):
            _, _, m = out
            assert np.isfinite(float(m["loss"])), m

        return StepBundle(step, args, {}, self._flops(meta, n1, E),
                          donate=(0, 1), concrete_args=concrete, check=check)


# ---------------------------------------------------------------------------
# RecSys family (xDeepFM).
# ---------------------------------------------------------------------------

RECSYS_CELLS = {
    "train_batch": Cell("train_batch", "train", batch=65536),
    "serve_p99": Cell("serve_p99", "serve", batch=512),
    "serve_bulk": Cell("serve_bulk", "serve", batch=262144),
    "retrieval_cand": Cell("retrieval_cand", "retrieval", batch=1,
                           meta=dict(n_candidates=1_000_000)),
}

RECSYS_SMOKE_CELLS = {
    "train_batch": Cell("train_batch", "train", batch=32),
    "serve_p99": Cell("serve_p99", "serve", batch=8),
    "serve_bulk": Cell("serve_bulk", "serve", batch=64),
    "retrieval_cand": Cell("retrieval_cand", "retrieval", batch=1,
                           meta=dict(n_candidates=512)),
}


class RecsysArch(ArchSpec):
    family = "recsys"
    arch_id = "xdeepfm"

    def config(self, smoke: bool = False) -> R.XDeepFMConfig:
        if smoke:
            return R.XDeepFMConfig("xdeepfm-smoke", n_fields=6,
                                   vocab_per_field=50, embed_dim=8,
                                   cin_layers=(8, 8), mlp_layers=(16, 16))
        return R.XDeepFMConfig("xdeepfm", n_fields=39,
                               vocab_per_field=1_000_000, embed_dim=10,
                               cin_layers=(200, 200, 200),
                               mlp_layers=(400, 400))

    def cells(self) -> Dict[str, Cell]:
        return RECSYS_CELLS

    def build(self, cell: Cell, policy: ShardingPolicy = NO_SHARDING,
              smoke: bool = False) -> StepBundle:
        cfg = self.config(smoke)
        c = (RECSYS_SMOKE_CELLS if smoke else RECSYS_CELLS)[cell.shape_id]
        B = c.batch
        sds = jax.ShapeDtypeStruct
        shapes = R.init_shapes(cfg)

        def mk_abs(pair):
            shape, logical = pair
            sh = policy.named(logical) if policy.mesh is not None else None
            return sds(shape, jnp.float32, sharding=sh)

        aparams = jax.tree.map(
            mk_abs, shapes,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple))
        batch_sh = policy.named(("batch", None, None))
        ids_t = sds((B, cfg.n_fields, cfg.multi_hot), jnp.int32,
                    sharding=batch_sh)
        # fwd flops: CIN dominates: 2 sum_k (B H_k m D + B H_k m D H_{k+1})
        m, D = cfg.n_fields, cfg.embed_dim
        prev = m
        fl = 0.0
        for h in cfg.cin_layers:
            fl += 2.0 * B * prev * m * D * (1 + h)
            prev = h
        d_mlp = m * D
        for h in cfg.mlp_layers:
            fl += 2.0 * B * d_mlp * h
            d_mlp = h

        if c.kind == "train":
            opt_cfg = adamw.AdamWConfig(weight_decay=0.0)

            def step(params, opt_state, ids, labels):
                lv, grads = jax.value_and_grad(
                    lambda p: R.bce_loss(cfg, p, ids, labels, policy))(params)
                params, opt_state, metrics = adamw.update(
                    opt_cfg, grads, opt_state, params)
                return params, opt_state, {"loss": lv, **metrics}

            args = (aparams, adamw.abstract_state(aparams), ids_t,
                    sds((B,), jnp.float32, sharding=policy.named(("batch",))))

            def concrete(key):
                p = R.init_params(cfg, key)
                rng = np.random.default_rng(0)
                ids = jnp.asarray(rng.integers(0, cfg.total_vocab,
                                               (B, cfg.n_fields, 1)), jnp.int32)
                lbl = jnp.asarray(rng.integers(0, 2, B), jnp.float32)
                return (p, adamw.init_state(p), ids, lbl)

            def check(out):
                _, _, metrics = out
                assert np.isfinite(float(metrics["loss"]))

            return StepBundle(step, args, {}, 3.0 * fl, donate=(0, 1),
                              concrete_args=concrete, check=check)

        if c.kind == "serve":
            def step(params, ids):
                return R.forward(cfg, params, ids, policy)

            args = (aparams, ids_t)

            def concrete(key):
                p = R.init_params(cfg, key)
                rng = np.random.default_rng(1)
                return (p, jnp.asarray(rng.integers(
                    0, cfg.total_vocab, (B, cfg.n_fields, 1)), jnp.int32))

            def check(out):
                assert np.all(np.isfinite(np.asarray(out)))

            return StepBundle(step, args, {}, fl, concrete_args=concrete,
                              check=check)

        # retrieval
        N = (cell if not smoke else RECSYS_SMOKE_CELLS[cell.shape_id]) \
            .meta["n_candidates"]

        def step(params, qids, cids):
            return R.retrieval_score(cfg, params, qids, cids, policy)

        cand_sh = policy.named(("batch", None, None))
        args = (aparams,
                sds((1, cfg.n_fields, cfg.multi_hot), jnp.int32),
                sds((N, cfg.n_fields, cfg.multi_hot), jnp.int32,
                    sharding=cand_sh))

        def concrete(key):
            p = R.init_params(cfg, key)
            rng = np.random.default_rng(2)
            q = jnp.asarray(rng.integers(0, cfg.total_vocab,
                                         (1, cfg.n_fields, 1)), jnp.int32)
            cd = jnp.asarray(rng.integers(0, cfg.total_vocab,
                                          (N, cfg.n_fields, 1)), jnp.int32)
            return (p, q, cd)

        def check(out):
            assert np.all(np.isfinite(np.asarray(out)))

        fl_ret = 2.0 * N * (cfg.n_fields * cfg.embed_dim + cfg.embed_dim)
        return StepBundle(step, args, {}, fl_ret, concrete_args=concrete,
                          check=check)


# ---------------------------------------------------------------------------
# The paper's own architecture: MFBC batch step.
# ---------------------------------------------------------------------------

BC_CELLS = {
    "bc_web_256k": Cell("bc_web_256k", "train", batch=8192,
                        meta=dict(n=262144, iters=8)),
    "bc_dense_64k": Cell("bc_dense_64k", "train", batch=16384,
                         meta=dict(n=65536, iters=6)),
}

BC_SMOKE_CELLS = {
    "bc_web_256k": Cell("bc_web_256k", "train", batch=8,
                        meta=dict(n=48, iters=6)),
    "bc_dense_64k": Cell("bc_dense_64k", "train", batch=12,
                         meta=dict(n=32, iters=5)),
}


class BCArch(ArchSpec):
    """MFBC itself, on the production mesh (Theorem 5.1 layout)."""

    family = "bc"
    arch_id = "mfbc_paper"

    def config(self, smoke: bool = False):
        return {"use_kernel": not smoke}

    def cells(self) -> Dict[str, Cell]:
        return BC_CELLS

    def build(self, cell: Cell, policy: ShardingPolicy = NO_SHARDING,
              smoke: bool = False, unroll: bool = False) -> StepBundle:
        from repro.core import dist_bc

        c = (BC_SMOKE_CELLS if smoke else BC_CELLS)[cell.shape_id]
        n, nb, iters = c.meta["n"], c.batch, c.meta["iters"]
        sds = jax.ShapeDtypeStruct

        if policy.mesh is not None:
            mesh = policy.mesh
            pod = "pod" if "pod" in mesh.axis_names else None
            cfg = dist_bc.BCMeshConfig(n=n, nb=nb, iters_bf=iters,
                                       iters_br=iters, pod_axis=pod,
                                       use_kernel=False, block=1024,
                                       unroll=unroll)
            step = dist_bc.build_mfbc_step(mesh, cfg)
            sh_a, sh_at, sh_src, sh_val = dist_bc.input_shardings(mesh, cfg)
            args = (sds((n, n), jnp.float32, sharding=sh_a),
                    sds((n, n), jnp.float32, sharding=sh_at),
                    sds((nb,), jnp.int32, sharding=sh_src),
                    sds((nb,), jnp.bool_, sharding=sh_val))
            trips = {} if unroll else {"while": iters}
            return StepBundle(step, args, trips,
                              self._flops(n, nb, iters), concrete_args=None)

        # smoke: single device, non-distributed jitted batch
        from repro.core.mfbc import mfbc_batch
        from repro.core.adjacency import DenseAdj

        def step(a, sources, valid):
            return mfbc_batch(DenseAdj(a, block=256), sources, valid,
                              iterate="fori", max_iters_bf=iters,
                              max_iters_br=iters)[0]

        args = (sds((n, n), jnp.float32), sds((nb,), jnp.int32),
                sds((nb,), jnp.bool_))

        def concrete(key):
            from repro.graphs.generators import erdos_renyi
            from repro.graphs.formats import coo_to_dense
            g = erdos_renyi(n, 4.0 / n, seed=1)
            return (jnp.asarray(coo_to_dense(g)),
                    jnp.arange(nb, dtype=jnp.int32),
                    jnp.ones(nb, bool))

        def check(lam):
            assert np.all(np.isfinite(np.asarray(lam)))
            assert np.all(np.asarray(lam) >= -1e-6)

        return StepBundle(step, args, {"while": iters},
                          self._flops(n, nb, iters), concrete_args=concrete,
                          check=check)

    @staticmethod
    def _flops(n, nb, iters):
        # each relax: nb*n*n candidate min-plus updates (~4 vector flops),
        # 2(d+1) relaxes per batch (MFBF + MFBr)
        return 4.0 * nb * n * n * 2 * (iters + 1)
