from repro.configs.base import ArchSpec, Cell, StepBundle
from repro.configs.registry import ARCHS, all_cells, get_arch

__all__ = ["ArchSpec", "Cell", "StepBundle", "ARCHS", "all_cells",
           "get_arch"]
