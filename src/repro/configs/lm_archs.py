"""The five assigned LM-family architectures (exact published configs)."""
from __future__ import annotations

from repro.configs.base import LMArch
from repro.models.layers import MoeConfig
from repro.models.transformer import TransformerConfig


def _gemma2_27b() -> TransformerConfig:
    # [arXiv:2408.00118]: 46L, d=4608, 32H (GQA kv=16), d_ff=36864,
    # vocab=256000; alternating 4096-window local / global attention;
    # attn softcap 50, final softcap 30; GeGLU; tied + scaled embeddings;
    # query scale = 1/sqrt(d_model/n_heads) = 1/sqrt(144).
    return TransformerConfig(
        name="gemma2-27b", n_layers=46, d_model=4608, n_heads=32, n_kv=16,
        d_ff=36864, vocab=256000, head_dim=128, block_style="sandwich",
        act="gelu", attn_softcap=50.0, final_softcap=30.0,
        query_scale=(4608 / 32) ** -0.5, tie_embeddings=True,
        scale_embeddings=True, window_pattern=(4096, None),
        rope_theta=10000.0, remat="full")


def _gemma2_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv=2,
        d_ff=128, vocab=512, head_dim=16, block_style="sandwich", act="gelu",
        attn_softcap=50.0, final_softcap=30.0, query_scale=16 ** -0.5,
        tie_embeddings=True, scale_embeddings=True, window_pattern=(16, None))


def _command_r_plus() -> TransformerConfig:
    # [hf:CohereForAI/c4ai-command-r-plus]: 64L, d=12288, 96H (GQA kv=8),
    # d_ff=33792, vocab=256000; parallel attention+FFN blocks, no bias,
    # tied embeddings, rope 75e4... (use 10k default; unverified tier).
    return TransformerConfig(
        name="command-r-plus-104b", n_layers=64, d_model=12288, n_heads=96,
        n_kv=8, d_ff=33792, vocab=256000, head_dim=128,
        block_style="parallel", act="silu", tie_embeddings=True,
        rope_theta=75000.0, remat="full")


def _command_r_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="command-r-smoke", n_layers=3, d_model=64, n_heads=8, n_kv=2,
        d_ff=128, vocab=512, head_dim=8, block_style="parallel",
        tie_embeddings=True)


def _granite_34b() -> TransformerConfig:
    # [arXiv:2405.04324] Granite code 34B: 88L, d=6144, 48H (MQA kv=1),
    # d_ff=24576, vocab=49152. GPT-BigCode lineage: MQA + plain (non-gated)
    # 2-matrix MLP — matches the 34B total; the assignment's "llama-arch"
    # note covers the pre-norm decoder block structure.
    return TransformerConfig(
        name="granite-34b", n_layers=88, d_model=6144, n_heads=48, n_kv=1,
        d_ff=24576, vocab=49152, head_dim=128, block_style="prenorm",
        mlp_style="plain", act="gelu", tie_embeddings=True, remat="full")


def _granite_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="granite-smoke", n_layers=3, d_model=48, n_heads=6, n_kv=1,
        d_ff=96, vocab=512, head_dim=8, block_style="prenorm",
        mlp_style="plain", act="gelu")


def _moonshot_16b() -> TransformerConfig:
    # [hf:moonshotai/Moonlight-16B-A3B]: 48L... spec sheet (assignment):
    # 48L (but 27L in HF — we follow the assignment row): d=2048, 16H
    # (kv=16), MoE 64 experts top-6, expert d_ff=1408, vocab=163840,
    # 2 shared experts of d_ff=2816 (moonlight uses shared experts).
    return TransformerConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
        n_kv=16, d_ff=1408, vocab=163840, head_dim=128,
        block_style="prenorm", act="silu", tie_embeddings=True,
        moe=MoeConfig(n_experts=64, top_k=6, d_ff=1408, n_shared=2,
                      d_ff_shared=2816), remat="full")


def _moonshot_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="moonshot-smoke", n_layers=3, d_model=64, n_heads=4, n_kv=4,
        d_ff=64, vocab=512, head_dim=16,
        moe=MoeConfig(n_experts=8, top_k=2, d_ff=64, n_shared=1,
                      d_ff_shared=96))


def _qwen3_moe() -> TransformerConfig:
    # [hf:Qwen/Qwen3-235B-A22B]: 94L, d=4096, 64H (GQA kv=4), MoE 128
    # experts top-8, expert d_ff=1536, vocab=151936.
    return TransformerConfig(
        name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
        n_kv=4, d_ff=1536, vocab=151936, head_dim=128, block_style="prenorm",
        act="silu", tie_embeddings=True,
        moe=MoeConfig(n_experts=128, top_k=8, d_ff=1536), remat="full")


def _qwen3_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-smoke", n_layers=3, d_model=64, n_heads=8, n_kv=2,
        d_ff=96, vocab=512, head_dim=8,
        moe=MoeConfig(n_experts=8, top_k=2, d_ff=48))


GEMMA2_27B = LMArch("gemma2-27b", _gemma2_27b, _gemma2_smoke)
COMMAND_R_PLUS = LMArch("command-r-plus-104b", _command_r_plus,
                        _command_r_smoke)
GRANITE_34B = LMArch("granite-34b", _granite_34b, _granite_smoke)
MOONSHOT_16B = LMArch("moonshot-v1-16b-a3b", _moonshot_16b, _moonshot_smoke)
QWEN3_MOE = LMArch("qwen3-moe-235b-a22b", _qwen3_moe, _qwen3_smoke)
