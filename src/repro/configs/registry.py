"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchSpec, BCArch, RecsysArch
from repro.configs.gnn_archs import GAT_CORA, GCN_CORA, GIN_TU, NEQUIP
from repro.configs.lm_archs import (COMMAND_R_PLUS, GEMMA2_27B, GRANITE_34B,
                                    MOONSHOT_16B, QWEN3_MOE)

ARCHS: Dict[str, ArchSpec] = {
    a.arch_id: a for a in [
        GEMMA2_27B, COMMAND_R_PLUS, GRANITE_34B, MOONSHOT_16B, QWEN3_MOE,
        GCN_CORA, GIN_TU, NEQUIP, GAT_CORA,
        RecsysArch(), BCArch(),
    ]
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells():
    """Every (arch_id, shape_id) dry-run cell."""
    out = []
    for aid, spec in ARCHS.items():
        for sid in spec.cells():
            out.append((aid, sid))
    return out
