"""The four assigned GNN architectures."""
from repro.configs.base import GNNArch

# gcn-cora [arXiv:1609.02907]: 2 layers, 16 hidden, mean/sym-norm agg.
GCN_CORA = GNNArch(
    "gcn-cora", "gcn",
    full_hp=dict(n_layers=2, d_hidden=16),
    smoke_hp=dict(n_layers=2, d_hidden=8))

# gin-tu [arXiv:1810.00826]: 5 layers, 64 hidden, sum agg, learnable eps.
GIN_TU = GNNArch(
    "gin-tu", "gin",
    full_hp=dict(n_layers=5, d_hidden=64, learn_eps=True),
    smoke_hp=dict(n_layers=2, d_hidden=16, learn_eps=True))

# nequip [arXiv:2101.03164]: 5 layers, 32 channels, l_max=2, 8 rbf,
# cutoff 5 Å — realized in the Cartesian tensor basis (DESIGN.md §3).
NEQUIP = GNNArch(
    "nequip", "nequip",
    full_hp=dict(n_layers=5, channels=32, l_max=2, n_rbf=8, cutoff=5.0),
    smoke_hp=dict(n_layers=2, channels=8, l_max=2, n_rbf=4, cutoff=5.0))

# gat-cora [arXiv:1710.10903]: 2 layers, 8 hidden x 8 heads.
GAT_CORA = GNNArch(
    "gat-cora", "gat",
    full_hp=dict(n_layers=2, d_hidden=8, n_heads=8),
    smoke_hp=dict(n_layers=2, d_hidden=4, n_heads=2))
