"""Version-compat shims for the jax API surface we depend on.

``shard_map`` moved from ``jax.experimental.shard_map`` (≤ 0.4.x, where the
replication-check kwarg is ``check_rep``) to the top-level ``jax``
namespace (≥ 0.5, kwarg renamed ``check_vma``). Callers always use the
modern spelling; this module translates for older installs.
"""
from __future__ import annotations

import functools

try:  # jax >= 0.5: top-level export, check_vma kwarg
    from jax import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
except ImportError:  # jax 0.4.x: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)

functools.wraps(_shard_map)(shard_map)


try:  # jax >= 0.6: context mesh for sharding propagation under jit
    from jax.sharding import set_mesh
except ImportError:  # jax 0.4.x: Mesh is itself a context manager
    import contextlib

    @contextlib.contextmanager
    def set_mesh(mesh):
        with mesh:
            yield mesh


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version.

    jax ≤ 0.4.x returns a per-device list of dicts; ≥ 0.5 returns the dict
    directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


try:  # jax >= 0.5
    from jax.lax import axis_size
except ImportError:  # jax 0.4.x: core.axis_frame(name)
    from jax.core import axis_frame as _axis_frame

    def axis_size(axis_name) -> int:
        # late 0.4.x returns the size itself; earlier 0.4.x a frame
        # object carrying .size
        frame = _axis_frame(axis_name)
        return getattr(frame, "size", frame)
