"""α–β communication cost model for distributed SpGEMM (paper §5.2).

Multiplying ``A (m×k) · B (k×n) → C (m×n)``, all potentially sparse, on a
processor grid. Costs are in seconds given ``CostParams``; sizes are in
*bytes* (the paper counts words — a constant factor absorbed into β).

Formulas implemented verbatim from the paper:

* 1D variant X ∈ {A, B, C}:       W_X  = α·log p + β·nnz(X)
* 2D variant YZ ∈ {AB, AC, BC}:   W_YZ = α·max(p_r, p_c)·log p
                                         + β·(nnz(Y)/p_r + nnz(Z)/p_c)
* 3D nesting (X over p₁, YZ over p₂×p₃) — the paper's composite expression,
  including the X=Y / X=Z / X∉{Y,Z} cases.
* ``w_mm`` — the W_MM envelope: min over factorizations p₁p₂p₃ = p of
  α·max(pᵢ)·log p + β·(nnzA/(p₁p₂)·δ(p₃) + nnzB/(p₂p₃)·δ(p₁)
  + nnzC/(p₁p₃)·δ(p₂)).
* ``w_mfbc`` — the Theorem 5.1 BC bound with replication factor c.
* ``mem_3d`` — the M_X,YZ memory footprint.

The same formulas drive the runtime autotuner (``repro.spgemm.autotune``)
— the analogue of CTF's model-based mapping search.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Iterable, List, Optional, Tuple

# --- hardware constants (TPU v5e, per chip) -------------------------------
V5E_PEAK_BF16_FLOPS = 197e12  # FLOP/s
V5E_HBM_BW = 819e9  # bytes/s
V5E_ICI_BW = 50e9  # bytes/s per link
V5E_ICI_LATENCY = 1e-6  # seconds per message (α)


@dataclasses.dataclass(frozen=True)
class CostParams:
    alpha: float = V5E_ICI_LATENCY  # s per message
    beta: float = 1.0 / V5E_ICI_BW  # s per byte

    def cost(self, msgs: float, bytes_: float) -> float:
        return self.alpha * msgs + self.beta * bytes_


DEFAULT = CostParams()


@dataclasses.dataclass(frozen=True)
class ProblemSizes:
    """Byte counts of the three operands (and flops for sanity checks)."""

    nnz_a: float
    nnz_b: float
    nnz_c: float
    flops: float = 0.0

    def nnz(self, which: str) -> float:
        return {"A": self.nnz_a, "B": self.nnz_b, "C": self.nnz_c}[which]


def _log2(p: float) -> float:
    return math.log2(max(p, 2.0))


def w_1d(variant: str, sizes: ProblemSizes, p: int,
         params: CostParams = DEFAULT) -> float:
    """W_X(X, p) = O(α log p + β nnz(X))."""
    assert variant in ("A", "B", "C")
    if p <= 1:
        return 0.0
    return params.cost(_log2(p), sizes.nnz(variant))


def w_2d(variant: str, sizes: ProblemSizes, pr: int, pc: int,
         params: CostParams = DEFAULT) -> float:
    """W_YZ(Y, Z, p_r, p_c)."""
    assert variant in ("AB", "AC", "BC")
    y, z = variant[0], variant[1]
    p = pr * pc
    if p <= 1:
        return 0.0
    bytes_ = sizes.nnz(y) / pr + sizes.nnz(z) / pc
    return params.cost(max(pr, pc) * _log2(p), bytes_)


def w_3d(x: str, yz: str, sizes: ProblemSizes, p1: int, p2: int, p3: int,
         params: CostParams = DEFAULT) -> float:
    """Nested 1D(X over p₁) ∘ 2D(YZ over p₂×p₃), paper's simplified form.

    The inner 2D problem sees operand sizes shrunk by the 1D blocking:
    X is gathered from a p₂×p₃ distribution (bytes nnz(X)/(p₂p₃) per step
    before replication — the paper's W_X(X[p₂,p₃]) term), and operands not
    replicated are sliced by p₁.
    """
    assert x in ("A", "B", "C") and yz in ("AB", "AC", "BC")
    y, z = yz[0], yz[1]
    inner = dataclasses.asdict(sizes)
    key = {"A": "nnz_a", "B": "nnz_b", "C": "nnz_c"}
    if x == y:
        inner[key[z]] = sizes.nnz(z) / p1
    elif x == z:
        inner[key[y]] = sizes.nnz(y) / p1
    else:
        inner[key[y]] = sizes.nnz(y) / p1
        inner[key[z]] = sizes.nnz(z) / p1
    inner_sizes = ProblemSizes(**inner)
    # 1D replication of X from its (p2, p3) distribution:
    w_repl = params.cost(_log2(p1) if p1 > 1 else 0.0,
                         sizes.nnz(x) / (p2 * p3) * max(p1 - 1, 0))
    return w_repl + w_2d(yz, inner_sizes, p2, p3, params)


def mem_3d(x: str, yz: str, sizes: ProblemSizes, p: int, p1: int) -> float:
    """M_X,YZ = O(nnz(X)·p₁/p + (nnz(Y)+nnz(Z))/p) bytes per processor."""
    y, z = yz[0], yz[1]
    return sizes.nnz(x) * p1 / p + (sizes.nnz(y) + sizes.nnz(z)) / p


def factorizations(p: int, ways: int = 3) -> List[Tuple[int, ...]]:
    """All ordered factorizations of p into ``ways`` positive factors."""
    if ways == 1:
        return [(p,)]
    out = []
    for d in range(1, p + 1):
        if p % d == 0:
            for rest in factorizations(p // d, ways - 1):
                out.append((d,) + rest)
    return out


def w_mm(sizes: ProblemSizes, p: int, params: CostParams = DEFAULT,
         mem_limit: float = float("inf")) -> Tuple[float, Tuple[int, int, int]]:
    """The paper's W_MM envelope: best cost over p₁p₂p₃ = p factorizations.

    Returns (cost_seconds, (p1, p2, p3)). δ(x)=0 iff x==1 — an axis of size
    1 moves nothing for its operand.
    """
    best, best_f = float("inf"), (p, 1, 1)
    for (p1, p2, p3) in factorizations(p):
        bytes_ = 0.0
        bytes_ += (sizes.nnz_a / (p1 * p2)) * (0 if p3 == 1 else 1)
        bytes_ += (sizes.nnz_b / (p2 * p3)) * (0 if p1 == 1 else 1)
        bytes_ += (sizes.nnz_c / (p1 * p3)) * (0 if p2 == 1 else 1)
        cost = params.cost(max(p1, p2, p3) * _log2(p), bytes_)
        # rough memory: replicated fraction of each operand
        mem = (sizes.nnz_a / (p1 * p2) + sizes.nnz_b / (p2 * p3)
               + sizes.nnz_c / (p1 * p3))
        if mem > mem_limit:
            continue
        if cost < best:
            best, best_f = cost, (p1, p2, p3)
    return best, best_f


V5E_VPU_OPS = 3.9e12  # elementwise min-plus ops/s (VPU, not MXU)


def w_mfbc(n: int, m_edges: int, p: int, c: int, d: int, word: int = 8,
           params: CostParams = DEFAULT, flop_rate: float = V5E_VPU_OPS
           ) -> Dict[str, float]:
    """Theorem 5.1 cost terms for one full BC computation.

    n vertices, m arcs, p processors, replication factor c, diameter d.
    word = bytes per matrix element (multpath = 8: w + m as f32 pairs).

    β term per batch: Σ_i (nnz(F_i)+nnz(G_i))/√(pc) ≤ 4cm/√(pc) words
    (unweighted frontier-uniqueness bound), plus the amortized adjacency
    replication cm/p. Total over n²/(cm) batches = 4n²/√(cp) + cm/p —
    the Theorem 5.1 bound. ``seconds`` adds a sparse-work compute term
    (8·n·m relaxation ops over p VPUs) so TEPS projections are grounded.
    """
    c = max(1, min(c, p))
    n_batches = max(1.0, n * n / (c * m_edges))
    msgs = d * n_batches * math.sqrt(p / c) * _log2(p)
    bytes_ = word * (c * m_edges / p  # adjacency replication (amortized)
                     + n_batches * (4.0 * c * m_edges) / math.sqrt(p * c))
    comm = params.cost(msgs, bytes_)
    compute = 8.0 * n * m_edges / (p * flop_rate)
    return {
        "alpha_msgs": msgs,
        "beta_bytes": bytes_,
        "seconds": max(comm, compute),
        "comm_seconds": comm,
        "compute_seconds": compute,
        "n_b": c * m_edges / n,
        "n_batches": n_batches,
        "memory_per_p": word * c * m_edges / p,
    }


# --- measured step-time calibration ---------------------------------------
#
# The analytic per-relax estimates above price the TPU target from
# first-principles hardware constants; on any real host (CPU CI, an
# actual TPU slice, an emulator) they are off by orders of magnitude —
# predicted 0.059s vs measured ~4.1s per run made every plan-based
# admission and packing decision fiction. ``Calibration`` closes the
# loop: ``repro.launch.calibrate`` measures warm batch-step times per
# execution variant, fits the α-β pair (fixed per-device-call overhead
# α, effective relax throughput 1/β) from two batch sizes, and persists
# it to ``results/cost_calibration.json``; ``load_calibration`` is how
# the planner and ``choose_bc_regime`` pick it up.

#: Default on-disk location (override with $REPRO_BC_CALIBRATION).
DEFAULT_CALIBRATION_PATH = "results/cost_calibration.json"
CALIBRATION_VERSION = 1

#: Execution variants the calibration prices (see ``variant_key``).
STEP_VARIANTS = ("dense", "dense_kernel", "coo", "csr")


def variant_key(backend: str, use_kernel: bool = False) -> str:
    """Calibration table key for a (backend, kernel flag) pair."""
    backend = str(getattr(backend, "value", backend))
    if backend == "dense":
        return "dense_kernel" if use_kernel else "dense"
    return backend


def relax_ops(backend: str, n: int, m_edges: int, nb: int,
              *, p: int = 1, use_kernel: bool = False,
              est_iters: Optional[int] = None) -> float:
    """Work units of ONE relax iteration of one batch, per device.

    The unit the calibrated throughput is expressed in: dense relax
    touches every (source, vertex²) candidate (``4·nb·n²/p`` min-plus +
    tie updates, kernel or jnp fallback alike); the COO relax is
    segment ops over the *full* padded edge list every iteration
    (``4·nb·m/p`` — that implementation does not compact frontiers, so
    work is fill-independent; the analytic model's ``fill`` knob only
    applies to the uncalibrated estimate).

    The CSR relax compacts the maximal frontier, so its per-iteration
    work is *occupancy-aware*: each (source, vertex) entry enters the
    maximal frontier O(1) times per sweep, so the sweep's total
    candidate work is ≈ ``nb·m`` — ``Σ_iter frontier_nnz·k̄`` — spread
    over ``est_iters`` iterations, plus the per-iteration ``(nb, n)``
    mask/compaction floor: ``4·nb·(m/est_iters + n)/p``. Callers that
    price a whole sweep (W = 2·est_iters·relax_ops) must pass the same
    ``est_iters`` the fit used, so the heuristic cancels.
    """
    backend = str(getattr(backend, "value", backend))
    if backend == "dense":
        return 4.0 * nb * n * n / max(p, 1)
    if backend == "csr":
        iters = max(int(est_iters or 1), 1)
        return 4.0 * nb * (m_edges / iters + n) / max(p, 1)
    return 4.0 * nb * m_edges / max(p, 1)


@dataclasses.dataclass(frozen=True)
class StepRates:
    """Fitted α-β constants for one execution variant.

    ``seconds(batch) = overhead_s + relaxes · ops_per_relax / ops_per_s``
    — ``overhead_s`` is the fixed per-device-call cost (dispatch, host
    sync), ``ops_per_s`` the measured effective relax throughput.
    """

    ops_per_s: float
    overhead_s: float = 0.0

    def relax_seconds(self, ops: float) -> float:
        return ops / max(self.ops_per_s, 1.0)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Measured step-time constants, keyed by execution variant.

    ``rates`` maps ``variant_key(backend, use_kernel)`` →
    ``StepRates``; ``meta`` records where the numbers came from (jax
    backend, graph shape, batch sizes, iteration model) so a stale
    calibration is auditable. Missing variants fall back to the
    analytic model at the call site.
    """

    rates: Dict[str, StepRates]
    meta: Dict = dataclasses.field(default_factory=dict)

    def has(self, backend: str, use_kernel: bool = False) -> bool:
        return variant_key(backend, use_kernel) in self.rates

    def step_seconds(self, backend: str, n: int, m_edges: int, nb: int,
                     *, p: int = 1, use_kernel: bool = False,
                     est_iters: Optional[int] = None) -> float:
        """Calibrated seconds of ONE relax iteration of one batch.

        ``est_iters`` only matters for the frontier-compacting CSR
        variant (its per-iteration work amortizes the sweep, see
        ``relax_ops``) and must match the value the fit used.
        """
        r = self.rates[variant_key(backend, use_kernel)]
        return r.relax_seconds(relax_ops(backend, n, m_edges, nb, p=p,
                                         use_kernel=use_kernel,
                                         est_iters=est_iters))

    def overhead_seconds(self, backend: str, use_kernel: bool = False
                         ) -> float:
        """Fixed per-batch (per device call) overhead of a variant."""
        return self.rates[variant_key(backend, use_kernel)].overhead_s

    def kernel_pays(self) -> bool:
        """Measured verdict: does the Pallas dense kernel beat the jnp
        fallback on this host? (False on CPU, where the kernel runs in
        interpret mode; True on the TPU target.) Conservative when the
        kernel variant was not measured."""
        if "dense" not in self.rates or "dense_kernel" not in self.rates:
            return False
        return (self.rates["dense_kernel"].ops_per_s
                > self.rates["dense"].ops_per_s)

    def to_json(self) -> Dict:
        return {
            "version": CALIBRATION_VERSION,
            "meta": dict(self.meta),
            "rates": {k: {"ops_per_s": r.ops_per_s,
                          "overhead_s": r.overhead_s}
                      for k, r in self.rates.items()},
        }

    @classmethod
    def from_json(cls, d: Dict) -> "Calibration":
        if d.get("version") != CALIBRATION_VERSION:
            raise ValueError(f"unsupported calibration version "
                             f"{d.get('version')!r}")
        rates = {k: StepRates(ops_per_s=float(r["ops_per_s"]),
                              overhead_s=float(r.get("overhead_s", 0.0)))
                 for k, r in d.get("rates", {}).items()}
        if not rates:
            raise ValueError("calibration has no rates")
        return cls(rates=rates, meta=dict(d.get("meta", {})))


_CAL_CACHE: Dict[Tuple[str, float], Optional[Calibration]] = {}


def calibration_path(path: Optional[str] = None) -> str:
    return path or os.environ.get("REPRO_BC_CALIBRATION",
                                  DEFAULT_CALIBRATION_PATH)


def load_calibration(path: Optional[str] = None) -> Optional[Calibration]:
    """Load the persisted calibration, or None when there is none.

    Cached per (absolute path, mtime): a benchmark that recalibrates
    and replans in one process sees the fresh numbers, while the
    planner's per-plan lookups stay free. An unreadable or malformed
    file is treated as "not calibrated" (the analytic model is always
    a safe fallback), not an error.
    """
    p = os.path.abspath(calibration_path(path))
    try:
        mtime = os.path.getmtime(p)
    except OSError:
        return None
    key = (p, mtime)
    if key not in _CAL_CACHE:
        _CAL_CACHE.clear()  # one live entry: old mtimes never return
        try:
            with open(p) as f:
                _CAL_CACHE[key] = Calibration.from_json(json.load(f))
        except (OSError, ValueError, KeyError, TypeError):
            _CAL_CACHE[key] = None
    return _CAL_CACHE[key]


def save_calibration(cal: Calibration, path: Optional[str] = None) -> str:
    """Persist a calibration (the measurement loop's last step)."""
    p = calibration_path(path)
    d = os.path.dirname(p)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(p, "w") as f:
        json.dump(cal.to_json(), f, indent=1)
    return p


def best_replication(n: int, m_edges: int, p: int, mem_bytes: float,
                     d: int = 10, word: int = 8,
                     params: CostParams = DEFAULT) -> int:
    """Paper: c* = p^{1/3} n²/m, clamped by memory M = Ω(c·m/p)."""
    c_star = p ** (1.0 / 3.0) * n * n / m_edges
    c_mem = mem_bytes * p / (word * m_edges)
    c = int(max(1, min(c_star, c_mem, p)))
    # refine within a factor-2 neighbourhood by direct evaluation
    cands = sorted({max(1, c // 2), c, min(p, 2 * c), 1})
    return min(cands, key=lambda cc: w_mfbc(n, m_edges, p, cc, d, word,
                                            params)["seconds"])
