"""α–β communication cost model for distributed SpGEMM (paper §5.2).

Multiplying ``A (m×k) · B (k×n) → C (m×n)``, all potentially sparse, on a
processor grid. Costs are in seconds given ``CostParams``; sizes are in
*bytes* (the paper counts words — a constant factor absorbed into β).

Formulas implemented verbatim from the paper:

* 1D variant X ∈ {A, B, C}:       W_X  = α·log p + β·nnz(X)
* 2D variant YZ ∈ {AB, AC, BC}:   W_YZ = α·max(p_r, p_c)·log p
                                         + β·(nnz(Y)/p_r + nnz(Z)/p_c)
* 3D nesting (X over p₁, YZ over p₂×p₃) — the paper's composite expression,
  including the X=Y / X=Z / X∉{Y,Z} cases.
* ``w_mm`` — the W_MM envelope: min over factorizations p₁p₂p₃ = p of
  α·max(pᵢ)·log p + β·(nnzA/(p₁p₂)·δ(p₃) + nnzB/(p₂p₃)·δ(p₁)
  + nnzC/(p₁p₃)·δ(p₂)).
* ``w_mfbc`` — the Theorem 5.1 BC bound with replication factor c.
* ``mem_3d`` — the M_X,YZ memory footprint.

The same formulas drive the runtime autotuner (``repro.spgemm.autotune``)
— the analogue of CTF's model-based mapping search.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Tuple

# --- hardware constants (TPU v5e, per chip) -------------------------------
V5E_PEAK_BF16_FLOPS = 197e12  # FLOP/s
V5E_HBM_BW = 819e9  # bytes/s
V5E_ICI_BW = 50e9  # bytes/s per link
V5E_ICI_LATENCY = 1e-6  # seconds per message (α)


@dataclasses.dataclass(frozen=True)
class CostParams:
    alpha: float = V5E_ICI_LATENCY  # s per message
    beta: float = 1.0 / V5E_ICI_BW  # s per byte

    def cost(self, msgs: float, bytes_: float) -> float:
        return self.alpha * msgs + self.beta * bytes_


DEFAULT = CostParams()


@dataclasses.dataclass(frozen=True)
class ProblemSizes:
    """Byte counts of the three operands (and flops for sanity checks)."""

    nnz_a: float
    nnz_b: float
    nnz_c: float
    flops: float = 0.0

    def nnz(self, which: str) -> float:
        return {"A": self.nnz_a, "B": self.nnz_b, "C": self.nnz_c}[which]


def _log2(p: float) -> float:
    return math.log2(max(p, 2.0))


def w_1d(variant: str, sizes: ProblemSizes, p: int,
         params: CostParams = DEFAULT) -> float:
    """W_X(X, p) = O(α log p + β nnz(X))."""
    assert variant in ("A", "B", "C")
    if p <= 1:
        return 0.0
    return params.cost(_log2(p), sizes.nnz(variant))


def w_2d(variant: str, sizes: ProblemSizes, pr: int, pc: int,
         params: CostParams = DEFAULT) -> float:
    """W_YZ(Y, Z, p_r, p_c)."""
    assert variant in ("AB", "AC", "BC")
    y, z = variant[0], variant[1]
    p = pr * pc
    if p <= 1:
        return 0.0
    bytes_ = sizes.nnz(y) / pr + sizes.nnz(z) / pc
    return params.cost(max(pr, pc) * _log2(p), bytes_)


def w_3d(x: str, yz: str, sizes: ProblemSizes, p1: int, p2: int, p3: int,
         params: CostParams = DEFAULT) -> float:
    """Nested 1D(X over p₁) ∘ 2D(YZ over p₂×p₃), paper's simplified form.

    The inner 2D problem sees operand sizes shrunk by the 1D blocking:
    X is gathered from a p₂×p₃ distribution (bytes nnz(X)/(p₂p₃) per step
    before replication — the paper's W_X(X[p₂,p₃]) term), and operands not
    replicated are sliced by p₁.
    """
    assert x in ("A", "B", "C") and yz in ("AB", "AC", "BC")
    y, z = yz[0], yz[1]
    inner = dataclasses.asdict(sizes)
    key = {"A": "nnz_a", "B": "nnz_b", "C": "nnz_c"}
    if x == y:
        inner[key[z]] = sizes.nnz(z) / p1
    elif x == z:
        inner[key[y]] = sizes.nnz(y) / p1
    else:
        inner[key[y]] = sizes.nnz(y) / p1
        inner[key[z]] = sizes.nnz(z) / p1
    inner_sizes = ProblemSizes(**inner)
    # 1D replication of X from its (p2, p3) distribution:
    w_repl = params.cost(_log2(p1) if p1 > 1 else 0.0,
                         sizes.nnz(x) / (p2 * p3) * max(p1 - 1, 0))
    return w_repl + w_2d(yz, inner_sizes, p2, p3, params)


def mem_3d(x: str, yz: str, sizes: ProblemSizes, p: int, p1: int) -> float:
    """M_X,YZ = O(nnz(X)·p₁/p + (nnz(Y)+nnz(Z))/p) bytes per processor."""
    y, z = yz[0], yz[1]
    return sizes.nnz(x) * p1 / p + (sizes.nnz(y) + sizes.nnz(z)) / p


def factorizations(p: int, ways: int = 3) -> List[Tuple[int, ...]]:
    """All ordered factorizations of p into ``ways`` positive factors."""
    if ways == 1:
        return [(p,)]
    out = []
    for d in range(1, p + 1):
        if p % d == 0:
            for rest in factorizations(p // d, ways - 1):
                out.append((d,) + rest)
    return out


def w_mm(sizes: ProblemSizes, p: int, params: CostParams = DEFAULT,
         mem_limit: float = float("inf")) -> Tuple[float, Tuple[int, int, int]]:
    """The paper's W_MM envelope: best cost over p₁p₂p₃ = p factorizations.

    Returns (cost_seconds, (p1, p2, p3)). δ(x)=0 iff x==1 — an axis of size
    1 moves nothing for its operand.
    """
    best, best_f = float("inf"), (p, 1, 1)
    for (p1, p2, p3) in factorizations(p):
        bytes_ = 0.0
        bytes_ += (sizes.nnz_a / (p1 * p2)) * (0 if p3 == 1 else 1)
        bytes_ += (sizes.nnz_b / (p2 * p3)) * (0 if p1 == 1 else 1)
        bytes_ += (sizes.nnz_c / (p1 * p3)) * (0 if p2 == 1 else 1)
        cost = params.cost(max(p1, p2, p3) * _log2(p), bytes_)
        # rough memory: replicated fraction of each operand
        mem = (sizes.nnz_a / (p1 * p2) + sizes.nnz_b / (p2 * p3)
               + sizes.nnz_c / (p1 * p3))
        if mem > mem_limit:
            continue
        if cost < best:
            best, best_f = cost, (p1, p2, p3)
    return best, best_f


V5E_VPU_OPS = 3.9e12  # elementwise min-plus ops/s (VPU, not MXU)


def w_mfbc(n: int, m_edges: int, p: int, c: int, d: int, word: int = 8,
           params: CostParams = DEFAULT, flop_rate: float = V5E_VPU_OPS
           ) -> Dict[str, float]:
    """Theorem 5.1 cost terms for one full BC computation.

    n vertices, m arcs, p processors, replication factor c, diameter d.
    word = bytes per matrix element (multpath = 8: w + m as f32 pairs).

    β term per batch: Σ_i (nnz(F_i)+nnz(G_i))/√(pc) ≤ 4cm/√(pc) words
    (unweighted frontier-uniqueness bound), plus the amortized adjacency
    replication cm/p. Total over n²/(cm) batches = 4n²/√(cp) + cm/p —
    the Theorem 5.1 bound. ``seconds`` adds a sparse-work compute term
    (8·n·m relaxation ops over p VPUs) so TEPS projections are grounded.
    """
    c = max(1, min(c, p))
    n_batches = max(1.0, n * n / (c * m_edges))
    msgs = d * n_batches * math.sqrt(p / c) * _log2(p)
    bytes_ = word * (c * m_edges / p  # adjacency replication (amortized)
                     + n_batches * (4.0 * c * m_edges) / math.sqrt(p * c))
    comm = params.cost(msgs, bytes_)
    compute = 8.0 * n * m_edges / (p * flop_rate)
    return {
        "alpha_msgs": msgs,
        "beta_bytes": bytes_,
        "seconds": max(comm, compute),
        "comm_seconds": comm,
        "compute_seconds": compute,
        "n_b": c * m_edges / n,
        "n_batches": n_batches,
        "memory_per_p": word * c * m_edges / p,
    }


def best_replication(n: int, m_edges: int, p: int, mem_bytes: float,
                     d: int = 10, word: int = 8,
                     params: CostParams = DEFAULT) -> int:
    """Paper: c* = p^{1/3} n²/m, clamped by memory M = Ω(c·m/p)."""
    c_star = p ** (1.0 / 3.0) * n * n / m_edges
    c_mem = mem_bytes * p / (word * m_edges)
    c = int(max(1, min(c_star, c_mem, p)))
    # refine within a factor-2 neighbourhood by direct evaluation
    cands = sorted({max(1, c // 2), c, min(p, 2 * c), 1})
    return min(cands, key=lambda cc: w_mfbc(n, m_edges, p, cc, d, word,
                                            params)["seconds"])
