"""Distributed SpGEMM variants as shard_map programs (paper §5.2 on TPU).

``spgemm(A, B, mesh, plan, semiring)`` computes the generalized product
``C(i,j) = ⊕_k f(A(i,k), B(k,j))`` for (pytree-valued) operands
``A: (m, k)`` and ``B: (k, n)`` using the decomposition named by ``plan``.

Implemented variants (paper labels; L/R below = left/right operand):

* ``1d_a``  — replicate L via all-gather; R and C column-sharded.
* ``1d_b``  — replicate R; L and C row-sharded.
* ``1d_c``  — shard the contraction dim; ⊕-reduce C (paper's variant C).
* ``2d_ab`` — SUMMA: gather L along grid columns and R along grid rows.
* ``2d_ac`` — gather L, ⊕-reduce-scatter C (R stationary).
* ``2d_bc`` — gather R, ⊕-reduce-scatter C (L stationary).
* ``3d_l_*``, ``3d_r_*``, ``3d_c_*`` — 1D replication of L / R /
  contraction-split over the first axis, nested with any 2D variant on the
  remaining two axes (the paper's nine-variant family; the Theorem 5.1 BC
  configuration is ``3d_r_ac``: adjacency replicated over the pod axis,
  frontier gathered, output reduce-scattered).

Each variant documents its input/output layouts as PartitionSpecs; the
byte cost of every collective matches ``repro.spgemm.cost_model`` (tested
by parsing compiled HLO in ``tests/test_spgemm*.py``).

CTF correspondence: CTF redistributes operands between processor grids at
runtime; under XLA SPMD the "redistribution" is the resharding XLA inserts
to satisfy ``in_specs`` — the autotuner therefore prefers plans whose input
layout matches the caller's persistent layout (e.g. the adjacency stays in
its ``2d_*`` layout across all MFBC iterations).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.spgemm.semiring import GeneralizedSemiring, arithmetic

Tree = Any


@dataclasses.dataclass(frozen=True)
class Plan:
    """A decomposition choice: variant name + mesh axis assignment.

    axes: (q,) for 1d, (r, c) for 2d, (p1, r, c) for 3d.
    """

    variant: str
    axes: Tuple[str, ...]

    def __post_init__(self):
        n_axes = {"1": 1, "2": 2, "3": 3}[self.variant[0]]
        assert len(self.axes) == n_axes, (self.variant, self.axes)


def _gather(x: Tree, axis_name: str, dim: int) -> Tree:
    return jax.tree.map(
        lambda v: jax.lax.all_gather(v, axis_name, axis=dim, tiled=True), x)


def _reduce_slice(x: Tree, axis_name: str, dim: int,
                  sr: GeneralizedSemiring) -> Tree:
    """⊕-reduce over an axis, then keep this shard's slice of ``dim``.

    For the arithmetic monoid this is a true ``psum_scatter``; general
    monoids reduce (pmin/pmax + psum pair) then slice.
    """
    if sr.name == "arith":
        return jax.tree.map(
            lambda v: jax.lax.psum_scatter(v, axis_name, scatter_dimension=dim,
                                           tiled=True), x)
    red = sr.axis_reduce(x, axis_name)
    idx = jax.lax.axis_index(axis_name)
    sz = compat.axis_size(axis_name)

    def slc(v):
        blk = v.shape[dim] // sz
        return jax.lax.dynamic_slice_in_dim(v, idx * blk, blk, axis=dim)

    return jax.tree.map(slc, red)


# --------------------------------------------------------------------------
# Layout tables: input/output PartitionSpecs per variant.
# --------------------------------------------------------------------------


def plan_specs(plan: Plan) -> Tuple[P, P, P]:
    """(spec_L, spec_R, spec_C) for the global operands under ``plan``."""
    v, ax = plan.variant, plan.axes
    if v == "1d_a":
        (q,) = ax
        return P(None, q), P(None, q), P(None, q)
    if v == "1d_b":
        (q,) = ax
        return P(q, None), P(q, None), P(q, None)
    if v == "1d_c":
        (q,) = ax
        return P(None, q), P(q, None), P(None, None)
    if v == "2d_ab":
        r, c = ax
        return P(r, c), P(r, c), P(r, c)
    if v == "2d_ac":
        r, c = ax
        return P(c, r), P(r, c), P(r, c)
    if v == "2d_bc":
        r, c = ax
        return P(r, c), P(c, r), P(r, c)
    if v.startswith("3d_"):
        _, x, yz = v.split("_")
        inner = plan_specs(Plan(f"2d_{yz}", ax[1:]))
        p1 = ax[0]
        sL, sR, sC = inner

        def stack(spec: P, dim: int) -> P:
            parts = [spec[0], spec[1]]
            cur = parts[dim]
            parts[dim] = (p1,) + ((cur,) if isinstance(cur, str) else tuple(cur or ()))
            return P(*parts)

        if x == "l":  # L replicated over p1; R, C split their free dim (n)
            return sL, stack(sR, 1), stack(sC, 1)
        if x == "r":  # R replicated over p1; L, C split their free dim (m)
            return stack(sL, 0), sR, stack(sC, 0)
        if x == "c":  # contraction split over p1
            return stack(sL, 1), stack(sR, 0), sC
    raise ValueError(f"unknown variant {plan.variant}")


# --------------------------------------------------------------------------
# Local (per-shard) programs.
# --------------------------------------------------------------------------


def _local_1d_a(plan, sr, a, b):
    (q,) = plan.axes
    a_full = _gather(a, q, 1)  # bytes ≈ nnz(L): paper W_A
    return sr.block_mm(a_full, b)


def _local_1d_b(plan, sr, a, b):
    (q,) = plan.axes
    b_full = _gather(b, q, 0)  # bytes ≈ nnz(R): paper W_B
    return sr.block_mm(a, b_full)


def _local_1d_c(plan, sr, a, b):
    (q,) = plan.axes
    c_part = sr.block_mm(a, b)
    return sr.axis_reduce(c_part, q)  # bytes ≈ nnz(C): paper W_C


def _local_2d_ab(plan, sr, a, b):
    r, c = plan.axes
    a_row = _gather(a, c, 1)  # bytes ≈ nnz(L)/p_r
    b_col = _gather(b, r, 0)  # bytes ≈ nnz(R)/p_c
    return sr.block_mm(a_row, b_col)


def _local_2d_ac(plan, sr, a, b):
    r, c = plan.axes
    a_full = _gather(a, c, 0)  # L arrives (m, k/p_r): bytes ≈ nnz(L)/p_r
    c_part = sr.block_mm(a_full, b)  # (m, n/p_c), partial over r
    return _reduce_slice(c_part, r, 0, sr)  # bytes ≈ nnz(C)/p_c


def _local_2d_bc(plan, sr, a, b):
    r, c = plan.axes
    b_full = _gather(b, r, 1)  # R arrives (k/p_c, n): bytes ≈ nnz(R)/p_c
    c_part = sr.block_mm(a, b_full)  # (m/p_r, n), partial over c
    return _reduce_slice(c_part, c, 1, sr)  # bytes ≈ nnz(C)/p_r


_LOCAL = {
    "1d_a": _local_1d_a,
    "1d_b": _local_1d_b,
    "1d_c": _local_1d_c,
    "2d_ab": _local_2d_ab,
    "2d_ac": _local_2d_ac,
    "2d_bc": _local_2d_bc,
}


def _local_3d(plan, sr, a, b):
    _, x, yz = plan.variant.split("_")
    inner = Plan(f"2d_{yz}", plan.axes[1:])
    p1 = plan.axes[0]
    if x in ("l", "r"):
        # The replicated operand is already identical across p1 (its spec
        # omits p1); inner 2D runs independently per p1 slice.
        return _LOCAL[inner.variant](inner, sr, a, b)
    # x == "c": contraction split over p1 -> inner product is partial.
    c_part = _LOCAL[inner.variant](inner, sr, a, b)
    return sr.axis_reduce(c_part, p1)


def spgemm(a: Tree, b: Tree, mesh: Mesh, plan: Plan,
           sr: GeneralizedSemiring = arithmetic,
           out_spec: Optional[P] = None) -> Tree:
    """Distributed generalized matmul. See module docstring for layouts."""
    spec_a, spec_b, spec_c = plan_specs(plan)
    local = _local_3d if plan.variant.startswith("3d_") else _LOCAL[plan.variant]

    fn = shard_map(
        partial(local, plan, sr),
        mesh=mesh,
        in_specs=(spec_a, spec_b),
        out_specs=spec_c,
        check_vma=False,
    )
    out = fn(a, b)
    if out_spec is not None:
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.NamedSharding(mesh, out_spec))
    return out


def replicate_adjacency(b: Tree, mesh: Mesh, pod_axis: str) -> Tree:
    """One-time replication of a persistent operand across the pod axis.

    The Theorem 5.1 proof amortizes the adjacency broadcast across all
    (up to d) products and all n/n_b batches; callers do it once here and
    then run ``3d_r_*`` plans whose R-spec omits the pod axis.
    """
    spec = P(*([None] * jax.tree.leaves(b)[0].ndim))
    return jax.lax.with_sharding_constraint(
        b, jax.sharding.NamedSharding(mesh, spec))
