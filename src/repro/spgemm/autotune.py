"""Decomposition autotuner — the CTF "automatic mapping search" (§6.2).

Given operand byte counts and a mesh, enumerate every implemented variant ×
mesh-axis role assignment, evaluate the §5.2 α–β cost (plus a resharding
penalty when the plan's input layout differs from the caller's persistent
layout), reject plans that exceed the per-device memory budget, and return
the cheapest plan.

This is an ahead-of-time search (XLA SPMD programs are static), but it uses
exactly the cost expressions CTF evaluates at runtime; `EXPERIMENTS.md
§SpGEMM` validates the predicted bytes against HLO-measured collective
bytes for every variant.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.spgemm.cost_model import CostParams, DEFAULT, ProblemSizes, _log2
from repro.spgemm.dist import Plan


@dataclasses.dataclass(frozen=True)
class PlanCost:
    plan: Plan
    seconds: float
    bytes_moved: float
    messages: float
    mem_per_device: float

    def __repr__(self):
        return (f"PlanCost({self.plan.variant}@{self.plan.axes}, "
                f"t={self.seconds:.3e}s, B={self.bytes_moved:.3e}, "
                f"M={self.mem_per_device:.3e})")


def _axis_perms(axes: Dict[str, int], k: int) -> Iterable[Tuple[str, ...]]:
    names = list(axes)
    return itertools.permutations(names, k)


def plan_cost(plan: Plan, sizes: ProblemSizes, axes: Dict[str, int],
              params: CostParams = DEFAULT) -> PlanCost:
    """Bytes/messages moved by our implementation of ``plan``.

    Byte counts mirror dist.py's collectives exactly (all-gather along an
    axis of size q multiplies a local shard by (q-1); monoid reductions
    cost 2x a psum — see semiring.py).
    """
    v = plan.variant
    nA, nB, nC = sizes.nnz_a, sizes.nnz_b, sizes.nnz_c
    total = math.prod(axes.values())

    def ag(nnz_global: float, shard_frac: float, q: int) -> Tuple[float, float]:
        """all_gather: local shard is nnz*shard_frac; returns (bytes, msgs)."""
        if q <= 1:
            return 0.0, 0.0
        return nnz_global * shard_frac * (q - 1), _log2(q)

    def rs(nnz_out_local: float, q: int) -> Tuple[float, float]:
        if q <= 1:
            return 0.0, 0.0
        return nnz_out_local * (q - 1) / q, _log2(q)

    b = m = 0.0
    sz = {a: axes[a] for a in plan.axes}
    if v == "1d_a":
        q = sz[plan.axes[0]]
        bb, mm = ag(nA, 1.0 / q, q)
        b, m = bb, mm
    elif v == "1d_b":
        q = sz[plan.axes[0]]
        b, m = ag(nB, 1.0 / q, q)
    elif v == "1d_c":
        q = sz[plan.axes[0]]
        b, m = rs(nC, q)
        b *= 2  # reduce to replicated (allreduce) ≈ 2x reduce-scatter
    elif v.startswith("2d") or v.startswith("3d"):
        if v.startswith("3d"):
            _, x, yz = v.split("_")
            p1, r, c = plan.axes
            q1, qr, qc = axes[p1], axes[r], axes[c]
            if x == "c":
                bb, mm = rs(nC / (qr * qc), q1)
                b += 2 * bb
                m += mm
            # l/r replication is amortized (replicate_adjacency) — charge 0
            inner_axes = (r, c)
        else:
            yz = v.split("_")[1]
            inner_axes = plan.axes
            qr, qc = axes[inner_axes[0]], axes[inner_axes[1]]
            q1 = 1
        qr, qc = axes[inner_axes[0]], axes[inner_axes[1]]
        frac = 1.0 / (qr * qc * q1)
        if yz == "ab":
            bb, mm = ag(nA, frac, qc)
            b += bb
            m += mm
            bb, mm = ag(nB, frac, qr)
            b += bb
            m += mm
        elif yz == "ac":
            bb, mm = ag(nA, frac, qc)
            b += bb
            m += mm
            bb, mm = rs(nC / (qc * q1), qr)
            b += bb
            m += mm
        elif yz == "bc":
            bb, mm = ag(nB, frac, qr)
            b += bb
            m += mm
            bb, mm = rs(nC / (qr * q1), qc)
            b += bb
            m += mm
    else:
        raise ValueError(v)

    # per-device memory after gathers (peak working set)
    mem = (nA + nB + nC) / total
    if v == "1d_a":
        mem += nA
    if v == "1d_b":
        mem += nB
    if v == "1d_c":
        mem += nC
    if v.startswith(("2d", "3d")):
        qr, qc = axes[inner_axes[0]], axes[inner_axes[1]]
        if yz == "ab":
            mem += nA / (qr * q1) + nB / (qc * q1)
        elif yz == "ac":
            mem += nA / (qr * q1) + nC / (qc * q1)
        elif yz == "bc":
            mem += nB / (qc * q1) + nC / (qr * q1)
        if v.startswith("3d") and v.split("_")[1] in ("l", "r"):
            which = nA if v.split("_")[1] == "l" else nB
            mem += which / (qr * qc)  # replicated over p1

    return PlanCost(plan, params.cost(m, b), b, m, mem)


def enumerate_plans(axes: Dict[str, int]) -> List[Plan]:
    plans: List[Plan] = []
    for (q,) in _axis_perms(axes, 1):
        for var in ("1d_a", "1d_b", "1d_c"):
            plans.append(Plan(var, (q,)))
    if len(axes) >= 2:
        for pair in _axis_perms(axes, 2):
            for var in ("2d_ab", "2d_ac", "2d_bc"):
                plans.append(Plan(var, pair))
    if len(axes) >= 3:
        for trip in _axis_perms(axes, 3):
            for x in ("l", "r", "c"):
                for yz in ("ab", "ac", "bc"):
                    plans.append(Plan(f"3d_{x}_{yz}", trip))
    return plans


def autotune(sizes: ProblemSizes, axes: Dict[str, int],
             mem_limit: float = float("inf"),
             params: CostParams = DEFAULT,
             allow: Optional[Sequence[str]] = None) -> PlanCost:
    """Pick the cheapest plan for the given operand sizes and mesh axes."""
    best: Optional[PlanCost] = None
    for plan in enumerate_plans(axes):
        if allow is not None and plan.variant not in allow:
            continue
        pc = plan_cost(plan, sizes, axes, params)
        if pc.mem_per_device > mem_limit:
            continue
        if best is None or pc.seconds < best.seconds:
            best = pc
    assert best is not None, "no feasible plan (memory limit too tight)"
    return best


def choose_bc_regime(n: int, m_edges: int, nb: int, fill: float,
                     *, vpu_ops: float = 3.9e12,
                     hbm_bw: float = 819e9, p: int = 256,
                     calibration=None,
                     est_iters: Optional[int] = None) -> Dict[str, float]:
    """Dense/COO/CSR relax regime choice (the paper's §7 observation that
    MFBC shines on dense frontiers, made quantitative for TPU).

    dense: work = 4·nb·n²/p VPU ops, traffic ≈ tile-model (compute-bound).
    coo:   work = 4·nb·m·fill/p ops but gather/segment traffic
           ≈ 24 bytes per (frontier-entry × edge) touch, memory-bound.
    csr:   frontier-occupancy-aware — the compacting relax's sweep-total
           work ``Σ_iter frontier_nnz·k̄ ≈ nb·m`` amortizes over
           ``est_iters`` iterations plus an ``nb·n`` per-iteration floor
           (``cost_model.relax_ops``); ``est_iters`` must be the same
           heuristic the planner prices sweeps with.

    With a measured ``calibration`` (``cost_model.Calibration``), the
    analytic estimates are replaced by fitted per-relax seconds for
    every measured variant — including the Pallas-kernel dense route
    (``dense_kernel_s``) and the frontier-compacted CSR rate
    (``csr_s``, present only when that variant was measured) — and the
    result carries ``calibrated: True``. Note the calibrated COO
    estimate is fill-independent: the real COO relax processes the full
    padded edge list every iteration (no frontier compaction), so
    ``fill`` only shapes the analytic fallback.

    Returns per-iteration second estimates and the winner; the driver
    switches per iteration as the frontier fills (fill = fraction of
    active frontier entries).
    """
    out: Dict[str, float] = {}
    csr_s: Optional[float] = None
    if calibration is not None and calibration.has("dense") \
            and calibration.has("coo"):
        dense_s = calibration.step_seconds("dense", n, m_edges, nb, p=p)
        coo_s = calibration.step_seconds("coo", n, m_edges, nb, p=p)
        if calibration.has("dense", use_kernel=True):
            out["dense_kernel_s"] = calibration.step_seconds(
                "dense", n, m_edges, nb, p=p, use_kernel=True)
        if calibration.has("csr"):
            csr_s = calibration.step_seconds("csr", n, m_edges, nb, p=p,
                                             est_iters=est_iters)
        out["calibrated"] = True
    else:
        dense_s = 4.0 * nb * n * n / (p * vpu_ops)
        coo_touch = nb * fill * m_edges / p
        coo_s = max(4.0 * coo_touch / vpu_ops, 24.0 * coo_touch / hbm_bw)
        iters = max(int(est_iters or 1), 1)
        # Matches cost_model.relax_ops("csr"): sweep-total nb·m amortized
        # over est_iters plus the per-iteration (nb, n) compaction floor.
        csr_touch = nb * (m_edges / iters + n) / p
        csr_s = max(4.0 * csr_touch / vpu_ops, 24.0 * csr_touch / hbm_bw)
        out["calibrated"] = False
    candidates = {"dense": dense_s, "coo": coo_s}
    if csr_s is not None:
        out["csr_s"] = csr_s
        candidates["csr"] = csr_s
    out.update({"dense_s": dense_s, "coo_s": coo_s,
                "regime": min(candidates, key=candidates.get),
                "crossover_fill": min(1.0, (n * n) / max(m_edges, 1)
                                      * (4.0 / vpu_ops)
                                      / max(4.0 / vpu_ops, 24.0 / hbm_bw))})
    return out
