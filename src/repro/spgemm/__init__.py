"""Communication-efficient distributed SpGEMM (the paper's §5 contribution)."""
from repro.spgemm.autotune import PlanCost, autotune, enumerate_plans, plan_cost
from repro.spgemm.cost_model import (CostParams, DEFAULT, ProblemSizes,
                                     best_replication, w_1d, w_2d, w_3d,
                                     w_mfbc, w_mm)
from repro.spgemm.dist import Plan, plan_specs, replicate_adjacency, spgemm
from repro.spgemm.semiring import (GeneralizedSemiring, arithmetic, by_name,
                                   centpath, multpath)

__all__ = [
    "PlanCost", "autotune", "enumerate_plans", "plan_cost",
    "CostParams", "DEFAULT", "ProblemSizes", "best_replication",
    "w_1d", "w_2d", "w_3d", "w_mfbc", "w_mm",
    "Plan", "plan_specs", "replicate_adjacency", "spgemm",
    "GeneralizedSemiring", "arithmetic", "by_name", "centpath", "multpath",
]
