"""Generalized (⊕, f) matmul semantics for the distributed SpGEMM layer.

The paper replaces semirings with a commutative monoid ``(D_C, ⊕)`` plus an
arbitrary map ``f : D_A × D_B → D_C`` (Section 3). A ``GeneralizedSemiring``
packages the three pieces the distributed algorithms need:

* ``block_mm(a, b)``   — the local generalized matmul on (pytree) blocks;
* ``combine(x, y)``    — elementwise ⊕ for panel accumulation;
* ``axis_reduce(x, axis_name)`` — the distributed ⊕-reduction.

TPU adaptation of CTF's "sparse reduction": a monoid reduction is not a
``psum``, but every monoid here decomposes into *two* optimal collectives:
an elementwise extremum (``lax.pmin``/``pmax`` — bandwidth-optimal) to
agree on the winning weight, then a ``psum`` of locally tie-masked payloads.
Cost: 2·(β·x + α·log p) = the paper's sparse-reduction bound.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.monoids import (Centpath, Multpath, centpath_combine,
                                multpath_combine)
from repro.kernels import ref as kref

INF = jnp.inf


@dataclasses.dataclass(frozen=True)
class GeneralizedSemiring:
    name: str
    block_mm: Callable[[Any, Any], Any]
    combine: Callable[[Any, Any], Any]
    axis_reduce: Callable[[Any, str], Any]
    identity: Callable[[Tuple[int, ...], Any], Any]
    # bytes per element of each operand domain (for the cost model)
    elem_bytes: Tuple[int, int, int] = (4, 4, 4)


# --- standard arithmetic (+, ×): used by the model-zoo sanity tests --------

def _arith_mm(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


arithmetic = GeneralizedSemiring(
    name="arith",
    block_mm=_arith_mm,
    combine=lambda x, y: x + y,
    axis_reduce=lambda x, axis: jax.lax.psum(x, axis),
    identity=lambda shape, dtype=jnp.float32: jnp.zeros(shape, dtype),
)


# --- multpath (MFBF action): A = Multpath frontier, B = adjacency ----------

def _mp_mm(a: Multpath, b: jax.Array) -> Multpath:
    from repro.core import monoids

    return monoids.multpath_relax_dense(a, b, block=256)


def _mp_reduce(x: Multpath, axis: str) -> Multpath:
    wmin = jax.lax.pmin(x.w, axis)
    m = jax.lax.psum(jnp.where((x.w == wmin) & jnp.isfinite(wmin), x.m, 0.0),
                     axis)
    return Multpath(wmin, m)


multpath = GeneralizedSemiring(
    name="multpath",
    block_mm=_mp_mm,
    combine=multpath_combine,
    axis_reduce=_mp_reduce,
    identity=lambda shape, dtype=jnp.float32: Multpath(
        jnp.full(shape, INF, dtype), jnp.zeros(shape, dtype)),
    elem_bytes=(8, 4, 8),
)


# --- centpath (MFBr action) ------------------------------------------------

def _cp_mm(a: Centpath, b: jax.Array) -> Centpath:
    from repro.core import monoids

    return monoids.centpath_relax_dense(a, b, block=256)


def _cp_reduce(x: Centpath, axis: str) -> Centpath:
    wmax = jax.lax.pmax(x.w, axis)
    tie = (x.w == wmax) & jnp.isfinite(wmax)
    p = jax.lax.psum(jnp.where(tie, x.p, 0.0), axis)
    c = jax.lax.psum(jnp.where(tie, x.c, 0.0), axis)
    return Centpath(wmax, p, c)


centpath = GeneralizedSemiring(
    name="centpath",
    block_mm=_cp_mm,
    combine=centpath_combine,
    axis_reduce=_cp_reduce,
    identity=lambda shape, dtype=jnp.float32: Centpath(
        jnp.full(shape, -INF, dtype), jnp.zeros(shape, dtype),
        jnp.zeros(shape, dtype)),
    elem_bytes=(12, 4, 12),
)


def by_name(name: str) -> GeneralizedSemiring:
    return {"arith": arithmetic, "multpath": multpath,
            "centpath": centpath}[name]
