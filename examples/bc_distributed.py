"""Distributed MFBC on a multi-pod device mesh (Theorem 5.1 layout).

Runs the exact sweep of the unified ``repro.bc`` solver on 8 emulated
devices — a (2, 2, 2) (pod, data, model) mesh with the adjacency
replicated across pods (the paper's replication factor c) — inspects the
``BCPlan`` first, and verifies against the oracle.

  PYTHONPATH=src python examples/bc_distributed.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import numpy as np

from repro.bc import BCQuery, plan, solve
from repro.core.brandes_ref import brandes_bc
from repro.graphs.generators import erdos_renyi
from repro.spgemm.cost_model import best_replication


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g = erdos_renyi(48, 0.15, seed=7, weighted=True, max_weight=9)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"graph n={g.n} m={g.m}")

    c = best_replication(g.n, g.m, 8, mem_bytes=1 << 30)
    print(f"cost-model replication factor c* = {c} (pod axis realizes c=2)")

    query = BCQuery(mode="exact", n_b=16)
    pl = plan(g, query, mesh=mesh)
    print(pl.summary())

    res = solve(g, query, plan=pl, mesh=mesh)
    ref = brandes_bc(g)
    np.testing.assert_allclose(res.lam, ref, rtol=1e-4, atol=1e-6)
    print("distributed λ == Brandes oracle ✓")
    print("top-3:", res.topk(3).tolist())


if __name__ == "__main__":
    main()
