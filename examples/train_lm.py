"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpoint/restart fault tolerance and gradient compression.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import shutil
import tempfile

import jax
import numpy as np

from repro.data.pipeline import LMDataConfig, LMPipeline
from repro.models.transformer import TransformerConfig
from repro.optim import adamw
from repro.optim.grad_compress import CompressConfig
from repro.train.fault import ChaosConfig, Supervisor
from repro.train.train_lib import make_lm_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    # ~100M-class decoder-only LM (gemma2-family block structure)
    cfg = TransformerConfig(
        name="lm100m", n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv=4, d_ff=4 * args.d_model, vocab=32768,
        head_dim=64, block_style="sandwich", act="gelu",
        attn_softcap=50.0, final_softcap=30.0, scale_embeddings=True,
        window_pattern=(256, None))
    opt = adamw.AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    init_fn, step_fn = make_lm_train_step(cfg, opt,
                                          compress_cfg=CompressConfig("int8"))
    pipe = LMPipeline(LMDataConfig(vocab=cfg.vocab, batch=4, seq=256))

    state = init_fn(jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"params: {n / 1e6:.1f}M")

    losses = []

    def do_step(st, step):
        st, m = step_fn(st, pipe.batch(step))
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"lr {float(m['lr']):.2e}")
        return st

    ckpt_dir = tempfile.mkdtemp(prefix="lm100m_ckpt_")
    try:
        sup = Supervisor(ckpt_dir, save_every=25)
        # inject one failure mid-run to demonstrate restart
        state = sup.run(init_state=state, step_fn=do_step,
                        n_steps=args.steps,
                        chaos=ChaosConfig(fail_at_steps=(args.steps // 2,)))
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    first, last = losses[0], np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(survived 1 injected failure)")
    assert last < first
    print("training converges ✓")


if __name__ == "__main__":
    main()
