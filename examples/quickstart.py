"""Quickstart: betweenness centrality of a graph in five lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.bc import ExecutionConfig
from repro.core import brandes_bc, mfbc
from repro.graphs.generators import rmat


def main():
    # A power-law graph with integer weights (the paper's hard case:
    # weighted BC, which BFS-based frameworks cannot do).
    g = rmat(7, 8, weighted=True, max_weight=100, seed=1)
    g, _ = g.remove_isolated()
    print(f"graph: n={g.n} m={g.m} (weighted R-MAT)")

    # MFBC (paper Algorithm 3); the typed ExecutionConfig is the blessed
    # way to pick a backend (stringly backend= kwargs are deprecated).
    lam = mfbc(g, n_b=64, execution=ExecutionConfig(backend="dense"))

    top = np.argsort(lam)[::-1][:5]
    print("top-5 central vertices:", [(int(v), round(float(lam[v]), 1))
                                      for v in top])

    ref = brandes_bc(g)  # oracle check
    np.testing.assert_allclose(lam, ref, rtol=1e-4, atol=1e-6)
    print("verified against the Brandes oracle ✓")


if __name__ == "__main__":
    main()
