"""Serve a small LM with batched requests: prefill + decode + KV cache.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


def main():
    cfg = T.TransformerConfig(
        name="serve-demo", n_layers=4, d_model=256, n_heads=8, n_kv=2,
        d_ff=1024, vocab=8192, head_dim=32, window_pattern=(64, None))
    params = T.init_params(cfg, jax.random.key(0))
    batch, prompt_len, gen_len = 8, 48, 32
    max_len = prompt_len + gen_len
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                          jnp.int32)

    prefill = jax.jit(lambda p, t, c: T.prefill(cfg, p, t, c))
    decode = jax.jit(lambda p, t, pos, c: T.decode_step(cfg, p, t, pos, c))

    cache = T.init_cache(cfg, batch, max_len)
    logits, cache = prefill(params, prompts, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    toks = [tok]
    for i in range(gen_len - 1):
        logits, cache = decode(params, tok, jnp.int32(prompt_len + i), cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(tok)
    out = jnp.concatenate(toks, axis=1)
    dt = time.time() - t0
    print(f"served {batch} requests x {gen_len} tokens "
          f"({batch * gen_len / dt:,.0f} tok/s incl. compile of decode)")
    # decode must agree with teacher-forced forward on the same sequence
    full = T.forward(cfg, params, jnp.concatenate([prompts, out[:, :-1]], 1))
    redecoded = jnp.argmax(full[:, prompt_len - 1:], -1)
    match = float(jnp.mean((redecoded == out).astype(jnp.float32)))
    print(f"decode/forward agreement: {match:.3f}")
    assert match > 0.99
    print("KV-cache decode is consistent ✓")


if __name__ == "__main__":
    main()
