"""Train a GNN (GCN) with the real neighbor sampler, and an equivariant
NequIP-class model on molecule batches.

  PYTHONPATH=src python examples/gnn_train.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.generators import erdos_renyi
from repro.graphs.sampler import NeighborSampler, SamplerSpec, batch_molecules
from repro.models import gnn as G
from repro.optim import adamw
from repro.train.train_lib import make_generic_train_step


def train_gcn_sampled():
    g = erdos_renyi(500, 0.02, seed=0)
    spec = SamplerSpec(batch_nodes=16, fanout=(5, 3))
    sampler = NeighborSampler(g, spec, seed=1)
    cfg = G.GCNConfig("gcn-sampled", d_in=16, d_hidden=16, n_classes=4)
    feats = np.random.default_rng(0).normal(size=(g.n + 1, 16)).astype(np.float32)
    labels = np.random.default_rng(1).integers(0, 4, g.n + 1).astype(np.int32)

    def make_batch(step):
        rng = np.random.default_rng(step)
        seeds = rng.choice(g.n, spec.batch_nodes, replace=False)
        sub = sampler.sample(seeds.astype(np.int64))
        ids = np.minimum(sub["node_ids"], g.n)
        x = feats[ids]
        deg = np.bincount(sub["dst"], minlength=ids.shape[0])
        return {"x": jnp.asarray(x), "src": jnp.asarray(sub["src"]),
                "dst": jnp.asarray(sub["dst"]),
                "deg": jnp.asarray(deg, jnp.float32),
                "labels": jnp.asarray(labels[ids]),
                "label_mask": jnp.asarray(sub["seed_mask"])}

    def loss(params, batch):
        return G.node_ce_loss("gcn", cfg, params, batch)

    init_fn, step_fn = make_generic_train_step(
        loss, lambda k: G.gcn_init(cfg, k), adamw.AdamWConfig(lr=5e-3))
    state = init_fn(jax.random.key(0))
    losses = []
    for step in range(40):
        state, m = step_fn(state, make_batch(step))
        losses.append(float(m["loss"]))
    print(f"GCN (neighbor-sampled): loss {losses[0]:.3f} -> "
          f"{np.mean(losses[-5:]):.3f}")
    assert np.mean(losses[-5:]) < losses[0]


def train_nequip():
    cfg = G.NequIPConfig("nequip-demo", n_layers=3, channels=16, d_in=8)
    params = G.nequip_init(cfg, jax.random.key(0))

    n_graphs_static = 8 + 1  # static under jit (batch dim of the readout)

    def loss(params, batch):
        batch = dict(batch, n_graphs=n_graphs_static)
        return G.energy_mse_loss(cfg, params, batch)

    init_fn, step_fn = make_generic_train_step(
        loss, lambda k: G.nequip_init(cfg, k), adamw.AdamWConfig(lr=2e-3))
    state = init_fn(jax.random.key(1))
    # a fixed dataset of molecules with fixed target energies
    mol = batch_molecules(8, 6, 12, d_in=8, seed=0)
    mol.pop("n_graphs")
    batch = {k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
             for k, v in mol.items()}
    losses = []
    for step in range(60):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    print(f"NequIP (molecules):     loss {np.mean(losses[:5]):.3f} -> "
          f"{np.mean(losses[-5:]):.3f}")
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


if __name__ == "__main__":
    train_gcn_sampled()
    train_nequip()
    print("GNN training converges ✓")
