"""Gateway serving latency: cold vs cached vs refine, plus overload.

Drives the real HTTP gateway (``repro.serve.start_gateway`` on an
ephemeral port, urllib as the client) through the three ways a query
can be answered and records what each costs:

* **cold** — empty cache, full solve: submit → poll → done wall time;
* **cached** — the identical repeat: answered inline from the
  content-addressed cache (one HTTP round trip, no solver);
* **refine** — a tighter-ε query against a looser cached entry: the
  stale answer's time-to-first-result (also one round trip) and the
  time until the checkpointed refinement lands, with the refined
  result checked bitwise against a from-scratch tight run on a fresh
  gateway (the ``repro.bc.refine`` resume contract, over the wire).

A second scenario floods the admission gate: a burst of loose batch-tier
queries sized past the predicted-seconds horizon, with interactive
queries interleaved — once under ``overload="reject"`` (expect 429s on
the flood, none on the tight tier) and once under ``"degrade"`` (expect
looser-ε admissions recorded instead). Per-tier admit/reject/degrade
counters come straight from the gateway's /v1/metrics endpoint.

The record lands under the ``"gateway"`` key of ``BENCH_serve.json``
(merged into the ``bc_serve`` record, like ``mixed_tier``);
``tools/check_bench.py`` gates the cache-hit speedup, the bitwise
refine flag, and no-starvation of the tight tier in CI.

  PYTHONPATH=src python -m benchmarks.bc_gateway            # scale 10
  PYTHONPATH=src python -m benchmarks.bc_gateway --smoke    # scale 8, CI
"""
from __future__ import annotations

import argparse
import json
import os
import time
import urllib.error
import urllib.request
from typing import Dict, Optional, Tuple

EPS_LOOSE = 0.15
EPS_TIGHT = 0.05


def _post(base: str, doc: Dict) -> Tuple[int, Dict]:
    req = urllib.request.Request(f"{base}/v1/bc",
                                 data=json.dumps(doc).encode(),
                                 headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _get(base: str, path: str) -> Dict:
    with urllib.request.urlopen(f"{base}{path}") as r:
        return json.loads(r.read())


def _poll_done(base: str, rid: int, timeout_s: float = 120.0) -> Dict:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        doc = _get(base, f"/v1/bc/{rid}")
        if doc["status"] in ("done", "error"):
            assert doc["status"] == "done", doc
            return doc
        time.sleep(0.002)
    raise RuntimeError(f"rid {rid} not done within {timeout_s}s")


def _gateway(g, **cfg):
    from repro.serve import (BCGateway, BCService, GatewayConfig,
                             start_gateway)

    svc = BCService({"web": g}, checkpoints=True)
    return start_gateway(BCGateway(svc, GatewayConfig(**cfg)))


def _submit_timed(base: str, doc: Dict) -> Tuple[float, int, Dict]:
    """(seconds to an answer in hand, status, response doc). A cache hit
    answers inside the POST; anything else is submit + poll."""
    t0 = time.monotonic()
    st, resp = _post(base, doc)
    if resp.get("status") != "done":
        resp = _poll_done(base, resp["rid"])
    return time.monotonic() - t0, st, resp


def bench_latency(g) -> Dict:
    """Cold / cached / refine latency over the wire, one graph."""
    # jit warm-up on a throwaway gateway: the timed legs measure
    # serving, not XLA compilation (module-level jitted steps cache
    # by shape across services)
    warm = _gateway(g, horizon_s=1e9)
    try:
        _submit_timed(warm.url, {"graph": "web", "eps": EPS_LOOSE})
        _submit_timed(warm.url, {"graph": "web", "eps": EPS_TIGHT})
    finally:
        warm.close()

    srv = _gateway(g, horizon_s=1e9)
    try:
        base = srv.url
        cold_s, _, cold = _submit_timed(
            base, {"graph": "web", "eps": EPS_LOOSE})
        cached_s, st, cached = _submit_timed(
            base, {"graph": "web", "eps": EPS_LOOSE})
        assert st == 200 and cached["cached"], "expected a cache hit"
        cache_identical = cached["result"] == cold["result"]

        # tighter ε against the loose entry: stale answer now, refined
        # answer when the resumed estimator lands
        t0 = time.monotonic()
        st, doc = _post(base, {"graph": "web", "eps": EPS_TIGHT})
        stale_s = time.monotonic() - t0
        refining = bool(doc.get("refining"))
        refined = _poll_done(base, doc["rid"])
        refine_done_s = time.monotonic() - t0
    finally:
        srv.close()

    # scratch leg: fresh gateway, tight ε directly — rid 0 gives the
    # same (seed, rid) stream the loose run had, so the refined result
    # must match bitwise (JSON floats are shortest-repr exact)
    srv2 = _gateway(g, horizon_s=1e9)
    try:
        _, _, scratch = _submit_timed(
            srv2.url, {"graph": "web", "eps": EPS_TIGHT})
    finally:
        srv2.close()
    refine_bitwise = all(
        refined["result"][f] == scratch["result"][f]
        for f in ("topk", "lam", "halfwidth", "n_samples", "n_epochs"))

    return {
        "cold_s": cold_s,
        "cached_s": cached_s,
        "cached_speedup": cold_s / max(cached_s, 1e-9),
        "cache_identical_payload": cache_identical,
        "refine_stale_s": stale_s,
        "refine_done_s": refine_done_s,
        "refining_flagged": refining,
        "refine_bitwise": refine_bitwise,
        "eps": {"loose": EPS_LOOSE, "tight": EPS_TIGHT},
    }


def bench_overload(g, *, n_burst: int = 12, n_tight: int = 3) -> Dict:
    """Admission under a synthetic burst, reject and degrade policies."""
    from repro.serve import BCService
    from repro.serve.bc_service import BCRequest

    pred = float(BCService({"web": g}).request_plan(
        BCRequest(rid=0, graph="web", eps=EPS_LOOSE)).predicted_seconds)

    legs = {}
    for policy in ("reject", "degrade"):
        # horizon under one predicted request keeps the gate hot for the
        # whole burst regardless of how fast the worker drains; a large
        # idle sleep keeps the burst ahead of the solver
        srv = _gateway(g, horizon_s=max(pred * 1.5, 1e-6),
                       overload=policy, degrade_eps=0.4,
                       idle_sleep_s=0.05)
        try:
            base = srv.url
            codes = {"batch": [], "interactive": []}
            for i in range(n_burst):
                st, _ = _post(base, {"graph": "web", "eps": EPS_LOOSE,
                                     "priority": "batch", "seed": i})
                codes["batch"].append(st)
                if i % (n_burst // max(n_tight, 1)) == 0:
                    st, _ = _post(base, {"graph": "web", "eps": EPS_LOOSE,
                                         "priority": "interactive",
                                         "seed": 1000 + i})
                    codes["interactive"].append(st)
            m = _get(base, "/v1/metrics")
        finally:
            srv.close()
        tiers = m["tiers"]

        def rate(t):
            sub = tiers[t]["submitted"]
            served = (tiers[t]["admitted"] + tiers[t]["cache_hits"]
                      + tiers[t]["cache_refines"])
            return served / sub if sub else 1.0

        legs[policy] = {
            "horizon_s": max(pred * 1.5, 1e-6),
            "predicted_s": pred,
            "n_burst": n_burst,
            "codes": codes,
            "tiers": tiers,
            "rejected": m["totals"]["rejected"],
            "degraded": m["totals"]["degraded"],
            "tight_admit_rate": rate("interactive"),
            "loose_admit_rate": rate("batch"),
        }
    return legs


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="merged into this record's 'gateway' key "
                         "(other keys preserved)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (scale 8)")
    args = ap.parse_args(argv)

    from repro.graphs.generators import from_spec

    scale = 8 if args.smoke else args.scale
    g = from_spec("rmat", scale=scale, degree=args.degree, seed=args.seed)
    g, _ = g.remove_isolated()

    gw_rec = {
        "name": f"bc_gateway_rmat_s{scale}_e{args.degree}",
        "n": g.n,
        "m": g.m,
        "latency": bench_latency(g),
        "overload": bench_overload(g),
    }

    rec = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            rec = json.load(f)
    rec["gateway"] = gw_rec
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)

    lat = gw_rec["latency"]
    print(f"[bc_gateway] n={g.n} m={g.m}")
    print(f"[bc_gateway] cold {lat['cold_s'] * 1e3:8.1f} ms   "
          f"cached {lat['cached_s'] * 1e3:6.1f} ms "
          f"({lat['cached_speedup']:.0f}x, "
          f"identical={lat['cache_identical_payload']})")
    print(f"[bc_gateway] refine: stale answer {lat['refine_stale_s'] * 1e3:.1f} ms, "
          f"refined {lat['refine_done_s'] * 1e3:.1f} ms, "
          f"bitwise={lat['refine_bitwise']}")
    for policy, leg in gw_rec["overload"].items():
        print(f"[bc_gateway] overload[{policy}]: rejected={leg['rejected']} "
              f"degraded={leg['degraded']} tight_admit="
              f"{leg['tight_admit_rate']:.2f} loose_admit="
              f"{leg['loose_admit_rate']:.2f}")
    return gw_rec


if __name__ == "__main__":
    main()
