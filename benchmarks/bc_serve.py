"""Fused vs unfused serving throughput (the cross-request batching win).

Drives ``serve.BCService`` at 1–16 concurrent approximate-BC queries on
one R-MAT graph, twice per concurrency level: ``fuse=False`` (the
pre-fusion behavior — every request's epoch runs as its own batch,
padded to the graph-wide ``n_b``) and ``fuse=True`` (per-request (ε, δ)
plans via ``repro.bc.plan_for_request`` + slot-tagged fused batches
through the executors' ``step_segmented``). The metric is tick-loop
throughput in *source samples per second*: fusion packs several
requests' ragged epoch demand into shared power-of-two buckets, so the
fixed per-batch cost (kernel dispatch; on a mesh, the fused moments
all-reduce) and the padding waste are amortized across queries.

The request mix cycles (ε, seed) so per-request plans differ — exactly
the ragged multi-tenant demand fusion exists for. Each leg is jit-warmed
by a throwaway identical run (module-level jitted steps cache by shape),
so timings are steady-state serving, not XLA compilation.

A second scenario exercises the QoS scheduler under *mixed-tier* load:
a burst of loose-ε batch-tier requests submitted ahead of tight-ε
interactive ones, driven twice — ``pack="fifo"`` (the legacy
strict-arrival baseline: interactive work queues behind the batch
burst) and ``pack="deadline"`` (EDF admission + deadline-slack
draining + a ``tick_budget`` that preempts batch slots mid-epoch).
The metric is per-tier p50/p95 *latency* (submit → retirement): the
tight-ε tier's p95 must beat the FIFO baseline leg without giving up
the fused throughput.

Everything lands in ``BENCH_serve.json`` with the per-request executed
``BCPlan``s (tiers included) and the graph capacity plan recorded next
to the timings; ``tools/check_bench.py`` asserts the record's shape —
including the tight-tier p95 win — in CI.

  PYTHONPATH=src python -m benchmarks.bc_serve            # scale 10
  PYTHONPATH=src python -m benchmarks.bc_serve --smoke    # scale 8, CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Sequence

# (ε, δ) mix cycled over concurrent requests: distinct accuracy contracts
# produce distinct per-request plans (tight ε → large n_b/budget, loose
# ε → small n_b and a sub-batch Hoeffding cap) and ragged epoch demand —
# the multi-tenant shape fusion is for. The loose tiers model cheap
# "find the hubs" queries; without fusion every one of their under-
# filled epochs pads to the graph-wide n_b.
EPS_MIX = (0.05, 0.3, 0.1, 0.4)


def _requests(concurrency: int, rule: str, seed: int):
    from repro.serve.bc_service import BCRequest

    return [BCRequest(rid=i, graph="web", k=10, eps=EPS_MIX[i % len(EPS_MIX)],
                      delta=0.1, rule=rule, seed=seed + i)
            for i in range(concurrency)]


def _drive(svc, reqs, max_ticks: int = 10_000):
    """Submit, tick to completion, count sources; returns (rec, responses)."""
    for r in reqs:
        svc.submit(r)
    t0 = time.time()
    sources = 0
    ticks = 0
    while (svc.queue or svc.active) and ticks < max_ticks:
        sources += svc.step()
        ticks += 1
    seconds = time.time() - t0
    out = svc.finished
    assert not svc.pending and len(out) == len(reqs), \
        (len(out), len(reqs), svc.pending)
    return {
        "seconds": seconds,
        "sources": sources,
        "sources_per_sec": sources / max(seconds, 1e-9),
        "ticks": ticks,
        "n_requests": len(reqs),
        "all_converged": all(r.converged for r in out),
    }, out


def bench_bc_serve(scale: int = 10, degree: int = 8,
                   levels: Sequence[int] = (1, 2, 4, 8, 16),
                   n_slots: int = 16, rule: str = "normal",
                   seed: int = 0) -> Dict:
    """Fused-vs-unfused serving sweep; returns the BENCH record."""
    from repro.graphs.generators import from_spec
    from repro.serve.bc_service import BCService

    g = from_spec("rmat", scale=scale, degree=degree, seed=seed)
    g, _ = g.remove_isolated()

    def make_service(fuse: bool) -> BCService:
        return BCService({"web": g}, n_slots=n_slots, fuse=fuse)

    runs: List[Dict] = []
    graph_plan = None
    for concurrency in levels:
        for fuse in (False, True):
            reqs = _requests(concurrency, rule, seed)
            # throwaway identical run: compiles every (bucket, variant)
            # shape this leg will touch, so the timed run is steady-state
            _drive(make_service(fuse), list(reqs))
            svc = make_service(fuse)
            rec, out = _drive(svc, list(reqs))
            rec.update(concurrency=concurrency, fused=fuse)
            # The per-request plans that *sized* each run (deduped:
            # requests sharing (ε, δ, rule) share a cached plan object;
            # the unfused leg is sized by the graph capacity plan). The
            # executor configuration that ran them is graph_plan.
            plans = {id(r.plan): r.plan.to_json() for r in out}
            rec["plans"] = list(plans.values())
            runs.append(rec)
            graph_plan = svc.plan_for("web").to_json()

    speedups = {}
    by = {(r["concurrency"], r["fused"]): r for r in runs}
    for c in levels:
        speedups[str(c)] = (by[(c, True)]["sources_per_sec"]
                            / max(by[(c, False)]["sources_per_sec"], 1e-9))
    return {
        "name": f"bc_serve_rmat_s{scale}_e{degree}",
        "n": g.n,
        "m": g.m,
        "rule": rule,
        "n_slots": n_slots,
        "eps_mix": list(EPS_MIX),
        "levels": list(levels),
        "graph_plan": graph_plan,
        "runs": runs,
        "fused_speedup": speedups,
    }


# -------------------------------------------------- mixed-tier QoS leg
# (ε, tier) per QoS class: the interactive tier is the *tight*-ε work —
# many sampling epochs, the requests whose tail latency the deadline
# scheduler exists to protect; the batch tier is loose-ε background
# load submitted ahead of it (the FIFO baseline's worst case).
TIER_MIX = {"interactive": 0.05, "batch": 0.15}


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an ascending-sorted sequence."""
    if not sorted_vals:
        return 0.0
    rank = max(1, int(-(-q / 100.0 * len(sorted_vals) // 1)))
    return float(sorted_vals[min(rank, len(sorted_vals)) - 1])


def _mixed_requests(n_interactive: int, n_batch: int, rule: str, seed: int):
    """Batch burst first, interactive arrivals behind it — FIFO admits
    the burst, EDF jumps the interactive tier over it."""
    from repro.serve.bc_service import BCRequest

    reqs = []
    for i in range(n_batch):
        reqs.append(BCRequest(rid=i, graph="web", k=10,
                              eps=TIER_MIX["batch"], delta=0.1, rule=rule,
                              seed=seed, priority="batch",
                              tenant=f"bg{i % 2}"))
    for i in range(n_interactive):
        reqs.append(BCRequest(rid=n_batch + i, graph="web", k=10,
                              eps=TIER_MIX["interactive"], delta=0.1,
                              rule=rule, seed=seed, priority="interactive",
                              tenant="fg"))
    return reqs


def bench_mixed_tiers(scale: int = 10, degree: int = 8, *,
                      n_interactive: int = 4, n_batch: int = 8,
                      n_slots: int = 4, rule: str = "normal", seed: int = 0,
                      tick_budget: int = 256) -> Dict:
    """Per-tier latency under mixed load: FIFO baseline vs QoS legs."""
    from repro.graphs.generators import from_spec
    from repro.serve.bc_service import BCService

    g = from_spec("rmat", scale=scale, degree=degree, seed=seed)
    g, _ = g.remove_isolated()

    legs: Dict[str, Dict] = {}
    for leg, pack, budget in (("fifo", "fifo", None),
                              ("deadline", "deadline", tick_budget)):
        def make_service() -> BCService:
            return BCService({"web": g}, n_slots=n_slots, pack=pack,
                             tick_budget=budget)

        # throwaway identical run: jit-warm every shape this leg touches
        _drive(make_service(), _mixed_requests(n_interactive, n_batch,
                                               rule, seed))
        rec, out = _drive(make_service(),
                          _mixed_requests(n_interactive, n_batch, rule,
                                          seed))
        per_tier = {}
        for tier in TIER_MIX:
            lats = sorted(r.latency_s for r in out if r.tier == tier)
            per_tier[tier] = {"n": len(lats),
                              "p50_s": _percentile(lats, 50),
                              "p95_s": _percentile(lats, 95),
                              "max_s": lats[-1] if lats else 0.0}
        plans = {id(r.plan): r.plan.to_json() for r in out}
        rec.update(pack=pack, tick_budget=budget, per_tier=per_tier,
                   plans=list(plans.values()))
        legs[leg] = rec

    p95_fifo = legs["fifo"]["per_tier"]["interactive"]["p95_s"]
    p95_dl = legs["deadline"]["per_tier"]["interactive"]["p95_s"]
    return {
        "n_slots": n_slots,
        "n_interactive": n_interactive,
        "n_batch": n_batch,
        "rule": rule,
        "eps": dict(TIER_MIX),
        "tight_tier": "interactive",
        "legs": legs,
        "tight_p95_speedup": p95_fifo / max(p95_dl, 1e-9),
    }


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--levels", default="1,2,4,8,16",
                    help="comma-separated concurrency levels")
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--rule", default="normal",
                    choices=["normal", "bernstein"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (scale 8, levels 1,2,4)")
    ap.add_argument("--no-mixed", action="store_true",
                    help="skip the mixed-tier QoS scenario")
    args = ap.parse_args(argv)

    scale = 8 if args.smoke else args.scale
    levels = ((1, 2, 4) if args.smoke
              else tuple(int(x) for x in args.levels.split(",")))
    rec = bench_bc_serve(scale=scale, degree=args.degree, levels=levels,
                         n_slots=args.slots, rule=args.rule, seed=args.seed)
    if not args.no_mixed:
        rec["mixed_tier"] = bench_mixed_tiers(
            scale=scale, degree=args.degree, rule=args.rule, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[bc_serve] n={rec['n']} m={rec['m']} slots={rec['n_slots']} "
          f"eps_mix={rec['eps_mix']}")
    for r in rec["runs"]:
        tag = "fused  " if r["fused"] else "unfused"
        print(f"[bc_serve] c={r['concurrency']:>2} {tag} "
              f"{r['sources_per_sec']:8.1f} src/s "
              f"({r['sources']} sources, {r['ticks']} ticks, "
              f"{r['seconds']:.2f}s, converged={r['all_converged']})")
    for c, s in rec["fused_speedup"].items():
        print(f"[bc_serve] fused speedup @ {c} concurrent: {s:.2f}x")
    mt = rec.get("mixed_tier")
    if mt:
        for leg, r in mt["legs"].items():
            for tier, p in r["per_tier"].items():
                print(f"[bc_serve] mixed {leg:>8} {tier:>11} "
                      f"p50={p['p50_s']:.3f}s p95={p['p95_s']:.3f}s "
                      f"(n={p['n']})")
            print(f"[bc_serve] mixed {leg:>8} "
                  f"{r['sources_per_sec']:8.1f} src/s over {r['ticks']} ticks")
        print(f"[bc_serve] mixed tight-tier p95 speedup "
              f"(fifo/deadline): {mt['tight_p95_speedup']:.2f}x")
    print(f"[bc_serve] wrote {args.out}")
    return rec


if __name__ == "__main__":
    main()
