"""Fused vs unfused serving throughput (the cross-request batching win).

Drives ``serve.BCService`` at 1–16 concurrent approximate-BC queries on
one R-MAT graph, twice per concurrency level: ``fuse=False`` (the
pre-fusion behavior — every request's epoch runs as its own batch,
padded to the graph-wide ``n_b``) and ``fuse=True`` (per-request (ε, δ)
plans via ``repro.bc.plan_for_request`` + slot-tagged fused batches
through the executors' ``step_segmented``). The metric is tick-loop
throughput in *source samples per second*: fusion packs several
requests' ragged epoch demand into shared power-of-two buckets, so the
fixed per-batch cost (kernel dispatch; on a mesh, the fused moments
all-reduce) and the padding waste are amortized across queries.

The request mix cycles (ε, seed) so per-request plans differ — exactly
the ragged multi-tenant demand fusion exists for. Each leg is jit-warmed
by a throwaway identical run (module-level jitted steps cache by shape),
so timings are steady-state serving, not XLA compilation.

Everything lands in ``BENCH_serve.json`` with the per-request executed
``BCPlan``s and the graph capacity plan recorded next to the timings;
``tools/check_bench.py`` asserts the record's shape in CI.

  PYTHONPATH=src python -m benchmarks.bc_serve            # scale 10
  PYTHONPATH=src python -m benchmarks.bc_serve --smoke    # scale 8, CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Sequence

# (ε, δ) mix cycled over concurrent requests: distinct accuracy contracts
# produce distinct per-request plans (tight ε → large n_b/budget, loose
# ε → small n_b and a sub-batch Hoeffding cap) and ragged epoch demand —
# the multi-tenant shape fusion is for. The loose tiers model cheap
# "find the hubs" queries; without fusion every one of their under-
# filled epochs pads to the graph-wide n_b.
EPS_MIX = (0.05, 0.3, 0.1, 0.4)


def _requests(concurrency: int, rule: str, seed: int):
    from repro.serve.bc_service import BCRequest

    return [BCRequest(rid=i, graph="web", k=10, eps=EPS_MIX[i % len(EPS_MIX)],
                      delta=0.1, rule=rule, seed=seed + i)
            for i in range(concurrency)]


def _drive(svc, reqs, max_ticks: int = 10_000):
    """Submit, tick to completion, count sources; returns (rec, responses)."""
    for r in reqs:
        svc.submit(r)
    t0 = time.time()
    sources = 0
    ticks = 0
    while (svc.queue or svc.active) and ticks < max_ticks:
        sources += svc.step()
        ticks += 1
    seconds = time.time() - t0
    out = svc.finished
    assert not svc.pending and len(out) == len(reqs), \
        (len(out), len(reqs), svc.pending)
    return {
        "seconds": seconds,
        "sources": sources,
        "sources_per_sec": sources / max(seconds, 1e-9),
        "ticks": ticks,
        "n_requests": len(reqs),
        "all_converged": all(r.converged for r in out),
    }, out


def bench_bc_serve(scale: int = 10, degree: int = 8,
                   levels: Sequence[int] = (1, 2, 4, 8, 16),
                   n_slots: int = 16, rule: str = "normal",
                   seed: int = 0) -> Dict:
    """Fused-vs-unfused serving sweep; returns the BENCH record."""
    from repro.graphs.generators import from_spec
    from repro.serve.bc_service import BCService

    g = from_spec("rmat", scale=scale, degree=degree, seed=seed)
    g, _ = g.remove_isolated()

    def make_service(fuse: bool) -> BCService:
        return BCService({"web": g}, n_slots=n_slots, fuse=fuse)

    runs: List[Dict] = []
    graph_plan = None
    for concurrency in levels:
        for fuse in (False, True):
            reqs = _requests(concurrency, rule, seed)
            # throwaway identical run: compiles every (bucket, variant)
            # shape this leg will touch, so the timed run is steady-state
            _drive(make_service(fuse), list(reqs))
            svc = make_service(fuse)
            rec, out = _drive(svc, list(reqs))
            rec.update(concurrency=concurrency, fused=fuse)
            # The per-request plans that *sized* each run (deduped:
            # requests sharing (ε, δ, rule) share a cached plan object;
            # the unfused leg is sized by the graph capacity plan). The
            # executor configuration that ran them is graph_plan.
            plans = {id(r.plan): r.plan.to_json() for r in out}
            rec["plans"] = list(plans.values())
            runs.append(rec)
            graph_plan = svc.plan_for("web").to_json()

    speedups = {}
    by = {(r["concurrency"], r["fused"]): r for r in runs}
    for c in levels:
        speedups[str(c)] = (by[(c, True)]["sources_per_sec"]
                            / max(by[(c, False)]["sources_per_sec"], 1e-9))
    return {
        "name": f"bc_serve_rmat_s{scale}_e{degree}",
        "n": g.n,
        "m": g.m,
        "rule": rule,
        "n_slots": n_slots,
        "eps_mix": list(EPS_MIX),
        "levels": list(levels),
        "graph_plan": graph_plan,
        "runs": runs,
        "fused_speedup": speedups,
    }


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--levels", default="1,2,4,8,16",
                    help="comma-separated concurrency levels")
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--rule", default="normal",
                    choices=["normal", "bernstein"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (scale 8, levels 1,2,4)")
    args = ap.parse_args(argv)

    scale = 8 if args.smoke else args.scale
    levels = ((1, 2, 4) if args.smoke
              else tuple(int(x) for x in args.levels.split(",")))
    rec = bench_bc_serve(scale=scale, degree=args.degree, levels=levels,
                         n_slots=args.slots, rule=args.rule, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[bc_serve] n={rec['n']} m={rec['m']} slots={rec['n_slots']} "
          f"eps_mix={rec['eps_mix']}")
    for r in rec["runs"]:
        tag = "fused  " if r["fused"] else "unfused"
        print(f"[bc_serve] c={r['concurrency']:>2} {tag} "
              f"{r['sources_per_sec']:8.1f} src/s "
              f"({r['sources']} sources, {r['ticks']} ticks, "
              f"{r['seconds']:.2f}s, converged={r['all_converged']})")
    for c, s in rec["fused_speedup"].items():
        print(f"[bc_serve] fused speedup @ {c} concurrent: {s:.2f}x")
    print(f"[bc_serve] wrote {args.out}")
    return rec


if __name__ == "__main__":
    main()
