"""Paper Figures 1 & 2: strong and weak scaling of MFBC.

Two layers of evidence on a CPU-only container:

* measured — real single-host executions of the batched MFBC step over
  R-MAT / uniform graphs (small n), reported as TEPS (the paper's metric:
  m·n_sources / seconds);
* modeled — the Theorem 5.1 α–β cost evaluated at Blue-Waters-like and
  v5e-pod scales, reproducing the shapes of Fig. 1 (strong scaling) and
  Fig. 2 (edge-weak vs vertex-weak): edge-weak scaling sustains efficiency
  while vertex-weak degrades by ~sqrt(p) — the paper's §7.3 observation.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import mfbc
from repro.graphs.generators import rmat, uniform_random
from repro.spgemm.cost_model import w_mfbc


def measured_strong_scaling(scale=7, degree=8, nb=64, weighted=False,
                            repeats=1) -> Dict:
    g = rmat(scale, degree, weighted=weighted, seed=3)
    g, _ = g.remove_isolated()
    mfbc(g, n_b=nb, backend="dense")  # warm up (jit compile)
    t0 = time.time()
    lam = mfbc(g, n_b=nb, backend="dense")
    dt = time.time() - t0
    teps = g.m * g.n / dt
    return {"n": g.n, "m": g.m, "seconds": dt, "teps": teps,
            "weighted": weighted, "lam_sum": float(lam.sum())}


def modeled_strong_scaling(n=1 << 22, k=64, d=8, mem=16 * 2 ** 30,
                           ps=(64, 256, 1024, 4096)) -> List[Dict]:
    m = n * k
    rows = []
    for p in ps:
        from repro.spgemm.cost_model import best_replication
        c = best_replication(n, m, p, mem, d=d)
        r = w_mfbc(n, m, p, c, d)
        rows.append({"p": p, "c": c, "seconds": r["seconds"],
                     "teps": m * n / r["seconds"],
                     "bytes": r["beta_bytes"], "msgs": r["alpha_msgs"]})
    return rows


def modeled_weak_scaling(kind="edge", base_n=1 << 18, base_p=64, d=8,
                         mem=16 * 2 ** 30, steps=4) -> List[Dict]:
    """edge: m/p and m/n^2 fixed (n ~ sqrt(p)); vertex: n/p and k fixed."""
    rows = []
    for i in range(steps):
        p = base_p * 4 ** i
        if kind == "edge":
            n = int(base_n * 2 ** i)  # n^2/p fixed
            k = n / 64
        else:
            n = base_n * 4 ** i  # n/p fixed
            k = 64
        m = int(n * k)
        from repro.spgemm.cost_model import best_replication
        c = best_replication(n, m, p, mem, d=d)
        r = w_mfbc(n, m, p, c, d)
        # efficiency = useful-compute fraction of the (overlapped) step:
        # drops exactly when communication outgrows the per-node work —
        # the paper's vertex-weak deterioration.
        eff = r["compute_seconds"] / max(r["seconds"], 1e-30)
        rows.append({"p": p, "n": n, "m": m, "c": c,
                     "seconds": r["seconds"], "efficiency": eff,
                     "comm_frac": r["comm_seconds"]
                     / (r["comm_seconds"] + r["compute_seconds"])})
    return rows


def weighted_slowdown(scale=6, degree=6, nb=32) -> Dict:
    """Fig. 1(c): weighted graphs roughly double the relax count."""
    u = measured_strong_scaling(scale, degree, nb, weighted=False)
    w = measured_strong_scaling(scale, degree, nb, weighted=True)
    return {"teps_unweighted": u["teps"], "teps_weighted": w["teps"],
            "slowdown": u["teps"] / max(w["teps"], 1e-9)}
