"""Scaling benchmarks: paper Figures 1 & 2 plus the CI-tracked record.

Three layers of evidence on a CPU-only container:

* measured (small) — real single-host executions of the batched MFBC
  step over R-MAT graphs, reported as TEPS (``measured_strong_scaling``;
  the paper's metric: m·n_sources / seconds);
* modeled — the Theorem 5.1 α–β cost evaluated at Blue-Waters-like and
  v5e-pod scales, reproducing the shapes of Fig. 1 (strong scaling) and
  Fig. 2 (edge-weak vs vertex-weak);
* measured (large) — the ``scaling`` record: R-MAT scale 18/20 and one
  real public graph ingested out-of-core through
  ``repro.graphs.formats.load_graph`` (chunked, digest-verified), run
  through the calibrated COO fast path for sources/sec, plus
  HLO-*measured* per-device collective bytes of the compiled distributed
  step at ≥ 2 mesh shapes against the §5.2 model prediction
  (``benchmarks.comm_cost.measured_mesh_collectives``). The record lands
  in ``BENCH_scaling.json`` — or is merged into ``BENCH_approx.json``
  under the ``"scaling"`` key with ``--merge`` — and is gated by
  ``tools/check_bench.py`` (bytes ratio vs model within tolerance, mesh
  -shape reduction matching the model, no sources/sec regression vs
  ``benchmarks/baselines/scaling.json``).

  PYTHONPATH=src python -m benchmarks.bc_scaling                # full
  PYTHONPATH=src python -m benchmarks.bc_scaling --smoke \
      --merge BENCH_approx.json                                 # CI leg

The collective measurement needs 64 fake host devices, which must be
configured before jax initializes — ``main`` re-invokes itself in a
``--comm-only`` subprocess for that step, so the measured sources/sec
legs in the parent keep the real (single-device) topology.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

# NOTE: all repro imports in this module are lazy — ``--comm-only`` must
# set XLA_FLAGS before anything initializes jax (repro.spgemm's package
# __init__ pulls it in via the autotuner).

SNAP_URL = "https://snap.stanford.edu/data/facebook_combined.txt.gz"
DATASET_DIR = "results/datasets"
BASELINE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baselines", "scaling.json")
# The two Table-3 mesh cells at p = 64: the 2D square grid (c = 1, what a
# CombBLAS-style code does) vs the 3D replicated grid (c = 4) — the §5.2
# claim is the bytes ratio between exactly these two.
COMM_SHAPES: Dict[str, Dict[str, int]] = {
    "8x8": {"data": 8, "model": 8},
    "4x4x4": {"pod": 4, "data": 4, "model": 4},
}


# --------------------------------------------------------------------------
# Paper Figures 1 & 2 (benchmarks.run CSV rows).
# --------------------------------------------------------------------------


def measured_strong_scaling(scale=7, degree=8, nb=64, weighted=False,
                            repeats=1) -> Dict:
    from repro.bc import BCQuery, ExecutionConfig, solve
    from repro.bc import plan as bc_plan
    from repro.graphs.generators import rmat

    g = rmat(scale, degree, weighted=weighted, seed=3)
    g, _ = g.remove_isolated()
    q = BCQuery(mode="exact", n_b=nb,
                execution=ExecutionConfig(backend="dense"))
    pl = bc_plan(g, q, n_devices=1)
    solve(g, q, plan=pl)  # warm up (jit compile)
    t0 = time.time()
    lam = solve(g, q, plan=pl).lam
    dt = time.time() - t0
    teps = g.m * g.n / dt
    return {"n": g.n, "m": g.m, "seconds": dt, "teps": teps,
            "weighted": weighted, "lam_sum": float(lam.sum())}


def modeled_strong_scaling(n=1 << 22, k=64, d=8, mem=16 * 2 ** 30,
                           ps=(64, 256, 1024, 4096)) -> List[Dict]:
    from repro.spgemm.cost_model import best_replication, w_mfbc

    m = n * k
    rows = []
    for p in ps:
        c = best_replication(n, m, p, mem, d=d)
        r = w_mfbc(n, m, p, c, d)
        rows.append({"p": p, "c": c, "seconds": r["seconds"],
                     "teps": m * n / r["seconds"],
                     "bytes": r["beta_bytes"], "msgs": r["alpha_msgs"]})
    return rows


def modeled_weak_scaling(kind="edge", base_n=1 << 18, base_p=64, d=8,
                         mem=16 * 2 ** 30, steps=4) -> List[Dict]:
    """edge: m/p and m/n^2 fixed (n ~ sqrt(p)); vertex: n/p and k fixed."""
    from repro.spgemm.cost_model import best_replication, w_mfbc

    rows = []
    for i in range(steps):
        p = base_p * 4 ** i
        if kind == "edge":
            n = int(base_n * 2 ** i)  # n^2/p fixed
            k = n / 64
        else:
            n = base_n * 4 ** i  # n/p fixed
            k = 64
        m = int(n * k)
        c = best_replication(n, m, p, mem, d=d)
        r = w_mfbc(n, m, p, c, d)
        # efficiency = useful-compute fraction of the (overlapped) step:
        # drops exactly when communication outgrows the per-node work —
        # the paper's vertex-weak deterioration.
        eff = r["compute_seconds"] / max(r["seconds"], 1e-30)
        rows.append({"p": p, "n": n, "m": m, "c": c,
                     "seconds": r["seconds"], "efficiency": eff,
                     "comm_frac": r["comm_seconds"]
                     / (r["comm_seconds"] + r["compute_seconds"])})
    return rows


def weighted_slowdown(scale=6, degree=6, nb=32) -> Dict:
    """Fig. 1(c): weighted graphs roughly double the relax count."""
    u = measured_strong_scaling(scale, degree, nb, weighted=False)
    w = measured_strong_scaling(scale, degree, nb, weighted=True)
    return {"teps_unweighted": u["teps"], "teps_weighted": w["teps"],
            "slowdown": u["teps"] / max(w["teps"], 1e-9)}


# --------------------------------------------------------------------------
# Out-of-core datasets: cached R-MAT RCOO files + one real public graph.
# --------------------------------------------------------------------------


def rmat_dataset(scale: int, degree: int = 8, seed: int = 7,
                 cache_dir: str = DATASET_DIR) -> str:
    """Write (once) the raw scale-``scale`` R-MAT arc stream as RCOO.gz.

    The generator runs in memory — arcs are just arrays — but the
    *benchmark* then forgets the arrays and goes through the on-disk
    chunked ingest, which is the code path under test.
    """
    from repro.graphs.formats import write_binary_coo
    from repro.graphs.generators import rmat

    path = os.path.join(cache_dir, f"rmat_s{scale}_e{degree}_{seed}.rcoo.gz")
    if not os.path.exists(path):
        os.makedirs(cache_dir, exist_ok=True)
        g = rmat(scale, degree, seed=seed)
        write_binary_coo(path, g)
    return path


def fetch_real_graph(cache_dir: str = DATASET_DIR,
                     timeout: float = 30.0) -> Tuple[str, bool]:
    """The SNAP ego-Facebook edge list, downloaded-or-cached.

    Returns ``(path, synthesized)``. Offline (or on any download
    failure) a synthesized stand-in of the same shape class (undirected
    power-law, n ≈ 4k) is written instead so the leg — and its baseline
    gate — runs everywhere; the record carries the ``synthesized`` flag.
    """
    real = os.path.join(cache_dir, "facebook_combined.txt.gz")
    if os.path.exists(real):
        return real, False
    os.makedirs(cache_dir, exist_ok=True)
    try:
        from urllib.request import urlopen

        with urlopen(SNAP_URL, timeout=timeout) as r:
            data = r.read()
        with open(real, "wb") as f:
            f.write(data)
        return real, False
    except Exception:
        pass
    synth = os.path.join(cache_dir, "facebook_synth.txt.gz")
    if not os.path.exists(synth):
        from repro.graphs.formats import write_edge_list
        from repro.graphs.generators import rmat

        g = rmat(12, 22, seed=41)  # ~4k vertices, ~88k arcs: SNAP-like
        write_edge_list(path=synth, g=g, weights=False)
    return synth, True


def ingest_leg(path: str, *, symmetrize: bool = False,
               chunk_edges: int = 1 << 18, name: Optional[str] = None
               ) -> Tuple["object", Dict]:
    """Chunked on-disk ingest, timed. Returns (IngestResult, record)."""
    from repro.graphs.formats import load_graph

    t0 = time.time()
    res = load_graph(path, chunk_edges=chunk_edges, symmetrize=symmetrize,
                     remove_isolated=True, name=name)
    dt = time.time() - t0
    rec = {
        "graph": res.graph.name,
        "path": path,
        "n": res.graph.n,
        "m": res.graph.m,
        "edges_read": res.edges_read,
        "n_chunks": res.n_chunks,
        "chunk_edges": chunk_edges,
        "seconds": dt,
        "edges_per_sec": res.edges_read / max(dt, 1e-9),
        "digest": res.digest,
    }
    return res, rec


# --------------------------------------------------------------------------
# Measured sources/sec legs (single-host COO fast path).
# --------------------------------------------------------------------------


def measured_bc_leg(ingest, *, nb: int = 16, iters: int = 48,
                    batches: int = 2, backend: str = "coo",
                    seed: int = 0, baselines: Optional[Dict] = None) -> Dict:
    """Steady-state sources/sec of the sampled BC sweep on one ingest.

    Plans from the ingest's ``GraphStats`` (no edge arrays needed at
    plan time — the out-of-core planning contract), then executes a
    fixed ``batches·nb`` uniform sample budget on the pinned backend
    after a one-batch jit warm-up.
    """
    from repro.bc import BCQuery, ExecutionConfig, solve
    from repro.bc import plan as bc_plan

    g = ingest.graph
    q = BCQuery(mode="approx", eps=0.1, delta=0.1, n_b=nb, iters=iters,
                strategy="uniform", max_samples=batches * nb, seed=seed,
                execution=ExecutionConfig(backend=backend))
    pl = bc_plan(ingest.stats, q, n_devices=1)  # plan without the arrays
    solve(g, dataclasses.replace(q, max_samples=nb, seed=seed + 1), plan=pl)
    t0 = time.time()
    out = solve(g, q, plan=pl)
    dt = time.time() - t0
    rec = {
        "graph": g.name,
        "n": g.n,
        "m": g.m,
        "nb": nb,
        "iters": iters,
        "backend": backend,
        "digest": ingest.digest,
        "n_sources": out.approx.n_samples,
        "seconds": dt,
        "sources_per_sec": out.approx.n_samples / max(dt, 1e-9),
        "plan": out.plan.to_json(),
    }
    base = (baselines or {}).get(g.name, {}).get("sources_per_sec")
    if base:
        rec["baseline_sources_per_sec"] = base
    return rec


# --------------------------------------------------------------------------
# HLO-measured collective bytes vs the §5.2 model (fake-mesh subprocess).
# --------------------------------------------------------------------------


def comm_record(scale: int, nb: int = 64, iters: int = 40,
                shapes: Dict[str, Dict[str, int]] = None) -> Dict:
    """Per-shape measured-vs-model collective bytes (call with the fake
    devices already configured — ``main --comm-only`` does)."""
    from benchmarks.comm_cost import measured_mesh_collectives

    shapes = shapes or COMM_SHAPES
    per_shape = {}
    for tag, axes in shapes.items():
        r = measured_mesh_collectives(1 << scale, nb, iters, axes)
        r["ratio"] = r["wire_bytes"] / max(r["model_bytes"], 1e-9)
        per_shape[tag] = r
    rec = {"scale": scale, "nb": nb, "iters": iters, "shapes": per_shape}
    tags = list(per_shape)
    if len(tags) >= 2:
        hi = max(tags, key=lambda t: per_shape[t]["model_bytes"])
        lo = min(tags, key=lambda t: per_shape[t]["model_bytes"])
        rec["reduction_measured"] = (per_shape[hi]["wire_bytes"]
                                     / max(per_shape[lo]["wire_bytes"], 1e-9))
        rec["reduction_model"] = (per_shape[hi]["model_bytes"]
                                  / max(per_shape[lo]["model_bytes"], 1e-9))
    return rec


def comm_record_subprocess(scale: int, nb: int = 64, iters: int = 40,
                           timeout: float = 1200.0) -> Dict:
    """Run ``comm_record`` in a fresh process with 64 fake devices.

    The parent's jax is already initialized on the real topology;
    forcing fake devices there would poison the measured legs' timings
    and the planner's routing, so the comm measurement re-invokes this
    module with ``--comm-only``.
    """
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out = f.name
    try:
        cmd = [sys.executable, "-m", "benchmarks.bc_scaling", "--comm-only",
               "--scale", str(scale), "--nb", str(nb),
               "--iters", str(iters), "--out", out]
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))
        if r.returncode != 0:
            raise RuntimeError(f"comm subprocess failed:\n{r.stderr[-2000:]}")
        with open(out) as f:
            return json.load(f)
    finally:
        os.unlink(out)


def _comm_only_main(args) -> None:
    # XLA_FLAGS was set by main() before anything imported jax.
    rec = comm_record(args.scale, nb=args.nb, iters=args.iters)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)


# --------------------------------------------------------------------------
# The full scaling record.
# --------------------------------------------------------------------------


def load_baselines(path: str = BASELINE_PATH) -> Dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def bench_scaling(smoke: bool = False, budget_s: float = 0.0,
                  comm_scale: int = 18, comm_nb: int = 64,
                  comm_iters: int = 40) -> Dict:
    """Assemble the ``scaling`` record (see module docstring)."""
    t_start = time.time()
    baselines = load_baselines()
    ingests: List[Dict] = []
    legs: List[Dict] = []

    def over_budget() -> bool:
        return bool(budget_s) and (time.time() - t_start) > budget_s

    # -- real public graph (small, runs everywhere) ---------------------
    real_path, synthesized = fetch_real_graph()
    res, irec = ingest_leg(real_path, symmetrize=True, chunk_edges=1 << 15)
    irec["synthesized"] = synthesized
    ingests.append(irec)
    legs.append(measured_bc_leg(res, nb=32, iters=24, batches=2,
                                baselines=baselines))
    legs[-1]["real"] = True
    legs[-1]["synthesized"] = synthesized

    # -- R-MAT scale 18 (the CI-gated big leg) --------------------------
    res, irec = ingest_leg(rmat_dataset(18), name="rmat_s18")
    ingests.append(irec)
    legs.append(measured_bc_leg(res, nb=16, iters=48, batches=2,
                                baselines=baselines))

    # -- R-MAT scale 20 (full runs only; budget-guarded) ----------------
    skipped = []
    if smoke or over_budget():
        skipped.append({"graph": "rmat_s20",
                        "reason": "smoke" if smoke else "budget"})
    else:
        res, irec = ingest_leg(rmat_dataset(20), name="rmat_s20")
        ingests.append(irec)
        legs.append(measured_bc_leg(res, nb=16, iters=56, batches=1,
                                    baselines=baselines))

    # -- HLO-measured collective bytes vs §5.2 model --------------------
    comm = comm_record_subprocess(comm_scale, nb=comm_nb, iters=comm_iters)

    return {
        "smoke": smoke,
        "ingest": ingests,
        "legs": legs,
        "skipped": skipped,
        "comm": comm,
        "baseline_path": os.path.relpath(BASELINE_PATH),
        "seconds_total": time.time() - t_start,
    }


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: skip the scale-20 leg")
    ap.add_argument("--budget-s", type=float, default=0.0,
                    help="soft wall-clock budget; optional legs are "
                         "skipped once exceeded")
    ap.add_argument("--out", default="BENCH_scaling.json")
    ap.add_argument("--merge", default=None, metavar="BENCH_APPROX",
                    help="also merge the record into this BENCH_approx"
                         ".json under the 'scaling' key")
    ap.add_argument("--comm-only", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--scale", type=int, default=18)
    ap.add_argument("--nb", type=int, default=64)
    ap.add_argument("--iters", type=int, default=40)
    args = ap.parse_args(argv)

    if args.comm_only:
        if "jax" in sys.modules:
            raise SystemExit("--comm-only must run before jax initializes")
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=64 "
            + os.environ.get("XLA_FLAGS", ""))
        _comm_only_main(args)
        return {}

    rec = bench_scaling(smoke=args.smoke, budget_s=args.budget_s,
                        comm_scale=args.scale, comm_nb=args.nb,
                        comm_iters=args.iters)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    if args.merge:
        with open(args.merge) as f:
            approx = json.load(f)
        approx["scaling"] = rec
        with open(args.merge, "w") as f:
            json.dump(approx, f, indent=1)

    for i in rec["ingest"]:
        print(f"[bc_scaling] ingest {i['graph']}: {i['edges_read']} arcs "
              f"-> n={i['n']} m={i['m']} in {i['seconds']:.1f}s "
              f"({i['edges_per_sec']:.0f} arcs/s, {i['n_chunks']} chunks)")
    for leg in rec["legs"]:
        base = leg.get("baseline_sources_per_sec")
        extra = f" (baseline {base:.2f})" if base else ""
        print(f"[bc_scaling] {leg['graph']}: {leg['n_sources']} sources in "
              f"{leg['seconds']:.1f}s = {leg['sources_per_sec']:.2f} "
              f"sources/s on {leg['backend']}{extra}")
    comm = rec["comm"]
    for tag, r in comm["shapes"].items():
        print(f"[bc_scaling] comm {tag}: measured "
              f"{r['wire_bytes'] / 1e9:.2f} GB/dev vs model "
              f"{r['model_bytes'] / 1e9:.2f} GB (ratio {r['ratio']:.2f}, "
              f"compile {r['seconds_compile']:.1f}s)")
    if "reduction_measured" in comm:
        print(f"[bc_scaling] 2D->3D bytes reduction: measured "
              f"{comm['reduction_measured']:.2f}x vs model "
              f"{comm['reduction_model']:.2f}x")
    print(f"[bc_scaling] wrote {args.out}"
          + (f" and merged into {args.merge}" if args.merge else ""))
    return rec


if __name__ == "__main__":
    main()
