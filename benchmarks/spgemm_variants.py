"""Paper §5.2: the SpGEMM decomposition family and the autotuner.

Evaluates the 1D/2D/3D cost formulas across operand-imbalance regimes
(the paper's headline: with imbalanced nnz the best variant changes, and
the 3D family wins by up to p^{1/3}), and reports which plan the autotuner
picks per regime — the CTF mapping search in miniature.
"""
from __future__ import annotations

from typing import Dict, List

from repro.spgemm import ProblemSizes, autotune, plan_cost, enumerate_plans

AXES = {"pod": 2, "data": 16, "model": 16}


def variant_table(n=1 << 20, k_dense=64) -> List[Dict]:
    regimes = {
        "balanced": ProblemSizes(8e9, 8e9, 8e9),
        "A_tiny(frontier)": ProblemSizes(8e6, 8e9, 8e8),
        "B_tiny": ProblemSizes(8e9, 8e6, 8e8),
        "C_small(output)": ProblemSizes(8e9, 8e9, 8e6),
    }
    rows = []
    for name, sizes in regimes.items():
        best = autotune(sizes, AXES)
        # cost of forcing the square-2D variant (the CombBLAS baseline)
        from repro.spgemm.dist import Plan
        p2d = plan_cost(Plan("2d_ab", ("data", "model")), sizes, AXES)
        rows.append({
            "regime": name,
            "best_variant": best.plan.variant,
            "best_axes": "x".join(best.plan.axes),
            "best_bytes": best.bytes_moved,
            "2d_ab_bytes": p2d.bytes_moved,
            "win_vs_2d": p2d.bytes_moved / max(best.bytes_moved, 1.0),
        })
    return rows
