"""Exact-vs-approximate BC benchmark (the new sampling workload).

Runs exact MFBC (all n sources) and adaptive-sampling approximate BC
(``repro.approx``) on the same R-MAT graph, reporting

* ``speedup``        — t_exact / t_approx (both jit-warm),
* ``topk_precision`` — |top-k(exact) ∩ top-k(approx)| / k,
* ``spearman``       — rank correlation of λ̂ vs λ over all vertices,
* ``max_norm_err``   — max_v |λ̂ − λ| / (n·(n−2)), comparable to ε,

and writing the record to ``BENCH_approx.json`` (consumed as a CI
artifact; ``benchmarks.run`` prints the same numbers as CSV rows).

  PYTHONPATH=src python -m benchmarks.bc_approx             # scale 10
  PYTHONPATH=src python -m benchmarks.bc_approx --smoke     # scale 8, CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import numpy as np


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    ra = np.argsort(np.argsort(a)).astype(np.float64)
    rb = np.argsort(np.argsort(b)).astype(np.float64)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    return float((ra * rb).sum() / denom) if denom > 0 else 1.0


def bench_bc_approx(scale: int = 10, degree: int = 8, eps: float = 0.05,
                    delta: float = 0.1, k: int = 10, nb: int = 64,
                    rule: str = "normal", seed: int = 0) -> Dict:
    """One exact-vs-approx comparison; returns the BENCH record."""
    from repro.approx import approx_bc
    from repro.core import mfbc
    from repro.graphs.generators import rmat

    g = rmat(scale, degree, seed=seed)
    g, _ = g.remove_isolated()

    # jit warm-up for both paths (one small restricted run each), so the
    # timed section measures steady-state batch throughput, not XLA.
    mfbc(g, n_b=nb, backend="dense", sources=np.arange(nb))
    approx_bc(g, eps=eps, delta=delta, rule=rule, n_b=nb,
              max_samples=nb, seed=seed + 1)

    t0 = time.time()
    lam_exact = mfbc(g, n_b=nb, backend="dense")
    t_exact = time.time() - t0

    t0 = time.time()
    res = approx_bc(g, eps=eps, delta=delta, rule=rule, n_b=nb,
                    topk=k, seed=seed)
    t_approx = time.time() - t0

    top_exact = set(np.argsort(lam_exact)[::-1][:k].tolist())
    top_approx = set(res.topk(k).tolist())
    norm = g.n * max(g.n - 2, 1)
    record = {
        "name": f"bc_approx_rmat_s{scale}_e{degree}",
        "n": g.n,
        "m": g.m,
        "eps": eps,
        "delta": delta,
        "rule": rule,
        "k": k,
        "n_samples": res.n_samples,
        "n_epochs": res.n_epochs,
        "converged": res.converged,
        "seconds_exact": t_exact,
        "seconds_approx": t_approx,
        "speedup": t_exact / max(t_approx, 1e-9),
        "sample_frac": res.n_samples / g.n,
        "topk_precision": len(top_exact & top_approx) / k,
        "spearman": _spearman(lam_exact, res.lam),
        "max_norm_err": float(np.abs(res.lam - lam_exact).max()) / norm,
    }
    return record


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--eps", type=float, default=0.05)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--nb", type=int, default=64)
    ap.add_argument("--rule", default="normal",
                    choices=["normal", "bernstein"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_approx.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (scale 8)")
    args = ap.parse_args(argv)

    scale = 8 if args.smoke else args.scale
    rec = bench_bc_approx(scale=scale, degree=args.degree, eps=args.eps,
                          delta=args.delta, k=args.k, nb=args.nb,
                          rule=args.rule, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[bc_approx] n={rec['n']} m={rec['m']} "
          f"samples={rec['n_samples']}/{rec['n']} "
          f"({rec['n_epochs']} epochs, converged={rec['converged']})")
    print(f"[bc_approx] exact {rec['seconds_exact']:.2f}s vs approx "
          f"{rec['seconds_approx']:.2f}s — speedup {rec['speedup']:.2f}x")
    print(f"[bc_approx] top-{rec['k']} precision {rec['topk_precision']:.2f} "
          f"spearman {rec['spearman']:.3f} "
          f"max_norm_err {rec['max_norm_err']:.4f} (eps {rec['eps']})")
    print(f"[bc_approx] wrote {args.out}")
    return rec


if __name__ == "__main__":
    main()
