"""Exact-vs-approximate BC benchmark (the new sampling workload).

Both legs now run through the unified solver API: one
``repro.bc.solve(graph, BCQuery(...))`` call per leg, with the chosen
``BCPlan`` (backend, n_b, placement, predicted cost) recorded next to
the timings — the perf trajectory captures planner decisions, not just
seconds. Reports

* ``speedup``        — t_exact / t_approx (both jit-warm),
* ``topk_precision`` — |top-k(exact) ∩ top-k(approx)| / k,
* ``spearman``       — rank correlation of λ̂ vs λ over all vertices,
* ``max_norm_err``   — max_v |λ̂ − λ| / (n·(n−2)), comparable to ε,
* ``plan`` / ``mesh_epochs.*.plan`` — the executed ``BCPlan`` records,
* ``backends``      — the self-calibrated dense/COO/CSR race: the run
  refits ``results/cost_calibration.json`` on its own graph, then times
  pinned dense, pinned COO, pinned frontier-sparse CSR and
  planner-routed (``auto``) legs over a fixed uniform sample budget,
  recording each executed plan next to its ``measured_seconds`` — the
  CSR leg's plan carries the frontier-occupancy trace
  (``tools/check_bench.py`` gates prediction drift at 2×, that ``auto``
  lands on a sparse backend, and that CSR beats pinned COO),

plus a mesh-vs-single-host *epoch* comparison (``mesh_epochs`` record):
both paths run the same adaptive estimator — the mesh step returns fused
(Σδ, Σδ²) — so the numbers to watch are epochs-to-converge and
``samples_saved`` vs the fixed Hoeffding budget. Fewer sampling epochs =
fewer distributed SpGEMM rounds for the same (ε, δ) guarantee.

Everything lands in ``BENCH_approx.json`` (consumed as a CI artifact;
``benchmarks.run`` prints the same numbers as CSV rows).

  PYTHONPATH=src python -m benchmarks.bc_approx             # scale 10
  PYTHONPATH=src python -m benchmarks.bc_approx --smoke     # scale 8, CI
  PYTHONPATH=src python -m benchmarks.bc_approx --mesh 2x2  # 4 devices
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, Tuple

import numpy as np


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    ra = np.argsort(np.argsort(a)).astype(np.float64)
    rb = np.argsort(np.argsort(b)).astype(np.float64)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    return float((ra * rb).sum() / denom) if denom > 0 else 1.0


def bench_bc_approx(scale: int = 10, degree: int = 8, eps: float = 0.05,
                    delta: float = 0.1, k: int = 10, nb: int = 64,
                    rule: str = "normal", seed: int = 0) -> Dict:
    """One exact-vs-approx comparison; returns the BENCH record."""
    from repro.bc import BCQuery, ExecutionConfig, solve
    from repro.bc import plan as bc_plan
    from repro.graphs.generators import from_spec

    g = from_spec("rmat", scale=scale, degree=degree, seed=seed)
    g, _ = g.remove_isolated()

    # backend/n_b/placement pinned (comparability with earlier BENCH
    # records, and fake mesh devices must not reroute the headline legs);
    # the plan's ``regime`` field still records the planner's unpinned
    # dense-vs-COO opinion. The dense-vs-COO wall-clock race itself is
    # ``bench_backends`` below.
    dense = ExecutionConfig(backend="dense")
    exact_q = BCQuery(mode="exact", n_b=nb, execution=dense)
    approx_q = BCQuery(mode="approx", eps=eps, delta=delta, rule=rule,
                       n_b=nb, execution=dense, topk=k, seed=seed)
    exact_pl = bc_plan(g, exact_q, n_devices=1)
    approx_pl = bc_plan(g, approx_q, n_devices=1)

    # jit warm-up for both paths (one small restricted run each), so the
    # timed section measures steady-state batch throughput, not XLA.
    solve(g, exact_q, plan=exact_pl, sources=np.arange(nb, dtype=np.int32))
    solve(g, dataclasses.replace(approx_q, max_samples=nb, seed=seed + 1),
          plan=approx_pl)

    t0 = time.time()
    exact = solve(g, exact_q, plan=exact_pl)
    t_exact = time.time() - t0

    t0 = time.time()
    out = solve(g, approx_q, plan=approx_pl)
    t_approx = time.time() - t0
    res = out.approx

    top_exact = set(exact.topk(k).tolist())
    top_approx = set(res.topk(k).tolist())
    norm = g.n * max(g.n - 2, 1)
    record = {
        "name": f"bc_approx_rmat_s{scale}_e{degree}",
        "n": g.n,
        "m": g.m,
        "eps": eps,
        "delta": delta,
        "rule": rule,
        "k": k,
        "n_samples": res.n_samples,
        "n_epochs": res.n_epochs,
        "converged": res.converged,
        "seconds_exact": t_exact,
        "seconds_approx": t_approx,
        "speedup": t_exact / max(t_approx, 1e-9),
        "sample_frac": res.n_samples / g.n,
        "topk_precision": len(top_exact & top_approx) / k,
        "spearman": _spearman(exact.lam, res.lam),
        "max_norm_err": float(np.abs(res.lam - exact.lam).max()) / norm,
        "plan": out.plan.to_json(),
        "plan_exact": exact.plan.to_json(),
    }
    return record


def bench_backends(scale: int = 10, degree: int = 8, eps: float = 0.05,
                   delta: float = 0.1, nb: int = 64, seed: int = 0) -> Dict:
    """Dense/COO/CSR executor race, planned with a fresh calibration.

    The ISSUE-6 measurement loop, end to end: (1) refit the α-β step
    constants on this benchmark's own graph (``repro.launch.calibrate``)
    and persist them to ``results/cost_calibration.json`` — the planner's
    ``"auto"`` calibration reloads the file mid-process via its
    mtime-keyed cache, so every leg below plans with the rates just
    measured (and future CLI runs inherit them); (2) run the same
    fixed-budget uniform-sampling query once per pinned backend and once
    unpinned (``auto`` — the calibrated regime routing), recording the
    executed ``BCPlan`` *with* its measured wall-clock next to
    ``predicted_seconds``. The budget is a fixed ``4·n_b`` samples
    (uniform strategy → exactly 4 batches, no adaptive early stop), so
    ``measured_seconds`` times exactly the work the plan priced —
    ``tools/check_bench.py`` gates the prediction drift at 2× and
    asserts the auto leg actually lights up a sparse fast path. The
    pinned CSR leg's executed plan additionally carries the
    frontier-occupancy trace (per-iteration frontier nnz, compaction
    hit rate, overflow count) under ``plan.occupancy``.
    """
    from repro.bc import BCQuery, ExecutionConfig, solve
    from repro.bc import plan as bc_plan
    from repro.graphs.generators import from_spec
    from repro.launch.calibrate import calibrate
    from repro.spgemm.cost_model import save_calibration

    g = from_spec("rmat", scale=scale, degree=degree, seed=seed)
    g, _ = g.remove_isolated()

    cal = calibrate(g, nb_pair=(max(nb // 4, 8), nb), reps=2,
                    variants=(("dense", False), ("coo", False),
                              ("csr", False)))
    cal_path = save_calibration(cal)

    budget = 4 * nb
    legs: Dict[str, Dict] = {}
    for leg in ("dense", "coo", "csr", "auto"):
        execution = ExecutionConfig(backend=None if leg == "auto" else leg)
        q = BCQuery(mode="approx", eps=eps, delta=delta, rule="normal",
                    n_b=nb, strategy="uniform", max_samples=budget,
                    seed=seed, execution=execution)
        pl = bc_plan(g, q, n_devices=1)
        # jit warm-up (one batch) so the timed run is steady-state
        solve(g, dataclasses.replace(q, max_samples=nb, seed=seed + 1),
              plan=pl)
        t0 = time.time()
        out = solve(g, q, plan=pl)
        dt = time.time() - t0
        legs[leg] = {
            "backend": out.plan.backend,
            "calibrated": bool(out.plan.regime.get("calibrated")),
            "n_samples": out.approx.n_samples,
            "measured_seconds": dt,
            "predicted_seconds": out.plan.predicted_seconds,
            "prediction_ratio": out.plan.predicted_seconds / max(dt, 1e-9),
            "plan": out.plan.to_json(),
        }
    return {
        "n": g.n,
        "m": g.m,
        "sample_budget": budget,
        "calibration_path": cal_path,
        "calibration": cal.to_json(),
        "coo_speedup": (legs["dense"]["measured_seconds"]
                        / max(legs["coo"]["measured_seconds"], 1e-9)),
        "csr_speedup": (legs["coo"]["measured_seconds"]
                        / max(legs["csr"]["measured_seconds"], 1e-9)),
        **legs,
    }


def _parse_mesh_dims(spec: str) -> Tuple[int, ...]:
    """Axis sizes of a ``DxM`` / ``PxDxM`` spec, jax-free.

    ``main`` must know the device count *before* anything imports jax
    (to set XLA_FLAGS); ``repro.launch.mesh.parse_mesh_spec`` imports
    jax only lazily inside the mesh constructors, so this is safe."""
    from repro.launch.mesh import parse_mesh_spec

    try:
        dims, _ = parse_mesh_spec(spec)
    except ValueError as e:
        raise SystemExit(f"--mesh: {e}")
    return dims


def bench_mesh_epochs(scale: int = 10, degree: int = 8, eps: float = 0.05,
                      delta: float = 0.1, nb: int = 64, rule: str = "normal",
                      seed: int = 0, mesh_shape: Tuple[int, ...] = (1, 1),
                      iters: int = 64) -> Dict:
    """Adaptive stopping on the mesh path vs single host vs Hoeffding.

    Runs the same (ε, δ) adaptive estimator through the single-host
    moments executor and the distributed mesh moments executor, and
    reports for each: epochs-to-converge, samples drawn, the executed
    ``BCPlan`` and ``samples_saved`` — how far under the fixed Hoeffding
    budget the empirical-Bernstein/CLT stopping rule got.

    Timing caveat: the single-host leg is jit-warmed (one capped run)
    so its ``seconds`` is steady-state, but the mesh leg's ``seconds``
    necessarily includes step preparation + shard_map compilation —
    the mesh executor is built fresh per solve call, so that cost is
    paid by every real caller and excluding it would flatter the mesh
    path. Epochs and samples are the apples-to-apples comparison;
    seconds are per-path end-to-end latencies.
    """
    import jax

    from repro.approx import hoeffding_budget
    from repro.bc import BCQuery, ExecutionConfig, solve
    from repro.graphs.generators import from_spec

    g = from_spec("rmat", scale=scale, degree=degree, seed=seed)
    g, _ = g.remove_isolated()
    names = (("data", "model") if len(mesh_shape) == 2
             else ("pod", "data", "model"))
    need = 1
    for d in mesh_shape:
        need *= d
    n_dev = len(jax.devices())
    if need != n_dev:
        raise SystemExit(f"mesh shape {mesh_shape} needs {need} devices, "
                         f"jax sees {n_dev}")
    mesh = jax.make_mesh(mesh_shape, names)
    budget = hoeffding_budget(g.n, eps, delta)
    base_q = BCQuery(mode="approx", eps=eps, delta=delta, rule=rule,
                     n_b=nb, execution=ExecutionConfig(backend="dense"),
                     seed=seed)

    from repro.bc import plan as bc_plan

    # pin the single-host leg's placement: with fake devices visible the
    # planner would otherwise route both legs through the mesh
    host_plan = bc_plan(g, base_q, n_devices=1)

    # jit warm-up for the single-host executor (the mesh executor compiles
    # per call — see the timing caveat above).
    solve(g, dataclasses.replace(base_q, max_samples=nb, seed=seed + 1),
          plan=host_plan)

    def one(tag, q=base_q, **kw):
        t0 = time.time()
        out = solve(g, q, **kw)
        res = out.approx
        return {
            "path": tag,
            "n_samples": res.n_samples,
            "n_epochs": res.n_epochs,
            "converged": res.converged,
            "has_moments": res.has_moments,
            "samples_saved": budget - res.n_samples,
            "seconds": time.time() - t0,
            "plan": out.plan.to_json(),
        }

    host = one("single_host", plan=host_plan)
    dist = one("mesh", q=dataclasses.replace(base_q, iters=iters), mesh=mesh)
    return {
        "n": g.n,
        "m": g.m,
        "eps": eps,
        "delta": delta,
        "rule": rule,
        "mesh_shape": list(mesh_shape),
        "hoeffding_budget": budget,
        "hoeffding_epochs": -(-budget // nb),
        "single_host": host,
        "mesh": dist,
    }


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--eps", type=float, default=0.05)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--nb", type=int, default=64)
    ap.add_argument("--rule", default="normal",
                    choices=["normal", "bernstein"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_approx.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (scale 8)")
    ap.add_argument("--mesh", default="1x1",
                    help="DxM or PxDxM axis sizes for the epoch benchmark "
                         "(forces fake host devices when needed)")
    ap.add_argument("--mesh-iters", type=int, default=64,
                    help="static sweep bound for the mesh step")
    args = ap.parse_args(argv)

    mesh_shape = _parse_mesh_dims(args.mesh)
    n_dev = 1
    for d in mesh_shape:
        n_dev *= d
    if n_dev > 1 and "jax" not in sys.modules:
        # Must happen before jax initializes; all repro imports are lazy.
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev} "
            + os.environ.get("XLA_FLAGS", ""))

    scale = 8 if args.smoke else args.scale
    # Calibrate first: the headline legs' regime records (and any
    # unpinned routing) then price with the constants just measured.
    backends = bench_backends(scale=scale, degree=args.degree, eps=args.eps,
                              delta=args.delta, nb=args.nb, seed=args.seed)
    rec = bench_bc_approx(scale=scale, degree=args.degree, eps=args.eps,
                          delta=args.delta, k=args.k, nb=args.nb,
                          rule=args.rule, seed=args.seed)
    rec["backends"] = backends
    rec["mesh_epochs"] = bench_mesh_epochs(
        scale=scale, degree=args.degree, eps=args.eps, delta=args.delta,
        nb=args.nb, rule=args.rule, seed=args.seed, mesh_shape=mesh_shape,
        iters=args.mesh_iters)
    # Records merged in by other benchmarks (bc_scaling.py --merge) must
    # survive a rerun of this one.
    if os.path.exists(args.out):
        with open(args.out) as f:
            prev = json.load(f)
        for key in ("scaling",):
            if key in prev and key not in rec:
                rec[key] = prev[key]
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    pl = rec["plan"]
    print(f"[bc_approx] n={rec['n']} m={rec['m']} "
          f"samples={rec['n_samples']}/{rec['n']} "
          f"({rec['n_epochs']} epochs, converged={rec['converged']})")
    print(f"[bc_approx] plan: {pl['placement']} backend={pl['backend']} "
          f"n_b={pl['n_b']} predicted {pl['predicted_seconds']:.3g}s")
    print(f"[bc_approx] exact {rec['seconds_exact']:.2f}s vs approx "
          f"{rec['seconds_approx']:.2f}s — speedup {rec['speedup']:.2f}x")
    bk = rec["backends"]
    print(f"[bc_approx] backends ({bk['sample_budget']} uniform samples): "
          f"dense {bk['dense']['measured_seconds']:.2f}s vs coo "
          f"{bk['coo']['measured_seconds']:.2f}s vs csr "
          f"{bk['csr']['measured_seconds']:.2f}s — coo speedup "
          f"{bk['coo_speedup']:.2f}x, csr-over-coo "
          f"{bk['csr_speedup']:.2f}x; auto routed to "
          f"backend={bk['auto']['backend']}"
          + (" [calibrated]" if bk["auto"]["calibrated"] else ""))
    occ = bk["csr"]["plan"].get("occupancy") or {}
    if occ:
        print(f"[bc_approx]   csr occupancy: fnnz "
              f"{occ.get('fnnz_first')}→{occ.get('fnnz_last')} over "
              f"{occ.get('iters_bf')} fwd iters, hit_rate "
              f"{occ.get('hit_rate', 0.0):.2f}, "
              f"overflows {occ.get('overflows')}")
    for leg in ("dense", "coo", "csr", "auto"):
        print(f"[bc_approx]   {leg}: predicted "
              f"{bk[leg]['predicted_seconds']:.3g}s / measured "
              f"{bk[leg]['measured_seconds']:.3g}s "
              f"(ratio {bk[leg]['prediction_ratio']:.2f})")
    print(f"[bc_approx] top-{rec['k']} precision {rec['topk_precision']:.2f} "
          f"spearman {rec['spearman']:.3f} "
          f"max_norm_err {rec['max_norm_err']:.4f} (eps {rec['eps']})")
    me = rec["mesh_epochs"]
    print(f"[bc_approx] mesh {args.mesh}: "
          f"{me['mesh']['n_samples']} samples in {me['mesh']['n_epochs']} "
          f"epochs (single-host {me['single_host']['n_samples']} in "
          f"{me['single_host']['n_epochs']}) vs Hoeffding budget "
          f"{me['hoeffding_budget']} ({me['hoeffding_epochs']} epochs) — "
          f"saved {me['mesh']['samples_saved']} samples")
    print(f"[bc_approx] wrote {args.out}")
    return rec


if __name__ == "__main__":
    main()
