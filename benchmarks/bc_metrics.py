"""One upload, many analytics: the metric-generic serving benchmark.

Two scenarios over a single R-MAT graph uploaded once:

* **gateway** — one HTTP gateway serves betweenness, closeness, k-hop
  reachability and connected components through the same ``/v1/bc``
  endpoint. Per metric: the cold solve wall time, the identical repeat
  (must be a content-addressed cache hit with a byte-identical payload),
  and the executed ``BCPlan``. The leg also proves metric-keyed cache
  *collision-freedom*: all four cached answers stay distinct — a hit
  under one metric never returns another metric's λ vector.
* **fused** — mixed-metric serving throughput through ``BCService``:
  a concurrent burst cycling betweenness and closeness requests (both
  members of the ``"sweep"`` fuse group, so their epochs pack into one
  ``step_segmented`` device batch), driven ``fuse=False`` vs
  ``fuse=True``. The metric is tick-loop sources/sec, same as
  ``benchmarks/bc_serve.py`` — the fused leg must not regress.

The record lands under the ``"metrics"`` key of ``BENCH_serve.json``
(merged like the ``"gateway"`` record); ``tools/check_bench.py``
gates the cache hits, collision-freedom, per-metric plans and the
no-fused-regression floor in CI.

  PYTHONPATH=src python -m benchmarks.bc_metrics            # scale 10
  PYTHONPATH=src python -m benchmarks.bc_metrics --smoke    # scale 8, CI
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Tuple
import urllib.error
import urllib.request

# (metric, hops, ε) legs through the gateway — one graph upload serves
# them all. Components is exact (ε ignored: cached at ε=0, any request
# hits); khop carries its hop bound into the cache key.
GW_LEGS: Tuple[Tuple[str, int, float], ...] = (
    ("betweenness", 0, 0.15),
    ("closeness", 0, 0.15),
    ("khop", 2, 0.15),
    ("components", 0, 0.05),
)


def _post(base: str, doc: Dict) -> Tuple[int, Dict]:
    req = urllib.request.Request(f"{base}/v1/bc",
                                 data=json.dumps(doc).encode(),
                                 headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _get(base: str, path: str) -> Dict:
    with urllib.request.urlopen(f"{base}{path}") as r:
        return json.loads(r.read())


def _poll_done(base: str, rid: int, timeout_s: float = 120.0) -> Dict:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        doc = _get(base, f"/v1/bc/{rid}")
        if doc["status"] in ("done", "error"):
            assert doc["status"] == "done", doc
            return doc
        time.sleep(0.002)
    raise RuntimeError(f"rid {rid} not done within {timeout_s}s")


def _submit_timed(base: str, doc: Dict) -> Tuple[float, int, Dict]:
    t0 = time.monotonic()
    st, resp = _post(base, doc)
    if resp.get("status") != "done":
        resp = _poll_done(base, resp["rid"])
    return time.monotonic() - t0, st, resp


def _payload(metric: str, hops: int, eps: float) -> Dict:
    doc = {"graph": "web", "eps": eps, "metric": metric}
    if hops:
        doc["hops"] = hops
    return doc


def _leg_key(metric: str, hops: int) -> str:
    return f"{metric}:{hops}" if hops else metric


def bench_gateway_metrics(g) -> Dict:
    """All metrics over the wire from one upload, plus cache isolation."""
    from repro.serve import BCGateway, BCService, GatewayConfig, start_gateway

    def gateway():
        svc = BCService({"web": g}, checkpoints=True)
        return start_gateway(BCGateway(svc, GatewayConfig(horizon_s=1e9)))

    # jit warm-up on a throwaway gateway (module-level jitted steps
    # cache by shape): the timed legs measure serving, not compilation
    warm = gateway()
    try:
        for metric, hops, eps in GW_LEGS:
            _submit_timed(warm.url, _payload(metric, hops, eps))
    finally:
        warm.close()

    srv = gateway()
    per_metric: Dict[str, Dict] = {}
    try:
        base = srv.url
        cold_results: Dict[str, Dict] = {}
        for metric, hops, eps in GW_LEGS:
            key = _leg_key(metric, hops)
            cold_s, _, cold = _submit_timed(base, _payload(metric, hops, eps))
            cached_s, st, cached = _submit_timed(
                base, _payload(metric, hops, eps))
            cold_results[key] = cold["result"]
            per_metric[key] = {
                "eps": eps,
                "cold_s": cold_s,
                "cached_s": cached_s,
                "cache_hit": st == 200 and bool(cached.get("cached")),
                "cache_identical": cached["result"] == cold["result"],
                "plan": cold["result"]["plan"],
            }
        m = _get(base, "/v1/metrics")
    finally:
        srv.close()

    # collision-freedom: every metric's cached answer is its own — no
    # two metrics share a λ vector (they are different analytics)
    lams = [tuple(r["lam"]) for r in cold_results.values()]
    collision_free = (len(set(lams)) == len(lams)
                      and all(p["cache_identical"]
                              for p in per_metric.values()))
    return {
        "n_uploads": 1,
        "legs": [list(leg) for leg in GW_LEGS],
        "per_metric": per_metric,
        "collision_free": collision_free,
        "cache": m.get("cache", {}),
        "admission_correction": m.get("admission_correction", {}),
    }


# ----------------------------------------------- mixed-metric fused leg
# betweenness and closeness share the "sweep" fuse group: their ragged
# epoch demand packs into one segmented device batch. The ε mix keeps
# per-request plans distinct (same multi-tenant shape as bc_serve).
METRIC_MIX: Tuple[Tuple[str, float], ...] = (
    ("betweenness", 0.1), ("closeness", 0.1),
    ("betweenness", 0.3), ("closeness", 0.3),
)


def _mixed_requests(concurrency: int, seed: int) -> List:
    from repro.serve.bc_service import BCRequest

    return [BCRequest(rid=i, graph="web", k=10,
                      metric=METRIC_MIX[i % len(METRIC_MIX)][0],
                      eps=METRIC_MIX[i % len(METRIC_MIX)][1],
                      delta=0.1, rule="normal", seed=seed + i)
            for i in range(concurrency)]


def _drive(svc, reqs, max_ticks: int = 10_000) -> Tuple[Dict, List]:
    for r in reqs:
        svc.submit(r)
    t0 = time.time()
    sources = 0
    ticks = 0
    while (svc.queue or svc.active) and ticks < max_ticks:
        sources += svc.step()
        ticks += 1
    seconds = time.time() - t0
    out = svc.finished
    assert not svc.pending and len(out) == len(reqs), \
        (len(out), len(reqs), svc.pending)
    return {
        "seconds": seconds,
        "sources": sources,
        "sources_per_sec": sources / max(seconds, 1e-9),
        "ticks": ticks,
        "n_requests": len(reqs),
        "all_converged": all(r.converged for r in out),
    }, out


def bench_mixed_fused(g, *, concurrency: int = 8, n_slots: int = 8,
                      seed: int = 0) -> Dict:
    """Mixed-metric fused vs unfused serving throughput."""
    from repro.serve.bc_service import BCService

    legs: Dict[str, Dict] = {}
    for fuse in (False, True):
        def make_service() -> BCService:
            return BCService({"web": g}, n_slots=n_slots, fuse=fuse)

        _drive(make_service(), _mixed_requests(concurrency, seed))  # warm
        rec, out = _drive(make_service(), _mixed_requests(concurrency, seed))
        plans = {id(r.plan): r.plan.to_json() for r in out}
        rec.update(fused=fuse, plans=list(plans.values()))
        legs["fused" if fuse else "unfused"] = rec

    return {
        "concurrency": concurrency,
        "n_slots": n_slots,
        "metric_mix": [list(x) for x in METRIC_MIX],
        "legs": legs,
        "mixed_speedup": (legs["fused"]["sources_per_sec"]
                          / max(legs["unfused"]["sources_per_sec"], 1e-9)),
    }


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="merged into this record's 'metrics' key "
                         "(other keys preserved)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (scale 8)")
    args = ap.parse_args(argv)

    from repro.graphs.generators import from_spec

    scale = 8 if args.smoke else args.scale
    g = from_spec("rmat", scale=scale, degree=args.degree, seed=args.seed)
    g, _ = g.remove_isolated()

    mrec = {
        "name": f"bc_metrics_rmat_s{scale}_e{args.degree}",
        "n": g.n,
        "m": g.m,
        "gateway": bench_gateway_metrics(g),
        "fused": bench_mixed_fused(g, seed=args.seed),
    }

    rec = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            rec = json.load(f)
    rec["metrics"] = mrec
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)

    gw = mrec["gateway"]
    print(f"[bc_metrics] n={g.n} m={g.m} (one upload, "
          f"{len(gw['per_metric'])} metrics)")
    for key, p in gw["per_metric"].items():
        print(f"[bc_metrics] {key:>12} cold {p['cold_s'] * 1e3:8.1f} ms   "
              f"cached {p['cached_s'] * 1e3:6.1f} ms "
              f"(hit={p['cache_hit']}, identical={p['cache_identical']}, "
              f"backend={p['plan'].get('backend')})")
    print(f"[bc_metrics] cache collision-free across metrics: "
          f"{gw['collision_free']}")
    fz = mrec["fused"]
    for leg, r in fz["legs"].items():
        print(f"[bc_metrics] mixed {leg:>7} {r['sources_per_sec']:8.1f} "
              f"src/s ({r['ticks']} ticks, converged={r['all_converged']})")
    print(f"[bc_metrics] mixed-metric fused speedup: "
          f"{fz['mixed_speedup']:.2f}x")
    print(f"[bc_metrics] wrote {args.out}")
    return mrec


if __name__ == "__main__":
    main()
