"""Paper Table 3: critical-path communication (W bytes, S messages).

Compares MFBC's 3D decomposition (replication c = pod axis) against the
2D-only baseline (c = 1 — what a CombBLAS-style square-grid code does),
from two sources:

* the analytic §5.2/§5.3 model at Blue-Waters scale (4096 cores) for the
  paper's graphs (Orkut / LiveJournal / Patents sizes), and
* HLO-measured per-device collective bytes of the compiled distributed BC
  step from the dry-run artifacts (512-chip mesh), which realizes the same
  ratio structurally.
"""
from __future__ import annotations

import glob
import math
import json
import os
import time
from typing import Dict, List

from repro.spgemm.cost_model import best_replication, w_mfbc

# (name, n, m, diameter) — Table 2 of the paper.
PAPER_GRAPHS = [
    ("orkut", 3_100_000, 117_000_000, 9),
    ("livejournal", 4_800_000, 70_000_000, 16),
    ("patents", 3_800_000, 16_500_000, 22),
]


def table3_model(p=4096, nb=512, word=8) -> List[Dict]:
    """One batch of ``nb`` sources (paper Table 3 setting).

    With the batch size fixed at 512, the useful replication is
    c = nb·n/m (Theorem 5.1's n_b = c·m/n inverted); the 2D baseline is
    c = 1. Per-batch bytes: 4·nb·n·word/√(pc) frontier movement +
    c·m·word/p adjacency replication (charged fully to this batch —
    conservative against MFBC).
    """
    rows = []
    for name, n, m, d in PAPER_GRAPHS:
        c3 = max(1, min(int(nb * n / m), p))

        def batch_bytes(c):
            front = 4.0 * nb * n * word / math.sqrt(p * c)
            adj = c * m * word / p
            return front + (adj if c > 1 else 0.0)

        def batch_msgs(c):
            return d * math.sqrt(p / c) * math.log2(p)

        w2, w3 = batch_bytes(1), batch_bytes(c3)
        rows.append({
            "graph": name, "n": n, "m": m, "d": d, "c_3d": c3,
            "W_2d_GB": w2 / 1e9, "W_3d_GB": w3 / 1e9,
            "S_2d": batch_msgs(1), "S_3d": batch_msgs(c3),
            "ratio_W": w2 / max(w3, 1e-9),
        })
    return rows


def model_mesh_bytes(n: int, nb: int, iters: int, axes: Dict[str, int],
                     word: int = 4) -> float:
    """§5.2 model: per-device collective bytes of one compiled batch step.

    The Theorem 5.1 realization on the (pod, data, model) mesh (see
    ``core.dist_bc``'s module docstring): each relaxation moves the
    pod-local dense state (nb/c rows × n vertices) three times —
    frontier all-gather, monoid reduce, product re-gather — at
    ``1/√(p/c)`` of its footprint per device. One batch runs the forward
    and backward sweeps, ``iters`` relaxations each. Monoid leaf counts
    and tie-mask doubling are deliberately *not* modeled — they are the
    constant factors the measured/model ratio gate absorbs; the
    shape-to-shape *scaling* is what the model pins down.
    """
    p = 1
    for s in axes.values():
        p *= s
    c = axes.get("pod", 1)
    per_iter = 3.0 * word * (nb / c) * n / max(math.sqrt(p / c), 1.0)
    return per_iter * 2 * iters


def measured_mesh_collectives(n: int, nb: int, iters: int,
                              axes: Dict[str, int],
                              block: int = 512) -> Dict:
    """HLO-measured per-device collective bytes of the distributed step.

    Compiles the real ``core.dist_bc`` batch step on a fake host mesh
    with *abstract* arguments — nothing is allocated, so this prices
    scale-18+ graphs whose dense adjacency could never materialize —
    and accounts the wire bytes of every collective in the compiled
    module via ``repro.roofline.hlo_parse`` (while-loop bodies scaled by
    the static trip count ``iters``). The caller must already be inside
    a process whose fake device count covers ``axes`` (bc_scaling spawns
    a subprocess with ``--xla_force_host_platform_device_count`` set
    before jax initializes).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.dist_bc import (BCMeshConfig, build_mfbc_step,
                                    input_shardings)
    from repro.roofline.hlo_parse import collective_bytes

    names = tuple(axes)
    shape = tuple(axes[a] for a in names)
    mesh = jax.make_mesh(shape, names)
    lcm = axes["data"] * axes["model"]
    n_pad = -(-n // lcm) * lcm
    chunk = axes.get("pod", 1) * axes["data"]
    nb_pad = -(-nb // chunk) * chunk
    cfg = BCMeshConfig(n=n_pad, nb=nb_pad, iters_bf=iters, iters_br=iters,
                       pod_axis="pod" if "pod" in axes else None,
                       block=block)
    step = build_mfbc_step(mesh, cfg)  # already jitted
    sh_a, sh_at, sh_src, sh_val = input_shardings(mesh, cfg)
    t0 = time.time()
    compiled = step.lower(
        jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32, sharding=sh_a),
        jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32, sharding=sh_at),
        jax.ShapeDtypeStruct((nb_pad,), jnp.int32, sharding=sh_src),
        jax.ShapeDtypeStruct((nb_pad,), jnp.bool_, sharding=sh_val),
    ).compile()
    coll = collective_bytes(compiled.as_text(), {"*": iters})
    return {
        "axes": dict(axes),
        "n": n, "n_pad": n_pad, "nb": nb_pad, "iters": iters,
        "seconds_compile": time.time() - t0,
        "wire_bytes": coll["wire_bytes"],
        "messages": coll["messages"],
        "by_kind": {k: v for k, v in coll.items()
                    if k.startswith("wire_")},
        "model_bytes": model_mesh_bytes(n_pad, nb_pad, iters, axes),
    }


def measured_bc_collectives(dryrun_dir="results/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              "mfbc_paper__*.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        rows.append({
            "cell": f"{rec['shape']}@{rec['mesh']}",
            "wire_GB_per_dev": rec["collectives"]["wire_bytes"] / 1e9,
            "msgs_per_dev": rec["collectives"]["messages"],
            "flops_per_dev": rec["flops_per_device"],
        })
    return rows
