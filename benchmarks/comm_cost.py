"""Paper Table 3: critical-path communication (W bytes, S messages).

Compares MFBC's 3D decomposition (replication c = pod axis) against the
2D-only baseline (c = 1 — what a CombBLAS-style square-grid code does),
from two sources:

* the analytic §5.2/§5.3 model at Blue-Waters scale (4096 cores) for the
  paper's graphs (Orkut / LiveJournal / Patents sizes), and
* HLO-measured per-device collective bytes of the compiled distributed BC
  step from the dry-run artifacts (512-chip mesh), which realizes the same
  ratio structurally.
"""
from __future__ import annotations

import glob
import math
import json
import os
from typing import Dict, List

from repro.spgemm.cost_model import best_replication, w_mfbc

# (name, n, m, diameter) — Table 2 of the paper.
PAPER_GRAPHS = [
    ("orkut", 3_100_000, 117_000_000, 9),
    ("livejournal", 4_800_000, 70_000_000, 16),
    ("patents", 3_800_000, 16_500_000, 22),
]


def table3_model(p=4096, nb=512, word=8) -> List[Dict]:
    """One batch of ``nb`` sources (paper Table 3 setting).

    With the batch size fixed at 512, the useful replication is
    c = nb·n/m (Theorem 5.1's n_b = c·m/n inverted); the 2D baseline is
    c = 1. Per-batch bytes: 4·nb·n·word/√(pc) frontier movement +
    c·m·word/p adjacency replication (charged fully to this batch —
    conservative against MFBC).
    """
    rows = []
    for name, n, m, d in PAPER_GRAPHS:
        c3 = max(1, min(int(nb * n / m), p))

        def batch_bytes(c):
            front = 4.0 * nb * n * word / math.sqrt(p * c)
            adj = c * m * word / p
            return front + (adj if c > 1 else 0.0)

        def batch_msgs(c):
            return d * math.sqrt(p / c) * math.log2(p)

        w2, w3 = batch_bytes(1), batch_bytes(c3)
        rows.append({
            "graph": name, "n": n, "m": m, "d": d, "c_3d": c3,
            "W_2d_GB": w2 / 1e9, "W_3d_GB": w3 / 1e9,
            "S_2d": batch_msgs(1), "S_3d": batch_msgs(c3),
            "ratio_W": w2 / max(w3, 1e-9),
        })
    return rows


def measured_bc_collectives(dryrun_dir="results/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              "mfbc_paper__*.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        rows.append({
            "cell": f"{rec['shape']}@{rec['mesh']}",
            "wire_GB_per_dev": rec["collectives"]["wire_bytes"] / 1e9,
            "msgs_per_dev": rec["collectives"]["messages"],
            "flops_per_dev": rec["flops_per_device"],
        })
    return rows
