"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * fig1_strong_scaling_*   — measured TEPS (real execution, small graphs)
                              + modeled TEPS at pod scale
  * fig1c_weighted          — weighted-vs-unweighted slowdown
  * fig2_weak_scaling_*     — edge-weak vs vertex-weak efficiency trend
  * table3_comm_*           — critical-path W/S: 2D baseline vs 3D MFBC
  * sec52_spgemm_*          — decomposition autotuner picks per regime
  * kernel_*                — Pallas kernel microbenches (interpret mode)
  * approx_bc_*             — exact-vs-sampled BC (speedup, top-k precision)

Run: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time

import numpy as np


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def bench_fig1_strong_scaling() -> None:
    from benchmarks.bc_scaling import (measured_strong_scaling,
                                       modeled_strong_scaling)

    m = measured_strong_scaling(scale=7, degree=8, nb=64)
    _row("fig1_strong_measured_rmat_s7_e8", m["seconds"] * 1e6,
         f"teps={m['teps']:.3e}")
    for r in modeled_strong_scaling():
        _row(f"fig1_strong_model_p{r['p']}", r["seconds"] * 1e6,
             f"teps={r['teps']:.3e};c={r['c']}")


def bench_fig1c_weighted() -> None:
    from benchmarks.bc_scaling import weighted_slowdown

    w = weighted_slowdown()
    _row("fig1c_weighted_slowdown", 0.0,
         f"slowdown={w['slowdown']:.2f};paper_claim~2x")


def bench_fig2_weak_scaling() -> None:
    from benchmarks.bc_scaling import modeled_weak_scaling

    for kind in ("edge", "vertex"):
        rows = modeled_weak_scaling(kind=kind)
        for r in rows:
            _row(f"fig2_{kind}_weak_p{r['p']}", r["seconds"] * 1e6,
                 f"eff={r['efficiency']:.3f};comm_frac={r['comm_frac']:.3f}")


def bench_table3_comm() -> None:
    from benchmarks.comm_cost import measured_bc_collectives, table3_model

    for r in table3_model():
        _row(f"table3_model_{r['graph']}", 0.0,
             f"W2d={r['W_2d_GB']:.2f}GB;W3d={r['W_3d_GB']:.2f}GB;"
             f"ratio={r['ratio_W']:.2f};c={r['c_3d']}")
    for r in measured_bc_collectives():
        _row(f"table3_hlo_{r['cell']}", 0.0,
             f"wire={r['wire_GB_per_dev']:.3f}GB/dev;"
             f"msgs={r['msgs_per_dev']:.0f}")


def bench_sec52_spgemm() -> None:
    from benchmarks.spgemm_variants import variant_table

    for r in variant_table():
        _row(f"sec52_autotune_{r['regime']}", 0.0,
             f"pick={r['best_variant']}@{r['best_axes']};"
             f"win_vs_2d={r['win_vs_2d']:.1f}x")


def bench_bc_approx() -> None:
    from benchmarks.bc_approx import bench_bc_approx as bench
    from benchmarks.bc_approx import bench_mesh_epochs

    r = bench(scale=8, nb=64)  # smoke-sized inside the CSV sweep
    _row(f"approx_{r['name']}", r["seconds_approx"] * 1e6,
         f"speedup={r['speedup']:.2f}x;topk_prec={r['topk_precision']:.2f};"
         f"spearman={r['spearman']:.3f};samples={r['n_samples']}")
    m = bench_mesh_epochs(scale=8, nb=64)
    _row("approx_mesh_epochs_s8", m["mesh"]["seconds"] * 1e6,
         f"epochs={m['mesh']['n_epochs']};samples={m['mesh']['n_samples']};"
         f"hoeffding={m['hoeffding_budget']};"
         f"saved={m['mesh']['samples_saved']}")


def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    nb, n = 128, 512
    fw = jnp.asarray(np.where(rng.random((nb, n)) < 0.5,
                              rng.integers(0, 20, (nb, n)), np.inf),
                     jnp.float32)
    fm = jnp.asarray((rng.random((nb, n)) < 0.5).astype(np.float32))
    a = jnp.asarray(np.where(rng.random((n, n)) < 0.3,
                             rng.integers(1, 9, (n, n)), np.inf), jnp.float32)
    f = jax.jit(lambda fw, fm, a: ops.multpath_matmul(fw, fm, a))
    f(fw, fm, a)[0].block_until_ready()
    t0 = time.time()
    reps = 3
    for _ in range(reps):
        f(fw, fm, a)[0].block_until_ready()
    us = (time.time() - t0) / reps * 1e6
    flops = 4 * nb * n * n
    _row("kernel_multpath_mm_512", us, f"interp_mode_gflops={flops/us/1e3:.2f}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_sec52_spgemm()
    bench_table3_comm()
    bench_fig2_weak_scaling()
    bench_fig1c_weighted()
    bench_fig1_strong_scaling()
    bench_bc_approx()
    bench_kernels()


if __name__ == "__main__":
    main()
