"""Continuous-batching engine: correctness vs teacher-forced forward."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

CFG = T.TransformerConfig(name="s", n_layers=2, d_model=32, n_heads=4,
                          n_kv=2, d_ff=64, vocab=64, head_dim=8)


def _greedy_reference(params, prompt, n_new):
    """Teacher-forced greedy continuation via full forward passes."""
    seq = list(prompt)
    for _ in range(n_new):
        logits = T.forward(CFG, params, jnp.asarray([seq], jnp.int32))
        seq.append(int(jnp.argmax(logits[0, -1])))
    return seq[len(prompt):]


def test_engine_matches_reference_and_recycles_slots():
    params = T.init_params(CFG, jax.random.key(0))
    eng = ServeEngine(CFG, params, n_slots=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, L).astype(np.int32),
                    max_new=m)
            for i, (L, m) in enumerate([(5, 6), (7, 4), (3, 5), (6, 3)])]
    for r in reqs:
        eng.submit(r)  # 4 requests through 2 slots -> slots must recycle
    done = eng.run()
    assert len(done) == 4 and all(r.done for r in done)
    for r in reqs:
        ref = _greedy_reference(params, r.prompt, r.max_new)
        assert r.out == ref, (r.rid, r.out, ref)


def test_engine_eos_frees_slot_early():
    params = T.init_params(CFG, jax.random.key(1))
    eng = ServeEngine(CFG, params, n_slots=1, max_len=32, eos_id=None)
    r = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=3)
    eng.submit(r)
    done = eng.run()
    assert len(done) == 1 and len(r.out) == 3
