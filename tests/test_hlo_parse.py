"""HLO collective parser unit tests (the roofline's measurement layer)."""
from repro.roofline.hlo_parse import (collective_bytes, parse_collectives,
                                      shape_bytes)

HLO = """
HloModule jit_f

ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,512]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[64,512]{1,0} all-reduce(%ag), to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(%ar), dimensions={1}
  ROOT %out = f32[64,128]{1,0} copy(%rs)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert shape_bytes("pred[8]") == 8


def test_parse_and_wire_estimates():
    stats = parse_collectives(HLO)
    kinds = sorted(op.kind for op in stats.ops)
    assert kinds == ["all-gather", "all-reduce", "reduce-scatter"]
    t = stats.totals()
    # ag wire = out - in = (512-128)*64*4; ar = 2*in; rs = in - out
    ag = (512 - 128) * 64 * 4
    ar = 2 * 64 * 512 * 4
    rs = (512 - 128) * 64 * 4
    assert abs(t["wire_bytes"] - (ag + ar + rs)) < 1
    assert t["messages"] == 3


def test_trip_count_scaling():
    hlo = HLO.replace("ENTRY %main", "%while_body_5 (p: f32[4]) -> f32[4] {\n"
                      " %x = f32[4]{0} parameter(0)\n}\nENTRY %main")
    # ops are in ENTRY here, so scaling by '*' should not change anything
    base = collective_bytes(hlo)
    scaled = collective_bytes(hlo, {"*": 10})
    assert base["wire_bytes"] == scaled["wire_bytes"]
