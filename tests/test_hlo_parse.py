"""HLO collective parser unit tests (the roofline's measurement layer)."""
import gzip
import os

from repro.roofline.hlo_parse import (collective_bytes, parse_collectives,
                                      shape_bytes)

GOLDEN_HLO = os.path.join(os.path.dirname(__file__), "data",
                          "mfbc_step_2x2x2.hlo.gz")

HLO = """
HloModule jit_f

ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,512]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[64,512]{1,0} all-reduce(%ag), to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(%ar), dimensions={1}
  ROOT %out = f32[64,128]{1,0} copy(%rs)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert shape_bytes("pred[8]") == 8


def test_parse_and_wire_estimates():
    stats = parse_collectives(HLO)
    kinds = sorted(op.kind for op in stats.ops)
    assert kinds == ["all-gather", "all-reduce", "reduce-scatter"]
    t = stats.totals()
    # ag wire = out - in = (512-128)*64*4; ar = 2*in; rs = in - out
    ag = (512 - 128) * 64 * 4
    ar = 2 * 64 * 512 * 4
    rs = (512 - 128) * 64 * 4
    assert abs(t["wire_bytes"] - (ag + ar + rs)) < 1
    assert t["messages"] == 3


def test_golden_mfbc_step_byte_accounting():
    """Golden compiled artifact: exact bytes-on-wire, incl. loop scaling.

    ``tests/data/mfbc_step_2x2x2.hlo.gz`` is the real compiled distributed
    BC batch step (2x2x2 (pod, data, model) mesh, n=64, nb=8, 4+4 iters)
    — the same module shape ``benchmarks.comm_cost.measured_mesh_
    collectives`` prices at scale 18+. The collectives live 6 in the
    forward while body, 8 in the backward while body, 12 in the entry;
    both bodies' collectives are hoisted into fusion computations *called
    from* the bodies, so these totals only come out right when trip
    counts propagate through the HLO call graph (calls=/body=/condition=
    edges), not just by body-name prefix matching.
    """
    text = gzip.open(GOLDEN_HLO, "rt").read()
    stats = parse_collectives(text)
    assert len(stats.ops) == 26
    assert len(stats.while_bodies) == 2
    body_ops = sum(1 for op in stats.ops
                   if any(op.computation == b or op.computation.startswith(b)
                          for b in stats.while_bodies))
    # the bodies themselves hold the collectives in this dump (post-fusion
    # attribution keeps them in the cloned regions); entry holds the rest
    assert body_ops == 14 and len(stats.ops) - body_ops == 12

    # exact totals, measured once at artifact generation time
    for trips, messages, wire in ((1, 26, 13568), (4, 68, 35840),
                                  (9, 138, 72960)):
        t = collective_bytes(text, {"*": trips})
        assert t["messages"] == messages
        assert t["wire_bytes"] == wire
    # wire = entry + per-iteration body traffic, exactly linear in trips
    t1 = collective_bytes(text, {"*": 1})["wire_bytes"]
    t4 = collective_bytes(text, {"*": 4})["wire_bytes"]
    t9 = collective_bytes(text, {"*": 9})["wire_bytes"]
    per_iter = (t9 - t4) / 5
    assert per_iter == 7424
    assert t1 == (t4 - 3 * per_iter)
    # kind split at trips=4
    t = collective_bytes(text, {"*": 4})
    assert t["wire_all-gather"] == 11008
    assert t["wire_all-reduce"] == 24832
    assert t["operand_bytes"] == 23424


def test_trip_count_scaling():
    hlo = HLO.replace("ENTRY %main", "%while_body_5 (p: f32[4]) -> f32[4] {\n"
                      " %x = f32[4]{0} parameter(0)\n}\nENTRY %main")
    # ops are in ENTRY here, so scaling by '*' should not change anything
    base = collective_bytes(hlo)
    scaled = collective_bytes(hlo, {"*": 10})
    assert base["wire_bytes"] == scaled["wire_bytes"]
