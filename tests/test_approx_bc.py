"""Approximate-BC subsystem: estimator convergence vs the Brandes oracle,
top-k precision, stopping-rule/sampler units, mesh-path second moments,
and the serving endpoint.

End-to-end runs go through the unified ``repro.bc.solve`` facade (the
``approx_bc`` shim's own deprecation contract is covered in
``test_bc_api.py``)."""
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare local run: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.approx import (bernstein_halfwidth, epoch_schedule,
                          hoeffding_budget, normal_halfwidth)
from repro.approx.driver import LambdaEstimator, choose_sample_batch
from repro.approx.sampling import AdaptiveSampler, UniformSampler
from repro.bc import BCQuery
from repro.bc import solve as bc_solve
from repro.core import brandes_bc
from repro.graphs.generators import ring_of_cliques, rmat, star_graph


def approx_bc(g, *, mesh=None, **kw):
    """The old driver call spelled as one unified-solver query."""
    return bc_solve(g, BCQuery(mode="approx", **kw), mesh=mesh).approx


@pytest.fixture(scope="module")
def small_rmat():
    g = rmat(7, 8, seed=5)
    g, _ = g.remove_isolated()
    return g, brandes_bc(g)


# ---------------------------------------------------------------- sampling
def test_hoeffding_budget_scales():
    b1 = hoeffding_budget(1000, 0.1, 0.1)
    b2 = hoeffding_budget(1000, 0.05, 0.1)
    assert b2 > 3.9 * b1  # 1/eps^2 scaling (log term shared)
    assert hoeffding_budget(10_000, 0.1, 0.1) > b1  # log n growth


def test_epoch_schedule_doubles():
    sched = epoch_schedule(64)
    taus = [next(sched) for _ in range(4)]
    assert taus == [64, 128, 256, 512]


def test_uniform_sampler_pads_and_honors_budget():
    s = UniformSampler(100, n_b=32, budget=70, seed=0)
    batches = list(s.batches())
    assert [b.n_valid for b in batches] == [32, 32, 6]
    for b in batches:
        assert b.sources.shape == (32,)
        assert np.all(b.sources[b.valid] < 100)
        assert np.all(b.sources[~b.valid] == 0)


def test_adaptive_sampler_stops_and_caps():
    s = AdaptiveSampler(100, n_b=16, cap=100, seed=0)
    drawn_per_epoch = []
    for ei, batches in s.epochs():
        drawn_per_epoch.append(sum(b.n_valid for b in batches))
        if ei == 1:
            s.stop()
    assert drawn_per_epoch == [16, 32]  # doubling, stopped after epoch 1
    assert s.drawn == 48 and not s.capped

    s2 = AdaptiveSampler(100, n_b=16, cap=40, seed=0)
    total = sum(b.n_valid for _, bs in s2.epochs() for b in bs)
    assert total == 40 and s2.capped


def test_halfwidths_shrink_with_tau():
    s1 = np.full(4, 50.0)
    s2 = np.full(4, 30.0)
    for fn in (bernstein_halfwidth, normal_halfwidth):
        hw100 = fn(s1, s2, 100, 1e-3)
        hw400 = fn(s1 * 4, s2 * 4, 400, 1e-3)
        assert np.all(hw400 < hw100)


def test_halfwidths_infinite_below_two_samples():
    """τ < 2 carries no variance estimate: the CI must be +inf, never a
    finite value a stopping rule could mistake for convergence."""
    s1 = np.array([0.5])
    s2 = np.array([0.3])
    for fn in (bernstein_halfwidth, normal_halfwidth):
        for tau in (0, 1):
            assert np.isinf(fn(s1 * tau, s2 * tau, tau, 0.01)).all()
        assert np.isfinite(fn(s1 * 2, s2 * 2, 2, 0.01)).all()


@settings(max_examples=60, deadline=None)
@given(st.floats(min_value=0.0, max_value=1.0),
       st.floats(min_value=0.0, max_value=0.25),
       st.integers(min_value=2, max_value=5000),
       st.floats(min_value=1e-6, max_value=0.5))
def test_bernstein_monotone_nonincreasing_in_tau(mean, var, tau, delta_v):
    """Maurer–Pontil with the unbiased sample variance: for a *fixed*
    empirical distribution (mean, variance held constant while τ grows)
    the halfwidth is monotone non-increasing in τ — more samples of the
    same data can never loosen the certificate. (The biased-variance
    variant this regression replaces satisfied it too, but silently
    understated V̂ by τ/(τ−1); the property pins the corrected form.)"""
    mean = float(np.clip(mean, 0.0, 1.0))
    var = float(min(var, mean * (1.0 - mean)))  # realizable on [0, 1]
    s2_rate = var + mean * mean

    def hw(t):
        return float(bernstein_halfwidth(
            np.array([mean * t]), np.array([s2_rate * t]), t, delta_v)[0])

    assert hw(tau + 1) <= hw(tau) + 1e-12
    assert hw(4 * tau) <= hw(tau) + 1e-12


def test_choose_sample_batch_respects_memory():
    # memory budget that only fits the smallest state
    nb = choose_sample_batch(4096, 32768, mem_bytes=4 * 4096 * 4096 + 2e6)
    assert nb in (16, 32, 64)
    # generous budget: dispatch amortization prefers larger batches
    nb_big = choose_sample_batch(4096, 32768, mem_bytes=64 * 2 ** 30)
    assert nb_big >= nb


# ---------------------------------------------------------------- estimator
def test_estimator_unbiased_on_full_sweep(small_rmat):
    """Feeding every source once reproduces exact λ (scale n/τ = 1)."""
    g, lam_ref = small_rmat
    from repro.core.adjacency import dense_adj_from_graph
    from repro.core.mfbc import mfbc_batch_moments
    import jax.numpy as jnp

    adj = dense_adj_from_graph(g)
    est = LambdaEstimator(g.n, eps=0.05, delta=0.1, rule="bernstein")
    nb = 32
    for b0 in range(0, g.n, nb):
        chunk = np.arange(b0, min(b0 + nb, g.n), dtype=np.int32)
        sources = np.zeros(nb, np.int32)
        sources[:chunk.shape[0]] = chunk
        valid = np.zeros(nb, bool)
        valid[:chunk.shape[0]] = True
        s1, s2, _ = mfbc_batch_moments(adj, jnp.asarray(sources),
                                       jnp.asarray(valid))
        est.update(np.asarray(s1, np.float64), np.asarray(s2, np.float64),
                   int(valid.sum()))
    res = est.result(n_epochs=1, converged=True)
    np.testing.assert_allclose(res.lam, lam_ref, rtol=1e-4, atol=1e-6)


def test_moments_first_moment_matches_mfbc_batch(small_rmat):
    g, _ = small_rmat
    from repro.core.adjacency import dense_adj_from_graph
    from repro.core.mfbc import mfbc_batch, mfbc_batch_moments
    import jax.numpy as jnp

    adj = dense_adj_from_graph(g)
    sources = jnp.asarray(np.arange(16, dtype=np.int32))
    valid = jnp.asarray(np.ones(16, bool))
    lam_b, _, _ = mfbc_batch(adj, sources, valid)
    s1, s2, _ = mfbc_batch_moments(adj, sources, valid)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(lam_b), rtol=1e-6)
    assert np.all(np.asarray(s2) >= 0)


# ---------------------------------------------------------------- end to end
def test_adaptive_converges_within_eps(small_rmat):
    """The headline guarantee: λ̂ within ε·n·(n−2) of Brandes, adaptively."""
    g, lam_ref = small_rmat
    eps = 0.05
    res = approx_bc(g, eps=eps, delta=0.1, rule="bernstein", seed=0)
    assert res.converged
    norm = g.n * (g.n - 2)
    assert np.abs(res.lam - lam_ref).max() / norm <= eps


def test_adaptive_normal_rule_converges_within_eps(small_rmat):
    g, lam_ref = small_rmat
    eps = 0.05
    res = approx_bc(g, eps=eps, delta=0.1, rule="normal", seed=0)
    assert res.converged
    norm = g.n * (g.n - 2)
    assert np.abs(res.lam - lam_ref).max() / norm <= eps
    # normal profile must not sample more than the rigorous one
    res_b = approx_bc(g, eps=eps, delta=0.1, rule="bernstein", seed=0)
    assert res.n_samples <= res_b.n_samples


def test_topk_precision(small_rmat):
    g, lam_ref = small_rmat
    k = 10
    res = approx_bc(g, eps=0.05, delta=0.1, rule="normal", topk=k, seed=0)
    top_ref = set(np.argsort(lam_ref)[::-1][:k].tolist())
    prec = len(top_ref & set(res.topk(k).tolist())) / k
    assert prec >= 0.9


def test_uniform_strategy_matches_budget(small_rmat):
    g, _ = small_rmat
    res = approx_bc(g, eps=0.1, delta=0.1, strategy="uniform", seed=3)
    assert res.n_samples == hoeffding_budget(g.n, 0.1, 0.1)
    assert res.converged


def test_structured_graph_ring_of_cliques():
    """Bridge vertices of a ring of cliques carry the centrality mass."""
    g = ring_of_cliques(6, 6)
    lam_ref = brandes_bc(g)
    res = approx_bc(g, eps=0.05, delta=0.1, rule="normal", seed=0)
    # bridges (one per clique) are the top-6; sampling must find them
    top_ref = set(np.argsort(lam_ref)[::-1][:6].tolist())
    assert set(res.topk(6).tolist()) == top_ref


def test_single_device_mesh_path(small_rmat):
    """The distributed epoch path on a 1x1 mesh equals the estimator run."""
    import jax
    from jax.sharding import Mesh

    g, lam_ref = small_rmat
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    res = approx_bc(g, eps=0.1, delta=0.2, mesh=mesh, iters=32,
                    strategy="uniform", max_samples=200, seed=0)
    assert res.n_samples == 200
    assert res.has_moments  # mesh batches now carry real (Σδ, Σδ²)
    # estimates correlate strongly with the oracle even at a small budget
    top_ref = set(np.argsort(lam_ref)[::-1][:5].tolist())
    assert len(top_ref & set(res.topk(5).tolist())) >= 4


def test_mesh_moments_match_single_host(small_rmat):
    """(Σδ, Σδ², n_reach) parity: 1x1 mesh step vs mfbc_batch_moments."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.core.adjacency import dense_adj_from_graph
    from repro.core.dist_bc import prepare_mesh_batch_step
    from repro.core.mfbc import mfbc_batch_moments

    g, _ = small_rmat
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    run, nb_pad = prepare_mesh_batch_step(g, mesh, nb=16, iters=32,
                                          moments=True)
    rng = np.random.default_rng(3)
    src = rng.integers(0, g.n, nb_pad).astype(np.int32)
    val = np.ones(nb_pad, bool)
    s1, s2, nr = run(src, val)
    adj = dense_adj_from_graph(g)
    r1, r2, rn = mfbc_batch_moments(adj, jnp.asarray(src), jnp.asarray(val))
    np.testing.assert_allclose(s1, np.asarray(r1, np.float64),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(s2, np.asarray(r2, np.float64),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(nr, np.asarray(rn))


def test_mesh_adaptive_stops_before_hoeffding_on_star():
    """The tentpole claim: mesh epochs stop adaptively, not at the budget.

    On a star graph every leaf source has the same dependency profile, so
    the empirical variance is tiny and Bernstein stopping certifies ε
    well before the variance-free Hoeffding budget — which is exactly
    what the mesh path could NOT do when its batch step returned only Σδ.
    """
    import jax
    from jax.sharding import Mesh

    g = star_graph(128)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    eps, delta = 0.05, 0.1
    res = approx_bc(g, eps=eps, delta=delta, rule="bernstein", n_b=64,
                    mesh=mesh, iters=8, seed=0)
    assert res.has_moments
    assert res.converged
    assert res.n_samples < hoeffding_budget(g.n, eps, delta)
    # the hub is unambiguously the top-1 vertex
    assert int(res.topk(1)[0]) == 0


@pytest.mark.slow
def test_multidevice_mesh_moments_subprocess():
    """Mesh (Σδ, Σδ²) == mfbc_batch_moments on 8 CPU devices."""
    script = os.path.join(os.path.dirname(__file__),
                          "md_distbc_moments_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout


# ---------------------------------------------------------------- serving
def test_bc_service_slot_scheduling(small_rmat):
    from repro.serve.bc_service import BCRequest, BCService

    g, lam_ref = small_rmat
    g2 = ring_of_cliques(5, 5)
    svc = BCService({"web": g, "ring": g2}, n_slots=2)
    svc.submit(BCRequest(rid=0, graph="web", k=10, rule="normal"))
    svc.submit(BCRequest(rid=1, graph="ring", k=5, rule="normal"))
    svc.submit(BCRequest(rid=2, graph="web", k=3, eps=0.2, rule="normal"))
    out = svc.run()
    assert sorted(r.rid for r in out) == [0, 1, 2]
    assert all(r.converged for r in out)
    by_rid = {r.rid: r for r in out}
    top_ref = set(np.argsort(lam_ref)[::-1][:10].tolist())
    assert len(top_ref & set(by_rid[0].topk)) >= 9
    lam2 = brandes_bc(g2)
    top2 = set(np.argsort(lam2)[::-1][:5].tolist())
    assert len(top2 & set(by_rid[1].topk)) >= 4


def test_bc_service_rejects_unknown_graph():
    from repro.serve.bc_service import BCRequest, BCService

    svc = BCService({}, n_slots=1)
    with pytest.raises(KeyError):
        svc.submit(BCRequest(rid=0, graph="nope"))


def test_bc_service_mesh_path(small_rmat):
    """Serving epochs through the distributed moments step (1x1 mesh)."""
    import jax
    from jax.sharding import Mesh

    from repro.serve.bc_service import BCRequest, BCService

    g, lam_ref = small_rmat
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    svc = BCService({"web": g}, n_slots=1, mesh=mesh, iters=32)
    svc.submit(BCRequest(rid=0, graph="web", k=5, rule="normal"))
    out = svc.run()
    assert len(out) == 1 and out[0].converged
    top_ref = set(np.argsort(lam_ref)[::-1][:5].tolist())
    assert len(top_ref & set(out[0].topk)) >= 4
