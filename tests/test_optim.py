"""Optimizer unit tests: schedules, clipping, f32 vs int8 moments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw


def _toy():
    rng = np.random.default_rng(0)
    Wt = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    X = jnp.asarray(rng.normal(size=(256, 16)).astype(np.float32))
    return X, X @ Wt


def _train(md, steps=200, lr=3e-2):
    X, Y = _toy()
    params = {"w": jnp.zeros((16, 8))}
    cfg = adamw.AdamWConfig(lr=lr, warmup_steps=1, total_steps=steps,
                            weight_decay=0.0, moment_dtype=md)
    state = adamw.init_state(params, md)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean((X @ p["w"] - Y) ** 2))(params)
        params, state, m = adamw.update(cfg, g, state, params)
        return params, state, loss

    loss = None
    for _ in range(steps):
        params, state, loss = step(params, state)
    return float(loss)


def test_adamw_converges_f32():
    assert _train("f32") < 1e-4


def test_adamw_converges_int8_moments():
    """8-bit-m / bf16-v moments must match f32 convergence on a toy task."""
    assert _train("int8") < 1e-3


def test_int8_state_is_smaller():
    params = {"w": jnp.zeros((64, 64))}
    s32 = adamw.init_state(params, "f32")
    s8 = adamw.init_state(params, "int8")

    def nbytes(t):
        return sum(np.asarray(l).nbytes for l in jax.tree.leaves(t))

    assert nbytes(s8) < 0.5 * nbytes(s32)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in
           (0, 9, 10, 50, 99)]
    assert lrs[0] < lrs[1] <= lrs[2]  # warmup rises
    assert lrs[2] > lrs[3] > lrs[4]  # cosine decays
    assert lrs[4] >= 0.1 * 0.99  # floor


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
