"""Multi-device chunked-ingest check (8 CPU devices, subprocess).

Pins the out-of-core loading contract end to end:

* a ``MeshBCContext`` built from ``GraphStats`` alone comes up with no
  adjacency resident and refuses to run until one is streamed in;
* ``build_sharded_adjacency`` fed chunked file reads produces **bitwise**
  the same per-batch BC output as the eager in-memory upload, for every
  chunking;
* both match the single-host reference solver.
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np
import jax

from repro.core.brandes_ref import brandes_bc
from repro.core.dist_bc import MeshBCContext
from repro.graphs.formats import (EdgeListReader, build_sharded_adjacency,
                                  load_graph, write_binary_coo,
                                  write_edge_list)
from repro.graphs.generators import erdos_renyi


def batch(ctx, g):
    sources = np.arange(g.n, dtype=np.int32)
    valid = np.ones(sources.shape[0], dtype=bool)
    return ctx.run_sum(sources, valid, nb=g.n)


def main():
    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

    g = erdos_renyi(40, 0.15, seed=7, weighted=True, max_weight=9)
    with tempfile.TemporaryDirectory() as tmp:
        path_rcoo = write_binary_coo(os.path.join(tmp, "g.rcoo.gz"), g)
        path_txt = write_edge_list(os.path.join(tmp, "g.txt"), g)

        # ingest parity: chunked file load == in-memory graph
        ing = load_graph(path_rcoo, chunk_edges=13, remove_isolated=False)
        ref = g.dedup()
        assert ing.graph.n == ref.n
        assert np.array_equal(ing.graph.src, ref.src)
        assert np.array_equal(ing.graph.dst, ref.dst)
        assert np.array_equal(ing.graph.w, ref.w)
        print(f"ok: chunked rcoo ingest bitwise == in-memory "
              f"({ing.n_chunks} chunks, digest {ing.digest[:12]})")

        # stats-only context refuses to run before an upload
        ctx = MeshBCContext(ing.stats, mesh, iters=g.n)
        try:
            batch(ctx, g)
        except RuntimeError as e:
            assert "no adjacency resident" in str(e)
            print("ok: stats-only context guards against missing adjacency")
        else:
            raise AssertionError("stats-only context ran without adjacency")

        # streamed shard upload == eager upload, bitwise, for any chunking
        eager = MeshBCContext(g, mesh, iters=g.n)
        lam_ref = batch(eager, g)
        for chunk_edges in (1, 7, 10_000):
            reader = EdgeListReader(path_txt, chunk_edges=chunk_edges)
            build_sharded_adjacency(reader, ctx)
            lam = batch(ctx, g)
            assert np.array_equal(lam, lam_ref), \
                f"streamed != eager at chunk_edges={chunk_edges}"
            print(f"ok: streamed upload bitwise == eager "
                  f"(chunk_edges={chunk_edges})")

    np.testing.assert_allclose(lam_ref[:g.n], brandes_bc(g),
                               rtol=1e-4, atol=1e-6)
    print("ok: mesh BC matches single-host Brandes")
    print("ALL-OK")


if __name__ == "__main__":
    main()
