"""Dry-run machinery tests.

``test_dryrun_one_cell_subprocess`` actually builds the 512-device
production mesh in a subprocess and lowers+compiles one small cell per
family — validating the full pipeline pytest-side. The full 84-cell sweep
runs via ``python -m repro.launch.dryrun --all --mesh both`` and its
results are validated by ``test_dryrun_results_complete`` (skipped if the
sweep has not been run).
"""
import glob
import json
import os
import subprocess
import sys

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("gcn-cora", "molecule"),
    ("xdeepfm", "serve_p99"),
])
def test_dryrun_one_cell_subprocess(arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "multi", "--out",
         os.path.join(os.path.dirname(__file__), "..", "results",
                      "dryrun_test")],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


def test_dryrun_results_complete():
    files = glob.glob(os.path.join(RESULTS, "*.json"))
    if len(files) < 84:
        pytest.skip(f"full sweep not present ({len(files)}/84 cells)")
    bad = []
    for p in files:
        rec = json.load(open(p))
        if not rec.get("ok"):
            bad.append(p)
            continue
        mem = (rec["memory"]["argument_bytes"]
               + rec["memory"]["peak_bytes"]) / 2 ** 30
        if mem > 16.0:
            bad.append((os.path.basename(p), f"{mem:.1f} GiB"))
        if rec["flops_per_device"] <= 0:
            bad.append((os.path.basename(p), "no flops"))
    assert not bad, bad


def test_roofline_analysis_runs():
    files = glob.glob(os.path.join(RESULTS, "*.json"))
    if not files:
        pytest.skip("no dry-run results yet")
    from repro.roofline.analysis import analyze_record, load_all

    rows = [analyze_record(r) for r in load_all(RESULTS)]
    assert all(r["t_step_s"] > 0 for r in rows)
    assert all(r["dominant"] in ("compute", "memory", "collective")
               for r in rows)


@pytest.mark.slow
def test_distributed_lm_training_equivalence_subprocess():
    """FSDP+TP sharded train step == single-device numerics (8 devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = os.path.join(os.path.dirname(__file__), "md_lm_dist_check.py")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout


@pytest.mark.slow
def test_gnn_2d_partition_equivalence_subprocess():
    """2D edge-partitioned GCN (hillclimb A) == reference on 8 devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = os.path.join(os.path.dirname(__file__), "md_gnn2d_check.py")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout
