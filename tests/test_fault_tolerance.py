"""Checkpointing, restart, elasticity, straggler mitigation, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import LMDataConfig, LMPipeline
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.grad_compress import (CompressConfig, compress,
                                       compression_ratio, init_error)
from repro.train import checkpoint as ckpt
from repro.train.fault import (BackupTaskPolicy, ChaosConfig, Supervisor,
                               WorkerFailure)
from repro.train.train_lib import make_lm_train_step

CFG = T.TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                          n_kv=2, d_ff=64, vocab=128, head_dim=8)
OPT = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)


def _pipeline():
    return LMPipeline(LMDataConfig(vocab=128, batch=2, seq=16, seed=7))


def test_checkpoint_roundtrip(tmp_path):
    init_fn, _ = make_lm_train_step(CFG, OPT)
    state = init_fn(jax.random.key(0))
    ckpt.save(str(tmp_path), 5, state)
    restored, step = ckpt.restore(str(tmp_path), like=state)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_retention(tmp_path):
    init_fn, _ = make_lm_train_step(CFG, OPT)
    state = init_fn(jax.random.key(0))
    for s in range(6):
        ckpt.save(str(tmp_path), s, state, keep=3)
    assert sorted(ckpt.all_steps(str(tmp_path))) == [3, 4, 5]
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restart_is_bit_exact(tmp_path):
    """Training with injected failures reaches the same state as without."""
    pipe = _pipeline()
    init_fn, step_fn = make_lm_train_step(CFG, OPT)

    def run(ckpt_dir, chaos):
        state = init_fn(jax.random.key(1))

        def do_step(st, step):
            st, _ = step_fn(st, pipe.batch(step))
            return st

        sup = Supervisor(ckpt_dir, save_every=3, keep=5)
        return sup.run(init_state=state, step_fn=do_step, n_steps=10,
                       chaos=chaos)

    clean = run(str(tmp_path / "a"), None)
    log = []
    chaotic_state = None
    chaos = ChaosConfig(fail_at_steps=(4, 8))
    chaotic_state = run(str(tmp_path / "b"), chaos)
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(chaotic_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint saved under one sharding restores onto another mesh."""
    init_fn, _ = make_lm_train_step(CFG, OPT)
    state = init_fn(jax.random.key(2))
    ckpt.save(str(tmp_path), 0, state)
    # target: same tree, explicitly device_put onto the (single) device with
    # a different layout request — on 1 CPU device this degenerates, so the
    # real multi-mesh version is covered by the subprocess test below; here
    # we check the `like=abstract` path (ShapeDtypeStruct targets).
    abstract = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored, _ = ckpt.restore(str(tmp_path), like=abstract)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    sup = Supervisor(str(tmp_path), save_every=100, max_restarts=2)

    def always_fail(st, step):
        raise WorkerFailure("boom")

    with pytest.raises(WorkerFailure):
        sup.run(init_state={"x": jnp.zeros(1)}, step_fn=always_fail,
                n_steps=5)


def test_straggler_backup_policy():
    lat = {0: 0.01, 1: 0.01, 2: 0.01, 3: 0.5}
    pol = BackupTaskPolicy(n_producers=4, threshold=3.0)
    for _ in range(5):
        for p, l in lat.items():
            pol.observe(p, l)
    assert pol.stragglers() == [3]
    calls = {p: 0 for p in lat}

    def mk(p):
        def fn():
            calls[p] += 1
            return p
        return fn

    out = pol.fetch({p: mk(p) for p in lat})
    assert out == {0: 0, 1: 1, 2: 2, 3: 3}
    assert calls[3] == 2  # straggler got a backup task
    assert calls[0] == 1


@pytest.mark.parametrize("kind", ["topk", "int8"])
def test_grad_compression_error_feedback(kind):
    """Compression + error feedback preserves the gradient in total."""
    cfg = CompressConfig(kind=kind, topk_ratio=0.25)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    err = init_error(g)
    # accumulate decompressed payloads; with error feedback the sum of
    # what was sent converges to the sum of true gradients
    sent_total = jnp.zeros((64, 64))
    true_total = jnp.zeros((64, 64))
    for _ in range(30):
        dense, err, wire = compress(cfg, g, err)
        sent_total = sent_total + dense["w"]
        true_total = true_total + g["w"]
        assert wire < 64 * 64 * 4 or kind == "topk"
    resid = jnp.abs(sent_total - true_total).max()
    scale = jnp.abs(true_total).max()
    assert float(resid / scale) < 0.1, float(resid / scale)
    assert compression_ratio(cfg, g) < 1.0


def test_pipeline_determinism():
    p1 = _pipeline()
    p2 = _pipeline()
    b1 = p1.batch(17)
    b2 = p2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(17)["tokens"], p1.batch(18)["tokens"])


@pytest.mark.slow
def test_elastic_reshard_across_meshes_subprocess():
    """Save on a (4,2) mesh, restore onto (2,2,2) — real device resharding."""
    import subprocess
    import sys

    script = os.path.join(os.path.dirname(__file__), "md_elastic_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout
