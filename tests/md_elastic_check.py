"""Elastic resharding across real multi-device meshes (subprocess)."""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import sys
import tempfile

import jax
import numpy as np

from repro.models import transformer as T
from repro.optim import adamw
from repro.sharding.rules import make_policy
from repro.train import checkpoint as ckpt

CFG = T.TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                          n_kv=2, d_ff=64, vocab=128, head_dim=8)


def abstract_state(policy):
    ap = T.abstract_params(CFG, policy)
    return {"params": ap, "opt": adamw.abstract_state(ap)}


def main():
    assert len(jax.devices()) == 8
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    mesh_b = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    pol_a = make_policy(mesh_a)
    pol_b = make_policy(mesh_b)

    params = T.init_params(CFG, jax.random.key(0))
    sharded = jax.tree.map(
        lambda a, s: jax.device_put(a, s.sharding) if hasattr(s, "sharding")
        and s.sharding is not None else a,
        {"params": params, "opt": adamw.init_state(params)},
        abstract_state(pol_a))

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, sharded)
        # restore onto the *multi-pod* mesh (elastic scale-up 8 -> 8 devices
        # but different topology: (4,2) -> (2,2,2))
        restored, step = ckpt.restore(d, like=abstract_state(pol_b))
        assert step == 3
        for a, b in zip(jax.tree.leaves(sharded), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored leaves actually live on the new mesh
        emb = restored["params"]["embedding"]
        assert emb.sharding.mesh.axis_names == ("pod", "data", "model"), \
            emb.sharding
    print("ALL-OK")


if __name__ == "__main__":
    main()
