"""Chunked out-of-core ingest parity wall (``repro.graphs.formats``).

The contract under test: every streaming path — arbitrary chunk sizes,
arbitrary arc order, any on-disk format — produces a ``Graph`` whose
arrays are **bitwise identical** to the in-memory pipeline
(``Graph(...).dedup()`` [+ ``symmetrize`` / ``remove_isolated``]), and
the content digest computed during the streaming pass equals
``graph_digest`` of the result. The multi-device shard-streaming side
(``build_sharded_adjacency`` into a stats-only ``MeshBCContext``) is
pinned here on one device and on 8 devices in the slow-lane subprocess
check ``md_ingest_check.py``.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.bc import BCQuery
from repro.bc import plan as bc_plan
from repro.graphs.formats import (ChunkedCSRBuilder, EdgeListReader, Graph,
                                  GraphStats, as_coo_chunks,
                                  build_sharded_adjacency, coo_to_dense,
                                  graph_digest, load_graph, write_binary_coo,
                                  write_edge_list)
from repro.graphs.generators import erdos_renyi, rmat


def make_raw(n=60, nnz=400, seed=3, weighted=True):
    """A raw arc stream with duplicates and self loops (pre-canonical)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, nnz).astype(np.int32)
    dst = rng.integers(0, n, nnz).astype(np.int32)
    w = (rng.random(nnz).astype(np.float32) + 0.25 if weighted
         else np.ones(nnz, np.float32))
    return n, src, dst, w


def reference(n, src, dst, w, *, symmetrize, remove_isolated):
    g = Graph(n, src, dst, w)
    g = g.symmetrize() if symmetrize else g.dedup()
    kept = None
    if remove_isolated:
        g, kept = g.remove_isolated()
    return g, kept


def assert_graphs_bitwise(a: Graph, b: Graph):
    assert a.n == b.n
    assert a.directed == b.directed
    np.testing.assert_array_equal(a.src, b.src)
    np.testing.assert_array_equal(a.dst, b.dst)
    np.testing.assert_array_equal(a.w, b.w)


def chunked(src, dst, w, size):
    for lo in range(0, src.shape[0], size):
        yield src[lo:lo + size], dst[lo:lo + size], w[lo:lo + size]


# ------------------------------------------------------------- builder parity
@pytest.mark.parametrize("chunk", [1, 7, 64, 10_000])
@pytest.mark.parametrize("symmetrize", [False, True])
@pytest.mark.parametrize("remove_isolated", [False, True])
def test_builder_bitwise_parity(chunk, symmetrize, remove_isolated):
    n, src, dst, w = make_raw()
    ref, kept_ref = reference(n, src, dst, w, symmetrize=symmetrize,
                              remove_isolated=remove_isolated)
    b = ChunkedCSRBuilder(n, symmetrize=symmetrize,
                          remove_isolated=remove_isolated)
    res = b.add_chunks(chunked(src, dst, w, chunk)).finalize()
    assert_graphs_bitwise(res.graph, ref)
    if remove_isolated:
        np.testing.assert_array_equal(res.kept, kept_ref)
    assert res.digest == graph_digest(res.graph)
    assert res.edges_read == src.shape[0]


def test_builder_order_independence():
    """Streaming dedup must not depend on arrival order or chunking."""
    n, src, dst, w = make_raw(seed=11)
    results = []
    for seed, chunk in ((0, 1), (1, 5), (2, 50), (3, 10_000)):
        order = np.random.default_rng(seed).permutation(src.shape[0])
        b = ChunkedCSRBuilder(n)
        res = b.add_chunks(chunked(src[order], dst[order], w[order],
                                   chunk)).finalize()
        results.append(res)
    for res in results[1:]:
        assert_graphs_bitwise(res.graph, results[0].graph)
        assert res.digest == results[0].digest


def test_builder_small_buffer_compaction():
    """Run compaction kicks in mid-stream without changing the result."""
    n, src, dst, w = make_raw()
    ref, _ = reference(n, src, dst, w, symmetrize=False,
                       remove_isolated=False)
    b = ChunkedCSRBuilder(n, buffer_edges=16)  # forces repeated compaction
    res = b.add_chunks(chunked(src, dst, w, 9)).finalize()
    assert_graphs_bitwise(res.graph, ref)


def test_builder_min_weight_dedup():
    """Duplicate (src, dst) pairs keep the minimum weight, bitwise."""
    src = np.array([0, 0, 0, 1], np.int32)
    dst = np.array([1, 1, 1, 2], np.int32)
    w = np.array([3.0, 1.5, 2.0, 1.0], np.float32)
    res = ChunkedCSRBuilder(3).add_chunks(chunked(src, dst, w, 1)).finalize()
    assert_graphs_bitwise(res.graph, Graph(3, src, dst, w).dedup())
    assert res.graph.w[0] == np.float32(1.5)


def test_builder_errors():
    b = ChunkedCSRBuilder(4)
    with pytest.raises(ValueError, match="negative"):
        b.add(np.array([-1], np.int32), np.array([0], np.int32))
    with pytest.raises(ValueError, match="out of range"):
        b.add(np.array([0], np.int32), np.array([7], np.int32))
    with pytest.raises(ValueError, match="shape"):
        b.add(np.array([0], np.int32), np.array([1, 2], np.int32))
    b.finalize()
    with pytest.raises(RuntimeError, match="finalized"):
        b.add(np.array([0], np.int32), np.array([1], np.int32))


def test_builder_empty():
    res = ChunkedCSRBuilder(5).finalize()
    assert res.graph.n == 5 and res.graph.nnz == 0
    assert res.digest == graph_digest(res.graph)


# -------------------------------------------------------- file round-trips
@pytest.mark.parametrize("suffix", ["txt", "txt.gz", "rcoo", "rcoo.gz"])
@pytest.mark.parametrize("chunk_edges", [1, 37, 1_000_000])
def test_file_round_trip_bitwise(tmp_path, suffix, chunk_edges):
    g = erdos_renyi(48, 0.12, seed=5, weighted=True, max_weight=9).dedup()
    path = str(tmp_path / f"g.{suffix}")
    if suffix.startswith("txt"):
        write_edge_list(path, g)
    else:
        write_binary_coo(path, g)
    res = load_graph(path, chunk_edges=chunk_edges, remove_isolated=False)
    # both formats declare n and directedness (RCOO header / text comment),
    # so the round trip is the identity — including trailing isolated ids
    assert_graphs_bitwise(res.graph, g)
    assert res.digest == graph_digest(g)


def test_text_unweighted_round_trip(tmp_path):
    g = erdos_renyi(30, 0.15, seed=9, weighted=False).dedup()
    path = write_edge_list(str(tmp_path / "g.txt"), g)
    # unweighted graphs serialize as two columns
    body = [ln for ln in open(path).read().splitlines()
            if not ln.startswith("#")]
    assert all(len(ln.split()) == 2 for ln in body)
    res = load_graph(path, n=g.n, remove_isolated=False)
    assert_graphs_bitwise(res.graph, g)


def test_float32_weights_survive_text_exactly(tmp_path):
    """%.9g: arbitrary float32 weights round-trip through text bitwise."""
    rng = np.random.default_rng(0)
    w = rng.random(200).astype(np.float32) * np.float32(1e-3)
    src = np.arange(200, dtype=np.int32) % 20
    dst = (np.arange(200, dtype=np.int32) + 1) % 20
    g = Graph(20, src, dst, w).dedup()
    path = write_edge_list(str(tmp_path / "w.txt"), g)
    res = load_graph(path, n=20, remove_isolated=False)
    np.testing.assert_array_equal(res.graph.w, g.w)


def test_rcoo_header_and_truncation(tmp_path):
    g = erdos_renyi(25, 0.2, seed=2, weighted=True).dedup()
    path = write_binary_coo(str(tmp_path / "g.rcoo"), g)
    reader = EdgeListReader(path)
    list(reader.chunks())
    assert reader.header_n == g.n  # n travels in the header, ids don't fix it
    assert reader.header_directed == g.directed

    data = open(path, "rb").read()
    bad = tmp_path / "trunc.rcoo"
    bad.write_bytes(data[:-5])
    with pytest.raises(ValueError, match="truncated"):
        list(EdgeListReader(str(bad)).chunks())
    notmagic = tmp_path / "bad.rcoo"
    notmagic.write_bytes(b"XXXX" + data[4:])
    with pytest.raises(ValueError, match="magic"):
        list(EdgeListReader(str(notmagic)).chunks())


def test_reader_restartable(tmp_path):
    g = erdos_renyi(20, 0.2, seed=4).dedup()
    reader = EdgeListReader(write_edge_list(str(tmp_path / "g.txt"), g),
                            chunk_edges=5)
    first = [tuple(map(np.copy, c)) for c in reader.chunks()]
    second = list(reader.chunks())  # a fresh pass, not a spent iterator
    assert len(first) == len(second) > 1
    for (s1, d1, w1), (s2, d2, w2) in zip(first, second):
        np.testing.assert_array_equal(s1, s2)
        np.testing.assert_array_equal(d1, d2)
        np.testing.assert_array_equal(w1, w2)


def test_load_graph_pinned_n_and_isolated(tmp_path):
    # vertex 6 of 10 is referenced; pinned n keeps the rest, the
    # remove_isolated pass compacts them away and reports the kept ids
    src = np.array([0, 2, 4], np.int32)
    dst = np.array([2, 4, 6], np.int32)
    g = Graph(10, src, dst, np.ones(3, np.float32))
    path = write_edge_list(str(tmp_path / "g.txt"), g)
    res = load_graph(path, n=10, remove_isolated=False)
    assert res.graph.n == 10
    res = load_graph(path, n=10, remove_isolated=True)
    assert res.graph.n == 4
    np.testing.assert_array_equal(res.kept, [0, 2, 4, 6])
    with pytest.raises(ValueError, match="out of range"):
        load_graph(path, n=5)


# ------------------------------------------------- stats / planner / digest
def test_graph_digest_canonical():
    n, src, dst, w = make_raw(seed=21)
    g = Graph(n, src, dst, w)
    # digest is over the canonical (deduped) form: raw == deduped
    assert graph_digest(g) == graph_digest(g.dedup())
    g2 = Graph(n, src, dst, w + np.float32(0.5))
    assert graph_digest(g) != graph_digest(g2)


def test_graph_stats_plans_without_arrays():
    """The planner consumes GraphStats — no edge arrays needed to plan."""
    g = rmat(10, 8, seed=7).dedup()
    stats = GraphStats.from_graph(g)
    assert (stats.n, stats.m) == (g.n, g.m)
    q = BCQuery(mode="approx", strategy="uniform", max_samples=64)
    p_stats = bc_plan(stats, q, n_devices=1)
    p_graph = bc_plan(g, q, n_devices=1)
    js, jg = p_stats.to_json(), p_graph.to_json()
    for key in ("placement", "n_b", "backend", "regime"):
        assert js[key] == jg[key], key


def test_as_coo_chunks_normalizes(tmp_path):
    g = erdos_renyi(16, 0.25, seed=1).dedup()
    res = ChunkedCSRBuilder(g.n).add_chunks([(g.src, g.dst, g.w)]).finalize()
    reader = EdgeListReader(write_edge_list(str(tmp_path / "g.txt"), g))
    for source in (g, res, reader, [(g.src, g.dst, g.w)]):
        chunks = list(as_coo_chunks(source))
        src = np.concatenate([c[0] for c in chunks])
        dst = np.concatenate([c[1] for c in chunks])
        w = np.concatenate([c[2] for c in chunks])
        assert_graphs_bitwise(Graph(g.n, src, dst, w,
                                    directed=g.directed).dedup(), g)


# ------------------------------------- sharded streaming (single device)
def test_build_sharded_adjacency_single_device(tmp_path):
    """Streamed shard upload == eager upload, bitwise, on a 1x1 mesh."""
    import jax

    from repro.core.dist_bc import MeshBCContext

    g = erdos_renyi(24, 0.2, seed=13, weighted=True, max_weight=5).dedup()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eager = MeshBCContext(g, mesh, iters=g.n)
    sources = np.arange(g.n, dtype=np.int32)
    valid = np.ones(g.n, dtype=bool)
    lam_ref = eager.run_sum(sources, valid, nb=g.n)

    ctx = MeshBCContext(GraphStats.from_graph(g), mesh, iters=g.n)
    with pytest.raises(RuntimeError, match="no adjacency resident"):
        ctx.run_sum(sources, valid, nb=g.n)
    reader = EdgeListReader(write_edge_list(str(tmp_path / "g.txt"), g),
                            chunk_edges=3)
    build_sharded_adjacency(reader, ctx)
    lam = ctx.run_sum(sources, valid, nb=g.n)
    np.testing.assert_array_equal(lam, lam_ref)
    # the streamed dense adjacency is bitwise coo_to_dense (+ inf diag)
    a_perm = np.asarray(ctx._a_dev)[:g.n]  # n_pad == n here, perm applies
    a = np.empty_like(a_perm)
    a[ctx.perm] = a_perm
    np.testing.assert_array_equal(a[:g.n, :g.n], coo_to_dense(g))


def test_upload_rejects_out_of_range(tmp_path):
    import jax

    from repro.core.dist_bc import MeshBCContext

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshBCContext(GraphStats(n=4, m=1), mesh, iters=4)
    with pytest.raises(ValueError, match="out of range"):
        ctx.upload_coo_chunks([(np.array([0]), np.array([9]),
                                np.array([1.0], np.float32))])


# ------------------------------------------------------------ multi-device
@pytest.mark.slow
def test_multidevice_ingest_subprocess():
    """8 visible devices: streamed shard upload parity (subprocess)."""
    script = os.path.join(os.path.dirname(__file__), "md_ingest_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout
