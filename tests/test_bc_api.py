"""Unified ``repro.bc`` solver API: planner decisions, BCPlan contents,
exact-vs-approx parity through both executors, and the deprecation shims.

The multi-device half of the planner contract (8 visible devices → mesh
placement, auto-built MeshExecutor, mesh-vs-host parity) runs in a
subprocess: ``md_bc_planner_check.py``, alongside the moments check.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic sweep, see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.bc import (Backend, BCPlanner, BCQuery, ExecutionConfig,
                      MeshExecutor, SingleHostExecutor, backend_spec,
                      build_executor, plan, registered_backends, solve)
from repro.core import brandes_bc
from repro.graphs.generators import from_spec, ring_of_cliques
from repro.spgemm.cost_model import Calibration, StepRates


@pytest.fixture(scope="module")
def small_graph():
    g = from_spec("rmat", scale=6, degree=8, seed=5)
    g, _ = g.remove_isolated()
    return g, brandes_bc(g)


def _mesh_1x1():
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


# ---------------------------------------------------------------- planner
def test_planner_single_host_on_one_device(small_graph):
    g, _ = small_graph
    pl = BCPlanner().plan(g, BCQuery(mode="approx"), n_devices=1)
    assert pl.placement == "single_host"
    assert pl.mesh_axes is None and pl.n_devices == 1
    assert pl.predicted_comm_bytes == 0.0  # no collectives on one host
    assert pl.backend in ("dense", "coo", "csr") and pl.n_b >= 1


def test_planner_mesh_on_eight_devices(small_graph):
    """The §6.2 search picks a (pod, data, model) decomposition for p=8."""
    g, _ = small_graph
    pl = BCPlanner().plan(g, BCQuery(mode="exact"), n_devices=8)
    assert pl.placement == "mesh"
    axes = pl.axes_dict()
    assert axes == {"pod": 2, "data": 2, "model": 2}
    assert pl.backend == "dense"  # the distributed step is dense-only
    assert pl.predicted_comm_bytes > 0.0
    assert pl.predicted_mem_bytes < BCPlanner().plan(
        g, BCQuery(mode="exact"), n_devices=1).predicted_mem_bytes


def test_planner_respects_overrides_and_budget(small_graph):
    g, _ = small_graph
    pl = BCPlanner().plan(
        g, BCQuery(mode="approx", n_b=16,
                   execution=ExecutionConfig(backend="coo")),
        n_devices=1)
    assert pl.n_b == 16 and pl.backend == "coo"
    assert pl.execution.resolved and pl.execution.backend is Backend.COO
    # a pinned COO backend has no distributed step: auto-placement must
    # stay on one host even with devices available — and never silently:
    # the fallback is warned and carried on plan.notes
    with pytest.warns(UserWarning, match="no distributed step"):
        pl8 = BCPlanner().plan(
            g, BCQuery(mode="approx",
                       execution=ExecutionConfig(backend=Backend.COO)),
            n_devices=8)
    assert pl8.placement == "single_host"
    assert any("falling back to single_host" in n for n in pl8.notes)
    assert pl8.to_json()["notes"] == list(pl8.notes)
    # ... but an explicit mesh pin with COO is a hard error, not a fallback
    with pytest.raises(ValueError, match="single-host only"):
        BCPlanner().plan(
            g, BCQuery(mode="approx",
                       execution=ExecutionConfig(backend="coo",
                                                 placement="mesh")),
            n_devices=8)
    # exact budget is the full sweep; approx budget is the Hoeffding cap
    e = BCPlanner().plan(g, BCQuery(mode="exact"), n_devices=1)
    a = BCPlanner().plan(g, BCQuery(mode="approx", eps=0.1, delta=0.1,
                                    max_samples=50), n_devices=1)
    assert e.sample_budget == g.n
    assert a.sample_budget == 50
    assert e.n_batches == -(-g.n // e.n_b)


def test_plan_is_json_serializable(small_graph):
    g, _ = small_graph
    pl = plan(g, BCQuery(mode="approx", topk=5), n_devices=8)
    d = json.loads(json.dumps(pl.to_json()))
    assert d["placement"] == "mesh"
    assert d["mesh_axes"] == {"pod": 2, "data": 2, "model": 2}
    assert d["regime"]["regime"] in ("dense", "coo", "csr")
    assert "single_host" in pl.summary() or "mesh" in pl.summary()


def test_query_validation():
    with pytest.raises(ValueError):
        BCQuery(mode="both")
    with pytest.raises(ValueError):
        BCQuery(mode="approx", eps=0.0)
    with pytest.raises(ValueError):
        BCQuery(rule="gaussian")
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            BCQuery(backend="hyper")
    with pytest.raises(ValueError):
        ExecutionConfig(backend="hyper")
    with pytest.raises(ValueError):
        ExecutionConfig(placement="cluster")
    with pytest.raises(ValueError, match="conflicting"):
        BCQuery(execution=ExecutionConfig(backend="coo"), backend="dense")


def test_legacy_kwargs_shim_matches_execution_config(small_graph):
    """The stringly-typed (backend, use_kernel, block) kwargs warn and
    resolve to the exact plan the typed ExecutionConfig produces."""
    g, _ = small_graph
    with pytest.warns(DeprecationWarning, match="ExecutionConfig"):
        q_old = BCQuery(mode="approx", backend="coo", use_kernel=False,
                        block=256)
    q_new = BCQuery(mode="approx",
                    execution=ExecutionConfig(backend="coo",
                                              use_kernel=False, block=256))
    assert q_old.execution == q_new.execution
    assert q_old.backend is Backend.COO and q_old.block == 256
    pl_old = BCPlanner().plan(g, q_old, n_devices=1)
    pl_new = BCPlanner().plan(g, q_new, n_devices=1)
    assert pl_old == pl_new
    # round-trips (dataclasses.replace re-passes the mirrored fields
    # next to execution=) stay silent
    import dataclasses as _dc
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        q2 = _dc.replace(q_new, n_b=32)
    assert q2.execution == q_new.execution and q2.n_b == 32


def test_backend_registry():
    assert set(registered_backends()) == {Backend.DENSE, Backend.COO,
                                          Backend.CSR}
    assert backend_spec("dense").placements == ("single_host", "mesh")
    assert backend_spec(Backend.COO).placements == ("single_host",)
    assert backend_spec("csr").placements == ("single_host",)
    assert backend_spec("dense").supports_kernel
    assert not backend_spec("coo").supports_kernel
    assert not backend_spec("csr").supports_kernel
    with pytest.raises(ValueError):
        backend_spec("hyper")


# ------------------------------------------------------------- executors
def test_build_executor_matches_plan(small_graph):
    g, _ = small_graph
    ex = build_executor(g, plan(g, BCQuery(), n_devices=1))
    assert isinstance(ex, SingleHostExecutor)
    mesh = _mesh_1x1()
    exm = build_executor(g, plan(g, BCQuery(n_b=16, iters=32), mesh=mesh),
                         mesh=mesh)
    assert isinstance(exm, MeshExecutor)
    # the shared protocol: same (S1, S2, n_reach) from identical batches
    rng = np.random.default_rng(0)
    src = rng.integers(0, g.n, 16).astype(np.int32)
    val = np.ones(16, bool)
    s1a, s2a, nra = ex.step(src, val)
    s1b, s2b, nrb = exm.step(src, val)
    np.testing.assert_allclose(s1a, s1b, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(s2a, s2b, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(nra, np.asarray(nrb))
    # the Σδ-only exact reduction agrees with the moments S1 on both
    np.testing.assert_allclose(ex.step_sum(src, val), s1a,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(exm.step_sum(src, val), s1b,
                               rtol=1e-4, atol=1e-6)


def test_executor_rejects_oversized_batch(small_graph):
    """step() must never silently truncate a too-large batch."""
    g, _ = small_graph
    ex = build_executor(g, plan(g, BCQuery(mode="exact", n_b=16),
                                n_devices=1))
    with pytest.raises(ValueError, match="exceeds"):
        ex.step(np.arange(17, dtype=np.int32), np.ones(17, bool))


# ------------------------------------------------------ solve: both modes
def test_exact_solve_single_host_matches_oracle(small_graph):
    g, ref = small_graph
    res = solve(g, BCQuery(mode="exact"))
    np.testing.assert_allclose(res.lam, ref, rtol=1e-4, atol=1e-6)
    assert res.converged and res.approx is None
    assert res.n_samples == g.n


def test_exact_solve_mesh_matches_oracle(small_graph):
    g, ref = small_graph
    res = solve(g, BCQuery(mode="exact", n_b=16, iters=32), mesh=_mesh_1x1())
    np.testing.assert_allclose(res.lam, ref, rtol=1e-4, atol=1e-6)
    assert res.plan.placement == "mesh"


def test_exact_solve_restricted_sources(small_graph):
    """The checkpoint-resume hook: a partial sweep is a partial λ sum."""
    g, ref = small_graph
    q = BCQuery(mode="exact", n_b=16)
    head = solve(g, q, sources=np.arange(16, dtype=np.int32))
    tail = solve(g, q, sources=np.arange(16, g.n, dtype=np.int32))
    np.testing.assert_allclose(head.lam + tail.lam, ref,
                               rtol=1e-4, atol=1e-6)
    # n_samples reports what was actually swept, not the full budget
    assert head.n_samples == 16 and tail.n_samples == g.n - 16


def test_bc_run_checkpoint_resume(tmp_path):
    """CLI resume: cumulative λ checkpoints + persisted nb survive a kill."""
    import shutil

    from repro.launch import bc_run
    from repro.train import checkpoint as ckpt_lib

    ck = str(tmp_path / "ck")
    args = ["--graph", "rmat", "--scale", "5", "--nb", "8",
            "--ckpt-dir", ck, "--verify"]
    bc_run.main(args)  # full run; saves cumulative λ at global steps
    # simulate a kill after global batch 1: drop the later checkpoints
    for s in ckpt_lib.all_steps(ck):
        if s > 1:
            shutil.rmtree(os.path.join(ck, f"step_{s:010d}"))
    bc_run.main(args)  # resumes at batch 2; --verify checks final λ
    # a resume with a mismatched --nb must refuse, not misalign sources
    with pytest.raises(SystemExit, match="mismatches checkpoint"):
        bc_run.main(["--graph", "rmat", "--scale", "5", "--nb", "4",
                     "--ckpt-dir", ck])


def test_approx_solve_converges_within_eps_both_executors(small_graph):
    """Exact-vs-approx parity through one entry point on both executors."""
    g, ref = small_graph
    eps = 0.05
    norm = g.n * (g.n - 2)
    host = solve(g, BCQuery(mode="approx", eps=eps, delta=0.1,
                            rule="bernstein", seed=0))
    assert host.approx.converged
    assert np.abs(host.lam - ref).max() / norm <= eps
    mesh_out = solve(g, BCQuery(mode="approx", eps=eps, delta=0.1,
                                rule="bernstein", seed=0, iters=32),
                     mesh=_mesh_1x1())
    assert mesh_out.approx.converged
    assert np.abs(mesh_out.lam - ref).max() / norm <= eps
    # same seed + same n_b → identical sample sequence → identical λ̂
    if host.plan.n_b == mesh_out.plan.n_b:
        np.testing.assert_allclose(mesh_out.lam, host.lam,
                                   rtol=1e-4, atol=1e-6)


def test_solve_reuses_prebuilt_executor(small_graph):
    """Serving pattern: one executor, many queries."""
    g, ref = small_graph
    pl = plan(g, BCQuery(mode="approx"), n_devices=1)
    ex = build_executor(g, pl)
    a = solve(g, BCQuery(mode="approx", eps=0.1, delta=0.1, seed=1),
              executor=ex)
    b = solve(g, BCQuery(mode="approx", eps=0.1, delta=0.1, seed=1),
              executor=ex)
    np.testing.assert_array_equal(a.lam, b.lam)
    assert a.plan is pl


def test_topk_through_facade(small_graph):
    g, ref = small_graph
    k = 10
    res = solve(g, BCQuery(mode="approx", eps=0.05, delta=0.1,
                           rule="normal", topk=k, seed=0))
    top_ref = set(np.argsort(ref)[::-1][:k].tolist())
    assert len(top_ref & set(res.topk(k).tolist())) / k >= 0.9


# ------------------------------------------------------ deprecation shims
def test_approx_bc_shim_warns_and_matches(small_graph):
    g, _ = small_graph
    from repro.approx import approx_bc

    # the shim's historical defaults pin (dense, no kernel) — the ref
    # must pin the same config, since an unpinned query is now free to
    # route to the calibrated COO fast path
    ref = solve(g, BCQuery(mode="approx", eps=0.1, delta=0.1,
                           rule="normal", seed=4,
                           execution=ExecutionConfig(backend="dense",
                                                     use_kernel=False))
                ).approx
    with pytest.warns(DeprecationWarning, match="repro.bc.solve"):
        old = approx_bc(g, eps=0.1, delta=0.1, rule="normal", seed=4)
    np.testing.assert_array_equal(old.lam, ref.lam)
    np.testing.assert_array_equal(old.halfwidth, ref.halfwidth)
    assert (old.n_samples, old.n_epochs, old.converged) == \
        (ref.n_samples, ref.n_epochs, ref.converged)


def test_dist_mfbc_shim_warns_and_matches(small_graph):
    g, _ = small_graph
    from repro.core.dist_bc import dist_mfbc

    mesh = _mesh_1x1()
    ref = solve(g, BCQuery(mode="exact", n_b=16, iters=32,
                           execution=ExecutionConfig(use_kernel=False)),
                mesh=mesh)
    with pytest.warns(DeprecationWarning, match="repro.bc.solve"):
        old = dist_mfbc(g, mesh, nb=16, iters=32)
    np.testing.assert_array_equal(old, ref.lam)


# ------------------------------------------------------------ service path
def test_service_exposes_plan(small_graph):
    from repro.serve.bc_service import BCRequest, BCService

    g, ref = small_graph
    svc = BCService({"web": g, "ring": ring_of_cliques(4, 5)}, n_slots=2)
    pl = svc.plan_for("web")
    assert pl.placement == "single_host" and pl.mode == "approx"
    svc.submit(BCRequest(rid=0, graph="web", k=5, rule="normal"))
    out = svc.run()
    assert len(out) == 1 and out[0].converged
    top_ref = set(np.argsort(ref)[::-1][:5].tolist())
    assert len(top_ref & set(out[0].topk)) >= 4


# -------------------------------------------- calibrated backend routing
def _coo_wins_calibration():
    """Synthetic measured rates where COO is ~20× faster per relax and
    the Pallas kernel loses to the jnp fallback (the CPU CI verdict)."""
    return Calibration(rates={
        "dense": StepRates(ops_per_s=4e9, overhead_s=0.0),
        "dense_kernel": StepRates(ops_per_s=3e9, overhead_s=0.1),
        "coo": StepRates(ops_per_s=3e9, overhead_s=0.05),
    }, meta={"jax_backend": "test"})


def test_calibrated_plan_routes_to_coo_backend():
    """Regression for the hard-pinned dense path: a scale-10 R-MAT plan
    whose calibrated regime record says COO must actually select the COO
    backend (and record why)."""
    g = from_spec("rmat", scale=10, degree=16, seed=7)
    g, _ = g.remove_isolated()
    planner = BCPlanner(calibration=_coo_wins_calibration())
    pl = planner.plan(g, BCQuery(mode="approx"), n_devices=1)
    assert pl.regime["calibrated"] is True
    assert pl.regime["regime"] == "coo"
    assert pl.backend == "coo"
    assert pl.execution.backend is Backend.COO
    assert pl.use_kernel is False  # kernel measured slower: stays off
    assert pl.predicted_step_seconds == pytest.approx(pl.regime["coo_s"])


def test_calibrated_kernel_verdict_lights_up_pallas():
    """Where the calibration measured the Pallas dense kernel faster,
    an unpinned dense plan resolves use_kernel=True; a pin still wins."""
    cal = Calibration(rates={
        # dense dominates COO; kernel beats the jnp fallback
        "dense": StepRates(ops_per_s=4e9),
        "dense_kernel": StepRates(ops_per_s=9e9),
        "coo": StepRates(ops_per_s=1e6),
    })
    assert cal.kernel_pays()
    g = from_spec("rmat", scale=6, degree=8, seed=5)
    g, _ = g.remove_isolated()
    planner = BCPlanner(calibration=cal)
    pl = planner.plan(g, BCQuery(mode="approx"), n_devices=1)
    assert pl.backend == "dense" and pl.use_kernel is True
    assert pl.predicted_step_seconds == pytest.approx(
        pl.regime["dense_kernel_s"])
    pinned = planner.plan(
        g, BCQuery(mode="approx",
                   execution=ExecutionConfig(use_kernel=False)),
        n_devices=1)
    assert pinned.backend == "dense" and pinned.use_kernel is False


# ------------------------------------------- COO vs dense executor parity
@st.composite
def rmat_graphs(draw):
    scale = draw(st.integers(min_value=5, max_value=7))
    degree = draw(st.integers(min_value=4, max_value=10))
    seed = draw(st.integers(min_value=0, max_value=2 ** 31 - 1))
    g = from_spec("rmat", scale=scale, degree=degree, seed=seed)
    g, _ = g.remove_isolated()
    return g


@settings(max_examples=10, deadline=None)
@given(rmat_graphs(), st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_coo_dense_executor_parity_on_random_rmat(g, batch_seed):
    """The parity oracle at executor level: COO-backend step and
    step_segmented moments must match the dense backend on random R-MAT
    graphs to the documented tolerance (both reduce exact per-source
    dependencies in float32; op order differs, so bitwise equality is
    not guaranteed — rtol=1e-4/atol=1e-6, same as kernels/ref.py)."""
    nb = 8
    execs = {}
    for be in ("dense", "coo", "csr"):
        pl = BCPlanner(calibration=None).plan(
            g, BCQuery(mode="approx", n_b=nb,
                       execution=ExecutionConfig(backend=be)),
            n_devices=1)
        assert pl.backend == be
        execs[be] = build_executor(g, pl)
    rng = np.random.default_rng(batch_seed)
    src = rng.integers(0, g.n, nb).astype(np.int32)
    val = np.ones(nb, bool)
    d1, d2, dn = execs["dense"].step(src, val)
    for be in ("coo", "csr"):
        c1, c2, cn = execs[be].step(src, val)
        np.testing.assert_allclose(c1, d1, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(c2, d2, rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(cn), np.asarray(dn))
    # fused slotted variant: same tolerance, per slot
    sid = np.sort(rng.integers(0, 2, nb)).astype(np.int32)
    ds = execs["dense"].step_segmented(src, val, sid, 2)
    for be in ("coo", "csr"):
        cs = execs[be].step_segmented(src, val, sid, 2)
        np.testing.assert_allclose(cs[0], ds[0], rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(cs[1], ds[1], rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(cs[2]), np.asarray(ds[2]))


def test_fused_equals_unfused_per_backend(small_graph):
    """The PR 4 bitwise fused-vs-unfused property, per backend: slot j of
    a fused step_segmented equals an unfused one-slot step_segmented over
    exactly slot j's rows (same segment-sum accumulation path → bitwise),
    on BOTH executors' backends."""
    g, _ = small_graph
    rng = np.random.default_rng(3)
    for be in ("dense", "coo", "csr"):
        pl = BCPlanner(calibration=None).plan(
            g, BCQuery(mode="approx", n_b=16,
                       execution=ExecutionConfig(backend=be)),
            n_devices=1)
        ex = build_executor(g, pl)
        src = rng.integers(0, g.n, 16).astype(np.int32)
        val = np.ones(16, bool)
        sid = np.repeat(np.arange(2, dtype=np.int32), 8)
        s1, s2, nr = ex.step_segmented(src, val, sid, 2)
        for slot in range(2):
            rows = src[sid == slot]
            u1, u2, un = ex.step_segmented(
                rows, np.ones(rows.shape[0], bool),
                np.zeros(rows.shape[0], np.int32), 1)
            np.testing.assert_array_equal(np.asarray(s1)[slot],
                                          np.asarray(u1)[0])
            np.testing.assert_array_equal(np.asarray(s2)[slot],
                                          np.asarray(u2)[0])
            np.testing.assert_array_equal(np.asarray(nr)[slot],
                                          np.asarray(un)[0])


# --------------------------------------------- frontier-sparse CSR backend
def test_csr_solve_attaches_occupancy_trace(small_graph):
    """A pinned-CSR solve records the frontier-occupancy side channel on
    the executed plan; dense/COO plans stay untouched (and pass through
    solve by identity — see test_solve_reuses_prebuilt_executor)."""
    g, ref = small_graph
    q = BCQuery(mode="exact", n_b=16,
                execution=ExecutionConfig(backend="csr"))
    res = solve(g, q)
    np.testing.assert_allclose(res.lam, ref, rtol=1e-4, atol=1e-6)
    occ = res.plan.occupancy
    assert occ is not None and occ["batches"] >= 1
    assert occ["per_iter_bf"] and occ["relax_calls"] > 0
    assert occ["fnnz_first"] >= occ["fnnz_last"]
    assert 0.0 <= occ["hit_rate"] <= 1.0
    # occupancy survives the JSON artifact round-trip
    from repro.bc.planner import BCPlan
    d = json.loads(json.dumps(res.plan.to_json()))
    assert d["occupancy"] == occ
    assert BCPlan.from_json(d).occupancy == occ
    # dense plans (and old JSON records without the field) stay None
    pl_dense = plan(g, BCQuery(mode="exact"), n_devices=1)
    assert pl_dense.occupancy is None
    old = pl_dense.to_json()
    old.pop("occupancy", None)
    assert BCPlan.from_json(old).occupancy is None


def test_dense_relax_cp_transpose_is_hoisted(small_graph):
    """Satellite 2: ``DenseAdj.relax_cp`` must use the prebuilt Aᵀ pytree
    leaf — no per-call 2D transpose of the (n, n) adjacency may appear in
    the traced program. (The monoid scan's 3D ``moveaxis`` over the
    frontier stack is expected and allowed.)"""
    import jax

    from repro.core.adjacency import dense_adj_from_graph
    from repro.core.mfbf import mfbf

    g, _ = small_graph
    adj = dense_adj_from_graph(g, block=64, use_kernel=False)
    assert adj.at is not None
    np.testing.assert_array_equal(np.asarray(adj.at), np.asarray(adj.a).T)

    from repro.core import monoids

    F = monoids.centpath_identity((4, g.n))
    jaxpr = jax.make_jaxpr(adj.relax_cp)(F)

    def _has_2d_transpose(jpr):
        for eqn in jpr.eqns:
            if eqn.primitive.name == "transpose":
                perm = eqn.params.get("permutation")
                if tuple(perm) == (1, 0):
                    return True
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    if _has_2d_transpose(sub.jaxpr):
                        return True
        return False

    assert not _has_2d_transpose(jaxpr.jaxpr), \
        "relax_cp still transposes the adjacency per call"
    # the hoisted transpose computes the same thing end to end
    src = np.arange(4, dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(mfbf(adj, src)[0]),
        np.asarray(mfbf(dense_adj_from_graph(g, block=64), src)[0]))


# ------------------------------------------------------------ multi-device
@pytest.mark.slow
def test_multidevice_planner_subprocess():
    """8 visible devices: auto mesh plan + solve parity (subprocess)."""
    script = os.path.join(os.path.dirname(__file__),
                          "md_bc_planner_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout
