"""HTTP gateway contract tests — a real server on an ephemeral port.

Every test drives the wire protocol end to end (urllib against
``start_gateway``'s ThreadingHTTPServer), not the gateway object:
submit → poll → done, cache hits returning byte-identical payloads,
looser-ε entries answering instantly with ``refining=true`` and then
refining to a result bitwise-equal to a from-scratch tight run, and
synthetic overload bursts producing 429/degrade without starving the
interactive tier.
"""
import json
import time
import urllib.error
import urllib.request

import pytest

from repro.graphs.generators import rmat
from repro.serve import BCGateway, BCService, GatewayConfig, start_gateway
from repro.serve.bc_service import BCRequest

_CACHE = {}


def _graph():
    if "g" not in _CACHE:
        g = rmat(6, 8, seed=5)
        g, _ = g.remove_isolated()
        _CACHE["g"] = g
    return _CACHE["g"]


def _server(**cfg):
    svc = BCService({"web": _graph()}, checkpoints=True)
    gw = BCGateway(svc, GatewayConfig(**cfg))
    return start_gateway(gw)


def _post(base, doc):
    req = urllib.request.Request(f"{base}/v1/bc",
                                 data=json.dumps(doc).encode(),
                                 headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read()), dict(e.headers)


def _get(base, path):
    try:
        with urllib.request.urlopen(f"{base}{path}") as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _poll_done(base, rid, timeout_s=30.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        st, doc = _get(base, f"/v1/bc/{rid}")
        assert st == 200
        if doc["status"] in ("done", "error"):
            return doc
        time.sleep(0.005)
    raise AssertionError(f"rid {rid} not done within {timeout_s}s")


# --------------------------------------------------------------- lifecycle
def test_submit_poll_done_and_cached_repeat():
    """The basic contract: cold submit is accepted and completes with a
    full result payload; an identical repeat answers instantly from the
    cache with the byte-identical payload."""
    srv = _server(horizon_s=30.0)
    try:
        base = srv.url
        st, doc, _ = _post(base, {"graph": "web", "eps": 0.15, "k": 10})
        assert st == 202 and doc["status"] == "queued"
        assert set(doc["queue_depth"]) == {"interactive", "normal", "batch"}
        rid = doc["rid"]

        done = _poll_done(base, rid)
        assert done["status"] == "done" and not done["cached"]
        res = done["result"]
        assert res["graph"] == "web" and len(res["topk"]) == 10
        assert res["converged"] and res["digest"]
        assert res["plan"]["n_b"] > 0
        assert done["latency_s"] > 0

        # identical repeat: HTTP 200 straight from the cache, payload
        # verbatim (the result's rid names the run that produced it)
        st2, doc2, _ = _post(base, {"graph": "web", "eps": 0.15, "k": 10})
        assert st2 == 200 and doc2["status"] == "done" and doc2["cached"]
        assert doc2["result"] == res
        assert doc2["rid"] != rid

        # a *looser* request is also a hit on the tighter entry
        st3, doc3, _ = _post(base, {"graph": "web", "eps": 0.3, "k": 10})
        assert st3 == 200 and doc3["cached"]
        assert doc3["result"] == res
    finally:
        srv.close()


def test_refine_serves_stale_then_bitwise_tight():
    """A tighter-ε request against a looser cached entry answers
    immediately (status=partial, refining=true, the looser payload),
    then refines from the checkpoint to a result bitwise-equal to a
    from-scratch tight run on a fresh gateway over the same
    (seed, rid) stream."""
    srv = _server(horizon_s=30.0)
    try:
        base = srv.url
        st, doc, _ = _post(base, {"graph": "web", "eps": 0.15, "k": 10})
        loose = _poll_done(base, doc["rid"])["result"]

        st, doc, _ = _post(base, {"graph": "web", "eps": 0.05, "k": 10})
        assert st == 202 and doc["status"] == "partial" and doc["refining"]
        assert doc["result"] == loose  # the stale answer, instantly
        refined = _poll_done(base, doc["rid"])
        assert refined["refined"] and not refined.get("refining")
        ref = refined["result"]
        assert ref["n_samples"] >= loose["n_samples"]
    finally:
        srv.close()

    # scratch leg: a fresh gateway gives the tight request the same rid
    # (0) the loose run had, hence the identical (seed, rid) stream the
    # refinement continued — JSON floats are shortest-repr exact, so
    # equality here is bitwise equality of the float64 results.
    srv2 = _server(horizon_s=30.0)
    try:
        st, doc, _ = _post(srv2.url, {"graph": "web", "eps": 0.05, "k": 10})
        scratch = _poll_done(srv2.url, doc["rid"])["result"]
        for field in ("topk", "lam", "halfwidth", "n_samples", "n_epochs",
                      "converged", "digest"):
            assert ref[field] == scratch[field], field
    finally:
        srv2.close()


# ---------------------------------------------------------------- overload
def test_overload_burst_rejects_without_starving_tight_tier():
    """A loose-tier flood past the horizon draws 429 + Retry-After, but
    an interactive request still admits: admission prices only backlog
    at equal-or-tighter deadlines, which the batch flood is not."""
    svc = BCService({"web": _graph()}, checkpoints=True)
    pred = float(svc.request_plan(
        BCRequest(rid=0, graph="web", eps=0.2)).predicted_seconds)
    gw = BCGateway(svc, GatewayConfig(horizon_s=pred * 1.5,
                                      idle_sleep_s=0.05))
    srv = start_gateway(gw)
    try:
        base = srv.url
        codes = []
        for _ in range(12):
            st, doc, headers = _post(base, {"graph": "web", "eps": 0.2,
                                            "priority": "batch"})
            codes.append(st)
            if st == 429:
                assert "Retry-After" in headers
                assert doc["retry_after_s"] > 0
                assert doc["backlog_s"] >= 0 and doc["horizon_s"] > 0
        assert 429 in codes, codes  # the flood tripped the gate
        assert 202 in codes, codes  # but not before admitting work

        # tight tier sails through the same overload
        st, doc, _ = _post(base, {"graph": "web", "eps": 0.2,
                                  "priority": "interactive"})
        assert st in (200, 202)
        m = _get(base, "/v1/metrics")[1]
        assert m["tiers"]["batch"]["rejected"] > 0
        assert m["tiers"]["interactive"]["rejected"] == 0
        assert m["tiers"]["interactive"]["admitted"] \
            + m["tiers"]["interactive"]["cache_hits"] >= 1
    finally:
        srv.close()


def test_overload_degrade_records_looser_eps():
    """overload='degrade': past the horizon the request is admitted at
    degrade_eps instead of rejected, with the original ε recorded."""
    svc = BCService({"web": _graph()}, checkpoints=True)
    pred = float(svc.request_plan(
        BCRequest(rid=0, graph="web", eps=0.05)).predicted_seconds)
    gw = BCGateway(svc, GatewayConfig(horizon_s=pred * 0.5,
                                      overload="degrade", degrade_eps=0.3,
                                      idle_sleep_s=0.05))
    srv = start_gateway(gw)
    try:
        base = srv.url
        st, doc, _ = _post(base, {"graph": "web", "eps": 0.05})
        assert st == 202 and doc["degraded_from"] == 0.05
        assert doc["eps"] == 0.3
        done = _poll_done(base, doc["rid"])
        assert done["degraded_from"] == 0.05
        m = _get(base, "/v1/metrics")[1]
        assert m["totals"]["degraded"] == 1 and m["totals"]["rejected"] == 0
    finally:
        srv.close()


# --------------------------------------------------------------- listings
def test_graphs_and_metrics_endpoints():
    srv = _server(horizon_s=30.0)
    try:
        base = srv.url
        st, doc = _get(base, "/v1/graphs")
        assert st == 200 and [g["name"] for g in doc["graphs"]] == ["web"]
        g = doc["graphs"][0]
        assert g["n"] > 0 and g["m"] > 0
        assert isinstance(g["digest"], str) and len(g["digest"]) == 64
        assert g["plan"]["n_b"] > 0

        st, m = _get(base, "/v1/metrics")
        assert st == 200
        assert set(m) == {"tiers", "totals", "cache", "queue_depth",
                          "admission_correction"}
        assert m["cache"]["entries"] == 0
        assert m["admission_correction"] == {}  # nothing observed yet
        assert set(m["queue_depth"]) == {"interactive", "normal", "batch"}
    finally:
        srv.close()


# ----------------------------------------------------- metric-generic wire
def test_metrics_through_the_wire_and_cache_isolation():
    """One upload serves betweenness, closeness, khop and components
    through the same POST endpoint; identical parameters under
    different metrics never share a cache entry."""
    srv = _server(horizon_s=100.0)
    try:
        base = srv.url
        docs = {}
        for payload in ({"graph": "web", "eps": 0.1, "seed": 3},
                        {"graph": "web", "eps": 0.1, "seed": 3,
                         "metric": "closeness"},
                        {"graph": "web", "eps": 0.1, "seed": 3,
                         "metric": "khop", "hops": 2},
                        {"graph": "web", "metric": "components"}):
            st, doc, _ = _post(base, payload)
            assert st == 202, doc
            key = (payload.get("metric", "betweenness"),
                   payload.get("hops", 0))
            docs[key] = _poll_done(base, doc["rid"])
        results = {k: d["result"] for k, d in docs.items()}
        lams = [tuple(r["lam"]) for r in results.values()]
        assert len(set(lams)) == len(lams)  # four distinct analytics

        # repeats hit their OWN per-metric entries, byte-identical
        for payload, key in ((
                {"graph": "web", "eps": 0.1, "seed": 3},
                ("betweenness", 0)), (
                {"graph": "web", "eps": 0.1, "seed": 3,
                 "metric": "closeness"}, ("closeness", 0))):
            st, doc, _ = _post(base, payload)
            assert st == 200 and doc["cached"]
            assert doc["result"] == results[key]

        # components cached as exact (ε = 0): any tighter ε still HITs
        st, doc, _ = _post(base, {"graph": "web", "metric": "components",
                                  "eps": 0.001})
        assert st == 200 and doc["cached"]
        assert doc["result"] == results[("components", 0)]

        # distinct hop bounds are distinct keys: hops=3 misses
        st, doc, _ = _post(base, {"graph": "web", "eps": 0.1, "seed": 3,
                                  "metric": "khop", "hops": 3})
        assert st == 202, doc
        assert _poll_done(base, doc["rid"])["result"] != \
            results[("khop", 2)]

        # bad metric / hops draw 400 at the door
        assert _post(base, {"graph": "web", "metric": "nope"})[0] == 400
        assert _post(base, {"graph": "web", "metric": "khop"})[0] == 400
        assert _post(base, {"graph": "web", "hops": 2})[0] == 400
    finally:
        srv.close()


def test_slow_solver_tightens_admission():
    """The EWMA admission correction: after the gateway observes runs
    slower than predicted, the same submission that admitted before is
    priced past the horizon and refused."""
    svc = BCService({"web": _graph()}, checkpoints=True)
    pred = float(svc.request_plan(
        BCRequest(rid=0, graph="web", eps=0.2)).predicted_seconds)
    backend = svc.request_plan(
        BCRequest(rid=0, graph="web", eps=0.2)).backend
    gw = BCGateway(svc, GatewayConfig(horizon_s=pred * 10))
    doc = gw.submit({"graph": "web", "eps": 0.2})
    assert doc["http_status"] == 202  # uncorrected price fits the horizon

    # solver measured 100x slower than the model's prediction
    gw._observe_latency("betweenness", backend, seconds=pred * 100,
                        predicted=pred)
    doc = gw.submit({"graph": "web", "eps": 0.21})
    assert doc["http_status"] == 429, doc  # corrected price trips the gate
    m = gw.metrics_doc()
    assert m["admission_correction"][f"betweenness/{backend}"] \
        == pytest.approx(100.0)
    # the correction is per-metric: closeness is still priced raw
    doc = gw.submit({"graph": "web", "eps": 0.2, "metric": "closeness"})
    assert doc["http_status"] == 202, doc


def test_poll_streams_progress_history():
    """While a job runs, GET /v1/bc/{rid} carries the estimator's
    epoch-by-epoch (τ, halfwidth) history — the streaming partial
    result — with a stable JSON shape."""
    svc = BCService({"web": _graph()}, n_slots=1)
    gw = BCGateway(svc, GatewayConfig(horizon_s=1000.0))
    doc = gw.submit({"graph": "web", "eps": 0.004, "delta": 0.1})
    assert doc["http_status"] == 202
    rid = doc["rid"]
    seen = None
    for _ in range(200):
        if not gw._work_once():  # one tick + finished-drain, inline
            break
        st = gw.get(rid)
        if st["status"] == "running" and "progress" in st:
            seen = st["progress"]
            json.dumps(st)  # the whole doc must be wire-serializable
            assert set(seen) == {"epochs"}
            taus = [e["tau"] for e in seen["epochs"]]
            assert taus == sorted(taus) and all(
                isinstance(t, int) for t in taus)
            for e in seen["epochs"]:
                assert set(e) == {"tau", "halfwidth"}
                assert e["halfwidth"] is None or (
                    isinstance(e["halfwidth"], float)
                    and e["halfwidth"] >= 0.0)
    assert seen is not None, "no running poll carried progress"
    gw.drain()
    assert gw.get(rid)["status"] == "done"
    assert "progress" not in gw.get(rid)  # final answer supersedes it


def test_error_paths():
    srv = _server(horizon_s=30.0)
    try:
        base = srv.url
        assert _post(base, {"graph": "nope"})[0] == 404
        assert _post(base, {})[0] == 400
        assert _post(base, {"graph": "web", "priority": "urgent"})[0] == 400
        assert _post(base, {"graph": "web", "eps": -1})[0] == 400
        assert _get(base, "/v1/bc/999")[0] == 404
        assert _get(base, "/v1/bc/notanint")[0] == 400
        assert _get(base, "/v1/nope")[0] == 404
        # malformed body
        req = urllib.request.Request(f"{base}/v1/bc", data=b"{not json")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 400
    finally:
        srv.close()
