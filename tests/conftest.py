"""Shared pytest setup.

* Puts ``src/`` on sys.path so the suite runs without ``PYTHONPATH=src``
  (and without requiring an installed wheel — CI installs the package, but
  a bare checkout works too).
* Puts ``tests/`` on sys.path so the ``_hypothesis_fallback`` shim is
  importable regardless of rootdir layout.
"""
from __future__ import annotations

import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
for p in (_SRC, _HERE):
    if p not in sys.path:
        sys.path.insert(0, p)
