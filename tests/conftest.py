"""Shared pytest setup.

* Puts ``src/`` on sys.path so the suite runs without ``PYTHONPATH=src``
  (and without requiring an installed wheel — CI installs the package, but
  a bare checkout works too).
* Hypothesis policy: CI bakes real hypothesis in (installed from
  ``requirements-dev.txt`` by the workflow), so on CI a missing install
  is a hard error — the deterministic ``tests/_hypothesis_fallback.py``
  shim must never silently water down the property tests there. On bare
  local runs without hypothesis, ``tests/`` goes on sys.path so the
  property tests' ``from _hypothesis_fallback import …`` fallback still
  collects and runs a fixed pseudo-random sweep.
"""
from __future__ import annotations

import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    if os.environ.get("CI"):
        raise ImportError(
            "hypothesis is required in CI (pip install -r "
            "requirements-dev.txt); the _hypothesis_fallback shim is for "
            "bare local runs only")
    # Bare local run: make the fallback shim importable.
    if _HERE not in sys.path:
        sys.path.insert(0, _HERE)
