"""QoS scheduling in ``serve.BCService``: latency tiers, EDF admission
with aging, tick-budget preemption (partial epoch drains), per-request
RNG streams, and the zero-budget retirement guards — the serving-side
regressions of the priority-aware scheduler.

The bitwise legs run on a star graph: its dependency values are small
integers, so f32 batch sums are exact and responses are reproducible
across any chunk grouping — which is what lets the preemption test
demand bitwise-equal answers from budgeted and unbudgeted runs.
"""
import numpy as np
import pytest

from repro.approx.sampling import hoeffding_budget
from repro.graphs.generators import rmat, star_graph
from repro.serve.bc_service import BCRequest, BCService

_CACHE = {}


def _graph():
    if "g" not in _CACHE:
        g = rmat(6, 8, seed=5)
        g, _ = g.remove_isolated()
        _CACHE["g"] = g
    return _CACHE["g"]


# ------------------------------------------------------------- admission
def test_request_validates_tier():
    with pytest.raises(ValueError, match="priority"):
        BCRequest(rid=0, graph="web", priority="urgent")


def test_request_validates_rid_and_seed():
    """(seed, rid) feed SeedSequence entropy, which rejects negatives —
    the request must fail at construction, not ticks later in _admit."""
    with pytest.raises(ValueError, match="non-negative"):
        BCRequest(rid=-1, graph="web")
    with pytest.raises(ValueError, match="non-negative"):
        BCRequest(rid=0, graph="web", seed=-3)


def test_edf_admission_prioritizes_tight_deadlines():
    """A batch burst ahead of an interactive request: FIFO serves the
    burst first, the deadline scheduler jumps the interactive tier over
    it (n_slots=1 makes completion order = admission order)."""
    g = _graph()
    for pack, first in (("fifo", 0), ("deadline", 2)):
        svc = BCService({"web": g}, n_slots=1, pack=pack)
        svc.submit(BCRequest(rid=0, graph="web", eps=0.2, priority="batch"))
        svc.submit(BCRequest(rid=1, graph="web", eps=0.2, priority="batch"))
        svc.submit(BCRequest(rid=2, graph="web", eps=0.2,
                             priority="interactive"))
        out = svc.run()
        assert [r.rid for r in out][0] == first, pack
        assert sorted(r.rid for r in out) == [0, 1, 2]


def test_edf_aging_overdue_batch_wins():
    """Aging via absolute deadlines: an already-overdue loose-tier
    request (explicit deadline_s=0) beats a fresh interactive one, so
    queued loose work cannot be starved by a tight-tier stream."""
    g = _graph()
    svc = BCService({"web": g}, n_slots=1, pack="deadline")
    svc.submit(BCRequest(rid=0, graph="web", eps=0.2, priority="batch",
                         deadline_s=0.0))
    svc.submit(BCRequest(rid=1, graph="web", eps=0.2,
                         priority="interactive"))
    out = svc.run()
    assert [r.rid for r in out][0] == 0


def test_untiered_requests_keep_fifo_order():
    """With all-default requests the deadline policy degenerates to
    FIFO: tiering is strictly opt-in."""
    g = _graph()
    svc = BCService({"web": g}, n_slots=1, pack="deadline")
    for rid in range(3):
        svc.submit(BCRequest(rid=rid, graph="web", eps=0.2))
    assert [q.rid for q in svc.pending] == [0, 1, 2]
    out = svc.run()
    assert [r.rid for r in out] == [0, 1, 2]


# ------------------------------------------------- per-request RNG streams
def test_concurrent_identical_requests_draw_distinct_streams():
    """Regression (seed collision): two live requests sharing the
    default seed used to draw *identical* source streams, silently
    correlating their answers. Streams now derive from (seed, rid)."""
    g = _graph()

    def run_pair():
        svc = BCService({"web": g}, n_slots=2)
        svc.submit(BCRequest(rid=0, graph="web", eps=0.1))
        svc.submit(BCRequest(rid=1, graph="web", eps=0.1))
        return {r.rid: r for r in svc.run()}

    a, b = run_pair(), run_pair()
    # distinct rids, same seed: disjoint-in-distribution draws — the
    # estimates must differ (they were bitwise-identical before the fix)
    assert not np.array_equal(a[0].lam, a[1].lam)
    # ... while staying estimates of the same λ (same graph, same ε)
    np.testing.assert_allclose(a[0].lam, a[1].lam, rtol=0.9)
    # same (seed, rid) in an identical run: exact reproducibility kept
    for rid in (0, 1):
        np.testing.assert_array_equal(a[rid].lam, b[rid].lam)
        assert a[rid].topk == b[rid].topk


def test_first_epoch_draws_differ_across_rids():
    """The mechanism itself: admitted samplers with equal seeds but
    different rids produce different first epochs."""
    g = _graph()
    svc = BCService({"web": g}, n_slots=2)
    svc.submit(BCRequest(rid=7, graph="web", eps=0.1, seed=3))
    svc.submit(BCRequest(rid=8, graph="web", eps=0.1, seed=3))
    svc._admit()
    s0 = svc.slots[0].sampler.draw(64)
    s1 = svc.slots[1].sampler.draw(64)
    assert not np.array_equal(s0, s1)


# ------------------------------------------------ preemption / tick budget
def test_tick_budget_preempts_and_preserves_answers():
    """Partial epoch drains: with a small tick budget the loose slot is
    preempted mid-epoch (backlog deferred across ticks), yet every
    response stays bitwise-identical to the unbudgeted run — deferral
    changes *when* sources run, never *which* sources or their order."""
    s = star_graph(64)

    def run(budget):
        svc = BCService({"s": s}, n_slots=2, pack="deadline",
                        tick_budget=budget)
        svc.submit(BCRequest(rid=0, graph="s", eps=0.02, priority="batch"))
        svc.submit(BCRequest(rid=1, graph="s", eps=0.05,
                             priority="interactive"))
        if budget is not None:
            # drive one tick by hand and observe the preemption: some
            # slot must carry deferred backlog into the next tick
            svc.step()
            assert any(job is not None and job.backlog.size
                       for job in svc.slots)
        out = svc.run()
        assert not svc.exhausted
        return {r.rid: r for r in out}

    base, budgeted = run(None), run(16)
    for rid in (0, 1):
        np.testing.assert_array_equal(base[rid].lam, budgeted[rid].lam)
        np.testing.assert_array_equal(base[rid].halfwidth,
                                      budgeted[rid].halfwidth)
        assert base[rid].n_samples == budgeted[rid].n_samples
        assert base[rid].topk == budgeted[rid].topk


def test_fifo_drain_follows_admission_order_not_slot_index():
    """Regression: slots recycle, so FIFO draining must key on admission
    order — an older request in a high slot must get the tick budget
    before a newer request admitted into a lower slot."""
    g = _graph()
    svc = BCService({"web": g}, n_slots=2, pack="fifo", tick_budget=4)
    for rid in range(3):
        svc.submit(BCRequest(rid=rid, graph="web", eps=0.3))
    svc._admit()  # rid 0 -> slot 0, rid 1 -> slot 1
    assert [j.req.rid for j in svc.slots] == [0, 1]
    svc.slots[0] = None  # rid 0 retires; rid 2 recycles slot 0
    svc._admit()
    assert [j.req.rid for j in svc.slots] == [2, 1]
    svc.step()
    # the 4-row budget went to the older rid 1 (slot 1), not slot 0
    assert svc.slots[1].est.tau == 4
    assert svc.slots[0].est.tau == 0


def test_tick_budget_validation():
    with pytest.raises(ValueError, match="tick_budget"):
        BCService({}, tick_budget=0)
    with pytest.raises(ValueError, match="pack"):
        BCService({}, pack="lifo")


# --------------------------------------------------------- tier plumbing
def test_response_and_plan_carry_tier():
    g = _graph()
    svc = BCService({"web": g}, n_slots=1)
    svc.submit(BCRequest(rid=0, graph="web", eps=0.2,
                         priority="interactive"))
    r = svc.run()[0]
    assert r.tier == "interactive"
    assert r.plan.tier == "interactive"
    assert r.plan.to_json()["tier"] == "interactive"
    assert r.latency_s >= r.seconds - 1e-9  # queue wait included
    # requests that differ only in tier do not share a cached plan
    svc2 = BCService({"web": g}, n_slots=2)
    svc2.submit(BCRequest(rid=0, graph="web", eps=0.2, priority="batch"))
    svc2.submit(BCRequest(rid=1, graph="web", eps=0.2,
                          priority="interactive"))
    by = {r.rid: r for r in svc2.run()}
    assert by[0].plan.tier == "batch" and by[1].plan.tier == "interactive"


def test_fair_pack_serves_all_tenants():
    g = _graph()
    svc = BCService({"web": g}, n_slots=4, pack="fair", tick_budget=64)
    for i in range(4):
        svc.submit(BCRequest(rid=i, graph="web", eps=0.15,
                             tenant=f"t{i % 2}"))
    out = svc.run()
    assert sorted(r.rid for r in out) == [0, 1, 2, 3]
    assert all(r.converged for r in out)
    assert set(svc._served) == {"t0", "t1"}


# ------------------------------------------------- zero/tiny-budget guard
@pytest.mark.parametrize("cap", [0, 1])
def test_zero_and_one_sample_caps_retire_honestly(cap):
    """Regression: a τ < 2 retirement used to report finite-garbage
    halfwidths (τ clamped to 2 inside the CI math) and could even stop
    "converged" on a loose ε with a single sample. Now: never converged,
    halfwidths +inf, no NaNs, and the service neither crashes nor
    hangs."""
    g = _graph()
    eps, delta = 0.3, 0.1
    assert cap < hoeffding_budget(g.n, eps, delta)
    svc = BCService({"web": g}, n_slots=1)
    svc.submit(BCRequest(rid=0, graph="web", eps=eps, delta=delta,
                         max_samples=cap))
    out = svc.run(max_ticks=50)
    assert not svc.exhausted and len(out) == 1
    r = out[0]
    assert r.n_samples == cap
    assert not r.converged
    assert np.isinf(r.halfwidth).all()
    assert not np.isnan(r.lam).any()
    # the per-request plan saw the degenerate cap too
    assert r.plan.sample_budget == cap


@pytest.mark.parametrize("cap", [0, 1])
def test_zero_and_one_sample_caps_through_solve(cap):
    from repro.bc import BCQuery, solve

    g = _graph()
    res = solve(g, BCQuery(mode="approx", eps=0.3, delta=0.1,
                           max_samples=cap))
    assert res.approx.n_samples == cap
    assert not res.converged
    assert np.isinf(res.approx.halfwidth).all()
    assert not np.isnan(res.lam).any()
