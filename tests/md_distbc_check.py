"""Multi-device distributed MFBC check (8 CPU devices, subprocess)."""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np
import jax

from repro.core.brandes_ref import brandes_bc
from repro.core.dist_bc import dist_mfbc
from repro.graphs.generators import erdos_renyi, ring_of_cliques


def run(g, mesh, nb, use_kernel=False):
    lam = dist_mfbc(g, mesh, nb=nb, use_kernel=use_kernel)
    ref = brandes_bc(g)
    np.testing.assert_allclose(lam, ref, rtol=1e-4, atol=1e-6)
    print(f"ok: dist_mfbc {g.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"nb={nb} kernel={use_kernel}")


def main():
    assert len(jax.devices()) == 8
    mesh_pod = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    mesh_flat = jax.make_mesh((4, 2), ("data", "model"))

    g1 = erdos_renyi(40, 0.15, seed=7, weighted=True, max_weight=9)
    g2 = ring_of_cliques(4, 6)
    g3 = erdos_renyi(36, 0.12, seed=11, weighted=True, max_weight=5,
                     directed=True)

    run(g1, mesh_pod, nb=16)
    run(g1, mesh_flat, nb=16)
    run(g2, mesh_pod, nb=24)
    run(g3, mesh_pod, nb=8)
    run(g1, mesh_pod, nb=16, use_kernel=True)
    print("ALL-OK")


if __name__ == "__main__":
    main()
