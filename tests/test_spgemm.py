"""SpGEMM distribution layer: cost model unit tests (single device) +
subprocess-spawned 8-device integration check (keeps this session on 1
device)."""
import math
import os
import subprocess
import sys

import pytest

from repro.spgemm import (CostParams, ProblemSizes, autotune,
                          best_replication, enumerate_plans, plan_cost,
                          w_1d, w_2d, w_mfbc, w_mm)

B = 4  # bytes per f32 element


def _sizes(m, k, n, da=1.0, db=1.0, dc=1.0):
    return ProblemSizes(m * k * B * da, k * n * B * db, m * n * B * dc)


def test_w_mm_prefers_1d_for_imbalanced_nnz():
    """Paper §5.2: with nnz(A) tiny, replicating A (p2=p3>1 path unused)
    beats square 2D — the 'imbalanced matrices' headline."""
    p = 64
    small_a = _sizes(1000, 1000, 1000, da=0.001)
    cost_env, (p1, p2, p3) = w_mm(small_a, p)
    # the envelope must not pay for moving B or C more than A's 1D cost
    params = CostParams()
    w2d = w_2d("AB", small_a, int(math.sqrt(p)), int(math.sqrt(p)), params)
    assert cost_env <= w2d + 1e-12


def test_w_mm_factorization_valid():
    sizes = _sizes(4096, 4096, 4096)
    _, (p1, p2, p3) = w_mm(sizes, 64)
    assert p1 * p2 * p3 == 64


def test_theorem_51_replication_wins():
    """Bandwidth term must fall as c grows (until the memory bound)."""
    n, m, p, d = 1 << 20, 1 << 24, 4096, 8
    t1 = w_mfbc(n, m, p, 1, d)
    tc = w_mfbc(n, m, p, 16, d)
    assert tc["beta_bytes"] < t1["beta_bytes"]
    assert tc["seconds"] < t1["seconds"]


def test_theorem_51_optimum_scaling():
    """At c* = p^{1/3} n²/m the per-batch bandwidth is O(n √m / p^{2/3})."""
    p, d = 4096, 8
    n = 1 << 18
    m = 16 * n
    c_star = max(1, int(p ** (1 / 3) * n * n / m))
    c_star = min(c_star, p)
    got = w_mfbc(n, m, p, c_star, d)["beta_bytes"]
    target = 8 * n * math.sqrt(m) / p ** (2 / 3)  # words->bytes (x8)
    assert got < 50 * target  # constant-factor envelope


def test_best_replication_memory_clamp():
    n, m, p = 1 << 16, 1 << 20, 256
    c_small_mem = best_replication(n, m, p, mem_bytes=9 * m // p)
    c_big_mem = best_replication(n, m, p, mem_bytes=1 << 40)
    assert c_small_mem <= c_big_mem
    assert 1 <= c_small_mem <= p


def test_enumerate_plans_covers_family():
    plans = enumerate_plans({"p1": 2, "r": 4, "c": 4})
    variants = {p.variant for p in plans}
    assert {"1d_a", "1d_b", "1d_c", "2d_ab", "2d_ac", "2d_bc"} <= variants
    assert any(v.startswith("3d_") for v in variants)
    # 3 axes: 9 3d variants x 6 axis perms
    assert sum(1 for p in plans if p.variant.startswith("3d_")) == 9 * 6


def test_plan_cost_matches_2d_formula():
    sizes = _sizes(512, 512, 512)
    axes = {"r": 4, "c": 4}
    pc = plan_cost(__import__("repro.spgemm.dist", fromlist=["Plan"]).Plan(
        "2d_ab", ("r", "c")), sizes, axes)
    expect = sizes.nnz_a / 4 * 3 / 4 + sizes.nnz_b / 4 * 3 / 4
    assert abs(pc.bytes_moved - (sizes.nnz_a / 16 * 3 + sizes.nnz_b / 16 * 3)) \
        < 1e-6 * sizes.nnz_a


def test_autotune_respects_memory_limit():
    from repro.spgemm import plan_cost as _pc, enumerate_plans as _ep
    sizes = _sizes(1 << 12, 1 << 12, 1 << 12)
    axes = {"r": 4, "c": 4}
    mems = sorted(_pc(p, sizes, axes).mem_per_device for p in _ep(axes))
    limit = mems[len(mems) // 2]  # median: excludes the hungriest plans
    loose = autotune(sizes, axes)
    tight = autotune(sizes, axes, mem_limit=limit)
    assert tight.mem_per_device <= limit
    assert tight.seconds >= loose.seconds  # constrained search can't win


@pytest.mark.slow
def test_multidevice_spgemm_subprocess():
    """All variants x semirings on 8 CPU devices + HLO byte validation."""
    script = os.path.join(os.path.dirname(__file__), "md_spgemm_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout


@pytest.mark.slow
def test_multidevice_dist_bc_subprocess():
    """Distributed MFBC (Theorem 5.1 mapping) == Brandes on 8 CPU devices."""
    script = os.path.join(os.path.dirname(__file__), "md_distbc_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout


def test_bc_regime_chooser():
    """Sparse frontiers should pick COO; full frontiers on dense-ish
    graphs should pick the dense relax (paper §7: MFBC shines when
    frontiers densify)."""
    from repro.spgemm.autotune import choose_bc_regime

    n, m, nb = 1 << 20, 1 << 24, 4096
    sparse = choose_bc_regime(n, m, nb, fill=1e-4)
    assert sparse["regime"] == "coo"
    dense_graph = choose_bc_regime(1 << 14, (1 << 14) ** 2 // 4, nb, fill=1.0)
    assert dense_graph["regime"] == "dense"
    # monotone: higher fill can only favor dense
    a = choose_bc_regime(n, m, nb, fill=0.01)["coo_s"]
    b = choose_bc_regime(n, m, nb, fill=0.5)["coo_s"]
    assert b > a
