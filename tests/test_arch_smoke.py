"""Per-architecture smoke tests: every assigned (arch x shape) cell
instantiates a REDUCED config of the same family and runs one real step on
CPU, asserting finite outputs / correct shapes. The FULL configs are only
exercised via the dry-run (abstract lowering, no allocation).
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch

CELLS = [(aid, sid) for aid, spec in ARCHS.items() for sid in spec.cells()]


@pytest.mark.parametrize("arch_id,shape_id", CELLS,
                         ids=[f"{a}::{s}" for a, s in CELLS])
def test_cell_smoke(arch_id, shape_id):
    spec = get_arch(arch_id)
    cell = spec.cells()[shape_id]
    bundle = spec.build(cell, smoke=True)
    assert bundle.concrete_args is not None
    args = bundle.concrete_args(jax.random.key(42))
    out = jax.jit(bundle.fn)(*args)
    if bundle.check is not None:
        bundle.check(jax.tree.map(np.asarray, out))


def test_registry_covers_assignment():
    expected = {
        "gemma2-27b", "command-r-plus-104b", "granite-34b",
        "moonshot-v1-16b-a3b", "qwen3-moe-235b-a22b",
        "gcn-cora", "gin-tu", "nequip", "gat-cora", "xdeepfm", "mfbc_paper",
    }
    assert expected == set(ARCHS)
    # 10 assigned archs x 4 shapes + 2 paper cells = 42
    n_cells = sum(len(s.cells()) for s in ARCHS.values())
    assert n_cells == 42


def test_full_configs_match_assignment():
    """Spot-check the published hyperparameters (no allocation)."""
    g = get_arch("gemma2-27b").config()
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv, g.d_ff, g.vocab) == \
        (46, 4608, 32, 16, 36864, 256000)
    c = get_arch("command-r-plus-104b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.vocab) == \
        (64, 12288, 96, 8, 256000)
    gr = get_arch("granite-34b").config()
    assert (gr.n_layers, gr.d_model, gr.n_heads, gr.n_kv) == (88, 6144, 48, 1)
    m = get_arch("moonshot-v1-16b-a3b").config()
    assert (m.moe.n_experts, m.moe.top_k, m.vocab) == (64, 6, 163840)
    q = get_arch("qwen3-moe-235b-a22b").config()
    assert (q.n_layers, q.moe.n_experts, q.moe.top_k) == (94, 128, 8)
    # parameter counts in the right ballpark
    assert 20e9 < g.n_params() < 35e9
    assert 90e9 < c.n_params() < 120e9
    assert 25e9 < gr.n_params() < 42e9
    assert 200e9 < q.n_params() < 260e9
    assert 15e9 < q.n_active_params() < 30e9
    x = get_arch("xdeepfm").config()
    assert x.n_fields == 39 and x.embed_dim == 10


def test_chunked_ce_matches_plain():
    """Perf-iteration 2: chunked CE loss+grads == plain CE."""
    import jax
    import jax.numpy as jnp
    from repro.models import transformer as T

    cfg = T.TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                              n_kv=2, d_ff=64, vocab=128, head_dim=8,
                              final_softcap=30.0)
    p = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, 128)
    l1 = T.loss_fn(cfg, p, toks, toks)
    l2 = T.loss_fn(cfg, p, toks, toks, chunks=4)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    g1 = jax.grad(lambda p: T.loss_fn(cfg, p, toks, toks))(p)
    g2 = jax.grad(lambda p: T.loss_fn(cfg, p, toks, toks, chunks=4))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)
