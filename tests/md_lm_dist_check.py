"""Distributed LM training equivalence (8 CPU devices, subprocess).

The sharded train step (FSDP+TP via logical rules) must produce the same
loss trajectory as the single-device step — GSPMD partitioning is
numerics-preserving modulo reduction order.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import LMDataConfig, LMPipeline
from repro.models import transformer as T
from repro.optim import adamw
from repro.sharding.rules import NO_SHARDING, make_policy

CFG = T.TransformerConfig(name="d", n_layers=2, d_model=64, n_heads=4,
                          n_kv=2, d_ff=128, vocab=256, head_dim=16)
OPT = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20)


def run(policy, shard=False):
    params = T.init_params(CFG, jax.random.key(0))
    if shard:
        logical = T.param_logical_axes(CFG, policy.model_size)
        shardings = jax.tree.map(
            policy.named, logical, is_leaf=lambda x: isinstance(x, tuple))
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else a,
            params, shardings, is_leaf=lambda x: hasattr(x, "shape"))
    opt = adamw.init_state(params)
    pipe = LMPipeline(LMDataConfig(vocab=256, batch=4, seq=32, seed=3))

    @jax.jit
    def step(params, opt, tokens, targets):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(CFG, p, tokens, targets, policy))(params)
        params, opt, _ = adamw.update(OPT, grads, opt, params)
        return params, opt, loss

    losses = []
    for s in range(5):
        b = pipe.batch(s)
        params, opt, loss = step(params, opt, jnp.asarray(b["tokens"]),
                                 jnp.asarray(b["targets"]))
        losses.append(float(loss))
    return losses


def main():
    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    base = run(NO_SHARDING, shard=False)
    with compat.set_mesh(mesh):
        sharded = run(make_policy(mesh), shard=True)
    print("single:", np.round(base, 5))
    print("sharded:", np.round(sharded, 5))
    np.testing.assert_allclose(base, sharded, rtol=2e-4)
    print("ALL-OK")


if __name__ == "__main__":
    main()
