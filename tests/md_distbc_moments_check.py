"""Multi-device mesh moments check (8 CPU devices, subprocess).

Asserts the distributed moments batch step's per-vertex (Σδ, Σδ², n_reach)
matches the single-host ``core.mfbc.mfbc_batch_moments`` on the same
sources — the contract the adaptive approximate-BC estimator relies on to
run Bernstein/CLT stopping at mesh scale.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.adjacency import dense_adj_from_graph
from repro.core.dist_bc import prepare_mesh_batch_step
from repro.core.mfbc import mfbc_batch_moments
from repro.graphs.generators import erdos_renyi, ring_of_cliques


def run(g, mesh, nb, sources):
    """Mesh (S1, S2, n_reach) == single-host moments on identical sources."""
    dist, nb_pad = prepare_mesh_batch_step(g, mesh, nb=nb, moments=True)
    src = np.zeros(nb_pad, np.int32)
    val = np.zeros(nb_pad, bool)
    k = sources.shape[0]
    src[:k], val[:k] = sources, True
    s1, s2, nr = dist(src, val)

    adj = dense_adj_from_graph(g)
    r1, r2, rn = mfbc_batch_moments(adj, jnp.asarray(src[:k]),
                                    jnp.asarray(val[:k]))
    np.testing.assert_allclose(s1, np.asarray(r1, np.float64),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(s2, np.asarray(r2, np.float64),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(nr, np.asarray(rn))
    print(f"ok: mesh moments {g.name} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} nb={nb}")


def main():
    assert len(jax.devices()) == 8
    mesh_pod = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    mesh_flat = jax.make_mesh((4, 2), ("data", "model"))

    g1 = erdos_renyi(40, 0.15, seed=7, weighted=True, max_weight=9)
    g2 = ring_of_cliques(4, 6)
    g3 = erdos_renyi(36, 0.12, seed=11, weighted=True, max_weight=5,
                     directed=True)
    rng = np.random.default_rng(0)

    run(g1, mesh_pod, 16, rng.integers(0, g1.n, 16).astype(np.int32))
    run(g1, mesh_flat, 16, rng.integers(0, g1.n, 16).astype(np.int32))
    run(g2, mesh_pod, 24, rng.integers(0, g2.n, 24).astype(np.int32))
    run(g3, mesh_pod, 8, rng.integers(0, g3.n, 8).astype(np.int32))
    # Ragged batch: padding rows must contribute nothing to any moment.
    run(g1, mesh_pod, 16, rng.integers(0, g1.n, 5).astype(np.int32))
    print("ALL-OK")


if __name__ == "__main__":
    main()
