"""Cross-request batch fusion: the fused-parity property, shape
bucketing, per-request planning, and the serving-stack regressions
(honest capped convergence, no silently dropped work, lone-request
bit-stability).

The headline property: for ANY mix of concurrent requests — random slot
interleavings, ragged demand, several buckets, and EVERY packing policy
(``pack="fifo"|"deadline"|"fair"``) — the per-slot ``(S1, S2,
n_reach)`` a fused ``step_segmented`` batch returns is
bitwise-identical to running each request's rows sequentially (unfused)
on the same executor, on both the single-host and the 1×1-mesh
executor; and a mid-epoch preemption (a slot's demand deferred across
two drains) leaves every slot's accumulated statistics bitwise-equal to
the undeferred drain. The multi-device (8 fake CPU devices) fused tick
rides the ``md_bc_planner_check.py`` subprocess (slow lane).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare local run: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.approx.sampling import AdaptiveSampler, hoeffding_budget
from repro.bc import (PACKS, BatchAssembler, BCQuery, FusedBatch,
                      build_executor, bucket_sizes, honest_converged,
                      order_demand, plan, plan_for_request, scatter)
from repro.core import brandes_bc
from repro.graphs.generators import rmat

# Shared state for the @given property tests (hypothesis forbids
# function-scoped fixtures inside @given; build lazily, once per run).
_CACHE = {}


def _graph():
    if "g" not in _CACHE:
        g = rmat(6, 8, seed=5)
        g, _ = g.remove_isolated()
        _CACHE["g"] = g
    return _CACHE["g"]


def _host_executor():
    if "host" not in _CACHE:
        g = _graph()
        _CACHE["host"] = build_executor(
            g, plan(g, BCQuery(mode="approx", n_b=64), n_devices=1))
    return _CACHE["host"]


def _mesh_executor():
    if "mesh" not in _CACHE:
        import jax
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        g = _graph()
        pl = plan(g, BCQuery(mode="approx", n_b=64, iters=32), mesh=mesh)
        _CACHE["mesh"] = build_executor(g, pl, mesh=mesh)
    return _CACHE["mesh"]


# ------------------------------------------------------------- assembler
def test_assembler_packs_contiguous_and_chops():
    asm = BatchAssembler(_host_executor())
    demand = [(3, np.arange(40, dtype=np.int32)),
              (7, np.arange(40, 70, dtype=np.int32)),
              (1, np.zeros(0, np.int32)),  # empty demand is dropped
              (5, np.arange(70, 100, dtype=np.int32))]
    batches = asm.assemble(demand)
    # 100 rows at capacity 64 -> two batches; slots stay contiguous
    assert [len(b.sources) for b in batches] == [64, 36]
    assert batches[0].slots == (3, 7) and batches[0].counts == (40, 24)
    assert batches[1].slots == (7, 5) and batches[1].counts == (6, 30)
    # the packed stream is the concatenation, per-slot order preserved
    joined = np.concatenate([b.sources for b in batches])
    np.testing.assert_array_equal(joined, np.arange(100, dtype=np.int32))
    assert all(isinstance(b, FusedBatch) and b.valid.all() for b in batches)
    assert asm.assemble([]) == []
    # duplicate slot keys would shadow each other in scatter(): refuse
    with pytest.raises(ValueError, match="duplicate slot keys"):
        asm.assemble([(3, np.arange(4, dtype=np.int32)),
                      (3, np.arange(4, dtype=np.int32))])


def test_bucket_sizes_and_bucket_for():
    assert bucket_sizes(64) == (8, 16, 32, 64)
    assert bucket_sizes(100) == (8, 16, 32, 64, 100)
    assert bucket_sizes(4) == (4,)
    ex = _host_executor()
    assert ex.bucket_for(1) == 8
    assert ex.bucket_for(33) == 64
    with pytest.raises(ValueError, match="exceeds"):
        ex.bucket_for(65)


# -------------------------------------------------- fused parity property
def _fused_vs_sequential(ex, n, slot_lens, order_seed, pack="fifo"):
    """Fused step_segmented == each request's batches run sequentially.

    Bitwise leg: for every fused batch, every slot's segmented rows must
    equal running exactly those rows alone (unfused) — fusing requests
    into one padded batch must not perturb any request's statistics by
    even an ulp, whatever ``pack`` policy ordered the demand (policies
    reorder whole entries, never a slot's rows). Numeric leg: the fused
    per-slot *totals* match the plain (unsegmented) ``step`` over the
    whole demand to f32 tolerance (the grouping of f32 partial sums may
    differ, the mathematics may not).
    """
    rng = np.random.default_rng(order_seed)
    demand = [(j, rng.integers(0, n, ln).astype(np.int32))
              for j, ln in enumerate(slot_lens) if ln > 0]
    if not demand:
        return
    # random interleaving of slot order into the assembler, plus random
    # slack/tenant metadata for the deadline / fair policies
    rng.shuffle(demand)
    slack = {j: float(rng.uniform(-1.0, 5.0)) for j, _ in demand}
    tenant = {j: f"t{int(rng.integers(0, 2))}" for j, _ in demand}
    asm = BatchAssembler(ex, pack=pack)
    fused = {}
    for fb in asm.assemble(demand, slack=slack, tenant=tenant):
        s1, s2, nr = ex.step_segmented(fb.sources, fb.valid, fb.slot_ids,
                                       fb.n_slots)
        for j, key in enumerate(fb.slots):
            # sequential baseline: the same rows, alone, same order
            rows = fb.sources[(fb.slot_ids == j) & fb.valid]
            assert rows.shape[0] == fb.counts[j]
            b1, b2, bn = ex.step_segmented(
                rows, np.ones(rows.shape[0], bool),
                np.zeros(rows.shape[0], np.int32), 1)
            np.testing.assert_array_equal(s1[j], b1[0])  # bitwise S1
            np.testing.assert_array_equal(s2[j], b2[0])  # bitwise S2
            np.testing.assert_array_equal(nr[j], bn[0])
            acc = fused.setdefault(
                key, [np.zeros(n), np.zeros(n), np.zeros(n, np.int64), 0])
            acc[0] += s1[j]
            acc[1] += s2[j]
            acc[2] += nr[j]
            acc[3] += fb.counts[j]
    for key, srcs in demand:
        assert fused[key][3] == srcs.shape[0]
        # numeric leg: per-slot totals == plain moments step of the whole
        # demand (chopped at capacity), to f32 regrouping tolerance
        m1 = np.zeros(n)
        mn = np.zeros(n, np.int64)
        for lo in range(0, srcs.shape[0], ex.n_b):
            c = srcs[lo:lo + ex.n_b]
            r1, _, rn = ex.step(c, np.ones(c.shape[0], bool))
            m1 += r1
            mn += rn
        np.testing.assert_allclose(fused[key][0], m1, rtol=1e-4, atol=1e-6)
        np.testing.assert_array_equal(fused[key][2], mn)


@settings(max_examples=12, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=50), min_size=1,
                max_size=5),
       st.integers(min_value=0, max_value=2 ** 16),
       st.integers(min_value=0, max_value=len(PACKS) - 1))
def test_fused_parity_single_host(lens, order_seed, pack_idx):
    """Random slot interleavings + ragged demand across several buckets
    and every packing policy: fused == sequential, bitwise, on the
    single-host executor."""
    _fused_vs_sequential(_host_executor(), _graph().n, lens, order_seed,
                         pack=PACKS[pack_idx])


@settings(max_examples=6, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=40), min_size=1,
                max_size=4),
       st.integers(min_value=0, max_value=2 ** 16),
       st.integers(min_value=0, max_value=len(PACKS) - 1))
def test_fused_parity_mesh_1x1(lens, order_seed, pack_idx):
    """Same property through the distributed (1×1 mesh) executor — the
    segmented stacked psum must not perturb per-slot statistics under
    any packing policy."""
    _fused_vs_sequential(_mesh_executor(), _graph().n, lens, order_seed,
                         pack=PACKS[pack_idx])


@settings(max_examples=8, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=50), min_size=2,
                max_size=4),
       st.integers(min_value=0, max_value=2 ** 16),
       st.integers(min_value=0, max_value=len(PACKS) - 1))
def test_fused_parity_survives_preemption_defer(lens, cut_seed, pack_idx):
    """Mid-epoch preemption: each slot's epoch demand is split at a
    random preemption point and drained over two assembler calls (the
    deferred chunks of a later tick). Two invariants: (1) across the
    whole defer cycle every slot executes exactly its original rows in
    its original order — deferral loses nothing, duplicates nothing,
    reorders nothing within a slot; (2) per fused batch, every slot's
    segmented statistics stay bitwise-identical to running those rows
    alone, so a deferred request's accumulated estimator state is
    bitwise what the same sequence of unfused chunk runs would give,
    under any packing policy."""
    ex = _host_executor()
    n = _graph().n
    rng = np.random.default_rng(cut_seed)
    demand = [(j, rng.integers(0, n, ln).astype(np.int32))
              for j, ln in enumerate(lens)]
    cuts = {j: int(rng.integers(0, srcs.size + 1)) for j, srcs in demand}
    slack = {j: float(rng.uniform(-1.0, 5.0)) for j, _ in demand}
    tenant = {j: f"t{int(rng.integers(0, 2))}" for j, _ in demand}
    asm = BatchAssembler(ex, pack=PACKS[pack_idx])
    fused = {j: [np.zeros(n), np.zeros(n)] for j, _ in demand}
    seq = {j: [np.zeros(n), np.zeros(n)] for j, _ in demand}
    ran_rows = {j: [] for j, _ in demand}
    drains = ([(j, srcs[:cuts[j]]) for j, srcs in demand],
              [(j, srcs[cuts[j]:]) for j, srcs in demand])
    for drain in drains:
        for fb in asm.assemble(drain, slack=slack, tenant=tenant):
            s1, s2, nr = ex.step_segmented(fb.sources, fb.valid,
                                           fb.slot_ids, fb.n_slots)
            for key, (r1, r2, _, _cnt) in scatter(fb, (s1, s2, nr)).items():
                fused[key][0] += r1
                fused[key][1] += r2
            for j, key in enumerate(fb.slots):
                rows = fb.sources[(fb.slot_ids == j) & fb.valid]
                ran_rows[key].append(rows)
                # sequential baseline at the same chunk grouping: the
                # same rows, alone, accumulated the same way
                b1, b2, _ = ex.step_segmented(
                    rows, np.ones(rows.size, bool),
                    np.zeros(rows.size, np.int32), 1)
                seq[key][0] += b1[0]
                seq[key][1] += b2[0]
    for j, srcs in demand:
        np.testing.assert_array_equal(
            np.concatenate(ran_rows[j]) if ran_rows[j] else
            np.zeros(0, np.int32), srcs)
        np.testing.assert_array_equal(fused[j][0], seq[j][0])
        np.testing.assert_array_equal(fused[j][1], seq[j][1])


# ---------------------------------------------------- packing policies
def test_order_demand_policies():
    a = np.arange(10, dtype=np.int32)
    b = np.arange(20, dtype=np.int32)
    c = np.arange(5, dtype=np.int32)
    demand = [(0, a), (1, b), (2, c)]
    # fifo: caller's order, untouched
    assert [k for k, _ in order_demand(demand, "fifo")] == [0, 1, 2]
    # deadline: ascending slack, missing slack sorts last, ties stable
    out = order_demand(demand, "deadline", slack={0: 5.0, 2: -1.0})
    assert [k for k, _ in out] == [2, 0, 1]
    # fair: tenant with least cumulative rows drains first; the caller's
    # served history counts
    out = order_demand(demand, "fair",
                       tenant={0: "x", 1: "x", 2: "y"},
                       served={"x": 100})
    assert [k for k, _ in out][0] == 2  # tenant y owes nothing yet
    # entries are moved whole: same arrays, just reordered
    assert {id(s) for _, s in out} == {id(a), id(b), id(c)}
    with pytest.raises(ValueError, match="pack"):
        order_demand(demand, "lifo")
    with pytest.raises(ValueError, match="pack"):
        BatchAssembler(_host_executor(), pack="nope")


def test_mesh_and_host_fused_agree():
    g = _graph()
    rng = np.random.default_rng(11)
    srcs = rng.integers(0, g.n, 48).astype(np.int32)
    tags = np.sort(rng.integers(0, 3, 48)).astype(np.int32)
    h = _host_executor().step_segmented(srcs, np.ones(48, bool), tags, 3)
    m = _mesh_executor().step_segmented(srcs, np.ones(48, bool), tags, 3)
    np.testing.assert_allclose(h[0], m[0], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(h[1], m[1], rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(h[2], m[2])


# --------------------------------------------------------- demand surface
def test_sampler_demand_matches_epoch_assembly():
    """draw()'s RNG stream is chunking-invariant: the demand side hands a
    fused assembler the same sources the single-query epochs() batches."""
    a = AdaptiveSampler(100, n_b=16, cap=200, seed=9)
    b = AdaptiveSampler(100, n_b=16, cap=200, seed=9)
    via_epochs = []
    for ei, batches in a.epochs():
        for batch in batches:
            via_epochs.append(batch.sources[batch.valid])
        if ei == 2:
            a.stop()
    via_demand = []
    while True:
        nxt = b.next_epoch()
        if nxt is None:
            break
        ei, tau = nxt
        via_demand.append(b.draw(tau))
        if ei == 2:
            b.stop()
    np.testing.assert_array_equal(np.concatenate(via_epochs),
                                  np.concatenate(via_demand))
    assert a.drawn == b.drawn


def test_sampler_demand_respects_cap_and_stop():
    s = AdaptiveSampler(100, n_b=16, cap=40, seed=0)
    e0 = s.next_epoch()
    assert e0 == (0, 16)
    s.draw(16)
    assert s.next_epoch() == (1, 24)  # 32 clamped to the 40-sample cap
    s.draw(24)
    assert s.capped and s.next_epoch() is None
    s2 = AdaptiveSampler(100, n_b=16, seed=0)
    s2.next_epoch()
    s2.stop()
    assert s2.next_epoch() is None


# ------------------------------------------------------ per-request plans
def test_plan_for_request_sizes_nb_from_eps():
    g = _graph()
    tight = plan_for_request(g, eps=0.03, delta=0.1, n_devices=1)
    loose = plan_for_request(g, eps=0.4, delta=0.1, n_devices=1)
    assert loose.n_b <= tight.n_b
    assert tight.buckets[-1] == tight.n_b
    assert list(tight.to_json()["buckets"]) == list(tight.buckets)


# ------------------------------------------------------------ the service
def test_service_fused_vs_unfused_converge_same_quality():
    from repro.serve.bc_service import BCRequest, BCService

    g = _graph()
    ref = brandes_bc(g)
    top = set(np.argsort(ref)[::-1][:10].tolist())
    for fuse in (False, True):
        svc = BCService({"web": g}, n_slots=4, fuse=fuse)
        for rid in range(4):
            svc.submit(BCRequest(rid=rid, graph="web", k=10,
                                 eps=0.05 + 0.03 * rid, rule="normal",
                                 seed=rid))
        out = svc.run()
        assert not svc.exhausted and svc.pending == []
        assert sorted(r.rid for r in out) == [0, 1, 2, 3]
        assert all(r.converged for r in out)
        by = {r.rid: r for r in out}
        assert len(top & set(by[0].topk)) >= 9
        # executed per-request plans ride the response
        assert all(r.plan is not None and r.plan.n_b > 0 for r in out)


def test_service_lone_request_bitwise_stable():
    """A lone request takes the classic per-request path: fused service ==
    unfused service, bitwise (the 'service answers stay identical' leg)."""
    from repro.serve.bc_service import BCRequest, BCService

    g = _graph()
    res = {}
    for fuse in (False, True):
        svc = BCService({"web": g}, n_slots=2, fuse=fuse)
        svc.submit(BCRequest(rid=0, graph="web", k=10, rule="normal",
                             seed=3))
        res[fuse] = svc.run()[0]
    np.testing.assert_array_equal(res[True].lam, res[False].lam)
    np.testing.assert_array_equal(res[True].halfwidth, res[False].halfwidth)
    assert res[True].topk == res[False].topk
    assert res[True].n_samples == res[False].n_samples


def test_service_capped_run_not_reported_converged():
    """Regression: a cap below the Hoeffding budget must go through
    ``honest_converged`` — the old path reported ``converged or capped``
    unconditionally."""
    from repro.serve.bc_service import BCRequest, BCService

    g = _graph()
    eps, delta = 0.01, 0.05
    cap = 32
    assert cap < hoeffding_budget(g.n, eps, delta)
    svc = BCService({"web": g}, n_slots=1)
    svc.submit(BCRequest(rid=0, graph="web", eps=eps, delta=delta,
                         max_samples=cap))
    out = svc.run()
    assert len(out) == 1
    assert out[0].n_samples == cap
    assert not out[0].converged  # CIs cannot certify ε=0.01 at τ=32
    # the same contract as the solve driver's honest_converged
    from repro.bc import LambdaEstimator

    est = LambdaEstimator(g.n, eps, delta, "normal")
    assert not honest_converged(est)


def test_service_run_surfaces_unfinished_work():
    from repro.serve.bc_service import BCRequest, BCService

    g = _graph()
    svc = BCService({"web": g}, n_slots=1)
    svc.submit(BCRequest(rid=1, graph="web", eps=0.01))
    svc.submit(BCRequest(rid=2, graph="web", eps=0.01))
    done = svc.run(max_ticks=1)
    assert svc.exhausted
    finished = {r.rid for r in done}
    assert sorted(q.rid for q in svc.pending) == \
        [r for r in (1, 2) if r not in finished]
    # draining the service clears the flag
    svc.run()
    assert not svc.exhausted and svc.pending == []
