"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracles.

Sweeps shapes (including non-block-multiples exercising the padding path)
and dtypes, plus adversarial inputs (all-inf rows, tie-heavy integer
weights, empty frontiers) and a hypothesis sweep.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic sweep, see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref

INF = np.inf


def _rand_multpath(rng, nb, n, density=0.5, dtype=np.float32):
    fw = rng.integers(0, 20, (nb, n)).astype(dtype)
    active = rng.random((nb, n)) < density
    fw = np.where(active, fw, INF).astype(dtype)
    fm = np.where(active, rng.integers(1, 5, (nb, n)), 0.0).astype(dtype)
    return fw, fm


def _rand_adj(rng, n, n2, density=0.3, dtype=np.float32):
    a = rng.integers(1, 10, (n, n2)).astype(dtype)
    return np.where(rng.random((n, n2)) < density, a, INF).astype(dtype)


SHAPES = [(8, 16, 16), (8, 128, 128), (16, 200, 136), (128, 128, 256),
          (1, 64, 300), (130, 257, 129)]


@pytest.mark.parametrize("nb,n,n2", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32])
def test_multpath_matmul_matches_ref(nb, n, n2, dtype):
    rng = np.random.default_rng(nb * 1000 + n)
    fw, fm = _rand_multpath(rng, nb, n, dtype=dtype)
    a = _rand_adj(rng, n, n2, dtype=dtype)
    cw, cm = ops.multpath_matmul(jnp.asarray(fw), jnp.asarray(fm),
                                 jnp.asarray(a))
    cw_r, cm_r = ref.multpath_matmul_ref(jnp.asarray(fw), jnp.asarray(fm),
                                         jnp.asarray(a))
    np.testing.assert_array_equal(np.asarray(cw), np.asarray(cw_r))
    np.testing.assert_allclose(np.asarray(cm), np.asarray(cm_r), rtol=1e-6)


@pytest.mark.parametrize("nb,n,n2", SHAPES)
def test_centpath_matmul_matches_ref(nb, n, n2):
    rng = np.random.default_rng(nb * 7 + n2)
    fw = rng.integers(0, 20, (nb, n)).astype(np.float32)
    active = rng.random((nb, n)) < 0.5
    fw = np.where(active, fw, -INF).astype(np.float32)
    fp = np.where(active, rng.random((nb, n)), 0.0).astype(np.float32)
    b = _rand_adj(rng, n, n2)
    cw, cp, cc = ops.centpath_matmul(jnp.asarray(fw), jnp.asarray(fp),
                                     jnp.asarray(b))
    cw_r, cp_r, cc_r = ref.centpath_matmul_ref(jnp.asarray(fw),
                                               jnp.asarray(fp), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(cw), np.asarray(cw_r))
    np.testing.assert_allclose(np.asarray(cp), np.asarray(cp_r), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(cc), np.asarray(cc_r))


def test_multpath_empty_frontier():
    """All-inactive frontier must produce all-inactive output."""
    nb, n = 8, 64
    fw = jnp.full((nb, n), INF)
    fm = jnp.zeros((nb, n))
    a = jnp.asarray(_rand_adj(np.random.default_rng(0), n, n))
    cw, cm = ops.multpath_matmul(fw, fm, a)
    assert bool(jnp.all(~jnp.isfinite(cw)))
    assert bool(jnp.all(cm == 0))


def test_multpath_tie_heavy():
    """Unit weights on a complete bipartite block: every path ties."""
    nb, n, n2 = 4, 32, 32
    fw = jnp.ones((nb, n))
    fm = jnp.full((nb, n), 2.0)
    a = jnp.ones((n, n2))
    cw, cm = ops.multpath_matmul(fw, fm, a)
    np.testing.assert_array_equal(np.asarray(cw), 2.0)
    np.testing.assert_array_equal(np.asarray(cm), 2.0 * n)


def test_centpath_no_nan_on_inactive_vs_noedge():
    """-inf frontier against inf edge must not produce NaN."""
    fw = jnp.array([[-INF, 0.0]])
    fp = jnp.array([[0.0, 1.0]])
    b = jnp.array([[INF, 1.0], [INF, INF]])
    cw, cp, cc = ops.centpath_matmul(fw, fp, b)
    assert not bool(jnp.any(jnp.isnan(cw)))
    # column 0 has no edges: inactive
    assert np.asarray(cw)[0, 0] == -INF
    # column 1: only (k=0) edge exists but frontier k=0 inactive; k=1 no edge
    # -> contribution from k=0: -inf - 1 = -inf; k=1: 0 - inf = -inf
    assert np.asarray(cw)[0, 1] == -INF


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 50), st.integers(1, 50),
       st.integers(0, 2**31 - 1))
def test_multpath_hypothesis_sweep(nb, n, n2, seed):
    rng = np.random.default_rng(seed)
    fw, fm = _rand_multpath(rng, nb, n, density=rng.random())
    a = _rand_adj(rng, n, n2, density=rng.random())
    cw, cm = ops.multpath_matmul(jnp.asarray(fw), jnp.asarray(fm),
                                 jnp.asarray(a))
    cw_r, cm_r = ref.multpath_matmul_ref(jnp.asarray(fw), jnp.asarray(fm),
                                         jnp.asarray(a))
    np.testing.assert_array_equal(np.asarray(cw), np.asarray(cw_r))
    np.testing.assert_allclose(np.asarray(cm), np.asarray(cm_r), rtol=1e-6)


def test_kernel_inside_mfbc_end_to_end():
    """use_kernel=True routes MFBC through the Pallas kernels; same λ."""
    from repro.core import brandes_bc, mfbc
    from repro.graphs.generators import erdos_renyi

    g = erdos_renyi(48, 0.12, seed=3, weighted=True, max_weight=6)
    lam_k = mfbc(g, n_b=16, backend="dense", use_kernel=True)
    lam_ref = brandes_bc(g)
    np.testing.assert_allclose(lam_k, lam_ref, rtol=1e-5, atol=1e-8)
