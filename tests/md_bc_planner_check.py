"""Multi-device planner/solver check (8 CPU devices, subprocess).

The configuration-search acceptance test: with 8 visible devices and no
explicit mesh, ``BCPlanner`` must choose a mesh placement on its own
(the paper's (2, 2, 2) (pod, data, model) grid for p = 8), the
``MeshExecutor`` must build that mesh from the plan, and both solve
drivers — exact sweep and adaptive sampling epochs — must agree with
their single-host counterparts through the one ``repro.bc.solve`` entry
point.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np
import jax

from repro.bc import BCQuery, MeshExecutor, plan, solve
from repro.core.brandes_ref import brandes_bc
from repro.graphs.generators import from_spec


def main():
    assert len(jax.devices()) == 8
    g = from_spec("er", scale=6, degree=8, weighted=True, seed=7)
    g, _ = g.remove_isolated()

    # --- the planner sees 8 devices and picks a mesh decomposition -----
    query = BCQuery(mode="exact", n_b=16)
    pl = plan(g, query)
    assert pl.placement == "mesh", pl
    axes = pl.axes_dict()
    total = 1
    for s in axes.values():
        total *= s
    assert total == 8, axes
    assert axes == {"pod": 2, "data": 2, "model": 2}, axes
    assert pl.predicted_comm_bytes > 0 and pl.predicted_mem_bytes > 0
    print(f"ok: auto plan {pl.summary()}")

    # --- exact solve over the auto-built MeshExecutor == oracle --------
    res = solve(g, query, plan=pl)
    ref = brandes_bc(g)
    np.testing.assert_allclose(res.lam, ref, rtol=1e-4, atol=1e-6)
    print("ok: exact mesh solve == Brandes oracle")

    # --- approx epochs on the same auto placement ----------------------
    aq = BCQuery(mode="approx", eps=0.1, delta=0.2, rule="bernstein",
                 strategy="uniform", max_samples=96, n_b=16,
                 seed=3)
    apl = plan(g, aq)
    assert apl.placement == "mesh"
    out = solve(g, aq, plan=apl)
    assert out.approx.n_samples == 96
    assert out.plan is apl

    # identical seeds through an explicit single-host plan must sample
    # the same sources: the mesh moments and single-host moments agree,
    # so λ̂ must match to float32-accumulation tolerance.
    host = solve(g, aq, plan=plan(g, aq, n_devices=1))
    np.testing.assert_allclose(out.lam, host.lam, rtol=1e-4, atol=1e-6)
    print("ok: approx mesh epochs == single-host epochs (same seed)")

    # the executor the solver built really is the distributed one
    from repro.bc import build_executor

    ex = build_executor(g, apl)
    assert isinstance(ex, MeshExecutor)
    assert dict(zip(ex.mesh.axis_names, ex.mesh.devices.shape)) == axes

    # --- one fused-mesh tick: cross-request fusion on the 8-dev mesh ---
    # Two concurrent requests' demand packed into slot-tagged batches:
    # per-slot (S1, S2, n_reach) from the one stacked segmented psum must
    # match each request's rows run alone (f32 tolerance — the 8-way
    # batch sharding regroups partial sums) and the single-host fused
    # step on the same rows.
    from repro.bc import BatchAssembler, scatter

    rng = np.random.default_rng(5)
    demand = [(0, rng.integers(0, g.n, 11).astype(np.int32)),
              (1, rng.integers(0, g.n, 5).astype(np.int32))]
    totals = {}
    for fb in BatchAssembler(ex).assemble(demand):
        s1, s2, nr = ex.step_segmented(fb.sources, fb.valid, fb.slot_ids,
                                       fb.n_slots)
        for key, (r1, r2, rn, cnt) in scatter(fb, (s1, s2, nr)).items():
            acc = totals.setdefault(key, [np.zeros(g.n), np.zeros(g.n),
                                          np.zeros(g.n, np.int64)])
            acc[0] += r1
            acc[1] += r2
            acc[2] += rn
    host_ex = build_executor(g, plan(g, aq, n_devices=1))
    for key, srcs in demand:
        solo = ex.step_segmented(srcs, np.ones(srcs.shape[0], bool),
                                 np.zeros(srcs.shape[0], np.int32), 1)
        np.testing.assert_allclose(totals[key][0], solo[0][0],
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(totals[key][1], solo[1][0],
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(totals[key][2], solo[2][0])
        hs1, hs2, hnr = host_ex.step_segmented(
            srcs, np.ones(srcs.shape[0], bool),
            np.zeros(srcs.shape[0], np.int32), 1)
        np.testing.assert_allclose(totals[key][0], hs1[0],
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_array_equal(totals[key][2], hnr[0])
    print("ok: fused-mesh tick — per-slot moments == solo mesh == host")

    # and the serving tick loop drives the same fused path end to end
    from repro.serve.bc_service import BCRequest, BCService

    svc = BCService({"er": g}, n_slots=2, mesh=ex.mesh, iters=32)
    svc.submit(BCRequest(rid=0, graph="er", k=5, eps=0.15, rule="normal"))
    svc.submit(BCRequest(rid=1, graph="er", k=5, eps=0.2, rule="normal",
                         seed=1))
    processed = svc.step()  # one fused tick: both slots, one graph group
    assert processed > 0 and svc.active + len(svc.finished) == 2
    print("ok: BCService fused mesh tick processed", processed, "sources")
    print("ALL-OK")


if __name__ == "__main__":
    main()
