"""Property-based tests (hypothesis) for the MFBC system invariants."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic sweep, see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import brandes_bc, mfbc, multpath_combine, centpath_combine
from repro.core.monoids import Centpath, Multpath
from repro.graphs.formats import Graph

import jax.numpy as jnp


@st.composite
def random_graphs(draw, max_n=24, max_w=6):
    n = draw(st.integers(min_value=3, max_value=max_n))
    nnz = draw(st.integers(min_value=2, max_value=min(n * (n - 1), 80)))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    weighted = draw(st.booleans())
    directed = draw(st.booleans())
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, nnz).astype(np.int32)
    dst = rng.integers(0, n, nnz).astype(np.int32)
    w = (rng.integers(1, max_w + 1, nnz) if weighted else np.ones(nnz)) \
        .astype(np.float32)
    g = Graph(n, src, dst, w, directed=directed).dedup()
    if not directed:
        g = g.symmetrize()
    if g.nnz == 0:  # all arcs were self loops; add one real edge
        g = Graph(n, np.array([0], np.int32), np.array([1], np.int32),
                  np.ones(1, np.float32), directed=True)
    return g


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_mfbc_equals_oracle_on_random_graphs(g):
    """End-to-end: MFBC == Brandes on arbitrary random graphs."""
    lam = mfbc(g, n_b=min(8, g.n), backend="coo")
    lam_ref = brandes_bc(g)
    np.testing.assert_allclose(lam, lam_ref, rtol=1e-4, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(random_graphs(max_n=16))
def test_bc_global_invariants(g):
    """λ ≥ 0 and Σ_v λ(v) = Σ_{s≠t reachable} (avg path interior length).

    The total Σ_v λ(v) equals Σ_{s,t} (expected number of interior vertices
    on a random shortest path) = Σ_{s,t} Σ_v σ(s,t,v)/σ̄(s,t); we check it
    against the oracle's total rather than a closed form, plus positivity
    and the zero-centrality of degree-boundary vertices on paths.
    """
    lam = mfbc(g, n_b=min(8, g.n), backend="dense")
    assert np.all(lam >= -1e-9)
    assert abs(lam.sum() - brandes_bc(g).sum()) < 1e-5 * max(1.0, lam.sum())


multpaths = st.tuples(
    st.one_of(st.just(np.inf), st.floats(0, 50).map(lambda x: float(int(x)))),
    st.integers(0, 5).map(float),
)


@settings(max_examples=200, deadline=None)
@given(multpaths, multpaths, multpaths)
def test_multpath_monoid_laws(a, b, c):
    """⊕ is associative and commutative with identity (inf, 0)."""

    def mk(t):
        w, m = t
        m = 0.0 if not np.isfinite(w) else m
        return Multpath(jnp.float32(w), jnp.float32(m))

    def eq(x, y):
        return (np.array_equal(np.asarray(x.w), np.asarray(y.w), equal_nan=True)
                and (not np.isfinite(x.w)
                     or np.asarray(x.m) == np.asarray(y.m)))

    A, B, C = mk(a), mk(b), mk(c)
    assert eq(multpath_combine(A, B), multpath_combine(B, A))
    assert eq(multpath_combine(multpath_combine(A, B), C),
              multpath_combine(A, multpath_combine(B, C)))
    ident = Multpath(jnp.float32(np.inf), jnp.float32(0.0))
    assert eq(multpath_combine(A, ident), A)


centpaths = st.tuples(
    st.one_of(st.just(-np.inf), st.floats(0, 50).map(lambda x: float(int(x)))),
    st.floats(0, 4).map(lambda x: float(int(x * 4)) / 4),
    st.integers(0, 4).map(float),
)


@settings(max_examples=200, deadline=None)
@given(centpaths, centpaths, centpaths)
def test_centpath_monoid_laws(a, b, c):
    """⊗ is associative and commutative with identity (-inf, 0, 0)."""

    def mk(t):
        w, p, cc = t
        if not np.isfinite(w):
            p, cc = 0.0, 0.0
        return Centpath(jnp.float32(w), jnp.float32(p), jnp.float32(cc))

    def eq(x, y):
        if not np.array_equal(np.asarray(x.w), np.asarray(y.w), equal_nan=True):
            return False
        if not np.isfinite(x.w):
            return True
        return (np.asarray(x.p) == np.asarray(y.p)
                and np.asarray(x.c) == np.asarray(y.c))

    A, B, C = mk(a), mk(b), mk(c)
    assert eq(centpath_combine(A, B), centpath_combine(B, A))
    assert eq(centpath_combine(centpath_combine(A, B), C),
              centpath_combine(A, centpath_combine(B, C)))
    ident = Centpath(jnp.float32(-np.inf), jnp.float32(0.0), jnp.float32(0.0))
    assert eq(centpath_combine(A, ident), A)


@settings(max_examples=10, deadline=None)
@given(random_graphs(max_n=14), st.integers(1, 5))
def test_batch_size_invariance(g, nb):
    """λ must not depend on the batching (Algorithm 3 is batch-oblivious)."""
    lam_a = mfbc(g, n_b=nb)
    lam_b = mfbc(g, n_b=g.n)
    np.testing.assert_allclose(lam_a, lam_b, rtol=1e-5, atol=1e-7)
