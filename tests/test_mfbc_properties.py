"""Property-based tests (hypothesis) for the MFBC system invariants."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic sweep, see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import brandes_bc, mfbc, multpath_combine, centpath_combine
from repro.core.monoids import (Centpath, Multpath, centpath_relax_coo,
                                multpath_relax_coo)
from repro.graphs.formats import (ChunkedCSRBuilder, Graph, graph_digest,
                                  pad_edges)

import jax.numpy as jnp


@st.composite
def random_graphs(draw, max_n=24, max_w=6):
    n = draw(st.integers(min_value=3, max_value=max_n))
    nnz = draw(st.integers(min_value=2, max_value=min(n * (n - 1), 80)))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    weighted = draw(st.booleans())
    directed = draw(st.booleans())
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, nnz).astype(np.int32)
    dst = rng.integers(0, n, nnz).astype(np.int32)
    w = (rng.integers(1, max_w + 1, nnz) if weighted else np.ones(nnz)) \
        .astype(np.float32)
    g = Graph(n, src, dst, w, directed=directed).dedup()
    if not directed:
        g = g.symmetrize()
    if g.nnz == 0:  # all arcs were self loops; add one real edge
        g = Graph(n, np.array([0], np.int32), np.array([1], np.int32),
                  np.ones(1, np.float32), directed=True)
    return g


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_mfbc_equals_oracle_on_random_graphs(g):
    """End-to-end: MFBC == Brandes on arbitrary random graphs."""
    lam = mfbc(g, n_b=min(8, g.n), backend="coo")
    lam_ref = brandes_bc(g)
    np.testing.assert_allclose(lam, lam_ref, rtol=1e-4, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(random_graphs(max_n=16))
def test_bc_global_invariants(g):
    """λ ≥ 0 and Σ_v λ(v) = Σ_{s≠t reachable} (avg path interior length).

    The total Σ_v λ(v) equals Σ_{s,t} (expected number of interior vertices
    on a random shortest path) = Σ_{s,t} Σ_v σ(s,t,v)/σ̄(s,t); we check it
    against the oracle's total rather than a closed form, plus positivity
    and the zero-centrality of degree-boundary vertices on paths.
    """
    lam = mfbc(g, n_b=min(8, g.n), backend="dense")
    assert np.all(lam >= -1e-9)
    assert abs(lam.sum() - brandes_bc(g).sum()) < 1e-5 * max(1.0, lam.sum())


multpaths = st.tuples(
    st.one_of(st.just(np.inf), st.floats(0, 50).map(lambda x: float(int(x)))),
    st.integers(0, 5).map(float),
)


@settings(max_examples=200, deadline=None)
@given(multpaths, multpaths, multpaths)
def test_multpath_monoid_laws(a, b, c):
    """⊕ is associative and commutative with identity (inf, 0)."""

    def mk(t):
        w, m = t
        m = 0.0 if not np.isfinite(w) else m
        return Multpath(jnp.float32(w), jnp.float32(m))

    def eq(x, y):
        return (np.array_equal(np.asarray(x.w), np.asarray(y.w), equal_nan=True)
                and (not np.isfinite(x.w)
                     or np.asarray(x.m) == np.asarray(y.m)))

    A, B, C = mk(a), mk(b), mk(c)
    assert eq(multpath_combine(A, B), multpath_combine(B, A))
    assert eq(multpath_combine(multpath_combine(A, B), C),
              multpath_combine(A, multpath_combine(B, C)))
    ident = Multpath(jnp.float32(np.inf), jnp.float32(0.0))
    assert eq(multpath_combine(A, ident), A)


centpaths = st.tuples(
    st.one_of(st.just(-np.inf), st.floats(0, 50).map(lambda x: float(int(x)))),
    st.floats(0, 4).map(lambda x: float(int(x * 4)) / 4),
    st.integers(0, 4).map(float),
)


@settings(max_examples=200, deadline=None)
@given(centpaths, centpaths, centpaths)
def test_centpath_monoid_laws(a, b, c):
    """⊗ is associative and commutative with identity (-inf, 0, 0)."""

    def mk(t):
        w, p, cc = t
        if not np.isfinite(w):
            p, cc = 0.0, 0.0
        return Centpath(jnp.float32(w), jnp.float32(p), jnp.float32(cc))

    def eq(x, y):
        if not np.array_equal(np.asarray(x.w), np.asarray(y.w), equal_nan=True):
            return False
        if not np.isfinite(x.w):
            return True
        return (np.asarray(x.p) == np.asarray(y.p)
                and np.asarray(x.c) == np.asarray(y.c))

    A, B, C = mk(a), mk(b), mk(c)
    assert eq(centpath_combine(A, B), centpath_combine(B, A))
    assert eq(centpath_combine(centpath_combine(A, B), C),
              centpath_combine(A, centpath_combine(B, C)))
    ident = Centpath(jnp.float32(-np.inf), jnp.float32(0.0), jnp.float32(0.0))
    assert eq(centpath_combine(A, ident), A)


# ---------------------------------------------------------------------------
# graphs/formats invariants: the canonicalization the ingest subsystem
# promises to preserve bitwise regardless of chunking or arrival order.
# ---------------------------------------------------------------------------

@st.composite
def raw_arc_streams(draw, max_n=20, max_nnz=120):
    """A raw (pre-canonical) arc stream: duplicates and self loops allowed."""
    n = draw(st.integers(min_value=3, max_value=max_n))
    nnz = draw(st.integers(min_value=0, max_value=max_nnz))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    weighted = draw(st.booleans())
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, nnz).astype(np.int32)
    dst = rng.integers(0, n, nnz).astype(np.int32)
    w = (rng.random(nnz).astype(np.float32) + np.float32(0.25) if weighted
         else np.ones(nnz, np.float32))
    return n, src, dst, w


def _graphs_bitwise(a, b):
    return (a.n == b.n and a.directed == b.directed
            and np.array_equal(a.src, b.src) and np.array_equal(a.dst, b.dst)
            and np.array_equal(a.w, b.w))


@settings(max_examples=40, deadline=None)
@given(raw_arc_streams())
def test_dedup_idempotent(stream):
    """dedup is a projection: dedup ∘ dedup = dedup (bitwise)."""
    n, src, dst, w = stream
    g1 = Graph(n, src, dst, w).dedup()
    assert _graphs_bitwise(g1.dedup(), g1)


@settings(max_examples=40, deadline=None)
@given(raw_arc_streams())
def test_symmetrize_idempotent(stream):
    n, src, dst, w = stream
    s1 = Graph(n, src, dst, w).symmetrize()
    assert _graphs_bitwise(s1.symmetrize(), s1)


@settings(max_examples=40, deadline=None)
@given(raw_arc_streams())
def test_remove_isolated_idempotent(stream):
    """After one compaction every vertex is touched: the second is identity."""
    n, src, dst, w = stream
    g1, _ = Graph(n, src, dst, w).dedup().remove_isolated()
    g2, kept = g1.remove_isolated()
    assert _graphs_bitwise(g2, g1)
    assert np.array_equal(kept, np.arange(g1.n))


@settings(max_examples=30, deadline=None)
@given(raw_arc_streams(), st.sampled_from([1, 3, 17, 1_000_000]),
       st.integers(min_value=0, max_value=2**31 - 1),
       st.booleans(), st.booleans())
def test_streaming_build_order_independent(stream, chunk, perm_seed,
                                           symmetrize, remove_isolated):
    """Chunked, shuffled streaming == the in-memory pipeline, bitwise.

    The ChunkedCSRBuilder contract: any chunking × any arrival order of
    the same raw arcs produces identical arrays and an identical content
    digest to ``Graph(...).dedup()`` (+ symmetrize / remove_isolated).
    """
    n, src, dst, w = stream
    ref = Graph(n, src, dst, w)
    ref = ref.symmetrize() if symmetrize else ref.dedup()
    if remove_isolated:
        ref, _ = ref.remove_isolated()
    order = np.random.default_rng(perm_seed).permutation(src.shape[0])
    src, dst, w = src[order], dst[order], w[order]
    b = ChunkedCSRBuilder(n, symmetrize=symmetrize,
                          remove_isolated=remove_isolated)
    for lo in range(0, src.shape[0], chunk):
        b.add(src[lo:lo + chunk], dst[lo:lo + chunk], w[lo:lo + chunk])
    res = b.finalize()
    assert _graphs_bitwise(res.graph, ref)
    assert res.digest == graph_digest(ref)


@settings(max_examples=25, deadline=None)
@given(random_graphs(max_n=12), st.integers(min_value=0,
                                            max_value=2**31 - 1))
def test_pad_edges_inert_under_monoids(g, seed):
    """Padding arcs (sink self loop, w = inf) change no monoid relax.

    This is the algebraic fact the static-shape device path rests on:
    one COO relax step over the padded arrays equals the step over the
    raw arrays, bitwise, for both the forward (multpath) and backward
    (centpath) monoids — on arbitrary frontier states.
    """
    rng = np.random.default_rng(seed)
    nb = 4
    wf = np.where(rng.random((nb, g.n)) < 0.3, np.inf,
                  rng.integers(0, 8, (nb, g.n))).astype(np.float32)
    mf = np.where(np.isfinite(wf),
                  rng.integers(1, 4, (nb, g.n)), 0).astype(np.float32)
    src_p, dst_p, w_p = pad_edges(g, nnz_padded=g.nnz + 32, multiple=32)
    assert src_p.shape[0] > g.nnz  # the property must actually see padding

    F = Multpath(jnp.asarray(wf), jnp.asarray(mf))
    ref = multpath_relax_coo(F, jnp.asarray(g.src), jnp.asarray(g.dst),
                             jnp.asarray(g.w), g.n)
    pad = multpath_relax_coo(F, jnp.asarray(src_p), jnp.asarray(dst_p),
                             jnp.asarray(w_p), g.n)
    np.testing.assert_array_equal(np.asarray(ref.w), np.asarray(pad.w))
    np.testing.assert_array_equal(np.asarray(ref.m), np.asarray(pad.m))

    wb = np.where(rng.random((nb, g.n)) < 0.3, -np.inf,
                  rng.integers(0, 8, (nb, g.n))).astype(np.float32)
    pb = np.where(np.isfinite(wb),
                  rng.random((nb, g.n)), 0).astype(np.float32)
    cb = np.where(np.isfinite(wb),
                  rng.integers(0, 3, (nb, g.n)), 0).astype(np.float32)
    C = Centpath(jnp.asarray(wb), jnp.asarray(pb), jnp.asarray(cb))
    ref = centpath_relax_coo(C, jnp.asarray(g.src), jnp.asarray(g.dst),
                             jnp.asarray(g.w), g.n)
    pad = centpath_relax_coo(C, jnp.asarray(src_p), jnp.asarray(dst_p),
                             jnp.asarray(w_p), g.n)
    np.testing.assert_array_equal(np.asarray(ref.w), np.asarray(pad.w))
    np.testing.assert_array_equal(np.asarray(ref.p), np.asarray(pad.p))
    np.testing.assert_array_equal(np.asarray(ref.c), np.asarray(pad.c))


@settings(max_examples=30, deadline=None)
@given(raw_arc_streams(max_n=16, max_nnz=60))
def test_pad_edges_idempotent(stream):
    """Re-padding already-padded arrays to the same size is the identity."""
    n, src, dst, w = stream
    g = Graph(n, src, dst, w).dedup()
    src_p, dst_p, w_p = pad_edges(g, multiple=32)
    g_p = Graph(n, src_p, dst_p, w_p)
    src_q, dst_q, w_q = pad_edges(g_p, nnz_padded=src_p.shape[0],
                                  multiple=32)
    assert np.array_equal(src_p, src_q)
    assert np.array_equal(dst_p, dst_q)
    assert np.array_equal(w_p, w_q)


@settings(max_examples=10, deadline=None)
@given(random_graphs(max_n=14), st.integers(1, 5))
def test_batch_size_invariance(g, nb):
    """λ must not depend on the batching (Algorithm 3 is batch-oblivious)."""
    lam_a = mfbc(g, n_b=nb)
    lam_b = mfbc(g, n_b=g.n)
    np.testing.assert_allclose(lam_a, lam_b, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# frontier-sparse CSR engine: bitwise parity with the dense/COO relaxes,
# overflow fallback, padding inertness, and the count-carry loop regression.
# ---------------------------------------------------------------------------

def _batch_sources(g, nb, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, g.n, nb).astype(np.int32)


@settings(max_examples=20, deadline=None)
@given(random_graphs(), st.integers(min_value=0, max_value=2**31 - 1))
def test_csr_sweep_bitwise_matches_dense(g, seed):
    """CSR (Tw, Tm) == dense (Tw, Tm) *bitwise* on random weighted R-MAT
    style graphs (incl. disconnected and single-edge draws): the
    compacted relax scatters the same candidates into the same segment
    reduction the COO relax uses, so no float reassociation happens."""
    from repro.core.adjacency import (csr_adj_from_graph,
                                      dense_adj_from_graph)
    from repro.core.mfbf import mfbf

    nb = min(4, g.n)
    src = _batch_sources(g, nb, seed)
    d = dense_adj_from_graph(g, block=64)
    c = csr_adj_from_graph(g, n_b=nb)
    dw, dm = mfbf(d, jnp.asarray(src))
    cw, cm = mfbf(c, jnp.asarray(src))
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(cw))
    np.testing.assert_array_equal(np.asarray(dm), np.asarray(cm))


@settings(max_examples=15, deadline=None)
@given(random_graphs(max_n=16), st.integers(min_value=0,
                                            max_value=2**31 - 1))
def test_csr_overflow_fallback_parity(g, seed):
    """Forcing the capacity ladder to overflow (caps = ((1, 1),)) and
    forcing a multi-rung ladder that must escalate both produce results
    identical to the unconstrained build — the ladder changes work,
    never values."""
    from repro.core.adjacency import csr_adj_from_graph
    from repro.core.mfbc import mfbc_batch_moments

    nb = min(4, g.n)
    src = jnp.asarray(_batch_sources(g, nb, seed))
    val = jnp.ones(nb, bool)
    ref = mfbc_batch_moments(csr_adj_from_graph(g, n_b=nb), src, val)
    tiny = mfbc_batch_moments(
        csr_adj_from_graph(g, caps=((1, 1),)), src, val)
    ladder = mfbc_batch_moments(
        csr_adj_from_graph(g, caps=((1, 2), (4, 8), (16, 64))), src, val)
    for got in (tiny, ladder):
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=15, deadline=None)
@given(random_graphs(max_n=12), st.integers(min_value=0,
                                            max_value=2**31 - 1))
def test_csr_padding_rows_inert(g, seed):
    """CSR built over padded arc arrays == CSR over the raw arrays,
    bitwise: the ``(n-1) -> (n-1)`` w = inf padding arcs are
    algebraically inert through the compacted expansion too."""
    from repro.core.adjacency import csr_adj_from_graph
    from repro.core.mfbf import mfbf

    nb = min(4, g.n)
    src = jnp.asarray(_batch_sources(g, nb, seed))
    raw = csr_adj_from_graph(g, n_b=nb, pad_multiple=1)
    padded = csr_adj_from_graph(g, n_b=nb, pad_multiple=32)
    assert padded.src.shape[0] > raw.src.shape[0] or g.nnz % 32 == 0
    rw, rm = mfbf(raw, src)
    pw, pm = mfbf(padded, src)
    np.testing.assert_array_equal(np.asarray(rw), np.asarray(pw))
    np.testing.assert_array_equal(np.asarray(rm), np.asarray(pm))


@settings(max_examples=10, deadline=None)
@given(random_graphs(max_n=14), st.integers(min_value=0,
                                            max_value=2**31 - 1))
def test_mfbf_count_carry_and_trace_bitwise(g, seed):
    """Satellite 6 regression: the while-loop cond now tests an active
    count carried through the step instead of re-scanning the (n_b, n)
    frontier — while == fori == traced-while, bitwise, and the trace's
    iteration count equals the sweep's."""
    from repro.core.adjacency import coo_adj_from_graph
    from repro.core.mfbf import TRACE_CAP, mfbf

    nb = min(4, g.n)
    src = jnp.asarray(_batch_sources(g, nb, seed))
    adj = coo_adj_from_graph(g)
    ww, wm = mfbf(adj, src, iterate="while")
    fw, fm = mfbf(adj, src, iterate="fori")
    tw, tm, tr = mfbf(adj, src, iterate="while", trace=True)
    np.testing.assert_array_equal(np.asarray(ww), np.asarray(fw))
    np.testing.assert_array_equal(np.asarray(wm), np.asarray(fm))
    np.testing.assert_array_equal(np.asarray(ww), np.asarray(tw))
    np.testing.assert_array_equal(np.asarray(wm), np.asarray(tm))
    iters = int(tr.iters)
    fnnz = np.asarray(tr.fnnz)
    assert 0 <= iters <= g.n + 1
    # every recorded iteration saw a non-empty incoming frontier, and
    # slots past the sweep keep the -1 fill from empty_trace()
    assert np.all(fnnz[:min(iters, TRACE_CAP)] > 0)
    if iters < TRACE_CAP:
        assert np.all(fnnz[iters:] == -1)


@settings(max_examples=20, deadline=None)
@given(random_graphs(max_n=16), st.integers(min_value=0,
                                            max_value=2**31 - 1),
       st.integers(min_value=1, max_value=6))
def test_gather_rows_scatter_matches_hit_matrix(g, seed, nb):
    """Satellite 1: the O(E log nb + nb·n) scatter gather_rows equals the
    old (nb, E) boolean hit-matrix implementation bitwise — including
    duplicate sources, which must all read the same row."""
    from repro.core.adjacency import coo_adj_from_graph, csr_adj_from_graph

    rng = np.random.default_rng(seed)
    sources = rng.integers(0, g.n, nb).astype(np.int32)
    if nb >= 2:
        sources[-1] = sources[0]  # force a duplicate

    def old_hit_matrix(src, dst, w, n, srcs):
        hit = np.asarray(src)[None, :] == srcs[:, None]  # (nb, E)
        cand = np.where(hit, np.asarray(w)[None, :], np.inf)
        out = np.full((srcs.shape[0], n), np.inf, np.float32)
        for b in range(srcs.shape[0]):
            np.minimum.at(out[b], np.asarray(dst), cand[b])
        return out

    for adj in (coo_adj_from_graph(g), csr_adj_from_graph(g, n_b=nb)):
        got = np.asarray(adj.gather_rows(jnp.asarray(sources)))
        ref = old_hit_matrix(adj.src, adj.dst, adj.w, g.n, sources)
        np.testing.assert_array_equal(got, ref)
