"""Multi-device SpGEMM integration check — run as a subprocess with 8 CPU
devices (spawned by tests/test_spgemm.py; keeps the main pytest session on
1 device per the dry-run isolation rule)."""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import monoids
from repro.core.monoids import Centpath, Multpath
from repro.roofline.hlo_parse import collective_bytes
from repro.spgemm import (Plan, ProblemSizes, arithmetic, autotune, centpath,
                          multpath, plan_cost, plan_specs, spgemm)

M, K, N = 32, 48, 64
rng = np.random.default_rng(0)


def check(cond, msg):
    assert cond, msg
    print("ok:", msg)


def run_arith(mesh, plan):
    a = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    with mesh:
        c = spgemm(a, b, mesh, plan, arithmetic)
    ref = a @ b
    np.testing.assert_allclose(np.asarray(c), np.asarray(ref), rtol=2e-4,
                               atol=1e-4)
    print(f"ok: arith {plan.variant}@{plan.axes}")


def run_multpath(mesh, plan):
    fw = rng.integers(0, 12, (M, K)).astype(np.float32)
    act = rng.random((M, K)) < 0.6
    fw = np.where(act, fw, np.inf).astype(np.float32)
    fm = np.where(act, rng.integers(1, 4, (M, K)), 0).astype(np.float32)
    adj = rng.integers(1, 9, (K, N)).astype(np.float32)
    adj = np.where(rng.random((K, N)) < 0.4, adj, np.inf).astype(np.float32)
    F = Multpath(jnp.asarray(fw), jnp.asarray(fm))
    B = jnp.asarray(adj)
    with mesh:
        c = spgemm(F, B, mesh, plan, multpath)
    ref = monoids.multpath_relax_dense(F, B)
    np.testing.assert_array_equal(np.asarray(c.w), np.asarray(ref.w))
    np.testing.assert_allclose(np.asarray(c.m), np.asarray(ref.m), rtol=1e-6)
    print(f"ok: multpath {plan.variant}@{plan.axes}")


def run_centpath(mesh, plan):
    fw = rng.integers(0, 12, (M, K)).astype(np.float32)
    act = rng.random((M, K)) < 0.6
    fw = np.where(act, fw, -np.inf).astype(np.float32)
    fp = np.where(act, rng.random((M, K)), 0).astype(np.float32)
    adj = rng.integers(1, 9, (K, N)).astype(np.float32)
    adj = np.where(rng.random((K, N)) < 0.4, adj, np.inf).astype(np.float32)
    F = Centpath(jnp.asarray(fw), jnp.asarray(fp),
                 jnp.asarray((fp > 0).astype(np.float32)))
    B = jnp.asarray(adj)
    with mesh:
        c = spgemm(F, B, mesh, plan, centpath)
    ref = monoids.centpath_relax_dense(F, B)
    np.testing.assert_array_equal(np.asarray(c.w), np.asarray(ref.w))
    np.testing.assert_allclose(np.asarray(c.p), np.asarray(ref.p), rtol=1e-5)
    print(f"ok: centpath {plan.variant}@{plan.axes}")


def run_hlo_bytes(mesh, plan, axes):
    """Predicted collective bytes ≈ HLO-measured wire bytes (order 2x)."""
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    sa, sb, _ = plan_specs(plan)
    with mesh:
        f = jax.jit(lambda x, y: spgemm(x, y, mesh, plan, arithmetic),
                    in_shardings=(jax.sharding.NamedSharding(mesh, sa),
                                  jax.sharding.NamedSharding(mesh, sb)))
        compiled = f.lower(a, b).compile()
    stats = collective_bytes(compiled.as_text())
    pred = plan_cost(plan, ProblemSizes(M * K * 4, K * N * 4, M * N * 4), axes)
    meas = stats["wire_bytes"]
    # measured is per-device; predicted is per-device too.
    ratio = meas / max(pred.bytes_moved, 1.0)
    check(0.2 < ratio < 5.0,
          f"hlo bytes {plan.variant}: measured={meas:.0f} "
          f"predicted={pred.bytes_moved:.0f} ratio={ratio:.2f}")


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh1 = jax.make_mesh((8,), ("q",))
    mesh2 = jax.make_mesh((4, 2), ("r", "c"))
    mesh3 = jax.make_mesh((2, 2, 2), ("p1", "r", "c"))

    for var in ("1d_a", "1d_b", "1d_c"):
        run_arith(mesh1, Plan(var, ("q",)))
        run_multpath(mesh1, Plan(var, ("q",)))
    for var in ("2d_ab", "2d_ac", "2d_bc"):
        run_arith(mesh2, Plan(var, ("r", "c")))
        run_multpath(mesh2, Plan(var, ("r", "c")))
        run_centpath(mesh2, Plan(var, ("r", "c")))
    for var in ("3d_l_ab", "3d_r_ac", "3d_r_bc", "3d_c_ab", "3d_c_bc"):
        run_arith(mesh3, Plan(var, ("p1", "r", "c")))
        run_multpath(mesh3, Plan(var, ("p1", "r", "c")))

    run_hlo_bytes(mesh2, Plan("2d_ab", ("r", "c")), {"r": 4, "c": 2})
    run_hlo_bytes(mesh2, Plan("2d_ac", ("r", "c")), {"r": 4, "c": 2})
    run_hlo_bytes(mesh1, Plan("1d_a", ("q",)), {"q": 8})

    # autotune returns a runnable plan
    best = autotune(ProblemSizes(M * K * 4, K * N * 4, M * N * 4),
                    {"r": 4, "c": 2})
    run_arith(mesh2, best.plan)
    print("ALL-OK")


if __name__ == "__main__":
    main()
