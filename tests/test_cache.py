"""Result-cache semantics + the checkpointed refine contract.

Three layers under test, service-side (no HTTP — that's
``test_gateway.py``):

* ``serve.cache.ResultCache`` — the hit/refine/miss state machine over
  (digest, δ, k, rule, tier) keys with ε ordered, tightest-entry-wins
  inserts, and the LRU eviction cap;
* ``repro.bc.refine`` — checkpoint snapshots and the bitwise resume
  contract: a loose-ε service run refined to a tighter ε must equal a
  from-scratch tight run over the same (seed, rid) stream, bit for bit;
* the ``BCResponse`` JSON wire form — numpy-free payloads that
  round-trip float64 exactly, pinned by a golden fixture.
"""
import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.bc import ApproxCheckpoint, resume_approx
from repro.graphs.generators import rmat
from repro.serve.bc_service import BCRequest, BCResponse, BCService
from repro.serve.cache import HIT, MISS, REFINE, ResultCache

_CACHE = {}


def _graph():
    if "g" not in _CACHE:
        g = rmat(6, 8, seed=5)
        g, _ = g.remove_isolated()
        _CACHE["g"] = g
    return _CACHE["g"]


def _ckpt_stub(n: int = 4) -> ApproxCheckpoint:
    return ApproxCheckpoint(n=n, eps=0.1, delta=0.1, rule="normal", n_b=n,
                            s1=np.zeros(n), s2=np.zeros(n), tau=0,
                            n_epochs=0, sampler_state={}, prefix_exact=True)


_KW = dict(delta=0.1, k=10, rule="normal", tier="normal")


# ---------------------------------------------------------- state machine
def test_lookup_state_machine():
    """ε ordering: tighter-or-equal cached → HIT, looser cached with a
    checkpoint → REFINE, empty → MISS."""
    c = ResultCache()
    assert c.lookup("d1", eps=0.05, **_KW) == (None, MISS)
    c.put("d1", eps=0.1, payload={"v": 1}, checkpoint=_ckpt_stub(), **_KW)
    entry, kind = c.lookup("d1", eps=0.1, **_KW)  # equal ε
    assert kind == HIT and entry.payload == {"v": 1}
    _, kind = c.lookup("d1", eps=0.2, **_KW)  # looser request
    assert kind == HIT
    entry, kind = c.lookup("d1", eps=0.05, **_KW)  # tighter request
    assert kind == REFINE and entry.checkpoint is not None
    assert c.stats()["hits"] == 2 and c.stats()["refines"] == 1


def test_refine_requires_checkpoint():
    """A looser entry with no checkpoint cannot satisfy a tighter request
    — reported as MISS, never as a silent loose answer."""
    c = ResultCache()
    c.put("d1", eps=0.1, payload={}, checkpoint=None, **_KW)
    assert c.lookup("d1", eps=0.05, **_KW) == (None, MISS)
    _, kind = c.lookup("d1", eps=0.1, **_KW)
    assert kind == HIT


def test_key_mismatches_miss():
    """Any differing key component — digest, δ, k, rule, tier, metric —
    misses: those change the answer, not just its accuracy."""
    c = ResultCache()
    c.put("d1", eps=0.1, payload={}, checkpoint=_ckpt_stub(), **_KW)
    assert c.lookup("d2", eps=0.1, **_KW)[1] == MISS  # digest
    for field, other in [("delta", 0.05), ("k", 5),
                         ("rule", "bernstein"), ("tier", "batch"),
                         ("metric", "closeness")]:
        kw = {**_KW, field: other}
        assert c.lookup("d1", eps=0.1, **kw)[1] == MISS, field
    assert c.lookup(None, eps=0.1, **_KW)[1] == MISS  # digest-less graph


def test_metric_keyed_entries_never_collide():
    """Same (digest, ε, δ, k, rule, tier) under different metrics are
    different analytics: each metric keeps its own entry, its own
    tightest-ε rule and its own refine path."""
    c = ResultCache()
    for m in ("betweenness", "closeness", "khop:2", "khop:3"):
        c.put("d1", eps=0.1, payload={"metric": m},
              checkpoint=_ckpt_stub(), **_KW, metric=m)
    assert len(c) == 4  # no shared slots across metrics (or hop bounds)
    for m in ("betweenness", "closeness", "khop:2", "khop:3"):
        entry, kind = c.lookup("d1", eps=0.1, **_KW, metric=m)
        assert kind == HIT and entry.payload == {"metric": m}, m
    # tightest-ε-wins holds per metric: a tight closeness put does not
    # shadow (or get shadowed by) the betweenness entry
    c.put("d1", eps=0.01, payload={"metric": "closeness", "tight": True},
          checkpoint=_ckpt_stub(), **_KW, metric="closeness")
    entry, kind = c.lookup("d1", eps=0.1, **_KW, metric="closeness")
    assert kind == HIT and entry.payload.get("tight")
    entry, kind = c.lookup("d1", eps=0.05, **_KW, metric="betweenness")
    assert kind == REFINE  # betweenness still at ε=0.1, refines
    # and the default-metric key is betweenness: omitting the kwarg
    # resolves to the same entry
    entry, kind = c.lookup("d1", eps=0.1, **_KW)
    assert kind == HIT and entry.payload == {"metric": "betweenness"}


def test_put_keeps_tightest_entry():
    """A looser result never overwrites a tighter cached one."""
    c = ResultCache()
    c.put("d1", eps=0.05, payload={"tight": True}, **_KW)
    entry = c.put("d1", eps=0.2, payload={"loose": True}, **_KW)
    assert entry.eps == 0.05  # the tighter entry survived
    got, kind = c.lookup("d1", eps=0.1, **_KW)
    assert kind == HIT and got.payload == {"tight": True}
    assert len(c) == 1


def test_lru_eviction_cap():
    """Insertions past max_entries evict least-recently-used keys; a
    lookup refreshes recency."""
    c = ResultCache(max_entries=3)
    for i in range(3):
        c.put(f"d{i}", eps=0.1, payload={"i": i}, **_KW)
    c.lookup("d0", eps=0.1, **_KW)  # refresh d0: d1 is now LRU
    c.put("d3", eps=0.1, payload={"i": 3}, **_KW)
    assert len(c) == 3 and c.evictions == 1
    assert c.lookup("d1", eps=0.1, **_KW)[1] == MISS  # evicted
    assert c.lookup("d0", eps=0.1, **_KW)[1] == HIT  # survived

    with pytest.raises(ValueError, match="max_entries"):
        ResultCache(max_entries=0)


# --------------------------------------------------------- refine contract
def _serve_one(eps: float, *, rid: int = 0, k: int = 10) -> BCResponse:
    """One checkpointing service run; rid pins the (seed, rid) stream."""
    svc = BCService({"web": _graph()}, checkpoints=True)
    svc.submit(BCRequest(rid=rid, graph="web", eps=eps, delta=0.1,
                         k=k, rule="normal"))
    out = svc.run()
    assert len(out) == 1 and not svc.exhausted
    return out[0], svc


def test_refined_bitwise_equals_scratch_tight():
    """The headline contract: loose run + checkpointed refine to tight ε
    == from-scratch tight run over the same stream, bitwise."""
    loose, svc = _serve_one(0.15)
    assert loose.checkpoint is not None and loose.checkpoint.prefix_exact
    ex = svc.executor_for("web")
    refined, _ = resume_approx(ex, loose.checkpoint, eps=0.05, topk=10)

    scratch, _ = _serve_one(0.05)
    ids = refined.topk(10)
    assert ids.tolist() == scratch.topk
    assert np.array_equal(refined.lam[ids], scratch.lam)
    assert np.array_equal(refined.halfwidth[ids], scratch.halfwidth)
    assert refined.n_samples == scratch.n_samples
    assert refined.n_epochs == scratch.n_epochs
    assert refined.converged


def test_refine_reuses_cached_samples():
    """Refinement continues from the cached sums — it never draws fewer
    samples than the loose run already paid for, and when the cached
    sums already certify the tighter ε it draws none at all."""
    loose, svc = _serve_one(0.2)
    ex = svc.executor_for("web")
    refined, ckpt2 = resume_approx(ex, loose.checkpoint, eps=0.1, topk=10)
    assert refined.n_samples >= loose.n_samples
    # the returned checkpoint snapshots the refined run (chainable)
    assert ckpt2.n_epochs == refined.n_epochs
    refined2, _ = resume_approx(ex, ckpt2, eps=0.05, topk=10)
    assert refined2.n_samples >= refined.n_samples


def test_capped_run_checkpoint_not_prefix_exact():
    """A run truncated by its Hoeffding cap records prefix_exact=False:
    its stream no longer matches a scratch run's, so the bitwise claim
    is off (refinement still statistically valid)."""
    g = _graph()
    svc = BCService({"web": g}, checkpoints=True)
    # bernstein at ε=0.1 caps well before the empirical rule fires
    svc.submit(BCRequest(rid=0, graph="web", eps=0.1, delta=0.1,
                         rule="bernstein"))
    out = svc.run()
    ck = out[0].checkpoint
    assert ck is not None and not ck.prefix_exact


def test_no_checkpoint_by_default():
    """checkpoints=False (the default) keeps responses lean."""
    svc = BCService({"web": _graph()})
    svc.submit(BCRequest(rid=0, graph="web", eps=0.2))
    assert svc.run()[0].checkpoint is None


# ------------------------------------------------------------- wire form
def test_response_json_roundtrip():
    """to_json → dumps → loads → from_json restores every field, float64
    bit-exactly (shortest-repr float serialization is lossless)."""
    resp, _ = _serve_one(0.15)
    d = json.loads(json.dumps(resp.to_json()))
    back = BCResponse.from_json(d)
    assert back.rid == resp.rid and back.graph == resp.graph
    assert back.topk == resp.topk
    assert np.array_equal(back.lam, np.asarray(resp.lam))
    assert np.array_equal(back.halfwidth, np.asarray(resp.halfwidth))
    assert (back.n_samples, back.n_epochs, back.converged) == \
        (resp.n_samples, resp.n_epochs, resp.converged)
    assert back.digest == resp.digest and back.tier == resp.tier
    assert back.plan is not None
    assert dataclasses.asdict(back.plan) == dataclasses.asdict(resp.plan)
    # nothing numpy leaks onto the wire
    def _no_numpy(v):
        if isinstance(v, dict):
            return all(_no_numpy(x) for x in v.values())
        if isinstance(v, (list, tuple)):
            return all(_no_numpy(x) for x in v)
        return not isinstance(v, np.generic) and not isinstance(v, np.ndarray)
    assert _no_numpy(resp.to_json())


def test_response_golden_fixture():
    """The wire schema is pinned by a checked-in fixture: from_json must
    accept it and to_json must reproduce it byte-for-byte. Breaking
    either means a gateway client just broke — update the fixture
    deliberately, not incidentally."""
    path = pathlib.Path(__file__).parent / "data" / "bc_response_golden.json"
    golden = json.loads(path.read_text())
    resp = BCResponse.from_json(golden)
    assert resp.to_json() == golden
